# CPU gym-style comparators (DESIGN.md §Substitutions): a per-step,
# object-per-car numpy simulator + a numpy PPO, standing in for the
# EV2Gym/Chargym/SustainGym + SB3 rows of Table 2.
