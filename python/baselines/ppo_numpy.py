"""Minimal numpy PPO (SB3-style CPU training loop) for the gym comparator.

Same algorithm family and hyperparameters as the fused JAX PPO (Table 3):
MLP actor-critic with concatenated categorical heads, GAE, clipped
surrogate, Adam. Used only by bench_gym.py to time the Table 2
"PPO (1)" / "PPO (16)" baseline rows.
"""

from __future__ import annotations

from typing import List

import numpy as np

from .gym_env import GymChargingEnv


class NumpyMlp:
    def __init__(self, rng, obs_dim: int, hidden: int, n_logits: int):
        def init(rows, cols, scale):
            return (rng.standard_normal((rows, cols)) * scale / np.sqrt(rows)).astype(
                np.float32
            )

        self.w1 = init(obs_dim, hidden, 1.4)
        self.b1 = np.zeros(hidden, np.float32)
        self.w2 = init(hidden, hidden, 1.4)
        self.b2 = np.zeros(hidden, np.float32)
        self.wpi = init(hidden, n_logits, 0.01)
        self.bpi = np.zeros(n_logits, np.float32)
        self.wv = init(hidden, 1, 1.0)
        self.bv = np.zeros(1, np.float32)

    def params(self):
        return [self.w1, self.b1, self.w2, self.b2, self.wpi, self.bpi, self.wv, self.bv]

    def forward(self, obs):
        h1 = np.tanh(obs @ self.w1 + self.b1)
        h2 = np.tanh(h1 @ self.w2 + self.b2)
        logits = h2 @ self.wpi + self.bpi
        value = (h2 @ self.wv + self.bv)[:, 0]
        return h1, h2, logits, value

    def backward(self, obs, h1, h2, dlogits, dvalue):
        dh2 = dlogits @ self.wpi.T + dvalue[:, None] @ self.wv.T
        g_wpi = h2.T @ dlogits
        g_bpi = dlogits.sum(0)
        g_wv = h2.T @ dvalue[:, None]
        g_bv = dvalue.sum(0, keepdims=True)
        dh2 = dh2 * (1 - h2 * h2)
        g_w2 = h1.T @ dh2
        g_b2 = dh2.sum(0)
        dh1 = dh2 @ self.w2.T * (1 - h1 * h1)
        g_w1 = obs.T @ dh1
        g_b1 = dh1.sum(0)
        return [g_w1, g_b1, g_w2, g_b2, g_wpi, g_bpi, g_wv, g_bv]


class Adam:
    def __init__(self, params: List[np.ndarray], lr=2.5e-4):
        self.m = [np.zeros_like(p) for p in params]
        self.v = [np.zeros_like(p) for p in params]
        self.t = 0
        self.lr = lr

    def step(self, params, grads):
        self.t += 1
        b1c = 1 - 0.9**self.t
        b2c = 1 - 0.999**self.t
        for p, g, m, v in zip(params, grads, self.m, self.v):
            m[:] = 0.9 * m + 0.1 * g
            v[:] = 0.999 * v + 0.001 * g * g
            p -= self.lr * (m / b1c) / (np.sqrt(v / b2c) + 1e-8)


def head_slices(nvec):
    out, ofs = [], 0
    for n in nvec:
        out.append((ofs, ofs + n))
        ofs += n
    return out


class NumpyPpo:
    def __init__(self, envs: List[GymChargingEnv], seed=0, hidden=128,
                 rollout_steps=300, n_minibatches=4, update_epochs=4):
        self.envs = envs
        self.rng = np.random.default_rng(seed)
        self.nvec = envs[0].action_nvec()
        self.slices = head_slices(self.nvec)
        self.n_logits = sum(self.nvec)
        self.mlp = NumpyMlp(self.rng, envs[0].obs_dim, hidden, self.n_logits)
        self.adam = Adam(self.mlp.params())
        self.rollout_steps = rollout_steps
        self.n_minibatches = n_minibatches
        self.update_epochs = update_epochs
        self.obs = np.stack([e.observe() for e in envs])
        self.gamma, self.lam = 0.99, 0.95
        self.clip_eps, self.vf_clip = 0.2, 10.0
        self.ent_coef, self.vf_coef = 0.01, 0.25

    def _sample(self, logits):
        e = logits.shape[0]
        actions = np.zeros((e, len(self.nvec)), np.int64)
        logp = np.zeros(e, np.float32)
        for h, (s, t) in enumerate(self.slices):
            lg = logits[:, s:t]
            lg = lg - lg.max(1, keepdims=True)
            p = np.exp(lg)
            p /= p.sum(1, keepdims=True)
            for i in range(e):
                a = self.rng.choice(t - s, p=p[i])
                actions[i, h] = a
                logp[i] += np.log(p[i, a] + 1e-12)
        return actions, logp

    def _logp_ent(self, logits, actions):
        b = logits.shape[0]
        logp = np.zeros(b, np.float32)
        ent = np.zeros(b, np.float32)
        dlogp = np.zeros_like(logits)
        dent = np.zeros_like(logits)
        for h, (s, t) in enumerate(self.slices):
            lg = logits[:, s:t] - logits[:, s:t].max(1, keepdims=True)
            p = np.exp(lg)
            p /= p.sum(1, keepdims=True)
            lp = np.log(p + 1e-12)
            a = actions[:, h]
            logp += lp[np.arange(b), a]
            hent = -(p * lp).sum(1)
            ent += hent
            dlogp[:, s:t] = -p
            dlogp[np.arange(b), s + a] += 1.0
            dent[:, s:t] = -p * (lp + hent[:, None])
        return logp, ent, dlogp, dent

    def iteration(self):
        e = len(self.envs)
        t_len = self.rollout_steps
        obs_b, act_b, logp_b, val_b, rew_b, done_b = [], [], [], [], [], []
        for _ in range(t_len):
            _, _, logits, value = self.mlp.forward(self.obs)
            actions, logp = self._sample(logits)
            obs_b.append(self.obs.copy())
            new_obs = np.empty_like(self.obs)
            rew = np.zeros(e, np.float32)
            done = np.zeros(e, np.float32)
            for i, env in enumerate(self.envs):
                o, r, d, _ = env.step(actions[i])
                new_obs[i], rew[i], done[i] = o, r, d
            self.obs = new_obs
            act_b.append(actions)
            logp_b.append(logp)
            val_b.append(value)
            rew_b.append(rew)
            done_b.append(done)
        obs_b = np.asarray(obs_b)
        act_b = np.asarray(act_b)
        logp_b = np.asarray(logp_b)
        val_b = np.asarray(val_b)
        rew_b = np.asarray(rew_b)
        done_b = np.asarray(done_b)
        _, _, _, last_v = self.mlp.forward(self.obs)

        adv = np.zeros_like(rew_b)
        g = np.zeros(e, np.float32)
        for t in range(t_len - 1, -1, -1):
            nv = last_v if t == t_len - 1 else val_b[t + 1]
            nonterm = 1.0 - done_b[t]
            delta = rew_b[t] + self.gamma * nv * nonterm - val_b[t]
            g = delta + self.gamma * self.lam * nonterm * g
            adv[t] = g
        targets = adv + val_b

        bsz = e * t_len
        flat = lambda x: x.reshape(bsz, *x.shape[2:])
        obs_f, act_f, logp_f, val_f = flat(obs_b), flat(act_b), flat(logp_b), flat(val_b)
        adv_f, tgt_f = flat(adv), flat(targets)
        mb = bsz // self.n_minibatches
        for _ in range(self.update_epochs):
            perm = self.rng.permutation(bsz)
            for k in range(self.n_minibatches):
                idx = perm[k * mb : (k + 1) * mb]
                self._update(obs_f[idx], act_f[idx], logp_f[idx], val_f[idx],
                             adv_f[idx], tgt_f[idx])
        return float(rew_b.mean())

    def _update(self, obs, act, old_logp, old_v, adv, tgt):
        b = obs.shape[0]
        a_n = (adv - adv.mean()) / (adv.std() + 1e-8)
        h1, h2, logits, value = self.mlp.forward(obs)
        logp, ent, dlogp, dent = self._logp_ent(logits, act)
        ratio = np.exp(logp - old_logp)
        clipped = np.clip(ratio, 1 - self.clip_eps, 1 + self.clip_eps)
        use_unclipped = ratio * a_n <= clipped * a_n
        dpg = np.where(use_unclipped, -ratio * a_n, 0.0)
        v_clip = old_v + np.clip(value - old_v, -self.vf_clip, self.vf_clip)
        e1 = (value - tgt) ** 2
        e2 = (v_clip - tgt) ** 2
        dv = np.where(e1 >= e2, value - tgt, np.where(
            np.abs(value - old_v) < self.vf_clip, v_clip - tgt, 0.0))
        dlogits = (dpg[:, None] * dlogp - self.ent_coef * dent) / b
        dvalue = (self.vf_coef * dv / b).astype(np.float32)
        grads = self.mlp.backward(obs, h1, h2, dlogits.astype(np.float32), dvalue)
        norm = np.sqrt(sum((g * g).sum() for g in grads))
        if norm > 100.0:
            grads = [g * (100.0 / norm) for g in grads]
        self.adam.step(self.mlp.params(), grads)
