"""Timing harness for the python gym comparator (Table 2 rows).

Prints one JSON object: {"mode": ..., "steps": N, "seconds_per_100k": S}.
Invoked by `chargax bench table2` as a subprocess — this is a *comparator*,
not part of the system; the chargax hot path never calls Python.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from .gym_env import GymChargingEnv, default_tables
from .ppo_numpy import NumpyPpo


def bench_random(steps: int) -> float:
    import numpy as np

    env = GymChargingEnv(default_tables(), seed=0)
    nvec = env.action_nvec()
    rng = np.random.default_rng(1)
    actions = rng.integers(0, nvec, size=(steps, len(nvec)))
    t0 = time.perf_counter()
    for i in range(steps):
        env.step(actions[i])
    return (time.perf_counter() - t0) * 100_000 / steps


def bench_ppo(steps: int, num_envs: int) -> float:
    envs = [GymChargingEnv(default_tables(), seed=i) for i in range(num_envs)]
    ppo = NumpyPpo(envs, seed=0)
    ppo.iteration()  # warm numpy caches
    per_iter = num_envs * ppo.rollout_steps
    iters = max(steps // per_iter, 1)
    t0 = time.perf_counter()
    for _ in range(iters):
        ppo.iteration()
    el = time.perf_counter() - t0
    return el * 100_000 / (iters * per_iter)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["random", "ppo1", "ppo16"], required=True)
    ap.add_argument("--steps", type=int, default=20_000)
    args = ap.parse_args()
    if args.mode == "random":
        sec = bench_random(args.steps)
    elif args.mode == "ppo1":
        sec = bench_ppo(args.steps, 1)
    else:
        sec = bench_ppo(args.steps, 16)
    print(json.dumps({"mode": args.mode, "steps": args.steps, "seconds_per_100k": sec}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
