"""Per-step, object-oriented Python EV-charging environment.

Architecturally this mirrors the paper's comparison environments
(SustainGym / Chargym / EV2Gym): a Gym-style class with per-car Python
objects, per-step method calls and host-side numpy RNG. Semantically it is
the same MDP as the Chargax JAX env (same transition order, same charging
curve, same reward family), which makes it the *fair* CPU comparator for
Table 2 — the measured difference is the architecture, not the model.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

STEPS_PER_EPISODE = 288
DT_HOURS = 1.0 / 12.0
STEPS_PER_HOUR = 12
N_LEVELS = 11
N_LEVELS_BATTERY = 21
MAX_ARRIVALS = 6
FIXED_COST_PER_STEP = 0.25


def charging_curve(soc: float, r_bar: float, tau: float) -> float:
    if soc <= tau:
        return r_bar
    return max((1.0 - soc) * r_bar / max(1.0 - tau, 1e-9), 0.0)


def discharging_curve(soc: float, r_bar: float, tau: float) -> float:
    return charging_curve(1.0 - soc, r_bar, tau)


@dataclass
class Car:
    soc: float
    de_remain: float
    dt_remain: float
    cap: float
    r_bar: float
    tau: float
    charge_sensitive: bool


@dataclass
class Evse:
    voltage: float
    i_max: float
    eta: float
    is_dc: bool
    car: Optional[Car] = None
    i_drawn: float = 0.0

    @property
    def p_max(self) -> float:
        return self.voltage * self.i_max / 1000.0


@dataclass
class Node:
    name: str
    ports: List[int]
    limit_kw: float
    eta: float


@dataclass
class Battery:
    capacity: float = 200.0
    p_max: float = 100.0
    voltage: float = 400.0
    tau: float = 0.8
    soc: float = 0.5
    i_drawn: float = 0.0


class GymChargingEnv:
    """Gym-like EV charging station (per-step CPU loop)."""

    def __init__(
        self,
        tables: dict,
        n_dc: int = 10,
        n_ac: int = 6,
        seed: int = 0,
        v2g: bool = False,
        grid_capacity_kw: Optional[float] = None,
        grid_policy: str = "proportional",
    ):
        self.rng = np.random.default_rng(seed)
        self.tables = tables
        # V2G: car ports use the battery's symmetric signed ladder
        # (N_LEVELS_BATTERY levels over [-1, 1]) instead of the unipolar
        # charge-only ladder; mirrors rust env/core.rs step_lane.
        self.v2g = v2g
        # Feeder coupling: a finite grid_capacity_kw turns on the rust
        # propose -> allocate -> commit semantics for this single station
        # (a one-member coupling group): when the post-projection proposed
        # grid draw exceeds the capacity, either every staged current is
        # scaled by capacity/proposed ("proportional") or the step's buy
        # price is multiplied by proposed/capacity ("price-feedback"), and
        # a normalized feeder-headroom column is appended to observations.
        # Mirrors rust env/core.rs proposed_grid_kw / commit_lane and
        # fleet/grid.rs allocate / headroom.
        if grid_policy not in ("proportional", "price-feedback"):
            raise ValueError(f"unknown grid_policy {grid_policy!r}")
        self.grid_capacity_kw = grid_capacity_kw
        self.grid_policy = grid_policy
        self.grid_headroom = 1.0
        self.evses: List[Evse] = [
            Evse(voltage=400.0, i_max=375.0, eta=0.95, is_dc=True) for _ in range(n_dc)
        ] + [
            Evse(voltage=230.0, i_max=50.0, eta=0.95, is_dc=False) for _ in range(n_ac)
        ]
        c = len(self.evses)
        self.battery = Battery()
        self.nodes = [Node("root", list(range(c + 1)), 600.0, 0.98)]
        if n_dc:
            self.nodes.append(Node("dc", list(range(n_dc)), 450.0, 0.98))
        if n_ac:
            self.nodes.append(Node("ac", list(range(n_dc, c)), 60.0, 0.98))
        self.t = 0
        self.day = 0
        self.reset()

    # -- gym API -------------------------------------------------------------

    @property
    def n_ports(self) -> int:
        return len(self.evses) + 1

    @property
    def obs_dim(self) -> int:
        coupled = 1 if self.grid_capacity_kw is not None else 0
        return 6 * len(self.evses) + 3 + 4 + 4 + coupled

    def action_nvec(self) -> List[int]:
        car_levels = N_LEVELS_BATTERY if self.v2g else N_LEVELS
        return [car_levels] * len(self.evses) + [N_LEVELS_BATTERY]

    def reset(self):
        self.t = 0
        self.day = int(self.rng.integers(0, self.tables["n_days"]))
        for e in self.evses:
            e.car = None
            e.i_drawn = 0.0
        self.battery.soc = 0.5
        self.battery.i_drawn = 0.0
        return self.observe()

    def _hour(self) -> int:
        return min(self.t // STEPS_PER_HOUR, 23)

    def _price_idx(self) -> int:
        return self.day * 24 + self._hour()

    def step(self, action):
        tb = self.tables
        idx = self._price_idx()
        price_buy = tb["price_buy"][idx]
        price_sell_grid = tb["price_sell_grid"][idx]

        # (i) apply actions
        for j, e in enumerate(self.evses):
            if e.car is None:
                e.i_drawn = 0.0
                continue
            r_ch = charging_curve(e.car.soc, e.car.r_bar, e.car.tau)
            head_up = (1.0 - e.car.soc) * e.car.cap / DT_HOURS
            if self.v2g:
                frac = action[j] / ((N_LEVELS_BATTERY - 1) / 2.0) - 1.0
                p_target = frac * e.p_max
                r_dis = discharging_curve(e.car.soc, e.car.r_bar, e.car.tau)
                head_dn = e.car.soc * e.car.cap / DT_HOURS
                p_kw = max(min(p_target, r_ch, head_up), -min(r_dis, head_dn))
            else:
                frac = action[j] / (N_LEVELS - 1)
                p_kw = max(min(frac * e.p_max, r_ch, head_up), 0.0)
            e.i_drawn = p_kw * 1000.0 / e.voltage
        b = self.battery
        frac = action[-1] / ((N_LEVELS_BATTERY - 1) / 2.0) - 1.0
        p_target = frac * b.p_max
        r_ch = charging_curve(b.soc, b.p_max, b.tau)
        r_dis = discharging_curve(b.soc, b.p_max, b.tau)
        head_up = (1.0 - b.soc) * b.capacity / DT_HOURS
        head_dn = b.soc * b.capacity / DT_HOURS
        b.i_drawn = max(min(p_target, r_ch, head_up), -min(r_dis, head_dn)) * 1000.0 / b.voltage

        excess = self._project_constraints()

        # Feeder allocate + commit (rust commit_lane's budget guards):
        # the proposal is read off the staged currents AFTER the tree
        # projection, exactly where the rust propose phase ends.
        if self.grid_capacity_kw is not None:
            cap = self.grid_capacity_kw
            proposed = self._proposed_grid_kw()
            if proposed > cap and proposed > 0.0:
                if self.grid_policy == "proportional":
                    f = cap / proposed
                    for e in self.evses:
                        e.i_drawn *= f
                    self.battery.i_drawn *= f
                else:  # price-feedback
                    price_buy *= proposed / cap
            self.grid_headroom = min(max(1.0 - max(proposed, 0.0) / cap, 0.0), 1.0)

        # (ii) charge. Car-side discharge is accumulated here, at charge
        # time, so a car departing this same step still incurs the
        # degradation penalty for its final-step discharge (matches rust
        # env/core.rs charge_cars).
        de_net = 0.0
        grid_cars = 0.0
        car_discharge = 0.0
        for e in self.evses:
            if e.car is None:
                continue
            p_kw = e.voltage * e.i_drawn / 1000.0
            en = p_kw * DT_HOURS
            en = max(min(en, (1.0 - e.car.soc) * e.car.cap), -e.car.soc * e.car.cap)
            if en < 0.0:
                car_discharge += -en
            e.car.soc = min(max(e.car.soc + en / max(e.car.cap, 1e-9), 0.0), 1.0)
            e.car.de_remain -= en
            e.car.dt_remain -= 1.0
            de_net += en
            grid_cars += en / e.eta if en > 0 else en * e.eta
        p_bat = b.voltage * b.i_drawn / 1000.0
        e_bat = p_bat * DT_HOURS
        e_bat = max(min(e_bat, (1.0 - b.soc) * b.capacity), -b.soc * b.capacity)
        b.soc = min(max(b.soc + e_bat / b.capacity, 0.0), 1.0)
        de_grid_net = grid_cars + e_bat
        self.t += 1

        # (iii) departures
        missing = overtime = early = 0.0
        for e in self.evses:
            if e.car is None:
                continue
            car = e.car
            leave = (
                car.de_remain <= 1e-6 if car.charge_sensitive else car.dt_remain <= 0.0
            )
            if leave:
                if car.charge_sensitive:
                    overtime += max(-car.dt_remain, 0.0)
                    early += max(car.dt_remain, 0.0)
                else:
                    missing += max(car.de_remain, 0.0)
                e.car = None
                e.i_drawn = 0.0

        # (iv) arrivals
        lam = tb["arrival_rate"][self._hour()] * tb["traffic"] / STEPS_PER_HOUR
        m = int(self.rng.poisson(lam))
        free = [j for j, e in enumerate(self.evses) if e.car is None]
        n_take = min(m, len(free), MAX_ARRIVALS)
        rejected = float(m - n_take)
        for slot in free[:n_take]:
            self.evses[slot].car = self._sample_car(slot)

        grid_price = price_buy if de_grid_net > 0 else price_sell_grid
        profit = tb["p_sell"] * de_net - grid_price * de_grid_net - FIXED_COST_PER_STEP
        pens = [
            excess,
            missing,
            overtime - tb["beta"] * early,
            tb["moer"][idx] * de_grid_net,
            rejected,
            max(-e_bat, 0.0) + car_discharge,
            abs(de_net),
        ]
        reward = profit - float(np.dot(tb["alpha"], pens))

        done = self.t >= STEPS_PER_EPISODE
        info = {"profit": profit, "missing": missing, "rejected": rejected}
        obs = self.observe()
        if done:
            obs = self.reset()
        return obs, reward, done, info

    def _project_constraints(self) -> float:
        """Two fixed-point passes, matching the JAX kernel (exact for the
        depth-2 standard tree)."""
        flows_excess = 0.0
        for pass_i in range(2):
            scale = [1.0] * self.n_ports
            currents = [e.i_drawn for e in self.evses] + [self.battery.i_drawn]
            volts = [e.voltage for e in self.evses] + [self.battery.voltage]
            for node in self.nodes:
                flow = sum(volts[j] * currents[j] / 1000.0 for j in node.ports)
                load = abs(flow) / node.eta
                if pass_i == 0:
                    flows_excess = max(flows_excess, max(load - node.limit_kw, 0.0))
                s = min(1.0, node.limit_kw * node.eta / max(abs(flow), 1e-9))
                for j in node.ports:
                    scale[j] = min(scale[j], s)
            for j, e in enumerate(self.evses):
                e.i_drawn *= scale[j]
            self.battery.i_drawn *= scale[-1]
        return flows_excess

    def _proposed_grid_kw(self) -> float:
        """Grid-side power (kW, positive = import) the staged currents
        would move this step — rust env/core.rs proposed_grid_kw: the
        charge-phase SoC clamps and port efficiencies, read-only."""
        grid_kwh = 0.0
        for e in self.evses:
            if e.car is None:
                continue
            p_kw = e.voltage * e.i_drawn / 1000.0
            en = p_kw * DT_HOURS
            en = max(min(en, (1.0 - e.car.soc) * e.car.cap), -e.car.soc * e.car.cap)
            grid_kwh += en / e.eta if en > 0 else en * e.eta
        b = self.battery
        p_bat = b.voltage * b.i_drawn / 1000.0
        e_bat = max(min(p_bat * DT_HOURS, (1.0 - b.soc) * b.capacity), -b.soc * b.capacity)
        grid_kwh += e_bat
        return grid_kwh / DT_HOURS

    def _sample_car(self, slot: int) -> Car:
        tb = self.tables
        up = tb["user_profile"]
        model = int(self.rng.choice(len(tb["car_weights"]), p=tb["car_weights"]))
        cap, ac_kw, dc_kw, tau = tb["car_table"][model]
        stay_h = up[0] + up[1] * float(self.rng.normal())
        stay = max(round(stay_h / DT_HOURS), 1)
        u = float(self.rng.uniform(1e-6, 1 - 1e-6))
        soc0 = float(np.clip((1 - (1 - u) ** (1 / up[3])) ** (1 / up[2]), 0.02, 0.98))
        de = max(up[4] - soc0, 0.0) * cap
        e = self.evses[slot]
        return Car(
            soc=soc0,
            de_remain=de,
            dt_remain=float(stay),
            cap=cap,
            r_bar=min(dc_kw if e.is_dc else ac_kw, e.p_max),
            tau=tau,
            charge_sensitive=bool(self.rng.random() < 1.0 - up[5]),
        )

    def observe(self) -> np.ndarray:
        c = len(self.evses)
        out = np.zeros(self.obs_dim, np.float32)
        for j, e in enumerate(self.evses):
            car = e.car
            out[j] = car is not None
            if car is not None:
                out[c + j] = car.soc
                out[2 * c + j] = car.de_remain / 100.0
                out[3 * c + j] = car.dt_remain / STEPS_PER_EPISODE
                out[4 * c + j] = charging_curve(car.soc, car.r_bar, car.tau) / e.p_max
            out[5 * c + j] = e.i_drawn / e.i_max
        b = 6 * c
        bat = self.battery
        out[b] = bat.soc
        out[b + 1] = bat.i_drawn / (bat.p_max * 1000.0 / bat.voltage)
        out[b + 2] = charging_curve(bat.soc, bat.p_max, bat.tau) / bat.p_max
        phase = 2 * math.pi * self.t / STEPS_PER_EPISODE
        out[b + 3] = math.sin(phase)
        out[b + 4] = math.cos(phase)
        out[b + 5] = (self.day % 7) < 5
        out[b + 6] = self.day / self.tables["n_days"]
        idx = self._price_idx()
        out[b + 7] = self.tables["price_buy"][idx]
        # Next-hour price wraps at midnight to hour 0 of the next day (mod
        # the table length), matching the JAX env and rust env/core.rs.
        h = self._hour()
        if h == 23:
            next_idx = ((self.day + 1) % self.tables["n_days"]) * 24
        else:
            next_idx = self.day * 24 + h + 1
        out[b + 8] = self.tables["price_buy"][next_idx]
        out[b + 9] = self.tables["price_sell_grid"][idx]
        out[b + 10] = self.tables["moer"][idx]
        if self.grid_capacity_kw is not None:
            out[b + 11] = self.grid_headroom
        return out


def default_tables(data_dir: str = None) -> dict:
    """Build tables from compile.data (no artifacts needed)."""
    import sys, os

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from compile import data

    buy = data.price_table("NL", 2021).reshape(-1)
    cars = data.car_table("EU")
    return {
        "price_buy": buy,
        "price_sell_grid": buy * 0.9,
        "moer": data.moer_table().reshape(-1),
        "arrival_rate": data.arrival_rate("shopping"),
        "car_table": cars["table"],
        "car_weights": cars["weights"],
        "user_profile": data.user_profile_vec("shopping"),
        "alpha": np.zeros(7, np.float32),
        "beta": 0.1,
        "p_sell": 0.75,
        "traffic": 1.0,
        "n_days": 365,
    }
