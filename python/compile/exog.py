"""Build ExogData bundles from the data stack.

The bundle's *shapes* are part of the AOT contract; its *values* are runtime
inputs. ``default_exog`` is what aot.py embeds in the manifest as the
defaults; the Rust coordinator overrides individual leaves (price year, car
region, scenario, traffic, alpha) per experiment.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import data
from .env.state import PENALTIES, ExogData


def default_exog(
    scenario: str = "shopping",
    region: str = "EU",
    country: str = "NL",
    year: int = 2021,
    traffic: str = "medium",
    alpha: dict | None = None,
    beta: float = 0.1,
    p_sell: float = 0.75,
    n_days: int = 365,
    feed_in_ratio: float = 0.9,
) -> ExogData:
    """Assemble a full exogenous bundle for one named scenario."""
    buy = data.price_table(country, year, n_days)
    cars = data.car_table(region)
    alpha_vec = np.zeros(len(PENALTIES), np.float32)
    for name, val in (alpha or {}).items():
        alpha_vec[PENALTIES.index(name)] = val
    moer = data.moer_table(n_days)
    # Synthetic V2G demand signal (used only when alpha["grid"] > 0):
    # follows the price shape, scaled to station-sized kWh per step.
    grid_demand = (buy / np.maximum(buy.mean(), 1e-6) - 1.0) * 5.0
    return ExogData(
        price_buy=jnp.asarray(buy),
        price_sell_grid=jnp.asarray(buy * feed_in_ratio),
        moer=jnp.asarray(moer),
        grid_demand=jnp.asarray(grid_demand.astype(np.float32)),
        arrival_rate=jnp.asarray(data.arrival_rate(scenario)),
        car_table=jnp.asarray(cars["table"]),
        car_weights=jnp.asarray(cars["weights"]),
        user_profile=jnp.asarray(data.user_profile_vec(scenario)),
        alpha=jnp.asarray(alpha_vec),
        p_sell=jnp.asarray(p_sell, jnp.float32),
        traffic=jnp.asarray(data.TRAFFIC_MULTIPLIERS[traffic], jnp.float32),
        beta=jnp.asarray(beta, jnp.float32),
    )
