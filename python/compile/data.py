"""Exogenous data stack (paper Table 1).

Everything here is generated *deterministically* (fixed seeds) at build time
and exported both as JAX arrays (baked into exogenous-input literals) and as
``artifacts/data/*.json`` consumed bit-identically by the Rust scalar
simulator and coordinator.

Substitutions (see DESIGN.md §Substitutions):

* **Price profiles NL/FR/DE × 2021/2022/2023** — the paper uses ENTSO-E
  day-ahead prices. We synthesize them: a daily duck-curve shape, a weekly
  pattern, a seasonal component, and AR(1) noise, with country-specific
  levels. 2022 carries the EU energy-crisis surge (≈3× level, 2.5×
  volatility) that drives the paper's Fig. 5 distribution-shift result.
* **Car distributions EU/US/World** — a catalog of 20 real EV models with
  public spec-sheet values (usable capacity kWh, max AC kW, max DC kW, τ)
  and per-region market-share-inspired weights (US skews to larger packs).
* **Arrival frequencies** — hourly rate shapes for highway / residential /
  work / shopping stations × low / medium / high traffic.
* **User profiles** — per-scenario stay duration, arrival SoC, target SoC,
  and time- vs charge-sensitivity mix.
"""

from __future__ import annotations

import json
import math
import os
from typing import Dict, List

import numpy as np

# ---------------------------------------------------------------------------
# Price profiles (EUR/kWh), day-ahead granularity: hourly, expanded to the
# 5-minute step grid by the env (price index = step // 12).
# ---------------------------------------------------------------------------

# (level EUR/MWh, volatility) per country-year. 2022 is the crisis year.
_PRICE_PARAMS = {
    ("NL", 2021): (103.0, 0.45),
    ("NL", 2022): (242.0, 0.95),
    ("NL", 2023): (95.0, 0.40),
    ("FR", 2021): (109.0, 0.42),
    ("FR", 2022): (276.0, 1.05),
    ("FR", 2023): (97.0, 0.38),
    ("DE", 2021): (97.0, 0.48),
    ("DE", 2022): (235.0, 1.00),
    ("DE", 2023): (92.0, 0.42),
}

PRICE_COUNTRIES = ("NL", "FR", "DE")
PRICE_YEARS = (2021, 2022, 2023)

# Normalized daily shape (24h): morning ramp, midday solar dip (duck curve),
# evening peak, night trough.
_DAILY_SHAPE = np.array(
    [
        0.78, 0.74, 0.72, 0.71, 0.73, 0.80,  # 00-05
        0.95, 1.12, 1.18, 1.10, 0.98, 0.90,  # 06-11
        0.84, 0.80, 0.82, 0.90, 1.02, 1.22,  # 12-17
        1.35, 1.30, 1.18, 1.05, 0.95, 0.85,  # 18-23
    ]
)


def _seed_for(country: str, year: int) -> int:
    return (hash_str(country) * 31 + year) % (2**31)


def hash_str(s: str) -> int:
    """Deterministic string hash (Python's hash() is salted per process)."""
    h = 2166136261
    for ch in s.encode():
        h = ((h ^ ch) * 16777619) % (2**32)
    return h


def price_table(country: str, year: int, n_days: int = 365) -> np.ndarray:
    """Hourly buy price, EUR/kWh, shape [n_days, 24]."""
    level, vol = _PRICE_PARAMS[(country, year)]
    rng = np.random.default_rng(_seed_for(country, year))
    days = np.arange(n_days)
    # Seasonal: winter high, summer low (Europe). 2022 ramps up through the
    # year (invasion-driven surge peaking in Q3).
    seasonal = 1.0 + 0.22 * np.cos(2 * math.pi * (days - 15) / 365.0)
    if year == 2022:
        surge = 1.0 + 0.9 * np.clip(np.sin(math.pi * (days - 40) / 300.0), 0.0, None)
        seasonal = seasonal * surge
    weekly = np.where((days % 7) >= 5, 0.88, 1.03)  # weekend dip
    # AR(1) day-level noise.
    ar = np.empty(n_days)
    ar[0] = 0.0
    eps = rng.normal(0.0, vol * 0.18, size=n_days)
    for d in range(1, n_days):
        ar[d] = 0.82 * ar[d - 1] + eps[d]
    day_level = level * seasonal * weekly * np.exp(ar - ar.var() / 2)
    hour_noise = rng.normal(0.0, vol * 0.06, size=(n_days, 24))
    table = day_level[:, None] * _DAILY_SHAPE[None, :] * np.exp(hour_noise)
    # Rare negative-price hours in low-demand periods (real EU phenomenon).
    neg_mask = (rng.random((n_days, 24)) < 0.004) & (_DAILY_SHAPE[None, :] < 0.85)
    table = np.where(neg_mask, -table * 0.15, table)
    return (table / 1000.0).astype(np.float32)  # EUR/MWh -> EUR/kWh


def moer_table(n_days: int = 365, seed: int = 7) -> np.ndarray:
    """Marginal operating emissions rate, kgCO2/kWh, [n_days, 24].

    Anti-correlated with solar output: low midday, high at the evening ramp.
    """
    rng = np.random.default_rng(seed)
    shape = 0.35 + 0.15 * (_DAILY_SHAPE - _DAILY_SHAPE.min()) / np.ptp(_DAILY_SHAPE)
    days = np.arange(n_days)
    seasonal = 1.0 + 0.10 * np.cos(2 * math.pi * (days - 15) / 365.0)
    noise = rng.normal(1.0, 0.05, size=(n_days, 24))
    return (shape[None, :] * seasonal[:, None] * noise).astype(np.float32)


# ---------------------------------------------------------------------------
# Car catalog: 20 real EV models. Columns: usable capacity (kWh), max AC
# charging (kW), max DC charging (kW), tau (bulk->absorption knee, fraction
# of SoC at which the max rate starts tapering; from typical charging curves).
# ---------------------------------------------------------------------------

CAR_CATALOG: List[Dict] = [
    {"name": "Tesla Model 3 SR", "cap": 57.5, "ac": 11.0, "dc": 170.0, "tau": 0.55},
    {"name": "Tesla Model Y LR", "cap": 75.0, "ac": 11.0, "dc": 250.0, "tau": 0.50},
    {"name": "VW ID.4", "cap": 77.0, "ac": 11.0, "dc": 135.0, "tau": 0.60},
    {"name": "VW ID.3", "cap": 58.0, "ac": 11.0, "dc": 120.0, "tau": 0.60},
    {"name": "Renault Zoe", "cap": 52.0, "ac": 22.0, "dc": 46.0, "tau": 0.65},
    {"name": "Hyundai Ioniq 5", "cap": 72.6, "ac": 11.0, "dc": 220.0, "tau": 0.55},
    {"name": "Kia EV6", "cap": 74.0, "ac": 11.0, "dc": 233.0, "tau": 0.55},
    {"name": "Fiat 500e", "cap": 37.3, "ac": 11.0, "dc": 85.0, "tau": 0.65},
    {"name": "Peugeot e-208", "cap": 45.0, "ac": 11.0, "dc": 99.0, "tau": 0.62},
    {"name": "Skoda Enyaq", "cap": 77.0, "ac": 11.0, "dc": 135.0, "tau": 0.60},
    {"name": "BMW i4", "cap": 80.7, "ac": 11.0, "dc": 205.0, "tau": 0.52},
    {"name": "Audi Q4 e-tron", "cap": 76.6, "ac": 11.0, "dc": 135.0, "tau": 0.58},
    {"name": "Tesla Model S", "cap": 95.0, "ac": 11.5, "dc": 250.0, "tau": 0.48},
    {"name": "Ford Mustang Mach-E", "cap": 91.0, "ac": 10.5, "dc": 150.0, "tau": 0.58},
    {"name": "Ford F-150 Lightning", "cap": 98.0, "ac": 17.2, "dc": 155.0, "tau": 0.60},
    {"name": "Chevrolet Bolt", "cap": 65.0, "ac": 11.5, "dc": 55.0, "tau": 0.68},
    {"name": "Rivian R1T", "cap": 128.9, "ac": 11.5, "dc": 210.0, "tau": 0.55},
    {"name": "Nissan Leaf", "cap": 39.0, "ac": 6.6, "dc": 46.0, "tau": 0.70},
    {"name": "BYD Atto 3", "cap": 60.5, "ac": 11.0, "dc": 88.0, "tau": 0.62},
    {"name": "Wuling Mini EV", "cap": 13.8, "ac": 3.3, "dc": 25.0, "tau": 0.75},
]

# Region market-mix weights over the catalog (normalized at use).
CAR_WEIGHTS: Dict[str, List[float]] = {
    # Europe: compacts + VW group + Tesla.
    "EU": [10, 8, 7, 7, 6, 5, 5, 5, 5, 5, 4, 4, 2, 2, 0.5, 1, 0.5, 4, 4, 1],
    # US: Tesla-heavy, trucks/large SUVs, almost no city cars.
    "US": [14, 16, 4, 1, 0.2, 4, 4, 0.3, 0.2, 0.5, 3, 3, 6, 8, 9, 7, 6, 2, 0.5, 0.1],
    # World: adds the Chinese mass market (BYD, Wuling).
    "WORLD": [9, 9, 5, 4, 3, 4, 4, 3, 3, 3, 3, 3, 2, 2, 2, 3, 1, 4, 12, 12],
}

CAR_REGIONS = ("EU", "US", "WORLD")


def car_table(region: str) -> Dict[str, np.ndarray]:
    """Catalog columns + normalized sampling weights for one region."""
    cols = np.array(
        [[m["cap"], m["ac"], m["dc"], m["tau"]] for m in CAR_CATALOG],
        dtype=np.float32,
    )
    w = np.asarray(CAR_WEIGHTS[region], dtype=np.float32)
    return {"table": cols, "weights": w / w.sum()}


# ---------------------------------------------------------------------------
# Arrival frequency: expected arrivals per HOUR for a 16-charger station,
# shaped per scenario; env scales to per-step and by a traffic multiplier.
# ---------------------------------------------------------------------------

_ARRIVAL_SHAPES = {
    # hours 0..23
    "shopping": [0.2, 0.1, 0.1, 0.1, 0.1, 0.2, 0.5, 1.0, 2.0, 3.5, 4.5, 5.0,
                 5.0, 4.8, 4.5, 4.2, 4.0, 3.8, 3.0, 2.0, 1.2, 0.8, 0.4, 0.3],
    "work": [0.1, 0.1, 0.1, 0.1, 0.2, 0.5, 2.0, 5.0, 6.0, 4.0, 2.0, 1.2,
             1.5, 1.5, 1.0, 0.8, 0.5, 0.4, 0.3, 0.2, 0.2, 0.1, 0.1, 0.1],
    "residential": [0.5, 0.3, 0.2, 0.2, 0.2, 0.3, 0.5, 0.8, 0.8, 0.6, 0.6, 0.8,
                    1.0, 1.0, 1.2, 1.8, 3.0, 4.5, 5.0, 4.0, 3.0, 2.0, 1.2, 0.8],
    "highway": [0.8, 0.6, 0.5, 0.5, 0.6, 1.0, 2.0, 3.2, 3.5, 3.2, 3.0, 3.2,
                3.5, 3.4, 3.2, 3.5, 3.8, 4.0, 3.5, 2.8, 2.2, 1.8, 1.4, 1.0],
}

SCENARIOS = ("shopping", "work", "residential", "highway")

TRAFFIC_MULTIPLIERS = {"low": 0.5, "medium": 1.0, "high": 1.8}


def arrival_rate(scenario: str) -> np.ndarray:
    """Expected arrivals/hour, shape [24] (medium traffic, 16 chargers)."""
    return np.asarray(_ARRIVAL_SHAPES[scenario], dtype=np.float32)


# ---------------------------------------------------------------------------
# User profiles: how owners use the station, per scenario.
#   stay_mean_h / stay_std_h : lognormal-ish stay duration
#   soc0_a, soc0_b           : Beta params of arrival SoC
#   target_soc               : desired SoC at departure
#   p_time_sensitive         : fraction of users leaving at their deadline
#                              (u=0 in the paper; rest are charge-sensitive)
# ---------------------------------------------------------------------------

USER_PROFILES: Dict[str, Dict[str, float]] = {
    "highway": {"stay_mean_h": 0.6, "stay_std_h": 0.25, "soc0_a": 2.0, "soc0_b": 5.0,
                "target_soc": 0.80, "p_time_sensitive": 0.25},
    "residential": {"stay_mean_h": 9.0, "stay_std_h": 3.0, "soc0_a": 3.0, "soc0_b": 4.0,
                    "target_soc": 0.90, "p_time_sensitive": 0.70},
    "work": {"stay_mean_h": 7.5, "stay_std_h": 1.8, "soc0_a": 3.0, "soc0_b": 3.5,
             "target_soc": 0.85, "p_time_sensitive": 0.80},
    "shopping": {"stay_mean_h": 1.5, "stay_std_h": 0.6, "soc0_a": 2.5, "soc0_b": 3.0,
                 "target_soc": 0.80, "p_time_sensitive": 0.65},
}

USER_PROFILE_FIELDS = (
    "stay_mean_h", "stay_std_h", "soc0_a", "soc0_b", "target_soc", "p_time_sensitive",
)


def user_profile_vec(scenario: str) -> np.ndarray:
    p = USER_PROFILES[scenario]
    return np.asarray([p[f] for f in USER_PROFILE_FIELDS], dtype=np.float32)


# ---------------------------------------------------------------------------
# Export for the Rust side.
# ---------------------------------------------------------------------------

def export_all(out_dir: str, n_days: int = 365) -> None:
    """Write every table as JSON under ``out_dir`` (consumed by rust/src/data)."""
    os.makedirs(out_dir, exist_ok=True)

    prices = {
        f"{c}_{y}": price_table(c, y, n_days).tolist()
        for c in PRICE_COUNTRIES
        for y in PRICE_YEARS
    }
    with open(os.path.join(out_dir, "prices.json"), "w") as f:
        json.dump({"unit": "EUR/kWh", "granularity": "hourly", "tables": prices}, f)

    with open(os.path.join(out_dir, "moer.json"), "w") as f:
        json.dump({"unit": "kgCO2/kWh", "table": moer_table(n_days).tolist()}, f)

    cars = {
        "catalog": CAR_CATALOG,
        "columns": ["cap_kwh", "ac_kw", "dc_kw", "tau"],
        "weights": {r: car_table(r)["weights"].tolist() for r in CAR_REGIONS},
    }
    with open(os.path.join(out_dir, "cars.json"), "w") as f:
        json.dump(cars, f, indent=1)

    with open(os.path.join(out_dir, "arrivals.json"), "w") as f:
        json.dump(
            {
                "unit": "cars/hour (16-charger station, medium traffic)",
                "shapes": {s: arrival_rate(s).tolist() for s in SCENARIOS},
                "traffic_multipliers": TRAFFIC_MULTIPLIERS,
            },
            f,
            indent=1,
        )

    with open(os.path.join(out_dir, "user_profiles.json"), "w") as f:
        json.dump({"fields": list(USER_PROFILE_FIELDS),
                   "profiles": USER_PROFILES}, f, indent=1)
