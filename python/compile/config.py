"""Static environment / training configuration.

Everything in :class:`EnvConfig` is baked into the AOT-lowered HLO (shapes,
station architecture, discretization). Everything *exogenous* — prices, car
tables, arrival profiles, penalty weights — is passed as runtime inputs so the
Rust coordinator can swap scenario data without re-AOT (see
``model.py::EXOG_SPEC``).

Mirrors the paper's Table 3 defaults: 16 chargers (10 DC / 6 AC), 5-minute
timesteps, 24-hour episodes, discretization factor 10, p_sell = 0.75.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ChargerSpec:
    """One charger type: electrical limits of the EVSE."""

    kind: str  # "ac" | "dc"
    voltage: float  # V (already encodes phases, paper A.1)
    i_max: float  # A

    @property
    def p_max_kw(self) -> float:
        return self.voltage * self.i_max / 1000.0


# Default EVSE types (paper: 150 kW DC fast chargers, 11.5 kW AC).
DC_CHARGER = ChargerSpec(kind="dc", voltage=400.0, i_max=375.0)  # 150 kW
AC_CHARGER = ChargerSpec(kind="ac", voltage=230.0, i_max=50.0)  # 11.5 kW


@dataclasses.dataclass(frozen=True)
class StationConfig:
    """Station architecture: charger mix + constraint tree (paper Fig. 3b).

    The tree is: root (grid connection) -> one splitter per charger type ->
    EVSEs; the battery hangs off the root. Node capacities are expressed in
    kW (power) — with fixed per-leaf voltage this is equivalent to the
    paper's per-current constraints within a splitter, and is well-defined
    at the root where AC and DC leaves mix.
    """

    n_dc: int = 10
    n_ac: int = 6
    root_p_kw: float = 600.0
    dc_split_p_kw: float = 450.0
    ac_split_p_kw: float = 60.0
    node_eta: float = 0.98  # transformer/cable efficiency per internal node
    evse_eta: float = 0.95  # EVSE power-electronics efficiency
    # Station battery (paper: optional; default on, it enables V2G strategy).
    battery_capacity_kwh: float = 200.0
    battery_p_max_kw: float = 100.0
    battery_voltage: float = 400.0
    battery_tau: float = 0.8
    battery_soc0: float = 0.5

    @property
    def n_chargers(self) -> int:
        return self.n_dc + self.n_ac

    @property
    def n_ports(self) -> int:
        """Chargers + battery (battery is port index n_chargers)."""
        return self.n_chargers + 1


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Full static config for one AOT variant."""

    station: StationConfig = StationConfig()
    minutes_per_step: int = 5
    episode_hours: int = 24
    # Action discretization (paper B.1: factor 10 -> fractions 0..100%).
    n_levels: int = 11  # car ports: 0%,10%,...,100% of port max
    n_levels_battery: int = 21  # battery: -100%..100% in 10% steps
    max_arrivals_per_step: int = 6
    n_car_models: int = 20
    n_days: int = 365  # price-table length (exploring-starts sampling)
    fixed_cost_per_step: float = 0.25  # c_dt, EUR
    feed_in_ratio: float = 0.9  # p_sell_grid = ratio * p_buy (if no table)

    @property
    def steps_per_episode(self) -> int:
        return self.episode_hours * 60 // self.minutes_per_step  # 288

    @property
    def dt_hours(self) -> float:
        return self.minutes_per_step / 60.0


@dataclasses.dataclass(frozen=True)
class PpoConfig:
    """PPO hyperparameters (paper Table 3)."""

    num_envs: int = 12
    rollout_steps: int = 300
    lr: float = 2.5e-4
    anneal_lr: bool = True
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    vf_clip: float = 10.0
    ent_coef: float = 0.01
    vf_coef: float = 0.25
    max_grad_norm: float = 100.0
    n_minibatches: int = 4
    update_epochs: int = 4
    hidden: int = 128
    total_timesteps: int = 10_000_000  # paper budget; L3 scales this down

    @property
    def batch_size(self) -> int:
        return self.num_envs * self.rollout_steps

    @property
    def minibatch_size(self) -> int:
        return self.batch_size // self.n_minibatches


# ---------------------------------------------------------------------------
# Named station variants used by the paper's figures.
# ---------------------------------------------------------------------------

STATION_VARIANTS = {
    # 10 DC + 6 AC — Table 2 / Fig. 4 / Fig. 6-8 default station.
    "mix10dc6ac": StationConfig(n_dc=10, n_ac=6),
    # Fig. 9: 16 AC (11.5 kW).
    "ac16": StationConfig(n_dc=0, n_ac=16, root_p_kw=200.0, dc_split_p_kw=1.0, ac_split_p_kw=160.0),
    # Fig. 10: 8 AC + 8 DC.
    "mix8dc8ac": StationConfig(n_dc=8, n_ac=8, dc_split_p_kw=400.0, ac_split_p_kw=80.0),
    # Fig. 11: 16 DC (150 kW).
    "dc16": StationConfig(n_dc=16, n_ac=0, root_p_kw=800.0, dc_split_p_kw=700.0, ac_split_p_kw=1.0),
}


def variant_key(station_name: str, num_envs: int) -> str:
    """Canonical artifact key, e.g. ``mix10dc6ac_e12``.

    A ``-ref`` suffix on the station name selects the CPU-fast kernel
    routing (pure-jnp oracles instead of interpret-mode Pallas) at AOT
    time; the station itself is unchanged.
    """
    return f"{station_name}_e{num_envs}"


def station_base_name(station_name: str) -> str:
    return station_name.removesuffix("-ref")


def make_configs(station_name: str, num_envs: int) -> Tuple[EnvConfig, PpoConfig]:
    env = EnvConfig(station=STATION_VARIANTS[station_base_name(station_name)])
    ppo = PpoConfig(num_envs=num_envs)
    return env, ppo
