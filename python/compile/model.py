"""L2 program definitions: what gets AOT-lowered, with flat-leaf signatures.

Each exported program takes/returns a *flat* tuple of arrays; the pytree
structure (carry = params / Adam / env state / obs / rng, exog = the
ExogData bundle) is recorded in the manifest so the Rust coordinator can
splice individual leaves (e.g. swap the price table) positionally without
understanding JAX pytrees.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import networks, ppo
from .config import EnvConfig, PpoConfig
from .env import ChargaxEnv
from .env.state import METRIC_FIELDS, ExogData
from .exog import default_exog

_DTYPES = {
    np.dtype("float32"): "f32",
    np.dtype("int32"): "i32",
    np.dtype("uint32"): "u32",
}


def leaf_spec(name: str, x) -> Dict:
    x = np.asarray(x)
    return {"name": name, "shape": list(x.shape), "dtype": _DTYPES[x.dtype]}


def _names_of(tree) -> List[str]:
    """Dotted leaf paths, e.g. ``params.w1``, ``env_state.soc``."""
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for k in path:
            if isinstance(k, jax.tree_util.GetAttrKey):
                parts.append(k.name)
            elif isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        names.append(".".join(parts))
    return names


@dataclasses.dataclass
class Program:
    """One lowered program: fn over flat leaves + example inputs."""

    name: str
    fn: Callable
    example_inputs: Tuple
    input_names: List[str]
    output_names: List[str]

    def lower_hlo_text(self) -> str:
        from jax._src.lib import xla_client as xc

        # keep_unused: the manifest promises the full flat signature; jit
        # would otherwise prune inputs a program doesn't read (env_reset
        # ignores most exog leaves) and the Rust call would mismatch.
        lowered = jax.jit(self.fn, keep_unused=True).lower(*self.example_inputs)
        mlir_mod = lowered.compiler_ir("stablehlo")
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        # print_large_constants: the default printer elides arrays >10
        # elements as `constant({...})`, which the text parser on the rust
        # side silently turns into garbage (NaNs). Station-tree vectors
        # (volt/p_max/membership) are exactly such constants.
        return comp.as_hlo_text(print_large_constants=True)


class ModelBundle:
    """All programs for one (station, num_envs) variant."""

    def __init__(self, env_cfg: EnvConfig, ppo_cfg: PpoConfig):
        self.env_cfg = env_cfg
        self.ppo_cfg = ppo_cfg
        self.env = ChargaxEnv(env_cfg)
        self.exog = default_exog(n_days=env_cfg.n_days)
        self.exog_leaves, self.exog_def = jax.tree_util.tree_flatten(self.exog)
        self.exog_names = list(ExogData._fields)
        self.total_updates = max(
            ppo_cfg.total_timesteps // ppo_cfg.batch_size, 1
        )

        # Carry structure comes from eval_shape of init (shapes only; cheap).
        init_fn = ppo.make_train_init(self.env, ppo_cfg, self.exog)
        carry_shape = jax.eval_shape(init_fn, jnp.asarray(0, jnp.uint32))
        self.carry_def = jax.tree_util.tree_structure(carry_shape)
        self.carry_names = _names_of(carry_shape)
        self.carry_example = jax.tree_util.tree_leaves(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), carry_shape)
        )
        # Param sub-tree (for eval programs): dict -> leaves sorted by key.
        params_shape = carry_shape.params
        self.param_names = ["params." + k for k in sorted(params_shape.keys())]
        self.param_example = [
            jnp.zeros(params_shape[k].shape, params_shape[k].dtype)
            for k in sorted(params_shape.keys())
        ]
        self.params_def = jax.tree_util.tree_structure(params_shape)
        self._init_state_spec()

    # -- helpers -----------------------------------------------------------

    def _unflatten_exog(self, leaves) -> ExogData:
        return jax.tree_util.tree_unflatten(self.exog_def, list(leaves))

    def _init_state_spec(self):
        state_shape = jax.eval_shape(
            lambda s: self.env.reset(
                jax.random.split(jax.random.PRNGKey(s), self.ppo_cfg.num_envs),
                self.exog,
            )[0],
            jnp.asarray(0, jnp.uint32),
        )
        self.state_names = _names_of(state_shape)
        self.state_example = jax.tree_util.tree_leaves(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), state_shape)
        )
        self.state_def = jax.tree_util.tree_structure(state_shape)

    def seed_example(self):
        return jnp.asarray(0, jnp.uint32)

    # -- programs ----------------------------------------------------------

    def program_train_init(self) -> Program:
        init_fn = ppo.make_train_init(self.env, self.ppo_cfg, self.exog)

        def fn(seed):
            return tuple(jax.tree_util.tree_leaves(init_fn(seed)))

        return Program(
            "train_init", fn, (self.seed_example(),), ["seed"], self.carry_names
        )

    def program_train_iter(self) -> Program:
        iter_fn = ppo.make_train_iter(self.env, self.ppo_cfg, self.total_updates)
        n_carry = len(self.carry_example)

        def fn(*leaves):
            carry = jax.tree_util.tree_unflatten(
                self.carry_def, list(leaves[:n_carry])
            )
            exog = self._unflatten_exog(leaves[n_carry:])
            carry, metrics = iter_fn(carry, exog)
            return tuple(jax.tree_util.tree_leaves(carry)) + (metrics,)

        return Program(
            "train_iter",
            fn,
            tuple(self.carry_example) + tuple(self.exog_leaves),
            self.carry_names + self.exog_names,
            self.carry_names + ["metrics"],
        )

    def program_eval(self, policy: str) -> Program:
        ev = ppo.make_eval_rollout(self.env, self.ppo_cfg, policy)
        n_par = len(self.param_example)

        def fn(*leaves):
            params = jax.tree_util.tree_unflatten(
                self.params_def, list(leaves[:n_par])
            )
            seed = leaves[n_par]
            exog = self._unflatten_exog(leaves[n_par + 1 :])
            return (ev(params, seed, exog),)

        return Program(
            f"eval_{policy}",
            fn,
            tuple(self.param_example) + (self.seed_example(),) + tuple(self.exog_leaves),
            self.param_names + ["seed"] + self.exog_names,
            ["eval_metrics"],
        )

    def program_random_rollout(self, n_steps: int) -> Program:
        rr = ppo.make_random_rollout(self.env, self.ppo_cfg.num_envs, n_steps)

        def fn(seed, *ex):
            mets, steps = rr(seed, self._unflatten_exog(ex))
            return mets, steps

        return Program(
            "random_rollout",
            fn,
            (self.seed_example(),) + tuple(self.exog_leaves),
            ["seed"] + self.exog_names,
            ["step_metrics_mean", "steps_done"],
        )

    def program_env_reset(self) -> Program:
        def fn(seed, *ex):
            exog = self._unflatten_exog(ex)
            keys = jax.random.split(
                jax.random.PRNGKey(seed), self.ppo_cfg.num_envs
            )
            state, obs = self.env.reset(keys, exog)
            return tuple(jax.tree_util.tree_leaves(state)) + (obs,)

        return Program(
            "env_reset",
            fn,
            (self.seed_example(),) + tuple(self.exog_leaves),
            ["seed"] + self.exog_names,
            self.state_names + ["obs"],
        )

    def program_env_step(self) -> Program:
        n_state = len(self.state_example)
        action_ex = jnp.zeros(
            (self.ppo_cfg.num_envs, self.env.n_ports), jnp.int32
        )

        def fn(*leaves):
            state = jax.tree_util.tree_unflatten(
                self.state_def, list(leaves[:n_state])
            )
            action = leaves[n_state]
            exog = self._unflatten_exog(leaves[n_state + 1 :])
            state, obs, r, done, metrics = self.env.step(state, action, exog)
            return tuple(jax.tree_util.tree_leaves(state)) + (obs, r, done, metrics)

        return Program(
            "env_step",
            fn,
            tuple(self.state_example) + (action_ex,) + tuple(self.exog_leaves),
            self.state_names + ["action"] + self.exog_names,
            self.state_names + ["obs", "reward", "done", "metrics"],
        )

    # -- manifest ----------------------------------------------------------

    def env_meta(self) -> Dict:
        return {
            "obs_dim": self.env.obs_dim,
            "n_ports": self.env.n_ports,
            "n_chargers": self.env.n_chargers,
            "n_dc": self.env_cfg.station.n_dc,
            "action_nvec": [int(x) for x in self.env.action_nvec],
            "steps_per_episode": self.env_cfg.steps_per_episode,
            "num_envs": self.ppo_cfg.num_envs,
            "rollout_steps": self.ppo_cfg.rollout_steps,
            "batch_size": self.ppo_cfg.batch_size,
            "total_updates_for_anneal": self.total_updates,
            "metric_fields": list(METRIC_FIELDS),
            "train_metric_fields": list(ppo.TRAIN_METRIC_FIELDS),
            "eval_metric_fields": list(ppo.EVAL_METRIC_FIELDS),
            "n_params": networks.n_params(
                jax.tree_util.tree_unflatten(self.params_def, self.param_example)
            ),
            "n_carry_leaves": len(self.carry_example),
            "n_exog_leaves": len(self.exog_leaves),
        }
