"""State containers (paper Table 4) and the exogenous-data bundle.

``EnvState`` is the endogenous state (batched, [E, ...] leading dim)
plus the per-car exogenous attributes that stay fixed while a car is
parked (paper A.1 "car state"). ``ExogData`` carries every swappable
time-series / distribution table — the Rust coordinator substitutes these
literals at runtime to change scenario, region, price year, traffic level
or reward weights *without re-AOT*.

Port layout: ``P = n_chargers + 1``; car ports are ``[0, C)``; the station
battery is lane ``C``. Arrays over car-only quantities have width C.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class EnvState(NamedTuple):
    """Endogenous state (+ fixed per-car attributes), all batched [E, ...]."""

    t: jnp.ndarray          # [E] i32, step within episode
    day: jnp.ndarray        # [E] i32, day index into the price tables
    key: jnp.ndarray        # [E, 2] u32, per-env PRNG key
    i_drawn: jnp.ndarray    # [E, P] f32, signed port current (A)
    occup: jnp.ndarray      # [E, C] f32 0/1
    soc: jnp.ndarray        # [E, P] f32, car/battery state of charge
    de_remain: jnp.ndarray  # [E, C] f32 kWh still wanted (can go <= 0)
    dt_remain: jnp.ndarray  # [E, C] f32 steps until desired departure
    cap: jnp.ndarray        # [E, P] f32 kWh battery capacity (car/battery)
    r_bar: jnp.ndarray      # [E, P] f32 kW max rate at *this* port
    tau: jnp.ndarray        # [E, P] f32 charging-curve knee
    pref: jnp.ndarray       # [E, C] f32, 0 = time-sensitive, 1 = charge-sensitive
    r_hat: jnp.ndarray      # [E, P] f32 kW current max rate (curve at SoC)
    ep_return: jnp.ndarray  # [E] f32, running episode return
    ep_profit: jnp.ndarray  # [E] f32, running episode profit


class ExogData(NamedTuple):
    """Runtime-swappable exogenous tables (model EXOG inputs, in order)."""

    price_buy: jnp.ndarray        # [D, 24] EUR/kWh
    price_sell_grid: jnp.ndarray  # [D, 24] EUR/kWh feed-in price
    moer: jnp.ndarray             # [D, 24] kgCO2/kWh
    grid_demand: jnp.ndarray      # [D, 24] kW V2G demand signal (c_grid)
    arrival_rate: jnp.ndarray     # [24] cars/hour (medium traffic)
    car_table: jnp.ndarray        # [M, 4] cap, ac_kw, dc_kw, tau
    car_weights: jnp.ndarray      # [M] sampling weights (sum 1)
    user_profile: jnp.ndarray     # [6] see data.USER_PROFILE_FIELDS
    alpha: jnp.ndarray            # [7] penalty weights (Eq. 3), order below
    p_sell: jnp.ndarray           # [] EUR/kWh customer tariff
    traffic: jnp.ndarray          # [] arrival-rate multiplier
    beta: jnp.ndarray             # [] early-departure bonus weight (A.3)


# Penalty order for ExogData.alpha (paper A.3).
PENALTIES = (
    "constraint",     # pre-projection node overload (kW)
    "satisfaction0",  # kWh missing for departing time-sensitive users
    "satisfaction1",  # overtime (minus beta * early) for charge-sensitive
    "sustain",        # MOER-weighted net grid energy
    "declined",       # rejected cars
    "degradation",    # battery + car discharge throughput
    "grid",           # |net car energy - grid demand signal|
)

# Per-step metric vector layout (step() returns metrics [E, len(METRIC_FIELDS)];
# the Rust coordinator and eval_rollout aggregate them).
METRIC_FIELDS = (
    "reward",
    "profit",
    "energy_to_cars_kwh",   # ΔE_net (car ports, signed)
    "energy_grid_net_kwh",  # ΔE_grid,net
    "excess_kw",            # pre-projection constraint violation
    "missing_kwh",          # satisfaction0 contribution this step
    "overtime_steps",       # charge-sensitive overtime at departure
    "rejected",             # cars turned away this step
    "departed",             # cars that left this step
    "arrived",              # cars that parked this step
    "done",                 # episode terminated after this step
    "ep_return",            # return of the episode that just finished (else 0)
    "ep_profit",            # profit of the episode that just finished (else 0)
)


def metric_index(name: str) -> int:
    return METRIC_FIELDS.index(name)
