"""Station architecture as flat arrays (paper Fig. 3 / §EV Station Layout).

A station is a tree: root = grid connection, internal nodes = splitters /
transformers / cables with a power capacity and an efficiency coefficient,
leaves = EVSEs (+ the station battery). For the kernels we flatten the tree
into an ancestor *membership matrix* ``[n_nodes, n_ports]`` — Eq. 5 then
becomes a matmul + rescale (see kernels/constraint.py).

``StationTree.standard`` builds the paper's default layout (Fig. 3b): one
splitter per charger type, battery directly under the root. Custom trees can
be built by passing explicit node lists to the constructor, mirroring
real-world infrastructure.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from ..config import AC_CHARGER, DC_CHARGER, StationConfig


@dataclasses.dataclass(frozen=True)
class StationTree:
    """Flattened station electrical topology. All arrays are numpy (static)."""

    # Per-port (chargers first, battery last):
    volt: np.ndarray       # [P] V
    i_max: np.ndarray      # [P] A
    p_max: np.ndarray      # [P] kW
    eta_port: np.ndarray   # [P] EVSE efficiency
    is_dc: np.ndarray      # [C] 1.0 for DC chargers
    # Tree nodes:
    membership: np.ndarray  # [N, P] 0/1 ancestor matrix
    node_limit: np.ndarray  # [N] kW
    node_eta: np.ndarray    # [N]
    node_names: Tuple[str, ...]

    @property
    def n_ports(self) -> int:
        return int(self.volt.shape[0])

    @property
    def n_chargers(self) -> int:
        return int(self.is_dc.shape[0])

    @property
    def n_nodes(self) -> int:
        return int(self.node_limit.shape[0])

    @staticmethod
    def standard(cfg: StationConfig) -> "StationTree":
        """Paper Fig. 3b: root -> {DC splitter, AC splitter, battery}."""
        c = cfg.n_chargers
        p = cfg.n_ports
        volt = np.empty(p, np.float32)
        i_max = np.empty(p, np.float32)
        volt[: cfg.n_dc] = DC_CHARGER.voltage
        i_max[: cfg.n_dc] = DC_CHARGER.i_max
        volt[cfg.n_dc : c] = AC_CHARGER.voltage
        i_max[cfg.n_dc : c] = AC_CHARGER.i_max
        volt[c] = cfg.battery_voltage
        i_max[c] = cfg.battery_p_max_kw * 1000.0 / cfg.battery_voltage
        p_max = volt * i_max / 1000.0
        eta_port = np.full(p, cfg.evse_eta, np.float32)
        is_dc = np.zeros(c, np.float32)
        is_dc[: cfg.n_dc] = 1.0

        names: List[str] = ["root"]
        membership = [np.ones(p, np.float32)]  # root covers everything
        limits = [cfg.root_p_kw]
        if cfg.n_dc > 0:
            row = np.zeros(p, np.float32)
            row[: cfg.n_dc] = 1.0
            membership.append(row)
            limits.append(cfg.dc_split_p_kw)
            names.append("dc_splitter")
        if cfg.n_ac > 0:
            row = np.zeros(p, np.float32)
            row[cfg.n_dc : c] = 1.0
            membership.append(row)
            limits.append(cfg.ac_split_p_kw)
            names.append("ac_splitter")
        return StationTree(
            volt=volt,
            i_max=i_max,
            p_max=p_max.astype(np.float32),
            eta_port=eta_port,
            is_dc=is_dc,
            membership=np.stack(membership),
            node_limit=np.asarray(limits, np.float32),
            node_eta=np.full(len(limits), cfg.node_eta, np.float32),
            node_names=tuple(names),
        )

    @staticmethod
    def custom(
        cfg: StationConfig,
        nodes: Sequence[Tuple[str, Sequence[int], float, float]],
    ) -> "StationTree":
        """Build an arbitrary architecture (paper Fig. 3c).

        ``nodes`` is a list of (name, port_indices, limit_kw, eta). A root
        covering every port is prepended automatically if absent.
        """
        base = StationTree.standard(cfg)
        p = cfg.n_ports
        names: List[str] = []
        rows: List[np.ndarray] = []
        limits: List[float] = []
        etas: List[float] = []
        has_root = any(sorted(ports) == list(range(p)) for _, ports, _, _ in nodes)
        if not has_root:
            names.append("root")
            rows.append(np.ones(p, np.float32))
            limits.append(cfg.root_p_kw)
            etas.append(cfg.node_eta)
        for name, ports, limit, eta in nodes:
            row = np.zeros(p, np.float32)
            row[np.asarray(list(ports), int)] = 1.0
            names.append(name)
            rows.append(row)
            limits.append(float(limit))
            etas.append(float(eta))
        return dataclasses.replace(
            base,
            membership=np.stack(rows),
            node_limit=np.asarray(limits, np.float32),
            node_eta=np.asarray(etas, np.float32),
            node_names=tuple(names),
        )

    def validate(self) -> None:
        """Sanity checks used by pytest and aot.py."""
        assert self.membership.shape == (self.n_nodes, self.n_ports)
        assert np.all((self.membership == 0) | (self.membership == 1))
        assert np.all(self.membership[0] == 1), "node 0 must be the root"
        assert np.all(self.node_limit > 0)
        assert np.all((self.node_eta > 0) & (self.node_eta <= 1))
        assert np.all(self.p_max > 0)
