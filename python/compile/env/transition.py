"""The four-step transition function (paper §4 / A.2), fully batched.

Step order (A.2): (i) apply actions — clamp to port, car-curve and headroom
limits, then project onto the station-tree constraints (L1 kernel);
(ii) charge stationed cars (L1 kernel); (iii) departures; (iv) arrivals.

Sampling notes:
* Arrival counts are Poisson (paper B.1), rate = hourly shape * traffic
  multiplier, converted to per-step.
* Arrival SoC uses a Kumaraswamy(a, b) draw — closed-form inverse CDF with
  the same support/shape family as the Beta the paper implies. jax.random's
  Beta lowers to a rejection-sampling while-loop; Kumaraswamy lowers to two
  pows, which keeps the AOT HLO small and the Rust scalar mirror exact.
* Stay duration is a truncated Normal (>= 1 step).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .. import kernels
from ..kernels.ref import charging_curve, discharging_curve
from .state import EnvState, ExogData


class Static(NamedTuple):
    """Station tree + config constants as device arrays / Python scalars."""

    volt: jnp.ndarray        # [P]
    i_max: jnp.ndarray       # [P]
    p_max: jnp.ndarray       # [P]
    eta_port: jnp.ndarray    # [P]
    is_dc: jnp.ndarray       # [C]
    membership: jnp.ndarray  # [N, P]
    node_limit: jnp.ndarray  # [N]
    node_eta: jnp.ndarray    # [N]
    n_chargers: int
    n_ports: int
    dt_hours: float
    steps_per_episode: int
    n_levels: int
    n_levels_battery: int
    max_arrivals: int
    n_days: int
    battery_soc0: float
    allow_v2g: bool  # cars may discharge (battery always may)


def _present(state: EnvState) -> jnp.ndarray:
    """[E, P] mask: occupied car ports + the always-present battery."""
    ones = jnp.ones_like(state.occup[:, :1])
    return jnp.concatenate([state.occup, ones], axis=1)


def apply_actions(
    state: EnvState, action: jnp.ndarray, st: Static
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """A.2 step (i): discrete levels -> clamped signed currents -> Eq. 5.

    ``action`` is [E, P] int32: car ports select a fraction of the port
    maximum in {0, 1/(L-1), ..., 1} (paper B.1 discretization; negative
    fractions when V2G is enabled); the battery lane uses a symmetric
    (-1..1) ladder.

    Returns (i_drawn [E, P], excess_kw [E]).
    """
    c = st.n_chargers
    lvl = action.astype(jnp.float32)
    frac_car = lvl[:, :c] / (st.n_levels - 1)
    if st.allow_v2g:
        # Levels span [-1, 1] for car ports too.
        frac_car = 2.0 * frac_car - 1.0
    half = (st.n_levels_battery - 1) / 2.0
    frac_bat = lvl[:, c:] / half - 1.0
    frac = jnp.concatenate([frac_car, frac_bat], axis=1)
    i_target = frac * st.i_max[None, :]

    pres = _present(state)
    p_target = i_target * st.volt[None, :] / 1000.0  # kW, signed
    # Car charging curve (and its flipped discharge twin, A.1).
    r_ch = charging_curve(state.soc, state.r_bar, state.tau)
    r_dis = discharging_curve(state.soc, state.r_bar, state.tau)
    # Headroom: cannot over-fill / over-drain within one step.
    head_up = (1.0 - state.soc) * state.cap / st.dt_hours
    head_dn = state.soc * state.cap / st.dt_hours
    p_new = jnp.clip(p_target, -jnp.minimum(r_dis, head_dn), jnp.minimum(r_ch, head_up))
    p_new = p_new * pres
    i_new = p_new * 1000.0 / st.volt[None, :]

    return kernels.constraint_projection(
        i_new, st.volt, st.membership, st.node_limit, st.node_eta
    )


def charge(state: EnvState, i_drawn: jnp.ndarray, st: Static):
    """A.2 step (ii): advance SoC / demand / time via the charge kernel.

    Returns (state', e_port [E, P]).
    """
    pres = _present(state)
    de_full = jnp.concatenate(
        [state.de_remain, jnp.zeros_like(state.de_remain[:, :1])], axis=1
    )
    dt_full = jnp.concatenate(
        [state.dt_remain, jnp.zeros_like(state.dt_remain[:, :1])], axis=1
    )
    soc_n, de_n, dt_n, r_hat_n, e_port = kernels.charge_update(
        i_drawn, st.volt, pres, state.soc, de_full, dt_full,
        state.cap, state.r_bar, state.tau, st.dt_hours,
    )
    c = st.n_chargers
    state = state._replace(
        i_drawn=i_drawn,
        soc=soc_n,
        de_remain=de_n[:, :c],
        dt_remain=dt_n[:, :c],
        r_hat=r_hat_n,
        t=state.t + 1,
    )
    return state, e_port


def departures(state: EnvState, st: Static):
    """A.2 step (iii): time-sensitive leave at the deadline, charge-sensitive
    when their demand is met.

    Returns (state', missing_kwh [E], overtime_steps [E], early_steps [E],
    departed [E]).
    """
    eps = 1e-6
    time_up = (state.pref == 0.0) & (state.dt_remain <= 0.0)
    charged = (state.pref == 1.0) & (state.de_remain <= eps)
    leave = (state.occup > 0.0) & (time_up | charged)
    leave_f = leave.astype(jnp.float32)

    missing = jnp.sum(
        leave_f * (state.pref == 0.0) * jnp.maximum(state.de_remain, 0.0), axis=1
    )
    overtime = jnp.sum(
        leave_f * (state.pref == 1.0) * jnp.maximum(-state.dt_remain, 0.0), axis=1
    )
    early = jnp.sum(
        leave_f * (state.pref == 1.0) * jnp.maximum(state.dt_remain, 0.0), axis=1
    )
    departed = jnp.sum(leave_f, axis=1)

    keep = 1.0 - leave_f
    c = st.n_chargers
    keep_p = jnp.concatenate([keep, jnp.ones_like(keep[:, :1])], axis=1)
    state = state._replace(
        occup=state.occup * keep,
        soc=state.soc * keep_p,
        de_remain=state.de_remain * keep,
        dt_remain=state.dt_remain * keep,
        cap=state.cap * keep_p + (1.0 - keep_p) * _cap_fill(state, c),
        r_bar=state.r_bar * keep_p,
        tau=state.tau * keep_p,
        pref=state.pref * keep,
        r_hat=state.r_hat * keep_p,
        i_drawn=state.i_drawn * keep_p,
    )
    return state, missing, overtime, early, departed


def _cap_fill(state: EnvState, c: int) -> jnp.ndarray:
    """Empty car lanes keep cap=1 (avoids 0/0 in the charge kernel); the
    battery lane keeps its true capacity."""
    ones = jnp.ones_like(state.cap)
    return ones.at[:, c].set(state.cap[:, c])


def _sample_candidates(key, exog: ExogData, st: Static):
    """Sample ``max_arrivals`` candidate (car, user) profiles for one env.

    Returns dict of [A]-shaped arrays.
    """
    a = st.max_arrivals
    k_model, k_stay, k_soc, k_pref = jax.random.split(key, 4)
    logw = jnp.log(jnp.maximum(exog.car_weights, 1e-30))
    model = jax.random.categorical(k_model, logw, shape=(a,))
    row = exog.car_table[model]  # [A, 4]
    cap, ac_kw, dc_kw, tau = row[:, 0], row[:, 1], row[:, 2], row[:, 3]

    up = exog.user_profile
    stay_mean_h, stay_std_h = up[0], up[1]
    soc0_a, soc0_b, target_soc, p_time = up[2], up[3], up[4], up[5]
    stay_h = stay_mean_h + stay_std_h * jax.random.normal(k_stay, (a,))
    stay_steps = jnp.maximum(jnp.round(stay_h / st.dt_hours), 1.0)
    # Kumaraswamy(a, b) arrival SoC (see module docstring).
    u = jax.random.uniform(k_soc, (a,), minval=1e-6, maxval=1.0 - 1e-6)
    soc0 = (1.0 - (1.0 - u) ** (1.0 / soc0_b)) ** (1.0 / soc0_a)
    soc0 = jnp.clip(soc0, 0.02, 0.98)
    de = jnp.maximum(target_soc - soc0, 0.0) * cap
    pref = (jax.random.uniform(k_pref, (a,)) < (1.0 - p_time)).astype(jnp.float32)
    return {
        "cap": cap, "ac_kw": ac_kw, "dc_kw": dc_kw, "tau": tau,
        "stay": stay_steps, "soc0": soc0, "de": de, "pref": pref,
    }


def arrivals(state: EnvState, exog: ExogData, st: Static):
    """A.2 step (iv): Poisson arrivals, first-come-first-served first-fit.

    Returns (state', rejected [E], arrived [E]).
    """
    c = st.n_chargers
    e = state.occup.shape[0]

    keys = jax.vmap(lambda k: jax.random.split(k, 3))(state.key)  # [E, 3, 2]
    key_next, k_count, k_cand = keys[:, 0], keys[:, 1], keys[:, 2]

    steps_per_hour = int(round(1.0 / st.dt_hours))
    hour = jnp.clip(state.t // steps_per_hour, 0, 23)
    lam = exog.arrival_rate[hour] * exog.traffic / steps_per_hour  # [E]
    m = jax.vmap(lambda k, l: jax.random.poisson(k, l))(k_count, lam)
    m = m.astype(jnp.int32)

    free = 1.0 - state.occup  # [E, C]
    n_free = jnp.sum(free, axis=1).astype(jnp.int32)
    n_take = jnp.minimum(jnp.minimum(m, n_free), st.max_arrivals)
    rejected = jnp.maximum(m - n_take, 0).astype(jnp.float32)

    cand = jax.vmap(lambda k: _sample_candidates(k, exog, st))(k_cand)

    # First-fit: the j-th accepted car takes the j-th free port.
    rank = jnp.cumsum(free, axis=1) - 1.0  # [E, C], rank among free ports
    rank = jnp.where(free > 0.0, rank, -1.0)
    # assign[e, j, p] = 1 iff candidate j parks at port p.
    j_idx = jnp.arange(st.max_arrivals, dtype=jnp.float32)
    assign = (
        (rank[:, None, :] == j_idx[None, :, None])
        & (j_idx[None, :, None] < n_take[:, None, None].astype(jnp.float32))
    ).astype(jnp.float32)  # [E, A, C]

    def place(col):  # [E, A] -> [E, C] scattered onto ports
        return jnp.einsum("ea,eac->ec", col, assign)

    newly = jnp.sum(assign, axis=1)  # [E, C] 0/1
    # Port-dependent max rate: DC ports use the car's DC limit, AC its AC
    # limit, both capped by the port's own power rating.
    car_rate = jnp.where(
        st.is_dc[None, None, :] > 0.0,
        cand["dc_kw"][:, :, None],
        cand["ac_kw"][:, :, None],
    )  # [E, A, C]
    r_bar_new = jnp.einsum("eac,eac->ec", car_rate, assign)
    r_bar_new = jnp.minimum(r_bar_new, st.p_max[None, :c]) * newly

    soc_new = place(cand["soc0"])
    cap_new = place(cand["cap"])
    tau_new = place(cand["tau"])

    occup = state.occup + newly
    pad = lambda x: jnp.concatenate([x, jnp.zeros_like(x[:, :1])], axis=1)
    keep_cap = state.cap * (1.0 - pad(newly)) + pad(cap_new)
    r_hat_new = charging_curve(soc_new, r_bar_new, jnp.maximum(tau_new, 1e-3)) * newly

    state = state._replace(
        key=key_next,
        occup=occup,
        soc=state.soc * (1.0 - pad(newly)) + pad(soc_new),
        de_remain=state.de_remain * (1.0 - newly) + place(cand["de"]),
        dt_remain=state.dt_remain * (1.0 - newly) + place(cand["stay"]),
        cap=keep_cap,
        r_bar=state.r_bar * (1.0 - pad(newly)) + pad(r_bar_new),
        tau=state.tau * (1.0 - pad(newly)) + pad(tau_new),
        pref=state.pref * (1.0 - newly) + place(cand["pref"]),
        r_hat=state.r_hat * (1.0 - pad(newly)) + pad(r_hat_new),
    )
    arrived = jnp.sum(newly, axis=1)
    return state, rejected, arrived
