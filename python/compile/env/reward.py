"""Profit and penalty terms (paper §4 Reward Function / A.3), batched.

Profit (Eq. 2): energy is metered at the port on the car side (ΔE_net);
grid-side flows carry the EVSE efficiency (charging draws e/η from the
grid, discharging feeds η·e into it); the battery contributes its port
energy directly (A.3). The net grid flow is bought at p_buy when positive
and sold at p_sell_grid when negative.

Reward (Eq. 3): r = Π − Σ_c α_c·c(t) with the seven penalty families of
A.3; the weights live in ``ExogData.alpha`` so sweeps (Fig. 4b/c) need no
re-AOT.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from .state import ExogData
from .transition import Static


class StepCosts(NamedTuple):
    """Per-step penalty inputs gathered by env.step()."""

    excess_kw: jnp.ndarray      # [E] pre-projection node overload
    missing_kwh: jnp.ndarray    # [E] unmet demand of departing u=0 users
    overtime_steps: jnp.ndarray # [E] overtime of departing u=1 users
    early_steps: jnp.ndarray    # [E] early departure of u=1 users
    rejected: jnp.ndarray       # [E]


def grid_energy(e_port: jnp.ndarray, st: Static) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split port energies into car-side ΔE_net and grid-side ΔE_grid,net.

    Args:
      e_port: [E, P] signed kWh transferred at each port this step.

    Returns (de_net [E] car ports only, de_grid_net [E] incl. battery).
    """
    c = st.n_chargers
    e_cars = e_port[:, :c]
    de_net = jnp.sum(e_cars, axis=1)

    eta = st.eta_port[None, :c]
    grid_cars = jnp.where(e_cars > 0.0, e_cars / eta, e_cars * eta)
    # Battery: ΔE_b,net enters the grid balance directly (A.3).
    e_bat = e_port[:, c]
    de_grid_net = jnp.sum(grid_cars, axis=1) + e_bat
    return de_net, de_grid_net


def profit(
    de_net: jnp.ndarray,
    de_grid_net: jnp.ndarray,
    p_buy: jnp.ndarray,
    p_sell_grid: jnp.ndarray,
    p_sell: jnp.ndarray,
    fixed_cost: float,
) -> jnp.ndarray:
    """Eq. 2. All price args broadcast over [E]."""
    grid_price = jnp.where(de_grid_net > 0.0, p_buy, p_sell_grid)
    return p_sell * de_net - grid_price * de_grid_net - fixed_cost


def penalties(
    costs: StepCosts,
    de_grid_net: jnp.ndarray,
    de_net: jnp.ndarray,
    e_port: jnp.ndarray,
    moer: jnp.ndarray,
    grid_demand: jnp.ndarray,
    exog: ExogData,
    st: Static,
) -> jnp.ndarray:
    """Stack the seven A.3 penalty terms -> [E, 7] (order: state.PENALTIES)."""
    c = st.n_chargers
    e_bat = e_port[:, c]
    discharge_cars = jnp.sum(jnp.maximum(-e_port[:, :c], 0.0), axis=1)

    c_constraint = costs.excess_kw
    c_sat0 = costs.missing_kwh
    c_sat1 = costs.overtime_steps - exog.beta * costs.early_steps
    c_sustain = moer * de_grid_net
    c_declined = costs.rejected
    c_degrad = jnp.maximum(-e_bat, 0.0) + discharge_cars
    c_grid = jnp.abs(de_net - grid_demand)
    return jnp.stack(
        [c_constraint, c_sat0, c_sat1, c_sustain, c_declined, c_degrad, c_grid],
        axis=1,
    )


def reward(
    pi: jnp.ndarray, pens: jnp.ndarray, exog: ExogData
) -> jnp.ndarray:
    """Eq. 3: profit minus the α-weighted penalty combination."""
    return pi - jnp.sum(pens * exog.alpha[None, :], axis=1)
