"""ChargaxEnv: batched reset/step with auto-reset (gymnax-style).

The environment object holds only *static* data (config + flattened station
tree); all dynamic state travels through ``EnvState`` and all swappable data
through ``ExogData``, so jitted/lowered functions close over shapes, never
values. Observations, actions and metrics are documented in README §State.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import EnvConfig
from . import reward as rew
from . import transition as tr
from .state import METRIC_FIELDS, EnvState, ExogData
from .tree import StationTree


class ChargaxEnv:
    """Vectorized EV-charging-station environment (paper §4)."""

    def __init__(self, cfg: EnvConfig, allow_v2g: bool = False):
        self.cfg = cfg
        self.tree = StationTree.standard(cfg.station)
        self.tree.validate()
        t = self.tree
        self.static = tr.Static(
            volt=jnp.asarray(t.volt),
            i_max=jnp.asarray(t.i_max),
            p_max=jnp.asarray(t.p_max),
            eta_port=jnp.asarray(t.eta_port),
            is_dc=jnp.asarray(t.is_dc),
            membership=jnp.asarray(t.membership),
            node_limit=jnp.asarray(t.node_limit),
            node_eta=jnp.asarray(t.node_eta),
            n_chargers=t.n_chargers,
            n_ports=t.n_ports,
            dt_hours=cfg.dt_hours,
            steps_per_episode=cfg.steps_per_episode,
            n_levels=cfg.n_levels,
            n_levels_battery=cfg.n_levels_battery,
            max_arrivals=cfg.max_arrivals_per_step,
            n_days=cfg.n_days,
            battery_soc0=cfg.station.battery_soc0,
            allow_v2g=allow_v2g,
        )

    # -- spaces ------------------------------------------------------------

    @property
    def n_ports(self) -> int:
        return self.static.n_ports

    @property
    def n_chargers(self) -> int:
        return self.static.n_chargers

    @property
    def obs_dim(self) -> int:
        return 6 * self.n_chargers + 3 + 4 + 4

    @property
    def action_nvec(self) -> np.ndarray:
        """Per-port category counts (MultiDiscrete): cars then battery."""
        return np.asarray(
            [self.cfg.n_levels] * self.n_chargers + [self.cfg.n_levels_battery]
        )

    # -- core --------------------------------------------------------------

    def reset(self, key: jnp.ndarray, exog: ExogData) -> Tuple[EnvState, jnp.ndarray]:
        """Batched reset. ``key``: [E, 2] u32. Samples a random data day per
        env (exploring starts, paper B.1)."""
        e = key.shape[0]
        c, p = self.n_chargers, self.n_ports
        keys = jax.vmap(lambda k: jax.random.split(k, 2))(key)
        key_day, key_state = keys[:, 0], keys[:, 1]
        day = jax.vmap(
            lambda k: jax.random.randint(k, (), 0, self.static.n_days)
        )(key_day).astype(jnp.int32)

        zc = jnp.zeros((e, c), jnp.float32)
        zp = jnp.zeros((e, p), jnp.float32)
        cap = jnp.ones((e, p), jnp.float32)
        cap = cap.at[:, c].set(self.cfg.station.battery_capacity_kwh)
        soc = zp.at[:, c].set(self.static.battery_soc0)
        r_bar = zp.at[:, c].set(self.cfg.station.battery_p_max_kw)
        tau = zp.at[:, c].set(self.cfg.station.battery_tau)
        from ..kernels.ref import charging_curve

        r_hat = charging_curve(soc, r_bar, jnp.maximum(tau, 1e-3)) * (
            jnp.zeros((e, p)).at[:, c].set(1.0)
        )
        state = EnvState(
            t=jnp.zeros((e,), jnp.int32),
            day=day,
            key=key_state,
            i_drawn=zp,
            occup=zc,
            soc=soc,
            de_remain=zc,
            dt_remain=zc,
            cap=cap,
            r_bar=r_bar,
            tau=tau,
            pref=zc,
            r_hat=r_hat,
            ep_return=jnp.zeros((e,), jnp.float32),
            ep_profit=jnp.zeros((e,), jnp.float32),
        )
        return state, self.observe(state, exog)

    def step(
        self, state: EnvState, action: jnp.ndarray, exog: ExogData
    ) -> Tuple[EnvState, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """One environment step with auto-reset.

        Returns (state', obs, reward [E], done [E], metrics [E, M]).
        """
        st = self.static
        steps_per_hour = int(round(1.0 / st.dt_hours))
        hour = jnp.clip(state.t // steps_per_hour, 0, 23)
        p_buy = exog.price_buy[state.day, hour]
        p_sell_grid = exog.price_sell_grid[state.day, hour]
        moer = exog.moer[state.day, hour]
        grid_demand = exog.grid_demand[state.day, hour]

        # (i) apply actions + Eq. 5 projection (L1 kernel).
        i_new, excess = tr.apply_actions(state, action, st)
        # (ii) charge (L1 kernel) — also advances t.
        state, e_port = tr.charge(state, i_new, st)
        # (iii) departures.
        state, missing, overtime, early, departed = tr.departures(state, st)
        # (iv) arrivals.
        state, rejected, arrived = tr.arrivals(state, exog, st)

        # Reward (Eq. 2-3).
        de_net, de_grid_net = rew.grid_energy(e_port, st)
        pi = rew.profit(
            de_net, de_grid_net, p_buy, p_sell_grid, exog.p_sell,
            self.cfg.fixed_cost_per_step,
        )
        costs = rew.StepCosts(
            excess_kw=excess,
            missing_kwh=missing,
            overtime_steps=overtime,
            early_steps=early,
            rejected=rejected,
        )
        pens = rew.penalties(
            costs, de_grid_net, de_net, e_port, moer, grid_demand, exog, st
        )
        r = rew.reward(pi, pens, exog)

        done = (state.t >= st.steps_per_episode).astype(jnp.float32)
        ep_return = state.ep_return + r
        ep_profit = state.ep_profit + pi
        state = state._replace(ep_return=ep_return, ep_profit=ep_profit)

        metrics = jnp.stack(
            [
                r,
                pi,
                de_net,
                de_grid_net,
                excess,
                missing,
                overtime,
                rejected,
                departed,
                arrived,
                done,
                ep_return * done,
                ep_profit * done,
            ],
            axis=1,
        )
        assert metrics.shape[1] == len(METRIC_FIELDS)

        # Auto-reset finished envs (fresh day, fresh key).
        reset_state, _ = self.reset(state.key, exog)
        state = jax.tree.map(
            lambda fresh, cur: jnp.where(
                done.reshape((-1,) + (1,) * (cur.ndim - 1)).astype(cur.dtype) > 0,
                fresh,
                cur,
            ),
            reset_state,
            state,
        )
        return state, self.observe(state, exog), r, done, metrics

    # -- observation --------------------------------------------------------

    def observe(self, state: EnvState, exog: ExogData) -> jnp.ndarray:
        """Flat observation [E, obs_dim]; see README §Observation."""
        st = self.static
        c = st.n_chargers
        steps_per_hour = int(round(1.0 / st.dt_hours))
        hour = jnp.clip(state.t // steps_per_hour, 0, 23)
        # Next-hour price wraps at midnight: hour 23 observes hour 0 of the
        # next day (mod the table length), matching rust env/core.rs.
        day_next = jnp.where(hour == 23, (state.day + 1) % st.n_days, state.day)
        hour_next = jnp.where(hour == 23, 0, hour + 1)

        per_port = jnp.concatenate(
            [
                state.occup,
                state.soc[:, :c],
                state.de_remain / 100.0,
                state.dt_remain / float(st.steps_per_episode),
                state.r_hat[:, :c] / st.p_max[None, :c],
                state.i_drawn[:, :c] / st.i_max[None, :c],
            ],
            axis=1,
        )
        battery = jnp.stack(
            [
                state.soc[:, c],
                state.i_drawn[:, c] / st.i_max[c],
                state.r_hat[:, c] / st.p_max[c],
            ],
            axis=1,
        )
        phase = 2.0 * jnp.pi * state.t.astype(jnp.float32) / float(st.steps_per_episode)
        weekday = ((state.day % 7) < 5).astype(jnp.float32)
        time_feat = jnp.stack(
            [
                jnp.sin(phase),
                jnp.cos(phase),
                weekday,
                state.day.astype(jnp.float32) / float(st.n_days),
            ],
            axis=1,
        )
        price_feat = jnp.stack(
            [
                exog.price_buy[state.day, hour],
                exog.price_buy[day_next, hour_next],
                exog.price_sell_grid[state.day, hour],
                exog.moer[state.day, hour],
            ],
            axis=1,
        )
        return jnp.concatenate([per_port, battery, time_feat, price_feat], axis=1)
