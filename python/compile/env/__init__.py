"""Chargax environment (L2): vectorized JAX implementation.

The environment is written *batched* — every state array carries a leading
env dimension [E, ...], so no vmap is needed and the L1 Pallas kernels see
full [E, P] tiles directly.
"""

from .env import ChargaxEnv
from .state import EnvState, ExogData, METRIC_FIELDS
from .tree import StationTree

__all__ = ["ChargaxEnv", "EnvState", "ExogData", "StationTree", "METRIC_FIELDS"]
