"""Actor-critic network (PureJaxRL-style MLP, paper Appendix B).

A shared tanh torso feeds (a) one categorical head per port — 16 car heads
with ``n_levels`` choices plus one battery head with ``n_levels_battery``
choices, emitted as a single concatenated logit vector — and (b) a scalar
value head. Pure jnp, no flax: parameters are a flat dict of arrays so the
AOT carry flattening is trivial and the Rust PPO baseline mirrors the same
math.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jnp.ndarray]


def head_slices(action_nvec: Sequence[int]) -> List[Tuple[int, int]]:
    """(start, end) of each port's logits inside the concatenated vector."""
    out, ofs = [], 0
    for n in action_nvec:
        out.append((ofs, ofs + int(n)))
        ofs += int(n)
    return out


def _orthogonal(key, shape, scale):
    """Variance-scaled normal init.

    PureJaxRL uses orthogonal init, but ``jnp.linalg.qr`` lowers to a
    typed-FFI custom-call (lapack geqrf) that xla_extension 0.5.1 — the
    version the rust `xla` crate binds — cannot compile. A fan-in-scaled
    normal is the standard drop-in (DESIGN.md §Substitutions) and lowers
    to pure HLO.
    """
    fan_in = shape[0]
    return scale * jax.random.normal(key, shape) / jnp.sqrt(float(fan_in))


def init_params(
    key: jnp.ndarray, obs_dim: int, hidden: int, action_nvec: Sequence[int]
) -> Params:
    n_logits = int(np.sum(action_nvec))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w1": _orthogonal(k1, (obs_dim, hidden), float(np.sqrt(2.0))),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": _orthogonal(k2, (hidden, hidden), float(np.sqrt(2.0))),
        "b2": jnp.zeros((hidden,), jnp.float32),
        "wpi": _orthogonal(k3, (hidden, n_logits), 0.01),
        "bpi": jnp.zeros((n_logits,), jnp.float32),
        "wv": _orthogonal(k4, (hidden, 1), 1.0),
        "bv": jnp.zeros((1,), jnp.float32),
    }


def apply(params: Params, obs: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """obs [B, obs_dim] -> (logits [B, sum(nvec)], value [B])."""
    h = jnp.tanh(obs @ params["w1"] + params["b1"])
    h = jnp.tanh(h @ params["w2"] + params["b2"])
    logits = h @ params["wpi"] + params["bpi"]
    value = (h @ params["wv"] + params["bv"])[:, 0]
    return logits, value


def sample_actions(
    key: jnp.ndarray, logits: jnp.ndarray, action_nvec: Sequence[int]
) -> jnp.ndarray:
    """Per-head categorical sample. Returns [B, n_ports] int32."""
    keys = jax.random.split(key, len(action_nvec))
    cols = []
    for k, (s, e) in zip(keys, head_slices(action_nvec)):
        cols.append(jax.random.categorical(k, logits[:, s:e], axis=-1))
    return jnp.stack(cols, axis=1).astype(jnp.int32)


def greedy_actions(logits: jnp.ndarray, action_nvec: Sequence[int]) -> jnp.ndarray:
    cols = [
        jnp.argmax(logits[:, s:e], axis=-1) for s, e in head_slices(action_nvec)
    ]
    return jnp.stack(cols, axis=1).astype(jnp.int32)


def log_prob_entropy(
    logits: jnp.ndarray, actions: jnp.ndarray, action_nvec: Sequence[int]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Joint (independent-head) log-prob of ``actions`` and total entropy.

    logits [B, sum(nvec)], actions [B, n_ports] -> (logp [B], ent [B]).
    """
    logp = 0.0
    ent = 0.0
    for h, (s, e) in enumerate(head_slices(action_nvec)):
        lg = jax.nn.log_softmax(logits[:, s:e], axis=-1)
        logp = logp + jnp.take_along_axis(lg, actions[:, h][:, None], axis=1)[:, 0]
        ent = ent - jnp.sum(jnp.exp(lg) * lg, axis=-1)
    return logp, ent


def n_params(params: Params) -> int:
    return int(sum(int(np.prod(v.shape)) for v in params.values()))
