"""Pallas kernel: charge-stationed-cars state update (paper A.2 step ii).

Pure VPU elementwise over the [E, P] state tile: port power -> transferred
energy (with over-fill / over-drain clips) -> SoC / remaining-demand /
remaining-time / charging-curve updates. 9 input lanes, 5 output lanes,
one VMEM tile per E-block; no MXU use. interpret=True on this image;
numerics validated against ``ref.charge_update_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-9
_BLOCK_E = 128


def _kernel(i_ref, volt_ref, pres_ref, soc_ref, de_ref, dt_ref, cap_ref,
            rbar_ref, tau_ref, soc_o, de_o, dt_o, rhat_o, e_o, *, dt_hours: float):
    volt = volt_ref[...]
    pres = pres_ref[...]
    soc = soc_ref[...]
    cap = cap_ref[...]
    rbar = rbar_ref[...]
    tau = tau_ref[...]

    p_kw = i_ref[...] * volt / 1000.0 * pres
    e = p_kw * dt_hours
    e = jnp.minimum(e, (1.0 - soc) * cap)
    e = jnp.maximum(e, -soc * cap)
    soc_n = jnp.clip(soc + e / jnp.maximum(cap, EPS), 0.0, 1.0)
    taper = (1.0 - soc_n) * rbar / jnp.maximum(1.0 - tau, EPS)
    r_hat = jnp.where(soc_n <= tau, rbar, jnp.maximum(taper, 0.0)) * pres

    soc_o[...] = soc_n
    de_o[...] = de_ref[...] - e
    dt_o[...] = dt_ref[...] - pres
    rhat_o[...] = r_hat
    e_o[...] = e


@functools.partial(jax.jit, static_argnames=("dt_hours", "interpret"))
def charge_update(i_drawn, volt, present, soc, de_remain, dt_remain, cap,
                  r_bar, tau, dt_hours: float, interpret: bool = True):
    """Batched charging step. All tensors [E, P] except volt [P].

    Returns (soc', de_remain', dt_remain', r_hat', e_port) — see
    ``ref.charge_update_ref`` for semantics.
    """
    e_dim, p = i_drawn.shape
    be = min(e_dim, _BLOCK_E)
    grid = (pl.cdiv(e_dim, be),)
    tile = pl.BlockSpec((be, p), lambda i: (i, 0))
    row = pl.BlockSpec((1, p), lambda i: (0, 0))
    f32 = lambda x: x.astype(jnp.float32)
    outs = pl.pallas_call(
        functools.partial(_kernel, dt_hours=dt_hours),
        grid=grid,
        in_specs=[tile, row] + [tile] * 7,
        out_specs=[tile] * 5,
        out_shape=[jax.ShapeDtypeStruct((e_dim, p), jnp.float32)] * 5,
        interpret=interpret,
    )(
        f32(i_drawn), f32(volt[None, :]), f32(present), f32(soc),
        f32(de_remain), f32(dt_remain), f32(cap), f32(r_bar), f32(tau),
    )
    return tuple(outs)
