"""Pure-jnp oracles for the Pallas kernels.

These are the *semantics* of the three L1 kernels — small, obviously-correct
jnp implementations used (a) by pytest to validate the Pallas kernels and
(b) as a drop-in fallback (``CHARGAX_NO_PALLAS=1``) when debugging lowering.

Conventions
-----------
* Currents ``i`` are signed amperes (+ = charging the car / battery).
* ``volt`` is the per-port voltage (phases pre-multiplied, paper A.1),
  so port power in kW is ``volt * i / 1000``.
* All per-port arrays have length P (chargers + battery last).
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-9


def charging_curve(soc, r_bar, tau):
    """Paper A.1 piecewise-linear max charging power r̂(SoC), in kW.

    r̂ = r̄ for SoC ≤ τ, then tapers linearly to 0 at SoC = 1.
    """
    taper = (1.0 - soc) * r_bar / jnp.maximum(1.0 - tau, EPS)
    return jnp.where(soc <= tau, r_bar, jnp.maximum(taper, 0.0))


def discharging_curve(soc, r_bar, tau):
    """Discharge limit: the charging curve flipped at SoC = 0.5 (paper A.1)."""
    return charging_curve(1.0 - soc, r_bar, tau)


def constraint_projection_ref(i_drawn, volt, membership, limits_kw, node_eta):
    """Eq. 5 safety layer: rescale port currents so every tree node holds.

    Args:
      i_drawn:    [P] signed port currents (A).
      volt:       [P] port voltages (V).
      membership: [N, P] 0/1 — node n is an ancestor of port p.
      limits_kw:  [N] node power capacity (kW).
      node_eta:   [N] node efficiency; a node carrying |f| kW of port power
                  loads the upstream side with |f|/η.

    Returns:
      (i_scaled [P], excess_kw scalar) — excess is the pre-projection
      constraint violation magnitude max_n max(0, |f_n|/η_n − limit_n),
      used by the soft-constraint penalty (paper A.3).
    """
    excess = jnp.asarray(0.0)
    # Two fixed-point passes: one subtree's rescale can re-expose an
    # ancestor whose flow had mixed-sign cancellation (battery discharging
    # while cars charge). For the paper's depth-2 trees (root -> per-type
    # splitters, Fig. 3b) depth passes are exact; excess reports the
    # pre-projection violation only.
    for p in range(2):
        p_kw = volt * i_drawn / 1000.0
        flow = membership @ p_kw  # [N] signed net node flow
        load = jnp.abs(flow) / jnp.maximum(node_eta, EPS)
        if p == 0:
            excess = jnp.max(jnp.maximum(load - limits_kw, 0.0))
        scale_n = jnp.minimum(
            1.0, limits_kw * node_eta / jnp.maximum(jnp.abs(flow), EPS)
        )
        # Each port is scaled by the tightest of its ancestors.
        per_port = jnp.where(membership > 0, scale_n[:, None], 1.0)  # [N, P]
        leaf_scale = jnp.min(per_port, axis=0)
        i_drawn = i_drawn * leaf_scale
    return i_drawn, excess


def charge_update_ref(i_drawn, volt, present, soc, de_remain, dt_remain,
                      cap, r_bar, tau, dt_hours):
    """Charge-stationed-cars step (paper A.2), battery included as a lane.

    ``present`` masks unoccupied ports (the battery lane is always 1).
    Energy is metered at the port: the car/battery side receives exactly
    e = p·Δt; grid-side losses are handled in the reward (A.3).

    Returns (soc', de_remain', dt_remain', r_hat', e_port) with
    e_port [P] the signed per-port energy (kWh) actually transferred.
    """
    p_kw = volt * i_drawn / 1000.0 * present
    e = p_kw * dt_hours  # kWh into the car (signed)
    # Safety clips (apply_actions already enforces these; keep the kernel
    # total regardless of inputs): cannot over-fill or over-drain.
    e = jnp.minimum(e, (1.0 - soc) * cap)
    e = jnp.maximum(e, -soc * cap)
    soc_n = jnp.clip(soc + e / jnp.maximum(cap, EPS), 0.0, 1.0)
    de_n = de_remain - e
    dt_n = dt_remain - 1.0 * present
    r_hat_n = charging_curve(soc_n, r_bar, tau) * present
    return soc_n, de_n, dt_n, r_hat_n, e


def gae_ref(rewards, values, dones, last_value, gamma, lam):
    """Generalized advantage estimation over a rollout.

    Args:
      rewards, values, dones: [T, E]; dones marks the step AFTER which the
        episode reset (value bootstrap is cut).
      last_value: [E] value of the state following the rollout.

    Returns (advantages [T, E], value_targets [T, E]).
    """
    T = rewards.shape[0]
    next_values = jnp.concatenate([values[1:], last_value[None, :]], axis=0)
    gae = jnp.zeros_like(last_value)
    out = []
    for t in range(T - 1, -1, -1):
        nonterm = 1.0 - dones[t]
        delta = rewards[t] + gamma * next_values[t] * nonterm - values[t]
        gae = delta + gamma * lam * nonterm * gae
        out.append(gae)
    adv = jnp.stack(out[::-1], axis=0)
    return adv, adv + values
