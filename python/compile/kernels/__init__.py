"""L1 Pallas kernels for the Chargax hot path.

Routing: by default every kernel runs as a Pallas kernel (interpret=True —
the only mode CPU PJRT can execute; real-TPU lowering emits Mosaic
custom-calls). Set ``CHARGAX_NO_PALLAS=1`` to route through the pure-jnp
oracles in ref.py instead — mathematically identical (pytest asserts
allclose on both paths), but XLA can fuse the jnp form far better on CPU,
so aot.py uses it for the ``*-ref`` CPU-fast artifact variants (see
EXPERIMENTS.md §Perf for the measured gap). The env var is read at call
time so one process can build both variants.
"""

from __future__ import annotations

import os

import jax

from . import ref
from .charge import charge_update as _charge_update_pallas
from .constraint import constraint_projection as _constraint_projection_pallas
from .gae import gae as _gae_pallas


def _use_ref() -> bool:
    return os.environ.get("CHARGAX_NO_PALLAS", "0") == "1"


def constraint_projection(i_drawn, volt, membership, limits_kw, node_eta):
    if _use_ref():
        return jax.vmap(
            lambda i: ref.constraint_projection_ref(i, volt, membership, limits_kw, node_eta)
        )(i_drawn)
    return _constraint_projection_pallas(i_drawn, volt, membership, limits_kw, node_eta)


def charge_update(i_drawn, volt, present, soc, de_remain, dt_remain, cap,
                  r_bar, tau, dt_hours):
    if _use_ref():
        return ref.charge_update_ref(
            i_drawn, volt[None, :], present, soc, de_remain, dt_remain,
            cap, r_bar, tau, dt_hours,
        )
    return _charge_update_pallas(
        i_drawn, volt, present, soc, de_remain, dt_remain, cap, r_bar, tau,
        dt_hours,
    )


def gae(rewards, values, dones, last_value, gamma, lam):
    if _use_ref():
        return ref.gae_ref(rewards, values, dones, last_value, gamma, lam)
    return _gae_pallas(rewards, values, dones, last_value, gamma, lam)


__all__ = ["constraint_projection", "charge_update", "gae", "ref"]
