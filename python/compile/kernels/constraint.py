"""Pallas kernel: station-tree constraint projection (paper Eq. 5).

The station architecture is a tree; every internal node n carries the net
power of the ports below it and must respect ``|flow_n| / eta_n <= limit_n``.
Violations are resolved by uniformly rescaling the offending subtree's port
currents — the "safety infrastructure on top of the controller" of A.2.

Kernel shape story (TPU): state is laid out [E, P] (envs x ports) so an
E-block is one VMEM tile; ``flow = p @ membership^T`` is an (E x P)·(P x N)
matmul on the MXU, everything else is VPU elementwise. N and P are tiny
(N <= 8 nodes, P = 17 ports by default), so the whole tree fits VMEM many
times over; we tile only over E. On this image Pallas runs interpret=True
(CPU PJRT cannot execute Mosaic custom-calls) — numerics are validated
against ``ref.constraint_projection_ref`` in pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-9
_BLOCK_E = 128  # env rows per VMEM tile


def _kernel(i_ref, volt_ref, mem_ref, lim_ref, eta_ref, out_i_ref, out_x_ref,
            *, n_nodes: int):
    i = i_ref[...]  # [Be, P]
    volt = volt_ref[...]  # [1, P]
    mem = mem_ref[...]  # [N, P]
    lim = lim_ref[...]  # [1, N]
    eta = eta_ref[...]  # [1, N]

    # Two fixed-point passes (see ref.constraint_projection_ref): exact for
    # the paper's depth-2 trees even with mixed-sign (V2G) flows.
    for p in range(2):
        p_kw = i * volt / 1000.0
        # MXU: [Be, P] @ [P, N] -> [Be, N] signed node flows.
        flow = jax.lax.dot_general(
            p_kw, mem.T, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        absf = jnp.abs(flow)
        load = absf / jnp.maximum(eta, EPS)
        if p == 0:
            out_x_ref[...] = jnp.max(
                jnp.maximum(load - lim, 0.0), axis=1, keepdims=True
            )
        scale_n = jnp.minimum(1.0, lim * eta / jnp.maximum(absf, EPS))  # [Be, N]
        leaf = jnp.ones_like(i)
        for n in range(n_nodes):  # N is tiny and static: unroll
            sel = mem[n][None, :] > 0.0  # [1, P]
            leaf = jnp.minimum(leaf, jnp.where(sel, scale_n[:, n][:, None], 1.0))
        i = i * leaf
    out_i_ref[...] = i


@functools.partial(jax.jit, static_argnames=("interpret",))
def constraint_projection(i_drawn, volt, membership, limits_kw, node_eta,
                          interpret: bool = True):
    """Batched Eq. 5 projection.

    Args:
      i_drawn:    [E, P] signed port currents (A).
      volt:       [P] port voltages.
      membership: [N, P] 0/1 ancestor matrix.
      limits_kw:  [N]; node_eta: [N].

    Returns: (i_scaled [E, P], excess_kw [E]).
    """
    e, p = i_drawn.shape
    n = membership.shape[0]
    be = min(e, _BLOCK_E)
    grid = (pl.cdiv(e, be),)
    out_i, out_x = pl.pallas_call(
        functools.partial(_kernel, n_nodes=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be, p), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((n, p), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((be, p), lambda i: (i, 0)),
            pl.BlockSpec((be, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, p), jnp.float32),
            jax.ShapeDtypeStruct((e, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        i_drawn.astype(jnp.float32),
        volt[None, :].astype(jnp.float32),
        membership.astype(jnp.float32),
        limits_kw[None, :].astype(jnp.float32),
        node_eta[None, :].astype(jnp.float32),
    )
    return out_i, out_x[:, 0]
