"""Pallas kernel: generalized advantage estimation (reverse-time scan).

GAE is the one PPO stage that resists XLA fusion — a strict reverse-time
recurrence over the rollout. The kernel keeps the whole [T, E] rollout tile
resident in VMEM (T=300, E<=16 by default: 300*16*4B*3 arrays ≈ 58 KB) and
walks it backwards with a fori_loop, carrying the running GAE accumulator
in registers. interpret=True on this image; validated against
``ref.gae_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, v_ref, d_ref, lv_ref, adv_ref, *, gamma: float, lam: float):
    t_len = r_ref.shape[0]

    def body(i, gae):
        t = t_len - 1 - i
        rt = pl.load(r_ref, (pl.dslice(t, 1), slice(None)))
        vt = pl.load(v_ref, (pl.dslice(t, 1), slice(None)))
        dt = pl.load(d_ref, (pl.dslice(t, 1), slice(None)))
        nv = jax.lax.cond(
            t == t_len - 1,
            lambda: lv_ref[...],
            lambda: pl.load(v_ref, (pl.dslice(jnp.minimum(t + 1, t_len - 1), 1), slice(None))),
        )
        nonterm = 1.0 - dt
        delta = rt + gamma * nv * nonterm - vt
        gae = delta + gamma * lam * nonterm * gae
        pl.store(adv_ref, (pl.dslice(t, 1), slice(None)), gae)
        return gae

    zero = jnp.zeros_like(lv_ref[...])
    jax.lax.fori_loop(0, t_len, body, zero)


@functools.partial(jax.jit, static_argnames=("gamma", "lam", "interpret"))
def gae(rewards, values, dones, last_value, gamma: float, lam: float,
        interpret: bool = True):
    """GAE over a rollout: rewards/values/dones [T, E], last_value [E].

    Returns (advantages [T, E], value_targets [T, E]).
    """
    t_len, e = rewards.shape
    f32 = lambda x: x.astype(jnp.float32)
    adv = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, lam=lam),
        out_shape=jax.ShapeDtypeStruct((t_len, e), jnp.float32),
        interpret=interpret,
    )(f32(rewards), f32(values), f32(dones), f32(last_value[None, :]))
    return adv, adv + values
