"""Fused PPO training iteration (PureJaxRL-style, paper Appendix B).

One call to :func:`make_train_iter`'s returned function performs, entirely
inside XLA:

  1. a ``rollout_steps``-long environment rollout (lax.scan over the batched
     env step — the L1 Pallas kernels lower inline),
  2. GAE via the L1 reverse-scan kernel,
  3. ``update_epochs`` x ``n_minibatches`` clipped-surrogate PPO updates with
     Adam and global grad-norm clipping.

The Rust coordinator calls it in a loop, feeding the returned carry back in
(see rust/src/coordinator/session.rs). Hyperparameters follow Table 3.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels, networks
from .config import PpoConfig
from .env.env import ChargaxEnv
from .env.state import METRIC_FIELDS, EnvState, ExogData


class AdamState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray  # [] i32


class TrainCarry(NamedTuple):
    params: dict
    opt: AdamState
    env_state: EnvState
    last_obs: jnp.ndarray  # [E, obs_dim]
    key: jnp.ndarray       # [2] u32
    update_i: jnp.ndarray  # [] i32 (lr annealing)


class Transition(NamedTuple):
    obs: jnp.ndarray
    action: jnp.ndarray
    logp: jnp.ndarray
    value: jnp.ndarray
    reward: jnp.ndarray
    done: jnp.ndarray
    metrics: jnp.ndarray


# Extra loss/diagnostic metrics appended to the env metric means.
TRAIN_METRIC_FIELDS = tuple(f"mean_{f}" for f in METRIC_FIELDS) + (
    "completed_episodes",
    "mean_completed_return",
    "mean_completed_profit",
    "total_loss",
    "pg_loss",
    "vf_loss",
    "entropy",
    "approx_kl",
    "clip_frac",
    "lr",
)


def adam_init(params: dict) -> AdamState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamState(m=z, v=jax.tree.map(jnp.zeros_like, params),
                     count=jnp.zeros((), jnp.int32))


def adam_update(grads: dict, opt: AdamState, params: dict, lr,
                b1=0.9, b2=0.999, eps=1e-8) -> Tuple[dict, AdamState]:
    count = opt.count + 1
    cf = count.astype(jnp.float32)
    m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g, opt.m, grads)
    v = jax.tree.map(lambda vo, g: b2 * vo + (1 - b2) * g * g, opt.v, grads)
    mhat = jax.tree.map(lambda x: x / (1 - b1 ** cf), m)
    vhat = jax.tree.map(lambda x: x / (1 - b2 ** cf), v)
    new_params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new_params, AdamState(m=m, v=v, count=count)


def clip_global_norm(grads: dict, max_norm: float) -> dict:
    sq = sum(jnp.sum(g * g) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


def make_train_init(env: ChargaxEnv, ppo: PpoConfig, exog: ExogData):
    """seed [] u32 -> TrainCarry (used once, lowered as train_init)."""

    nvec = tuple(int(x) for x in env.action_nvec)

    def train_init(seed):
        key = jax.random.PRNGKey(seed)
        k_net, k_env, k_run = jax.random.split(key, 3)
        params = networks.init_params(k_net, env.obs_dim, ppo.hidden, nvec)
        env_keys = jax.random.split(k_env, ppo.num_envs)
        env_state, obs = env.reset(env_keys, exog)
        return TrainCarry(
            params=params,
            opt=adam_init(params),
            env_state=env_state,
            last_obs=obs,
            key=k_run,
            update_i=jnp.zeros((), jnp.int32),
        )

    return train_init


def make_train_iter(env: ChargaxEnv, ppo: PpoConfig, total_updates: int):
    """Build the fused (carry, exog) -> (carry', metrics) iteration."""

    nvec = tuple(int(x) for x in env.action_nvec)

    def rollout_step(carry, _, exog: ExogData):
        tc: TrainCarry = carry
        key, k_act = jax.random.split(tc.key)
        logits, value = networks.apply(tc.params, tc.last_obs)
        action = networks.sample_actions(k_act, logits, nvec)
        logp, _ = networks.log_prob_entropy(logits, action, nvec)
        env_state, obs, rwd, done, metrics = env.step(tc.env_state, action, exog)
        trans = Transition(
            obs=tc.last_obs, action=action, logp=logp, value=value,
            reward=rwd, done=done, metrics=metrics,
        )
        return tc._replace(env_state=env_state, last_obs=obs, key=key), trans

    def loss_fn(params, batch, clip_eps, ent_coef, vf_coef, vf_clip):
        obs, action, old_logp, old_value, adv, target = batch
        logits, value = networks.apply(params, obs)
        logp, ent = networks.log_prob_entropy(logits, action, nvec)
        ratio = jnp.exp(logp - old_logp)
        adv_n = (adv - adv.mean()) / (adv.std() + 1e-8)
        pg1 = ratio * adv_n
        pg2 = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv_n
        pg_loss = -jnp.mean(jnp.minimum(pg1, pg2))
        v_clipped = old_value + jnp.clip(value - old_value, -vf_clip, vf_clip)
        vf_loss = 0.5 * jnp.mean(
            jnp.maximum((value - target) ** 2, (v_clipped - target) ** 2)
        )
        ent_mean = jnp.mean(ent)
        total = pg_loss + vf_coef * vf_loss - ent_coef * ent_mean
        approx_kl = jnp.mean(old_logp - logp)
        clip_frac = jnp.mean((jnp.abs(ratio - 1.0) > clip_eps).astype(jnp.float32))
        return total, (pg_loss, vf_loss, ent_mean, approx_kl, clip_frac)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_iter(carry: TrainCarry, exog: ExogData):
        # ---- 1. rollout ----------------------------------------------------
        carry, traj = jax.lax.scan(
            lambda c, x: rollout_step(c, x, exog), carry, None,
            length=ppo.rollout_steps,
        )
        _, last_value = networks.apply(carry.params, carry.last_obs)

        # ---- 2. GAE (L1 kernel) -------------------------------------------
        adv, target = kernels.gae(
            traj.reward, traj.value, traj.done, last_value,
            ppo.gamma, ppo.gae_lambda,
        )
        adv = jax.lax.stop_gradient(adv)
        target = jax.lax.stop_gradient(target)

        lr = jnp.asarray(ppo.lr, jnp.float32)
        if ppo.anneal_lr:
            frac = 1.0 - carry.update_i.astype(jnp.float32) / float(total_updates)
            lr = lr * jnp.maximum(frac, 0.0)

        # ---- 3. minibatched updates ----------------------------------------
        bsz = ppo.batch_size
        flat = lambda x: x.reshape((bsz,) + x.shape[2:])
        dataset = (
            flat(traj.obs), flat(traj.action), flat(traj.logp),
            flat(traj.value), flat(adv), flat(target),
        )

        def epoch(state, _):
            params, opt, key = state
            key, k_perm = jax.random.split(key)
            perm = jax.random.permutation(k_perm, bsz)
            shuffled = tuple(x[perm] for x in dataset)
            mb = tuple(
                x.reshape((ppo.n_minibatches, ppo.minibatch_size) + x.shape[1:])
                for x in shuffled
            )

            def minibatch(state, batch):
                params, opt = state
                (total, aux), grads = grad_fn(
                    params, batch, ppo.clip_eps, ppo.ent_coef, ppo.vf_coef,
                    ppo.vf_clip,
                )
                grads = clip_global_norm(grads, ppo.max_grad_norm)
                params, opt = adam_update(grads, opt, params, lr)
                return (params, opt), jnp.stack((total,) + aux)

            (params, opt), stats = jax.lax.scan(minibatch, (params, opt), mb)
            return (params, opt, key), stats

        (params, opt, key), stats = jax.lax.scan(
            epoch, (carry.params, carry.opt, carry.key), None,
            length=ppo.update_epochs,
        )
        stats = stats.reshape((-1, 6)).mean(axis=0)

        carry = carry._replace(
            params=params, opt=opt, key=key, update_i=carry.update_i + 1
        )

        # ---- metrics --------------------------------------------------------
        met_mean = traj.metrics.mean(axis=(0, 1))  # [len(METRIC_FIELDS)]
        done_cnt = jnp.maximum(traj.metrics[:, :, METRIC_FIELDS.index("done")].sum(), 1.0)
        comp_ret = traj.metrics[:, :, METRIC_FIELDS.index("ep_return")].sum() / done_cnt
        comp_prof = traj.metrics[:, :, METRIC_FIELDS.index("ep_profit")].sum() / done_cnt
        metrics = jnp.concatenate([
            met_mean,
            jnp.stack([
                traj.metrics[:, :, METRIC_FIELDS.index("done")].sum(),
                comp_ret,
                comp_prof,
                stats[0], stats[1], stats[2], stats[3], stats[4], stats[5],
                lr,
            ]),
        ])
        return carry, metrics

    assert len(TRAIN_METRIC_FIELDS) == len(METRIC_FIELDS) + 10
    return train_iter


def make_eval_rollout(env: ChargaxEnv, ppo: PpoConfig, policy: str = "net"):
    """Full-episode evaluation: (params, seed, exog) -> summary vector.

    ``policy``: 'net' (greedy argmax), 'max' (paper's always-charge-max
    baseline, battery idle), 'random'. Returns EVAL_METRIC_FIELDS.
    """
    nvec = tuple(int(x) for x in env.action_nvec)
    n_ports = env.n_ports

    def act(params, obs, key):
        if policy == "net":
            logits, _ = networks.apply(params, obs)
            return networks.greedy_actions(logits, nvec)
        e = obs.shape[0]
        if policy == "max":
            a = jnp.full((e, n_ports), 0, jnp.int32)
            a = a.at[:, : n_ports - 1].set(
                jnp.asarray([n - 1 for n in nvec[:-1]], jnp.int32)[None, :]
            )
            # battery idle = midpoint level (zero current)
            a = a.at[:, n_ports - 1].set((nvec[-1] - 1) // 2)
            return a
        # random
        cols = [
            jax.random.randint(jax.random.fold_in(key, h), (e,), 0, nvec[h])
            for h in range(n_ports)
        ]
        return jnp.stack(cols, axis=1).astype(jnp.int32)

    def eval_rollout(params, seed, exog: ExogData):
        key = jax.random.PRNGKey(seed)
        k_env, k_act = jax.random.split(key)
        env_keys = jax.random.split(k_env, ppo.num_envs)
        state, obs = env.reset(env_keys, exog)

        def step(c, i):
            state, obs = c
            a = act(params, obs, jax.random.fold_in(k_act, i))
            state, obs, r, done, metrics = env.step(state, a, exog)
            return (state, obs), metrics

        _, mets = jax.lax.scan(
            step, (state, obs), jnp.arange(env.static.steps_per_episode)
        )
        # mets: [T, E, M] — exactly one episode per env (reset at t=T).
        total = mets.sum(axis=0)  # [E, M]
        mi = METRIC_FIELDS.index
        return jnp.stack([
            total[:, mi("reward")].mean(),
            total[:, mi("profit")].mean(),
            total[:, mi("energy_to_cars_kwh")].mean(),
            total[:, mi("missing_kwh")].mean(),
            total[:, mi("overtime_steps")].mean(),
            total[:, mi("rejected")].mean(),
            total[:, mi("departed")].mean(),
            total[:, mi("arrived")].mean(),
            total[:, mi("excess_kw")].mean(),
            total[:, mi("energy_grid_net_kwh")].mean(),
        ])

    return eval_rollout


EVAL_METRIC_FIELDS = (
    "ep_reward", "ep_profit", "ep_energy_kwh", "ep_missing_kwh",
    "ep_overtime_steps", "ep_rejected", "ep_departed", "ep_arrived",
    "ep_excess_kw", "ep_grid_net_kwh",
)


def make_random_rollout(env: ChargaxEnv, num_envs: int, n_steps: int):
    """(seed, exog) -> (mean step metrics, steps done). Table 2 'Random' row.

    The whole n_steps rollout is one fused scan — a single PJRT call
    advances num_envs * n_steps environment steps.
    """
    nvec = tuple(int(x) for x in env.action_nvec)

    def random_rollout(seed, exog: ExogData):
        key = jax.random.PRNGKey(seed)
        k_env, k_act = jax.random.split(key)
        env_keys = jax.random.split(k_env, num_envs)
        state, obs = env.reset(env_keys, exog)

        def step(c, i):
            state, obs = c
            cols = [
                jax.random.randint(
                    jax.random.fold_in(jax.random.fold_in(k_act, i), h),
                    (num_envs,), 0, nvec[h],
                )
                for h in range(len(nvec))
            ]
            a = jnp.stack(cols, axis=1).astype(jnp.int32)
            state, obs, r, done, metrics = env.step(state, a, exog)
            return (state, obs), metrics

        _, mets = jax.lax.scan(step, (state, obs), jnp.arange(n_steps))
        return mets.mean(axis=(0, 1)), jnp.asarray(n_steps * num_envs, jnp.int32)

    return random_rollout
