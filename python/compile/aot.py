"""AOT entrypoint: lower every program of every variant to HLO *text*.

HLO text — NOT ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate binds)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--variants a,b] [--quick]

Writes::

    artifacts/<program>_<variant>.hlo.txt
    artifacts/manifest.json       # I/O leaf specs per program
    artifacts/data/*.json         # exogenous tables for the Rust side
    artifacts/data/test_vectors.json  # cross-check vectors (rust tests)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import numpy as np

from . import data
from .config import PpoConfig, make_configs
from .model import ModelBundle, leaf_spec

# Variants built by default: the Table-3 training config (12 envs), the
# Table-2 single-env and 16-env benchmark configs, and the Fig. 9-11
# charger mixes.
DEFAULT_VARIANTS = (
    ("mix10dc6ac", 12),
    ("mix10dc6ac", 1),
    ("mix10dc6ac", 16),
    ("ac16", 12),
    ("mix8dc8ac", 12),
    ("dc16", 12),
    # CPU-fast kernel routing (jnp oracles; XLA fuses them far better than
    # interpret-mode Pallas on CPU) — the Table 2 / production-CPU variants.
    ("mix10dc6ac-ref", 12),
    ("mix10dc6ac-ref", 1),
    ("mix10dc6ac-ref", 16),
)

RANDOM_ROLLOUT_STEPS = 1000


def build_variant(station: str, num_envs: int, out_dir: str, quick: bool) -> dict:
    # "-ref" variants route kernels through the jnp oracles (read at trace
    # time by compile.kernels).
    if station.endswith("-ref"):
        os.environ["CHARGAX_NO_PALLAS"] = "1"
    else:
        os.environ.pop("CHARGAX_NO_PALLAS", None)
    env_cfg, ppo_cfg = make_configs(station, num_envs)
    if quick:
        ppo_cfg = PpoConfig(num_envs=num_envs, rollout_steps=32, n_minibatches=2)
    bundle = ModelBundle(env_cfg, ppo_cfg)
    key = f"{station}_e{num_envs}"

    programs = [
        bundle.program_train_init(),
        bundle.program_train_iter(),
        bundle.program_eval("net"),
        bundle.program_eval("max"),
        bundle.program_eval("random"),
        bundle.program_random_rollout(RANDOM_ROLLOUT_STEPS),
        bundle.program_env_reset(),
        bundle.program_env_step(),
    ]

    entry = {"meta": bundle.env_meta(), "programs": {}}
    entry["meta"]["random_rollout_steps"] = RANDOM_ROLLOUT_STEPS
    for prog in programs:
        t0 = time.time()
        text = prog.lower_hlo_text()
        fname = f"{prog.name}_{key}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outputs = _output_specs(prog)
        entry["programs"][prog.name] = {
            "file": fname,
            "inputs": [
                leaf_spec(n, x)
                for n, x in zip(prog.input_names, prog.example_inputs)
            ],
            "outputs": outputs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(
            f"  [{key}] {prog.name}: {len(text) / 1e6:.2f} MB HLO"
            f" ({time.time() - t0:.1f}s)",
            flush=True,
        )
    return entry


def _output_specs(prog) -> list:
    import jax

    shapes = jax.eval_shape(prog.fn, *prog.example_inputs)
    leaves = jax.tree_util.tree_leaves(shapes)
    assert len(leaves) == len(prog.output_names), (
        prog.name, len(leaves), len(prog.output_names),
    )
    return [
        leaf_spec(n, np.zeros(s.shape, s.dtype))
        for n, s in zip(prog.output_names, leaves)
    ]


def export_test_vectors(out_path: str) -> None:
    """Deterministic transition/reward vectors for the Rust cross-check."""
    import jax.numpy as jnp

    from .env.state import PENALTIES
    from .kernels import ref

    rng = np.random.default_rng(42)
    cases = []
    p, n = 17, 3
    volt = np.where(np.arange(p) < 10, 400.0, 230.0).astype(np.float32)
    volt[-1] = 400.0
    mem = np.zeros((n, p), np.float32)
    mem[0] = 1.0
    mem[1, :10] = 1.0
    mem[2, 10:16] = 1.0
    lim = np.array([600.0, 450.0, 60.0], np.float32)
    eta = np.array([0.98, 0.98, 0.98], np.float32)
    for _ in range(16):
        i = rng.normal(0.0, 150.0, p).astype(np.float32)
        si, ex = ref.constraint_projection_ref(
            jnp.asarray(i), jnp.asarray(volt), jnp.asarray(mem),
            jnp.asarray(lim), jnp.asarray(eta),
        )
        cases.append(
            {
                "kind": "constraint",
                "i_drawn": i.tolist(),
                "volt": volt.tolist(),
                "membership": mem.tolist(),
                "limits": lim.tolist(),
                "eta": eta.tolist(),
                "want_i": np.asarray(si).tolist(),
                "want_excess": float(ex),
            }
        )
    for _ in range(16):
        soc = rng.uniform(0.0, 1.0, p).astype(np.float32)
        pres = (rng.random(p) < 0.7).astype(np.float32)
        i = rng.normal(0.0, 120.0, p).astype(np.float32)
        de = rng.uniform(0.0, 60.0, p).astype(np.float32)
        dtr = rng.uniform(0.0, 40.0, p).astype(np.float32)
        cap = rng.uniform(20.0, 110.0, p).astype(np.float32)
        rbar = rng.uniform(5.0, 160.0, p).astype(np.float32)
        tau = rng.uniform(0.4, 0.8, p).astype(np.float32)
        outs = ref.charge_update_ref(
            jnp.asarray(i)[None], jnp.asarray(volt)[None], pres[None],
            soc[None], de[None], dtr[None], cap[None], rbar[None], tau[None],
            1.0 / 12.0,
        )
        cases.append(
            {
                "kind": "charge",
                "i_drawn": i.tolist(), "volt": volt.tolist(),
                "present": pres.tolist(), "soc": soc.tolist(),
                "de_remain": de.tolist(), "dt_remain": dtr.tolist(),
                "cap": cap.tolist(), "r_bar": rbar.tolist(),
                "tau": tau.tolist(), "dt_hours": 1.0 / 12.0,
                "want": [np.asarray(o)[0].tolist() for o in outs],
            }
        )
    for _ in range(8):
        soc = float(rng.uniform(0, 1))
        rbar = float(rng.uniform(5, 200))
        tau = float(rng.uniform(0.3, 0.9))
        cases.append(
            {
                "kind": "curve",
                "soc": soc, "r_bar": rbar, "tau": tau,
                "want_charge": float(ref.charging_curve(soc, rbar, tau)),
                "want_discharge": float(ref.discharging_curve(soc, rbar, tau)),
            }
        )
    with open(out_path, "w") as f:
        json.dump({"penalty_order": list(PENALTIES), "cases": cases}, f)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(f"{s}_e{e}" for s, e in DEFAULT_VARIANTS),
        help="comma-separated station_eN keys",
    )
    ap.add_argument(
        "--quick", action="store_true",
        help="tiny rollout/minibatch sizes (CI smoke builds)",
    )
    ap.add_argument(
        "--merge", action="store_true",
        help="merge new variants into an existing manifest instead of replacing it",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    data_dir = os.path.join(args.out_dir, "data")
    print("exporting data tables ...", flush=True)
    data.export_all(data_dir)
    export_test_vectors(os.path.join(data_dir, "test_vectors.json"))

    manifest = {"format": 1, "quick": args.quick, "variants": {}}
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if args.merge and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    for spec in args.variants.split(","):
        station, e = spec.rsplit("_e", 1)
        print(f"building variant {spec} ...", flush=True)
        manifest["variants"][spec] = build_variant(
            station, int(e), args.out_dir, args.quick
        )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("manifest written", flush=True)


if __name__ == "__main__":
    sys.exit(main())
