"""Station tree construction and custom architectures (paper Fig. 3)."""

import numpy as np
import pytest

from compile.config import STATION_VARIANTS, StationConfig
from compile.env.tree import StationTree


class TestStandardTree:
    def test_default_layout(self):
        t = StationTree.standard(StationConfig())
        t.validate()
        assert t.n_ports == 17
        assert t.n_chargers == 16
        assert t.node_names == ("root", "dc_splitter", "ac_splitter")
        # DC ports 0..9 under dc_splitter, AC 10..15 under ac_splitter.
        assert t.membership[1, :10].all() and not t.membership[1, 10:].any()
        assert t.membership[2, 10:16].all() and not t.membership[2, :10].any()
        # battery only under root
        assert t.membership[0, 16] == 1 and t.membership[1:, 16].sum() == 0

    def test_port_ratings(self):
        t = StationTree.standard(StationConfig())
        assert np.allclose(t.p_max[:10], 150.0)
        assert np.allclose(t.p_max[10:16], 11.5)
        assert np.isclose(t.p_max[16], 100.0)

    @pytest.mark.parametrize("name", list(STATION_VARIANTS))
    def test_variants_validate(self, name):
        t = StationTree.standard(STATION_VARIANTS[name])
        t.validate()
        # only-AC / only-DC variants drop the empty splitter node.
        if name == "ac16":
            assert "dc_splitter" not in t.node_names
        if name == "dc16":
            assert "ac_splitter" not in t.node_names


class TestCustomTree:
    def test_custom_nodes(self):
        cfg = StationConfig(n_dc=4, n_ac=2)
        t = StationTree.custom(
            cfg,
            [
                ("left_cable", [0, 1], 200.0, 0.97),
                ("right_cable", [2, 3], 200.0, 0.97),
                ("ac_box", [4, 5], 22.0, 0.99),
            ],
        )
        t.validate()
        assert t.node_names[0] == "root"  # auto-prepended
        assert t.n_nodes == 4
        assert t.membership[1, 0] == 1 and t.membership[1, 2] == 0
        assert np.isclose(t.node_eta[3], 0.99)

    def test_custom_with_explicit_root(self):
        cfg = StationConfig(n_dc=1, n_ac=1)
        t = StationTree.custom(cfg, [("root", [0, 1, 2], 100.0, 0.95)])
        assert t.n_nodes == 1

    def test_validate_rejects_rootless(self):
        cfg = StationConfig(n_dc=1, n_ac=1)
        t = StationTree.standard(cfg)
        bad = t.membership.copy()
        bad[0, 0] = 0.0
        import dataclasses

        broken = dataclasses.replace(t, membership=bad)
        with pytest.raises(AssertionError):
            broken.validate()
