"""L2 environment invariants: transition structure, reward identity,
auto-reset, arrivals/departures bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import EnvConfig, PpoConfig, StationConfig, STATION_VARIANTS
from compile.env import ChargaxEnv
from compile.env.state import METRIC_FIELDS, metric_index
from compile.exog import default_exog


@pytest.fixture(scope="module")
def env():
    return ChargaxEnv(EnvConfig())


@pytest.fixture(scope="module")
def exog():
    return default_exog(traffic="high")


def batched_keys(e, base=0):
    return jax.vmap(jax.random.PRNGKey)(jnp.arange(base, base + e, dtype=jnp.uint32))


def random_actions(rng, env, e):
    return jnp.asarray(
        rng.integers(0, np.asarray(env.action_nvec)[None, :].repeat(e, 0)),
        dtype=jnp.int32,
    )


class TestReset:
    def test_shapes_and_emptiness(self, env, exog):
        e = 5
        state, obs = env.reset(batched_keys(e), exog)
        assert obs.shape == (e, env.obs_dim)
        assert state.occup.shape == (e, env.n_chargers)
        assert float(state.occup.sum()) == 0.0
        assert np.allclose(np.asarray(state.soc)[:, -1], 0.5)  # battery soc0
        assert (np.asarray(state.day) >= 0).all()
        assert (np.asarray(state.day) < 365).all()

    def test_different_keys_different_days(self, env, exog):
        state, _ = env.reset(batched_keys(64), exog)
        assert len(np.unique(np.asarray(state.day))) > 5

    def test_observation_finite(self, env, exog):
        _, obs = env.reset(batched_keys(8), exog)
        assert bool(jnp.isfinite(obs).all())


class TestStep:
    def test_metric_vector_layout(self, env, exog):
        e = 3
        state, _ = env.reset(batched_keys(e), exog)
        rng = np.random.default_rng(0)
        state, obs, r, done, met = jax.jit(env.step)(
            state, random_actions(rng, env, e), exog
        )
        assert met.shape == (e, len(METRIC_FIELDS))
        np.testing.assert_allclose(
            np.asarray(met[:, metric_index("reward")]), np.asarray(r), atol=1e-5
        )

    def test_time_advances_and_autoreset(self, env, exog):
        e = 2
        state, _ = env.reset(batched_keys(e), exog)
        step = jax.jit(env.step)
        rng = np.random.default_rng(1)
        for i in range(env.cfg.steps_per_episode):
            state, _, _, done, _ = step(state, random_actions(rng, env, e), exog)
        # Episode ended exactly at step 288 and auto-reset to t=0.
        assert bool((np.asarray(done) == 1.0).all())
        assert (np.asarray(state.t) == 0).all()
        assert float(state.occup.sum()) == 0.0

    def test_occupancy_bounded(self, env, exog):
        e = 4
        state, _ = env.reset(batched_keys(e), exog)
        step = jax.jit(env.step)
        rng = np.random.default_rng(2)
        for _ in range(150):
            state, _, _, _, met = step(state, random_actions(rng, env, e), exog)
            occ = np.asarray(state.occup)
            assert ((occ == 0.0) | (occ == 1.0)).all()
            assert bool(jnp.isfinite(state.soc).all())
            soc = np.asarray(state.soc)
            assert (soc >= -1e-5).all() and (soc <= 1.0 + 1e-5).all()

    def test_idle_actions_cost_fixed_fee(self, env, exog):
        """All-zero actions + empty station: reward = -c_dt (no arrivals at
        midnight is the common case; allow arrivals by masking)."""
        e = 4
        state, _ = env.reset(batched_keys(e), exog)
        a = jnp.zeros((e, env.n_ports), jnp.int32)
        # battery midpoint level = zero current
        a = a.at[:, -1].set((env.cfg.n_levels_battery - 1) // 2)
        state, _, r, _, met = jax.jit(env.step)(state, a, exog)
        de = np.asarray(met[:, metric_index("energy_to_cars_kwh")])
        np.testing.assert_allclose(de, 0.0, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(r), -env.cfg.fixed_cost_per_step, atol=1e-5
        )

    def test_cars_arrive_and_depart_over_a_day(self, env, exog):
        e = 4
        state, _ = env.reset(batched_keys(e, base=50), exog)
        step = jax.jit(env.step)
        rng = np.random.default_rng(3)
        acc = np.zeros((e, len(METRIC_FIELDS)))
        for _ in range(env.cfg.steps_per_episode):
            state, _, _, _, met = step(state, random_actions(rng, env, e), exog)
            acc += np.asarray(met)
        arrived = acc[:, metric_index("arrived")]
        departed = acc[:, metric_index("departed")]
        assert (arrived > 20).all(), arrived  # high-traffic shopping day
        assert (departed <= arrived).all()
        assert (departed >= arrived * 0.7).all()

    def test_max_actions_transfer_energy(self, env, exog):
        e = 4
        state, _ = env.reset(batched_keys(e, base=80), exog)
        step = jax.jit(env.step)
        a = jnp.full((e, env.n_ports), env.cfg.n_levels - 1, jnp.int32)
        a = a.at[:, -1].set((env.cfg.n_levels_battery - 1) // 2)
        total_e = np.zeros(e)
        for _ in range(180):
            state, _, _, _, met = step(state, a, exog)
            total_e += np.asarray(met[:, metric_index("energy_to_cars_kwh")])
        assert (total_e > 50.0).all(), total_e


class TestConstraintsInsideStep:
    def test_node_limits_hold_for_any_action(self, env, exog):
        """Post-projection drawn power can never exceed the root limit."""
        e = 6
        state, _ = env.reset(batched_keys(e, base=7), exog)
        step = jax.jit(env.step)
        a = jnp.full((e, env.n_ports), env.cfg.n_levels - 1, jnp.int32)
        a = a.at[:, -1].set(env.cfg.n_levels_battery - 1)  # battery max charge
        tree = env.tree
        for _ in range(100):
            state, _, _, _, _ = step(state, a, exog)
            p_kw = np.asarray(state.i_drawn) * tree.volt[None, :] / 1000.0
            flows = p_kw @ tree.membership.T
            load = np.abs(flows) / tree.node_eta[None, :]
            assert (load <= tree.node_limit[None, :] + 1e-2).all()


class TestRewardIdentity:
    def test_profit_formula(self, env, exog):
        """reward == profit when all alpha are 0 (default exog)."""
        e = 3
        state, _ = env.reset(batched_keys(e, base=11), exog)
        rng = np.random.default_rng(4)
        step = jax.jit(env.step)
        for _ in range(50):
            state, _, r, _, met = step(state, random_actions(rng, env, e), exog)
            np.testing.assert_allclose(
                np.asarray(r),
                np.asarray(met[:, metric_index("profit")]),
                atol=1e-5,
            )

    def test_alpha_declined_reduces_reward(self, env):
        exog_pen = default_exog(traffic="high", alpha={"declined": 5.0})
        exog_free = default_exog(traffic="high")
        e = 8
        state_p, _ = env.reset(batched_keys(e, base=21), exog_pen)
        state_f, _ = env.reset(batched_keys(e, base=21), exog_f := exog_free)
        step = jax.jit(env.step)
        rng = np.random.default_rng(5)
        rp = rf = 0.0
        rej = 0.0
        for _ in range(288):
            a = random_actions(rng, env, e)
            state_p, _, r1, _, met1 = step(state_p, a, exog_pen)
            state_f, _, r2, _, _ = step(state_f, a, exog_f)
            rp += float(r1.sum())
            rf += float(r2.sum())
            rej += float(met1[:, metric_index("rejected")].sum())
        if rej > 0:
            assert rp < rf


class TestVariants:
    @pytest.mark.parametrize("name", list(STATION_VARIANTS))
    def test_all_station_variants_step(self, name, exog):
        env = ChargaxEnv(EnvConfig(station=STATION_VARIANTS[name]))
        e = 2
        state, obs = env.reset(batched_keys(e), exog)
        rng = np.random.default_rng(0)
        state, obs, r, done, met = jax.jit(env.step)(
            state, random_actions(rng, env, e), exog
        )
        assert obs.shape == (e, env.obs_dim)
        assert bool(jnp.isfinite(obs).all())
