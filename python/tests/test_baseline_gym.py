"""The per-step python comparator must implement the same MDP as the JAX
env: identical deterministic sub-transitions and consistent aggregate
behaviour."""

import numpy as np
import pytest

from baselines.gym_env import (
    GymChargingEnv,
    charging_curve,
    default_tables,
    discharging_curve,
)
from compile.kernels import ref


class TestCurveEquivalence:
    @pytest.mark.parametrize("soc", [0.0, 0.3, 0.6, 0.85, 1.0])
    def test_matches_jax_ref(self, soc):
        assert abs(
            charging_curve(soc, 150.0, 0.55) - float(ref.charging_curve(soc, 150.0, 0.55))
        ) < 1e-3
        assert abs(
            discharging_curve(soc, 150.0, 0.55)
            - float(ref.discharging_curve(soc, 150.0, 0.55))
        ) < 1e-3


class TestGymEnv:
    @pytest.fixture(scope="class")
    def env(self):
        return GymChargingEnv(default_tables(), seed=0)

    def test_reset_and_obs(self, env):
        obs = env.reset()
        assert obs.shape == (env.obs_dim,)
        assert np.isfinite(obs).all()

    def test_full_day_dynamics(self, env):
        env.reset()
        rng = np.random.default_rng(0)
        nvec = env.action_nvec()
        total_r = 0.0
        arrived_any = False
        for i in range(288):
            a = rng.integers(0, nvec)
            obs, r, done, info = env.step(a)
            total_r += r
            arrived_any = arrived_any or any(e.car is not None for e in env.evses)
            assert np.isfinite(r)
        assert done or env.t == 0  # episode boundary handled
        assert arrived_any

    def test_constraints_hold(self, env):
        env.reset()
        nvec = env.action_nvec()
        for _ in range(100):
            a = [n - 1 for n in nvec]  # everything at max
            env.step(a)
            currents = [e.i_drawn for e in env.evses] + [env.battery.i_drawn]
            volts = [e.voltage for e in env.evses] + [env.battery.voltage]
            for node in env.nodes:
                flow = sum(volts[j] * currents[j] / 1000.0 for j in node.ports)
                assert abs(flow) / node.eta <= node.limit_kw + 1e-2

    def test_idle_step_costs_fixed_fee(self):
        env = GymChargingEnv(default_tables(), seed=1)
        a = [0] * len(env.evses) + [10]  # battery midpoint = idle
        _, r, _, info = env.step(a)
        assert abs(info["profit"] - r) < 1e-9  # alpha = 0
        # no cars at t=0 -> only the fixed cost
        assert abs(r + 0.25) < 1e-6 or info["profit"] != r


class TestNumpyPpoSmoke:
    def test_one_iteration_runs_and_learns_shape(self):
        from baselines.ppo_numpy import NumpyPpo

        envs = [GymChargingEnv(default_tables(), seed=i) for i in range(2)]
        ppo = NumpyPpo(envs, seed=0, rollout_steps=16, n_minibatches=2,
                       update_epochs=1)
        w_before = ppo.mlp.w1.copy()
        mean_r = ppo.iteration()
        assert np.isfinite(mean_r)
        assert not np.allclose(w_before, ppo.mlp.w1)
