"""V2G action mode, exogenous swapping semantics, and battery behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import EnvConfig
from compile.env import ChargaxEnv
from compile.env.state import METRIC_FIELDS, metric_index
from compile.exog import default_exog


def keys(e, base=0):
    return jax.vmap(jax.random.PRNGKey)(jnp.arange(base, base + e, dtype=jnp.uint32))


class TestBattery:
    def test_battery_charges_and_discharges(self):
        env = ChargaxEnv(EnvConfig())
        exog = default_exog(traffic="low")
        e = 2
        state, _ = env.reset(keys(e), exog)
        step = jax.jit(env.step)
        # charge battery at max for 2 hours
        a = jnp.zeros((e, env.n_ports), jnp.int32)
        a = a.at[:, -1].set(env.cfg.n_levels_battery - 1)
        for _ in range(24):
            state, _, _, _, _ = step(state, a, exog)
        soc_up = float(state.soc[:, -1].mean())
        assert soc_up > 0.55, soc_up
        # now discharge
        a = a.at[:, -1].set(0)
        for _ in range(24):
            state, _, _, _, met = step(state, a, exog)
        soc_dn = float(state.soc[:, -1].mean())
        assert soc_dn < soc_up
        # discharging feeds the grid: negative net grid energy
        assert float(met[:, metric_index("energy_grid_net_kwh")].mean()) < 0.0

    def test_battery_charge_respects_curve_taper(self):
        env = ChargaxEnv(EnvConfig())
        exog = default_exog(traffic="low")
        state, _ = env.reset(keys(1), exog)
        step = jax.jit(env.step)
        a = jnp.zeros((1, env.n_ports), jnp.int32)
        a = a.at[:, -1].set(env.cfg.n_levels_battery - 1)
        deltas = []
        prev = float(state.soc[0, -1])
        for _ in range(60):
            state, _, _, _, _ = step(state, a, exog)
            cur = float(state.soc[0, -1])
            deltas.append(cur - prev)
            prev = cur
        # past tau=0.8 the per-step SoC gain must shrink
        early = np.mean(deltas[:6])
        late = np.mean(deltas[-6:])
        assert late < early


class TestV2G:
    def test_v2g_flag_allows_car_discharge(self):
        env = ChargaxEnv(EnvConfig(), allow_v2g=True)
        exog = default_exog(traffic="high")
        e = 4
        state, _ = env.reset(keys(e, base=30), exog)
        step = jax.jit(env.step)
        # fill station first with max charging
        a_max = jnp.full((e, env.n_ports), env.cfg.n_levels - 1, jnp.int32)
        a_max = a_max.at[:, -1].set((env.cfg.n_levels_battery - 1) // 2)
        for _ in range(80):
            state, _, _, _, _ = step(state, a_max, exog)
        # now level 0 = -100% (discharge) in V2G mode
        a_dis = jnp.zeros((e, env.n_ports), jnp.int32)
        a_dis = a_dis.at[:, -1].set((env.cfg.n_levels_battery - 1) // 2)
        state, _, _, _, met = step(state, a_dis, exog)
        de = float(met[:, metric_index("energy_to_cars_kwh")].sum())
        assert de < 0.0, "cars should discharge under V2G level 0"

    def test_no_v2g_level_zero_is_idle(self):
        env = ChargaxEnv(EnvConfig(), allow_v2g=False)
        exog = default_exog(traffic="high")
        e = 4
        state, _ = env.reset(keys(e, base=30), exog)
        step = jax.jit(env.step)
        a = jnp.zeros((e, env.n_ports), jnp.int32)
        a = a.at[:, -1].set((env.cfg.n_levels_battery - 1) // 2)
        for _ in range(40):
            state, _, _, _, met = step(state, a, exog)
            assert float(met[:, metric_index("energy_to_cars_kwh")].sum()) >= -1e-5


class TestExogSwap:
    def test_price_year_changes_profit_not_dynamics(self):
        env = ChargaxEnv(EnvConfig())
        e = 4
        ex21 = default_exog(year=2021, traffic="high")
        ex22 = default_exog(year=2022, traffic="high")
        step = jax.jit(env.step)
        # identical keys -> identical physical trajectories
        s21, _ = env.reset(keys(e, base=9), ex21)
        s22, _ = env.reset(keys(e, base=9), ex22)
        rng = np.random.default_rng(0)
        p21 = p22 = 0.0
        for _ in range(100):
            a = jnp.asarray(
                rng.integers(0, np.asarray(env.action_nvec)[None, :].repeat(e, 0)),
                dtype=jnp.int32,
            )
            s21, _, _, _, m21 = step(s21, a, ex21)
            s22, _, _, _, m22 = step(s22, a, ex22)
            # same arrivals & same energy delivered...
            np.testing.assert_allclose(
                np.asarray(m21[:, 9]), np.asarray(m22[:, 9]), atol=1e-5
            )
            np.testing.assert_allclose(
                np.asarray(m21[:, 2]), np.asarray(m22[:, 2]), atol=1e-3
            )
            p21 += float(m21[:, 1].sum())
            p22 += float(m22[:, 1].sum())
        # ...but crisis-year prices depress profit
        assert p22 < p21

    def test_traffic_multiplier_scales_arrivals(self):
        env = ChargaxEnv(EnvConfig())
        e = 8
        lo = default_exog(traffic="low")
        hi = default_exog(traffic="high")
        step = jax.jit(env.step)
        tot = {}
        for name, ex in [("low", lo), ("high", hi)]:
            state, _ = env.reset(keys(e, base=60), ex)
            acc = 0.0
            a = jnp.zeros((e, env.n_ports), jnp.int32)
            for _ in range(288):
                state, _, _, _, met = step(state, a, ex)
                # demand = accepted + rejected (idle chargers saturate the
                # station, so accepted arrivals alone are capacity-capped)
                acc += float(met[:, metric_index("arrived")].sum())
                acc += float(met[:, metric_index("rejected")].sum())
            tot[name] = acc
        assert tot["high"] > 2.0 * tot["low"], tot
