"""Data stack: determinism, realism constraints, export integrity."""

import json

import numpy as np
import pytest

from compile import data


class TestPrices:
    def test_deterministic(self):
        a = data.price_table("NL", 2021)
        b = data.price_table("NL", 2021)
        np.testing.assert_array_equal(a, b)

    def test_shape_and_units(self):
        t = data.price_table("DE", 2023, n_days=100)
        assert t.shape == (100, 24)
        # EUR/kWh: typical European day-ahead range.
        assert 0.02 < float(np.median(t)) < 0.5

    @pytest.mark.parametrize("country", data.PRICE_COUNTRIES)
    def test_2022_surge(self, country):
        """The EU energy crisis must be visible (drives paper Fig. 5)."""
        p21 = float(data.price_table(country, 2021).mean())
        p22 = float(data.price_table(country, 2022).mean())
        p23 = float(data.price_table(country, 2023).mean())
        assert p22 > 1.8 * p21
        assert p22 > 1.8 * p23

    def test_2022_more_volatile(self):
        v21 = float(data.price_table("NL", 2021).std())
        v22 = float(data.price_table("NL", 2022).std())
        assert v22 > 2.0 * v21

    def test_evening_peak_exceeds_midday(self):
        t = data.price_table("NL", 2021)
        assert float(t[:, 18].mean()) > float(t[:, 13].mean())

    def test_countries_differ(self):
        assert not np.allclose(
            data.price_table("NL", 2021), data.price_table("FR", 2021)
        )


class TestCars:
    def test_catalog_sane(self):
        assert len(data.CAR_CATALOG) == 20
        for m in data.CAR_CATALOG:
            assert 10 < m["cap"] < 150
            assert 3 <= m["ac"] <= 25
            assert 20 <= m["dc"] <= 300
            assert 0.4 <= m["tau"] <= 0.8

    @pytest.mark.parametrize("region", data.CAR_REGIONS)
    def test_weights_normalized(self, region):
        w = data.car_table(region)["weights"]
        assert np.isclose(w.sum(), 1.0)
        assert (w >= 0).all()

    def test_us_skews_to_larger_packs(self):
        caps = data.car_table("EU")["table"][:, 0]
        eu = float((data.car_table("EU")["weights"] * caps).sum())
        us = float((data.car_table("US")["weights"] * caps).sum())
        assert us > eu + 5.0  # kWh


class TestArrivals:
    @pytest.mark.parametrize("scenario", data.SCENARIOS)
    def test_shapes(self, scenario):
        r = data.arrival_rate(scenario)
        assert r.shape == (24,)
        assert (r >= 0).all()

    def test_scenario_signatures(self):
        work = data.arrival_rate("work")
        assert work[7:9].mean() > 4 * work[14:20].mean()  # morning rush
        resi = data.arrival_rate("residential")
        assert resi[17:20].mean() > 3 * resi[8:12].mean()  # evening peak
        shop = data.arrival_rate("shopping")
        assert shop[11:16].mean() > 5 * shop[0:5].mean()  # daytime


class TestUserProfiles:
    @pytest.mark.parametrize("scenario", data.SCENARIOS)
    def test_vector_layout(self, scenario):
        v = data.user_profile_vec(scenario)
        assert v.shape == (6,)
        stay_mean, stay_std, a, b, target, p_time = v
        assert 0.2 <= stay_mean <= 12
        assert 0 < stay_std < stay_mean
        assert 0 < p_time < 1
        assert 0.5 <= target <= 1.0

    def test_highway_short_residential_long(self):
        assert (
            data.USER_PROFILES["highway"]["stay_mean_h"]
            < data.USER_PROFILES["shopping"]["stay_mean_h"]
            < data.USER_PROFILES["residential"]["stay_mean_h"]
        )


class TestExport:
    def test_export_roundtrip(self, tmp_path):
        data.export_all(str(tmp_path), n_days=30)
        for f in ["prices.json", "moer.json", "cars.json", "arrivals.json",
                  "user_profiles.json"]:
            with open(tmp_path / f) as fh:
                j = json.load(fh)
            assert j
        with open(tmp_path / "prices.json") as fh:
            p = json.load(fh)
        assert len(p["tables"]) == 9
        arr = np.asarray(p["tables"]["NL_2021"], np.float32)
        np.testing.assert_allclose(arr, data.price_table("NL", 2021, 30), atol=1e-6)
