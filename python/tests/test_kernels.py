"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes and value ranges; every property asserts
allclose against ref.py — the core correctness signal of the L1 layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import charge, constraint, ref
from compile.kernels.gae import gae as gae_fn

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rng_arrays(seed, *specs):
    r = np.random.default_rng(seed)
    out = []
    for lo, hi, shape in specs:
        out.append(r.uniform(lo, hi, shape).astype(np.float32))
    return out


@st.composite
def tree_case(draw):
    """Hierarchical depth-2 trees (paper Fig. 3: root + disjoint splitters).

    The two-pass projection is exact for this family; arbitrary overlapping
    node sets are out of scope (the builders in env/tree.py only produce
    hierarchical trees).
    """
    e = draw(st.integers(1, 40))
    p = draw(st.integers(2, 24))
    n_children = draw(st.integers(0, 4))
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    i = r.normal(0, 150, (e, p)).astype(np.float32)
    volt = r.uniform(100, 500, p).astype(np.float32)
    rows = [np.ones(p, np.float32)]  # root
    if n_children > 0:
        assignment = r.integers(0, n_children + 1, p)  # 0 = direct to root
        for child in range(1, n_children + 1):
            row = (assignment == child).astype(np.float32)
            if row.sum() > 0:
                rows.append(row)
    mem = np.stack(rows)
    n = mem.shape[0]
    lim = r.uniform(5, 500, n).astype(np.float32)
    eta = r.uniform(0.8, 1.0, n).astype(np.float32)
    return i, volt, mem, lim, eta


class TestConstraintProjection:
    @given(tree_case())
    def test_matches_ref(self, case):
        i, volt, mem, lim, eta = case
        si, ex = constraint.constraint_projection(
            jnp.asarray(i), jnp.asarray(volt), jnp.asarray(mem),
            jnp.asarray(lim), jnp.asarray(eta),
        )
        ri, rx = jax.vmap(
            lambda a: ref.constraint_projection_ref(a, volt, mem, lim, eta)
        )(jnp.asarray(i))
        np.testing.assert_allclose(si, ri, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(ex, rx, atol=1e-4, rtol=1e-4)

    @given(tree_case())
    def test_projected_flows_satisfy_constraints(self, case):
        i, volt, mem, lim, eta = case
        si, _ = constraint.constraint_projection(
            jnp.asarray(i), jnp.asarray(volt), jnp.asarray(mem),
            jnp.asarray(lim), jnp.asarray(eta),
        )
        p_kw = np.asarray(si) * volt[None, :] / 1000.0
        flows = p_kw @ mem.T  # [E, N]
        load = np.abs(flows) / eta[None, :]
        assert (load <= lim[None, :] * (1 + 1e-3) + 1e-3).all()

    @given(tree_case())
    def test_projection_shrinks_never_flips(self, case):
        i, volt, mem, lim, eta = case
        si, _ = constraint.constraint_projection(
            jnp.asarray(i), jnp.asarray(volt), jnp.asarray(mem),
            jnp.asarray(lim), jnp.asarray(eta),
        )
        si = np.asarray(si)
        assert (np.sign(si) == np.sign(i)).all() or (
            np.abs(si[np.sign(si) != np.sign(i)]) < 1e-6
        ).all()
        assert (np.abs(si) <= np.abs(i) + 1e-5).all()

    def test_zero_current_noop(self):
        e, p, n = 3, 5, 2
        mem = np.ones((n, p), np.float32)
        si, ex = constraint.constraint_projection(
            jnp.zeros((e, p)), jnp.full((p,), 400.0), jnp.asarray(mem),
            jnp.full((n,), 100.0), jnp.full((n,), 0.98),
        )
        assert np.allclose(si, 0.0)
        assert np.allclose(ex, 0.0)


class TestChargeUpdate:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 32), st.integers(2, 20))
    def test_matches_ref(self, seed, e, p):
        r = np.random.default_rng(seed)
        i = r.normal(0, 120, (e, p)).astype(np.float32)
        volt = r.uniform(100, 500, p).astype(np.float32)
        pres = (r.random((e, p)) < 0.7).astype(np.float32)
        soc = r.random((e, p)).astype(np.float32)
        de = r.uniform(0, 60, (e, p)).astype(np.float32)
        dtr = r.uniform(0, 40, (e, p)).astype(np.float32)
        cap = r.uniform(10, 120, (e, p)).astype(np.float32)
        rbar = r.uniform(3, 200, (e, p)).astype(np.float32)
        tau = r.uniform(0.3, 0.9, (e, p)).astype(np.float32)
        outs = charge.charge_update(
            jnp.asarray(i), jnp.asarray(volt), pres, soc, de, dtr, cap, rbar,
            tau, 1.0 / 12.0,
        )
        refs = ref.charge_update_ref(
            i, volt[None, :], pres, soc, de, dtr, cap, rbar, tau, 1.0 / 12.0
        )
        for o, rr, name in zip(outs, refs, ["soc", "de", "dt", "rhat", "e"]):
            np.testing.assert_allclose(o, rr, atol=1e-4, rtol=1e-4, err_msg=name)

    @given(st.integers(0, 2**31 - 1))
    def test_soc_stays_in_unit_interval(self, seed):
        r = np.random.default_rng(seed)
        e, p = 8, 17
        outs = charge.charge_update(
            jnp.asarray(r.normal(0, 500, (e, p)).astype(np.float32)),  # huge currents
            jnp.full((p,), 400.0, np.float32),
            jnp.ones((e, p), jnp.float32),
            jnp.asarray(r.random((e, p)).astype(np.float32)),
            jnp.zeros((e, p)), jnp.zeros((e, p)),
            jnp.asarray(r.uniform(10, 100, (e, p)).astype(np.float32)),
            jnp.full((e, p), 150.0), jnp.full((e, p), 0.6),
            1.0 / 12.0,
        )
        soc = np.asarray(outs[0])
        assert (soc >= 0.0).all() and (soc <= 1.0).all()

    def test_energy_conservation(self):
        """Port energy == cap * delta_soc when no clipping binds."""
        e, p = 4, 6
        i = jnp.full((e, p), 50.0)
        volt = jnp.full((p,), 400.0)
        soc = jnp.full((e, p), 0.3)
        cap = jnp.full((e, p), 80.0)
        outs = charge.charge_update(
            i, volt, jnp.ones((e, p)), soc, jnp.full((e, p), 50.0),
            jnp.full((e, p), 20.0), cap, jnp.full((e, p), 150.0),
            jnp.full((e, p), 0.8), 1.0 / 12.0,
        )
        soc_n, _, _, _, e_port = [np.asarray(o) for o in outs]
        np.testing.assert_allclose(
            (soc_n - 0.3) * 80.0, e_port, atol=1e-4
        )

    def test_absent_port_untouched(self):
        e, p = 2, 3
        outs = charge.charge_update(
            jnp.full((e, p), 100.0), jnp.full((p,), 400.0),
            jnp.zeros((e, p)),  # nothing present
            jnp.full((e, p), 0.5), jnp.full((e, p), 10.0),
            jnp.full((e, p), 5.0), jnp.full((e, p), 60.0),
            jnp.full((e, p), 100.0), jnp.full((e, p), 0.6), 1.0 / 12.0,
        )
        soc_n, de_n, dt_n, rhat_n, e_port = [np.asarray(o) for o in outs]
        assert np.allclose(soc_n, 0.5)
        assert np.allclose(de_n, 10.0)
        assert np.allclose(dt_n, 5.0)  # presence-gated decrement
        assert np.allclose(e_port, 0.0)
        assert np.allclose(rhat_n, 0.0)


class TestGae:
    @given(
        st.integers(0, 2**31 - 1),
        st.integers(1, 64),
        st.integers(1, 12),
        st.floats(0.5, 0.999),
        st.floats(0.5, 1.0),
    )
    def test_matches_ref(self, seed, t, e, gamma, lam):
        r = np.random.default_rng(seed)
        rew = r.normal(0, 1, (t, e)).astype(np.float32)
        val = r.normal(0, 1, (t, e)).astype(np.float32)
        done = (r.random((t, e)) < 0.15).astype(np.float32)
        lv = r.normal(0, 1, e).astype(np.float32)
        a1, t1 = gae_fn(rew, val, done, lv, gamma, lam)
        a2, t2 = ref.gae_ref(rew, val, done, lv, gamma, lam)
        np.testing.assert_allclose(a1, a2, atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(t1, t2, atol=2e-4, rtol=1e-3)

    def test_terminal_cuts_bootstrap(self):
        rew = jnp.asarray([[1.0], [1.0]])
        val = jnp.asarray([[0.0], [5.0]])
        done = jnp.asarray([[1.0], [0.0]])
        lv = jnp.asarray([100.0])
        adv, _ = gae_fn(rew, val, done, lv, 0.99, 0.95)
        # t=0 terminal: advantage = r - v = 1.0, ignoring the future.
        np.testing.assert_allclose(np.asarray(adv)[0, 0], 1.0, atol=1e-5)

    def test_gamma_zero_is_td_residual(self):
        r = np.random.default_rng(1)
        rew = r.normal(0, 1, (5, 2)).astype(np.float32)
        val = r.normal(0, 1, (5, 2)).astype(np.float32)
        adv, _ = gae_fn(
            rew, val, np.zeros((5, 2), np.float32),
            np.zeros(2, np.float32), 0.0, 0.95,
        )
        np.testing.assert_allclose(adv, rew - val, atol=1e-5)


class TestCurves:
    @given(st.floats(0, 1), st.floats(1, 300), st.floats(0.05, 0.95))
    def test_charging_curve_bounds(self, soc, rbar, tau):
        v = float(ref.charging_curve(soc, rbar, tau))
        assert 0.0 <= v <= rbar + 1e-5

    @given(st.floats(0, 1), st.floats(1, 300), st.floats(0.05, 0.95))
    def test_discharge_is_flipped_charge(self, soc, rbar, tau):
        a = float(ref.discharging_curve(soc, rbar, tau))
        b = float(ref.charging_curve(1.0 - soc, rbar, tau))
        assert abs(a - b) < 1e-5

    def test_zero_at_full(self):
        assert float(ref.charging_curve(1.0, 150.0, 0.6)) == 0.0
        assert float(ref.discharging_curve(0.0, 150.0, 0.6)) == 0.0


class TestRefFallbackAgreement:
    """CHARGAX_NO_PALLAS routes through ref; both paths must agree (they're
    exercised above individually; this is the wiring check)."""

    def test_kernel_init_exports(self):
        import compile.kernels as K

        assert callable(K.constraint_projection)
        assert callable(K.charge_update)
        assert callable(K.gae)
