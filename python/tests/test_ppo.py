"""PPO stack: network shapes/math, train_iter learning signal, eval
policies, and the numpy comparator's agreement on the loss family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import networks, ppo
from compile.config import EnvConfig, PpoConfig
from compile.env import ChargaxEnv
from compile.exog import default_exog


@pytest.fixture(scope="module")
def small():
    env = ChargaxEnv(EnvConfig())
    cfg = PpoConfig(num_envs=4, rollout_steps=16, n_minibatches=2, update_epochs=2)
    exog = default_exog(traffic="high")
    return env, cfg, exog


class TestNetworks:
    def test_param_shapes_and_count(self):
        nvec = [11] * 3 + [21]
        params = networks.init_params(jax.random.PRNGKey(0), 10, 32, nvec)
        assert params["wpi"].shape == (32, 54)
        n = networks.n_params(params)
        assert n == 10 * 32 + 32 + 32 * 32 + 32 + 32 * 54 + 54 + 32 + 1

    def test_apply_shapes(self):
        nvec = [5, 7]
        params = networks.init_params(jax.random.PRNGKey(1), 6, 16, nvec)
        logits, value = networks.apply(params, jnp.ones((4, 6)))
        assert logits.shape == (4, 12)
        assert value.shape == (4,)

    def test_sample_within_bounds(self):
        nvec = [3, 5, 2]
        params = networks.init_params(jax.random.PRNGKey(2), 4, 8, nvec)
        logits, _ = networks.apply(params, jnp.zeros((100, 4)))
        a = networks.sample_actions(jax.random.PRNGKey(3), logits, nvec)
        assert a.shape == (100, 3)
        for h, n in enumerate(nvec):
            assert int(a[:, h].max()) < n
            assert int(a[:, h].min()) >= 0

    def test_logprob_normalized(self):
        """Sum of exp(logp) over all joint actions == 1 for tiny heads."""
        nvec = [2, 3]
        logits = jnp.asarray([[0.3, -0.2, 1.0, 0.1, -0.5]])
        total = 0.0
        for a0 in range(2):
            for a1 in range(3):
                lp, _ = networks.log_prob_entropy(
                    logits, jnp.asarray([[a0, a1]]), nvec
                )
                total += float(jnp.exp(lp[0]))
        assert abs(total - 1.0) < 1e-5

    def test_entropy_max_at_uniform(self):
        nvec = [4]
        lp_uniform, ent_u = networks.log_prob_entropy(
            jnp.zeros((1, 4)), jnp.zeros((1, 1), jnp.int32), nvec
        )
        _, ent_peaked = networks.log_prob_entropy(
            jnp.asarray([[10.0, 0.0, 0.0, 0.0]]), jnp.zeros((1, 1), jnp.int32), nvec
        )
        assert float(ent_u[0]) > float(ent_peaked[0])
        assert abs(float(ent_u[0]) - np.log(4)) < 1e-5

    def test_greedy_is_argmax(self):
        nvec = [3, 2]
        logits = jnp.asarray([[0.0, 2.0, -1.0, 5.0, 1.0]])
        a = networks.greedy_actions(logits, nvec)
        assert a.tolist() == [[1, 0]]


class TestAdam:
    def test_adam_moves_toward_minimum(self):
        params = {"w": jnp.asarray([5.0])}
        opt = ppo.adam_init(params)
        for _ in range(500):
            grads = {"w": 2.0 * params["w"]}  # d/dw of w^2
            params, opt = ppo.adam_update(grads, opt, params, lr=0.05)
        assert abs(float(params["w"][0])) < 0.1

    def test_clip_global_norm(self):
        g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        clipped = ppo.clip_global_norm(g, 1.0)
        norm = float(
            jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(clipped)))
        )
        assert abs(norm - 1.0) < 1e-5
        # below threshold: untouched
        same = ppo.clip_global_norm(g, 100.0)
        assert float(same["a"][0]) == 3.0


class TestTrainIter:
    def test_metrics_and_carry_structure(self, small):
        env, cfg, exog = small
        init = jax.jit(ppo.make_train_init(env, cfg, exog))
        carry = init(jnp.asarray(0, jnp.uint32))
        it = jax.jit(ppo.make_train_iter(env, cfg, total_updates=10))
        carry2, met = it(carry, exog)
        assert met.shape == (len(ppo.TRAIN_METRIC_FIELDS),)
        assert bool(jnp.isfinite(met).all())
        assert int(carry2.update_i) == 1
        # params changed
        assert not np.allclose(carry.params["w1"], carry2.params["w1"])

    def test_lr_anneals(self, small):
        env, cfg, exog = small
        init = jax.jit(ppo.make_train_init(env, cfg, exog))
        it = jax.jit(ppo.make_train_iter(env, cfg, total_updates=4))
        carry = init(jnp.asarray(1, jnp.uint32))
        lrs = []
        for _ in range(3):
            carry, met = it(carry, exog)
            lrs.append(float(dict(zip(ppo.TRAIN_METRIC_FIELDS, np.asarray(met)))["lr"]))
        assert lrs[0] > lrs[1] > lrs[2]

    def test_deterministic_given_seed(self, small):
        env, cfg, exog = small
        init = jax.jit(ppo.make_train_init(env, cfg, exog))
        it = jax.jit(ppo.make_train_iter(env, cfg, total_updates=10))
        c1, m1 = it(init(jnp.asarray(7, jnp.uint32)), exog)
        c2, m2 = it(init(jnp.asarray(7, jnp.uint32)), exog)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        c3, m3 = it(init(jnp.asarray(8, jnp.uint32)), exog)
        assert not np.allclose(np.asarray(m1), np.asarray(m3))

    @pytest.mark.filterwarnings("ignore")
    def test_reward_improves_with_training(self):
        """Short training must beat the untrained policy (learning signal)."""
        env = ChargaxEnv(EnvConfig())
        cfg = PpoConfig(num_envs=8, rollout_steps=128, n_minibatches=4)
        exog = default_exog(traffic="high")
        init = jax.jit(ppo.make_train_init(env, cfg, exog))
        it = jax.jit(ppo.make_train_iter(env, cfg, total_updates=40))
        carry = init(jnp.asarray(3, jnp.uint32))
        first = None
        for i in range(40):
            carry, met = it(carry, exog)
            m = dict(zip(ppo.TRAIN_METRIC_FIELDS, np.asarray(met)))
            if first is None:
                first = m["mean_reward"]
        assert m["mean_reward"] > first + 0.2, (first, m["mean_reward"])


class TestEvalRollout:
    def test_eval_shapes_and_policies_differ(self, small):
        env, cfg, exog = small
        params = networks.init_params(
            jax.random.PRNGKey(0), env.obs_dim, cfg.hidden,
            tuple(int(x) for x in env.action_nvec),
        )
        outs = {}
        for policy in ["net", "max", "random"]:
            ev = jax.jit(ppo.make_eval_rollout(env, cfg, policy))
            v = ev(params, jnp.asarray(0, jnp.uint32), exog)
            assert v.shape == (len(ppo.EVAL_METRIC_FIELDS),)
            assert bool(jnp.isfinite(v).all())
            outs[policy] = np.asarray(v)
        assert not np.allclose(outs["max"], outs["random"])

    def test_max_policy_charges_more_than_random(self, small):
        env, cfg, exog = small
        params = networks.init_params(
            jax.random.PRNGKey(0), env.obs_dim, cfg.hidden,
            tuple(int(x) for x in env.action_nvec),
        )
        i_energy = ppo.EVAL_METRIC_FIELDS.index("ep_energy_kwh")
        e_max = float(
            jax.jit(ppo.make_eval_rollout(env, cfg, "max"))(
                params, jnp.asarray(1, jnp.uint32), exog
            )[i_energy]
        )
        e_rand = float(
            jax.jit(ppo.make_eval_rollout(env, cfg, "random"))(
                params, jnp.asarray(1, jnp.uint32), exog
            )[i_energy]
        )
        assert e_max > e_rand

    def test_random_rollout_program(self, small):
        env, cfg, exog = small
        rr = jax.jit(ppo.make_random_rollout(env, num_envs=4, n_steps=32))
        mets, steps = rr(jnp.asarray(0, jnp.uint32), exog)
        assert int(steps) == 128
        assert bool(jnp.isfinite(mets).all())
