"""AOT contract: flat-leaf signatures, manifest specs, HLO lowering.

These tests build a *quick* (tiny-rollout) bundle and verify the manifest
promises match what the programs actually consume/produce — the contract
the Rust coordinator trusts blindly.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import EnvConfig, PpoConfig
from compile.env.state import ExogData
from compile.model import ModelBundle, leaf_spec


@pytest.fixture(scope="module")
def bundle():
    return ModelBundle(
        EnvConfig(), PpoConfig(num_envs=2, rollout_steps=8, n_minibatches=2)
    )


class TestLeafSpecs:
    def test_dtype_mapping(self):
        assert leaf_spec("x", np.zeros((2, 3), np.float32))["dtype"] == "f32"
        assert leaf_spec("x", np.zeros((), np.int32))["dtype"] == "i32"
        assert leaf_spec("x", np.zeros(4, np.uint32))["dtype"] == "u32"

    def test_exog_leaf_order_matches_namedtuple(self, bundle):
        assert bundle.exog_names == list(ExogData._fields)

    def test_carry_names_are_dotted(self, bundle):
        assert "params.w1" in bundle.carry_names
        assert any(n.startswith("env_state.") for n in bundle.carry_names)
        assert "key" in bundle.carry_names


class TestProgramSignatures:
    def test_train_init_matches_train_iter_carry(self, bundle):
        pi = bundle.program_train_init()
        pt = bundle.program_train_iter()
        assert pi.output_names == pt.input_names[: len(pi.output_names)]
        # iter outputs = same carry + metrics
        assert pt.output_names[:-1] == pi.output_names
        assert pt.output_names[-1] == "metrics"

    def test_eval_param_leaves_prefix(self, bundle):
        pe = bundle.program_eval("max")
        n_par = len(bundle.param_example)
        assert all(n.startswith("params.") for n in pe.input_names[:n_par])
        assert pe.input_names[n_par] == "seed"

    def test_shapes_execute(self, bundle):
        """Every program's fn runs on its example inputs (jit, no lowering)."""
        progs = [
            bundle.program_train_init(),
            bundle.program_eval("max"),
            bundle.program_random_rollout(8),
            bundle.program_env_reset(),
            bundle.program_env_step(),
        ]
        for p in progs:
            outs = jax.jit(p.fn)(*p.example_inputs)
            leaves = jax.tree_util.tree_leaves(outs)
            assert len(leaves) == len(p.output_names), p.name

    def test_output_specs_consistent(self, bundle):
        from compile.aot import _output_specs

        p = bundle.program_env_reset()
        specs = _output_specs(p)
        assert [s["name"] for s in specs] == p.output_names


class TestLowering:
    def test_train_iter_lowers_to_parseable_hlo(self, bundle):
        text = bundle.program_train_iter().lower_hlo_text()
        assert text.startswith("HloModule")
        assert "while" in text  # the rollout scan
        # The killer for xla_extension 0.5.1 is typed-FFI custom calls —
        # ensure none leak into the export (qr/erf_inv/lu would add them).
        assert "api_version=API_VERSION_TYPED_FFI" not in text

    def test_eval_lowering_no_ffi(self, bundle):
        text = bundle.program_eval("net").lower_hlo_text()
        assert "api_version=API_VERSION_TYPED_FFI" not in text

    def test_env_step_roundtrip_values(self, bundle):
        """Lowered env_step evaluated via jax equals direct env.step."""
        p_reset = bundle.program_env_reset()
        p_step = bundle.program_env_step()
        reset_out = jax.jit(p_reset.fn)(*p_reset.example_inputs)
        state_leaves = reset_out[:-1]
        action = jnp.ones((2, bundle.env.n_ports), jnp.int32)
        step_in = tuple(state_leaves) + (action,) + tuple(bundle.exog_leaves)
        out1 = jax.jit(p_step.fn)(*step_in)
        out2 = jax.jit(p_step.fn)(*step_in)
        for a, b in zip(jax.tree_util.tree_leaves(out1), jax.tree_util.tree_leaves(out2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestManifestOnDisk:
    """If `make artifacts` has run, the shipped manifest must be coherent."""

    @pytest.fixture(scope="class")
    def manifest(self):
        import os

        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_variants_present(self, manifest):
        assert "mix10dc6ac_e12" in manifest["variants"]

    def test_program_files_exist(self, manifest):
        import os

        base = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for v in manifest["variants"].values():
            for prog in v["programs"].values():
                assert os.path.exists(os.path.join(base, prog["file"])), prog["file"]

    def test_train_iter_io_contract(self, manifest):
        v = manifest["variants"]["mix10dc6ac_e12"]
        ti = v["programs"]["train_iter"]
        in_names = [i["name"] for i in ti["inputs"]]
        out_names = [o["name"] for o in ti["outputs"]]
        assert out_names[:-1] == in_names[: len(out_names) - 1]
        assert out_names[-1] == "metrics"
        assert any(n.startswith("params.") for n in out_names)
        n_exog = v["meta"]["n_exog_leaves"]
        assert in_names[-n_exog:] == list(ExogData._fields)
