#!/usr/bin/env python3
"""Render the CSVs under runs/ into paper-style figures (PNG).

Usage: python scripts/plot_runs.py [runs_dir] [out_dir]

Produces (when the corresponding CSV exists):
  fig1_table2.png        — Table 2 / Fig. 1 bar chart (log-scale seconds)
  fig4a_training.png     — reward curves per traffic level (paper Fig. 4a)
  fig4bc_satisfaction.png— alpha sweeps (paper Fig. 4b/c)
  fig5_shift.png         — train-year x eval-year matrix (paper Fig. 5)
  fig6to11_scenarios.png — scenario/region/mix bars (paper Fig. 6-11)
  train_shopping.png     — E2E loss/reward curve (examples/train_shopping)
  telemetry_stages.png   — per-iteration stage time breakdown + pool
                           utilization (runs/telemetry.jsonl, `--telemetry`)
  telemetry_grid.png     — feeder delivery vs curtailment per iteration
                           (grid-coupled runs only; README §Grid coupling)
"""

import csv
import json
import os
import sys
from collections import defaultdict

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt


def read_csv(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def maybe(path):
    return os.path.exists(path)


def plot_table2(runs, out):
    rows = read_csv(os.path.join(runs, "table2.csv"))
    labels = [r["row"] for r in rows]
    series = [
        ("Chargax (AOT)", "chargax_s"),
        ("scalar-gym (rust)", "scalar_gym_s"),
        ("python-gym", "python_gym_s"),
    ]
    fig, ax = plt.subplots(figsize=(7, 4))
    width = 0.25
    for i, (name, key) in enumerate(series):
        xs = [j + (i - 1) * width for j in range(len(rows))]
        ys = [float(r[key]) if r[key] else float("nan") for r in rows]
        ax.bar(xs, ys, width, label=name)
    ax.set_xticks(range(len(rows)))
    ax.set_xticklabels(labels)
    ax.set_yscale("log")
    ax.set_ylabel("seconds / 100k env steps (log)")
    ax.set_title("Table 2 / Fig. 1 — wallclock per 100k steps")
    ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig1_table2.png"), dpi=130)


def plot_fig4a(runs, out):
    rows = read_csv(os.path.join(runs, "fig4a.csv"))
    by = defaultdict(lambda: defaultdict(list))  # traffic -> iter -> returns
    for r in rows:
        val = float(r["mean_completed_return"])
        if val == val and val != 0.0:
            by[r["traffic"]][int(r["iter"])].append(val)
    fig, ax = plt.subplots(figsize=(7, 4))
    for traffic, pts in by.items():
        its = sorted(pts)
        mean = [sum(pts[i]) / len(pts[i]) for i in its]
        ax.plot(its, mean, label=f"traffic={traffic}")
    ax.set_xlabel("PPO iteration (3600 env steps each)")
    ax.set_ylabel("mean completed-episode return")
    ax.set_title("Fig. 4a — PPO training, shopping scenario")
    ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig4a_training.png"), dpi=130)


def plot_fig4bc(runs, out):
    rows = read_csv(os.path.join(runs, "fig4bc.csv"))
    fig, axes = plt.subplots(1, 2, figsize=(10, 4))
    for ax, panel, field, ylabel in [
        (axes[0], "4b", "ep_missing_kwh", "kWh missing at departure"),
        (axes[1], "4c", "ep_overtime_steps", "overtime (steps)"),
    ]:
        by = defaultdict(list)
        for r in rows:
            if r["panel"] == panel:
                by[float(r["alpha"])].append(float(r[field]))
        alphas = sorted(by)
        means = [sum(by[a]) / len(by[a]) for a in alphas]
        ax.bar(range(len(alphas)), means, 0.6)
        ax.set_xticks(range(len(alphas)))
        ax.set_xticklabels([str(a) for a in alphas])
        ax.set_xlabel("alpha")
        ax.set_ylabel(ylabel)
        ax.set_title(f"Fig. {panel}")
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig4bc_satisfaction.png"), dpi=130)


def plot_fig5(runs, out):
    rows = read_csv(os.path.join(runs, "fig5.csv"))
    years = sorted({r["train_year"] for r in rows})
    mat = [[0.0] * len(years) for _ in years]
    for r in rows:
        i = years.index(r["train_year"])
        j = years.index(r["eval_year"])
        mat[i][j] = float(r["mean_reward"])
    fig, ax = plt.subplots(figsize=(5, 4))
    im = ax.imshow(mat, cmap="viridis")
    ax.set_xticks(range(len(years)), years)
    ax.set_yticks(range(len(years)), years)
    ax.set_xlabel("evaluation year")
    ax.set_ylabel("training year")
    for i in range(len(years)):
        for j in range(len(years)):
            ax.text(j, i, f"{mat[i][j]:.0f}", ha="center", va="center", color="w")
    ax.set_title("Fig. 5 — price-year distribution shift")
    fig.colorbar(im, label="mean episode reward")
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig5_shift.png"), dpi=130)


def plot_scenarios(runs, out):
    paths = [p for p in ["fig6to8.csv", "fig9to11.csv"] if maybe(os.path.join(runs, p))]
    if not paths:
        return
    fig, axes = plt.subplots(1, len(paths), figsize=(6 * len(paths), 4))
    if len(paths) == 1:
        axes = [axes]
    for ax, p in zip(axes, paths):
        rows = read_csv(os.path.join(runs, p))
        groups = sorted({(r["variant"], r["region"]) for r in rows})
        scenarios = ["shopping", "work", "residential", "highway"]
        width = 0.8 / len(groups)
        for gi, (v, reg) in enumerate(groups):
            ys = []
            for s in scenarios:
                match = [r for r in rows if r["variant"] == v and r["region"] == reg and r["scenario"] == s]
                ys.append(float(match[0]["ppo_profit"]) if match else 0.0)
            xs = [i + gi * width for i in range(len(scenarios))]
            label = reg if p == "fig6to8.csv" else v.split("_")[0]
            ax.bar(xs, ys, width, label=label)
        ax.set_xticks(range(len(scenarios)))
        ax.set_xticklabels(scenarios)
        ax.set_ylabel("PPO profit / episode")
        ax.set_title("Fig. 6-8 (regions)" if p == "fig6to8.csv" else "Fig. 9-11 (charger mixes)")
        ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig6to11_scenarios.png"), dpi=130)


def plot_e2e(runs, out):
    rows = read_csv(os.path.join(runs, "train_shopping.csv"))
    fig, ax1 = plt.subplots(figsize=(7, 4))
    xs = [int(r["env_steps"]) for r in rows]
    ax1.plot(xs, [float(r["mean_reward"]) for r in rows], "C0", label="mean reward/step")
    ax1.set_xlabel("environment steps")
    ax1.set_ylabel("mean reward / step", color="C0")
    ax2 = ax1.twinx()
    ax2.plot(xs, [float(r["total_loss"]) for r in rows], "C1", alpha=0.6, label="PPO loss")
    ax2.set_ylabel("total loss", color="C1")
    ax1.set_title("E2E training run (examples/train_shopping)")
    fig.tight_layout()
    fig.savefig(os.path.join(out, "train_shopping.png"), dpi=130)


STAGE_ORDER = [
    "rollout", "policy-forward", "env-step", "grid-reduce",
    "update-chunks", "reduce", "adam", "eval",
]


def read_telemetry(path):
    """One dict per JSONL record of type 'telemetry' (skips blank lines
    and any foreign records sharing the sink)."""
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("type") == "telemetry":
                recs.append(rec)
    return recs


def plot_telemetry(runs, out):
    recs = read_telemetry(os.path.join(runs, "telemetry.jsonl"))
    if not recs:
        print("skip: telemetry.jsonl has no telemetry records")
        return
    its = [int(r["iter"]) for r in recs]
    fig, (ax1, ax2) = plt.subplots(
        2, 1, figsize=(8, 6), sharex=True,
        gridspec_kw={"height_ratios": [3, 1]},
    )
    # Stacked per-stage total time per iteration: where each iteration's
    # wallclock actually went. Note policy-forward/env-step run INSIDE the
    # rollout envelope (and update-chunks inside pool dispatches), so the
    # stack shows instrumented work, not disjoint wallclock.
    bottom = [0.0] * len(recs)
    for si, stage in enumerate(STAGE_ORDER):
        ys = [float(r["stages"].get(stage, {}).get("total_ms", 0.0)) for r in recs]
        if not any(ys):
            continue
        ax1.bar(its, ys, 0.8, bottom=bottom, label=stage, color=f"C{si}")
        bottom = [b + y for b, y in zip(bottom, ys)]
    ax1.plot(its, [float(r["wall_ms"]) for r in recs], "k--", lw=1,
             label="iteration wallclock")
    ax1.set_ylabel("stage time (ms, summed over shards)")
    ax1.set_title("Telemetry — per-iteration stage time breakdown")
    ax1.legend(fontsize=8, ncol=2)
    # Pool utilization + shard imbalance under the same x axis.
    util = [float(r["shards"]["utilization"]) for r in recs]
    imb = [float(r["shards"]["imbalance_mean"]) for r in recs]
    ax2.plot(its, util, "C0", label="pool utilization")
    ax2.set_ylim(0, 1.05)
    ax2.set_ylabel("utilization", color="C0")
    ax3 = ax2.twinx()
    ax3.plot(its, imb, "C3", alpha=0.7, label="imbalance (mean max/min)")
    ax3.set_ylabel("imbalance ratio", color="C3")
    ax2.set_xlabel("iteration")
    fig.tight_layout()
    fig.savefig(os.path.join(out, "telemetry_stages.png"), dpi=130)
    plot_grid_coupling(recs, out)


def plot_grid_coupling(recs, out):
    """Feeder panel for grid-coupled runs: per-iteration curtailed energy
    next to the energy actually delivered from the grid, plus the curtailed
    fraction (how often the shared feeder was binding). Skipped entirely for
    uncoupled runs, where curtailed_kwh is 0 and no grid-reduce spans exist."""
    curt = [float(r.get("counters", {}).get("curtailed_kwh", 0.0)) for r in recs]
    if not any(curt):
        print("skip: no curtailed_kwh in telemetry (uncoupled run)")
        return
    its = [int(r["iter"]) for r in recs]
    grid = [float(r.get("counters", {}).get("grid_kwh", 0.0)) for r in recs]
    fig, ax = plt.subplots(figsize=(8, 4))
    ax.bar(its, grid, 0.8, label="grid kWh delivered", color="C0")
    ax.bar(its, curt, 0.8, bottom=grid, label="kWh curtailed", color="C3")
    ax.set_xlabel("iteration")
    ax.set_ylabel("energy (kWh)")
    ax.set_title("Grid coupling — feeder delivery vs curtailment")
    ax2 = ax.twinx()
    frac = [c / (c + g) if (c + g) > 0 else 0.0 for c, g in zip(curt, grid)]
    ax2.plot(its, frac, "k--", lw=1.2, label="curtailed fraction")
    ax2.set_ylim(0, max(frac) * 1.3 + 1e-9)
    ax2.set_ylabel("curtailed fraction of proposed-over-cap energy")
    h1, l1 = ax.get_legend_handles_labels()
    h2, l2 = ax2.get_legend_handles_labels()
    ax.legend(h1 + h2, l1 + l2, fontsize=8)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "telemetry_grid.png"), dpi=130)


def main():
    runs = sys.argv[1] if len(sys.argv) > 1 else "runs"
    out = sys.argv[2] if len(sys.argv) > 2 else runs
    os.makedirs(out, exist_ok=True)
    made = []
    for name, fn in [
        ("table2.csv", plot_table2),
        ("fig4a.csv", plot_fig4a),
        ("fig4bc.csv", plot_fig4bc),
        ("fig5.csv", plot_fig5),
        ("fig6to8.csv", plot_scenarios),
        ("train_shopping.csv", plot_e2e),
        ("telemetry.jsonl", plot_telemetry),
    ]:
        if maybe(os.path.join(runs, name)):
            fn(runs, out)
            made.append(name)
        else:
            print(f"skip: {name} not found in {runs}/")
    print(f"plotted {len(made)} figure sets into {out}/")


if __name__ == "__main__":
    main()
