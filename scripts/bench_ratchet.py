#!/usr/bin/env python3
"""Perf ratchet: compare a fresh BENCH_table2.json against the committed
BENCH_baseline.json and warn on steps/sec regressions.

The gated row is the native-vector pool path at B=256 (present in both the
full sweep and the CI `--smoke` sweep). CI runner variance is still being
characterized, so a regression past the threshold emits a GitHub
``::warning`` annotation and exits 0 — flip ``--strict`` once the variance
envelope is known and the ratchet should fail the job instead.

Usage:
  scripts/bench_ratchet.py [--current BENCH_table2.json]
                           [--baseline BENCH_baseline.json]
                           [--batch 256] [--threshold 0.20]
                           [--strict] [--update]

``--update`` rewrites the baseline from the current file (run it on a
trusted machine / quiet CI runner and commit the result).
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> list[dict]:
    with open(path) as f:
        data = json.load(f)
    rows = data.get("rows", [])
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: 'rows' is not a list")
    return rows


def pick_row(rows: list[dict], batch: int) -> dict | None:
    """The native-vector (pool step_all) row at the gated batch size; falls
    back to the largest native-vector batch present."""
    native = [
        r
        for r in rows
        if str(r.get("variant", "")).startswith("native-vector") and "batch" in r
    ]
    if not native:
        return None
    exact = [r for r in native if int(r["batch"]) == batch]
    if exact:
        return exact[0]
    return max(native, key=lambda r: int(r["batch"]))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_table2.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--threshold", type=float, default=0.20)
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regression instead of warning")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from --current and exit")
    args = ap.parse_args()

    try:
        cur_rows = load_rows(args.current)
    except FileNotFoundError:
        print(f"::warning::bench ratchet: {args.current} not found "
              "(did the bench job run?)")
        return 0

    if args.update:
        cur = pick_row(cur_rows, args.batch)
        if cur is None:
            raise SystemExit(f"{args.current} has no native-vector rows to baseline")
        payload = {
            "note": (
                "Perf-ratchet baseline: native-vector steps/sec rows from a "
                "trusted run of `cargo bench --bench table2_throughput -- "
                "--smoke`. Refresh with scripts/bench_ratchet.py --update."
            ),
            "rows": [r for r in cur_rows
                     if str(r.get("variant", "")).startswith("native-vector")],
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"baseline updated from {args.current} -> {args.baseline}")
        return 0

    try:
        base_rows = load_rows(args.baseline)
    except FileNotFoundError:
        print(f"bench ratchet: no baseline at {args.baseline}; nothing to compare")
        return 0

    base = pick_row(base_rows, args.batch)
    cur = pick_row(cur_rows, args.batch)
    if base is None:
        print("bench ratchet: baseline has no native-vector rows yet — "
              "populate it with scripts/bench_ratchet.py --update on a "
              "trusted run and commit BENCH_baseline.json")
        return 0
    if cur is None:
        print(f"::warning::bench ratchet: {args.current} has no native-vector rows")
        return 0
    if int(base["batch"]) != int(cur["batch"]):
        print(f"bench ratchet: batch mismatch (baseline B={base['batch']}, "
              f"current B={cur['batch']}); skipping comparison")
        return 0

    b = float(base["steps_per_sec"])
    c = float(cur["steps_per_sec"])
    delta = (c - b) / b if b > 0 else 0.0
    label = f"native-vector B={int(cur['batch'])}"
    print(f"bench ratchet: {label}: baseline {b:,.0f} steps/s, "
          f"current {c:,.0f} steps/s ({delta:+.1%})")
    if delta < -args.threshold:
        msg = (f"bench ratchet: {label} regressed {-delta:.1%} "
               f"(threshold {args.threshold:.0%}): "
               f"{b:,.0f} -> {c:,.0f} steps/s")
        print(f"::warning::{msg}")
        return 1 if args.strict else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
