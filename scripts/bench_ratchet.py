#!/usr/bin/env python3
"""Perf ratchet: compare a fresh BENCH_table2.json against the committed
BENCH_baseline.json and warn on steps/sec regressions.

Eight rows are gated, all at B=256 (present in the full sweep and the CI
``--smoke`` sweep): the ``native-vector`` pool path (raw env runtime),
the ``policy-fused`` path (shard-parallel MLP policy + env, the default
training rollout), the ``update-sharded`` path (the shard-parallel PPO
minibatch update; its unit is PPO samples/sec rather than env steps/sec,
compared like-for-like against its own baseline row), the kernel-layer
pair ``forward-blocked`` / ``update-blocked`` (blocked MLP forward, and
forward + blocked backward, in MLP rows/sec — the tiled GEMM layer
measured without env overhead), and two rows from BENCH_fleet.json
(pass the fleet file via ``--current-fleet``): ``fleet-generalist``
(ONE shared-trunk policy across the demo grid's three station families,
fused rollout at L=256) and ``fleet-coupled`` (the same fused per-family
nets with all families on one shared feeder, so every step pays the
propose -> allocate -> commit double dispatch — this row holds the
grid-coupling overhead to the ratchet threshold), plus
``pipeline-overlapped`` from BENCH_table2.json (full train iterations
with `--overlap on` double buffering at B=256 — this row keeps the
streamed-rollout pipeline from silently losing its win). CI
runner variance is still being characterized, so a
regression past the threshold emits a GitHub ``::warning`` annotation and
exits 0 — flip ``--strict`` once the variance envelope is known and the
ratchet should fail the job instead.

A second, baseline-free gate covers the telemetry layer: pass
``--overhead BENCH_overhead.json`` (written by ``cargo bench --bench
runtime_overhead``) and the ``telemetry-overhead`` row's measured
``overhead_pct`` — env-steps/sec with the span recorder off vs on — is
checked against the ISSUE 8 budget (``--overhead-budget``, default 2%).
No baseline file is involved because the bench A/B-measures both modes in
one run.

Usage:
  scripts/bench_ratchet.py [--current BENCH_table2.json]
                           [--current-fleet BENCH_fleet.json]
                           [--baseline BENCH_baseline.json]
                           [--overhead BENCH_overhead.json]
                           [--overhead-budget 2.0]
                           [--batch 256] [--threshold 0.20]
                           [--strict] [--update]

``--update`` rewrites the baseline from the current file (run it on a
trusted machine / quiet CI runner and commit the result).
"""

from __future__ import annotations

import argparse
import json
import sys

# Variant-name prefixes of the gated rows (and of the rows kept by
# --update). Each is compared independently at the gated batch size.
# NOTE: "update-serial" must not match, so the prefix includes "-sharded";
# likewise "update-blocked" is its own gated prefix and must never be
# swallowed by a bare "update" prefix.
GATED_PREFIXES = (
    "native-vector",
    "policy-fused",
    "update-sharded",
    "forward-blocked",
    "update-blocked",
    "fleet-generalist",
    "fleet-coupled",
    "pipeline-overlapped",
)


def load_rows(path: str) -> list[dict]:
    with open(path) as f:
        data = json.load(f)
    rows = data.get("rows", [])
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: 'rows' is not a list")
    return rows


def pick_row(rows: list[dict], prefix: str, batch: int) -> dict | None:
    """The `prefix` row at the gated batch size; falls back to the largest
    batch present for that prefix."""
    matching = [
        r
        for r in rows
        if str(r.get("variant", "")).startswith(prefix) and "batch" in r
    ]
    if not matching:
        return None
    exact = [r for r in matching if int(r["batch"]) == batch]
    if exact:
        return exact[0]
    return max(matching, key=lambda r: int(r["batch"]))


def compare_one(prefix: str, base_rows: list[dict], cur_rows: list[dict],
                batch: int, threshold: float) -> bool:
    """Compare one gated prefix; returns True when it regressed past the
    threshold."""
    base = pick_row(base_rows, prefix, batch)
    cur = pick_row(cur_rows, prefix, batch)
    if base is None:
        print(f"bench ratchet: baseline has no {prefix} rows yet — "
              "populate it with scripts/bench_ratchet.py --update on a "
              "trusted run and commit BENCH_baseline.json")
        return False
    if cur is None:
        print(f"::warning::bench ratchet: current run has no {prefix} rows")
        return False
    if int(base["batch"]) != int(cur["batch"]):
        print(f"bench ratchet: {prefix} batch mismatch (baseline "
              f"B={base['batch']}, current B={cur['batch']}); skipping")
        return False
    b = float(base["steps_per_sec"])
    c = float(cur["steps_per_sec"])
    delta = (c - b) / b if b > 0 else 0.0
    label = f"{prefix} B={int(cur['batch'])}"
    print(f"bench ratchet: {label}: baseline {b:,.0f} steps/s, "
          f"current {c:,.0f} steps/s ({delta:+.1%})")
    if delta < -threshold:
        msg = (f"bench ratchet: {label} regressed {-delta:.1%} "
               f"(threshold {threshold:.0%}): "
               f"{b:,.0f} -> {c:,.0f} steps/s")
        print(f"::warning::{msg}")
        return True
    return False


def check_overhead(path: str, budget_pct: float) -> bool:
    """Gate the telemetry-overhead row against its budget (baseline-free:
    the bench measures off vs on in one run). Returns True on breach."""
    try:
        rows = load_rows(path)
    except FileNotFoundError:
        print(f"::warning::bench ratchet: {path} not found "
              "(did the overhead bench run?)")
        return False
    row = next((r for r in rows
                if str(r.get("variant", "")) == "telemetry-overhead"), None)
    if row is None:
        print(f"::warning::bench ratchet: {path} has no telemetry-overhead row")
        return False
    pct = float(row["overhead_pct"])
    off = float(row.get("steps_per_sec_off", 0.0))
    on = float(row.get("steps_per_sec_on", 0.0))
    print(f"bench ratchet: telemetry overhead {pct:+.2f}% "
          f"(off {off:,.0f} -> on {on:,.0f} env-steps/s, "
          f"budget {budget_pct:.1f}%)")
    if pct > budget_pct:
        print(f"::warning::bench ratchet: telemetry overhead {pct:.2f}% "
              f"exceeds the {budget_pct:.1f}% budget (ISSUE 8 / ROADMAP "
              "§Telemetry) — the recorder must stay a thread-local push "
              "per span")
        return True
    return False


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_table2.json")
    ap.add_argument("--current-fleet", default=None,
                    help="BENCH_fleet.json to merge in "
                         "(fleet-generalist / fleet-coupled rows)")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--overhead", default=None,
                    help="BENCH_overhead.json to gate telemetry overhead")
    ap.add_argument("--overhead-budget", type=float, default=2.0,
                    help="max telemetry overhead_pct before warning")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--threshold", type=float, default=0.20)
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regression instead of warning")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from --current and exit")
    args = ap.parse_args()

    overhead_breach = False
    if args.overhead:
        overhead_breach = check_overhead(args.overhead, args.overhead_budget)

    try:
        cur_rows = load_rows(args.current)
    except FileNotFoundError:
        print(f"::warning::bench ratchet: {args.current} not found "
              "(did the bench job run?)")
        return 1 if (overhead_breach and args.strict) else 0

    # The fleet sweep writes its own artifact; merge its rows so the
    # fleet-generalist and fleet-coupled prefixes are gated (and kept by
    # --update) alongside the single-env rows. Variant prefixes are
    # disjoint across the two files, so merging cannot shadow a table2 row.
    if args.current_fleet:
        try:
            cur_rows = cur_rows + load_rows(args.current_fleet)
        except FileNotFoundError:
            print(f"::warning::bench ratchet: {args.current_fleet} not found "
                  "(did the fleet sweep run?)")

    if args.update:
        kept = [r for r in cur_rows
                if str(r.get("variant", "")).startswith(GATED_PREFIXES)]
        if not kept:
            raise SystemExit(
                f"{args.current} has no {'/'.join(GATED_PREFIXES)} rows to baseline")
        payload = {
            "note": (
                "Perf-ratchet baseline: native-vector, policy-fused, "
                "update-sharded, forward-blocked, update-blocked, "
                "fleet-generalist, fleet-coupled, and "
                "pipeline-overlapped steps/sec rows "
                "from a trusted run of "
                "`cargo bench --bench table2_throughput -- --smoke`. "
                "Refresh with scripts/bench_ratchet.py --update "
                "--current-fleet BENCH_fleet.json."
            ),
            "rows": kept,
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"baseline updated from {args.current} -> {args.baseline}")
        return 0

    try:
        base_rows = load_rows(args.baseline)
    except FileNotFoundError:
        print(f"bench ratchet: no baseline at {args.baseline}; nothing to compare")
        return 1 if (overhead_breach and args.strict) else 0

    regressed = overhead_breach
    for prefix in GATED_PREFIXES:
        regressed |= compare_one(prefix, base_rows, cur_rows,
                                 args.batch, args.threshold)
    if regressed and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
