//! User-satisfaction reward shaping (paper Fig. 4b, reduced scale):
//! sweep the alpha weight of the satisfaction0 penalty (kWh missing when a
//! time-sensitive user departs) and watch missing-charge fall while profit
//! stays roughly level — the paper's headline qualitative result.
//!
//! Run: `cargo run --release --example satisfaction_sweep`

use anyhow::Result;
use chargax::coordinator::metrics;
use chargax::coordinator::trainer::{self, TrainOptions};
use chargax::data::{DataStore, Scenario};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;

fn main() -> Result<()> {
    let steps: usize = std::env::var("CHARGAX_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let manifest = Manifest::load(&artifacts_dir())?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let variant = manifest.variant("mix10dc6ac_e12")?;
    let engine = Engine::cpu()?;

    println!("=== Fig. 4b (reduced): alpha_satisfaction0 sweep, {steps} steps/agent ===");
    println!("{:>8} {:>18} {:>14}", "alpha", "missing kWh/ep", "profit/ep");
    for alpha in [0.0f32, 0.5, 2.0, 8.0] {
        let sc = Scenario { traffic: "high".into(), ..Default::default() }
            .with_alpha("satisfaction0", alpha)?;
        let opts = TrainOptions { seed: 2, total_env_steps: steps, quiet: true, ..Default::default() };
        let out = trainer::train(&engine, variant, &store, &sc, &opts)?;
        let evals = trainer::evaluate(&engine, &out.session, &store, &sc, 300..308)?;
        let m = metrics::mean(&evals)?;
        println!(
            "{alpha:>8.1} {:>18.2} {:>14.1}",
            m.get("ep_missing_kwh")?,
            m.get("ep_profit")?
        );
    }
    println!("(higher alpha should push missing kWh toward 0 at similar profit)");
    Ok(())
}
