//! Distribution shift across electricity-price years (paper Fig. 5,
//! reduced scale): train on one NL price year, evaluate on all three.
//! The 2022 energy-crisis prices (≈3x level, higher volatility) make
//! agents trained on 2022 data *worse* — even on 2022 itself.
//!
//! Run: `cargo run --release --example distribution_shift`
//! (CHARGAX_STEPS to change the per-agent budget, default 100k)

use anyhow::Result;
use chargax::coordinator::metrics;
use chargax::coordinator::trainer::{self, TrainOptions};
use chargax::data::{DataStore, Scenario};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;

fn main() -> Result<()> {
    let steps: usize = std::env::var("CHARGAX_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let manifest = Manifest::load(&artifacts_dir())?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let variant = manifest.variant("mix10dc6ac_e12")?;
    let engine = Engine::cpu()?;
    let years = [2021u32, 2022, 2023];

    println!("=== Fig. 5 (reduced): train year -> eval years, NL prices, {steps} steps ===");
    println!("{:>10} {:>12} {:>12} {:>12}", "train\\eval", 2021, 2022, 2023);
    for train_year in years {
        let sc = Scenario { year: train_year, traffic: "high".into(), ..Default::default() };
        let opts = TrainOptions { seed: 1, total_env_steps: steps, quiet: true, ..Default::default() };
        let out = trainer::train(&engine, variant, &store, &sc, &opts)?;
        let mut row = format!("{train_year:>10}");
        for eval_year in years {
            let esc = Scenario { year: eval_year, traffic: "high".into(), ..Default::default() };
            let evals = trainer::evaluate(&engine, &out.session, &store, &esc, 100..106)?;
            row.push_str(&format!(" {:>12.1}", metrics::mean(&evals)?.get("ep_reward")?));
        }
        println!("{row}");
    }
    println!("(rows: training year; columns: mean episode reward on eval year)");
    Ok(())
}
