//! Quickstart: load the AOT artifacts, reset a vectorized station, step it
//! with hand-picked actions, and read the metrics — the minimal use of the
//! public API.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use chargax::coordinator::metrics::NamedVec;
use chargax::data::{DataStore, Scenario};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::runtime::tensor::Tensor;

fn main() -> Result<()> {
    // 1. Load the manifest (the AOT contract) and the bundled data stack.
    let manifest = Manifest::load(&artifacts_dir())?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let variant = manifest.variant("mix10dc6ac_e12")?;
    println!(
        "variant mix10dc6ac_e12: {} envs x {} ports, obs_dim {}",
        variant.meta.num_envs, variant.meta.n_ports, variant.meta.obs_dim
    );

    // 2. Pick a scenario (everything swappable without re-AOT).
    let scenario = Scenario {
        scenario: "shopping".into(),
        country: "NL".into(),
        year: 2021,
        traffic: "high".into(),
        ..Default::default()
    };
    let exog: Vec<xla::Literal> = scenario
        .to_tensors(&store)?
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;

    // 3. Compile + reset.
    let engine = Engine::cpu()?;
    let reset = engine.load(variant.program("env_reset")?)?;
    let step = engine.load(variant.program("env_step")?)?;
    let seed = Tensor::scalar_u32(7).to_literal()?;
    let mut ins: Vec<&xla::Literal> = vec![&seed];
    ins.extend(exog.iter());
    let mut outs = reset.run_literals(&ins)?;
    let _obs = outs.pop().unwrap();
    let n_state = outs.len();
    let mut state = outs;

    // 4. Step for two simulated hours: all chargers at 80%, battery idle.
    let e = variant.meta.num_envs;
    let p = variant.meta.n_ports;
    let mut action = vec![8i32; e * p];
    for env_i in 0..e {
        action[env_i * p + p - 1] = 10; // battery midpoint = 0 A
    }
    let action = Tensor::i32(vec![e, p], action)?.to_literal()?;

    let metric_fields = &variant.meta.metric_fields;
    for step_i in 0..24 {
        let mut ins: Vec<&xla::Literal> = state.iter().collect();
        ins.push(&action);
        ins.extend(exog.iter());
        let full = step.run_literals(&ins)?;
        // outputs: state' ++ [obs, reward, done, metrics]
        let metrics = Tensor::from_literal(&full[n_state + 3])?;
        let row = metrics.as_f32()?;
        // mean over envs for display
        let m = variant.meta.metric_fields.len();
        let mean: Vec<f32> = (0..m)
            .map(|k| (0..e).map(|i| row[i * m + k]).sum::<f32>() / e as f32)
            .collect();
        let nv = NamedVec::new(metric_fields, mean)?;
        if step_i % 6 == 0 {
            println!(
                "t={:>3} min: {}",
                (step_i + 1) * 5,
                nv.fmt_fields(&["reward", "profit", "energy_to_cars_kwh", "arrived"])
            );
        }
        state = full.into_iter().take(n_state).collect();
    }
    println!("quickstart OK — the station simulated 2 hours under a fixed policy");
    Ok(())
}
