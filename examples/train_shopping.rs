//! End-to-end driver (DESIGN.md: the E2E validation run).
//!
//! Trains the paper's PPO agent (55.9k-param actor-critic, Table 3
//! hyperparameters) on the *shopping* scenario with a 16-charger station
//! (10 DC / 6 AC), entirely through the AOT fast path — one PJRT call per
//! PPO iteration (3600 env steps + GAE + 16 minibatch updates fused).
//! Logs the reward curve, evaluates against the paper's always-charge-max
//! baseline, and writes runs/train_shopping.csv. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example train_shopping`
//! (env CHARGAX_STEPS overrides the 200k default)

use anyhow::Result;
use chargax::coordinator::metrics;
use chargax::coordinator::trainer::{self, TrainOptions};
use chargax::data::{DataStore, Scenario};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;

fn main() -> Result<()> {
    let total_steps: usize = std::env::var("CHARGAX_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);

    let manifest = Manifest::load(&artifacts_dir())?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let variant = manifest.variant("mix10dc6ac_e12")?;
    let engine = Engine::cpu()?;
    let scenario = Scenario { traffic: "high".into(), ..Default::default() };

    println!(
        "=== Chargax E2E: PPO on shopping/high ({} params, {} envs, {} steps) ===",
        variant.meta.n_params, variant.meta.num_envs, total_steps
    );

    // Baseline first (paper Fig. 4a: charge max within constraints).
    let base = trainer::evaluate_baseline(&engine, variant, &store, &scenario, "max", 500..508)?;
    let base_mean = metrics::mean(&base)?;
    println!(
        "baseline (max-charge): reward/ep {:.1}  profit/ep {:.1}  missing kWh/ep {:.2}",
        base_mean.get("ep_reward")?,
        base_mean.get("ep_profit")?,
        base_mean.get("ep_missing_kwh")?,
    );

    // Train.
    let opts = TrainOptions {
        seed: 0,
        total_env_steps: total_steps,
        log_every: 5,
        quiet: false,
    };
    let out = trainer::train(&engine, variant, &store, &scenario, &opts)?;
    println!(
        "trained {} env steps in {:.1}s = {:.0} steps/s (one PJRT call per {}-step iteration)",
        out.env_steps,
        out.wallclock_s,
        out.env_steps as f64 / out.wallclock_s,
        variant.meta.batch_size,
    );

    // Loss/reward curve to CSV.
    std::fs::create_dir_all("runs").ok();
    let mut csv = String::from("iter,env_steps,mean_reward,mean_completed_return,total_loss,entropy\n");
    for (i, m) in out.history.iter().enumerate() {
        csv.push_str(&format!(
            "{},{},{},{},{},{}\n",
            i,
            (i + 1) * variant.meta.batch_size,
            m.get("mean_reward")?,
            m.get("mean_completed_return")?,
            m.get("total_loss")?,
            m.get("entropy")?,
        ));
    }
    std::fs::write("runs/train_shopping.csv", csv)?;

    // Final evaluation vs baseline.
    let evals = trainer::evaluate(&engine, &out.session, &store, &scenario, 900..910)?;
    let m = metrics::mean(&evals)?;
    let s = metrics::std(&evals)?;
    println!(
        "PPO (trained):         reward/ep {:.1}±{:.1}  profit/ep {:.1}  missing kWh/ep {:.2}",
        m.get("ep_reward")?,
        s.get("ep_reward")?,
        m.get("ep_profit")?,
        m.get("ep_missing_kwh")?,
    );
    let uplift = 100.0 * (m.get("ep_profit")? - base_mean.get("ep_profit")?)
        / base_mean.get("ep_profit")?.abs().max(1e-6);
    println!("profit vs baseline: {uplift:+.1}%  (curve in runs/train_shopping.csv)");

    // Learning-signal check for CI use (window means: single iterations are
    // Poisson-noisy).
    let w = 5.min(out.history.len());
    let head: f32 = out.history[..w]
        .iter()
        .map(|m| m.get("mean_reward").unwrap())
        .sum::<f32>()
        / w as f32;
    let tail: f32 = out.history[out.history.len() - w..]
        .iter()
        .map(|m| m.get("mean_reward").unwrap())
        .sum::<f32>()
        / w as f32;
    anyhow::ensure!(
        tail > head - 0.25,
        "training regressed: head {head:.3}, tail {tail:.3}"
    );
    println!("E2E OK (reward head {head:.2} -> tail {tail:.2})");
    Ok(())
}
