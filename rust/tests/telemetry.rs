//! ISSUE 8 gate: the telemetry layer is provably inert.
//!
//! Headline property: training results are **bitwise identical** with
//! telemetry on vs off — trainer weights and fused-rollout buffers, at
//! `--threads` {1, 4, max}, for the per-family oracle AND the shared-trunk
//! generalist. The recorder only reads `Instant` and writes its own
//! buffers; these tests pin that contract so no future instrumentation
//! can leak into RNG streams, dispatch shapes, or float math.
//!
//! Telemetry state is process-global (enable flag, registry, dispatch
//! counter), so every test serializes on one lock and leaves the recorder
//! disabled and drained.

use std::sync::{Arc, Mutex, MutexGuard};

use chargax::baselines::ppo::{Learner, PpoParams};
use chargax::env::scalar::ScenarioTables;
use chargax::env::tree::StationConfig;
use chargax::env::vector::{PolicyRollout, RolloutBuffers, VectorEnv};
use chargax::fleet::{Fleet, FleetPpoTrainer, FleetSpec};
use chargax::telemetry::{self, IterationReport, SpanKind};
use chargax::util::json::Json;
use chargax::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Reset the recorder to a known state around each measured run.
fn reset(on: bool) {
    telemetry::set_enabled(on);
    telemetry::drain();
}

/// Two fleet training iterations (demo grid, rollout + sharded update +
/// per-cell greedy eval) → (flat weights, per-family stats, eval bits).
fn run_training(threads: usize, generalist: bool) -> (Vec<f32>, Vec<(f32, f32)>, Vec<u32>) {
    let mut fleet = Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap();
    fleet.set_threads(threads);
    let hp = PpoParams {
        rollout_steps: 16,
        n_minibatches: 2,
        update_epochs: 1,
        hidden: 16,
        threads,
        ..Default::default()
    };
    let mut tr = if generalist {
        FleetPpoTrainer::new_generalist(hp, fleet, 5)
    } else {
        FleetPpoTrainer::new(hp, fleet, 5)
    };
    let mut stats = Vec::new();
    for _ in 0..2 {
        for s in tr.iteration() {
            stats.push((s.total_loss, s.entropy));
        }
    }
    let evals: Vec<u32> = tr
        .eval_all_cells_current()
        .iter()
        .flat_map(|c| [c.reward.to_bits(), c.profit.to_bits()])
        .collect();
    (tr.policy.params_flat(), stats, evals)
}

/// Trainer weights, stats, and eval returns are bitwise identical with
/// telemetry on vs off at every thread count, for both fleet policy
/// architectures.
#[test]
fn telemetry_is_bitwise_inert_for_training() {
    let _g = lock();
    for generalist in [false, true] {
        let arch = if generalist { "generalist" } else { "per-family" };
        for threads in [1usize, 4, max_threads()] {
            reset(false);
            let (w_off, s_off, e_off) = run_training(threads, generalist);
            reset(true);
            let (w_on, s_on, e_on) = run_training(threads, generalist);
            let d = telemetry::drain();
            reset(false);
            assert!(
                !d.spans.is_empty(),
                "{arch} threads={threads}: telemetry-on run recorded no spans"
            );
            let wb_off: Vec<u32> = w_off.iter().map(|x| x.to_bits()).collect();
            let wb_on: Vec<u32> = w_on.iter().map(|x| x.to_bits()).collect();
            assert_eq!(wb_off, wb_on, "{arch} threads={threads}: weights drifted");
            assert_eq!(s_off, s_on, "{arch} threads={threads}: train stats drifted");
            assert_eq!(e_off, e_on, "{arch} threads={threads}: eval returns drifted");
        }
    }
}

/// The fused rollout writes bitwise-identical env-side and policy-side
/// buffers with telemetry on vs off at every thread count.
#[test]
fn telemetry_is_bitwise_inert_for_fused_rollout() {
    let _g = lock();
    let t_len = 40;
    let b = 48;
    let build = || {
        let tables = Arc::new(ScenarioTables::synthetic(1.2));
        VectorEnv::new(StationConfig::default(), tables, b, 77)
    };
    let proto = build();
    let learner =
        Learner::new(&mut Rng::new(23), proto.obs_dim(), 24, proto.action_nvec());
    let (d, p) = (proto.obs_dim(), proto.n_ports());
    drop(proto);
    let run = |threads: usize, on: bool| -> Vec<u32> {
        reset(on);
        let mut env = build();
        env.set_threads(threads);
        let mut obs = vec![0f32; (t_len + 1) * b * d];
        let mut rew = vec![0f32; t_len * b];
        let mut done = vec![0f32; t_len * b];
        let mut profit = vec![0f32; t_len * b];
        let mut act = vec![0usize; t_len * b * p];
        let mut logp = vec![0f32; t_len * b];
        let mut val = vec![0f32; t_len * b];
        {
            let mut rb = RolloutBuffers {
                obs: &mut obs,
                rewards: &mut rew,
                dones: &mut done,
                profits: &mut profit,
            };
            let mut pol =
                PolicyRollout { actions: &mut act, logp: &mut logp, values: &mut val };
            env.rollout_fused(t_len, &mut rb, &mut pol, &learner, 0xDEAD, false);
        }
        if on {
            let drained = telemetry::drain();
            assert!(
                drained.counters.env_steps >= (t_len * b) as u64,
                "threads={threads}: env_steps counter missed steps"
            );
        }
        reset(false);
        obs.iter()
            .chain(rew.iter())
            .chain(done.iter())
            .chain(profit.iter())
            .chain(logp.iter())
            .chain(val.iter())
            .map(|x| x.to_bits())
            .chain(act.iter().map(|&a| a as u32))
            .collect()
    };
    for threads in [1usize, 4, max_threads()] {
        let off = run(threads, false);
        let on = run(threads, true);
        assert_eq!(off, on, "threads={threads}: fused-rollout checksum drifted");
    }
}

/// One instrumented fleet iteration produces a report that covers every
/// pipeline stage, exact env-step accounting, and sane shard columns.
#[test]
fn fleet_iteration_report_covers_stages_and_counters() {
    let _g = lock();
    reset(true);
    let mut fleet = Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap();
    fleet.set_threads(4);
    let hp = PpoParams {
        rollout_steps: 16,
        n_minibatches: 2,
        update_epochs: 1,
        hidden: 16,
        threads: 4,
        ..Default::default()
    };
    let mut tr = FleetPpoTrainer::new(hp, fleet, 5);
    let lanes = tr.fleet.total_lanes();
    tr.iteration();
    let d = telemetry::drain();
    reset(false);

    let rep = IterationReport::from_drained(3, 42.0, &d);
    assert_eq!(rep.iter, 3);
    assert_eq!(rep.stages.len(), SpanKind::STAGES.len());
    let count_of = |kind: SpanKind| {
        rep.stages.iter().find(|s| s.kind == kind).map(|s| s.count).unwrap_or(0)
    };
    assert_eq!(count_of(SpanKind::Rollout), 1, "one fused rollout per iteration");
    assert!(count_of(SpanKind::PolicyForward) > 0, "no policy-forward spans");
    assert!(count_of(SpanKind::EnvStep) > 0, "no env-step spans");
    assert!(count_of(SpanKind::UpdateChunk) > 0, "no update-chunk spans");
    assert!(count_of(SpanKind::Reduce) > 0, "no reduce spans");
    assert!(count_of(SpanKind::Adam) > 0, "no adam spans");
    assert_eq!(count_of(SpanKind::Eval), 0, "no eval ran yet");
    for s in &rep.stages {
        assert!(s.p50_ms <= s.p99_ms + 1e-9, "{}: p50 > p99", s.kind.label());
        assert!(s.total_ms >= 0.0 && s.p99_ms.is_finite(), "{}", s.kind.label());
    }
    // Exactly one EnvStep counter tick per (lane, step) of the rollout —
    // the greedy eval has not run, so nothing else steps envs.
    assert_eq!(rep.counters.env_steps, (lanes * 16) as u64, "env-step accounting");
    assert!(rep.counters.minibatch_rows > 0, "no minibatch rows counted");
    assert!(rep.dropped_spans == 0, "spans dropped in a tiny run");
    assert!(!rep.shard_busy_ms.is_empty(), "no per-shard busy time");
    assert!(rep.utilization > 0.0 && rep.utilization <= 1.0, "{}", rep.utilization);
    assert!(rep.imbalance_max >= rep.imbalance_mean, "imbalance ordering");
    assert!(rep.imbalance_mean >= 1.0, "imbalance ratio is max/min >= 1");

    // The JSONL record carries every stage label the ISSUE names.
    let j = rep.to_json();
    let txt = j.to_string();
    let parsed = Json::parse(&txt).expect("record round-trips");
    let stages = parsed.get("stages").and_then(|s| s.as_obj()).expect("stages object");
    for kind in SpanKind::STAGES {
        assert!(stages.contains_key(kind.label()), "record lacks stage {}", kind.label());
    }
    assert_eq!(parsed.get("type").and_then(|t| t.as_str()), Some("telemetry"));

    // Eval spans show up once the greedy eval runs.
    reset(true);
    tr.eval_cells(0, 7);
    let d2 = telemetry::drain();
    reset(false);
    let rep2 = IterationReport::from_drained(4, 1.0, &d2);
    let evals =
        rep2.stages.iter().find(|s| s.kind == SpanKind::Eval).map(|s| s.count).unwrap_or(0);
    assert!(evals > 0, "eval pass recorded no eval spans");
}

/// Grid-coupling telemetry: a coupled fleet iteration records EXACTLY one
/// `grid-reduce` span per rollout step (the allocate phase runs once per
/// step, covering every feeder), drops nothing, and — under a feeder
/// tight enough to bind — accrues a positive `curtailed_kwh` counter. An
/// uncoupled iteration records zero `grid-reduce` spans and zero
/// curtailed energy.
#[test]
fn grid_reduce_spans_cover_coupled_iterations_exactly() {
    let _g = lock();
    let run = |spec: &FleetSpec| {
        reset(true);
        let mut fleet = Fleet::from_spec(spec, None).unwrap();
        fleet.set_threads(4);
        let hp = PpoParams {
            rollout_steps: 16,
            n_minibatches: 2,
            update_epochs: 1,
            hidden: 16,
            threads: 4,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new(hp, fleet, 5);
        tr.iteration();
        let d = telemetry::drain();
        reset(false);
        IterationReport::from_drained(0, 1.0, &d)
    };

    let rep = run(&FleetSpec::demo(9, 1));
    let count_of = |rep: &IterationReport, kind: SpanKind| {
        rep.stages.iter().find(|s| s.kind == kind).map(|s| s.count).unwrap_or(0)
    };
    assert_eq!(
        count_of(&rep, SpanKind::GridReduce),
        0,
        "uncoupled fleets must never enter the allocate phase"
    );
    assert_eq!(rep.counters.curtailed_kwh, 0.0, "uncoupled run curtailed energy");

    // 100 kW shared feeder for 20 lanes: binds from the first steps.
    let mut spec = FleetSpec::demo_coupled(9, 1);
    for s in &mut spec.specs {
        s.grid.as_mut().unwrap().capacity_kw = Some(100.0);
    }
    let rep = run(&spec);
    assert_eq!(
        count_of(&rep, SpanKind::GridReduce),
        16,
        "one grid-reduce span per rollout step"
    );
    assert_eq!(rep.dropped_spans, 0, "allocate-phase spans were dropped");
    assert!(
        rep.counters.curtailed_kwh > 0.0,
        "a binding feeder must accrue curtailed_kwh"
    );
    // The allocate phase is once-per-step bookkeeping over a handful of
    // f32 sums — it must stay a rounding error next to the env step
    // work, far inside the <2% overhead budget.
    let ms_of = |rep: &IterationReport, kind: SpanKind| {
        rep.stages.iter().find(|s| s.kind == kind).map(|s| s.total_ms).unwrap_or(0.0)
    };
    let reduce_ms = ms_of(&rep, SpanKind::GridReduce);
    let step_ms = ms_of(&rep, SpanKind::EnvStep);
    assert!(
        reduce_ms <= (step_ms * 0.5).max(2.0),
        "grid-reduce {reduce_ms} ms vs env-step {step_ms} ms: allocate phase too heavy"
    );
}

/// The Chrome trace export is valid JSON with one complete event per span
/// and per-lane thread metadata — loadable in Perfetto.
#[test]
fn chrome_trace_export_is_valid_and_complete() {
    let _g = lock();
    reset(true);
    let mut fleet = Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap();
    fleet.set_threads(4);
    let hp = PpoParams {
        rollout_steps: 8,
        n_minibatches: 2,
        update_epochs: 1,
        hidden: 16,
        threads: 4,
        ..Default::default()
    };
    let mut tr = FleetPpoTrainer::new(hp, fleet, 5);
    tr.iteration();
    let d = telemetry::drain();
    reset(false);
    assert!(!d.spans.is_empty());

    let dir = std::env::temp_dir().join(format!(
        "chargax-trace-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let path = dir.join("trace.json");
    telemetry::write_chrome_trace(&path, &d.spans).expect("write trace");
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).expect("trace file is valid JSON");
    let events = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
    let complete: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .collect();
    assert_eq!(complete.len(), d.spans.len(), "one X event per span");
    for e in &complete {
        assert!(e.get("name").and_then(|n| n.as_str()).is_some());
        assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("dur").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("tid").and_then(|t| t.as_usize()).is_some());
    }
    assert!(
        events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M")),
        "no thread_name metadata events"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
