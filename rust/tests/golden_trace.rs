//! Golden-trace regression (ISSUE 5): a small fixed-seed fused rollout
//! whose per-stream checksums are pinned in a checked-in fixture, so any
//! refactor that silently changes an observation, action, reward, or
//! value stream fails loudly instead of drifting.
//!
//! The fixture (`tests/fixtures/golden_trace.json`) ships with
//! `"checksums": null` until a machine with a Rust toolchain populates it:
//! run `CHARGAX_UPDATE_GOLDEN=1 cargo test --test golden_trace` once and
//! commit the rewritten fixture. While unpopulated the comparison half
//! skips (loudly) — but the trace's internal determinism is still
//! asserted, so the test is never vacuous.

use chargax::baselines::ppo::Learner;
use chargax::env::core::ScenarioTables;
use chargax::env::tree::StationConfig;
use chargax::env::vector::{PolicyRollout, RolloutBuffers, VectorEnv};
use chargax::util::json::Json;
use chargax::util::rng::Rng;

const TRACE_STEPS: usize = 64;
const TRACE_LANES: usize = 4;
const ENV_SEED: u64 = 4242;
const LEARNER_SEED: u64 = 77;
const POLICY_SEED: u64 = 99;
const HIDDEN: usize = 32;

/// FNV-1a 64 over a little-endian byte stream — stable across platforms
/// for bit-identical inputs, which is exactly the contract the fused
/// rollout makes.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    fn f32s(mut self, xs: &[f32]) -> u64 {
        for x in xs {
            self.bytes(&x.to_bits().to_le_bytes());
        }
        self.0
    }

    fn usizes(mut self, xs: &[usize]) -> u64 {
        for &x in xs {
            self.bytes(&(x as u64).to_le_bytes());
        }
        self.0
    }
}

/// The streams the golden trace pins, in fixture-key order.
const STREAM_KEYS: [&str; 7] =
    ["obs", "actions", "logp", "values", "rewards", "dones", "profits"];

fn compute_trace_checksums() -> Vec<(&'static str, u64)> {
    let mut venv = VectorEnv::new(
        StationConfig::default(),
        ScenarioTables::synthetic(1.0),
        TRACE_LANES,
        ENV_SEED,
    );
    let (b, d, p) = (TRACE_LANES, venv.obs_dim(), venv.n_ports());
    let mut lrng = Rng::new(LEARNER_SEED);
    let learner = Learner::new(&mut lrng, d, HIDDEN, venv.action_nvec());
    let t = TRACE_STEPS;
    let mut obs = vec![0f32; (t + 1) * b * d];
    let mut rewards = vec![0f32; t * b];
    let mut dones = vec![0f32; t * b];
    let mut profits = vec![0f32; t * b];
    let mut actions = vec![0usize; t * b * p];
    let mut logp = vec![0f32; t * b];
    let mut values = vec![0f32; t * b];
    {
        let mut bufs = RolloutBuffers {
            obs: &mut obs,
            rewards: &mut rewards,
            dones: &mut dones,
            profits: &mut profits,
        };
        let mut pol = PolicyRollout {
            actions: &mut actions,
            logp: &mut logp,
            values: &mut values,
        };
        venv.rollout_fused(t, &mut bufs, &mut pol, &learner, POLICY_SEED, false);
    }
    vec![
        ("obs", Fnv::new().f32s(&obs)),
        ("actions", Fnv::new().usizes(&actions)),
        ("logp", Fnv::new().f32s(&logp)),
        ("values", Fnv::new().f32s(&values)),
        ("rewards", Fnv::new().f32s(&rewards)),
        ("dones", Fnv::new().f32s(&dones)),
        ("profits", Fnv::new().f32s(&profits)),
    ]
}

fn fixture_path() -> String {
    format!("{}/tests/fixtures/golden_trace.json", env!("CARGO_MANIFEST_DIR"))
}

fn fixture_text(checksums: &[(&str, u64)]) -> String {
    let body: Vec<String> = checksums
        .iter()
        .map(|(k, v)| format!("    \"{k}\": \"{v:#018x}\""))
        .collect();
    format!(
        "{{\n  \"note\": \"Golden 64-step fused-rollout trace (B={TRACE_LANES}, \
         env seed {ENV_SEED}, learner seed {LEARNER_SEED}, policy seed \
         {POLICY_SEED}, hidden {HIDDEN}, synthetic tables traffic=1.0). \
         FNV-1a 64 over each stream's little-endian bits. Regenerate with \
         CHARGAX_UPDATE_GOLDEN=1 cargo test --test golden_trace.\",\n  \
         \"checksums\": {{\n{}\n  }}\n}}\n",
        body.join(",\n")
    )
}

/// The trace is a pure function of its seeds: recomputing from scratch
/// reproduces every checksum bit-for-bit. This half runs even while the
/// fixture is unpopulated, so the golden test always checks something.
#[test]
fn golden_trace_is_internally_deterministic() {
    let a = compute_trace_checksums();
    let b = compute_trace_checksums();
    assert_eq!(a, b, "two from-scratch traces disagree — rollout is not deterministic");
    assert_eq!(a.len(), STREAM_KEYS.len());
    for ((k, v), want) in a.iter().zip(STREAM_KEYS) {
        assert_eq!(*k, want, "stream order drifted");
        assert_ne!(*v, 0, "degenerate checksum for {k}");
    }
}

/// Compare against (or, with CHARGAX_UPDATE_GOLDEN=1, rewrite) the
/// checked-in fixture.
#[test]
fn golden_trace_matches_committed_fixture() {
    let got = compute_trace_checksums();
    let path = fixture_path();
    if std::env::var("CHARGAX_UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false) {
        std::fs::write(&path, fixture_text(&got)).expect("writing golden fixture");
        println!("golden trace fixture rewritten: {path}");
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden fixture missing at {path}: {e}"));
    let j = Json::parse(&text).expect("golden fixture must be valid JSON");
    let sums = j.get("checksums").expect("golden fixture needs a 'checksums' key");
    if *sums == Json::Null {
        eprintln!(
            "SKIP golden trace comparison: fixture unpopulated — run \
             CHARGAX_UPDATE_GOLDEN=1 cargo test --test golden_trace on a \
             trusted machine and commit {path}"
        );
        return;
    }
    for (k, v) in &got {
        let want = sums
            .get(k)
            .and_then(|x| x.as_str())
            .unwrap_or_else(|| panic!("fixture missing checksum for stream '{k}'"));
        let got_hex = format!("{v:#018x}");
        assert_eq!(
            got_hex, want,
            "stream '{k}' drifted from the golden trace — if this change is \
             intentional, regenerate the fixture with CHARGAX_UPDATE_GOLDEN=1"
        );
    }
}
