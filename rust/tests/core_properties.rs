//! Transition-core property suite (ISSUE 5): randomized `StationConfig`s
//! — including V2G and battery-less stations — driven 288 steps (one full
//! episode) with random actions, asserting the invariants every consumer
//! of the simulator silently relies on:
//!
//! * every SoC (cars and battery) stays in [0, 1];
//! * observations, rewards, and profits are never NaN/Inf;
//! * the per-step energy books balance: battery energy implied by its SoC
//!   delta respects the battery's power rating, and the grid-side car
//!   energy relates to the delivered car energy through the port
//!   efficiency (exactly for charge-only stations, as one-sided
//!   inequalities for mixed-sign V2G flows).
//!
//! `proptest` is unavailable offline, so configs come from hand-rolled
//! generators over the `util::prop` micro-harness (failing case seeds are
//! printed for reproduction).

use chargax::env::core::{ScenarioTables, StepInfo, DT_HOURS, STEPS_PER_EPISODE};
use chargax::env::tree::{StationConfig, StationTree};
use chargax::env::vector::VectorEnv;
use chargax::util::prop::Prop;
use chargax::util::rng::Rng;

/// Random-but-valid station config. Roughly 1/3 of draws are battery-less
/// (capacity AND power zero — the only legal battery-less encoding) and
/// half are V2G; charger counts cover DC-only, AC-only, and mixed trees.
fn random_config(rng: &mut Rng) -> StationConfig {
    loop {
        let n_dc = rng.below(5) as usize;
        let n_ac = rng.below(5) as usize;
        if n_dc + n_ac == 0 {
            continue;
        }
        let batteryless = rng.f32() < 0.33;
        let (cap, p_max) = if batteryless {
            (0.0, 0.0)
        } else {
            (rng.range_f32(20.0, 300.0), rng.range_f32(10.0, 150.0))
        };
        let cfg = StationConfig {
            n_dc,
            n_ac,
            root_p_kw: rng.range_f32(50.0, 800.0),
            dc_split_p_kw: rng.range_f32(50.0, 600.0),
            ac_split_p_kw: rng.range_f32(10.0, 100.0),
            node_eta: rng.range_f32(0.9, 0.999),
            evse_eta: rng.range_f32(0.85, 0.99),
            battery_capacity_kwh: cap,
            battery_p_max_kw: p_max,
            battery_voltage: 400.0,
            battery_tau: rng.range_f32(0.4, 0.95),
            battery_soc0: rng.range_f32(0.0, 1.0),
            v2g: rng.f32() < 0.5,
        };
        if cfg.validate().is_ok() {
            return cfg;
        }
    }
}

/// Random scenario tables: traffic level, penalty weights, and reward
/// prices all move per case so the reward path is exercised, not just the
/// physics.
fn random_tables(rng: &mut Rng) -> ScenarioTables {
    let mut t = ScenarioTables::synthetic(rng.range_f32(0.0, 2.5));
    for a in t.alpha.iter_mut() {
        *a = rng.range_f32(0.0, 0.5);
    }
    t.beta = rng.range_f32(0.0, 0.3);
    t.p_sell = rng.range_f32(0.3, 1.0);
    t
}

fn random_actions(rng: &mut Rng, env: &VectorEnv) -> Vec<usize> {
    let nvec = env.action_nvec();
    (0..env.batch())
        .flat_map(|_| {
            nvec.iter().map(|&n| rng.below(n as u32) as usize).collect::<Vec<_>>()
        })
        .collect()
}

/// The generator really produces the variants the sweep claims to cover
/// (guards against silent generator drift narrowing the property).
#[test]
fn config_generator_covers_batteryless_v2g_and_plain() {
    let mut rng = Rng::new(0x5EED);
    let mut batteryless = 0;
    let mut v2g = 0;
    let mut plain = 0;
    let mut dc_only = 0;
    let mut ac_only = 0;
    for _ in 0..64 {
        let cfg = random_config(&mut rng);
        if cfg.battery_capacity_kwh == 0.0 {
            batteryless += 1;
        }
        if cfg.v2g {
            v2g += 1;
        } else {
            plain += 1;
        }
        if cfg.n_ac == 0 {
            dc_only += 1;
        }
        if cfg.n_dc == 0 {
            ac_only += 1;
        }
    }
    assert!(batteryless >= 5, "battery-less configs underrepresented: {batteryless}/64");
    assert!(v2g >= 10 && plain >= 10, "v2g/plain split degenerate: {v2g}/{plain}");
    assert!(dc_only >= 2 && ac_only >= 2, "single-type trees missing: {dc_only}/{ac_only}");
}

/// The 288-step sweep itself: for each randomized (config, tables) case,
/// run one full episode on a B=2 `VectorEnv` with fresh random actions
/// per step and check every invariant at every step.
#[test]
fn randomized_configs_hold_invariants_for_a_full_episode() {
    Prop::new(16).check("core-invariants-288-steps", |rng| {
        let cfg = random_config(rng);
        let tables = random_tables(rng);
        let tree = StationTree::standard(&cfg);
        let eta = cfg.evse_eta;
        let c = cfg.n_chargers();
        // Electrical ceiling on per-step car energy (projection can only
        // scale currents down).
        let car_power_bound: f32 =
            (0..c).map(|j| tree.p_max[j]).sum::<f32>() * DT_HOURS + 1e-3;
        let bat_bound = cfg.battery_p_max_kw * DT_HOURS + 1e-3;
        let b = 2usize;
        let mut env = VectorEnv::new(cfg.clone(), tables, b, rng.next_u64());
        let mut infos = vec![StepInfo::default(); b];
        let mut obs = vec![0f32; b * env.obs_dim()];
        for step in 0..STEPS_PER_EPISODE {
            let soc_before: Vec<f32> = (0..b).map(|l| env.lane_battery_soc(l)).collect();
            let actions = random_actions(rng, &env);
            env.step_all(&actions, &mut infos);
            env.observe_all(&mut obs);
            for (k, &x) in obs.iter().enumerate() {
                assert!(x.is_finite(), "step {step}: obs[{k}] = {x} with cfg {cfg:?}");
            }
            for (lane, info) in infos.iter().enumerate() {
                assert!(info.reward.is_finite(), "step {step} lane {lane}: reward NaN/Inf");
                assert!(info.profit.is_finite(), "step {step} lane {lane}: profit NaN/Inf");
                let soc_bat = env.lane_battery_soc(lane);
                assert!(
                    (0.0..=1.0).contains(&soc_bat),
                    "step {step} lane {lane}: battery SoC {soc_bat}"
                );
                if cfg.battery_capacity_kwh == 0.0 {
                    assert_eq!(soc_bat, 0.0, "battery-less station must pin SoC to 0");
                }
                for slot in 0..c {
                    if let Some(car) = env.lane_car(lane, slot) {
                        assert!(
                            (0.0..=1.0).contains(&car.soc),
                            "step {step} lane {lane} slot {slot}: car SoC {}",
                            car.soc
                        );
                        assert!(car.cap > 0.0);
                    }
                }
                let de_net = info.energy_to_cars_kwh;
                assert!(
                    de_net.abs() <= car_power_bound,
                    "step {step} lane {lane}: |car energy| {de_net} exceeds \
                     electrical bound {car_power_bound}"
                );
                // Energy book (skipped on episode-end steps: the lane has
                // already reset, so the SoC delta no longer encodes the
                // step's battery energy).
                if info.done {
                    continue;
                }
                let e_bat = (soc_bat - soc_before[lane]) * cfg.battery_capacity_kwh;
                assert!(
                    e_bat.abs() <= bat_bound,
                    "step {step} lane {lane}: battery moved {e_bat} kWh, rating \
                     allows {bat_bound}"
                );
                let grid_cars = info.energy_grid_net_kwh - e_bat;
                let tol = 1e-3 * (1.0 + de_net.abs());
                if cfg.v2g {
                    // Mixed-sign flows: charging pays 1/η, discharging
                    // returns ·η, so the grid side always sees at least
                    // the delivered energy AND at least de_net/η — the
                    // grid can never come out ahead of the cars.
                    assert!(
                        grid_cars >= de_net - tol,
                        "step {step} lane {lane}: grid {grid_cars} < cars {de_net}"
                    );
                    assert!(
                        grid_cars >= de_net / eta - tol,
                        "step {step} lane {lane}: grid {grid_cars} < cars/η {}",
                        de_net / eta
                    );
                } else {
                    // Charge-only: every car flow is non-negative and the
                    // grid side is exactly delivered/η.
                    assert!(
                        de_net >= -tol,
                        "step {step} lane {lane}: charge-only station discharged \
                         ({de_net} kWh)"
                    );
                    assert!(
                        (grid_cars * eta - de_net).abs() <= tol,
                        "step {step} lane {lane}: grid·η {} != cars {de_net}",
                        grid_cars * eta
                    );
                }
            }
        }
        // One full episode ends exactly at step 288 on every lane.
        assert!(infos.iter().all(|i| i.done), "episode must end at step 288");
    });
}
