//! VectorEnv <-> ScalarEnv equivalence and batching invariants.
//!
//! The headline property: a heterogeneous B=8 batch stepped through
//! `VectorEnv::step_all` is indistinguishable (rewards, observations,
//! step metrics, episode state) from 8 independent `ScalarEnv`s fed the
//! same per-lane seeds and actions — for a full 288-step episode and
//! across thread-shard counts.

use std::sync::Arc;

use chargax::env::scalar::{ScalarEnv, ScenarioTables, StepInfo, STEPS_PER_EPISODE};
use chargax::env::tree::StationConfig;
use chargax::env::vector::VectorEnv;
use chargax::util::rng::Rng;

const TOL: f32 = 1e-5;

/// Four genuinely different synthetic scenarios (traffic level, price
/// level/ratio, reward weights) — the mixed-batch axes of the paper's
/// bundled scenarios without needing exported artifacts.
fn scenario_set() -> Vec<Arc<ScenarioTables>> {
    let mut a = ScenarioTables::synthetic(0.6);
    a.alpha[1] = 0.5; // satisfaction0 penalty on
    let mut b = ScenarioTables::synthetic(1.2);
    b.price_buy.iter_mut().for_each(|x| *x = 0.25);
    b.price_sell_grid.iter_mut().for_each(|x| *x = 0.20);
    let mut c = ScenarioTables::synthetic(2.0);
    c.p_sell = 0.6;
    c.beta = 0.3;
    let d = ScenarioTables::synthetic(1.0);
    vec![Arc::new(a), Arc::new(b), Arc::new(c), Arc::new(d)]
}

fn close(a: f32, b: f32, what: &str, step: usize, lane: usize) {
    assert!(
        (a - b).abs() <= TOL * (1.0 + b.abs()),
        "{what} diverged at step {step} lane {lane}: vector {a} vs scalar {b}"
    );
}

#[test]
fn mixed_batch_matches_independent_scalar_envs_for_an_episode() {
    let b = 8usize;
    let tables = scenario_set();
    let lane_scenario: Vec<usize> = (0..b).map(|j| j % tables.len()).collect();
    let seeds: Vec<u64> = (0..b as u64).map(|j| 0xC0FFEE ^ (j * 7919 + 13)).collect();

    let mut venv = VectorEnv::with_seeds(
        StationConfig::default(),
        tables.clone(),
        lane_scenario.clone(),
        &seeds,
    );
    let mut scalars: Vec<ScalarEnv> = (0..b)
        .map(|j| {
            ScalarEnv::new(
                StationConfig::default(),
                Arc::clone(&tables[lane_scenario[j]]),
                seeds[j],
            )
        })
        .collect();

    let nvec = venv.action_nvec();
    let p = venv.n_ports();
    let d = venv.obs_dim();
    let mut arng = Rng::new(2024);
    let mut actions = vec![0usize; b * p];
    let mut infos = vec![StepInfo::default(); b];
    let mut vobs = vec![0f32; b * d];
    let mut sobs = vec![0f32; d];

    for step in 0..STEPS_PER_EPISODE {
        for (k, a) in actions.iter_mut().enumerate() {
            *a = arng.below(nvec[k % p] as u32) as usize;
        }
        // alternate shard counts to also exercise the threaded path
        venv.step_all_sharded(&actions, &mut infos, [1, 2, 5, 8][step % 4]);

        for (lane, env) in scalars.iter_mut().enumerate() {
            let sinfo = env.step(&actions[lane * p..(lane + 1) * p]);
            let vinfo = &infos[lane];
            close(vinfo.reward, sinfo.reward, "reward", step, lane);
            close(vinfo.profit, sinfo.profit, "profit", step, lane);
            close(
                vinfo.energy_to_cars_kwh,
                sinfo.energy_to_cars_kwh,
                "energy_to_cars_kwh",
                step,
                lane,
            );
            close(
                vinfo.energy_grid_net_kwh,
                sinfo.energy_grid_net_kwh,
                "energy_grid_net_kwh",
                step,
                lane,
            );
            close(vinfo.excess_kw, sinfo.excess_kw, "excess_kw", step, lane);
            close(vinfo.missing_kwh, sinfo.missing_kwh, "missing_kwh", step, lane);
            close(
                vinfo.overtime_steps,
                sinfo.overtime_steps,
                "overtime_steps",
                step,
                lane,
            );
            assert_eq!(vinfo.rejected, sinfo.rejected, "rejected at {step}/{lane}");
            assert_eq!(vinfo.departed, sinfo.departed, "departed at {step}/{lane}");
            assert_eq!(vinfo.arrived, sinfo.arrived, "arrived at {step}/{lane}");
            assert_eq!(vinfo.done, sinfo.done, "done flag at {step}/{lane}");

            close(
                venv.lane_battery_soc(lane),
                env.battery_soc(),
                "battery_soc",
                step,
                lane,
            );
            close(
                venv.lane_ep_return(lane),
                env.ep_return(),
                "ep_return",
                step,
                lane,
            );
        }

        venv.observe_all(&mut vobs);
        for (lane, env) in scalars.iter().enumerate() {
            env.observe(&mut sobs);
            for (k, (&v, &s)) in vobs[lane * d..(lane + 1) * d].iter().zip(&sobs).enumerate()
            {
                assert!(
                    (v - s).abs() <= TOL * (1.0 + s.abs()),
                    "obs[{k}] diverged at step {step} lane {lane}: {v} vs {s}"
                );
            }
        }
    }
    // episode ended: every lane wrapped and reset identically
    for lane in 0..b {
        assert_eq!(venv.lane_t(lane), 0);
        assert_eq!(venv.lane_t(lane), scalars[lane].t());
        assert_eq!(venv.lane_day(lane), scalars[lane].day());
    }
}

#[test]
fn homogeneous_batch_lanes_diverge_from_each_other() {
    // Different per-lane RNG streams: lanes must not be mirror copies.
    let mut venv = VectorEnv::new(
        StationConfig::default(),
        ScenarioTables::synthetic(1.5),
        4,
        99,
    );
    let p = venv.n_ports();
    let mut infos = vec![StepInfo::default(); 4];
    let actions = vec![5usize; 4 * p];
    let mut distinct = false;
    for _ in 0..50 {
        venv.step_all(&actions, &mut infos);
        let r0 = infos[0].reward;
        if infos.iter().skip(1).any(|x| x.reward != r0) {
            distinct = true;
            break;
        }
    }
    assert!(distinct, "all lanes produced identical rewards for 50 steps");
}

#[test]
fn vector_env_respects_node_constraints_under_max_actions() {
    use chargax::env::scalar::{N_LEVELS, N_LEVELS_BATTERY};
    use chargax::env::tree::StationTree;

    let cfg = StationConfig::default();
    let tree = StationTree::standard(&cfg);
    let mut venv = VectorEnv::new(cfg, ScenarioTables::synthetic(2.0), 16, 5);
    let c = venv.n_chargers();
    let p = venv.n_ports();
    let mut actions = vec![N_LEVELS - 1; 16 * p];
    for lane in 0..16 {
        actions[lane * p + c] = (N_LEVELS_BATTERY - 1) / 2;
    }
    let mut infos = vec![StepInfo::default(); 16];
    for _ in 0..200 {
        venv.step_all(&actions, &mut infos);
        for lane in 0..16 {
            let i_drawn = venv.lane_i_drawn(lane);
            for n in 0..tree.n_nodes() {
                let mut flow = 0f32;
                for j in 0..p {
                    if tree.membership[n][j] {
                        flow += tree.volt[j] * i_drawn[j] / 1000.0;
                    }
                }
                assert!(
                    flow.abs() / tree.node_eta[n] <= tree.node_limit[n] + 1e-2,
                    "lane {lane} node {n} overloaded: {flow}"
                );
            }
        }
    }
}
