//! Persistent worker-pool runtime equivalence + transition-core bugfix
//! regressions (day-boundary price wrap, zero-capacity battery, full
//! episode NaN/SoC invariants).

use std::sync::Arc;

use chargax::baselines::ppo::Learner;
use chargax::env::scalar::{ScalarEnv, ScenarioTables, StepInfo, STEPS_PER_EPISODE};
use chargax::env::tree::StationConfig;
use chargax::env::vector::{PolicyRollout, RolloutBuffers, VectorEnv};
use chargax::util::prop::Prop;
use chargax::util::rng::Rng;

fn random_actions(rng: &mut Rng, env: &VectorEnv) -> Vec<usize> {
    let nvec = env.action_nvec();
    (0..env.batch())
        .flat_map(|_| nvec.iter().map(|&n| rng.below(n as u32) as usize).collect::<Vec<_>>())
        .collect()
}

/// A battery-less station (capacity 0 AND power 0 — the only legal way to
/// express "no battery", enforced by `StationConfig::validate`).
fn batteryless() -> StationConfig {
    StationConfig {
        battery_capacity_kwh: 0.0,
        battery_p_max_kw: 0.0,
        ..StationConfig::default()
    }
}

/// The pool runtime must match the scoped-thread oracle bit-for-bit per
/// lane, across a mix of batch sizes and shard counts.
#[test]
fn pool_matches_scoped_oracle_at_mixed_batch_sizes() {
    for &b in &[1usize, 3, 64, 130] {
        let tables = Arc::new(ScenarioTables::synthetic(1.5));
        let mut pooled = VectorEnv::new(StationConfig::default(), Arc::clone(&tables), b, 42);
        pooled.set_threads(4);
        let mut scoped = VectorEnv::new(StationConfig::default(), Arc::clone(&tables), b, 42);
        let mut arng = Rng::new(b as u64 + 1);
        let mut pi = vec![StepInfo::default(); b];
        let mut si = vec![StepInfo::default(); b];
        for step in 0..60 {
            let actions = random_actions(&mut arng, &pooled);
            let shards = [1usize, 2, 3, 4][step % 4].min(b);
            pooled.step_all_pooled(&actions, &mut pi, shards);
            scoped.step_all_sharded(&actions, &mut si, shards);
            for lane in 0..b {
                assert_eq!(
                    pi[lane].reward, si[lane].reward,
                    "B={b} step {step} lane {lane}: pool diverged from scoped oracle"
                );
                assert_eq!(pi[lane].profit, si[lane].profit, "B={b} step {step} lane {lane}");
                assert_eq!(pi[lane].arrived, si[lane].arrived, "B={b} step {step} lane {lane}");
                assert_eq!(pi[lane].done, si[lane].done, "B={b} step {step} lane {lane}");
            }
        }
        let d = pooled.obs_dim();
        let mut po = vec![0f32; b * d];
        let mut so = vec![0f32; b * d];
        pooled.observe_all(&mut po);
        scoped.observe_all(&mut so);
        assert_eq!(po, so, "B={b}: observations diverged");
    }
}

/// Property: a full 288-step episode under random actions never produces
/// a NaN observation or an out-of-[0,1] SoC (car or battery) — for the
/// default station and for the battery-less (capacity 0) variant that
/// used to NaN-poison `battery_soc`.
#[test]
fn full_episode_soc_and_obs_stay_finite_and_bounded() {
    for cfg in [StationConfig::default(), batteryless()] {
        Prop::new(4).check("episode-soc-obs-invariants", |rng| {
            let b = 4usize;
            let seed = rng.next_u64();
            let mut env =
                VectorEnv::new(cfg.clone(), ScenarioTables::synthetic(1.5), b, seed);
            let mut arng = Rng::new(seed ^ 0xA5A5);
            let d = env.obs_dim();
            let mut infos = vec![StepInfo::default(); b];
            let mut obs = vec![0f32; b * d];
            for step in 0..STEPS_PER_EPISODE {
                let actions = random_actions(&mut arng, &env);
                env.step_all(&actions, &mut infos);
                env.observe_all(&mut obs);
                for (k, &x) in obs.iter().enumerate() {
                    assert!(x.is_finite(), "obs[{k}] = {x} at step {step}");
                }
                for lane in 0..b {
                    assert!(infos[lane].reward.is_finite(), "reward NaN at step {step}");
                    let bs = env.lane_battery_soc(lane);
                    assert!(
                        (0.0..=1.0).contains(&bs),
                        "battery_soc {bs} out of [0,1] at step {step}"
                    );
                    for slot in 0..env.n_chargers() {
                        if let Some(car) = env.lane_car(lane, slot) {
                            assert!(
                                (0.0..=1.0).contains(&car.soc),
                                "car soc {} out of [0,1] at step {step}",
                                car.soc
                            );
                        }
                    }
                }
            }
        });
    }
}

/// Regression: in the last hour of the day the observed "next-hour price"
/// must wrap to hour 0 of the next day (mod n_days), not repeat hour 23.
#[test]
fn next_hour_price_observation_wraps_at_midnight() {
    let price = |h: usize| 0.10f32 + 0.01 * h as f32;
    let mut tables = ScenarioTables::synthetic(0.0); // traffic 0: deterministic
    tables.n_days = 1; // the drawn day is always 0; "next day" wraps to 0
    tables.price_buy = (0..24).map(price).collect();
    let cfg = StationConfig::default();
    let c = cfg.n_chargers();
    let mut env = ScalarEnv::new(cfg, tables, 17);
    let mut obs = vec![0f32; env.obs_dim()];
    let action = vec![0usize; env.n_ports()];
    let b = 6 * c;

    // hour 0: next price is hour 1 of the same day.
    env.observe(&mut obs);
    assert_eq!(obs[b + 7], price(0), "current price at hour 0");
    assert_eq!(obs[b + 8], price(1), "next price at hour 0");

    // step into the last hour of the day (t in 276..288 -> hour 23).
    for _ in 0..276 {
        env.step(&action);
    }
    assert_eq!(env.t(), 276);
    env.observe(&mut obs);
    assert_eq!(obs[b + 7], price(23), "current price at hour 23");
    assert_eq!(
        obs[b + 8],
        price(0),
        "next price at hour 23 must be hour 0 of the next day, not hour 23 again"
    );
}

/// A "real" battery port (positive power) with zero capacity is a config
/// error caught at construction instead of NaN at runtime.
#[test]
#[should_panic(expected = "invalid StationConfig")]
fn powered_battery_with_zero_capacity_is_rejected() {
    let bad = StationConfig { battery_capacity_kwh: 0.0, ..StationConfig::default() };
    let _ = VectorEnv::new(bad, ScenarioTables::synthetic(1.0), 1, 0);
}

/// The battery-less station keeps its (unused) battery SoC pinned at 0 and
/// never moves grid energy through the battery port.
#[test]
fn batteryless_station_runs_a_full_episode() {
    let mut env = VectorEnv::new(batteryless(), ScenarioTables::synthetic(1.0), 2, 9);
    let mut arng = Rng::new(10);
    let mut infos = vec![StepInfo::default(); 2];
    for _ in 0..STEPS_PER_EPISODE {
        let actions = random_actions(&mut arng, &env);
        env.step_all(&actions, &mut infos);
        for lane in 0..2 {
            assert_eq!(env.lane_battery_soc(lane), 0.0);
            let p = env.n_ports();
            assert_eq!(env.lane_i_drawn(lane)[p - 1], 0.0, "battery port must stay idle");
        }
    }
}

/// The fused rollout fills PPO buffers identically to the step-then-observe
/// loop it replaces, across an episode boundary. B = 128 with a 4-wide
/// pool so the rollout's *sharded* path (auto_shards > 1) is exercised
/// regardless of the host's core count.
#[test]
fn fused_rollout_buffers_match_manual_loop_across_episode_boundary() {
    let b = 128usize;
    let t_len = STEPS_PER_EPISODE + 10; // cross the reset
    let tables = Arc::new(ScenarioTables::synthetic(1.2));
    let mut rolled = VectorEnv::new(StationConfig::default(), Arc::clone(&tables), b, 77);
    rolled.set_threads(4);
    let mut stepped = VectorEnv::new(StationConfig::default(), Arc::clone(&tables), b, 77);
    stepped.set_threads(4);
    let p = rolled.n_ports();
    let d = rolled.obs_dim();

    let mut arng = Rng::new(5);
    let per_step: Vec<Vec<usize>> =
        (0..t_len).map(|_| random_actions(&mut arng, &rolled)).collect();

    let mut obs = vec![0f32; (t_len + 1) * b * d];
    let mut rewards = vec![0f32; t_len * b];
    let mut dones = vec![0f32; t_len * b];
    let mut profits = vec![0f32; t_len * b];
    {
        let mut bufs = RolloutBuffers {
            obs: &mut obs,
            rewards: &mut rewards,
            dones: &mut dones,
            profits: &mut profits,
        };
        rolled.rollout(t_len, &mut bufs, |t, _obs, actions| {
            actions.copy_from_slice(&per_step[t]);
        });
    }
    assert_eq!(p, per_step[0].len() / b);

    let mut infos = vec![StepInfo::default(); b];
    let mut want = vec![0f32; b * d];
    let mut saw_done = false;
    stepped.observe_all(&mut want);
    assert_eq!(&obs[..b * d], want.as_slice());
    for (t, actions) in per_step.iter().enumerate() {
        stepped.step_all(actions, &mut infos);
        for lane in 0..b {
            assert_eq!(rewards[t * b + lane], infos[lane].reward, "t={t} lane {lane}");
            assert_eq!(
                dones[t * b + lane],
                infos[lane].done as i32 as f32,
                "t={t} lane {lane}"
            );
            saw_done |= infos[lane].done;
        }
        stepped.observe_all(&mut want);
        assert_eq!(&obs[(t + 1) * b * d..(t + 2) * b * d], want.as_slice(), "obs row {}", t + 1);
    }
    assert!(saw_done, "rollout must have crossed an episode boundary");
}

/// Everything one fused-policy rollout produces, for bitwise comparison.
struct FusedRun {
    obs: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
    profits: Vec<f32>,
    actions: Vec<usize>,
    logp: Vec<f32>,
    values: Vec<f32>,
}

/// One fused-policy rollout on a fresh env/learner pair built from fixed
/// seeds (so every call sees identical weights and lane streams).
fn fused_run(threads: usize, greedy: bool, b: usize, t_len: usize) -> FusedRun {
    let tables = Arc::new(ScenarioTables::synthetic(1.3));
    let mut env = VectorEnv::new(StationConfig::default(), tables, b, 55);
    env.set_threads(threads);
    let (p, d) = (env.n_ports(), env.obs_dim());
    let mut lrng = Rng::new(7);
    let learner = Learner::new(&mut lrng, d, 32, env.action_nvec());
    let mut run = FusedRun {
        obs: vec![0.0; (t_len + 1) * b * d],
        rewards: vec![0.0; t_len * b],
        dones: vec![0.0; t_len * b],
        profits: vec![0.0; t_len * b],
        actions: vec![0usize; t_len * b * p],
        logp: vec![0.0; t_len * b],
        values: vec![0.0; t_len * b],
    };
    let mut bufs = RolloutBuffers {
        obs: &mut run.obs,
        rewards: &mut run.rewards,
        dones: &mut run.dones,
        profits: &mut run.profits,
    };
    let mut pol = PolicyRollout {
        actions: &mut run.actions,
        logp: &mut run.logp,
        values: &mut run.values,
    };
    env.rollout_fused(t_len, &mut bufs, &mut pol, &learner, 0xABCD, greedy);
    run
}

/// ISSUE 4 tentpole invariance: the fused-policy rollout (policy forward
/// + sampling INSIDE the shard tasks) must be bit-identical across
/// `--threads` {1, 4, max}. Per-(lane, t) counter sampling means shard
/// placement cannot perturb the action stream; B=96 keeps the batch above
/// the sharding threshold so threads=4/max actually shard.
#[test]
fn fused_policy_rollout_is_thread_count_invariant() {
    let (b, t_len) = (96usize, 40usize);
    let max_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for greedy in [false, true] {
        let want = fused_run(1, greedy, b, t_len);
        for threads in [4usize, max_threads] {
            let got = fused_run(threads, greedy, b, t_len);
            assert_eq!(got.actions, want.actions, "threads={threads} greedy={greedy}: actions");
            assert_eq!(got.obs, want.obs, "threads={threads} greedy={greedy}: observations");
            assert_eq!(got.rewards, want.rewards, "threads={threads} greedy={greedy}: rewards");
            assert_eq!(got.dones, want.dones, "threads={threads} greedy={greedy}: dones");
            assert_eq!(got.profits, want.profits, "threads={threads} greedy={greedy}: profits");
            assert_eq!(got.logp, want.logp, "threads={threads} greedy={greedy}: logp");
            assert_eq!(got.values, want.values, "threads={threads} greedy={greedy}: values");
        }
    }
}

/// ISSUE 6 re-proof at B=4096 with the blocked kernels on: the paper's
/// headline batch size, where shard lane blocks are large enough to hit
/// every kernel path (full 4-row tiles, 8-wide column tiles, remainders).
/// Short horizon keeps the buffers small; the invariance claim is the
/// same — kernel accumulation order depends on fixed tile widths only,
/// never on `--threads`.
#[test]
fn fused_policy_rollout_is_thread_count_invariant_at_b4096() {
    let (b, t_len) = (4096usize, 3usize);
    let max_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let want = fused_run(1, false, b, t_len);
    for threads in [4usize, max_threads] {
        let got = fused_run(threads, false, b, t_len);
        assert_eq!(got.actions, want.actions, "threads={threads}: actions");
        assert_eq!(got.obs, want.obs, "threads={threads}: observations");
        assert_eq!(got.rewards, want.rewards, "threads={threads}: rewards");
        assert_eq!(got.logp, want.logp, "threads={threads}: logp");
        assert_eq!(got.values, want.values, "threads={threads}: values");
    }
}

/// The fused-policy rollout agrees with a manual loop that replays the
/// recorded actions through `step_all` — the policy moved into the shards
/// must not change what the env computes.
#[test]
fn fused_policy_rollout_matches_replayed_actions() {
    let (b, t_len) = (8usize, 50usize);
    let run = fused_run(3, false, b, t_len);
    let tables = Arc::new(ScenarioTables::synthetic(1.3));
    let mut env = VectorEnv::new(StationConfig::default(), tables, b, 55);
    let (p, d) = (env.n_ports(), env.obs_dim());
    let mut infos = vec![StepInfo::default(); b];
    let mut want_obs = vec![0f32; b * d];
    env.observe_all(&mut want_obs);
    assert_eq!(&run.obs[..b * d], want_obs.as_slice(), "row 0");
    for t in 0..t_len {
        env.step_all(&run.actions[t * b * p..(t + 1) * b * p], &mut infos);
        for lane in 0..b {
            assert_eq!(run.rewards[t * b + lane], infos[lane].reward, "t={t} lane {lane}");
            assert_eq!(
                run.dones[t * b + lane],
                infos[lane].done as i32 as f32,
                "t={t} lane {lane}"
            );
        }
        env.observe_all(&mut want_obs);
        assert_eq!(
            &run.obs[(t + 1) * b * d..(t + 2) * b * d],
            want_obs.as_slice(),
            "obs row {}",
            t + 1
        );
    }
}
