//! V2G semantics: energy conservation across a charge→discharge cycle,
//! degradation penalties on the discharge leg, and agreement with the
//! per-step python comparator (`python/baselines/gym_env.py`) over a full
//! 288-step V2G episode.
//!
//! All rust-side stepping goes through the public transition core
//! (`env::core::step_lane` over a hand-built `LaneView`) with traffic 0,
//! so there are no arrivals and no RNG draws — both sides are exactly
//! deterministic and comparable.

use chargax::env::core::{
    self, LaneView, ScenarioTables, Scratch, StepInfo, N_LEVELS_BATTERY, N_LEVELS_V2G,
};
use chargax::env::tree::{StationConfig, StationTree};
use chargax::util::rng::CounterRng;

/// Flat per-lane state backing a hand-built `LaneView` (the integration
/// mirror of core.rs's test-local helper).
struct Lane {
    t: u32,
    day: u32,
    battery_soc: f32,
    ep_return: f32,
    ep_profit: f32,
    present: Vec<bool>,
    soc: Vec<f32>,
    de_remain: Vec<f32>,
    dt_remain: Vec<f32>,
    cap: Vec<f32>,
    r_bar: Vec<f32>,
    tau: Vec<f32>,
    sensitive: Vec<bool>,
    i_drawn: Vec<f32>,
}

impl Lane {
    fn empty(cfg: &StationConfig) -> Lane {
        let (c, p) = (cfg.n_chargers(), cfg.n_ports());
        Lane {
            t: 0,
            day: 0,
            battery_soc: cfg.battery_soc0,
            ep_return: 0.0,
            ep_profit: 0.0,
            present: vec![false; c],
            soc: vec![0.0; c],
            de_remain: vec![0.0; c],
            dt_remain: vec![0.0; c],
            cap: vec![60.0; c],
            r_bar: vec![50.0; c],
            tau: vec![0.8; c],
            sensitive: vec![false; c],
            i_drawn: vec![0.0; p],
        }
    }

    fn park(&mut self, slot: usize, soc: f32, cap: f32, r_bar: f32, tau: f32) {
        self.present[slot] = true;
        self.soc[slot] = soc;
        self.cap[slot] = cap;
        self.r_bar[slot] = r_bar;
        self.tau[slot] = tau;
        self.de_remain[slot] = (0.8 - soc).max(0.0) * cap;
        self.dt_remain[slot] = 1e6; // stays the whole episode
        self.sensitive[slot] = false;
    }

    fn view(&mut self) -> LaneView<'_> {
        LaneView {
            t: &mut self.t,
            day: &mut self.day,
            battery_soc: &mut self.battery_soc,
            ep_return: &mut self.ep_return,
            ep_profit: &mut self.ep_profit,
            present: &mut self.present,
            soc: &mut self.soc,
            de_remain: &mut self.de_remain,
            dt_remain: &mut self.dt_remain,
            cap: &mut self.cap,
            r_bar: &mut self.r_bar,
            tau: &mut self.tau,
            sensitive: &mut self.sensitive,
            i_drawn: &mut self.i_drawn,
        }
    }
}

/// No-arrival synthetic tables (traffic 0) with the penalty weights the
/// test chooses.
fn quiet_tables(alpha: [f32; 7]) -> ScenarioTables {
    let mut t = ScenarioTables::synthetic(0.0);
    t.alpha = alpha;
    t
}

const IDLE_BAT: usize = (N_LEVELS_BATTERY - 1) / 2;

/// `CHARGAX_REQUIRE_PARITY=1` (set by the dedicated CI parity job, which
/// provisions python3 + numpy) turns the python-comparator skip paths
/// into hard failures — so the parity half can never silently stop
/// running on the one job that exists to run it.
fn parity_required() -> bool {
    std::env::var("CHARGAX_REQUIRE_PARITY").map(|v| v == "1").unwrap_or(false)
}

/// Skip (default) or fail (parity job) a python-comparator half.
fn skip_or_fail(why: &str) {
    if parity_required() {
        panic!("CHARGAX_REQUIRE_PARITY=1 but the python comparator did not run: {why}");
    }
    eprintln!("SKIP v2g python parity: {why}");
}

fn step(
    lane: &mut Lane,
    rng: &mut CounterRng,
    cfg: &StationConfig,
    tree: &StationTree,
    tables: &ScenarioTables,
    action: &[usize],
    scratch: &mut Scratch,
) -> StepInfo {
    core::step_lane(&mut lane.view(), rng, cfg, tree, tables, action, scratch)
}

/// Drive a full charge→discharge cycle at one V2G car port with the
/// battery idle. Returns per-leg sums:
/// (delivered kWh, discharged kWh, grid bought kWh, grid returned kWh,
/// discharge-leg reward sum, end SoC).
fn run_cycle(alpha: [f32; 7]) -> (f32, f32, f32, f32, f32, f32) {
    let cfg = StationConfig { v2g: true, ..StationConfig::default() };
    let tree = StationTree::standard(&cfg);
    let tables = quiet_tables(alpha);
    let mut rng = CounterRng::new(7);
    let mut scratch = Scratch::new(cfg.n_ports());
    let c = cfg.n_chargers();
    let mut lane = Lane::empty(&cfg);
    lane.park(0, 0.2, 60.0, 120.0, 0.8);
    let mut action = vec![0usize; cfg.n_ports()];
    action[c] = IDLE_BAT;

    let (mut de_ch, mut de_dis) = (0f32, 0f32);
    let (mut grid_buy, mut grid_ret) = (0f32, 0f32);
    let mut reward_dis = 0f32;

    action[0] = N_LEVELS_V2G - 1; // +100%: charge
    let mut steps = 0;
    while lane.soc[0] < 0.999 && steps < 100 {
        let info = step(&mut lane, &mut rng, &cfg, &tree, &tables, &action, &mut scratch);
        assert!(info.energy_to_cars_kwh >= 0.0, "charge leg must not discharge");
        de_ch += info.energy_to_cars_kwh;
        grid_buy += info.energy_grid_net_kwh;
        steps += 1;
    }
    assert!(lane.soc[0] > 0.99, "car never filled (soc {})", lane.soc[0]);

    action[0] = 0; // -100%: discharge
    while lane.soc[0] > 0.2 && steps < 250 {
        let info = step(&mut lane, &mut rng, &cfg, &tree, &tables, &action, &mut scratch);
        assert!(info.energy_to_cars_kwh <= 0.0, "discharge leg must not charge");
        de_dis += -info.energy_to_cars_kwh;
        grid_ret += -info.energy_grid_net_kwh;
        reward_dis += info.reward;
        steps += 1;
    }
    assert!(
        steps < 250 && (lane.t as usize) < core::STEPS_PER_EPISODE,
        "cycle must finish inside one episode ({steps} steps)"
    );
    (de_ch, de_dis, grid_buy, grid_ret, reward_dis, lane.soc[0])
}

/// Energy books balance over a full cycle: SoC accounting is exact, and
/// the grid sees the round trip through the port efficiency twice
/// (buy = delivered/η on the way in, return = discharged·η on the way
/// out ⇒ return/buy = η² · discharged/delivered).
#[test]
fn v2g_cycle_conserves_energy_within_round_trip_losses() {
    let (de_ch, de_dis, grid_buy, grid_ret, _r, soc_end) = run_cycle([0.0; 7]);
    let cap = 60.0f32;
    // Net energy into the car equals its SoC change.
    let net = de_ch - de_dis;
    let want = (soc_end - 0.2) * cap;
    assert!(
        (net - want).abs() < 1e-2,
        "net {net} kWh vs SoC-implied {want} kWh"
    );
    assert!(de_ch >= 48.0 * 0.99, "full charge from 0.2 delivers ~48 kWh, got {de_ch}");
    // Round-trip grid efficiency: port η = 0.95 applied on both legs.
    let eta = 0.95f32;
    let got = grid_ret / grid_buy;
    let want = eta * eta * de_dis / de_ch;
    assert!(
        (got - want).abs() < 1e-3,
        "grid round-trip ratio {got} vs η²-implied {want}"
    );
    assert!(got < 1.0, "the grid must not gain energy from a V2G round trip");
}

/// The degradation penalty (α_degradation) bites exactly the discharged
/// kWh on the discharge leg: identical cycle with the weight on loses
/// α·de_dis of reward relative to the weight off, and nothing on the
/// charge leg.
#[test]
fn v2g_discharge_leg_pays_degradation_penalty() {
    let (de_ch0, de_dis0, _, _, r_dis0, _) = run_cycle([0.0; 7]);
    let alpha_deg = 0.7f32;
    let mut alpha = [0.0f32; 7];
    alpha[5] = alpha_deg; // "degradation" (data::PENALTIES[5])
    let (de_ch1, de_dis1, _, _, r_dis1, _) = run_cycle(alpha);
    // Deterministic setting: both runs traverse the same trajectory.
    assert!((de_ch0 - de_ch1).abs() < 1e-5);
    assert!((de_dis0 - de_dis1).abs() < 1e-5);
    let lost = r_dis0 - r_dis1;
    let want = alpha_deg * de_dis0;
    assert!(
        (lost - want).abs() < 1e-2 * (1.0 + want.abs()),
        "discharge-leg reward delta {lost} vs α·discharged {want}"
    );
}

/// 288-step V2G episode agreement with the python per-step comparator:
/// same hand-parked cars, same scripted signed actions, per-step rewards
/// match within float32 tolerance. Skips (loudly) when python/numpy are
/// unavailable; the dedicated CI `gym-parity` job provisions them and
/// sets `CHARGAX_REQUIRE_PARITY=1` so the skip becomes a failure there.
#[test]
fn v2g_episode_matches_python_gym_comparator() {
    let cfg = StationConfig { v2g: true, ..StationConfig::default() };
    let tree = StationTree::standard(&cfg);
    let c = cfg.n_chargers();
    let p = cfg.n_ports();

    // Hour-varying prices/moer so the reward path is exercised, one day,
    // no arrivals; every penalty weight on.
    let mut tables = quiet_tables([0.3, 0.5, 0.4, 0.2, 0.1, 0.7, 0.05]);
    tables.n_days = 1;
    tables.price_buy = (0..24).map(|h| 0.05 + 0.01 * h as f32).collect();
    tables.price_sell_grid = tables.price_buy.iter().map(|x| x * 0.9).collect();
    tables.moer = (0..24).map(|h| 0.2 + 0.01 * h as f32).collect();

    let mut lane = Lane::empty(&cfg);
    lane.park(0, 0.3, 60.0, 120.0, 0.6); // DC slot
    lane.park(10, 0.9, 40.0, 11.0, 0.7); // first AC slot
    let mut rng = CounterRng::new(1);
    let mut scratch = Scratch::new(p);
    let nvec = core::action_nvec(&cfg);
    let mut rewards = Vec::with_capacity(288);
    let mut mid_socs = (0f32, 0f32, 0f32);
    for t in 0..288usize {
        let mut action = vec![0usize; p];
        for (j, a) in action.iter_mut().enumerate().take(c) {
            *a = (t * 7 + j * 3) % nvec[j];
        }
        action[c] = (t * 5 + 1) % nvec[c];
        let info = step(&mut lane, &mut rng, &cfg, &tree, &tables, &action, &mut scratch);
        rewards.push(info.reward);
        if t == 143 {
            mid_socs = (lane.soc[0], lane.soc[10], lane.battery_soc);
        }
    }

    let python_dir = format!("{}/../python", env!("CARGO_MANIFEST_DIR"));
    let script = r#"
import json, sys
from baselines.gym_env import Car, GymChargingEnv

h = [0.05 + 0.01 * i for i in range(24)]
tables = {
    "price_buy": h,
    "price_sell_grid": [x * 0.9 for x in h],
    "moer": [0.2 + 0.01 * i for i in range(24)],
    "arrival_rate": [3.0] * 24,
    "car_table": [[60.0, 11.0, 120.0, 0.6]],
    "car_weights": [1.0],
    "user_profile": [1.5, 0.6, 2.5, 3.0, 0.8, 0.65],
    "alpha": [0.3, 0.5, 0.4, 0.2, 0.1, 0.7, 0.05],
    "beta": 0.1,
    "p_sell": 0.75,
    "traffic": 0.0,
    "n_days": 1,
}
env = GymChargingEnv(tables, seed=0, v2g=True)
env.t = 0
env.day = 0
env.evses[0].car = Car(soc=0.3, de_remain=(0.8 - 0.3) * 60.0, dt_remain=1e6,
                       cap=60.0, r_bar=120.0, tau=0.6, charge_sensitive=False)
env.evses[10].car = Car(soc=0.9, de_remain=0.0, dt_remain=1e6,
                        cap=40.0, r_bar=11.0, tau=0.7, charge_sensitive=False)
nv = env.action_nvec()
rewards = []
mid = None
for t in range(288):
    a = [(t * 7 + j * 3) % nv[j] for j in range(len(env.evses))]
    a.append((t * 5 + 1) % nv[-1])
    obs, r, done, info = env.step(a)
    rewards.append(r)
    if t == 143:
        mid = [env.evses[0].car.soc, env.evses[10].car.soc, env.battery.soc]
print(json.dumps({"rewards": rewards, "mid": mid}))
"#;
    let output = std::process::Command::new("python3")
        .args(["-c", script])
        .current_dir(&python_dir)
        .output();
    let output = match output {
        Ok(o) if o.status.success() => o,
        Ok(o) => {
            skip_or_fail(&format!(
                "python exited nonzero:\n{}",
                String::from_utf8_lossy(&o.stderr)
            ));
            return;
        }
        Err(e) => {
            skip_or_fail(&format!("cannot spawn python3: {e}"));
            return;
        }
    };
    let text = String::from_utf8_lossy(&output.stdout);
    let j = chargax::util::json::Json::parse(text.trim()).expect("python JSON output");
    let py_rewards: Vec<f32> =
        j.get("rewards").and_then(|x| x.as_f32_flat()).expect("rewards array");
    let py_mid: Vec<f32> = j.get("mid").and_then(|x| x.as_f32_flat()).expect("mid socs");
    assert_eq!(py_rewards.len(), rewards.len());
    for (t, (rs, py)) in rewards.iter().zip(&py_rewards).enumerate() {
        assert!(
            (rs - py).abs() < 2e-3 * (1.0 + py.abs()),
            "step {t}: rust reward {rs} vs python {py}"
        );
    }
    let (s0, s10, sb) = mid_socs;
    assert!((s0 - py_mid[0]).abs() < 1e-3, "DC car SoC {s0} vs {}", py_mid[0]);
    assert!((s10 - py_mid[1]).abs() < 1e-3, "AC car SoC {s10} vs {}", py_mid[1]);
    assert!((sb - py_mid[2]).abs() < 1e-3, "battery SoC {sb} vs {}", py_mid[2]);
}
