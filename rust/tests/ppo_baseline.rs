//! The pure-Rust PPO comparator: learning signal + invariants — and the
//! ISSUE 5 bitwise-determinism contract of the shard-parallel update
//! (`update_sharded` == serial `update`, for any pool width, odd or even
//! minibatch sizes, single learner or a pooled multi-family dispatch).

use chargax::baselines::ppo::{
    update_sharded_many, Learner, PpoParams, PpoTrainer, UpdateBatch,
};
use chargax::env::scalar::ScenarioTables;
use chargax::env::tree::StationConfig;
use chargax::env::vector::{PolicyRollout, RolloutBuffers, VectorEnv};
use chargax::runtime::pool::WorkerPool;
use chargax::util::rng::Rng;

fn tables() -> ScenarioTables {
    ScenarioTables {
        price_buy: vec![0.10; 365 * 24],
        price_sell_grid: vec![0.09; 365 * 24],
        moer: vec![0.3; 365 * 24],
        arrival_rate: vec![4.0; 24],
        car_table: vec![60.0, 11.0, 120.0, 0.6, 90.0, 11.0, 200.0, 0.5],
        car_weights: vec![0.6, 0.4],
        user_profile: vec![1.5, 0.6, 2.5, 3.0, 0.8, 0.65],
        n_days: 365,
        alpha: [0.0; 7],
        beta: 0.1,
        p_sell: 0.75,
        traffic: 1.5,
    }
}

#[test]
fn ppo_iteration_produces_finite_stats() {
    let params = PpoParams {
        num_envs: 2,
        rollout_steps: 32,
        n_minibatches: 2,
        update_epochs: 2,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 3);
    let s = tr.iteration();
    assert!(s.mean_reward.is_finite());
    assert!(s.total_loss.is_finite());
    assert!(s.entropy > 0.0);
    assert_eq!(tr.env_steps, 64);
}

#[test]
fn ppo_learns_on_fixed_price_world() {
    // With flat prices and profit-only reward, charging more = more profit;
    // PPO should push mean reward up. Single-iteration rewards are noisy
    // (Poisson arrivals), so compare 5-iteration windows over a longer run.
    let params = PpoParams {
        num_envs: 4,
        rollout_steps: 144,
        n_minibatches: 4,
        update_epochs: 4,
        lr: 1e-3,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 5);
    let rewards: Vec<f32> = (0..40).map(|_| tr.iteration().mean_reward).collect();
    let head: f32 = rewards[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = rewards[35..].iter().sum::<f32>() / 5.0;
    assert!(
        tail > head + 0.05,
        "no learning signal: head {head}, tail {tail} ({rewards:?})"
    );
}

#[test]
fn ppo_entropy_decreases_as_policy_sharpens() {
    let params = PpoParams {
        num_envs: 2,
        rollout_steps: 96,
        lr: 1e-3,
        ent_coef: 0.0,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 6);
    let e0 = tr.iteration().entropy;
    let mut e_last = e0;
    for _ in 0..10 {
        e_last = tr.iteration().entropy;
    }
    assert!(e_last < e0, "entropy should shrink: {e0} -> {e_last}");
}

#[test]
fn greedy_eval_runs_full_episode() {
    let params = PpoParams {
        num_envs: 1,
        rollout_steps: 16,
        n_minibatches: 2,
        update_epochs: 1,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 7);
    tr.iteration();
    let (r, p) = tr.eval_episode(99);
    assert!(r.is_finite() && p.is_finite());
}

/// One family's filled rollout buffers (the env-written + policy-written
/// halves of one fused pass). Kept separate from the `Learner` so tests
/// can borrow the buffers immutably while updating the learner.
struct Bufs {
    n_envs: usize,
    t_len: usize,
    obs: Vec<f32>,
    act: Vec<usize>,
    logp: Vec<f32>,
    val: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<f32>,
}

impl Bufs {
    fn batch(&self) -> UpdateBatch<'_> {
        UpdateBatch {
            n_envs: self.n_envs,
            t_len: self.t_len,
            obs: &self.obs,
            act: &self.act,
            logp: &self.logp,
            val: &self.val,
            rew: &self.rew,
            done: &self.done,
        }
    }
}

/// Deterministic PPO fixture: a learner plus the buffers one fused
/// rollout fills. Rebuilding with the same arguments yields bit-identical
/// weights AND buffers, so every execution path under test starts from
/// exactly the same state.
fn fixture(cfg: StationConfig, n_envs: usize, t_len: usize, seed: u64) -> (Learner, Bufs) {
    let mut venv = VectorEnv::new(cfg, tables(), n_envs, seed);
    let (d, p) = (venv.obs_dim(), venv.n_ports());
    let mut lrng = Rng::new(seed ^ 0xABCD);
    let learner = Learner::new(&mut lrng, d, 16, venv.action_nvec());
    let bsz = n_envs * t_len;
    let mut b = Bufs {
        n_envs,
        t_len,
        obs: vec![0.0; (t_len + 1) * n_envs * d],
        act: vec![0; bsz * p],
        logp: vec![0.0; bsz],
        val: vec![0.0; bsz],
        rew: vec![0.0; bsz],
        done: vec![0.0; bsz],
    };
    let mut profits = vec![0f32; bsz];
    let mut bufs = RolloutBuffers {
        obs: &mut b.obs,
        rewards: &mut b.rew,
        dones: &mut b.done,
        profits: &mut profits,
    };
    let mut pol = PolicyRollout { actions: &mut b.act, logp: &mut b.logp, values: &mut b.val };
    venv.rollout_fused(t_len, &mut bufs, &mut pol, &learner, seed ^ 7, false);
    (learner, b)
}

fn weights(l: &Learner) -> Vec<Vec<f32>> {
    l.mlp.params().into_iter().cloned().collect()
}

/// Acceptance gate (ISSUE 5): `update_sharded` is bit-identical to the
/// serial `update` and invariant across pool widths {1, 4, max}, for both
/// even (192) and odd (135) batch sizes — two consecutive updates per
/// path so Adam's moment state is covered too.
#[test]
fn update_sharded_is_bit_identical_to_serial_for_any_pool_width() {
    let hp = PpoParams { n_minibatches: 2, update_epochs: 2, hidden: 16, ..Default::default() };
    let max_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    // (even bsz 4*48=192 -> 96-row minibatches; odd bsz 5*27=135 -> 67/68)
    for (n_envs, t_len) in [(4usize, 48usize), (5, 27)] {
        // Serial reference: Learner::update (pool-free entry point).
        let (mut l0, b0) = fixture(StationConfig::default(), n_envs, t_len, 21);
        let mut rng0 = Rng::new(11);
        let mut stats0 = Vec::new();
        for _ in 0..2 {
            stats0.push(l0.update(
                &hp, &mut rng0, n_envs, t_len,
                &b0.obs, &b0.act, &b0.logp, &b0.val, &b0.rew, &b0.done,
            ));
        }
        let w0 = weights(&l0);
        assert!(stats0.iter().all(|(l, e)| l.is_finite() && e.is_finite()));
        for threads in [1usize, 4, max_threads] {
            let (mut l, b) = fixture(StationConfig::default(), n_envs, t_len, 21);
            let pool = WorkerPool::new(threads);
            let mut rng = Rng::new(11);
            let mut stats = Vec::new();
            for _ in 0..2 {
                stats.push(l.update_sharded(
                    &hp, &mut rng, Some(&pool), n_envs, t_len,
                    &b.obs, &b.act, &b.logp, &b.val, &b.rew, &b.done,
                ));
            }
            assert_eq!(stats, stats0, "bsz {} threads {threads}: stats drifted", n_envs * t_len);
            for (k, (a, want)) in weights(&l).iter().zip(&w0).enumerate() {
                assert_eq!(
                    a, want,
                    "bsz {} threads {threads}: weight tensor {k} not bit-identical",
                    n_envs * t_len
                );
            }
        }
    }
}

/// ISSUE 6 re-proof at the paper's headline batch: 512 envs x 8 steps =
/// 4096 PPO samples, run through the blocked kernel layer (64-row chunks
/// hit full 4-row/8-column tiles plus remainders). One update per width
/// keeps the test fast; serial `update` stays the bitwise reference.
#[test]
fn update_sharded_is_bit_identical_to_serial_at_b4096() {
    let hp = PpoParams { n_minibatches: 4, update_epochs: 1, hidden: 16, ..Default::default() };
    let (n_envs, t_len) = (512usize, 8usize);
    let max_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let (mut l0, b0) = fixture(StationConfig::default(), n_envs, t_len, 63);
    let mut rng0 = Rng::new(29);
    let stats0 = l0.update(
        &hp, &mut rng0, n_envs, t_len,
        &b0.obs, &b0.act, &b0.logp, &b0.val, &b0.rew, &b0.done,
    );
    let w0 = weights(&l0);
    for threads in [1usize, 4, max_threads] {
        let (mut l, b) = fixture(StationConfig::default(), n_envs, t_len, 63);
        let pool = WorkerPool::new(threads);
        let mut rng = Rng::new(29);
        let stats = l.update_sharded(
            &hp, &mut rng, Some(&pool), n_envs, t_len,
            &b.obs, &b.act, &b.logp, &b.val, &b.rew, &b.done,
        );
        assert_eq!(stats, stats0, "threads {threads}: stats drifted at bsz 4096");
        for (k, (a, want)) in weights(&l).iter().zip(&w0).enumerate() {
            assert_eq!(a, want, "threads {threads}: weight tensor {k} not bit-identical");
        }
    }
}

/// The fleet path: one `update_sharded_many` call covering two
/// differently-shaped family learners is bit-identical to updating each
/// family serially with `Learner::update` — the pooled dispatch draws the
/// epoch permutations in the same family-major order the serial calls
/// consume them, and gradient chunks from BOTH families share one pool.
#[test]
fn pooled_multi_family_update_matches_sequential_serial_updates() {
    let hp = PpoParams { n_minibatches: 2, update_epochs: 2, hidden: 16, ..Default::default() };
    let small = StationConfig { n_dc: 2, n_ac: 1, ..StationConfig::default() };
    let build = || {
        vec![
            fixture(StationConfig::default(), 3, 24, 33), // even bsz 72
            fixture(small.clone(), 5, 17, 44),            // odd bsz 85 -> 42/43 split
        ]
    };
    // Serial reference: per-family Learner::update, one shared rng.
    let mut serial = build();
    let mut rng_s = Rng::new(9);
    let mut stats_s = Vec::new();
    for (learner, b) in serial.iter_mut() {
        stats_s.push(learner.update(
            &hp, &mut rng_s, b.n_envs, b.t_len,
            &b.obs, &b.act, &b.logp, &b.val, &b.rew, &b.done,
        ));
    }
    for threads in [1usize, 4] {
        let pooled = build();
        let (mut learners, bufs): (Vec<Learner>, Vec<Bufs>) = pooled.into_iter().unzip();
        let batches: Vec<UpdateBatch<'_>> = bufs.iter().map(Bufs::batch).collect();
        let pool = WorkerPool::new(threads);
        let mut rng_p = Rng::new(9);
        let stats_p =
            update_sharded_many(&mut learners, &hp, &mut rng_p, Some(&pool), &batches);
        assert_eq!(stats_p, stats_s, "threads {threads}: per-family stats drifted");
        for (e, ((serial_l, _), pooled_l)) in serial.iter().zip(&learners).enumerate() {
            for (k, (a, want)) in weights(pooled_l).iter().zip(weights(serial_l)).enumerate() {
                assert_eq!(
                    a, &want,
                    "threads {threads} family {e}: weight tensor {k} not bit-identical"
                );
            }
        }
    }
}

/// Regression (ISSUE 4): an odd B*T with n_minibatches=2 used to silently
/// drop one sample per epoch (truncating `bsz / n` split). The update must
/// consume the full batch and stay finite.
#[test]
fn ppo_update_handles_odd_batch_sizes() {
    let params = PpoParams {
        num_envs: 3,
        rollout_steps: 7, // bsz = 21, indivisible by 2
        n_minibatches: 2,
        update_epochs: 2,
        hidden: 16,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 8);
    let s = tr.iteration();
    assert!(s.total_loss.is_finite());
    assert!(s.entropy > 0.0);
    assert_eq!(tr.env_steps, 21);
}
