//! The pure-Rust PPO comparator: learning signal + invariants.

use chargax::baselines::ppo::{PpoParams, PpoTrainer};
use chargax::env::scalar::ScenarioTables;
use chargax::env::tree::StationConfig;

fn tables() -> ScenarioTables {
    ScenarioTables {
        price_buy: vec![0.10; 365 * 24],
        price_sell_grid: vec![0.09; 365 * 24],
        moer: vec![0.3; 365 * 24],
        arrival_rate: vec![4.0; 24],
        car_table: vec![60.0, 11.0, 120.0, 0.6, 90.0, 11.0, 200.0, 0.5],
        car_weights: vec![0.6, 0.4],
        user_profile: vec![1.5, 0.6, 2.5, 3.0, 0.8, 0.65],
        n_days: 365,
        alpha: [0.0; 7],
        beta: 0.1,
        p_sell: 0.75,
        traffic: 1.5,
    }
}

#[test]
fn ppo_iteration_produces_finite_stats() {
    let params = PpoParams {
        num_envs: 2,
        rollout_steps: 32,
        n_minibatches: 2,
        update_epochs: 2,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 3);
    let s = tr.iteration();
    assert!(s.mean_reward.is_finite());
    assert!(s.total_loss.is_finite());
    assert!(s.entropy > 0.0);
    assert_eq!(tr.env_steps, 64);
}

#[test]
fn ppo_learns_on_fixed_price_world() {
    // With flat prices and profit-only reward, charging more = more profit;
    // PPO should push mean reward up. Single-iteration rewards are noisy
    // (Poisson arrivals), so compare 5-iteration windows over a longer run.
    let params = PpoParams {
        num_envs: 4,
        rollout_steps: 144,
        n_minibatches: 4,
        update_epochs: 4,
        lr: 1e-3,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 5);
    let rewards: Vec<f32> = (0..40).map(|_| tr.iteration().mean_reward).collect();
    let head: f32 = rewards[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = rewards[35..].iter().sum::<f32>() / 5.0;
    assert!(
        tail > head + 0.05,
        "no learning signal: head {head}, tail {tail} ({rewards:?})"
    );
}

#[test]
fn ppo_entropy_decreases_as_policy_sharpens() {
    let params = PpoParams {
        num_envs: 2,
        rollout_steps: 96,
        lr: 1e-3,
        ent_coef: 0.0,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 6);
    let e0 = tr.iteration().entropy;
    let mut e_last = e0;
    for _ in 0..10 {
        e_last = tr.iteration().entropy;
    }
    assert!(e_last < e0, "entropy should shrink: {e0} -> {e_last}");
}

#[test]
fn greedy_eval_runs_full_episode() {
    let params = PpoParams {
        num_envs: 1,
        rollout_steps: 16,
        n_minibatches: 2,
        update_epochs: 1,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 7);
    tr.iteration();
    let (r, p) = tr.eval_episode(99);
    assert!(r.is_finite() && p.is_finite());
}

/// Regression (ISSUE 4): an odd B*T with n_minibatches=2 used to silently
/// drop one sample per epoch (truncating `bsz / n` split). The update must
/// consume the full batch and stay finite.
#[test]
fn ppo_update_handles_odd_batch_sizes() {
    let params = PpoParams {
        num_envs: 3,
        rollout_steps: 7, // bsz = 21, indivisible by 2
        n_minibatches: 2,
        update_epochs: 2,
        hidden: 16,
        ..Default::default()
    };
    let mut tr = PpoTrainer::new(params, StationConfig::default(), tables(), 8);
    let s = tr.iteration();
    assert!(s.total_loss.is_finite());
    assert!(s.entropy > 0.0);
    assert_eq!(tr.env_steps, 21);
}
