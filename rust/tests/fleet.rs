//! Fleet scheduler determinism + catalog/env integration.
//!
//! The headline property: a fused `Fleet::rollout` over heterogeneous
//! station families (different charger mixes, V2G, battery-less — hence
//! different obs/action dims) scheduled on ONE worker pool is
//! bit-identical to rolling the same `VectorEnv`s out independently, for
//! thread counts {1, 4, max}. Lane RNG is counter-based and shard
//! placement never changes what a lane computes, so the cross-env
//! scheduler must be invisible in the results.

use std::sync::Arc;

use chargax::env::scalar::ScenarioTables;
use chargax::env::tree::StationConfig;
use chargax::env::vector::{RolloutBuffers, VectorEnv};
use chargax::fleet::{Fleet, FleetSpec};
use chargax::util::rng::Rng;

/// Three structurally different station families: the paper's mixed
/// AC/DC default, a DC-only V2G plaza, and a battery-less AC lot. Batch
/// sizes straddle the sharding threshold so the big family actually
/// shards while the small ones stay single-shard.
fn family_specs() -> Vec<(StationConfig, usize, u64)> {
    vec![
        (StationConfig::default(), 64, 1_000),
        (
            StationConfig { n_dc: 8, n_ac: 0, v2g: true, ..StationConfig::default() },
            8,
            2_000,
        ),
        (
            StationConfig {
                n_dc: 0,
                n_ac: 8,
                battery_capacity_kwh: 0.0,
                battery_p_max_kw: 0.0,
                ..StationConfig::default()
            },
            5,
            3_000,
        ),
    ]
}

/// Heterogeneous per-lane scenarios inside each family, same recipe for
/// fleet and reference builds.
fn build_env(cfg: &StationConfig, b: usize, seed_base: u64) -> VectorEnv {
    let tables = vec![
        Arc::new(ScenarioTables::synthetic(0.8)),
        Arc::new(ScenarioTables::synthetic(1.8)),
    ];
    let scen: Vec<usize> = (0..b).map(|j| j % 2).collect();
    let seeds: Vec<u64> = (0..b as u64).map(|j| seed_base + j * 31 + 7).collect();
    VectorEnv::with_seeds(cfg.clone(), tables, scen, &seeds)
}

struct Bufs {
    obs: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<f32>,
    profit: Vec<f32>,
}

fn alloc(env: &VectorEnv, t_len: usize) -> Bufs {
    let (b, d) = (env.batch(), env.obs_dim());
    Bufs {
        obs: vec![0.0; (t_len + 1) * b * d],
        rew: vec![0.0; t_len * b],
        done: vec![0.0; t_len * b],
        profit: vec![0.0; t_len * b],
    }
}

#[test]
fn fleet_rollout_matches_independent_envs_at_every_thread_count() {
    let t_len = 60;
    let specs = family_specs();

    // Scripted actions per (env, step), drawn once and replayed verbatim
    // by every run below.
    let protos: Vec<VectorEnv> =
        specs.iter().map(|(c, b, s)| build_env(c, *b, *s)).collect();
    let mut arng = Rng::new(55);
    let scripted: Vec<Vec<Vec<usize>>> = protos
        .iter()
        .map(|env| {
            let nvec = env.action_nvec();
            (0..t_len)
                .map(|_| {
                    (0..env.batch())
                        .flat_map(|_| {
                            nvec.iter()
                                .map(|&n| arng.below(n as u32) as usize)
                                .collect::<Vec<_>>()
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    // Reference: each env rolled out on its own (its private pool).
    let mut reference: Vec<Bufs> = Vec::new();
    for (i, (cfg, b, s)) in specs.iter().enumerate() {
        let mut env = build_env(cfg, *b, *s);
        let mut bufs = alloc(&env, t_len);
        let mut rb = RolloutBuffers {
            obs: &mut bufs.obs,
            rewards: &mut bufs.rew,
            dones: &mut bufs.done,
            profits: &mut bufs.profit,
        };
        env.rollout(t_len, &mut rb, |t, _obs, a| a.copy_from_slice(&scripted[i][t]));
        reference.push(bufs);
    }

    let max_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for threads in [1usize, 4, max_threads] {
        let envs: Vec<VectorEnv> =
            specs.iter().map(|(c, b, s)| build_env(c, *b, *s)).collect();
        let mut fleet = Fleet::from_envs(
            envs,
            vec!["mixed".into(), "dc-v2g".into(), "ac-lot".into()],
        )
        .unwrap();
        fleet.set_threads(threads);
        let mut bufs: Vec<Bufs> =
            (0..fleet.n_envs()).map(|e| alloc(fleet.env(e), t_len)).collect();
        {
            let mut rbs: Vec<RolloutBuffers<'_>> = bufs
                .iter_mut()
                .map(|b| RolloutBuffers {
                    obs: &mut b.obs,
                    rewards: &mut b.rew,
                    dones: &mut b.done,
                    profits: &mut b.profit,
                })
                .collect();
            fleet.rollout(t_len, &mut rbs, |e, t, _obs, a| {
                a.copy_from_slice(&scripted[e][t]);
            });
        }
        for (e, (got, want)) in bufs.iter().zip(&reference).enumerate() {
            assert!(
                got.obs == want.obs,
                "threads={threads} env {e}: observations diverged from independent rollout"
            );
            assert_eq!(got.rew, want.rew, "threads={threads} env {e}: rewards");
            assert_eq!(got.done, want.done, "threads={threads} env {e}: dones");
            assert_eq!(got.profit, want.profit, "threads={threads} env {e}: profits");
        }
    }
}

/// The fused fleet rollout crosses episode boundaries correctly for every
/// family (dones fire at step 288 for all lanes of every config).
#[test]
fn fleet_rollout_handles_episode_boundaries() {
    use chargax::env::scalar::STEPS_PER_EPISODE;

    let specs = family_specs();
    let envs: Vec<VectorEnv> = specs
        .iter()
        .map(|(c, _b, s)| build_env(c, 3, *s))
        .collect();
    let mut fleet =
        Fleet::from_envs(envs, vec!["a".into(), "b".into(), "c".into()]).unwrap();
    fleet.set_threads(2);
    let t_len = STEPS_PER_EPISODE + 5;
    let mut bufs: Vec<Bufs> =
        (0..fleet.n_envs()).map(|e| alloc(fleet.env(e), t_len)).collect();
    let nvecs: Vec<Vec<usize>> =
        (0..fleet.n_envs()).map(|e| fleet.env(e).action_nvec()).collect();
    {
        let mut rbs: Vec<RolloutBuffers<'_>> = bufs
            .iter_mut()
            .map(|b| RolloutBuffers {
                obs: &mut b.obs,
                rewards: &mut b.rew,
                dones: &mut b.done,
                profits: &mut b.profit,
            })
            .collect();
        let mut rng = Rng::new(9);
        fleet.rollout(t_len, &mut rbs, |e, _t, _obs, a| {
            for (k, x) in a.iter_mut().enumerate() {
                *x = rng.below(nvecs[e][k % nvecs[e].len()] as u32) as usize;
            }
        });
    }
    for (e, b) in bufs.iter().enumerate() {
        let lanes = 3;
        for t in 0..t_len {
            for j in 0..lanes {
                let done = b.done[t * lanes + j];
                let expect = if t + 1 == STEPS_PER_EPISODE { 1.0 } else { 0.0 };
                assert_eq!(done, expect, "env {e} lane {j} step {t}");
                assert!(b.rew[t * lanes + j].is_finite(), "env {e} lane {j} step {t}");
            }
        }
    }
}

/// End-to-end: spec -> catalog expansion -> fleet -> fused rollout, with
/// shared tables actually shared (`Arc` dedup) across lanes.
#[test]
fn spec_built_fleet_rolls_out_and_shares_tables() {
    let mut fleet = Fleet::from_spec(&FleetSpec::demo(4, 1), None).unwrap();
    fleet.set_threads(3);
    assert_eq!(fleet.n_envs(), 3);
    // Lanes of the first family cycle over 4 scenario cells: lanes 0 and
    // 4 share one Arc'd table.
    let env0 = fleet.env(0);
    assert!(Arc::ptr_eq(&env0.tables_arc(0), &env0.tables_arc(4)));
    let t_len = 12;
    let mut bufs: Vec<Bufs> =
        (0..fleet.n_envs()).map(|e| alloc(fleet.env(e), t_len)).collect();
    let nvecs: Vec<Vec<usize>> =
        (0..fleet.n_envs()).map(|e| fleet.env(e).action_nvec()).collect();
    let mut rbs: Vec<RolloutBuffers<'_>> = bufs
        .iter_mut()
        .map(|b| RolloutBuffers {
            obs: &mut b.obs,
            rewards: &mut b.rew,
            dones: &mut b.done,
            profits: &mut b.profit,
        })
        .collect();
    let mut rng = Rng::new(2);
    fleet.rollout(t_len, &mut rbs, |e, _t, _obs, a| {
        for (k, x) in a.iter_mut().enumerate() {
            *x = rng.below(nvecs[e][k % nvecs[e].len()] as u32) as usize;
        }
    });
}

/// Per-family learners with deterministic weights (fresh Rng per call, so
/// fleet and reference builds see identical nets).
fn build_learners(specs: &[(StationConfig, usize, u64)]) -> Vec<chargax::baselines::ppo::Learner> {
    use chargax::baselines::ppo::Learner;
    let mut lrng = Rng::new(17);
    specs
        .iter()
        .map(|(cfg, b, s)| {
            let env = build_env(cfg, *b, *s);
            Learner::new(&mut lrng, env.obs_dim(), 32, env.action_nvec())
        })
        .collect()
}

struct PolBufs {
    act: Vec<usize>,
    logp: Vec<f32>,
    val: Vec<f32>,
}

fn alloc_pol(env: &VectorEnv, t_len: usize) -> PolBufs {
    let (b, p) = (env.batch(), env.n_ports());
    PolBufs {
        act: vec![0usize; t_len * b * p],
        logp: vec![0.0; t_len * b],
        val: vec![0.0; t_len * b],
    }
}

/// ISSUE 4 tentpole invariance, fleet half: `Fleet::rollout_fused` (every
/// family's forward+step shard tasks in ONE pooled dispatch per step)
/// must be bit-identical to rolling each member env out independently via
/// `VectorEnv::rollout_fused` with the same learners and per-family
/// seeds, for thread counts {1, 4, max} — env-side AND policy-side
/// buffers.
#[test]
fn fleet_fused_policy_matches_independent_envs_at_every_thread_count() {
    use chargax::env::vector::PolicyRollout;
    use chargax::fleet::family_policy_seed;

    let t_len = 60;
    let base_seed = 0xF00D;
    let specs = family_specs();
    let learners = build_learners(&specs);

    // Reference: each env rolled out fused on its own (its private pool),
    // with the SAME per-family policy seed the fleet path derives.
    let mut reference: Vec<(Bufs, PolBufs)> = Vec::new();
    for (e, (cfg, b, s)) in specs.iter().enumerate() {
        let mut env = build_env(cfg, *b, *s);
        let mut bufs = alloc(&env, t_len);
        let mut pb = alloc_pol(&env, t_len);
        {
            let mut rb = RolloutBuffers {
                obs: &mut bufs.obs,
                rewards: &mut bufs.rew,
                dones: &mut bufs.done,
                profits: &mut bufs.profit,
            };
            let mut pol = PolicyRollout {
                actions: &mut pb.act,
                logp: &mut pb.logp,
                values: &mut pb.val,
            };
            env.rollout_fused(
                t_len, &mut rb, &mut pol, &learners[e],
                family_policy_seed(base_seed, e), false,
            );
        }
        reference.push((bufs, pb));
    }

    let max_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for threads in [1usize, 4, max_threads] {
        let envs: Vec<VectorEnv> =
            specs.iter().map(|(c, b, s)| build_env(c, *b, *s)).collect();
        let mut fleet = Fleet::from_envs(
            envs,
            vec!["mixed".into(), "dc-v2g".into(), "ac-lot".into()],
        )
        .unwrap();
        fleet.set_threads(threads);
        let mut bufs: Vec<Bufs> =
            (0..fleet.n_envs()).map(|e| alloc(fleet.env(e), t_len)).collect();
        let mut pbs: Vec<PolBufs> =
            (0..fleet.n_envs()).map(|e| alloc_pol(fleet.env(e), t_len)).collect();
        {
            let mut rbs: Vec<RolloutBuffers<'_>> = bufs
                .iter_mut()
                .map(|b| RolloutBuffers {
                    obs: &mut b.obs,
                    rewards: &mut b.rew,
                    dones: &mut b.done,
                    profits: &mut b.profit,
                })
                .collect();
            let mut pols: Vec<PolicyRollout<'_>> = pbs
                .iter_mut()
                .map(|p| PolicyRollout {
                    actions: &mut p.act,
                    logp: &mut p.logp,
                    values: &mut p.val,
                })
                .collect();
            fleet.rollout_fused(t_len, &mut rbs, &mut pols, &learners, base_seed, false);
        }
        for (e, ((got, gpol), (want, wpol))) in
            bufs.iter().zip(&pbs).zip(reference.iter().map(|(a, b)| (a, b))).enumerate()
        {
            assert_eq!(gpol.act, wpol.act, "threads={threads} env {e}: sampled actions");
            assert!(
                got.obs == want.obs,
                "threads={threads} env {e}: observations diverged from independent rollout"
            );
            assert_eq!(got.rew, want.rew, "threads={threads} env {e}: rewards");
            assert_eq!(got.done, want.done, "threads={threads} env {e}: dones");
            assert_eq!(got.profit, want.profit, "threads={threads} env {e}: profits");
            assert_eq!(gpol.logp, wpol.logp, "threads={threads} env {e}: logp");
            assert_eq!(gpol.val, wpol.val, "threads={threads} env {e}: values");
        }
    }
}

/// Per-cell greedy eval covers every distinct scenario cell of every
/// family (not just lane 0's), names each cell, and accounts every
/// training lane to exactly one cell.
#[test]
fn fleet_eval_reports_every_scenario_cell() {
    use chargax::baselines::ppo::PpoParams;
    use chargax::fleet::{FleetPpoTrainer, FleetSpec};

    let fleet = Fleet::from_spec(&FleetSpec::demo(11, 1), None).unwrap();
    let hp = PpoParams { hidden: 16, ..Default::default() };
    let tr = FleetPpoTrainer::new(hp, fleet, 3);
    // The demo's first family spans a 4-cell grid (2 years x 2 traffics):
    // the old lane-0-only eval scored exactly one of these.
    assert_eq!(tr.fleet.env(0).n_scenarios(), 4);
    for e in 0..tr.fleet.n_envs() {
        let evals = tr.eval_cells(e, 42);
        assert_eq!(evals.len(), tr.fleet.env(e).n_scenarios(), "family {e}");
        let lane_sum: usize = evals.iter().map(|c| c.lanes).sum();
        assert_eq!(lane_sum, tr.fleet.env(e).batch(), "family {e}: lanes");
        let mut names: Vec<&str> = evals.iter().map(|c| c.cell.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), evals.len(), "family {e}: duplicate cell names");
        for c in &evals {
            assert!(c.reward.is_finite() && c.profit.is_finite(), "{}/{}", c.family, c.cell);
            assert!(c.cell.contains('/'), "family {e}: cell '{}' not a grid name", c.cell);
        }
    }
}

/// ISSUE 5 end-to-end gate: a full fleet training iteration — fused
/// rollout AND the pooled multi-family `update_sharded_many` — produces
/// bit-identical learner weights at `--threads` 1, 4, and max. Two
/// iterations so Adam state and the second rollout's updated policy are
/// covered.
#[test]
fn fleet_training_iteration_is_thread_count_invariant_including_update() {
    use chargax::baselines::ppo::PpoParams;
    use chargax::fleet::{FleetPpoTrainer, FleetSpec};

    let run = |threads: usize| -> (Vec<Vec<f32>>, Vec<(f32, f32)>) {
        let mut fleet = Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap();
        fleet.set_threads(threads);
        let hp = PpoParams {
            rollout_steps: 24,
            n_minibatches: 2,
            update_epochs: 2,
            hidden: 16,
            threads,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new(hp, fleet, 5);
        let mut stats = Vec::new();
        for _ in 0..2 {
            for s in tr.iteration() {
                stats.push((s.total_loss, s.entropy));
            }
        }
        (vec![tr.policy.params_flat()], stats)
    };
    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let (w1, s1) = run(1);
    let (w4, s4) = run(4);
    let (wm, sm) = run(max_threads);
    assert_eq!(s1, s4, "threads 1 vs 4: per-family stats drifted");
    assert_eq!(s1, sm, "threads 1 vs max: per-family stats drifted");
    assert_eq!(w1.len(), w4.len());
    for (k, (a, b)) in w1.iter().zip(&w4).enumerate() {
        assert_eq!(a, b, "threads 1 vs 4: weight tensor {k} not bit-identical");
    }
    for (k, (a, b)) in w1.iter().zip(&wm).enumerate() {
        assert_eq!(a, b, "threads 1 vs max: weight tensor {k} not bit-identical");
    }
}

/// Regression (ISSUE 5): greedy evals are keyed by ONE per-iteration seed
/// drawn from the trainer rng — repeated `eval_cells_current` calls
/// between two iterations are bit-identical (the old caller-invented
/// per-call seeds made "the same iteration's eval" unrepeatable), the
/// seed advances with the trainer across iterations, and running evals
/// never perturbs the training stream.
#[test]
fn fleet_eval_is_reproducible_within_an_iteration() {
    use chargax::baselines::ppo::PpoParams;
    use chargax::fleet::{FleetPpoTrainer, FleetSpec};

    let hp = PpoParams {
        rollout_steps: 12,
        n_minibatches: 2,
        update_epochs: 1,
        hidden: 16,
        ..Default::default()
    };
    let mk = || {
        FleetPpoTrainer::new(hp.clone(), Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap(), 7)
    };
    let mut tr = mk();
    tr.iteration();
    let seed_a = tr.current_eval_seed();
    let a1 = tr.eval_all_cells_current();
    let a2 = tr.eval_all_cells_current();
    assert_eq!(a1.len(), a2.len());
    for (x, y) in a1.iter().zip(&a2) {
        assert_eq!(x.cell, y.cell);
        assert_eq!(x.reward.to_bits(), y.reward.to_bits(), "{}/{}", x.family, x.cell);
        assert_eq!(x.profit.to_bits(), y.profit.to_bits(), "{}/{}", x.family, x.cell);
    }
    // The per-iteration seed moves with the trainer rng.
    tr.iteration();
    assert_ne!(seed_a, tr.current_eval_seed(), "eval seed must advance per iteration");
    // Evals are pure observers: a trainer that ran (and re-ran) evals
    // takes exactly the same training trajectory as one that never did.
    let mut silent = mk();
    silent.iteration();
    silent.iteration();
    assert_eq!(
        tr.policy.params_flat(),
        silent.policy.params_flat(),
        "evals perturbed training"
    );
    // Explicit-seed evals remain pure functions of their seed.
    let e1 = tr.eval_cells(0, 123);
    let e2 = tr.eval_cells(0, 123);
    for (x, y) in e1.iter().zip(&e2) {
        assert_eq!(x.reward.to_bits(), y.reward.to_bits());
    }
}
