//! Grid-coupled fleets: the propose → allocate → commit step split.
//!
//! The contracts under test, in order of importance:
//!  1. Coupled-fleet training is bitwise thread-count invariant at
//!     `--threads` {1, 4, max} — the allocate phase's fixed-order tree
//!     reduce makes the feeder total independent of the shard plan.
//!  2. Conservation: under proportional curtailment the committed group
//!     draw never exceeds the feeder capacity, and allocation factors
//!     stay in [0, 1].
//!  3. A spec whose `grid` key has `capacity_kw: null` (documentation
//!     only) reproduces the no-`grid` trajectories byte for byte.
//!  4. A 288-step proportional-curtailment episode agrees per-step with
//!     the python comparator (`gym_env.py` grid mode) — the same
//!     skip-or-fail CHARGAX_REQUIRE_PARITY protocol as rust/tests/v2g.rs.

use chargax::env::core::{
    self, GridBudget, LaneView, ScenarioTables, Scratch, StepInfo, DT_HOURS, N_LEVELS_BATTERY,
};
use chargax::env::tree::{StationConfig, StationTree};
use chargax::env::vector::RolloutBuffers;
use chargax::fleet::grid::{self, CurtailPolicy};
use chargax::fleet::{Fleet, FleetPpoTrainer, FleetSpec, GridSpec};
use chargax::util::rng::CounterRng;

// -- core-level lane harness (the v2g.rs pattern) ---------------------------

struct Lane {
    t: u32,
    day: u32,
    battery_soc: f32,
    ep_return: f32,
    ep_profit: f32,
    present: Vec<bool>,
    soc: Vec<f32>,
    de_remain: Vec<f32>,
    dt_remain: Vec<f32>,
    cap: Vec<f32>,
    r_bar: Vec<f32>,
    tau: Vec<f32>,
    sensitive: Vec<bool>,
    i_drawn: Vec<f32>,
}

impl Lane {
    fn empty(cfg: &StationConfig) -> Lane {
        let (c, p) = (cfg.n_chargers(), cfg.n_ports());
        Lane {
            t: 0,
            day: 0,
            battery_soc: cfg.battery_soc0,
            ep_return: 0.0,
            ep_profit: 0.0,
            present: vec![false; c],
            soc: vec![0.0; c],
            de_remain: vec![0.0; c],
            dt_remain: vec![0.0; c],
            cap: vec![60.0; c],
            r_bar: vec![50.0; c],
            tau: vec![0.8; c],
            sensitive: vec![false; c],
            i_drawn: vec![0.0; p],
        }
    }

    fn park(&mut self, slot: usize, soc: f32, cap: f32, r_bar: f32, tau: f32) {
        self.present[slot] = true;
        self.soc[slot] = soc;
        self.cap[slot] = cap;
        self.r_bar[slot] = r_bar;
        self.tau[slot] = tau;
        self.de_remain[slot] = (0.8 - soc).max(0.0) * cap;
        self.dt_remain[slot] = 1e6;
        self.sensitive[slot] = false;
    }

    fn view(&mut self) -> LaneView<'_> {
        LaneView {
            t: &mut self.t,
            day: &mut self.day,
            battery_soc: &mut self.battery_soc,
            ep_return: &mut self.ep_return,
            ep_profit: &mut self.ep_profit,
            present: &mut self.present,
            soc: &mut self.soc,
            de_remain: &mut self.de_remain,
            dt_remain: &mut self.dt_remain,
            cap: &mut self.cap,
            r_bar: &mut self.r_bar,
            tau: &mut self.tau,
            sensitive: &mut self.sensitive,
            i_drawn: &mut self.i_drawn,
        }
    }
}

/// No-arrival synthetic tables (traffic 0) so every trajectory is exactly
/// deterministic and python-comparable.
fn quiet_tables(alpha: [f32; 7]) -> ScenarioTables {
    let mut t = ScenarioTables::synthetic(0.0);
    t.alpha = alpha;
    t
}

// -- 1. bitwise thread invariance -------------------------------------------

/// Full coupled-fleet training — fused two-phase rollout (propose →
/// fixed-order feeder reduce → commit) AND the pooled update — produces
/// bit-identical weights and stats at `--threads` 1, 4, and max. Two
/// iterations so Adam state and a second rollout of the updated policy
/// are covered.
#[test]
fn coupled_fleet_training_is_thread_count_invariant() {
    use chargax::baselines::ppo::PpoParams;

    let run = |threads: usize| -> (Vec<f32>, Vec<(f32, f32)>) {
        let mut fleet = Fleet::from_spec(&FleetSpec::demo_coupled(9, 1), None).unwrap();
        assert!(fleet.has_coupling(), "demo_coupled must couple every family");
        fleet.set_threads(threads);
        let hp = PpoParams {
            rollout_steps: 24,
            n_minibatches: 2,
            update_epochs: 2,
            hidden: 16,
            threads,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new(hp, fleet, 5);
        let mut stats = Vec::new();
        for _ in 0..2 {
            for s in tr.iteration() {
                stats.push((s.total_loss, s.entropy));
            }
        }
        (tr.policy.params_flat(), stats)
    };
    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let (w1, s1) = run(1);
    let (w4, s4) = run(4);
    let (wm, sm) = run(max_threads);
    assert_eq!(s1, s4, "threads 1 vs 4: coupled per-family stats drifted");
    assert_eq!(s1, sm, "threads 1 vs max: coupled per-family stats drifted");
    assert_eq!(w1, w4, "threads 1 vs 4: coupled weights not bit-identical");
    assert_eq!(w1, wm, "threads 1 vs max: coupled weights not bit-identical");
}

/// Coupling is visible where it should be: every coupled family grows
/// exactly one obs column (normalized feeder headroom, the last column),
/// and under a feeder tight enough to bind, rollout observations show
/// headroom in [0, 1] and strictly below 1 once charging ramps.
#[test]
fn coupled_rollout_reports_binding_feeder_headroom() {
    let uncoupled = Fleet::from_spec(&FleetSpec::demo(3, 1), None).unwrap();
    let mut spec = FleetSpec::demo_coupled(3, 1);
    for s in &mut spec.specs {
        // 100 kW for 20 lanes of stations that can each pull hundreds of
        // kW: the feeder binds almost immediately.
        s.grid.as_mut().unwrap().capacity_kw = Some(100.0);
    }
    let mut fleet = Fleet::from_spec(&spec, None).unwrap();
    fleet.set_threads(2);
    for e in 0..fleet.n_envs() {
        assert_eq!(
            fleet.env(e).obs_dim(),
            uncoupled.env(e).obs_dim() + 1,
            "family {e}: coupled family must grow exactly the headroom column"
        );
    }
    let t_len = 40;
    let dims: Vec<(usize, usize)> =
        (0..fleet.n_envs()).map(|e| (fleet.env(e).batch(), fleet.env(e).obs_dim())).collect();
    let nvecs: Vec<Vec<usize>> =
        (0..fleet.n_envs()).map(|e| fleet.env(e).action_nvec()).collect();
    let mut obs: Vec<Vec<f32>> =
        dims.iter().map(|&(b, d)| vec![0.0; (t_len + 1) * b * d]).collect();
    let mut rew: Vec<Vec<f32>> = dims.iter().map(|&(b, _)| vec![0.0; t_len * b]).collect();
    let mut done: Vec<Vec<f32>> = dims.iter().map(|&(b, _)| vec![0.0; t_len * b]).collect();
    let mut profit: Vec<Vec<f32>> = dims.iter().map(|&(b, _)| vec![0.0; t_len * b]).collect();
    {
        let mut rbs: Vec<RolloutBuffers<'_>> = obs
            .iter_mut()
            .zip(rew.iter_mut())
            .zip(done.iter_mut())
            .zip(profit.iter_mut())
            .map(|(((o, r), dn), p)| RolloutBuffers {
                obs: o,
                rewards: r,
                dones: dn,
                profits: p,
            })
            .collect();
        // Max-charge actions everywhere: propose as much draw as the
        // stations can stage.
        fleet.rollout(t_len, &mut rbs, |e, _t, _obs, a| {
            for (k, x) in a.iter_mut().enumerate() {
                *x = nvecs[e][k % nvecs[e].len()] - 1;
            }
        });
    }
    let mut min_head = f32::INFINITY;
    for (e, &(b, d)) in dims.iter().enumerate() {
        for t in 0..=t_len {
            for j in 0..b {
                let h = obs[e][t * b * d + j * d + (d - 1)];
                assert!((0.0..=1.0).contains(&h), "env {e} t {t} lane {j}: headroom {h}");
                // One feeder ⇒ one headroom per step, shared by every
                // lane of every member family.
                let h0 = obs[0][t * dims[0].0 * dims[0].1 + (dims[0].1 - 1)];
                assert_eq!(h.to_bits(), h0.to_bits(), "env {e} t {t} lane {j}: headroom differs");
                min_head = min_head.min(h);
            }
        }
        for t in 0..t_len {
            for j in 0..b {
                assert!(rew[e][t * b + j].is_finite(), "env {e} t {t} lane {j}: reward");
            }
        }
    }
    assert_eq!(
        min_head, 0.0,
        "a 100 kW feeder under max-charge must hit zero headroom"
    );
}

// -- 2. conservation ---------------------------------------------------------

/// Proportional curtailment conserves the feeder: every step, allocation
/// factors are in [0, 1], the committed group draw stays at or under
/// capacity, and equals `factor x proposed` (the stage-phase SoC clamps
/// are linear through zero, so shrinking currents cannot newly bind).
#[test]
fn proportional_commit_conserves_feeder_capacity() {
    let cfg = StationConfig::default();
    let tree = StationTree::standard(&cfg);
    let tables = quiet_tables([0.0; 7]);
    let cap_kw = 150.0f32;
    let n_lanes = 4;
    let c = cfg.n_chargers();
    let p = cfg.n_ports();

    let mut lanes: Vec<Lane> = (0..n_lanes)
        .map(|l| {
            let mut lane = Lane::empty(&cfg);
            // Stagger start SoCs so lanes propose different draws.
            for slot in 0..6 {
                lane.park(slot, 0.1 + 0.05 * (l as f32), 60.0, 120.0, 0.8);
            }
            lane.park(10, 0.2, 40.0, 11.0, 0.7);
            lane
        })
        .collect();
    let mut rngs: Vec<CounterRng> = (0..n_lanes as u64).map(CounterRng::new).collect();
    let mut scratch = Scratch::new(p);
    let nvec = core::action_nvec(&cfg);
    let idle_bat = (N_LEVELS_BATTERY - 1) / 2;

    let mut curtailed_steps = 0usize;
    for t in 0..120usize {
        let mut action = vec![0usize; p];
        for (j, a) in action.iter_mut().enumerate().take(c) {
            *a = (nvec[j] - 1).min(nvec[j] - 1 - (t + j) % 3);
        }
        action[c] = idle_bat;

        // Propose every lane, reduce in fixed order, allocate once.
        let mut proposals: Vec<core::Proposal> = Vec::with_capacity(n_lanes);
        for lane in lanes.iter_mut() {
            proposals.push(core::propose_lane(&mut lane.view(), &cfg, &tree, &action, &mut scratch));
        }
        let kw: Vec<f32> = proposals.iter().map(|pr| pr.grid_kw).collect();
        let total = grid::reduce_proposals(&kw);
        let budget = grid::allocate(total, cap_kw, CurtailPolicy::Proportional);
        assert!(
            budget.factor > 0.0 && budget.factor <= 1.0,
            "step {t}: factor {} out of (0, 1]",
            budget.factor
        );
        assert_eq!(budget.buy_mult, 1.0, "proportional never reprices");

        let infos: Vec<StepInfo> = lanes
            .iter_mut()
            .zip(rngs.iter_mut())
            .zip(&proposals)
            .map(|((lane, rng), pr)| {
                core::commit_lane(&mut lane.view(), rng, &cfg, &tree, &tables, budget, pr.excess_kw)
            })
            .collect();
        let committed_kw: f32 =
            infos.iter().map(|i| i.energy_grid_net_kwh).sum::<f32>() / DT_HOURS;
        assert!(
            committed_kw <= cap_kw * (1.0 + 1e-4),
            "step {t}: committed {committed_kw} kW exceeds the {cap_kw} kW feeder"
        );
        if budget != GridBudget::UNCURTAILED {
            curtailed_steps += 1;
            let want = budget.factor * total;
            assert!(
                (committed_kw - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "step {t}: committed {committed_kw} kW vs factor x proposed {want} kW"
            );
        }
    }
    assert!(
        curtailed_steps > 30,
        "a 150 kW feeder under 4 charging stations must actually bind \
         (curtailed {curtailed_steps}/120 steps)"
    );
}

// -- 3. null capacity == uncoupled, byte for byte ----------------------------

/// `grid.capacity_kw: null` documents the feeder without coupling it:
/// obs dims, training stats, and learner weights are byte-identical to
/// the same spec with no `grid` key at all.
#[test]
fn null_capacity_grid_reproduces_uncoupled_trajectories_byte_for_byte() {
    use chargax::baselines::ppo::PpoParams;

    let run = |spec: &FleetSpec| -> (Vec<usize>, Vec<f32>, Vec<(f32, f32)>) {
        let mut fleet = Fleet::from_spec(spec, None).unwrap();
        assert!(!fleet.has_coupling(), "capacity_kw: null must not couple");
        fleet.set_threads(2);
        let dims = (0..fleet.n_envs()).map(|e| fleet.env(e).obs_dim()).collect();
        let hp = PpoParams {
            rollout_steps: 16,
            n_minibatches: 2,
            update_epochs: 1,
            hidden: 16,
            threads: 2,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new(hp, fleet, 11);
        let stats =
            tr.iteration().into_iter().map(|s| (s.mean_reward, s.total_loss)).collect();
        (dims, tr.policy.params_flat(), stats)
    };

    let plain = FleetSpec::demo(5, 1);
    let mut documented = FleetSpec::demo(5, 1);
    for s in &mut documented.specs {
        s.grid = Some(GridSpec {
            feeder: "doc-only".into(),
            capacity_kw: None,
            policy: CurtailPolicy::Proportional,
        });
    }
    let (d_a, w_a, s_a) = run(&plain);
    let (d_b, w_b, s_b) = run(&documented);
    assert_eq!(d_a, d_b, "null capacity must not add the headroom obs column");
    assert_eq!(s_a.len(), s_b.len());
    for (k, (a, b)) in s_a.iter().zip(&s_b).enumerate() {
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "family {k}: mean reward drifted");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "family {k}: loss drifted");
    }
    assert_eq!(w_a.len(), w_b.len());
    for (k, (a, b)) in w_a.iter().zip(&w_b).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "weight {k} not byte-identical");
    }
}

// -- 4. python parity --------------------------------------------------------

fn parity_required() -> bool {
    std::env::var("CHARGAX_REQUIRE_PARITY").map(|v| v == "1").unwrap_or(false)
}

fn skip_or_fail(why: &str) {
    if parity_required() {
        panic!("CHARGAX_REQUIRE_PARITY=1 but the python comparator did not run: {why}");
    }
    eprintln!("SKIP grid-coupling python parity: {why}");
}

/// 288-step proportionally-curtailed episode agreement with the python
/// comparator's grid mode: same parked cars, same scripted actions, same
/// 100 kW feeder; per-step rewards and mid-episode SoCs match within
/// float32 tolerance, and both sides actually curtail.
#[test]
fn curtailed_episode_matches_python_gym_comparator() {
    let cfg = StationConfig::default();
    let tree = StationTree::standard(&cfg);
    let c = cfg.n_chargers();
    let p = cfg.n_ports();
    let cap_kw = 100.0f32;

    let mut tables = quiet_tables([0.3, 0.5, 0.4, 0.2, 0.1, 0.7, 0.05]);
    tables.n_days = 1;
    tables.price_buy = (0..24).map(|h| 0.05 + 0.01 * h as f32).collect();
    tables.price_sell_grid = tables.price_buy.iter().map(|x| x * 0.9).collect();
    tables.moer = (0..24).map(|h| 0.2 + 0.01 * h as f32).collect();

    let mut lane = Lane::empty(&cfg);
    for slot in 0..6 {
        lane.park(slot, 0.05 + 0.1 * slot as f32, 60.0, 120.0, 0.6);
    }
    lane.park(10, 0.3, 40.0, 11.0, 0.7);
    let mut rng = CounterRng::new(1);
    let mut scratch = Scratch::new(p);
    let nvec = core::action_nvec(&cfg);
    let mut rewards = Vec::with_capacity(288);
    let mut heads = Vec::with_capacity(288);
    let mut curtailed = 0usize;
    let mut mid_socs = (0f32, 0f32, 0f32);
    for t in 0..288usize {
        let mut action = vec![0usize; p];
        for (j, a) in action.iter_mut().enumerate().take(c) {
            *a = (t * 7 + j * 3) % nvec[j];
        }
        action[c] = (t * 5 + 1) % nvec[c];
        let prop = core::propose_lane(&mut lane.view(), &cfg, &tree, &action, &mut scratch);
        let total = grid::reduce_proposals(&[prop.grid_kw]);
        let budget = grid::allocate(total, cap_kw, CurtailPolicy::Proportional);
        if budget != GridBudget::UNCURTAILED {
            curtailed += 1;
        }
        let info =
            core::commit_lane(&mut lane.view(), &mut rng, &cfg, &tree, &tables, budget, prop.excess_kw);
        rewards.push(info.reward);
        heads.push(grid::headroom(total, cap_kw));
        if t == 143 {
            mid_socs = (lane.soc[0], lane.soc[10], lane.battery_soc);
        }
    }
    // The parked cars fill up over the day (no new arrivals), so the feeder
    // binds early and relaxes once SoCs saturate — the python comparator
    // sees ~43 binding steps on this script.
    assert!(curtailed > 25, "a 100 kW feeder must bind often (got {curtailed}/288)");

    let python_dir = format!("{}/../python", env!("CARGO_MANIFEST_DIR"));
    let script = r#"
import json
from baselines.gym_env import Car, GymChargingEnv

h = [0.05 + 0.01 * i for i in range(24)]
tables = {
    "price_buy": h,
    "price_sell_grid": [x * 0.9 for x in h],
    "moer": [0.2 + 0.01 * i for i in range(24)],
    "arrival_rate": [3.0] * 24,
    "car_table": [[60.0, 11.0, 120.0, 0.6]],
    "car_weights": [1.0],
    "user_profile": [1.5, 0.6, 2.5, 3.0, 0.8, 0.65],
    "alpha": [0.3, 0.5, 0.4, 0.2, 0.1, 0.7, 0.05],
    "beta": 0.1,
    "p_sell": 0.75,
    "traffic": 0.0,
    "n_days": 1,
}
env = GymChargingEnv(tables, seed=0, grid_capacity_kw=100.0, grid_policy="proportional")
env.t = 0
env.day = 0
for slot in range(6):
    soc = 0.05 + 0.1 * slot
    env.evses[slot].car = Car(soc=soc, de_remain=(0.8 - soc) * 60.0, dt_remain=1e6,
                              cap=60.0, r_bar=120.0, tau=0.6, charge_sensitive=False)
env.evses[10].car = Car(soc=0.3, de_remain=0.5 * 40.0, dt_remain=1e6,
                        cap=40.0, r_bar=11.0, tau=0.7, charge_sensitive=False)
nv = env.action_nvec()
rewards = []
heads = []
mid = None
for t in range(288):
    a = [(t * 7 + j * 3) % nv[j] for j in range(len(env.evses))]
    a.append((t * 5 + 1) % nv[-1])
    obs, r, done, info = env.step(a)
    rewards.append(r)
    heads.append(env.grid_headroom)
    if t == 143:
        mid = [env.evses[0].car.soc, env.evses[10].car.soc, env.battery.soc]
print(json.dumps({"rewards": rewards, "heads": heads, "mid": mid}))
"#;
    let output = std::process::Command::new("python3")
        .args(["-c", script])
        .current_dir(&python_dir)
        .output();
    let output = match output {
        Ok(o) if o.status.success() => o,
        Ok(o) => {
            skip_or_fail(&format!(
                "python exited nonzero:\n{}",
                String::from_utf8_lossy(&o.stderr)
            ));
            return;
        }
        Err(e) => {
            skip_or_fail(&format!("cannot spawn python3: {e}"));
            return;
        }
    };
    let text = String::from_utf8_lossy(&output.stdout);
    let j = chargax::util::json::Json::parse(text.trim()).expect("python JSON output");
    let py_rewards: Vec<f32> =
        j.get("rewards").and_then(|x| x.as_f32_flat()).expect("rewards array");
    let py_heads: Vec<f32> = j.get("heads").and_then(|x| x.as_f32_flat()).expect("heads array");
    let py_mid: Vec<f32> = j.get("mid").and_then(|x| x.as_f32_flat()).expect("mid socs");
    assert_eq!(py_rewards.len(), rewards.len());
    for (t, (rs, py)) in rewards.iter().zip(&py_rewards).enumerate() {
        assert!(
            (rs - py).abs() < 2e-3 * (1.0 + py.abs()),
            "step {t}: rust reward {rs} vs python {py}"
        );
    }
    for (t, (rs, py)) in heads.iter().zip(&py_heads).enumerate() {
        assert!((rs - py).abs() < 1e-3, "step {t}: rust headroom {rs} vs python {py}");
    }
    let (s0, s10, sb) = mid_socs;
    assert!((s0 - py_mid[0]).abs() < 1e-3, "DC car SoC {s0} vs {}", py_mid[0]);
    assert!((s10 - py_mid[1]).abs() < 1e-3, "AC car SoC {s10} vs {}", py_mid[1]);
    assert!((sb - py_mid[2]).abs() < 1e-3, "battery SoC {sb} vs {}", py_mid[2]);
}
