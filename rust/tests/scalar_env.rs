//! Scalar-simulator invariants (property-based via util::prop) and the
//! python-exported cross-check vectors.

use chargax::baselines::policies::{self, MaxCharge, Policy, PriceThreshold, RandomPolicy};
use chargax::env::scalar::{ScalarEnv, ScenarioTables, STEPS_PER_EPISODE};
use chargax::env::tree::{charging_curve, StationConfig, StationTree};
use chargax::util::prop::Prop;
use chargax::util::rng::Rng;

/// Synthetic tables (no artifacts needed): flat prices, constant arrivals.
fn test_tables(traffic: f32) -> ScenarioTables {
    ScenarioTables::synthetic(traffic)
}

#[test]
fn occupancy_and_soc_invariants_under_random_policy() {
    Prop::new(12).check("env-invariants", |rng| {
        let seed = rng.next_u64();
        let mut env = ScalarEnv::new(StationConfig::default(), test_tables(1.5), seed);
        let mut pol = RandomPolicy { rng: Rng::new(seed ^ 1) };
        let mut action = vec![0usize; env.n_ports()];
        for _ in 0..400 {
            pol.act(&env, &mut action);
            let info = env.step(&action);
            assert!(info.reward.is_finite());
            assert!((0.0..=1.0).contains(&env.battery_soc()));
            for slot in 0..env.cfg().n_chargers() {
                let Some(car) = env.car(slot) else { continue };
                assert!((0.0..=1.0).contains(&car.soc), "car soc {}", car.soc);
                assert!(car.cap > 0.0);
            }
            // metric consistency
            assert!(info.arrived >= 0.0 && info.departed >= 0.0);
        }
    });
}

#[test]
fn node_constraints_hold_under_max_policy() {
    Prop::new(8).check("constraints-max-policy", |rng| {
        let seed = rng.next_u64();
        let mut env = ScalarEnv::new(StationConfig::default(), test_tables(2.0), seed);
        let mut pol = MaxCharge;
        let mut action = vec![0usize; env.n_ports()];
        let tree = StationTree::standard(&StationConfig::default());
        for _ in 0..300 {
            pol.act(&env, &mut action);
            env.step(&action);
            for n in 0..tree.n_nodes() {
                let mut flow = 0f32;
                for j in 0..tree.n_ports() {
                    if tree.membership[n][j] {
                        flow += tree.volt[j] * env.i_drawn()[j] / 1000.0;
                    }
                }
                assert!(
                    flow.abs() / tree.node_eta[n] <= tree.node_limit[n] + 1e-2,
                    "node {n} overloaded: {flow}"
                );
            }
        }
    });
}

#[test]
fn episodes_reset_exactly_at_boundary() {
    let mut env = ScalarEnv::new(StationConfig::default(), test_tables(1.0), 3);
    let mut pol = RandomPolicy { rng: Rng::new(4) };
    let mut action = vec![0usize; env.n_ports()];
    let mut dones = 0;
    for i in 1..=2 * STEPS_PER_EPISODE {
        pol.act(&env, &mut action);
        let info = env.step(&action);
        if info.done {
            dones += 1;
            assert_eq!(i % STEPS_PER_EPISODE, 0, "done off-boundary at {i}");
            assert_eq!(env.t(), 0);
            assert!((0..env.cfg().n_chargers()).all(|j| !env.occupied(j)));
        }
    }
    assert_eq!(dones, 2);
}

#[test]
fn max_charge_beats_random_on_energy_delivery() {
    let mut env_m = ScalarEnv::new(StationConfig::default(), test_tables(1.5), 11);
    let mut env_r = ScalarEnv::new(StationConfig::default(), test_tables(1.5), 11);
    let mut pm = MaxCharge;
    let mut pr = RandomPolicy { rng: Rng::new(12) };
    let sm = policies::rollout(&mut env_m, &mut pm, 2 * STEPS_PER_EPISODE);
    let sr = policies::rollout(&mut env_r, &mut pr, 2 * STEPS_PER_EPISODE);
    assert!(sm.mean_profit > sr.mean_profit);
    assert!(sm.total_missing_kwh <= sr.total_missing_kwh);
}

#[test]
fn price_threshold_policy_runs() {
    let mut env = ScalarEnv::new(StationConfig::default(), test_tables(1.0), 21);
    let mut p = PriceThreshold::default();
    let s = policies::rollout(&mut env, &mut p, STEPS_PER_EPISODE);
    assert!(s.mean_reward.is_finite());
    assert_eq!(s.steps, STEPS_PER_EPISODE);
}

#[test]
fn degenerate_stations_work() {
    // 1 charger, no AC; and AC-only.
    for cfg in [
        StationConfig { n_dc: 1, n_ac: 0, ..Default::default() },
        StationConfig { n_dc: 0, n_ac: 2, ..Default::default() },
    ] {
        let mut env = ScalarEnv::new(cfg.clone(), test_tables(1.0), 5);
        let mut pol = RandomPolicy { rng: Rng::new(6) };
        let mut action = vec![0usize; env.n_ports()];
        for _ in 0..100 {
            pol.act(&env, &mut action);
            let info = env.step(&action);
            assert!(info.reward.is_finite());
        }
    }
}

#[test]
fn no_arrivals_when_traffic_zero() {
    let mut env = ScalarEnv::new(StationConfig::default(), test_tables(0.0), 8);
    let mut pol = MaxCharge;
    let mut action = vec![0usize; env.n_ports()];
    for _ in 0..STEPS_PER_EPISODE {
        pol.act(&env, &mut action);
        let info = env.step(&action);
        assert_eq!(info.arrived, 0.0);
    }
    assert!((0..env.cfg().n_chargers()).all(|j| !env.occupied(j)));
}

#[test]
fn charging_curve_taper_region_monotone() {
    Prop::new(64).check("curve-monotone", |rng| {
        let rbar = rng.range_f32(5.0, 250.0);
        let tau = rng.range_f32(0.2, 0.9);
        let s1 = rng.range_f32(tau, 1.0);
        let s2 = rng.range_f32(tau, 1.0);
        let (lo, hi) = if s1 < s2 { (s1, s2) } else { (s2, s1) };
        assert!(charging_curve(lo, rbar, tau) >= charging_curve(hi, rbar, tau) - 1e-5);
    });
}

#[test]
fn cross_check_vectors_match_python_export() {
    // Requires artifacts/data/test_vectors.json.
    let base = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join("data")
        .join("test_vectors.json");
    if !base.exists() {
        eprintln!("skipping: test vectors not exported (run `make artifacts`)");
        return;
    }
    std::env::set_var(
        "CHARGAX_ARTIFACTS",
        base.parent().unwrap().parent().unwrap(),
    );
    // The check logic lives in the binary's experiments module; replicate
    // the constraint-case check here against the tree directly.
    let text = std::fs::read_to_string(&base).unwrap();
    let j = chargax::util::json::Json::parse(&text).unwrap();
    let cases = j.get("cases").and_then(|c| c.as_arr()).unwrap();
    let mut n_constraint = 0;
    for case in cases {
        if case.get("kind").and_then(|k| k.as_str()) != Some("constraint") {
            continue;
        }
        n_constraint += 1;
        let mut i = case.get("i_drawn").and_then(|x| x.as_f32_flat()).unwrap();
        let volt = case.get("volt").and_then(|x| x.as_f32_flat()).unwrap();
        let mem = case.get("membership").and_then(|x| x.as_f32_flat()).unwrap();
        let lim = case.get("limits").and_then(|x| x.as_f32_flat()).unwrap();
        let eta = case.get("eta").and_then(|x| x.as_f32_flat()).unwrap();
        let want_i = case.get("want_i").and_then(|x| x.as_f32_flat()).unwrap();
        let p = i.len();
        let n = lim.len();
        let tree = StationTree {
            volt,
            i_max: vec![1.0; p],
            p_max: vec![1.0; p],
            eta_port: vec![1.0; p],
            is_dc: vec![false; p - 1],
            membership: (0..n)
                .map(|r| (0..p).map(|c| mem[r * p + c] > 0.5).collect())
                .collect(),
            node_limit: lim,
            node_eta: eta,
        };
        tree.project_currents(&mut i);
        for (a, b) in i.iter().zip(&want_i) {
            assert!(
                (a - b).abs() < 1e-2 * (1.0 + b.abs()),
                "constraint projection drifted from python: {a} vs {b}"
            );
        }
    }
    assert!(n_constraint >= 8);
}
