//! Integration tests over real artifacts + a live PJRT client.
//!
//! These need `make artifacts` to have run; they skip (with a message)
//! when artifacts/ is absent so `cargo test` stays green on a fresh
//! checkout.

use std::path::PathBuf;

use chargax::coordinator::session::{EvalSession, RandomRollout, TrainSession};
use chargax::coordinator::trainer::{self, TrainOptions};
use chargax::data::{DataStore, Scenario};
use chargax::runtime::engine::Engine;
use chargax::runtime::manifest::Manifest;
use chargax::runtime::tensor::Tensor;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

/// PjRtClient is not Sync (Rc internals): each test owns its engine.
fn new_engine() -> Engine {
    Engine::cpu().expect("pjrt cpu client")
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_and_has_default_variants() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    for v in ["mix10dc6ac_e12", "mix10dc6ac_e1", "mix10dc6ac_e16"] {
        let var = m.variant(v).unwrap();
        assert_eq!(var.meta.n_ports, 17);
        assert_eq!(var.meta.obs_dim, 107);
        for prog in [
            "train_init", "train_iter", "eval_net", "eval_max", "eval_random",
            "random_rollout", "env_reset", "env_step",
        ] {
            assert!(var.programs.contains_key(prog), "{v} missing {prog}");
        }
    }
}

#[test]
fn datastore_loads_all_tables() {
    let dir = require_artifacts!();
    let store = DataStore::load(&dir.join("data")).unwrap();
    assert_eq!(store.prices.len(), 9); // 3 countries x 3 years
    assert_eq!(store.n_models, 20);
    assert_eq!(store.n_days, 365);
    assert_eq!(store.arrival_shapes.len(), 4);
    // crisis year visible (drives fig5)
    let p21: f64 = store.price("NL", 2021).unwrap().iter().map(|x| *x as f64).sum();
    let p22: f64 = store.price("NL", 2022).unwrap().iter().map(|x| *x as f64).sum();
    assert!(p22 > 1.8 * p21);
}

#[test]
fn env_step_executes_and_feeds_back() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let v = m.variant("mix10dc6ac_e12").unwrap();
    let sc = Scenario::default();

    let reset = &new_engine().load(v.program("env_reset").unwrap()).unwrap();
    let step = &new_engine().load(v.program("env_step").unwrap()).unwrap();
    let exog: Vec<xla::Literal> = sc
        .to_tensors(&store)
        .unwrap()
        .iter()
        .map(|t| t.to_literal().unwrap())
        .collect();

    let seed = Tensor::scalar_u32(42).to_literal().unwrap();
    let mut ins: Vec<&xla::Literal> = vec![&seed];
    ins.extend(exog.iter());
    let mut outs = reset.run_literals(&ins).unwrap();
    let obs = outs.pop().unwrap();
    let obs_t = Tensor::from_literal(&obs).unwrap();
    assert_eq!(obs_t.shape(), &[12, 107]);
    assert!(obs_t.as_f32().unwrap().iter().all(|x| x.is_finite()));

    // 30 feedback steps with constant mid-level actions.
    let action = Tensor::i32(vec![12, 17], vec![5; 12 * 17])
        .unwrap()
        .to_literal()
        .unwrap();
    let n_state = outs.len();
    let mut state = outs;
    for _ in 0..30 {
        let mut ins: Vec<&xla::Literal> = state.iter().collect();
        ins.push(&action);
        ins.extend(exog.iter());
        let full = step.run_literals(&ins).unwrap();
        // outputs: state' ++ [obs, reward, done, metrics]
        assert_eq!(full.len(), n_state + 4);
        let reward = Tensor::from_literal(&full[n_state + 1]).unwrap();
        assert_eq!(reward.shape(), &[12]);
        assert!(reward.as_f32().unwrap().iter().all(|x| x.is_finite()));
        state = full.into_iter().take(n_state).collect();
    }
    // t advanced to 30 for every env (state leaf 't' is output index of
    // name "t").
    let t_idx = step
        .spec
        .outputs
        .iter()
        .position(|s| s.name == "t")
        .unwrap();
    let t = Tensor::from_literal(&state[t_idx]).unwrap();
    assert_eq!(t.as_i32().unwrap(), &[30i32; 12]);
}

#[test]
fn train_session_learns_and_is_deterministic() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let v = m.variant("mix10dc6ac_e12").unwrap();
    let sc = Scenario { traffic: "high".into(), ..Default::default() };

    let mut s1 = TrainSession::new(&new_engine(), v, &store, &sc, 123).unwrap();
    let m1 = s1.step().unwrap();
    assert!(m1.get("total_loss").unwrap().is_finite());
    assert!(m1.get("entropy").unwrap() > 0.0);
    assert_eq!(s1.env_steps_done, v.meta.batch_size);

    // determinism: same seed, same first-iteration metrics
    let mut s2 = TrainSession::new(&new_engine(), v, &store, &sc, 123).unwrap();
    let m2 = s2.step().unwrap();
    assert_eq!(m1.values, m2.values);

    // different seed diverges
    let mut s3 = TrainSession::new(&new_engine(), v, &store, &sc, 124).unwrap();
    let m3 = s3.step().unwrap();
    assert_ne!(m1.values, m3.values);
}

#[test]
fn eval_policies_rank_sanely() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let v = m.variant("mix10dc6ac_e12").unwrap();
    let sc = Scenario { traffic: "high".into(), ..Default::default() };

    let max_eval = EvalSession::new(&new_engine(), v, &store, &sc, "max").unwrap();
    let rand_eval = EvalSession::new(&new_engine(), v, &store, &sc, "random").unwrap();
    let zeros = max_eval.zero_params().unwrap();
    let refs: Vec<&xla::Literal> = zeros.iter().collect();
    let mm = max_eval.run(&refs, 7).unwrap();
    let mr = rand_eval.run(&refs, 7).unwrap();
    // max-charge delivers more energy and leaves less unmet demand.
    assert!(mm.get("ep_energy_kwh").unwrap() > mr.get("ep_energy_kwh").unwrap());
    assert!(mm.get("ep_missing_kwh").unwrap() <= mr.get("ep_missing_kwh").unwrap());
    // both served cars
    assert!(mm.get("ep_arrived").unwrap() > 10.0);
}

#[test]
fn random_rollout_advances_envs() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let v = m.variant("mix10dc6ac_e16").unwrap();
    let rr = RandomRollout::new(&new_engine(), v, &store, &Scenario::default()).unwrap();
    let (mets, steps) = rr.run(3).unwrap();
    assert_eq!(steps, v.meta.random_rollout_steps * v.meta.num_envs);
    assert!(mets.get("reward").unwrap().is_finite());
    // deterministic per seed
    let (mets2, _) = rr.run(3).unwrap();
    assert_eq!(mets.values, mets2.values);
}

#[test]
fn trainer_improves_reward_over_short_run() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let v = m.variant("mix10dc6ac_e12").unwrap();
    let sc = Scenario { traffic: "high".into(), ..Default::default() };
    let opts = TrainOptions {
        seed: 5,
        total_env_steps: 15 * v.meta.batch_size,
        quiet: true,
        ..Default::default()
    };
    let out = trainer::train(&new_engine(), v, &store, &sc, &opts).unwrap();
    let first = out.history.first().unwrap().get("mean_reward").unwrap();
    let last = out.history.last().unwrap().get("mean_reward").unwrap();
    assert!(
        last > first,
        "no learning signal: first {first}, last {last}"
    );

    // trained params evaluate
    let evals = trainer::evaluate(&new_engine(), &out.session, &store, &sc, 0..3).unwrap();
    assert_eq!(evals.len(), 3);
    assert!(evals[0].get("ep_reward").unwrap().is_finite());
}

#[test]
fn scenario_swap_changes_exog_not_carry() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let v = m.variant("mix10dc6ac_e12").unwrap();
    let mut s = TrainSession::new(&new_engine(), v, &store, &Scenario::default(), 9).unwrap();
    s.step().unwrap();
    let steps_before = s.env_steps_done;
    // swap to crisis-year prices mid-training (fig5 machinery)
    s.set_scenario(&store, &Scenario { year: 2022, ..Default::default() })
        .unwrap();
    let m2 = s.step().unwrap();
    assert!(m2.get("mean_reward").unwrap().is_finite());
    assert_eq!(s.env_steps_done, steps_before + v.meta.batch_size);
}
