//! Generalist shared-trunk policy: end-to-end determinism + holdout
//! carve-out (ISSUE 7).
//!
//! Headline properties:
//! * A full generalist training iteration — fused rollout through the
//!   shared trunk AND the pooled cross-family `update_generalist_sharded`
//!   — produces bit-identical weights at `--threads` 1, 4, and max.
//! * Scenario cells named by the spec's `holdout` key never appear in any
//!   training lane of the expanded plan, yet survive as named zero-shot
//!   eval cells.

use chargax::baselines::ppo::PpoParams;
use chargax::fleet::{expand, Fleet, FleetPpoTrainer, FleetSpec};

/// The built-in demo grid with one of the mixed family's four cells held
/// out for zero-shot eval.
fn demo_with_holdout(seed: u64) -> FleetSpec {
    let mut spec = FleetSpec::demo(seed, 1);
    spec.holdout = vec!["shopping/NL/2022/high".to_string()];
    spec
}

/// ISSUE 7 tentpole gate: two generalist iterations (so Adam state and
/// the second rollout's updated trunk are covered) over a fleet WITH a
/// holdout cell are bit-identical at `--threads` 1, 4, and max — the
/// cross-family gradient accumulation reduces through one fixed-order
/// tree, so pool width must be invisible in the weights and the
/// per-family stats.
#[test]
fn generalist_training_iteration_is_thread_count_invariant() {
    let run = |threads: usize| -> (Vec<f32>, Vec<(f32, f32)>) {
        let mut fleet = Fleet::from_spec(&demo_with_holdout(9), None).unwrap();
        fleet.set_threads(threads);
        let hp = PpoParams {
            rollout_steps: 24,
            n_minibatches: 2,
            update_epochs: 2,
            hidden: 16,
            threads,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new_generalist(hp, fleet, 5);
        assert_eq!(tr.policy.label(), "generalist");
        let mut stats = Vec::new();
        for _ in 0..2 {
            for s in tr.iteration() {
                stats.push((s.total_loss, s.entropy));
            }
        }
        (tr.policy.params_flat(), stats)
    };
    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let (w1, s1) = run(1);
    let (w4, s4) = run(4);
    let (wm, sm) = run(max_threads);
    assert_eq!(s1, s4, "threads 1 vs 4: per-family stats drifted");
    assert_eq!(s1, sm, "threads 1 vs max: per-family stats drifted");
    assert_eq!(w1.len(), w4.len());
    for (k, (a, b)) in w1.iter().zip(&w4).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "threads 1 vs 4: weight {k} not bit-identical");
    }
    for (k, (a, b)) in w1.iter().zip(&wm).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "threads 1 vs max: weight {k} not bit-identical");
    }
}

/// Holdout cells are carved out of the EXPANDED LANE PLAN itself — not
/// merely skipped at rollout time: no training lane of any family maps to
/// a held cell, the held cell is absent from the trainable cell list, and
/// every family keeps its configured lane count (remaining cells absorb
/// the held cell's lanes).
#[test]
fn holdout_cells_never_enter_training_lanes() {
    let held = "shopping/NL/2022/high";
    let spec = demo_with_holdout(9);
    let plans = expand(&spec, None).unwrap();
    let baseline = expand(&FleetSpec::demo(9, 1), None).unwrap();
    assert_eq!(plans.len(), baseline.len());
    let mut held_seen = 0usize;
    for (fam, base) in plans.iter().zip(&baseline) {
        // Lane counts are preserved: the carve-out redistributes lanes,
        // it never shrinks the family.
        assert_eq!(fam.lane_scenario.len(), base.lane_scenario.len(), "{}", fam.label);
        assert_eq!(fam.seeds.len(), base.seeds.len(), "{}", fam.label);
        // The held cell is not a trainable cell...
        assert!(
            !fam.cell_names.iter().any(|n| n == held),
            "{}: held cell still in trainable cell list",
            fam.label
        );
        // ...and every lane points at a real trainable cell.
        for (lane, &cell) in fam.lane_scenario.iter().enumerate() {
            assert!(
                cell < fam.cell_names.len(),
                "{} lane {lane}: scenario index {cell} out of range",
                fam.label
            );
        }
        held_seen += fam.holdout_names.iter().filter(|n| n.as_str() == held).count();
        assert_eq!(fam.holdout_names.len(), fam.holdout_tables.len(), "{}", fam.label);
    }
    assert_eq!(held_seen, 1, "held cell must survive as exactly one zero-shot eval cell");

    // The same invariant via the built fleet: the holdout cell is
    // reported for eval but owns zero lanes and no cell label.
    let fleet = Fleet::from_spec(&spec, None).unwrap();
    let mut found = false;
    for e in 0..fleet.n_envs() {
        for cell in 0..fleet.env(e).n_scenarios() {
            assert_ne!(fleet.cell_label(e, cell), held, "family {e} trains the held cell");
        }
        for (name, _tables) in fleet.holdout_cells(e) {
            assert_eq!(name, held);
            found = true;
        }
    }
    assert!(found, "held cell missing from the fleet's holdout set");
}

/// Zero-shot reporting end to end: after a (tiny) generalist training
/// run, per-cell eval emits exactly one extra row for the held cell,
/// marked `holdout` with `lanes == 0`, alongside the trained cells.
#[test]
fn generalist_eval_reports_heldout_cell_zero_shot() {
    let mut fleet = Fleet::from_spec(&demo_with_holdout(11), None).unwrap();
    fleet.set_threads(2);
    let hp = PpoParams {
        rollout_steps: 12,
        n_minibatches: 2,
        update_epochs: 1,
        hidden: 16,
        ..Default::default()
    };
    let mut tr = FleetPpoTrainer::new_generalist(hp, fleet, 3);
    tr.iteration();
    let evals = tr.eval_all_cells_current();
    let held: Vec<_> = evals.iter().filter(|c| c.holdout).collect();
    assert_eq!(held.len(), 1, "exactly one zero-shot row");
    let h = held[0];
    assert_eq!(h.cell, "shopping/NL/2022/high");
    assert_eq!(h.lanes, 0, "holdout cells own no training lanes");
    assert!(h.episodes >= 1, "zero-shot eval must complete an episode");
    assert!(h.reward.is_finite() && h.profit.is_finite());
    for c in evals.iter().filter(|c| !c.holdout) {
        assert!(c.lanes > 0, "{}/{}: trained cell without lanes", c.family, c.cell);
        assert_ne!(c.cell, h.cell, "held cell leaked into trained rows");
        assert!(c.episodes >= 1, "{}/{}", c.family, c.cell);
    }
}
