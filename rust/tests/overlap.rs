//! Overlapped (double-buffered) training == the barrier oracle, bitwise.
//!
//! `--overlap on` streams iteration k+1's fused rollout on the pool's
//! pipeline lane while the caller finishes iteration k's accounting,
//! stats, and interleaved eval. The determinism contract says the mode
//! flag may only move WHEN work executes, never WHAT is computed: the
//! per-iteration rng draw order (policy seed, update permutations, eval
//! seed) forms the same global sequence either way. These tests prove
//! weights, per-iteration stats, and per-cell greedy evals bit-identical
//! between the two modes at `--threads` 1, 4, and max, for all three
//! training paths (per-family, generalist, grid-coupled) and the
//! single-family `PpoTrainer`, plus the eval-interleaving
//! order-independence and the `set_grids` named-error regression.

use std::sync::Arc;

use chargax::baselines::ppo::{PpoParams, PpoTrainer};
use chargax::env::scalar::ScenarioTables;
use chargax::env::tree::StationConfig;
use chargax::env::vector::VectorEnv;
use chargax::fleet::{CurtailPolicy, Fleet, FleetPpoTrainer, FleetSpec, GridSpec};

fn hp(threads: usize, overlap: bool) -> PpoParams {
    PpoParams {
        rollout_steps: 24,
        n_minibatches: 2,
        update_epochs: 2,
        hidden: 16,
        threads,
        overlap,
        ..Default::default()
    }
}

fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// One short fleet training run — three iterations, the last through
/// `final_iteration` so both modes perform exactly three rollouts —
/// returning flattened weights, per-iteration stat bits, and the closing
/// per-cell eval bits.
#[allow(clippy::type_complexity)]
fn run_fleet(
    spec: &FleetSpec,
    generalist: bool,
    threads: usize,
    overlap: bool,
) -> (Vec<u32>, Vec<(u32, u32)>, Vec<(String, u32, u32)>) {
    let mut fleet = Fleet::from_spec(spec, None).unwrap();
    fleet.set_threads(threads);
    let params = hp(threads, overlap);
    let mut tr = if generalist {
        FleetPpoTrainer::new_generalist(params, fleet, 5)
    } else {
        FleetPpoTrainer::new(params, fleet, 5)
    };
    let mut stats = Vec::new();
    for i in 0..3 {
        let s = if i == 2 { tr.final_iteration() } else { tr.iteration() };
        for f in s {
            stats.push((f.total_loss.to_bits(), f.entropy.to_bits()));
        }
    }
    let evals = tr
        .eval_all_cells_current()
        .into_iter()
        .map(|c| {
            (format!("{}/{}", c.family, c.cell), c.reward.to_bits(), c.profit.to_bits())
        })
        .collect();
    let weights = tr.policy.params_flat().iter().map(|w| w.to_bits()).collect();
    (weights, stats, evals)
}

fn assert_overlap_matches_barrier(spec: &FleetSpec, generalist: bool) {
    for threads in [1usize, 4, max_threads()] {
        let (w_off, s_off, e_off) = run_fleet(spec, generalist, threads, false);
        let (w_on, s_on, e_on) = run_fleet(spec, generalist, threads, true);
        assert_eq!(
            s_off, s_on,
            "threads={threads}: per-iteration stats drifted between overlap modes"
        );
        assert_eq!(w_off.len(), w_on.len(), "threads={threads}: weight count");
        for (k, (a, b)) in w_off.iter().zip(&w_on).enumerate() {
            assert_eq!(a, b, "threads={threads}: weight {k} not bit-identical");
        }
        assert_eq!(
            e_off, e_on,
            "threads={threads}: per-cell evals drifted between overlap modes"
        );
    }
}

/// Tentpole gate, per-family path: overlap on == off bitwise at threads
/// {1, 4, max}.
#[test]
fn overlap_is_bit_identical_per_family() {
    assert_overlap_matches_barrier(&FleetSpec::demo(9, 1), false);
}

/// Tentpole gate, generalist path: one shared trunk, same proof.
#[test]
fn overlap_is_bit_identical_generalist() {
    assert_overlap_matches_barrier(&FleetSpec::demo(9, 1), true);
}

/// Tentpole gate, grid-coupled path: the two-phase propose -> allocate ->
/// commit step streams on the pipeline lane too.
#[test]
fn overlap_is_bit_identical_grid_coupled() {
    assert_overlap_matches_barrier(&FleetSpec::demo_coupled(9, 1), false);
}

/// Tentpole gate, single-family comparator: `PpoTrainer` double-buffers
/// through the same pipeline lane; weights, stats, and the greedy eval
/// episode are bit-identical between modes at every thread count.
#[test]
fn overlap_is_bit_identical_single_env_ppo() {
    #[allow(clippy::type_complexity)]
    let run = |threads: usize, overlap: bool| -> (Vec<u32>, Vec<(u32, u32, u32)>, (u32, u32)) {
        let tables = Arc::new(ScenarioTables::synthetic(1.2));
        // 128 lanes: wide enough to shard at threads >= 2, so the
        // prefetch actually engages off the rollout pool.
        let params = PpoParams { num_envs: 128, rollout_steps: 16, ..hp(threads, overlap) };
        let mut tr = PpoTrainer::new(params, StationConfig::default(), tables, 11);
        let mut stats = Vec::new();
        for i in 0..3 {
            let s = if i == 2 { tr.final_iteration() } else { tr.iteration() };
            stats.push((
                s.total_loss.to_bits(),
                s.entropy.to_bits(),
                s.mean_reward.to_bits(),
            ));
        }
        let weights: Vec<u32> = tr
            .learner
            .mlp
            .params()
            .into_iter()
            .flat_map(|p| p.iter().map(|w| w.to_bits()).collect::<Vec<_>>())
            .collect();
        let (r, p) = tr.eval_episode(77);
        (weights, stats, (r.to_bits(), p.to_bits()))
    };
    for threads in [1usize, 4, max_threads()] {
        let off = run(threads, false);
        let on = run(threads, true);
        assert_eq!(off.1, on.1, "threads={threads}: stats drifted between overlap modes");
        assert_eq!(off.0, on.0, "threads={threads}: weights not bit-identical");
        assert_eq!(off.2, on.2, "threads={threads}: eval episode drifted");
    }
}

/// Satellite regression: eval episodes interleaved INSIDE the overlap
/// window (`iteration_with_eval`) are bit-identical to running the same
/// iteration and evaluating afterwards — the per-iteration eval seed
/// makes the ordering irrelevant — and interleaved evals never perturb
/// the training trajectory.
#[test]
fn interleaved_eval_is_order_independent_and_pure() {
    let mk = || {
        let mut fleet = Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap();
        fleet.set_threads(4);
        FleetPpoTrainer::new(hp(4, true), fleet, 7)
    };
    // A: evals interleaved with the streaming next-iteration rollout.
    let mut a = mk();
    let (_, ev_a1) = a.iteration_with_eval();
    let (_, ev_a2) = a.iteration_with_eval();
    a.final_iteration();
    // B: same trajectory, evals after each iteration returns.
    let mut b = mk();
    b.iteration();
    let ev_b1 = b.eval_all_cells_current();
    b.iteration();
    let ev_b2 = b.eval_all_cells_current();
    b.final_iteration();
    // C: never evaluates at all.
    let mut c = mk();
    c.iteration();
    c.iteration();
    c.final_iteration();

    for (it, (ia, ib)) in [(&ev_a1, &ev_b1), (&ev_a2, &ev_b2)].iter().enumerate() {
        assert_eq!(ia.len(), ib.len(), "iteration {it}: eval row count");
        for (x, y) in ia.iter().zip(ib.iter()) {
            assert_eq!(x.cell, y.cell, "iteration {it}: cell order");
            assert_eq!(
                x.reward.to_bits(),
                y.reward.to_bits(),
                "iteration {it} {}/{}: interleaved eval reward drifted",
                x.family,
                x.cell
            );
            assert_eq!(
                x.profit.to_bits(),
                y.profit.to_bits(),
                "iteration {it} {}/{}: interleaved eval profit drifted",
                x.family,
                x.cell
            );
        }
    }
    let wa: Vec<u32> = a.policy.params_flat().iter().map(|w| w.to_bits()).collect();
    let wb: Vec<u32> = b.policy.params_flat().iter().map(|w| w.to_bits()).collect();
    let wc: Vec<u32> = c.policy.params_flat().iter().map(|w| w.to_bits()).collect();
    assert_eq!(wa, wc, "interleaved evals perturbed training");
    assert_eq!(wb, wc, "trailing evals perturbed training");
}

/// Satellite regression (fleet/rollout.rs feeder-capacity panic): invalid
/// feeder capacities are rejected at `set_grids` construction time with a
/// named error — feeder + family — instead of panicking at rollout time
/// deep inside the allocate phase.
#[test]
fn set_grids_rejects_invalid_feeder_capacities_by_name() {
    let mk_fleet = || {
        let tables = Arc::new(ScenarioTables::synthetic(1.0));
        let envs = vec![
            VectorEnv::new(StationConfig::default(), Arc::clone(&tables), 2, 1),
            VectorEnv::new(StationConfig::default(), Arc::clone(&tables), 2, 2),
        ];
        Fleet::from_envs(envs, vec!["alpha".into(), "beta".into()]).unwrap()
    };
    let gs = |cap: Option<f32>| GridSpec {
        feeder: "sub-7".into(),
        capacity_kw: cap,
        policy: CurtailPolicy::Proportional,
    };

    // Null capacity (a doc-only entry that must not couple).
    let err =
        mk_fleet().set_grids(vec![Some(gs(None)), None]).unwrap_err().to_string();
    assert!(err.contains("sub-7"), "error must name the feeder: {err}");
    assert!(err.contains("alpha"), "error must name the family: {err}");
    assert!(err.contains("capacity_kw"), "error must name the field: {err}");

    // Non-finite and non-positive capacities.
    for bad in [f32::NAN, f32::INFINITY, 0.0, -5.0] {
        let err = mk_fleet()
            .set_grids(vec![None, Some(gs(Some(bad)))])
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("sub-7") && err.contains("beta"),
            "capacity {bad}: error must name feeder and family: {err}"
        );
    }

    // Entry-count mismatch.
    assert!(mk_fleet().set_grids(vec![None]).is_err());

    // Two families naming one feeder with different definitions.
    let err = mk_fleet()
        .set_grids(vec![Some(gs(Some(100.0))), Some(gs(Some(200.0)))])
        .unwrap_err()
        .to_string();
    assert!(err.contains("sub-7"), "conflict error must name the feeder: {err}");

    // Valid round trip: one agreed concrete capacity couples both.
    let mut fleet = mk_fleet();
    fleet.set_grids(vec![Some(gs(Some(150.0))), Some(gs(Some(150.0)))]).unwrap();
    assert!(fleet.has_coupling());
    assert!(fleet.grid(0).is_some_and(GridSpec::coupled));
}
