//! `cargo bench` — L3 runtime microbenchmarks, two sections:
//!
//! 1. **Telemetry overhead** (always runs, no artifacts needed): full
//!    native PPO iterations (fused rollout + sharded update) timed with
//!    the telemetry layer off vs on (including the per-iteration drain).
//!    The ISSUE 8 budget is < 2% — the recorder must stay a thread-local
//!    Vec push per span — and the measured ratio lands in
//!    `BENCH_overhead.json` so `scripts/bench_ratchet.py --overhead`
//!    can gate it in CI.
//! 2. **PJRT call overhead** (gated on `make artifacts`): per-step
//!    env_step vs fused rollout — the paper's core architectural claim
//!    transposed to AOT — plus literal build/convert costs.

use std::sync::Arc;

use chargax::baselines::ppo::{PpoParams, PpoTrainer};
use chargax::coordinator::session::RandomRollout;
use chargax::data::{DataStore, Scenario};
use chargax::env::scalar::ScenarioTables;
use chargax::env::tree::StationConfig;
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::runtime::tensor::Tensor;
use chargax::telemetry;
use chargax::util::stats;

fn main() {
    telemetry_overhead();
    pjrt_overhead();
}

/// Env-steps/sec through full PPO iterations with telemetry off vs on.
/// Runs are interleaved off/on and the best rep per mode is kept, so a
/// one-off scheduler hiccup cannot masquerade as recorder overhead.
fn telemetry_overhead() {
    const B: usize = 256;
    const T_LEN: usize = 32;
    const ITERS: usize = 5;
    const REPS: usize = 3;

    println!("== telemetry overhead (native PPO iteration, B={B} T={T_LEN}) ==\n");

    let run = |on: bool| -> f64 {
        telemetry::set_enabled(on);
        telemetry::drain();
        let params = PpoParams {
            num_envs: B,
            rollout_steps: T_LEN,
            hidden: 32,
            ..Default::default()
        };
        let tables = Arc::new(ScenarioTables::synthetic(1.0));
        let mut tr = PpoTrainer::new(params, StationConfig::default(), tables, 11);
        tr.iteration(); // warm: pool spawn, buffer allocs
        telemetry::drain();
        let t0 = std::time::Instant::now();
        for _ in 0..ITERS {
            tr.iteration();
            if on {
                // The per-iteration drain is part of the enabled path's
                // real cost; charge it to the "on" rate.
                let _ = telemetry::drain();
            }
        }
        let el = t0.elapsed().as_secs_f64();
        telemetry::set_enabled(false);
        telemetry::drain();
        (ITERS * B * T_LEN) as f64 / el
    };

    let (mut best_off, mut best_on) = (0.0f64, 0.0f64);
    for _ in 0..REPS {
        best_off = best_off.max(run(false));
        best_on = best_on.max(run(true));
    }
    let overhead_pct = (best_off / best_on - 1.0) * 100.0;
    println!("telemetry off: {best_off:>10.0} env-steps/s");
    println!("telemetry on:  {best_on:>10.0} env-steps/s");
    println!("overhead:      {overhead_pct:>10.2} %   (budget < 2%, ROADMAP §Telemetry)\n");

    let payload = format!(
        "{{\n  \"note\": \"Telemetry-overhead bench: full native PPO iterations \
         (fused rollout + sharded update) timed with the span recorder off vs on, \
         best of {REPS} interleaved reps. overhead_pct = (off/on - 1) * 100; \
         gated < 2% by scripts/bench_ratchet.py --overhead.\",\n  \"rows\": [\n    \
         {{\"variant\": \"telemetry-overhead\", \"batch\": {B}, \
         \"rollout_steps\": {T_LEN}, \"iters\": {ITERS}, \
         \"steps_per_sec_off\": {best_off:.1}, \"steps_per_sec_on\": {best_on:.1}, \
         \"overhead_pct\": {overhead_pct:.3}}}\n  ]\n}}\n"
    );
    write_bench_json("BENCH_overhead.json", &payload);
}

fn write_bench_json(name: &str, payload: &str) {
    let repo_root = format!("{}/../{name}", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&repo_root, payload) {
        Ok(()) => println!("wrote {repo_root}"),
        Err(_) => match std::fs::write(name, payload) {
            Ok(()) => println!("wrote {name} (cwd)"),
            Err(e) => eprintln!("could not write {name}: {e}"),
        },
    }
}

fn pjrt_overhead() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("PJRT bench skipped: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let engine = Engine::cpu().unwrap();
    let sc = Scenario::default();
    let v = manifest.variant("mix10dc6ac_e16").unwrap();

    println!("== L3 runtime microbenchmarks ==\n");

    // literal build cost for the big exog table (365x24 f32)
    let tensors = sc.to_tensors(&store).unwrap();
    let s = stats::bench(10, 100, || {
        let _ = tensors[0].to_literal().unwrap();
    });
    println!("literal build (365x24 f32):   {}", s.fmt_human());

    let lit = tensors[0].to_literal().unwrap();
    let s = stats::bench(10, 100, || {
        let _ = Tensor::from_literal(&lit).unwrap();
    });
    println!("literal -> host tensor:       {}", s.fmt_human());

    // per-step path vs fused path
    let step_exe = engine.load(v.program("env_step").unwrap()).unwrap();
    let reset_exe = engine.load(v.program("env_reset").unwrap()).unwrap();
    let exog: Vec<xla::Literal> =
        tensors.iter().map(|t| t.to_literal().unwrap()).collect();
    let seed = Tensor::scalar_u32(1).to_literal().unwrap();
    let mut ins: Vec<&xla::Literal> = vec![&seed];
    ins.extend(exog.iter());
    let mut state = reset_exe.run_literals(&ins).unwrap();
    state.pop();
    let n_state = state.len();
    let action = Tensor::i32(
        vec![v.meta.num_envs, v.meta.n_ports],
        vec![5; v.meta.num_envs * v.meta.n_ports],
    )
    .unwrap()
    .to_literal()
    .unwrap();
    let s_step = stats::bench(5, 50, || {
        let mut ins: Vec<&xla::Literal> = state.iter().collect();
        ins.push(&action);
        ins.extend(exog.iter());
        let mut outs = step_exe.run_literals(&ins).unwrap();
        outs.truncate(n_state);
        state = outs;
    });
    let naive_rate = v.meta.num_envs as f64 / s_step.mean_s;
    println!(
        "env_step PJRT call (16 envs): {}  -> {:.0} env-steps/s",
        s_step.fmt_human(),
        naive_rate
    );

    let rr = RandomRollout::new(&engine, v, &store, &sc).unwrap();
    rr.run(0).unwrap();
    let s_fused = stats::bench(1, 8, || {
        rr.run(1).unwrap();
    });
    let fused_steps = (v.meta.random_rollout_steps * v.meta.num_envs) as f64;
    let fused_rate = fused_steps / s_fused.mean_s;
    println!(
        "fused 1000-step rollout:      {}  -> {:.0} env-steps/s",
        s_fused.fmt_human(),
        fused_rate
    );
    println!(
        "\nfusion speedup: {:.1}x (this is the paper's vectorize-on-accelerator claim\ntransposed to the AOT setting; see EXPERIMENTS.md §Perf)",
        fused_rate / naive_rate
    );
}
