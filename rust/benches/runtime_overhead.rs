//! `cargo bench` — L3 runtime microbenchmarks: PJRT call overhead
//! (per-step env_step vs fused rollout — the paper's core architectural
//! claim transposed to AOT), literal build/convert costs, compile times.

use chargax::coordinator::session::RandomRollout;
use chargax::data::{DataStore, Scenario};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::runtime::tensor::Tensor;
use chargax::util::stats;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("bench skipped: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let engine = Engine::cpu().unwrap();
    let sc = Scenario::default();
    let v = manifest.variant("mix10dc6ac_e16").unwrap();

    println!("== L3 runtime microbenchmarks ==\n");

    // literal build cost for the big exog table (365x24 f32)
    let tensors = sc.to_tensors(&store).unwrap();
    let s = stats::bench(10, 100, || {
        let _ = tensors[0].to_literal().unwrap();
    });
    println!("literal build (365x24 f32):   {}", s.fmt_human());

    let lit = tensors[0].to_literal().unwrap();
    let s = stats::bench(10, 100, || {
        let _ = Tensor::from_literal(&lit).unwrap();
    });
    println!("literal -> host tensor:       {}", s.fmt_human());

    // per-step path vs fused path
    let step_exe = engine.load(v.program("env_step").unwrap()).unwrap();
    let reset_exe = engine.load(v.program("env_reset").unwrap()).unwrap();
    let exog: Vec<xla::Literal> =
        tensors.iter().map(|t| t.to_literal().unwrap()).collect();
    let seed = Tensor::scalar_u32(1).to_literal().unwrap();
    let mut ins: Vec<&xla::Literal> = vec![&seed];
    ins.extend(exog.iter());
    let mut state = reset_exe.run_literals(&ins).unwrap();
    state.pop();
    let n_state = state.len();
    let action = Tensor::i32(
        vec![v.meta.num_envs, v.meta.n_ports],
        vec![5; v.meta.num_envs * v.meta.n_ports],
    )
    .unwrap()
    .to_literal()
    .unwrap();
    let s_step = stats::bench(5, 50, || {
        let mut ins: Vec<&xla::Literal> = state.iter().collect();
        ins.push(&action);
        ins.extend(exog.iter());
        let mut outs = step_exe.run_literals(&ins).unwrap();
        outs.truncate(n_state);
        state = outs;
    });
    let naive_rate = v.meta.num_envs as f64 / s_step.mean_s;
    println!(
        "env_step PJRT call (16 envs): {}  -> {:.0} env-steps/s",
        s_step.fmt_human(),
        naive_rate
    );

    let rr = RandomRollout::new(&engine, v, &store, &sc).unwrap();
    rr.run(0).unwrap();
    let s_fused = stats::bench(1, 8, || {
        rr.run(1).unwrap();
    });
    let fused_steps = (v.meta.random_rollout_steps * v.meta.num_envs) as f64;
    let fused_rate = fused_steps / s_fused.mean_s;
    println!(
        "fused 1000-step rollout:      {}  -> {:.0} env-steps/s",
        s_fused.fmt_human(),
        fused_rate
    );
    println!(
        "\nfusion speedup: {:.1}x (this is the paper's vectorize-on-accelerator claim\ntransposed to the AOT setting; see EXPERIMENTS.md §Perf)",
        fused_rate / naive_rate
    );
}
