//! `cargo bench` — figure regenerators at bench scale.
//!
//! One section per paper figure (Fig. 4a, 4b/c, 5, 6-8, 9-11), delegating
//! to the same experiment drivers as `chargax bench <id>` but with small
//! budgets so `cargo bench` completes in minutes. Full-scale runs:
//! `chargax bench <id> [--paper_scale true]`.

use chargax::config::RunConfig;

fn main() {
    let dir = chargax::runtime::engine::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("bench skipped: run `make artifacts` first");
        return;
    }
    // Bench-scale budgets: one seed, ~40k env steps per trained agent.
    let mut cfg = RunConfig::default();
    cfg.n_seeds = 1;
    cfg.total_env_steps = 40_000;
    cfg.eval_seeds = 4;
    cfg.scenario.traffic = "high".into();

    // The experiments module lives in the chargax binary; invoke it.
    let exe = std::env::current_exe().unwrap();
    let chargax_bin = exe
        .parent()
        .unwrap() // deps/
        .parent()
        .unwrap() // release/
        .join("chargax");
    if !chargax_bin.exists() {
        eprintln!("bench skipped: build the chargax binary first (cargo build --release)");
        return;
    }
    for fig in ["fig4a", "fig4bc", "fig5", "fig6to8", "fig9to11"] {
        println!("\n================= {fig} (bench scale) =================");
        let status = std::process::Command::new(&chargax_bin)
            .args([
                "bench", fig,
                "--n_seeds", "1",
                "--steps", "40000",
                "--eval_seeds", "4",
                "--traffic", "high",
            ])
            .status()
            .expect("spawn chargax");
        assert!(status.success(), "{fig} failed");
    }
}
