//! `cargo bench` — Table 2 / Fig. 1 timing core: seconds per 100k env
//! steps on every execution path. (criterion is unavailable offline; this
//! uses util::stats' warmup+samples harness. The full paper table with
//! the python comparator is `chargax bench table2`.)

use chargax::baselines::policies::{self, RandomPolicy};
use chargax::baselines::ppo::{PpoParams, PpoTrainer};
use chargax::coordinator::session::{RandomRollout, TrainSession};
use chargax::data::{DataStore, Scenario};
use chargax::env::scalar::{ScalarEnv, ScenarioTables};
use chargax::env::tree::StationConfig;
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::util::rng::Rng;
use chargax::util::stats;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("bench skipped: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let store = DataStore::load(&dir.join("data")).unwrap();
    let engine = Engine::cpu().unwrap();
    let sc = Scenario::default();

    println!("== Table 2 core timings (seconds per 100k env steps) ==\n");

    // Chargax fused random rollout (e16).
    let v16 = manifest.variant("mix10dc6ac_e16").unwrap();
    let rr = RandomRollout::new(&engine, v16, &store, &sc).unwrap();
    rr.run(0).unwrap();
    let chunk = (v16.meta.random_rollout_steps * v16.meta.num_envs) as f64;
    let s = stats::bench(1, 8, || {
        rr.run(1).unwrap();
    });
    println!(
        "chargax random (fused, 16 envs): {}/chunk -> {:.2} s/100k",
        s.fmt_human(),
        s.mean_s * 100_000.0 / chunk
    );

    // Chargax PPO(1) and PPO(16).
    for vkey in ["mix10dc6ac_e1", "mix10dc6ac_e16"] {
        let v = manifest.variant(vkey).unwrap();
        let mut session = TrainSession::new(&engine, v, &store, &sc, 0).unwrap();
        session.step().unwrap();
        let s = stats::bench(0, 5, || {
            session.step().unwrap();
        });
        println!(
            "chargax PPO ({:>2} envs) train_iter: {}/iter -> {:.2} s/100k",
            v.meta.num_envs,
            s.fmt_human(),
            s.mean_s * 100_000.0 / v.meta.batch_size as f64
        );
    }

    // Scalar-gym comparators.
    let mk = || ScenarioTables::build(&store, &sc).unwrap();
    {
        let mut env = ScalarEnv::new(StationConfig::default(), mk(), 7);
        let mut pol = RandomPolicy { rng: Rng::new(3) };
        let s = stats::bench(1, 5, || {
            policies::rollout(&mut env, &mut pol, 20_000);
        });
        println!(
            "scalar-gym random:               {}/20k -> {:.2} s/100k",
            s.fmt_human(),
            s.mean_s * 5.0
        );
    }
    for envs in [1usize, 16] {
        let params = PpoParams { num_envs: envs, ..Default::default() };
        let mut tr = PpoTrainer::new(params, StationConfig::default(), mk, 7);
        tr.iteration();
        let per_iter = (envs * tr.cfg.rollout_steps) as f64;
        let s = stats::bench(0, 3, || {
            tr.iteration();
        });
        println!(
            "scalar-gym PPO ({envs:>2} envs):        {}/iter -> {:.2} s/100k",
            s.fmt_human(),
            s.mean_s * 100_000.0 / per_iter
        );
    }
}
