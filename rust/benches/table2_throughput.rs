//! `cargo bench` — Table 2 / Fig. 1 timing core: seconds per 100k env
//! steps on every execution path. (criterion is unavailable offline; this
//! uses util::stats' warmup+samples harness. The full paper table with
//! the python comparator is `chargax bench table2`.)
//!
//! Always runs the native rows: scalar-gym comparators plus the SoA
//! `VectorEnv` batch sweep B ∈ {1, 16, 256, 1024, 4096} on three
//! runtimes — the persistent worker pool (`native-vector`, the default),
//! the per-call scoped-thread fallback (`native-scoped`), and the fused
//! rollout entry point (`native-rollout`) — and the MLP-policy pair
//! `policy-serial` / `policy-fused` at B ∈ {256, 1024, 4096} (caller
//! -thread `sample_row` vs shard-side `rollout_fused`; same net, so the
//! pair records the shard-parallel policy win), plus the PPO-update pair
//! `update-serial` / `update-sharded` at B ∈ {256, 1024} (caller-thread
//! minibatch backward vs gradient chunks strided over the pool — the
//! shard-parallel learner win), plus the kernel-layer pair
//! `forward-blocked` / `update-blocked` at B ∈ {256, 1024, 4096} (blocked
//! MLP forward alone vs forward + blocked backward, in MLP rows/sec — the
//! tiled GEMM layer measured without env overhead). The PJRT rows run only
//! when AOT artifacts and a real PJRT runtime are present. Writes the
//! machine-readable perf trajectory to `BENCH_table2.json` at the repo
//! root so the numbers are tracked across PRs; the fleet sweep (random +
//! serial-net + fused-net policies, plus the shared-trunk
//! `fleet-generalist` rows at L ∈ {256, 1024}) lands in
//! `BENCH_fleet.json`, and a tiny generalist train + zero-shot per-cell
//! eval writes `EVAL_cells.csv` (the CI bench-smoke artifact).
//!
//! `cargo bench --bench table2_throughput -- --smoke` runs a reduced
//! sweep (B ∈ {1, 64, 256}, policy/update/kernel rows at B=256 only,
//! small step budget) — the CI regression-visibility job.

use std::sync::Arc;

use chargax::baselines::policies::{self, RandomPolicy};
use chargax::baselines::ppo::{self, PpoParams, PpoTrainer};
use chargax::coordinator::session::{RandomRollout, TrainSession};
use chargax::data::{DataStore, Scenario};
use chargax::env::scalar::{ScalarEnv, ScenarioTables};
use chargax::env::tree::StationConfig;
use chargax::env::vector::{self, StepPath, NATIVE_SWEEP_B};
use chargax::fleet::{
    measure_fleet_throughput, measure_fleet_training_throughput, Fleet, FleetBenchPolicy,
    FleetPpoTrainer, FleetSpec,
};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::util::json::{self, Json};
use chargax::util::rng::Rng;
use chargax::util::stats;

struct BenchRow {
    name: String,
    batch: usize,
    steps_per_sec: f64,
    s_per_100k: f64,
}

fn row(name: &str, batch: usize, steps: f64, seconds: f64) -> BenchRow {
    BenchRow {
        name: name.to_string(),
        batch,
        steps_per_sec: steps / seconds,
        s_per_100k: seconds * 100_000.0 / steps,
    }
}

/// Record one batch's (base, contrast) speedup pair: the base path pushes
/// `(b, v, 0.0)`, the contrast path fills slot 2 of the matching batch.
/// Shared by every paired sweep (pool/scoped, policy serial/fused, update
/// serial/sharded) so the find-and-fill bookkeeping exists once.
fn pair_fill(pairs: &mut Vec<(usize, f64, f64)>, b: usize, v: f64, contrast: bool) {
    if contrast {
        if let Some(e) = pairs.iter_mut().find(|e| e.0 == b) {
            e.2 = v;
        }
    } else {
        pairs.push((b, v, 0.0));
    }
}

fn main() {
    // `--smoke`: reduced sweep for per-PR CI regression visibility. B=256
    // stays in the smoke sweep — it is the row scripts/bench_ratchet.py
    // gates on.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (sweep_b, budget): (&[usize], usize) =
        if smoke { (&[1, 64, 256], 12_000) } else { (NATIVE_SWEEP_B, 120_000) };
    let sc = Scenario::default();
    let dir = artifacts_dir();
    let store = DataStore::load(&dir.join("data")).ok();
    let tables: Arc<ScenarioTables> = Arc::new(match &store {
        Some(s) => ScenarioTables::build(s, &sc).expect("tables from artifacts"),
        None => {
            eprintln!("(artifacts/data not exported; using synthetic scenario tables)");
            ScenarioTables::synthetic_for(&sc)
        }
    });

    println!("== Table 2 core timings (seconds per 100k env steps) ==\n");
    let mut rows: Vec<BenchRow> = Vec::new();

    // -- Chargax PJRT rows (gated on artifacts + runtime) -------------------
    match (Manifest::load(&dir), store.as_ref(), Engine::cpu()) {
        (Ok(manifest), Some(store), Ok(engine)) => {
            pjrt_rows(&manifest, store, &engine, &sc, &mut rows);
        }
        (manifest, _, engine) => {
            let why = manifest
                .err()
                .map(|e| format!("{e:#}"))
                .or_else(|| engine.err().map(|e| format!("{e:#}")))
                .unwrap_or_else(|| "artifacts/data missing".into());
            println!("chargax PJRT rows skipped: {why}\n");
        }
    }

    // -- Scalar-gym comparators ---------------------------------------------
    {
        let mut env = ScalarEnv::new(StationConfig::default(), Arc::clone(&tables), 7);
        let mut pol = RandomPolicy { rng: Rng::new(3) };
        let s = stats::bench(1, 5, || {
            policies::rollout(&mut env, &mut pol, 20_000);
        });
        println!(
            "scalar-gym random (B=1):         {}/20k -> {:.2} s/100k",
            s.fmt_human(),
            s.mean_s * 5.0
        );
        rows.push(row("scalar-gym random", 1, 20_000.0, s.mean_s));
    }
    for envs in [1usize, 16] {
        let params = PpoParams { num_envs: envs, ..Default::default() };
        let mut tr = PpoTrainer::new(params, StationConfig::default(), Arc::clone(&tables), 7);
        tr.iteration();
        let per_iter = (envs * tr.cfg.rollout_steps) as f64;
        let s = stats::bench(0, 3, || {
            tr.iteration();
        });
        println!(
            "scalar-gym PPO ({envs:>2} envs):        {}/iter -> {:.2} s/100k",
            s.fmt_human(),
            s.mean_s * 100_000.0 / per_iter
        );
        rows.push(row(&format!("scalar-gym PPO ({envs})"), envs, per_iter, s.mean_s));
    }

    // -- Native sweep: SoA batched env, random actions, three runtimes ------
    // pool (persistent workers, the default step_all path), scoped
    // (per-call thread spawn, the fallback/oracle), and the fused rollout.
    let scalar_b1 = rows
        .iter()
        .find(|r| r.name == "scalar-gym random")
        .map(|r| r.steps_per_sec);
    let mut b1024_speedup = None;
    let mut pool_vs_scoped: Vec<(usize, f64, f64)> = Vec::new();
    for path in [StepPath::Pool, StepPath::Scoped, StepPath::Rollout] {
        println!("\n{} sweep (random actions):", path.label());
        for &b in sweep_b {
            let (steps_per_sec, s_per_100k) =
                vector::measure_throughput(Arc::clone(&tables), b, 0, path, budget);
            let vs = scalar_b1
                .map(|s| format!("  ({:.1}x vs scalar-gym B=1)", steps_per_sec / s))
                .unwrap_or_default();
            println!("  B={b:<5} {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k{vs}");
            if path == StepPath::Pool && b == 1024 {
                b1024_speedup = scalar_b1.map(|s| steps_per_sec / s);
            }
            match path {
                StepPath::Pool => pair_fill(&mut pool_vs_scoped, b, steps_per_sec, false),
                StepPath::Scoped => pair_fill(&mut pool_vs_scoped, b, steps_per_sec, true),
                _ => {}
            }
            rows.push(BenchRow {
                name: format!("{} (B={b})", path.label()),
                batch: b,
                steps_per_sec,
                s_per_100k,
            });
        }
    }
    println!("\npool vs scoped-thread dispatch (steps/s):");
    for (b, pool, scoped) in &pool_vs_scoped {
        if *scoped > 0.0 {
            println!("  B={b:<5} pool {pool:>12.0}  scoped {scoped:>12.0}  ({:.2}x)", pool / scoped);
        }
    }
    if let Some(x) = b1024_speedup {
        println!("\nnative-vector B=1024 vs scalar-gym B=1: {x:.1}x steps/sec");
    }

    // -- Policy rows: real MLP forwards, serial vs fused ---------------------
    // Same net and buffers on both paths; the pair isolates where the
    // policy forward runs (caller thread vs inside the shard tasks). The
    // B=256 policy-fused row stays in the smoke sweep — it is the second
    // row scripts/bench_ratchet.py gates on.
    let policy_b: &[usize] = if smoke { &[256] } else { &[256, 1024, 4096] };
    let mut serial_vs_fused: Vec<(usize, f64, f64)> = Vec::new();
    for path in [StepPath::PolicySerial, StepPath::PolicyFused] {
        println!("\n{} sweep (MLP policy):", path.label());
        for &b in policy_b {
            let (steps_per_sec, s_per_100k) =
                vector::measure_throughput(Arc::clone(&tables), b, 0, path, budget);
            println!("  B={b:<5} {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k");
            let fused = path == StepPath::PolicyFused;
            pair_fill(&mut serial_vs_fused, b, steps_per_sec, fused);
            rows.push(BenchRow {
                name: format!("{} (B={b})", path.label()),
                batch: b,
                steps_per_sec,
                s_per_100k,
            });
        }
    }
    println!("\nserial-policy vs fused-policy rollout (steps/s):");
    for (b, serial, fused) in &serial_vs_fused {
        if *fused > 0.0 && *serial > 0.0 {
            println!(
                "  B={b:<5} serial {serial:>12.0}  fused {fused:>12.0}  ({:.2}x)",
                fused / serial
            );
        }
    }

    // -- Update rows: PPO minibatch update, serial vs pool-sharded -----------
    // Same learner, buffers, and (chunked) math on both rows — the pair
    // isolates where the minibatch forward/backward runs (caller thread
    // vs gradient chunks strided over the worker pool). The B=256
    // update-sharded row stays in the smoke sweep — it is the third row
    // scripts/bench_ratchet.py gates on. The unit is PPO samples
    // (B * T * update_epochs per update call), not env steps.
    let update_b: &[usize] = if smoke { &[256] } else { &[256, 1024] };
    let mut upd_pairs: Vec<(usize, f64, f64)> = Vec::new();
    for sharded in [false, true] {
        let label = if sharded { "update-sharded" } else { "update-serial" };
        println!("\n{label} sweep (PPO minibatch update):");
        for &b in update_b {
            let (samples_per_sec, s_per_100k) =
                ppo::measure_update_throughput(Arc::clone(&tables), b, 0, sharded, budget);
            println!(
                "  B={b:<5} {samples_per_sec:>12.0} samples/s  {s_per_100k:>8.3} s/100k"
            );
            pair_fill(&mut upd_pairs, b, samples_per_sec, sharded);
            rows.push(BenchRow {
                name: format!("{label} (B={b})"),
                batch: b,
                steps_per_sec: samples_per_sec,
                s_per_100k,
            });
        }
    }
    println!("\nserial vs sharded PPO update (samples/s):");
    for (b, serial, sharded) in &upd_pairs {
        if *serial > 0.0 && *sharded > 0.0 {
            println!(
                "  B={b:<5} serial {serial:>12.0}  sharded {sharded:>12.0}  ({:.2}x)",
                sharded / serial
            );
        }
    }

    // -- Kernel rows: blocked MLP forward / forward+backward -----------------
    // Direct microbench of the tiled kernel layer (ISSUE 6) over the bench
    // policy net, same dims as the policy rows: `forward-blocked` runs one
    // B-row blocked forward per rep, `update-blocked` adds a zeroed-grads
    // blocked backward — exactly the shape of a PPO update chunk pass. The
    // unit is MLP rows, not env steps. The B=256 rows stay in the smoke
    // sweep — they are the kernel rows scripts/bench_ratchet.py gates on.
    {
        use chargax::baselines::mlp::{BackwardScratch, Cache};
        use chargax::baselines::ppo::Learner;
        use chargax::env::vector::VectorEnv;

        let kernel_b: &[usize] = if smoke { &[256] } else { &[256, 1024, 4096] };
        let probe = VectorEnv::new(StationConfig::default(), Arc::clone(&tables), 1, 11);
        let d = probe.obs_dim();
        let nvec = probe.action_nvec();
        drop(probe);
        let mut lrng = Rng::new(41);
        let learner = Learner::new(&mut lrng, d, vector::BENCH_POLICY_HIDDEN, nvec);
        let nl = learner.mlp.n_logits;
        let mut orng = Rng::new(5);
        for blocked_update in [false, true] {
            let label = if blocked_update { "update-blocked" } else { "forward-blocked" };
            println!("\n{label} sweep (kernel-layer MLP):");
            for &b in kernel_b {
                let obs: Vec<f32> = (0..b * d).map(|_| orng.normal() * 0.5).collect();
                let mut cache = Cache::empty();
                let mut grads = learner.mlp.zero_grads();
                let mut bw = BackwardScratch::new();
                let dlogits = vec![0.01f32; b * nl];
                let dvalue = vec![0.01f32; b];
                let reps = (budget / b.max(1)).clamp(4, 4_000);
                let mut pass = || {
                    for _ in 0..reps {
                        learner.mlp.forward_reuse(&obs, &mut cache);
                        if blocked_update {
                            grads.zero();
                            learner.mlp.backward_scratch(
                                &obs, &cache, &dlogits, &dvalue, &mut grads, &mut bw,
                            );
                        }
                    }
                };
                pass(); // warm (sizes the cache/scratch buffers)
                let t0 = std::time::Instant::now();
                pass();
                let el = t0.elapsed().as_secs_f64();
                let total_rows = (reps * b) as f64;
                let rows_per_sec = total_rows / el;
                let s_per_100k = el * 100_000.0 / total_rows;
                println!("  B={b:<5} {rows_per_sec:>12.0} rows/s  {s_per_100k:>8.3} s/100k");
                rows.push(BenchRow {
                    name: format!("{label} (B={b})"),
                    batch: b,
                    steps_per_sec: rows_per_sec,
                    s_per_100k,
                });
            }
        }
    }

    // -- Fleet sweep: heterogeneous station families on one pool ------------
    // The demo grid's three structurally different families (mixed AC/DC,
    // DC-fast V2G, battery-less AC) rolled out fused on a single worker
    // pool; rows land in BENCH_fleet.json so the perf trajectory covers
    // the multi-env path from its first PR.
    let fleet_scales: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 16] };
    let mut fleet_rows: Vec<Json> = Vec::new();
    for policy in
        [FleetBenchPolicy::Random, FleetBenchPolicy::SerialNet, FleetBenchPolicy::FusedNet]
    {
        println!(
            "\n{} sweep (demo grid: 3 station families incl. V2G):",
            policy.label()
        );
        for &scale in fleet_scales {
            match measure_fleet_throughput(
                &FleetSpec::demo(7, scale),
                store.as_ref(),
                0,
                budget,
                policy,
            ) {
                Ok((steps_per_sec, s_per_100k, lanes, families)) => {
                    println!(
                        "  L={lanes:<5} ({families} families) {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k"
                    );
                    fleet_rows.push(json::obj(vec![
                        ("variant", Json::Str(format!("{} (L={lanes})", policy.label()))),
                        ("batch", Json::Num(lanes as f64)),
                        ("families", Json::Num(families as f64)),
                        ("steps_per_sec", Json::Num(steps_per_sec)),
                        ("s_per_100k", Json::Num(s_per_100k)),
                    ]));
                }
                Err(e) => println!("  {} scale {scale} skipped: {e:#}", policy.label()),
            }
        }
    }
    // Generalist rows: ONE shared-trunk net across all three families,
    // measured at fixed fleet-wide lane totals (the ratchet gates the
    // L=256 row). `demo_total` splits lanes 2:2:1 across the families so
    // the totals land exactly on the gated batch sizes.
    let gen_lanes: &[usize] = if smoke { &[256] } else { &[256, 1024] };
    println!(
        "\n{} sweep (one shared trunk, 3 family heads):",
        FleetBenchPolicy::GeneralistNet.label()
    );
    for &total in gen_lanes {
        match measure_fleet_throughput(
            &FleetSpec::demo_total(7, total),
            store.as_ref(),
            0,
            budget,
            FleetBenchPolicy::GeneralistNet,
        ) {
            Ok((steps_per_sec, s_per_100k, lanes, families)) => {
                println!(
                    "  L={lanes:<5} ({families} families) {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k"
                );
                fleet_rows.push(json::obj(vec![
                    (
                        "variant",
                        Json::Str(format!(
                            "{} (L={lanes})",
                            FleetBenchPolicy::GeneralistNet.label()
                        )),
                    ),
                    ("batch", Json::Num(lanes as f64)),
                    ("families", Json::Num(families as f64)),
                    ("steps_per_sec", Json::Num(steps_per_sec)),
                    ("s_per_100k", Json::Num(s_per_100k)),
                ]));
            }
            Err(e) => println!(
                "  {} L={total} skipped: {e:#}",
                FleetBenchPolicy::GeneralistNet.label()
            ),
        }
    }
    // Coupled rows: the same fused per-family nets with all three
    // families on one shared feeder (proportional curtailment), so every
    // step pays propose → fixed-order reduce → commit. Matched lane
    // totals with the fused rows make the pair isolate the grid-coupling
    // overhead; the ratchet gates the L=256 row.
    let coupled_lanes: &[usize] = if smoke { &[256] } else { &[256, 1024] };
    println!(
        "\n{} sweep (shared feeder, two-phase step):",
        FleetBenchPolicy::CoupledNet.label()
    );
    for &total in coupled_lanes {
        match measure_fleet_throughput(
            &FleetSpec::demo_coupled_total(7, total),
            store.as_ref(),
            0,
            budget,
            FleetBenchPolicy::CoupledNet,
        ) {
            Ok((steps_per_sec, s_per_100k, lanes, families)) => {
                println!(
                    "  L={lanes:<5} ({families} families) {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k"
                );
                fleet_rows.push(json::obj(vec![
                    (
                        "variant",
                        Json::Str(format!(
                            "{} (L={lanes})",
                            FleetBenchPolicy::CoupledNet.label()
                        )),
                    ),
                    ("batch", Json::Num(lanes as f64)),
                    ("families", Json::Num(families as f64)),
                    ("steps_per_sec", Json::Num(steps_per_sec)),
                    ("s_per_100k", Json::Num(s_per_100k)),
                ]));
            }
            Err(e) => println!(
                "  {} L={total} skipped: {e:#}",
                FleetBenchPolicy::CoupledNet.label()
            ),
        }
    }
    // -- Pipeline rows: barrier vs double-buffered training ------------------
    // Full training iterations (fused rollout + sharded PPO update +
    // accounting) over the demo grid at fixed fleet-wide lane totals,
    // `--overlap off` vs `--overlap on`. Both modes perform bit-identical
    // work (same seeds, same draws), so the pair isolates the wall-clock
    // won by streaming iteration k+1's rollout on the pipeline lane
    // behind iteration k's tail. Rows land in BENCH_table2.json; the
    // ratchet gates the overlapped B=256 row.
    let pipe_lanes: &[usize] = if smoke { &[256] } else { &[256, 1024] };
    let pipe_iters = if smoke { 3 } else { 6 };
    let mut pipe_pairs: Vec<(usize, f64, f64)> = Vec::new();
    for overlap in [false, true] {
        let label = if overlap { "pipeline-overlapped" } else { "pipeline-barrier" };
        println!("\n{label} sweep (full train iterations, demo grid):");
        for &total in pipe_lanes {
            match measure_fleet_training_throughput(
                &FleetSpec::demo_total(7, total),
                store.as_ref(),
                0,
                pipe_iters,
                overlap,
            ) {
                Ok((steps_per_sec, s_per_100k, lanes, families)) => {
                    println!(
                        "  B={lanes:<5} ({families} families) {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k"
                    );
                    pair_fill(&mut pipe_pairs, lanes, steps_per_sec, overlap);
                    rows.push(BenchRow {
                        name: format!("{label} (B={lanes})"),
                        batch: lanes,
                        steps_per_sec,
                        s_per_100k,
                    });
                }
                Err(e) => println!("  {label} B={total} skipped: {e:#}"),
            }
        }
    }
    println!("\nbarrier vs overlapped training pipeline (steps/s):");
    for (b, barrier, overlapped) in &pipe_pairs {
        if *barrier > 0.0 && *overlapped > 0.0 {
            println!(
                "  B={b:<5} barrier {barrier:>12.0}  overlapped {overlapped:>12.0}  ({:.2}x)",
                overlapped / barrier
            );
        }
    }

    let fleet_payload = json::obj(vec![
        ("bench", Json::Str("fleet_throughput".into())),
        ("unit", Json::Str("env_steps".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(fleet_rows)),
    ])
    .to_string();
    write_bench_json("BENCH_fleet.json", &fleet_payload);

    // -- EVAL_cells.csv: per-cell eval on the paper's profit metric ----------
    // A tiny generalist train over the demo grid with one cell held out,
    // then per-cell greedy eval — trained cells AND the zero-shot holdout
    // row, comparable on episodes/reward/profit. CI's bench-smoke job
    // uploads this file as an artifact.
    {
        let mut spec = FleetSpec::demo(7, 1);
        spec.holdout = vec!["shopping/NL/2022/high".to_string()];
        match Fleet::from_spec(&spec, store.as_ref()) {
            Ok(fleet) => {
                let hp = PpoParams {
                    rollout_steps: 24,
                    n_minibatches: 2,
                    update_epochs: 2,
                    hidden: 32,
                    ..Default::default()
                };
                let mut tr = FleetPpoTrainer::new_generalist(hp, fleet, 7);
                let iters = if smoke { 2 } else { 5 };
                for _ in 0..iters {
                    tr.iteration();
                }
                let mut csv =
                    String::from("family,cell,holdout,lanes,episodes,ep_reward,ep_profit\n");
                for c in tr.eval_all_cells_current() {
                    csv.push_str(&format!(
                        "{},{},{},{},{},{:.6},{:.6}\n",
                        c.family, c.cell, c.holdout, c.lanes, c.episodes, c.reward, c.profit
                    ));
                }
                write_bench_json("EVAL_cells.csv", &csv);
            }
            Err(e) => eprintln!("per-cell eval CSV skipped: {e:#}"),
        }
    }

    // -- BENCH_table2.json: perf trajectory across PRs -----------------------
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("variant", Json::Str(r.name.clone())),
                ("batch", Json::Num(r.batch as f64)),
                ("steps_per_sec", Json::Num(r.steps_per_sec)),
                ("s_per_100k", Json::Num(r.s_per_100k)),
            ])
        })
        .collect();
    let mut top = vec![
        ("bench", Json::Str("table2_throughput".into())),
        ("unit", Json::Str("env_steps".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(json_rows)),
    ];
    if let Some(x) = b1024_speedup {
        top.push(("speedup_native_b1024_vs_scalar_b1", Json::Num(x)));
    }
    let payload = json::obj(top).to_string();
    write_bench_json("BENCH_table2.json", &payload);
}

/// Write a bench artifact, preferring the source checkout root (so it is
/// tracked next to the repo); fall back to the current directory when the
/// binary runs from a moved/copied tree.
fn write_bench_json(name: &str, payload: &str) {
    let repo_root = format!("{}/../{name}", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&repo_root, payload) {
        Ok(()) => println!("wrote {repo_root}"),
        Err(_) => match std::fs::write(name, payload) {
            Ok(()) => println!("wrote {name} (cwd)"),
            Err(e) => eprintln!("could not write {name}: {e}"),
        },
    }
}

/// The AOT fast-path rows (only with artifacts + a real PJRT runtime).
fn pjrt_rows(
    manifest: &Manifest,
    store: &DataStore,
    engine: &Engine,
    sc: &Scenario,
    rows: &mut Vec<BenchRow>,
) {
    if let Ok(v16) = manifest.variant("mix10dc6ac_e16") {
        if let Ok(rr) = RandomRollout::new(engine, v16, store, sc) {
            let _ = rr.run(0);
            let chunk = (v16.meta.random_rollout_steps * v16.meta.num_envs) as f64;
            let s = stats::bench(1, 8, || {
                rr.run(1).unwrap();
            });
            println!(
                "chargax random (fused, 16 envs): {}/chunk -> {:.2} s/100k",
                s.fmt_human(),
                s.mean_s * 100_000.0 / chunk
            );
            rows.push(row("chargax random (fused)", 16, chunk, s.mean_s));
        }
    }
    for vkey in ["mix10dc6ac_e1", "mix10dc6ac_e16"] {
        let Ok(v) = manifest.variant(vkey) else { continue };
        let Ok(mut session) = TrainSession::new(engine, v, store, sc, 0) else { continue };
        if session.step().is_err() {
            continue;
        }
        let s = stats::bench(0, 5, || {
            session.step().unwrap();
        });
        println!(
            "chargax PPO ({:>2} envs) train_iter: {}/iter -> {:.2} s/100k",
            v.meta.num_envs,
            s.fmt_human(),
            s.mean_s * 100_000.0 / v.meta.batch_size as f64
        );
        rows.push(row(
            &format!("chargax PPO ({})", v.meta.num_envs),
            v.meta.num_envs,
            v.meta.batch_size as f64,
            s.mean_s,
        ));
    }
}
