//! Paper table/figure regenerators (`chargax bench <id>`).
//!
//! Every experiment in the paper's evaluation maps to one function here
//! (DESIGN.md §Experiment-index). Budgets are scaled for the CPU-PJRT
//! testbed; `--paper_scale true` restores the paper's (GPU-sized) budgets.
//! Results print as the paper's rows/series and also land in runs/*.csv.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use chargax::baselines::policies::{self, RandomPolicy};
use chargax::baselines::ppo::{PpoParams, PpoTrainer};
use chargax::config::RunConfig;
use chargax::coordinator::metrics;
use chargax::coordinator::session::RandomRollout;
use chargax::coordinator::trainer::{self, TrainOptions};
use chargax::data::{DataStore, Scenario};
use chargax::env::scalar::{ScalarEnv, ScenarioTables};
use chargax::env::tree::StationConfig;
use chargax::env::vector::{self, StepPath, NATIVE_SWEEP_B};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::util::rng::Rng;
use chargax::util::stats;

pub fn run(id: &str, cfg: &RunConfig) -> Result<()> {
    std::fs::create_dir_all("runs").ok();
    match id {
        "table2" => table2(cfg),
        "fig4a" => fig4a(cfg),
        "fig4bc" => fig4bc(cfg),
        "fig5" => fig5(cfg),
        "fig6to8" => fig_scenarios(cfg, &["EU", "US", "WORLD"], &["mix10dc6ac_e12"], "fig6to8"),
        "fig9to11" => fig_scenarios(
            cfg,
            &["EU"],
            &["ac16_e12", "mix8dc8ac_e12", "dc16_e12"],
            "fig9to11",
        ),
        "perf" => perf(cfg),
        "fleet" => fleet_bench(cfg),
        other => anyhow::bail!(
            "unknown experiment '{other}' (table2 fig4a fig4bc fig5 fig6to8 fig9to11 perf fleet)"
        ),
    }
}

fn setup() -> Result<(Manifest, DataStore, Engine)> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let engine = Engine::cpu()?;
    Ok((manifest, store, engine))
}

// ---------------------------------------------------------------------------
// Table 2 + Fig. 1: seconds per 100k env steps (Random / PPO(1) / PPO(16)).
// ---------------------------------------------------------------------------

fn table2(cfg: &RunConfig) -> Result<()> {
    let sc = &cfg.scenario;
    const TARGET: f64 = 100_000.0;

    println!("Table 2 — seconds to complete 100k environment steps");
    println!("(Chargax = this repo's AOT fast path; native-vector = SoA batched Rust env;");
    println!(" scalar-gym = per-step CPU simulator; python-gym = per-step numpy simulator)\n");

    // Scenario tables: built once from artifacts when available, otherwise
    // synthesized — shared across every env below via Arc.
    let store = DataStore::load(&artifacts_dir().join("data")).ok();
    if store.is_none() {
        println!("  (artifacts/data not exported; scalar/native rows use synthetic tables)");
    }
    let tables: Arc<ScenarioTables> = Arc::new(match &store {
        Some(s) => ScenarioTables::build(s, sc)?,
        None => ScenarioTables::synthetic_for(sc),
    });

    // (name, chargax_s, scalar_s, python_s, native_s) per 100k steps.
    let mut rows: Vec<(String, Option<f64>, Option<f64>, Option<f64>, Option<f64>)> = vec![
        ("Random".into(), None, None, None, None),
        ("PPO (1)".into(), None, None, None, None),
        ("PPO (16)".into(), None, None, None, None),
    ];

    // -- Chargax PJRT rows (need artifacts + a real PJRT runtime) -----------
    match table2_pjrt_rows(sc, TARGET, store.as_ref()) {
        Ok(vals) => {
            for (row, v) in rows.iter_mut().zip(vals) {
                row.1 = Some(v);
            }
        }
        Err(e) => println!("  (chargax PJRT rows skipped: {e:#})"),
    }

    // -- Rust scalar-gym rows ------------------------------------------------
    {
        let mut env = ScalarEnv::new(StationConfig::default(), Arc::clone(&tables), 7);
        let mut pol = RandomPolicy { rng: Rng::new(3) };
        let n = 100_000;
        let t0 = Instant::now();
        policies::rollout(&mut env, &mut pol, n);
        let el = t0.elapsed().as_secs_f64() * TARGET / n as f64;
        rows[0].2 = Some(el);
    }
    for (row, envs) in [(1usize, 1usize), (2, 16)] {
        let params = PpoParams { num_envs: envs, ..Default::default() };
        let mut tr = PpoTrainer::new(params, StationConfig::default(), Arc::clone(&tables), 7);
        tr.iteration(); // warm caches
        let measure_steps = 24_000.max(tr.cfg.num_envs * tr.cfg.rollout_steps);
        let iters = measure_steps / (tr.cfg.num_envs * tr.cfg.rollout_steps);
        let t0 = Instant::now();
        for _ in 0..iters {
            tr.iteration();
        }
        let el = t0.elapsed().as_secs_f64();
        let steps = (iters * tr.cfg.num_envs * tr.cfg.rollout_steps) as f64;
        rows[row].2 = Some(el * TARGET / steps);
    }

    // -- Native rows: SoA batched env, random actions, three runtimes -------
    // (pool = persistent workers, the default; scoped = per-call thread
    // spawn fallback; rollout = fused act/step/observe into PPO buffers)
    let scalar_random = rows[0].2;
    for path in [StepPath::Pool, StepPath::Scoped, StepPath::Rollout] {
        println!("\n  {} sweep (random actions, threads={}):", path.label(), cfg.num_threads);
        for &b in NATIVE_SWEEP_B {
            let (steps_per_sec, s_per_100k) = vector::measure_throughput(
                Arc::clone(&tables),
                b,
                cfg.num_threads,
                path,
                120_000,
            );
            let vs = scalar_random
                .map(|s| format!("  ({:.1}x vs scalar B=1)", s / s_per_100k))
                .unwrap_or_default();
            println!(
                "    B={b:<5} {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k{vs}"
            );
            rows.push((
                format!("{} (B={b})", path.label()),
                None,
                None,
                None,
                Some(s_per_100k),
            ));
        }
    }

    // -- Policy rows: real MLP forwards, serial caller thread vs fused ------
    // inside the shard tasks (the serial/fused pair isolates where the
    // policy forward runs; same net, same buffers).
    for path in [StepPath::PolicySerial, StepPath::PolicyFused] {
        println!("\n  {} sweep (MLP policy, threads={}):", path.label(), cfg.num_threads);
        for &b in &[256usize, 1024, 4096] {
            let (steps_per_sec, s_per_100k) = vector::measure_throughput(
                Arc::clone(&tables),
                b,
                cfg.num_threads,
                path,
                120_000,
            );
            println!(
                "    B={b:<5} {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k"
            );
            rows.push((
                format!("{} (B={b})", path.label()),
                None,
                None,
                None,
                Some(s_per_100k),
            ));
        }
    }

    // -- Python gym rows (optional subprocess) -------------------------------
    for (row, mode) in [(0usize, "random"), (1, "ppo1"), (2, "ppo16")] {
        match python_gym_bench(mode) {
            Ok(sec) => rows[row].3 = Some(sec),
            Err(e) => eprintln!("  (python-gym {mode} skipped: {e})"),
        }
    }

    println!(
        "\n{:<22} {:>18} {:>18} {:>18} {:>18}",
        "", "Chargax (s)", "scalar-gym (s)", "python-gym (s)", "native-vector (s)"
    );
    let mut csv = String::from("row,chargax_s,scalar_gym_s,python_gym_s,native_vector_s\n");
    let fmt_col = |x: &Option<f64>| {
        x.map(|v| format!("{v:>18.3}")).unwrap_or_else(|| format!("{:>18}", "-"))
    };
    for (name, ours, scalar, py, native) in &rows {
        println!(
            "{name:<22} {} {} {} {}",
            fmt_col(ours),
            fmt_col(scalar),
            fmt_col(py),
            fmt_col(native)
        );
        let cell = |x: &Option<f64>| x.map(|v| v.to_string()).unwrap_or_default();
        writeln!(
            csv,
            "{name},{},{},{},{}",
            cell(ours),
            cell(scalar),
            cell(py),
            cell(native)
        )
        .ok();
    }
    std::fs::write("runs/table2.csv", csv).context("writing runs/table2.csv")?;
    println!("\nwrote runs/table2.csv");
    Ok(())
}

/// The original Chargax AOT rows (Random / PPO(1) / PPO(16)); errors out
/// cleanly when artifacts or the PJRT runtime are unavailable. Takes the
/// caller's already-loaded DataStore so the data stack isn't parsed twice.
fn table2_pjrt_rows(sc: &Scenario, target: f64, store: Option<&DataStore>) -> Result<[f64; 3]> {
    let store = store.context("artifacts/data not exported")?;
    let manifest = Manifest::load(&artifacts_dir())?;
    let engine = Engine::cpu()?;
    // Prefer the CPU-fast kernel routing ("-ref": jnp oracles, XLA-fused)
    // over interpret-mode Pallas; see EXPERIMENTS.md §Perf.
    let pick = |key: &str, fallback: &str| -> anyhow::Result<&chargax::runtime::manifest::Variant> {
        manifest.variant(key).or_else(|_| manifest.variant(fallback))
    };
    let mut out = [0f64; 3];
    {
        let v16 = pick("mix10dc6ac-ref_e16", "mix10dc6ac_e16")?;
        let rr = RandomRollout::new(&engine, v16, &store, sc)?;
        rr.run(0)?; // warm (compile already cached by ::new; first run warms)
        let chunk = (v16.meta.random_rollout_steps * v16.meta.num_envs) as f64;
        let calls = (target / chunk).ceil() as usize;
        let t0 = Instant::now();
        for s in 0..calls {
            rr.run(s as u32 + 1)?;
        }
        let el = t0.elapsed().as_secs_f64();
        out[0] = el * target / (chunk * calls as f64);
        println!("  chargax random: {calls} calls x {chunk} steps -> {:.2}s/100k", out[0]);
    }
    for (i, (label, vkey, fb)) in [
        ("PPO (1)", "mix10dc6ac-ref_e1", "mix10dc6ac_e1"),
        ("PPO (16)", "mix10dc6ac-ref_e16", "mix10dc6ac_e16"),
    ]
    .iter()
    .enumerate()
    {
        let v = pick(vkey, fb)?;
        let mut session =
            chargax::coordinator::session::TrainSession::new(&engine, v, &store, sc, 0)?;
        session.step()?; // warm
        session.reset(0)?;
        let iters = (target / v.meta.batch_size as f64).ceil() as usize;
        let t0 = Instant::now();
        for _ in 0..iters {
            session.step()?;
        }
        let el = t0.elapsed().as_secs_f64();
        out[i + 1] = el * target / (v.meta.batch_size as f64 * iters as f64);
        println!("  chargax {label}: {iters} iters -> {:.2}s/100k", out[i + 1]);
    }
    Ok(out)
}

fn python_gym_bench(mode: &str) -> Result<f64> {
    let steps = match mode {
        "random" => 20_000,
        "ppo1" => 3_000,
        _ => 6_000,
    };
    let out = std::process::Command::new("python")
        .args(["-m", "baselines.bench_gym", "--mode", mode, "--steps", &steps.to_string()])
        .current_dir("python")
        .output()
        .context("spawning python")?;
    if !out.status.success() {
        anyhow::bail!("python exited: {}", String::from_utf8_lossy(&out.stderr));
    }
    let text = String::from_utf8_lossy(&out.stdout);
    let j = chargax::util::json::Json::parse(text.trim())
        .context("parsing bench_gym output")?;
    j.get("seconds_per_100k")
        .and_then(|x| x.as_f64())
        .context("seconds_per_100k missing")
}

// ---------------------------------------------------------------------------
// Fig. 4a: PPO vs max-charge baseline, shopping scenario, 3 traffic levels.
// ---------------------------------------------------------------------------

fn fig4a(cfg: &RunConfig) -> Result<()> {
    let (manifest, store, engine) = setup()?;
    let variant = manifest.variant(&cfg.variant)?;
    let n_seeds = if cfg.paper_scale { 20 } else { cfg.n_seeds };
    let steps = if cfg.paper_scale { 10_000_000 } else { cfg.total_env_steps };

    println!("Fig. 4a — PPO vs max-charge baseline (shopping, {} seeds, {} steps)\n", n_seeds, steps);
    let mut csv = String::from("traffic,seed,iter,env_steps,mean_completed_return\n");
    let mut summary = Vec::new();
    for traffic in ["low", "medium", "high"] {
        let sc = Scenario { traffic: traffic.into(), ..cfg.scenario.clone() };
        // baseline
        let base = trainer::evaluate_baseline(&engine, variant, &store, &sc, "max", 500..510)?;
        let base_profit = metrics::mean(&base)?.get("ep_profit")?;
        let base_reward = metrics::mean(&base)?.get("ep_reward")?;

        let mut finals = Vec::new();
        for seed in 0..n_seeds as u32 {
            let opts = TrainOptions {
                seed,
                total_env_steps: steps,
                quiet: true,
                ..Default::default()
            };
            let out = trainer::train(&engine, variant, &store, &sc, &opts)?;
            for (i, m) in out.history.iter().enumerate() {
                writeln!(
                    csv, "{traffic},{seed},{i},{},{}",
                    (i + 1) * variant.meta.batch_size,
                    m.get("mean_completed_return").unwrap_or(f32::NAN)
                ).ok();
            }
            let evals = trainer::evaluate(&engine, &out.session, &store, &sc, 900..908)?;
            finals.push(metrics::mean(&evals)?);
        }
        let m = metrics::mean(&finals)?;
        let s = metrics::std(&finals)?;
        println!(
            "  traffic={traffic:<7} PPO return {:>9.1} ± {:<7.1} profit {:>9.1} | baseline reward {:>9.1} profit {:>9.1}  -> uplift {:+.1}%",
            m.get("ep_reward")?, s.get("ep_reward")?, m.get("ep_profit")?,
            base_reward, base_profit,
            100.0 * (m.get("ep_profit")? - base_profit) / base_profit.abs().max(1e-6),
        );
        summary.push((traffic, m.get("ep_profit")?, base_profit));
    }
    std::fs::write("runs/fig4a.csv", csv)?;
    println!("\nwrote runs/fig4a.csv (training curves per traffic level/seed)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4b/4c: user-satisfaction sweeps over alpha.
// ---------------------------------------------------------------------------

fn fig4bc(cfg: &RunConfig) -> Result<()> {
    let (manifest, store, engine) = setup()?;
    let variant = manifest.variant(&cfg.variant)?;
    let n_seeds = if cfg.paper_scale { 5 } else { cfg.n_seeds.min(3) };
    let steps = if cfg.paper_scale { 10_000_000 } else { cfg.total_env_steps };
    let eval_seeds = if cfg.paper_scale { 125 } else { 25 };

    let mut csv = String::from("panel,alpha,seed,ep_missing_kwh,ep_overtime_steps,ep_profit\n");
    for (panel, penalty, alphas) in [
        ("4b", "satisfaction0", vec![0.0f32, 0.5, 2.0, 8.0]),
        ("4c", "satisfaction1", vec![0.0f32, 0.05, 0.2, 1.0]),
    ] {
        println!("\nFig. {panel} — alpha_{penalty} sweep ({n_seeds} seeds x {steps} steps, {eval_seeds} eval episodes/seed-batch)");
        println!("  {:>8} {:>16} {:>18} {:>12}", "alpha", "missing kWh/ep", "overtime steps/ep", "profit/ep");
        for &a in &alphas {
            let sc = cfg.scenario.clone().with_alpha(penalty, a)?;
            let mut per_seed = Vec::new();
            for seed in 0..n_seeds as u32 {
                let opts = TrainOptions {
                    seed: seed + 37,
                    total_env_steps: steps,
                    quiet: true,
                    ..Default::default()
                };
                let out = trainer::train(&engine, variant, &store, &sc, &opts)?;
                let evals = trainer::evaluate(
                    &engine, &out.session, &store, &sc,
                    2000..2000 + eval_seeds as u32 / 8,
                )?;
                let m = metrics::mean(&evals)?;
                writeln!(
                    csv, "{panel},{a},{seed},{},{},{}",
                    m.get("ep_missing_kwh")?, m.get("ep_overtime_steps")?, m.get("ep_profit")?
                ).ok();
                per_seed.push(m);
            }
            let m = metrics::mean(&per_seed)?;
            let s = metrics::std(&per_seed)?;
            println!(
                "  {a:>8.2} {:>9.2} ± {:<5.2} {:>11.1} ± {:<5.1} {:>12.1}",
                m.get("ep_missing_kwh")?, s.get("ep_missing_kwh")?,
                m.get("ep_overtime_steps")?, s.get("ep_overtime_steps")?,
                m.get("ep_profit")?
            );
        }
    }
    std::fs::write("runs/fig4bc.csv", csv)?;
    println!("\nwrote runs/fig4bc.csv");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5: distribution shift across NL price years.
// ---------------------------------------------------------------------------

fn fig5(cfg: &RunConfig) -> Result<()> {
    let (manifest, store, engine) = setup()?;
    let variant = manifest.variant(&cfg.variant)?;
    let n_seeds = if cfg.paper_scale { 10 } else { cfg.n_seeds };
    let steps = if cfg.paper_scale { 10_000_000 } else { cfg.total_env_steps };
    let years = [2021u32, 2022, 2023];

    println!("Fig. 5 — train on one NL price year, evaluate on all ({} seeds x {} steps)\n", n_seeds, steps);
    let mut matrix = vec![vec![Vec::<f32>::new(); 3]; 3];
    for (ti, &train_year) in years.iter().enumerate() {
        for seed in 0..n_seeds as u32 {
            let sc = Scenario { year: train_year, ..cfg.scenario.clone() };
            let opts = TrainOptions {
                seed: seed + 100,
                total_env_steps: steps,
                quiet: true,
                ..Default::default()
            };
            let out = trainer::train(&engine, variant, &store, &sc, &opts)?;
            for (ei, &eval_year) in years.iter().enumerate() {
                let esc = Scenario { year: eval_year, ..cfg.scenario.clone() };
                let evals =
                    trainer::evaluate(&engine, &out.session, &store, &esc, 3000..3008)?;
                matrix[ti][ei].push(metrics::mean(&evals)?.get("ep_reward")?);
            }
        }
    }
    println!("  mean episode reward (rows = train year, cols = eval year)");
    println!("  {:>10} {:>12} {:>12} {:>12}", "", "2021", "2022", "2023");
    let mut csv = String::from("train_year,eval_year,mean_reward,std_reward\n");
    for (ti, &ty) in years.iter().enumerate() {
        let mut row = format!("  {ty:>10}");
        for (ei, &ey) in years.iter().enumerate() {
            let xs: Vec<f64> = matrix[ti][ei].iter().map(|x| *x as f64).collect();
            let (m, s) = stats::mean_std(&xs);
            write!(row, " {m:>7.1}±{s:<4.1}").ok();
            writeln!(csv, "{ty},{ey},{m},{s}").ok();
        }
        println!("{row}");
    }
    std::fs::write("runs/fig5.csv", csv)?;
    println!("\nwrote runs/fig5.csv");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6-8 (regions) and Fig. 9-11 (charger mixes): 4 bundled scenarios.
// ---------------------------------------------------------------------------

fn fig_scenarios(
    cfg: &RunConfig,
    regions: &[&str],
    variants: &[&str],
    tag: &str,
) -> Result<()> {
    let (manifest, store, engine) = setup()?;
    let steps = if cfg.paper_scale { 10_000_000 } else { cfg.total_env_steps };
    let scenarios = ["shopping", "work", "residential", "highway"];

    println!("Fig. {tag} — 4 bundled scenarios ({} steps/agent, PPO vs max baseline)\n", steps);
    let mut csv = String::from("variant,region,scenario,ppo_reward,ppo_profit,base_reward,base_profit\n");
    for vkey in variants {
        let variant = manifest.variant(vkey)?;
        for region in regions {
            println!("  [{vkey} / {region} cars]");
            println!(
                "  {:>12} {:>12} {:>12} {:>14} {:>12}",
                "scenario", "PPO reward", "PPO profit", "base reward", "base profit"
            );
            for scen in scenarios {
                let sc = Scenario {
                    scenario: scen.into(),
                    region: region.to_string(),
                    ..cfg.scenario.clone()
                };
                let base =
                    trainer::evaluate_baseline(&engine, variant, &store, &sc, "max", 600..608)?;
                let bm = metrics::mean(&base)?;
                let opts = TrainOptions {
                    seed: cfg.seed,
                    total_env_steps: steps,
                    quiet: true,
                    ..Default::default()
                };
                let out = trainer::train(&engine, variant, &store, &sc, &opts)?;
                let evals = trainer::evaluate(&engine, &out.session, &store, &sc, 700..708)?;
                let m = metrics::mean(&evals)?;
                println!(
                    "  {scen:>12} {:>12.1} {:>12.1} {:>14.1} {:>12.1}",
                    m.get("ep_reward")?, m.get("ep_profit")?,
                    bm.get("ep_reward")?, bm.get("ep_profit")?
                );
                writeln!(
                    csv, "{vkey},{region},{scen},{},{},{},{}",
                    m.get("ep_reward")?, m.get("ep_profit")?,
                    bm.get("ep_reward")?, bm.get("ep_profit")?
                ).ok();
            }
        }
    }
    std::fs::write(format!("runs/{tag}.csv"), csv)?;
    println!("\nwrote runs/{tag}.csv");
    Ok(())
}

// ---------------------------------------------------------------------------
// perf: layer-by-layer profile (EXPERIMENTS.md §Perf data source).
// ---------------------------------------------------------------------------

fn perf(cfg: &RunConfig) -> Result<()> {
    let (manifest, store, engine) = setup()?;
    let sc = &cfg.scenario;
    println!("Perf profile (see EXPERIMENTS.md §Perf)\n");

    // L3 naive wiring: per-step env_step PJRT calls.
    let v = manifest.variant("mix10dc6ac_e16")?;
    let step_spec = v.program("env_step")?;
    let reset_spec = v.program("env_reset")?;
    let step_exe = engine.load(step_spec)?;
    let reset_exe = engine.load(reset_spec)?;
    let exog: Vec<chargax::runtime::tensor::Tensor> = sc.to_tensors(&store)?;
    let exog_lits: Vec<xla::Literal> = exog
        .iter()
        .map(|t| t.to_literal().unwrap())
        .collect();
    let seed = chargax::runtime::tensor::Tensor::scalar_u32(1).to_literal()?;
    let mut inputs: Vec<&xla::Literal> = vec![&seed];
    inputs.extend(exog_lits.iter());
    let mut state = reset_exe.run_literals(&inputs)?;
    state.pop(); // drop obs
    let n_state = state.len();
    let action = chargax::runtime::tensor::Tensor::i32(
        vec![v.meta.num_envs, v.meta.n_ports],
        vec![5; v.meta.num_envs * v.meta.n_ports],
    )?
    .to_literal()?;
    let per_step = stats::bench(3, 50, || {
        let mut ins: Vec<&xla::Literal> = state.iter().collect();
        ins.push(&action);
        ins.extend(exog_lits.iter());
        let mut outs = step_exe.run_literals(&ins).unwrap();
        outs.truncate(n_state);
        state = outs;
    });
    let steps_per_call = v.meta.num_envs as f64;
    println!(
        "L3 naive (per-step env_step calls):  {}  -> {:.0} env-steps/s",
        per_step.fmt_human(),
        steps_per_call / per_step.mean_s
    );

    // L3 fused rollout.
    let rr = RandomRollout::new(&engine, v, &store, sc)?;
    rr.run(0)?;
    let fused = stats::bench(1, 10, || {
        rr.run(1).unwrap();
    });
    let fused_steps = (v.meta.random_rollout_steps * v.meta.num_envs) as f64;
    println!(
        "L3 fused (random_rollout scan):      {}  -> {:.0} env-steps/s  ({:.0}x over naive)",
        fused.fmt_human(),
        fused_steps / fused.mean_s,
        (fused_steps / fused.mean_s) / (steps_per_call / per_step.mean_s)
    );

    // train_iter throughput.
    let mut session =
        chargax::coordinator::session::TrainSession::new(&engine, v, &store, sc, 0)?;
    session.step()?;
    let ti = stats::bench(0, 5, || {
        session.step().unwrap();
    });
    println!(
        "L2 fused train_iter:                 {}  -> {:.0} env-steps/s (incl. PPO update)",
        ti.fmt_human(),
        v.meta.batch_size as f64 / ti.mean_s
    );

    // L1 routing ablation: interpret-mode Pallas vs XLA-fused jnp oracles.
    println!("\nL1 kernel routing (fused 1000-step random rollout, 16 envs):");
    for (label, key) in [
        ("pallas interpret=True", "mix10dc6ac_e16"),
        ("jnp oracles (XLA-fused)", "mix10dc6ac-ref_e16"),
    ] {
        match manifest.variant(key) {
            Ok(vv) => {
                let rr = RandomRollout::new(&engine, vv, &store, sc)?;
                rr.run(0)?;
                let s = stats::bench(1, 8, || {
                    rr.run(1).unwrap();
                });
                let steps = (vv.meta.random_rollout_steps * vv.meta.num_envs) as f64;
                println!(
                    "  {label:<26} {}  -> {:.0} env-steps/s",
                    s.fmt_human(),
                    steps / s.mean_s
                );
            }
            Err(_) => println!("  {label:<26} (variant {key} not built)"),
        }
    }

    // Vectorization scaling: the paper's Fig. 1 lever (more envs per fused
    // call). Variants built by `aot.py --variants ... --merge`.
    println!("\nvectorization scaling (fused rollout + train_iter, jnp-oracle routing):");
    for key in ["mix10dc6ac-ref_e16", "mix10dc6ac-ref_e64", "mix10dc6ac-ref_e256"] {
        let Ok(vv) = manifest.variant(key) else {
            println!("  {key:<22} (not built)");
            continue;
        };
        let rr = RandomRollout::new(&engine, vv, &store, sc)?;
        rr.run(0)?;
        let s = stats::bench(1, 5, || {
            rr.run(1).unwrap();
        });
        let steps = (vv.meta.random_rollout_steps * vv.meta.num_envs) as f64;
        let mut session =
            chargax::coordinator::session::TrainSession::new(&engine, vv, &store, sc, 0)?;
        session.step()?;
        let st = stats::bench(0, 3, || {
            session.step().unwrap();
        });
        println!(
            "  {key:<22} rollout {:>9.0} steps/s | train {:>9.0} steps/s",
            steps / s.mean_s,
            vv.meta.batch_size as f64 / st.mean_s
        );
    }

    // scalar env for reference.
    let mut env = ScalarEnv::new(
        StationConfig::default(),
        ScenarioTables::build(&store, sc)?,
        3,
    );
    let mut pol = RandomPolicy { rng: Rng::new(5) };
    let t0 = Instant::now();
    policies::rollout(&mut env, &mut pol, 100_000);
    let el = t0.elapsed().as_secs_f64();
    println!(
        "scalar-gym reference:                {:.2} s/100k -> {:.0} env-steps/s",
        el,
        100_000.0 / el
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// fleet: heterogeneous multi-station throughput on one worker pool.
// ---------------------------------------------------------------------------

/// `chargax bench fleet` — fused fleet-rollout throughput over the demo
/// scenario grid (or `--fleet spec.json`) at growing lane counts. The
/// multi-env analogue of the Table 2 native sweep; the machine-readable
/// trajectory lands in BENCH_fleet.json via `cargo bench --bench
/// table2_throughput`.
fn fleet_bench(cfg: &RunConfig) -> Result<()> {
    use chargax::fleet::{measure_fleet_throughput, FleetBenchPolicy, FleetSpec};

    let store = DataStore::load(&artifacts_dir().join("data")).ok();
    if store.is_none() {
        println!("  (artifacts/data not exported; using synthetic scenario tables)");
    }
    let base = match cfg.fleet_spec.as_deref() {
        Some("demo") | None => None,
        Some(path) => Some(FleetSpec::from_json_file(path)?),
    };
    println!(
        "Fleet rollout throughput (heterogeneous station families, one worker pool, threads={})\n",
        if cfg.num_threads == 0 { "auto".to_string() } else { cfg.num_threads.to_string() },
    );
    let mut csv =
        String::from("policy,scale,total_lanes,families,steps_per_sec,s_per_100k\n");
    for policy in
        [FleetBenchPolicy::Random, FleetBenchPolicy::SerialNet, FleetBenchPolicy::FusedNet]
    {
        println!("  {}:", policy.label());
        for scale in [1usize, 4, 16] {
            let spec = match &base {
                Some(s) => {
                    // Scale a user-provided spec by multiplying lane counts.
                    let mut s = s.clone();
                    for e in &mut s.specs {
                        e.lanes *= scale;
                    }
                    s
                }
                None => FleetSpec::demo(cfg.seed as u64, scale),
            };
            let (steps_per_sec, s_per_100k, lanes, families) =
                measure_fleet_throughput(&spec, store.as_ref(), cfg.num_threads, 120_000, policy)?;
            println!(
                "    L={lanes:<5} ({families} families) {steps_per_sec:>12.0} steps/s  {s_per_100k:>8.3} s/100k"
            );
            writeln!(
                csv,
                "{},{scale},{lanes},{families},{steps_per_sec},{s_per_100k}",
                policy.label()
            )
            .ok();
        }
    }
    std::fs::write("runs/fleet.csv", csv).context("writing runs/fleet.csv")?;
    println!("\nwrote runs/fleet.csv");
    Ok(())
}

// ---------------------------------------------------------------------------
// cross-check: scalar vs JAX env_step on deterministic sub-transitions.
// ---------------------------------------------------------------------------

pub fn cross_check(_variant: &str) -> Result<String> {
    use chargax::env::tree::{charging_curve, discharging_curve, StationTree};
    use chargax::util::json::Json;

    let path = artifacts_dir().join("data").join("test_vectors.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)?;
    let cases = j.get("cases").and_then(Json::as_arr).context("cases")?;
    let mut n_ok = 0usize;
    let mut out = String::new();
    for (i, case) in cases.iter().enumerate() {
        let kind = case
            .get("kind")
            .and_then(Json::as_str)
            .with_context(|| format!("case {i}: field 'kind' missing or not a string"))?;
        let ok = match kind {
            "constraint" => {
                check_constraint(case).with_context(|| format!("case {i} (constraint)"))?
            }
            "charge" => check_charge(case).with_context(|| format!("case {i} (charge)"))?,
            "curve" => {
                let soc = get_f32(case, "soc").with_context(|| format!("case {i} (curve)"))?;
                let rb = get_f32(case, "r_bar").with_context(|| format!("case {i} (curve)"))?;
                let tau = get_f32(case, "tau").with_context(|| format!("case {i} (curve)"))?;
                let wc = get_f32(case, "want_charge")
                    .with_context(|| format!("case {i} (curve)"))?;
                let wd = get_f32(case, "want_discharge")
                    .with_context(|| format!("case {i} (curve)"))?;
                (charging_curve(soc, rb, tau) - wc).abs() < 1e-3
                    && (discharging_curve(soc, rb, tau) - wd).abs() < 1e-3
            }
            other => anyhow::bail!("case {i}: unknown case kind '{other}'"),
        };
        if ok {
            n_ok += 1;
        } else {
            writeln!(out, "case {i} ({kind}): MISMATCH").ok();
        }
    }
    writeln!(
        out,
        "cross-check: {n_ok}/{} python-exported vectors match the rust scalar env",
        cases.len()
    )
    .ok();
    if n_ok != cases.len() {
        anyhow::bail!("cross-check failures:\n{out}");
    }

    // silence unused import warning path for StationTree used below
    let _ = StationTree::standard(&StationConfig::default());
    Ok(out)
}

fn get_vec(j: &chargax::util::json::Json, k: &str) -> Result<Vec<f32>> {
    j.get(k)
        .and_then(|x| x.as_f32_flat())
        .with_context(|| format!("field '{k}' missing or not a float array"))
}

fn get_f32(j: &chargax::util::json::Json, k: &str) -> Result<f32> {
    j.get(k)
        .and_then(|x| x.as_f64())
        .map(|x| x as f32)
        .with_context(|| format!("field '{k}' missing or not a number"))
}

fn check_constraint(case: &chargax::util::json::Json) -> Result<bool> {
    use chargax::env::tree::StationTree;
    let mut i = get_vec(case, "i_drawn")?;
    let volt = get_vec(case, "volt")?;
    let mem = get_vec(case, "membership")?;
    let lim = get_vec(case, "limits")?;
    let eta = get_vec(case, "eta")?;
    let want_i = get_vec(case, "want_i")?;
    let want_x = get_f32(case, "want_excess")?;
    let p = i.len();
    let n = lim.len();
    let tree = StationTree {
        volt,
        i_max: vec![1.0; p],
        p_max: vec![1.0; p],
        eta_port: vec![1.0; p],
        is_dc: vec![false; p - 1],
        membership: (0..n)
            .map(|r| (0..p).map(|c| mem[r * p + c] > 0.5).collect())
            .collect(),
        node_limit: lim,
        node_eta: eta,
    };
    let x = tree.project_currents(&mut i);
    let ok_i = i
        .iter()
        .zip(&want_i)
        .all(|(a, b)| (a - b).abs() < 1e-2 * (1.0 + b.abs()));
    Ok(ok_i && (x - want_x).abs() < 1e-2 * (1.0 + want_x.abs()))
}

fn check_charge(case: &chargax::util::json::Json) -> Result<bool> {
    use chargax::env::tree::charging_curve;
    let i = get_vec(case, "i_drawn")?;
    let volt = get_vec(case, "volt")?;
    let present = get_vec(case, "present")?;
    let soc = get_vec(case, "soc")?;
    let de = get_vec(case, "de_remain")?;
    let dtr = get_vec(case, "dt_remain")?;
    let cap = get_vec(case, "cap")?;
    let rbar = get_vec(case, "r_bar")?;
    let tau = get_vec(case, "tau")?;
    let dt_hours = get_f32(case, "dt_hours")?;
    let want = case
        .get("want")
        .and_then(|x| x.as_arr())
        .context("field 'want' missing or not an array")?;
    let want_row = |i: usize| -> Result<Vec<f32>> {
        want.get(i)
            .and_then(|x| x.as_f32_flat())
            .with_context(|| format!("field 'want[{i}]' missing or not a float array"))
    };
    let w_soc = want_row(0)?;
    let w_de = want_row(1)?;
    let w_dt = want_row(2)?;
    let w_rh = want_row(3)?;
    let w_e = want_row(4)?;
    for j in 0..i.len() {
        // replicate ref.charge_update_ref per lane
        let p_kw = volt[j] * i[j] / 1000.0 * present[j];
        let mut e = p_kw * dt_hours;
        e = e.min((1.0 - soc[j]) * cap[j]).max(-soc[j] * cap[j]);
        let soc_n = (soc[j] + e / cap[j].max(1e-9)).clamp(0.0, 1.0);
        let de_n = de[j] - e;
        let dt_n = dtr[j] - present[j];
        let rh = charging_curve(soc_n, rbar[j], tau[j]) * present[j];
        let close = |a: f32, b: f32| (a - b).abs() < 1e-3 * (1.0 + b.abs());
        if !(close(soc_n, w_soc[j])
            && close(de_n, w_de[j])
            && close(dt_n, w_dt[j])
            && close(rh, w_rh[j])
            && close(e, w_e[j]))
        {
            return Ok(false);
        }
    }
    Ok(true)
}
