//! Small in-tree substrates (JSON, RNG, bench stats, property testing).
//!
//! These exist because the image's offline crate cache only carries the
//! `xla` dependency closure — see DESIGN.md §Substitutions.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
