//! Minimal JSON parser / writer.
//!
//! The image's offline crate cache has no `serde`/`serde_json`, so the
//! coordinator carries its own small JSON implementation (DESIGN.md
//! §Substitutions). Supports the full JSON grammar minus exotic number
//! forms; numbers parse as f64 (the manifest and data tables only use
//! f64-representable values).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors (None on type mismatch) ---------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a (possibly nested) numeric array into f32s.
    pub fn as_f32_flat(&self) -> Option<Vec<f32>> {
        fn rec(j: &Json, out: &mut Vec<f32>) -> bool {
            match j {
                Json::Num(n) => {
                    out.push(*n as f32);
                    true
                }
                Json::Arr(a) => a.iter().all(|x| rec(x, out)),
                _ => false,
            }
        }
        let mut out = Vec::new();
        if rec(self, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    pub fn as_str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_str().map(|s| s.to_string()))
            .collect()
    }

    // -- writer -------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed by our writers;
                            // map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"h\"i","d":false},"e":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn flat_f32() {
        let j = Json::parse("[[1,2],[3,4.5]]").unwrap();
        assert_eq!(j.as_f32_flat().unwrap(), vec![1.0, 2.0, 3.0, 4.5]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".to_string())
        );
    }
}
