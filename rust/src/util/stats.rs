//! Benchmark timing statistics (criterion is unavailable offline; the
//! bench harnesses under rust/benches use this instead — warmup + N
//! timed samples + mean/std/min, DESIGN.md §Substitutions).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Sample {
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub n: usize,
}

impl Sample {
    pub fn from_durations(xs: &[f64]) -> Sample {
        let n = xs.len().max(1);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        Sample {
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: xs.iter().cloned().fold(0.0, f64::max),
            n,
        }
    }

    pub fn fmt_human(&self) -> String {
        format!(
            "{} ± {} (n={})",
            fmt_duration(self.mean_s),
            fmt_duration(self.std_s),
            self.n
        )
    }
}

/// Time `f` with `warmup` discarded runs then `samples` measured runs.
pub fn bench<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        xs.push(t0.elapsed().as_secs_f64());
    }
    Sample::from_durations(&xs)
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Mean / std over a slice of f64 metrics.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / xs.len().max(2).saturating_sub(1) as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_stats() {
        let s = Sample::from_durations(&[1.0, 2.0, 3.0]);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.std_s - 1.0).abs() < 1e-12);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 3.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn durations_format() {
        assert_eq!(fmt_duration(2.5), "2.50 s");
        assert_eq!(fmt_duration(0.0025), "2.50 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.5 µs");
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0]);
        assert!((m - 3.0).abs() < 1e-12);
        assert!((s - std::f64::consts::SQRT_2).abs() < 1e-9);
    }
}
