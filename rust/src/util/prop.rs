//! Property-testing micro-harness.
//!
//! `proptest` is not in the offline crate cache, so invariant tests use
//! this quickcheck-style helper: N seeded random cases per property, with
//! the failing seed printed for reproduction (no shrinking — cases are
//! generated from compact primitives, so failures are already small).

use super::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 256, seed: 0xC0FFEE }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Run `f(rng)` for each case; panic with the case seed on failure.
    pub fn check<F: FnMut(&mut Rng)>(&self, name: &str, mut f: F) {
        for case in 0..self.cases {
            let case_seed = self
                .seed
                .wrapping_mul(0x9e3779b97f4a7c15)
                .wrapping_add(case as u64);
            let mut rng = Rng::new(case_seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut rng)
            }));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property '{name}' failed on case {case} (seed {case_seed:#x}): {msg}"
                );
            }
        }
    }
}

/// Generators for common test inputs.
pub mod gen {
    use super::Rng;

    pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| rng.range_f32(lo, hi)).collect()
    }

    pub fn vec_f32_len(rng: &mut Rng, min_len: usize, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let len = min_len + rng.below((max_len - min_len + 1) as u32) as usize;
        vec_f32(rng, len, lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        Prop::new(32).check("reflexive", |rng| {
            let x = rng.f32();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        Prop::new(4).check("always-fails", |_| panic!("boom"));
    }

    #[test]
    fn gen_respects_bounds() {
        Prop::new(16).check("gen-bounds", |rng| {
            let v = gen::vec_f32_len(rng, 1, 10, -2.0, 3.0);
            assert!(!v.is_empty() && v.len() <= 10);
            assert!(v.iter().all(|x| (-2.0..=3.0).contains(x)));
        });
    }
}
