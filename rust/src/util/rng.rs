//! Deterministic PRNG + distributions.
//!
//! The offline crate cache has no `rand`/`rand_distr`, so the scalar
//! simulator and the Rust PPO baseline use this small PCG64-based
//! generator (DESIGN.md §Substitutions). Not cryptographic; seeded runs
//! are fully reproducible across platforms.

/// PCG-XSH-RR 64/32 with 64-bit output composed from two draws.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut r = Rng { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc | 1);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let zone = u32::MAX - (u32::MAX % n);
        loop {
            let v = self.next_u32();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-7 {
                let u2 = self.f32();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Poisson sample; Knuth for small lambda, normal approx above 30.
    pub fn poisson(&mut self, lambda: f32) -> u32 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u32;
        }
        let l = (-lambda).exp();
        let mut k = 0u32;
        let mut p = 1.0f32;
        loop {
            p *= self.f32();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 1000 {
                return k; // numeric guard; unreachable for sane lambda
            }
        }
    }

    /// Categorical sample from (unnormalized, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return 0;
        }
        let mut x = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Kumaraswamy(a, b) — the closed-form Beta stand-in used by the JAX
    /// env (transition.py) so both simulators draw from the same family.
    pub fn kumaraswamy(&mut self, a: f32, b: f32) -> f32 {
        let u = self.f32().clamp(1e-6, 1.0 - 1e-6);
        (1.0 - (1.0 - u).powf(1.0 / b)).powf(1.0 / a)
    }

    /// Fisher-Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u32) as usize;
            v.swap(i, j);
        }
        v
    }
}

/// Source of uniform draws in [0, 1): lets samplers (e.g. the PPO
/// categorical heads) run off either the stateful [`Rng`] or a
/// counter-based per-(lane, step) [`CounterRng`] stream.
pub trait Uniform01 {
    fn u01(&mut self) -> f32;
}

impl Uniform01 for Rng {
    fn u01(&mut self) -> f32 {
        self.f32()
    }
}

impl Uniform01 for CounterRng {
    fn u01(&mut self) -> f32 {
        self.f32()
    }
}

/// SplitMix64 finalizer (also the key-derivation hash for [`CounterRng`]).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Counter-based PRNG: output i is a pure hash of (key, i).
///
/// The vectorized environment gives every lane its own `CounterRng`, so a
/// lane's stream depends only on its seed and how many draws it has made —
/// never on which thread stepped it or how the batch was sharded. The
/// distribution methods mirror [`Rng`]'s exactly (same algorithms, same
/// draw counts) so scalar/vector cross-checks can compare streams 1:1.
#[derive(Debug, Clone)]
pub struct CounterRng {
    key: u64,
    ctr: u64,
}

impl CounterRng {
    pub fn new(seed: u64) -> Self {
        CounterRng { key: splitmix64(seed), ctr: 0 }
    }

    /// Independent child stream (used to seed per-lane generators).
    pub fn derive(seed: u64, lane: u64) -> Self {
        CounterRng {
            key: splitmix64(splitmix64(seed) ^ lane.wrapping_mul(0xd1342543de82ef95)),
            ctr: 0,
        }
    }

    /// Independent child stream keyed by two indices — e.g. (lane, step)
    /// for fused policy sampling, where a lane's action stream at step t
    /// must be a pure function of `(seed, lane, t)` so shard placement and
    /// thread count can never perturb it.
    pub fn derive2(seed: u64, a: u64, b: u64) -> Self {
        CounterRng {
            key: splitmix64(
                splitmix64(splitmix64(seed) ^ a.wrapping_mul(0xd1342543de82ef95))
                    ^ b.wrapping_mul(0x2545f4914f6cdd1d),
            ),
            ctr: 0,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let x = splitmix64(self.key ^ self.ctr.wrapping_mul(0x2545f4914f6cdd1d));
        self.ctr = self.ctr.wrapping_add(1);
        x
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let zone = u32::MAX - (u32::MAX % n);
        loop {
            let v = self.next_u32();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (same draw pattern as [`Rng::normal`]).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-7 {
                let u2 = self.f32();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Poisson sample; Knuth for small lambda, normal approx above 30.
    pub fn poisson(&mut self, lambda: f32) -> u32 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u32;
        }
        let l = (-lambda).exp();
        let mut k = 0u32;
        let mut p = 1.0f32;
        loop {
            p *= self.f32();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 1000 {
                return k; // numeric guard; unreachable for sane lambda
            }
        }
    }

    /// Categorical sample from (unnormalized, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return 0;
        }
        let mut x = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Kumaraswamy(a, b) — closed-form Beta stand-in (see [`Rng::kumaraswamy`]).
    pub fn kumaraswamy(&mut self, a: f32, b: f32) -> f32 {
        let u = self.f32().clamp(1e-6, 1.0 - 1e-6);
        (1.0 - (1.0 - u).powf(1.0 / b)).powf(1.0 / a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(3);
        for &lam in &[0.3f32, 2.0, 8.0, 50.0] {
            let n = 20000;
            let m = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((m - lam as f64).abs() < 0.15 * lam as f64 + 0.05, "lam {lam} got {m}");
        }
    }

    #[test]
    fn categorical_distribution() {
        let mut r = Rng::new(4);
        let w = [1.0f32, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..30000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 30000.0 - 0.6).abs() < 0.02);
        assert!((counts[1] as f64 / 30000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn below_unbiased_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(6);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for i in p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn counter_rng_is_stateless_in_thread_order() {
        // Draw-by-draw the stream is a pure function of (key, counter): two
        // clones interleaved arbitrarily agree with a straight-line run.
        let mut a = CounterRng::new(99);
        let reference: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let mut b = CounterRng::new(99);
        let again: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(reference, again);
        assert_ne!(reference[0], CounterRng::new(100).next_u64());
    }

    #[test]
    fn derive2_streams_are_deterministic_and_distinct() {
        let a: Vec<u64> = {
            let mut r = CounterRng::derive2(9, 3, 17);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = CounterRng::derive2(9, 3, 17);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        // Any coordinate change moves the stream.
        assert_ne!(a[0], CounterRng::derive2(10, 3, 17).next_u64());
        assert_ne!(a[0], CounterRng::derive2(9, 4, 17).next_u64());
        assert_ne!(a[0], CounterRng::derive2(9, 3, 18).next_u64());
        // (a, b) is not symmetric: lane 3 step 17 != lane 17 step 3.
        assert_ne!(a[0], CounterRng::derive2(9, 17, 3).next_u64());
    }

    #[test]
    fn counter_rng_lanes_are_independent() {
        let mut x = CounterRng::derive(7, 0);
        let mut y = CounterRng::derive(7, 1);
        let same = (0..32).filter(|_| x.next_u32() == y.next_u32()).count();
        assert!(same < 2, "lane streams look correlated ({same}/32 equal)");
    }

    #[test]
    fn counter_rng_moments() {
        let mut r = CounterRng::new(5);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
        for &lam in &[0.5f32, 4.0] {
            let m = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((m - lam as f64).abs() < 0.15 * lam as f64 + 0.05, "lam {lam} got {m}");
        }
    }

    #[test]
    fn kumaraswamy_support() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.kumaraswamy(2.5, 3.0);
            assert!((0.0..=1.0).contains(&x));
        }
    }
}
