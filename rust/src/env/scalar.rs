//! Scalar per-step CPU simulator — the "classic gym" comparator.
//!
//! This mirrors the JAX environment's semantics (same transition order,
//! same charging curve, same reward; deterministic pieces are cross-checked
//! against python-exported vectors in rust/tests/cross_check.rs) but is
//! architected the way the paper's comparison environments are: one object
//! per station, per-step method calls, per-car loops, host RNG. It is the
//! substrate for the Table 2 baseline rows.

use crate::data::{DataStore, Scenario};
use crate::util::rng::Rng;

use super::tree::{charging_curve, discharging_curve, StationConfig, StationTree};

pub const STEPS_PER_EPISODE: usize = 288;
pub const DT_HOURS: f32 = 1.0 / 12.0;
pub const STEPS_PER_HOUR: usize = 12;
pub const N_LEVELS: usize = 11;
pub const N_LEVELS_BATTERY: usize = 21;
pub const MAX_ARRIVALS: usize = 6;
pub const FIXED_COST_PER_STEP: f32 = 0.25;

/// A parked car (paper A.1 car state).
#[derive(Debug, Clone, Copy, Default)]
pub struct Car {
    pub soc: f32,
    pub de_remain: f32,
    pub dt_remain: f32,
    pub cap: f32,
    pub r_bar: f32, // max kW at this port
    pub tau: f32,
    pub charge_sensitive: bool, // u = 1
}

/// Per-step outcome metrics (mirrors METRIC_FIELDS where applicable).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepInfo {
    pub reward: f32,
    pub profit: f32,
    pub energy_to_cars_kwh: f32,
    pub energy_grid_net_kwh: f32,
    pub excess_kw: f32,
    pub missing_kwh: f32,
    pub overtime_steps: f32,
    pub rejected: f32,
    pub departed: f32,
    pub arrived: f32,
    pub done: bool,
}

/// Scenario data resolved to flat tables (borrowed from the DataStore).
pub struct ScenarioTables {
    pub price_buy: Vec<f32>,       // [days*24]
    pub price_sell_grid: Vec<f32>, // [days*24]
    pub moer: Vec<f32>,            // [days*24]
    pub arrival_rate: Vec<f32>,    // [24]
    pub car_table: Vec<f32>,       // [models*4]
    pub car_weights: Vec<f32>,
    pub user_profile: Vec<f32>, // [6]
    pub n_days: usize,
    pub alpha: [f32; 7],
    pub beta: f32,
    pub p_sell: f32,
    pub traffic: f32,
}

impl ScenarioTables {
    pub fn build(store: &DataStore, sc: &Scenario) -> anyhow::Result<ScenarioTables> {
        let buy = store.price(&sc.country, sc.year)?.clone();
        let sell: Vec<f32> = buy.iter().map(|x| x * sc.feed_in_ratio).collect();
        Ok(ScenarioTables {
            price_sell_grid: sell,
            price_buy: buy,
            moer: store.moer.clone(),
            arrival_rate: store.arrival_shapes[&sc.scenario].clone(),
            car_table: store.car_table.clone(),
            car_weights: store.car_weights[&sc.region].clone(),
            user_profile: store.user_profiles[&sc.scenario].clone(),
            n_days: store.n_days,
            alpha: sc.alpha,
            beta: sc.beta,
            p_sell: sc.p_sell,
            traffic: store.traffic[&sc.traffic],
        })
    }
}

pub struct ScalarEnv {
    pub cfg: StationConfig,
    pub tree: StationTree,
    pub tables: ScenarioTables,
    rng: Rng,
    // state
    pub t: usize,
    pub day: usize,
    pub cars: Vec<Option<Car>>, // per charger
    pub i_drawn: Vec<f32>,      // per port (signed A)
    pub battery_soc: f32,
    pub ep_return: f32,
    pub ep_profit: f32,
}

impl ScalarEnv {
    pub fn new(cfg: StationConfig, tables: ScenarioTables, seed: u64) -> ScalarEnv {
        let tree = StationTree::standard(&cfg);
        let c = cfg.n_chargers();
        let p = cfg.n_ports();
        let mut env = ScalarEnv {
            tree,
            tables,
            rng: Rng::new(seed),
            t: 0,
            day: 0,
            cars: vec![None; c],
            i_drawn: vec![0.0; p],
            battery_soc: cfg.battery_soc0,
            ep_return: 0.0,
            ep_profit: 0.0,
            cfg,
        };
        env.reset();
        env
    }

    pub fn n_ports(&self) -> usize {
        self.cfg.n_ports()
    }

    pub fn obs_dim(&self) -> usize {
        6 * self.cfg.n_chargers() + 3 + 4 + 4
    }

    pub fn action_nvec(&self) -> Vec<usize> {
        let mut v = vec![N_LEVELS; self.cfg.n_chargers()];
        v.push(N_LEVELS_BATTERY);
        v
    }

    pub fn reset(&mut self) {
        self.t = 0;
        self.day = self.rng.below(self.tables.n_days as u32) as usize;
        self.cars.iter_mut().for_each(|c| *c = None);
        self.i_drawn.iter_mut().for_each(|i| *i = 0.0);
        self.battery_soc = self.cfg.battery_soc0;
        self.ep_return = 0.0;
        self.ep_profit = 0.0;
    }

    fn hour(&self) -> usize {
        (self.t / STEPS_PER_HOUR).min(23)
    }

    fn price_idx(&self) -> usize {
        self.day * 24 + self.hour()
    }

    /// One env step. `action[p]` is the discrete level per port.
    pub fn step(&mut self, action: &[usize]) -> StepInfo {
        let c = self.cfg.n_chargers();
        let p = self.cfg.n_ports();
        let price_buy = self.tables.price_buy[self.price_idx()];
        let price_sell_grid = self.tables.price_sell_grid[self.price_idx()];
        let moer = self.tables.moer[self.price_idx()];

        // (i) apply actions: level -> fraction -> clamped signed current.
        let mut i_new = vec![0f32; p];
        for j in 0..c {
            let Some(car) = self.cars[j] else { continue };
            let frac = action[j] as f32 / (N_LEVELS - 1) as f32;
            let p_target = frac * self.tree.p_max[j];
            let r_ch = charging_curve(car.soc, car.r_bar, car.tau);
            let head_up = (1.0 - car.soc) * car.cap / DT_HOURS;
            let p_kw = p_target.min(r_ch).min(head_up).max(0.0);
            i_new[j] = p_kw * 1000.0 / self.tree.volt[j];
        }
        {
            // battery lane: symmetric ladder.
            let half = (N_LEVELS_BATTERY - 1) as f32 / 2.0;
            let frac = action[c] as f32 / half - 1.0;
            let p_target = frac * self.tree.p_max[c];
            let r_ch = charging_curve(self.battery_soc, self.cfg.battery_p_max_kw, self.cfg.battery_tau);
            let r_dis = discharging_curve(self.battery_soc, self.cfg.battery_p_max_kw, self.cfg.battery_tau);
            let head_up = (1.0 - self.battery_soc) * self.cfg.battery_capacity_kwh / DT_HOURS;
            let head_dn = self.battery_soc * self.cfg.battery_capacity_kwh / DT_HOURS;
            let p_kw = p_target.clamp(-r_dis.min(head_dn), r_ch.min(head_up));
            i_new[c] = p_kw * 1000.0 / self.tree.volt[c];
        }
        let excess = self.tree.project_currents(&mut i_new);
        self.i_drawn = i_new;

        // (ii) charge.
        let mut de_net = 0f32;
        let mut grid_cars = 0f32;
        for j in 0..c {
            let Some(car) = self.cars[j].as_mut() else { continue };
            let p_kw = self.tree.volt[j] * self.i_drawn[j] / 1000.0;
            let mut e = p_kw * DT_HOURS;
            e = e.min((1.0 - car.soc) * car.cap).max(-car.soc * car.cap);
            car.soc = (car.soc + e / car.cap.max(1e-9)).clamp(0.0, 1.0);
            car.de_remain -= e;
            car.dt_remain -= 1.0;
            de_net += e;
            grid_cars += if e > 0.0 {
                e / self.tree.eta_port[j]
            } else {
                e * self.tree.eta_port[j]
            };
        }
        let e_bat = {
            let p_kw = self.tree.volt[c] * self.i_drawn[c] / 1000.0;
            let mut e = p_kw * DT_HOURS;
            e = e
                .min((1.0 - self.battery_soc) * self.cfg.battery_capacity_kwh)
                .max(-self.battery_soc * self.cfg.battery_capacity_kwh);
            self.battery_soc =
                (self.battery_soc + e / self.cfg.battery_capacity_kwh).clamp(0.0, 1.0);
            e
        };
        let de_grid_net = grid_cars + e_bat;
        self.t += 1;

        // (iii) departures.
        let mut missing = 0f32;
        let mut overtime = 0f32;
        let mut early = 0f32;
        let mut departed = 0f32;
        let mut car_discharge = 0f32;
        for j in 0..c {
            let Some(car) = self.cars[j] else { continue };
            let leave = if car.charge_sensitive {
                car.de_remain <= 1e-6
            } else {
                car.dt_remain <= 0.0
            };
            if leave {
                if car.charge_sensitive {
                    overtime += (-car.dt_remain).max(0.0);
                    early += car.dt_remain.max(0.0);
                } else {
                    missing += car.de_remain.max(0.0);
                }
                departed += 1.0;
                self.cars[j] = None;
                self.i_drawn[j] = 0.0;
            }
        }
        // degradation: any car-side discharge this step (computed before
        // departures clear lanes; cars only charge unless V2G, so this is
        // battery-dominated).
        for j in 0..c {
            let p_kw = self.tree.volt[j] * self.i_drawn[j] / 1000.0;
            if p_kw < 0.0 {
                car_discharge += -p_kw * DT_HOURS;
            }
        }

        // (iv) arrivals.
        let lam = self.tables.arrival_rate[self.hour()] * self.tables.traffic
            / STEPS_PER_HOUR as f32;
        let m = self.rng.poisson(lam) as usize;
        let free: Vec<usize> = (0..c).filter(|&j| self.cars[j].is_none()).collect();
        let n_take = m.min(free.len()).min(MAX_ARRIVALS);
        let rejected = (m - n_take) as f32;
        for &slot in free.iter().take(n_take) {
            self.cars[slot] = Some(self.sample_car(slot));
        }
        let arrived = n_take as f32;

        // Reward (Eq. 2-3).
        let grid_price = if de_grid_net > 0.0 { price_buy } else { price_sell_grid };
        let profit =
            self.tables.p_sell * de_net - grid_price * de_grid_net - FIXED_COST_PER_STEP;
        let pens = [
            excess,
            missing,
            overtime - self.tables.beta * early,
            moer * de_grid_net,
            rejected,
            (-e_bat).max(0.0) + car_discharge,
            (de_net - 0.0).abs(), // grid-demand signal ~0 unless configured
        ];
        let mut reward = profit;
        for (a, c_) in self.tables.alpha.iter().zip(&pens) {
            reward -= a * c_;
        }

        self.ep_return += reward;
        self.ep_profit += profit;
        let done = self.t >= STEPS_PER_EPISODE;
        let info = StepInfo {
            reward,
            profit,
            energy_to_cars_kwh: de_net,
            energy_grid_net_kwh: de_grid_net,
            excess_kw: excess,
            missing_kwh: missing,
            overtime_steps: overtime,
            rejected,
            departed,
            arrived,
            done,
        };
        if done {
            self.reset();
        }
        info
    }

    fn sample_car(&mut self, slot: usize) -> Car {
        let up = &self.tables.user_profile;
        let (stay_mean_h, stay_std_h) = (up[0], up[1]);
        let (soc0_a, soc0_b, target_soc, p_time) = (up[2], up[3], up[4], up[5]);
        let model = self.rng.categorical(&self.tables.car_weights);
        let row = &self.tables.car_table[model * 4..model * 4 + 4];
        let (cap, ac_kw, dc_kw, tau) = (row[0], row[1], row[2], row[3]);
        let stay_h = stay_mean_h + stay_std_h * self.rng.normal();
        let stay_steps = (stay_h / DT_HOURS).round().max(1.0);
        let soc0 = self.rng.kumaraswamy(soc0_a, soc0_b).clamp(0.02, 0.98);
        let de = (target_soc - soc0).max(0.0) * cap;
        let charge_sensitive = self.rng.f32() < 1.0 - p_time;
        let car_rate = if self.tree.is_dc[slot] { dc_kw } else { ac_kw };
        Car {
            soc: soc0,
            de_remain: de,
            dt_remain: stay_steps,
            cap,
            r_bar: car_rate.min(self.tree.p_max[slot]),
            tau,
            charge_sensitive,
        }
    }

    /// Observation mirroring env.py::observe (same layout & normalizers).
    pub fn observe(&self, out: &mut [f32]) {
        let c = self.cfg.n_chargers();
        debug_assert_eq!(out.len(), self.obs_dim());
        let hour = self.hour();
        let hour_next = (hour + 1).min(23);
        for j in 0..c {
            let car = self.cars[j];
            let occ = car.is_some() as i32 as f32;
            let (soc, de, dtr, rhat) = match car {
                Some(cc) => (
                    cc.soc,
                    cc.de_remain,
                    cc.dt_remain,
                    charging_curve(cc.soc, cc.r_bar, cc.tau),
                ),
                None => (0.0, 0.0, 0.0, 0.0),
            };
            out[j] = occ;
            out[c + j] = soc;
            out[2 * c + j] = de / 100.0;
            out[3 * c + j] = dtr / STEPS_PER_EPISODE as f32;
            out[4 * c + j] = rhat / self.tree.p_max[j];
            out[5 * c + j] = self.i_drawn[j] / self.tree.i_max[j];
        }
        let b = 6 * c;
        out[b] = self.battery_soc;
        out[b + 1] = self.i_drawn[c] / self.tree.i_max[c];
        out[b + 2] = charging_curve(
            self.battery_soc,
            self.cfg.battery_p_max_kw,
            self.cfg.battery_tau,
        ) / self.tree.p_max[c];
        let phase = 2.0 * std::f32::consts::PI * self.t as f32 / STEPS_PER_EPISODE as f32;
        out[b + 3] = phase.sin();
        out[b + 4] = phase.cos();
        out[b + 5] = ((self.day % 7) < 5) as i32 as f32;
        out[b + 6] = self.day as f32 / self.tables.n_days as f32;
        let idx = self.day * 24 + hour;
        out[b + 7] = self.tables.price_buy[idx];
        out[b + 8] = self.tables.price_buy[self.day * 24 + hour_next];
        out[b + 9] = self.tables.price_sell_grid[idx];
        out[b + 10] = self.tables.moer[idx];
    }
}
