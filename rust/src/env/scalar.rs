//! Scalar per-step CPU simulator — the "classic gym" comparator.
//!
//! Since the SoA refactor this is a thin B = 1 wrapper over the shared
//! transition core (`env::core`) driven through [`VectorEnv`]: one station,
//! per-step method calls, host-visible state accessors. It keeps the
//! architecture the paper's comparison environments have (one env object,
//! one step call at a time) and is the substrate for the Table 2 baseline
//! rows, while being semantically identical to one lane of the batched
//! environment by construction (cross-checked in rust/tests/vector_env.rs).

use std::sync::Arc;

use super::tree::{charging_curve, StationConfig, StationTree};
use super::vector::VectorEnv;

pub use super::core::{
    Car, ScenarioTables, StepInfo, DT_HOURS, FIXED_COST_PER_STEP, MAX_ARRIVALS, N_LEVELS,
    N_LEVELS_BATTERY, STEPS_PER_EPISODE, STEPS_PER_HOUR,
};

pub struct ScalarEnv {
    inner: VectorEnv,
}

impl ScalarEnv {
    pub fn new(
        cfg: StationConfig,
        tables: impl Into<Arc<ScenarioTables>>,
        seed: u64,
    ) -> ScalarEnv {
        ScalarEnv {
            inner: VectorEnv::with_seeds(cfg, vec![tables.into()], vec![0], &[seed]),
        }
    }

    pub fn cfg(&self) -> &StationConfig {
        &self.inner.cfg
    }

    pub fn tree(&self) -> &StationTree {
        &self.inner.tree
    }

    pub fn tables(&self) -> &ScenarioTables {
        self.inner.tables_for(0)
    }

    /// Share this env's scenario tables (cheap Arc clone).
    pub fn tables_arc(&self) -> Arc<ScenarioTables> {
        self.inner.tables_arc(0)
    }

    pub fn n_ports(&self) -> usize {
        self.inner.n_ports()
    }

    pub fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    pub fn action_nvec(&self) -> Vec<usize> {
        self.inner.action_nvec()
    }

    pub fn t(&self) -> usize {
        self.inner.lane_t(0)
    }

    pub fn day(&self) -> usize {
        self.inner.lane_day(0)
    }

    pub fn battery_soc(&self) -> f32 {
        self.inner.lane_battery_soc(0)
    }

    pub fn ep_return(&self) -> f32 {
        self.inner.lane_ep_return(0)
    }

    pub fn ep_profit(&self) -> f32 {
        self.inner.lane_ep_profit(0)
    }

    /// Signed per-port currents (A); last entry is the battery port.
    pub fn i_drawn(&self) -> &[f32] {
        self.inner.lane_i_drawn(0)
    }

    /// The car parked at charger `slot`, if any.
    pub fn car(&self, slot: usize) -> Option<Car> {
        self.inner.lane_car(0, slot)
    }

    pub fn occupied(&self, slot: usize) -> bool {
        self.car(slot).is_some()
    }

    pub fn reset(&mut self) {
        self.inner.reset_lane_idx(0);
    }

    /// One env step. `action[p]` is the discrete level per port.
    pub fn step(&mut self, action: &[usize]) -> StepInfo {
        let mut infos = [StepInfo::default()];
        self.inner.step_all(action, &mut infos);
        infos[0]
    }

    /// Observation mirroring env.py::observe (same layout & normalizers).
    pub fn observe(&self, out: &mut [f32]) {
        self.inner.observe_lane_into(0, out);
    }

    /// Estimated deliverable rate right now for an occupied slot (kW).
    pub fn charge_rate_hat(&self, slot: usize) -> f32 {
        self.car(slot)
            .map(|car| charging_curve(car.soc, car.r_bar, car.tau))
            .unwrap_or(0.0)
    }
}
