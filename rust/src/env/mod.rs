//! Pure-Rust scalar reference simulator.
//!
//! Semantics mirror the JAX environment (cross-checked in
//! rust/tests/cross_check.rs against python-exported vectors); the
//! *architecture* mirrors the paper's comparison environments — a per-step,
//! per-car, host-RNG object loop — making it the fair CPU-gym comparator
//! for Table 2.

pub mod scalar;
pub mod tree;

pub use scalar::{ScalarEnv, ScenarioTables, StepInfo};
pub use tree::{StationConfig, StationTree};
