//! Pure-Rust simulators over one shared transition core.
//!
//! * [`core`] — the pure per-lane transition semantics (actions, curves,
//!   current allocation, battery, arrivals/departures, reward, observe),
//!   cross-checked in rust/tests against python-exported vectors.
//! * [`vector`] — the native fast path: a structure-of-arrays batched env
//!   stepping B stations per call, thread-sharded, with counter-based
//!   per-lane RNG and heterogeneous per-lane scenarios.
//! * [`scalar`] — the per-step B = 1 comparator wrapper (the paper's
//!   "classic gym" architecture) used for the Table 2 baseline rows.

pub mod core;
pub mod scalar;
pub mod tree;
pub mod vector;

pub use self::core::{Car, ScenarioTables, StepInfo};
pub use scalar::ScalarEnv;
pub use tree::{StationConfig, StationTree};
pub use vector::VectorEnv;
