//! Native batched environment: B stations stepped in lockstep over flat
//! structure-of-arrays state.
//!
//! This is the Rust-native analogue of the paper's vectorized JAX
//! environment (and of Jumanji-style batched pure-function envs): all
//! per-car/per-port/per-env state lives in flat `Vec<f32>`/`Vec<u32>`
//! lanes of shape `[B, ...]`, one `step_all` call advances every lane, and
//! large batches are sharded across OS threads with `std::thread::scope`
//! (no external dependency). Each lane carries its own counter-based
//! [`CounterRng`], so results are bit-identical for any shard count or
//! thread schedule.
//!
//! Batches may be **heterogeneous**: every lane holds an index into a set
//! of shared `Arc<ScenarioTables>`, so one batch can mix countries, price
//! years, traffic levels, and user profiles — multi-scenario training in a
//! single rollout.

use std::sync::Arc;

use crate::util::rng::CounterRng;

use super::core::{self, LaneRef, LaneView, Scratch, ScenarioTables, StepInfo};
use super::tree::{StationConfig, StationTree};

/// Don't spawn shard threads below this batch size; the per-lane work is
/// microseconds and thread dispatch would dominate.
const PAR_MIN_BATCH: usize = 64;

/// Keep every shard at least this many lanes so scoped-thread spawn cost
/// (~tens of µs) stays small relative to per-shard stepping work.
const MIN_LANES_PER_SHARD: usize = 32;

pub struct VectorEnv {
    pub cfg: StationConfig,
    pub tree: StationTree,
    tables: Vec<Arc<ScenarioTables>>,
    lane_scenario: Vec<u32>, // [B] index into `tables`
    b: usize,
    c: usize,
    p: usize,
    parallel: bool,
    /// available_parallelism() cached at construction — the std call is
    /// documented as expensive and step_all runs once per env step.
    threads: usize,
    // per-env lanes [B]
    t: Vec<u32>,
    day: Vec<u32>,
    battery_soc: Vec<f32>,
    ep_return: Vec<f32>,
    ep_profit: Vec<f32>,
    rng: Vec<CounterRng>,
    // per-charger lanes [B * C]
    present: Vec<bool>,
    soc: Vec<f32>,
    de_remain: Vec<f32>,
    dt_remain: Vec<f32>,
    cap: Vec<f32>,
    r_bar: Vec<f32>,
    tau: Vec<f32>,
    sensitive: Vec<bool>,
    // per-port lanes [B * P]
    i_drawn: Vec<f32>,
}

impl VectorEnv {
    /// Homogeneous batch: B lanes sharing one scenario. Lane j's RNG
    /// stream is derived as `CounterRng::derive(seed, j)`.
    pub fn new(
        cfg: StationConfig,
        tables: impl Into<Arc<ScenarioTables>>,
        batch: usize,
        seed: u64,
    ) -> VectorEnv {
        let rngs: Vec<CounterRng> =
            (0..batch).map(|j| CounterRng::derive(seed, j as u64)).collect();
        VectorEnv::new_mixed(cfg, vec![tables.into()], vec![0; batch], rngs)
    }

    /// Heterogeneous batch: lane j runs scenario `lane_scenario[j]`
    /// (index into `tables`) with its own pre-seeded RNG stream.
    pub fn with_seeds(
        cfg: StationConfig,
        tables: Vec<Arc<ScenarioTables>>,
        lane_scenario: Vec<usize>,
        seeds: &[u64],
    ) -> VectorEnv {
        assert_eq!(lane_scenario.len(), seeds.len());
        let rngs: Vec<CounterRng> = seeds.iter().map(|&s| CounterRng::new(s)).collect();
        VectorEnv::new_mixed(cfg, tables, lane_scenario, rngs)
    }

    fn new_mixed(
        cfg: StationConfig,
        tables: Vec<Arc<ScenarioTables>>,
        lane_scenario: Vec<usize>,
        rngs: Vec<CounterRng>,
    ) -> VectorEnv {
        assert!(!tables.is_empty(), "need at least one scenario table");
        assert_eq!(lane_scenario.len(), rngs.len());
        for &s in &lane_scenario {
            assert!(s < tables.len(), "lane scenario index {s} out of range");
        }
        let b = lane_scenario.len();
        let tree = StationTree::standard(&cfg);
        let c = cfg.n_chargers();
        let p = cfg.n_ports();
        let mut env = VectorEnv {
            tree,
            tables,
            lane_scenario: lane_scenario.iter().map(|&s| s as u32).collect(),
            b,
            c,
            p,
            parallel: true,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            t: vec![0; b],
            day: vec![0; b],
            battery_soc: vec![cfg.battery_soc0; b],
            ep_return: vec![0.0; b],
            ep_profit: vec![0.0; b],
            rng: rngs,
            present: vec![false; b * c],
            soc: vec![0.0; b * c],
            de_remain: vec![0.0; b * c],
            dt_remain: vec![0.0; b * c],
            cap: vec![0.0; b * c],
            r_bar: vec![0.0; b * c],
            tau: vec![0.0; b * c],
            sensitive: vec![false; b * c],
            i_drawn: vec![0.0; b * p],
            cfg,
        };
        env.reset_all();
        env
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn n_ports(&self) -> usize {
        self.p
    }

    pub fn n_chargers(&self) -> usize {
        self.c
    }

    pub fn obs_dim(&self) -> usize {
        core::obs_dim(&self.cfg)
    }

    pub fn action_nvec(&self) -> Vec<usize> {
        core::action_nvec(&self.cfg)
    }

    /// Enable/disable thread sharding (on by default; sharding never
    /// changes results, only wall-clock).
    pub fn set_parallel(&mut self, on: bool) {
        self.parallel = on;
    }

    pub fn tables_for(&self, lane: usize) -> &ScenarioTables {
        &self.tables[self.lane_scenario[lane] as usize]
    }

    /// Share lane `lane`'s scenario tables (cheap Arc clone).
    pub fn tables_arc(&self, lane: usize) -> Arc<ScenarioTables> {
        Arc::clone(&self.tables[self.lane_scenario[lane] as usize])
    }

    // -- lane accessors (used by the B=1 ScalarEnv wrapper and tests) ------

    pub fn lane_t(&self, lane: usize) -> usize {
        self.t[lane] as usize
    }

    pub fn lane_day(&self, lane: usize) -> usize {
        self.day[lane] as usize
    }

    pub fn lane_battery_soc(&self, lane: usize) -> f32 {
        self.battery_soc[lane]
    }

    pub fn lane_ep_return(&self, lane: usize) -> f32 {
        self.ep_return[lane]
    }

    pub fn lane_ep_profit(&self, lane: usize) -> f32 {
        self.ep_profit[lane]
    }

    pub fn lane_i_drawn(&self, lane: usize) -> &[f32] {
        &self.i_drawn[lane * self.p..(lane + 1) * self.p]
    }

    /// AoS view of one charger slot (None when unoccupied).
    pub fn lane_car(&self, lane: usize, slot: usize) -> Option<core::Car> {
        let k = lane * self.c + slot;
        if !self.present[k] {
            return None;
        }
        Some(core::Car {
            soc: self.soc[k],
            de_remain: self.de_remain[k],
            dt_remain: self.dt_remain[k],
            cap: self.cap[k],
            r_bar: self.r_bar[k],
            tau: self.tau[k],
            charge_sensitive: self.sensitive[k],
        })
    }

    /// Reset every lane (fresh day draw per lane RNG).
    pub fn reset_all(&mut self) {
        for lane in 0..self.b {
            self.reset_lane_idx(lane);
        }
    }

    pub fn reset_lane_idx(&mut self, lane: usize) {
        let (c, p) = (self.c, self.p);
        let tables = Arc::clone(&self.tables[self.lane_scenario[lane] as usize]);
        let mut view = LaneView {
            t: &mut self.t[lane],
            day: &mut self.day[lane],
            battery_soc: &mut self.battery_soc[lane],
            ep_return: &mut self.ep_return[lane],
            ep_profit: &mut self.ep_profit[lane],
            present: &mut self.present[lane * c..(lane + 1) * c],
            soc: &mut self.soc[lane * c..(lane + 1) * c],
            de_remain: &mut self.de_remain[lane * c..(lane + 1) * c],
            dt_remain: &mut self.dt_remain[lane * c..(lane + 1) * c],
            cap: &mut self.cap[lane * c..(lane + 1) * c],
            r_bar: &mut self.r_bar[lane * c..(lane + 1) * c],
            tau: &mut self.tau[lane * c..(lane + 1) * c],
            sensitive: &mut self.sensitive[lane * c..(lane + 1) * c],
            i_drawn: &mut self.i_drawn[lane * p..(lane + 1) * p],
        };
        core::reset_lane(&mut view, &mut self.rng[lane], &self.cfg, &tables);
    }

    /// Step every lane. `actions` is `[B * P]` (row-major per lane),
    /// `infos` receives one [`StepInfo`] per lane. Shard count is chosen
    /// from `available_parallelism`; results are identical for any count.
    pub fn step_all(&mut self, actions: &[usize], infos: &mut [StepInfo]) {
        let shards = if self.parallel && self.b >= PAR_MIN_BATCH {
            self.threads.min(self.b / MIN_LANES_PER_SHARD).max(1)
        } else {
            1
        };
        self.step_all_sharded(actions, infos, shards);
    }

    /// Step with an explicit shard count (exposed so tests can prove
    /// thread-count independence).
    pub fn step_all_sharded(&mut self, actions: &[usize], infos: &mut [StepInfo], shards: usize) {
        assert_eq!(actions.len(), self.b * self.p, "actions must be [B * n_ports]");
        assert_eq!(infos.len(), self.b, "infos must be [B]");
        let shards = shards.clamp(1, self.b.max(1));
        let lanes_per = self.b.div_ceil(shards);
        let (c, p) = (self.c, self.p);
        let cfg = &self.cfg;
        let tree = &self.tree;
        let tables: &[Arc<ScenarioTables>] = &self.tables;

        if shards == 1 {
            step_lanes(
                cfg,
                tree,
                tables,
                &self.lane_scenario,
                &mut self.t,
                &mut self.day,
                &mut self.battery_soc,
                &mut self.ep_return,
                &mut self.ep_profit,
                &mut self.rng,
                &mut self.present,
                &mut self.soc,
                &mut self.de_remain,
                &mut self.dt_remain,
                &mut self.cap,
                &mut self.r_bar,
                &mut self.tau,
                &mut self.sensitive,
                &mut self.i_drawn,
                actions,
                infos,
            );
            return;
        }

        // Split every SoA lane into per-shard chunks and step them on
        // scoped threads. Chunks are disjoint, so no synchronization is
        // needed; lane RNGs are counter-based, so the schedule is
        // irrelevant to the results.
        let mut scen = self.lane_scenario.as_slice();
        let mut t = self.t.as_mut_slice();
        let mut day = self.day.as_mut_slice();
        let mut bsoc = self.battery_soc.as_mut_slice();
        let mut ep_r = self.ep_return.as_mut_slice();
        let mut ep_p = self.ep_profit.as_mut_slice();
        let mut rng = self.rng.as_mut_slice();
        let mut present = self.present.as_mut_slice();
        let mut soc = self.soc.as_mut_slice();
        let mut de = self.de_remain.as_mut_slice();
        let mut dt = self.dt_remain.as_mut_slice();
        let mut cap = self.cap.as_mut_slice();
        let mut r_bar = self.r_bar.as_mut_slice();
        let mut tau = self.tau.as_mut_slice();
        let mut sens = self.sensitive.as_mut_slice();
        let mut i_drawn = self.i_drawn.as_mut_slice();
        let mut acts = actions;
        let mut infos = infos;

        std::thread::scope(|scope| {
            let mut remaining = self.b;
            while remaining > 0 {
                let take = lanes_per.min(remaining);
                remaining -= take;

                macro_rules! split_mut {
                    ($v:ident, $n:expr) => {{
                        let (head, rest) = std::mem::take(&mut $v).split_at_mut($n);
                        $v = rest;
                        head
                    }};
                }
                macro_rules! split_ref {
                    ($v:ident, $n:expr) => {{
                        let (head, rest) = $v.split_at($n);
                        $v = rest;
                        head
                    }};
                }

                let scen_h = split_ref!(scen, take);
                let t_h = split_mut!(t, take);
                let day_h = split_mut!(day, take);
                let bsoc_h = split_mut!(bsoc, take);
                let ep_r_h = split_mut!(ep_r, take);
                let ep_p_h = split_mut!(ep_p, take);
                let rng_h = split_mut!(rng, take);
                let present_h = split_mut!(present, take * c);
                let soc_h = split_mut!(soc, take * c);
                let de_h = split_mut!(de, take * c);
                let dt_h = split_mut!(dt, take * c);
                let cap_h = split_mut!(cap, take * c);
                let r_bar_h = split_mut!(r_bar, take * c);
                let tau_h = split_mut!(tau, take * c);
                let sens_h = split_mut!(sens, take * c);
                let i_drawn_h = split_mut!(i_drawn, take * p);
                let acts_h = split_ref!(acts, take * p);
                let infos_h = split_mut!(infos, take);

                scope.spawn(move || {
                    step_lanes(
                        cfg, tree, tables, scen_h, t_h, day_h, bsoc_h, ep_r_h, ep_p_h,
                        rng_h, present_h, soc_h, de_h, dt_h, cap_h, r_bar_h, tau_h,
                        sens_h, i_drawn_h, acts_h, infos_h,
                    );
                });
            }
        });
    }

    /// Observations for every lane into `out` (`[B * obs_dim]` row-major).
    pub fn observe_all(&self, out: &mut [f32]) {
        let d = self.obs_dim();
        assert_eq!(out.len(), self.b * d, "out must be [B * obs_dim]");
        for (lane, row) in out.chunks_mut(d).enumerate() {
            self.observe_lane_into(lane, row);
        }
    }

    pub fn observe_lane_into(&self, lane: usize, out: &mut [f32]) {
        let (c, p) = (self.c, self.p);
        let view = LaneRef {
            t: self.t[lane],
            day: self.day[lane],
            battery_soc: self.battery_soc[lane],
            present: &self.present[lane * c..(lane + 1) * c],
            soc: &self.soc[lane * c..(lane + 1) * c],
            de_remain: &self.de_remain[lane * c..(lane + 1) * c],
            dt_remain: &self.dt_remain[lane * c..(lane + 1) * c],
            r_bar: &self.r_bar[lane * c..(lane + 1) * c],
            tau: &self.tau[lane * c..(lane + 1) * c],
            i_drawn: &self.i_drawn[lane * p..(lane + 1) * p],
        };
        core::observe_lane(
            &view,
            &self.cfg,
            &self.tree,
            &self.tables[self.lane_scenario[lane] as usize],
            out,
        );
    }
}

/// Measure raw `step_all` throughput at batch size `b` with random actions
/// refreshed every step: one warm pass then one timed pass. Shared by
/// `benches/table2_throughput` and `chargax bench table2` so the JSON
/// artifact and the printed table can never use different protocols.
/// Returns (env-steps/sec, seconds per 100k env steps).
pub fn measure_step_throughput(tables: Arc<ScenarioTables>, b: usize) -> (f64, f64) {
    use crate::util::rng::Rng;

    let mut venv = VectorEnv::new(StationConfig::default(), tables, b, 11);
    let nvec = venv.action_nvec();
    let p = venv.n_ports();
    let mut infos = vec![StepInfo::default(); b];
    let reps = (120_000 / b).clamp(40, 20_000);
    // Pre-generate every step's actions so the timed region contains only
    // step_all — serial host-side RNG would otherwise be billed as env
    // throughput, and it grows with B.
    let mut arng = Rng::new(17);
    let all_actions: Vec<usize> = (0..reps * b * p)
        .map(|k| arng.below(nvec[k % p] as u32) as usize)
        .collect();
    let mut pass = |venv: &mut VectorEnv| {
        for actions in all_actions.chunks_exact(b * p) {
            venv.step_all(actions, &mut infos);
        }
    };
    pass(&mut venv); // warm
    let t0 = std::time::Instant::now();
    pass(&mut venv);
    let el = t0.elapsed().as_secs_f64();
    let steps = (reps * b) as f64;
    (steps / el, el * 100_000.0 / steps)
}

/// Step a contiguous block of lanes (one shard's work).
#[allow(clippy::too_many_arguments)]
fn step_lanes(
    cfg: &StationConfig,
    tree: &StationTree,
    tables: &[Arc<ScenarioTables>],
    lane_scenario: &[u32],
    t: &mut [u32],
    day: &mut [u32],
    battery_soc: &mut [f32],
    ep_return: &mut [f32],
    ep_profit: &mut [f32],
    rng: &mut [CounterRng],
    present: &mut [bool],
    soc: &mut [f32],
    de_remain: &mut [f32],
    dt_remain: &mut [f32],
    cap: &mut [f32],
    r_bar: &mut [f32],
    tau: &mut [f32],
    sensitive: &mut [bool],
    i_drawn: &mut [f32],
    actions: &[usize],
    infos: &mut [StepInfo],
) {
    let c = cfg.n_chargers();
    let p = cfg.n_ports();
    let mut scratch = Scratch::new(p);
    for lane in 0..t.len() {
        let mut view = LaneView {
            t: &mut t[lane],
            day: &mut day[lane],
            battery_soc: &mut battery_soc[lane],
            ep_return: &mut ep_return[lane],
            ep_profit: &mut ep_profit[lane],
            present: &mut present[lane * c..(lane + 1) * c],
            soc: &mut soc[lane * c..(lane + 1) * c],
            de_remain: &mut de_remain[lane * c..(lane + 1) * c],
            dt_remain: &mut dt_remain[lane * c..(lane + 1) * c],
            cap: &mut cap[lane * c..(lane + 1) * c],
            r_bar: &mut r_bar[lane * c..(lane + 1) * c],
            tau: &mut tau[lane * c..(lane + 1) * c],
            sensitive: &mut sensitive[lane * c..(lane + 1) * c],
            i_drawn: &mut i_drawn[lane * p..(lane + 1) * p],
        };
        infos[lane] = core::step_lane(
            &mut view,
            &mut rng[lane],
            cfg,
            tree,
            &tables[lane_scenario[lane] as usize],
            &actions[lane * p..(lane + 1) * p],
            &mut scratch,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mixed_env(b: usize) -> VectorEnv {
        let tables = vec![
            Arc::new(ScenarioTables::synthetic(0.8)),
            Arc::new(ScenarioTables::synthetic(2.0)),
        ];
        let scen: Vec<usize> = (0..b).map(|j| j % 2).collect();
        let seeds: Vec<u64> = (0..b as u64).map(|j| 1000 + j * 7).collect();
        VectorEnv::with_seeds(StationConfig::default(), tables, scen, &seeds)
    }

    fn random_actions(rng: &mut Rng, env: &VectorEnv) -> Vec<usize> {
        let nvec = env.action_nvec();
        (0..env.batch())
            .flat_map(|_| nvec.iter().map(|&n| rng.below(n as u32) as usize).collect::<Vec<_>>())
            .collect()
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let mut rng = Rng::new(42);
        let mut envs: Vec<VectorEnv> = (0..3).map(|_| mixed_env(8)).collect();
        let mut infos = vec![StepInfo::default(); 8];
        for step in 0..100 {
            let actions = random_actions(&mut rng, &envs[0]);
            let mut rewards = Vec::new();
            for (i, env) in envs.iter_mut().enumerate() {
                env.step_all_sharded(&actions, &mut infos, [1, 3, 8][i]);
                rewards.push(infos.iter().map(|x| x.reward).collect::<Vec<_>>());
            }
            assert_eq!(rewards[0], rewards[1], "1 vs 3 shards diverged at step {step}");
            assert_eq!(rewards[0], rewards[2], "1 vs 8 shards diverged at step {step}");
        }
        let obs_len = envs[0].batch() * envs[0].obs_dim();
        let mut o1 = vec![0f32; obs_len];
        let mut o3 = vec![0f32; obs_len];
        envs[0].observe_all(&mut o1);
        envs[1].observe_all(&mut o3);
        assert_eq!(o1, o3);
    }

    #[test]
    fn mixed_batch_invariants_hold() {
        let mut env = mixed_env(16);
        let mut rng = Rng::new(7);
        let mut infos = vec![StepInfo::default(); 16];
        for _ in 0..300 {
            let actions = random_actions(&mut rng, &env);
            env.step_all(&actions, &mut infos);
            for (lane, info) in infos.iter().enumerate() {
                assert!(info.reward.is_finite());
                assert!((0.0..=1.0).contains(&env.lane_battery_soc(lane)));
                for slot in 0..env.n_chargers() {
                    if let Some(car) = env.lane_car(lane, slot) {
                        assert!((0.0..=1.0).contains(&car.soc));
                        assert!(car.cap > 0.0);
                    }
                }
            }
        }
        // high-traffic lanes (odd) should have seen more arrivals on
        // average than low-traffic lanes (even) — scenario heterogeneity
        // is actually wired through.
        let mut env2 = mixed_env(32);
        let mut arrived = vec![0f32; 32];
        let mut infos = vec![StepInfo::default(); 32];
        for _ in 0..288 {
            let actions = random_actions(&mut rng, &env2);
            env2.step_all(&actions, &mut infos);
            for (lane, info) in infos.iter().enumerate() {
                arrived[lane] += info.arrived;
            }
        }
        let low: f32 = arrived.iter().step_by(2).sum();
        let high: f32 = arrived.iter().skip(1).step_by(2).sum();
        assert!(high > low, "traffic heterogeneity not visible: low {low} high {high}");
    }

    #[test]
    fn episode_boundary_resets_all_lanes() {
        let mut env = VectorEnv::new(
            StationConfig::default(),
            ScenarioTables::synthetic(1.0),
            4,
            9,
        );
        let mut infos = vec![StepInfo::default(); 4];
        let actions = vec![0usize; 4 * env.n_ports()];
        for i in 1..=core::STEPS_PER_EPISODE {
            env.step_all(&actions, &mut infos);
            let all_done = infos.iter().all(|x| x.done);
            if i == core::STEPS_PER_EPISODE {
                assert!(all_done);
                for lane in 0..4 {
                    assert_eq!(env.lane_t(lane), 0);
                    assert_eq!(env.lane_ep_return(lane), 0.0);
                }
            } else {
                assert!(!all_done);
            }
        }
    }
}
