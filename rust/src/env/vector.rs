//! Native batched environment: B stations stepped in lockstep over flat
//! structure-of-arrays state.
//!
//! This is the Rust-native analogue of the paper's vectorized JAX
//! environment (and of Jumanji-style batched pure-function envs): all
//! per-car/per-port/per-env state lives in flat `Vec<f32>`/`Vec<u32>`
//! lanes of shape `[B, ...]`, one `step_all` call advances every lane, and
//! large batches are sharded across a **persistent worker pool**
//! ([`crate::runtime::pool::WorkerPool`]) — long-lived shard-pinned
//! threads parked between calls, so per-step dispatch is a condvar wake
//! instead of an OS thread spawn. A scoped-thread fallback
//! ([`VectorEnv::step_all_sharded`]) is kept as the cross-check oracle.
//! Each lane carries its own counter-based [`CounterRng`], so results are
//! bit-identical for any shard count, runtime, or thread schedule.
//!
//! Batches may be **heterogeneous**: every lane holds an index into a set
//! of shared `Arc<ScenarioTables>`, so one batch can mix countries, price
//! years, traffic levels, and user profiles — multi-scenario training in a
//! single rollout.
//!
//! For training, [`VectorEnv::rollout`] fuses the whole
//! act → step → observe loop: each shard steps its lanes and immediately
//! writes next-step observations, rewards, dones, and profits straight
//! into caller-provided PPO buffers (time-major), removing the serial
//! observe pass and the per-step obs copy. [`VectorEnv::rollout_fused`]
//! goes one further and moves the policy forward itself into the shard
//! tasks: each shard samples its own lanes' MLP actions (shared-read
//! weights, per-shard scratch, per-(lane, t) counter RNG) before
//! stepping them, so nothing about a rollout is serial in B.

use std::sync::Arc;

use crate::baselines::generalist::PolicyRef;
use crate::baselines::mlp::MlpScratch;
use crate::baselines::ppo::Learner;
use crate::runtime::pool::{DisjointTasks, WorkerPool};
use crate::telemetry;
use crate::util::rng::CounterRng;

use super::core::{self, GridBudget, LaneRef, LaneView, Scratch, ScenarioTables, StepInfo};
use super::tree::{StationConfig, StationTree};

/// Don't shard below this batch size; the per-lane work is microseconds
/// and even a condvar wake would dominate. (Shared with the fleet
/// scheduler, which plans shards across several envs at once.)
pub(crate) const PAR_MIN_BATCH: usize = 64;

/// Keep every shard at least this many lanes so wakeup/park overhead
/// stays small relative to per-shard stepping work.
pub(crate) const MIN_LANES_PER_SHARD: usize = 32;

pub struct VectorEnv {
    pub cfg: StationConfig,
    pub tree: StationTree,
    tables: Vec<Arc<ScenarioTables>>,
    lane_scenario: Vec<u32>, // [B] index into `tables`
    b: usize,
    c: usize,
    p: usize,
    parallel: bool,
    /// Shard-count ceiling; defaults to available_parallelism() (cached at
    /// construction — the std call is documented as expensive) and is
    /// overridable via [`VectorEnv::set_threads`] (`--threads`).
    threads: usize,
    /// Persistent worker pool, built lazily on the first sharded step and
    /// reused for every subsequent `step_all`/`rollout` call.
    pool: Option<Arc<WorkerPool>>,
    /// Separate pool for caller-driven auxiliary compute (the sharded PPO
    /// update) whose lane demand exceeds the rollout pool's width. Kept
    /// apart so the update can never grow the rollout pool: `run` wakes
    /// every pool worker (`notify_all`), so an inflated rollout pool
    /// would pay spurious wake/park cycles on EVERY step dispatch.
    aux_pool: Option<Arc<WorkerPool>>,
    // per-env lanes [B]
    t: Vec<u32>,
    day: Vec<u32>,
    battery_soc: Vec<f32>,
    ep_return: Vec<f32>,
    ep_profit: Vec<f32>,
    rng: Vec<CounterRng>,
    // per-charger lanes [B * C]
    present: Vec<bool>,
    soc: Vec<f32>,
    de_remain: Vec<f32>,
    dt_remain: Vec<f32>,
    cap: Vec<f32>,
    r_bar: Vec<f32>,
    tau: Vec<f32>,
    sensitive: Vec<bool>,
    // per-port lanes [B * P]
    i_drawn: Vec<f32>,
    /// Normalized feeder headroom the NEXT observation reports (coupled
    /// envs only — `cfg.grid_coupled` adds the obs column). The fleet's
    /// allocate phase updates it between the propose and commit
    /// dispatches; uncoupled envs keep the initial 1.0 forever and never
    /// read it into an observation.
    grid_headroom: f32,
}

/// Caller-provided PPO rollout buffers (time-major). `obs` holds one extra
/// row: row `t` is the observation *before* step `t`, row `n_steps` is the
/// bootstrap observation after the final step.
pub struct RolloutBuffers<'a> {
    pub obs: &'a mut [f32],     // [(T + 1) * B * obs_dim]
    pub rewards: &'a mut [f32], // [T * B]
    pub dones: &'a mut [f32],   // [T * B] (1.0 = episode ended this step)
    pub profits: &'a mut [f32], // [T * B]
}

/// Caller-provided policy-side rollout buffers (time-major), filled by
/// the fused-policy rollouts ([`VectorEnv::rollout_fused`] and
/// `Fleet::rollout_fused`): sampled actions, per-lane joint log-probs,
/// and value estimates. `logp` is 0 in greedy mode.
pub struct PolicyRollout<'a> {
    pub actions: &'a mut [usize], // [T * B * n_ports]
    pub logp: &'a mut [f32],      // [T * B]
    pub values: &'a mut [f32],    // [T * B]
}

impl VectorEnv {
    /// Homogeneous batch: B lanes sharing one scenario. Lane j's RNG
    /// stream is derived as `CounterRng::derive(seed, j)`.
    pub fn new(
        cfg: StationConfig,
        tables: impl Into<Arc<ScenarioTables>>,
        batch: usize,
        seed: u64,
    ) -> VectorEnv {
        let rngs: Vec<CounterRng> =
            (0..batch).map(|j| CounterRng::derive(seed, j as u64)).collect();
        VectorEnv::new_mixed(cfg, vec![tables.into()], vec![0; batch], rngs)
    }

    /// Heterogeneous batch: lane j runs scenario `lane_scenario[j]`
    /// (index into `tables`) with its own pre-seeded RNG stream.
    pub fn with_seeds(
        cfg: StationConfig,
        tables: Vec<Arc<ScenarioTables>>,
        lane_scenario: Vec<usize>,
        seeds: &[u64],
    ) -> VectorEnv {
        assert_eq!(lane_scenario.len(), seeds.len());
        let rngs: Vec<CounterRng> = seeds.iter().map(|&s| CounterRng::new(s)).collect();
        VectorEnv::new_mixed(cfg, tables, lane_scenario, rngs)
    }

    fn new_mixed(
        cfg: StationConfig,
        tables: Vec<Arc<ScenarioTables>>,
        lane_scenario: Vec<usize>,
        rngs: Vec<CounterRng>,
    ) -> VectorEnv {
        if let Err(e) = cfg.validate() {
            panic!("invalid StationConfig: {e}");
        }
        assert!(!tables.is_empty(), "need at least one scenario table");
        assert_eq!(lane_scenario.len(), rngs.len());
        for &s in &lane_scenario {
            assert!(s < tables.len(), "lane scenario index {s} out of range");
        }
        let b = lane_scenario.len();
        let tree = StationTree::standard(&cfg);
        let c = cfg.n_chargers();
        let p = cfg.n_ports();
        let mut env = VectorEnv {
            tree,
            tables,
            lane_scenario: lane_scenario.iter().map(|&s| s as u32).collect(),
            b,
            c,
            p,
            parallel: true,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            pool: None,
            aux_pool: None,
            t: vec![0; b],
            day: vec![0; b],
            battery_soc: vec![cfg.battery_soc0; b],
            ep_return: vec![0.0; b],
            ep_profit: vec![0.0; b],
            rng: rngs,
            present: vec![false; b * c],
            soc: vec![0.0; b * c],
            de_remain: vec![0.0; b * c],
            dt_remain: vec![0.0; b * c],
            cap: vec![0.0; b * c],
            r_bar: vec![0.0; b * c],
            tau: vec![0.0; b * c],
            sensitive: vec![false; b * c],
            i_drawn: vec![0.0; b * p],
            grid_headroom: 1.0,
            cfg,
        };
        env.reset_all();
        env
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn n_ports(&self) -> usize {
        self.p
    }

    pub fn n_chargers(&self) -> usize {
        self.c
    }

    pub fn obs_dim(&self) -> usize {
        core::obs_dim(&self.cfg)
    }

    pub fn action_nvec(&self) -> Vec<usize> {
        core::action_nvec(&self.cfg)
    }

    /// Enable/disable thread sharding (on by default; sharding never
    /// changes results, only wall-clock).
    pub fn set_parallel(&mut self, on: bool) {
        self.parallel = on;
    }

    /// Cap the shard/worker count (`--threads`). `0` restores the
    /// `available_parallelism()` default. Rebuilds the worker pool lazily
    /// on the next sharded call.
    pub fn set_threads(&mut self, threads: usize) {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        if t != self.threads {
            self.threads = t;
            self.pool = None;
            self.aux_pool = None;
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Set the feeder-headroom value the next observations report (the
    /// fleet allocate phase calls this between the propose and commit
    /// dispatches). No-op in effect for uncoupled envs: without
    /// `cfg.grid_coupled` the obs has no headroom column.
    pub fn set_grid_headroom(&mut self, headroom: f32) {
        self.grid_headroom = headroom;
    }

    pub fn grid_headroom(&self) -> f32 {
        self.grid_headroom
    }

    pub fn tables_for(&self, lane: usize) -> &ScenarioTables {
        &self.tables[self.lane_scenario[lane] as usize]
    }

    /// Share lane `lane`'s scenario tables (cheap Arc clone).
    pub fn tables_arc(&self, lane: usize) -> Arc<ScenarioTables> {
        Arc::clone(&self.tables[self.lane_scenario[lane] as usize])
    }

    /// Number of distinct scenario cells (tables) behind this batch.
    pub fn n_scenarios(&self) -> usize {
        self.tables.len()
    }

    /// Scenario tables by cell index (cheap Arc clone).
    pub fn scenario_tables(&self, idx: usize) -> Arc<ScenarioTables> {
        Arc::clone(&self.tables[idx])
    }

    /// Which scenario cell lane `lane` runs.
    pub fn lane_scenario_idx(&self, lane: usize) -> usize {
        self.lane_scenario[lane] as usize
    }

    /// How many lanes run each scenario cell (indexed like
    /// [`VectorEnv::scenario_tables`]).
    pub fn scenario_lane_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.tables.len()];
        for &s in &self.lane_scenario {
            counts[s as usize] += 1;
        }
        counts
    }

    // -- lane accessors (used by the B=1 ScalarEnv wrapper and tests) ------

    pub fn lane_t(&self, lane: usize) -> usize {
        self.t[lane] as usize
    }

    pub fn lane_day(&self, lane: usize) -> usize {
        self.day[lane] as usize
    }

    pub fn lane_battery_soc(&self, lane: usize) -> f32 {
        self.battery_soc[lane]
    }

    pub fn lane_ep_return(&self, lane: usize) -> f32 {
        self.ep_return[lane]
    }

    pub fn lane_ep_profit(&self, lane: usize) -> f32 {
        self.ep_profit[lane]
    }

    pub fn lane_i_drawn(&self, lane: usize) -> &[f32] {
        &self.i_drawn[lane * self.p..(lane + 1) * self.p]
    }

    /// AoS view of one charger slot (None when unoccupied).
    pub fn lane_car(&self, lane: usize, slot: usize) -> Option<core::Car> {
        let k = lane * self.c + slot;
        if !self.present[k] {
            return None;
        }
        Some(core::Car {
            soc: self.soc[k],
            de_remain: self.de_remain[k],
            dt_remain: self.dt_remain[k],
            cap: self.cap[k],
            r_bar: self.r_bar[k],
            tau: self.tau[k],
            charge_sensitive: self.sensitive[k],
        })
    }

    /// Reset every lane (fresh day draw per lane RNG).
    pub fn reset_all(&mut self) {
        for lane in 0..self.b {
            self.reset_lane_idx(lane);
        }
    }

    pub fn reset_lane_idx(&mut self, lane: usize) {
        let (c, p) = (self.c, self.p);
        let tables = Arc::clone(&self.tables[self.lane_scenario[lane] as usize]);
        let mut view = LaneView {
            t: &mut self.t[lane],
            day: &mut self.day[lane],
            battery_soc: &mut self.battery_soc[lane],
            ep_return: &mut self.ep_return[lane],
            ep_profit: &mut self.ep_profit[lane],
            present: &mut self.present[lane * c..(lane + 1) * c],
            soc: &mut self.soc[lane * c..(lane + 1) * c],
            de_remain: &mut self.de_remain[lane * c..(lane + 1) * c],
            dt_remain: &mut self.dt_remain[lane * c..(lane + 1) * c],
            cap: &mut self.cap[lane * c..(lane + 1) * c],
            r_bar: &mut self.r_bar[lane * c..(lane + 1) * c],
            tau: &mut self.tau[lane * c..(lane + 1) * c],
            sensitive: &mut self.sensitive[lane * c..(lane + 1) * c],
            i_drawn: &mut self.i_drawn[lane * p..(lane + 1) * p],
        };
        core::reset_lane(&mut view, &mut self.rng[lane], &self.cfg, &tables);
    }

    /// Shard count `step_all`/`rollout` will use for the current batch.
    fn auto_shards(&self) -> usize {
        if self.parallel && self.b >= PAR_MIN_BATCH {
            self.threads.min(self.b / MIN_LANES_PER_SHARD).max(1)
        } else {
            1
        }
    }

    /// The persistent pool, sized to the shard demand actually seen (not
    /// to `threads`): a 64-core host stepping B=256 uses 8 shards, and a
    /// 64-wide pool would notify_all-wake 56 workers per step just to
    /// re-park them. Grown (rebuilt) if a later call needs more shards;
    /// `shards` is already capped by `self.threads` at every call site.
    fn ensure_pool(&mut self, shards: usize) -> Arc<WorkerPool> {
        let need = shards.max(1);
        let rebuild = match &self.pool {
            Some(p) => p.max_shards() < need,
            None => true,
        };
        if rebuild {
            self.pool = Some(Arc::new(WorkerPool::new(need)));
        }
        Arc::clone(self.pool.as_ref().expect("pool just built"))
    }

    /// A persistent worker pool with at least `width` concurrent lanes
    /// (hard-capped by `--threads`), or `None` when a single lane
    /// suffices. This is how the sharded PPO update
    /// ([`crate::baselines::ppo::Learner::update_sharded`]) runs its
    /// gradient chunks: on the SAME long-lived workers that drive
    /// rollouts when the rollout pool is already wide enough, otherwise
    /// on a separately-grown auxiliary pool — growing the rollout pool
    /// itself would make every later step dispatch `notify_all`-wake
    /// workers it has no shards for.
    pub fn shared_pool(&mut self, width: usize) -> Option<Arc<WorkerPool>> {
        crate::runtime::pool::aux_or_primary_pool(
            &self.pool,
            &mut self.aux_pool,
            self.threads,
            width,
        )
    }

    /// The pool a fused rollout of the current batch will dispatch on, or
    /// `None` when rollouts run inline (single shard). Building it up
    /// front lets the overlapped trainer submit the NEXT iteration's
    /// rollout to this pool's pipeline lane while it keeps the `&mut`
    /// borrow of the env for the streamed rollout itself.
    pub fn rollout_pool(&mut self) -> Option<Arc<WorkerPool>> {
        let shards = self.auto_shards();
        if shards > 1 { Some(self.ensure_pool(shards)) } else { None }
    }

    /// Step every lane. `actions` is `[B * P]` (row-major per lane),
    /// `infos` receives one [`StepInfo`] per lane. Sharded over the
    /// persistent worker pool; results are identical for any shard count.
    pub fn step_all(&mut self, actions: &[usize], infos: &mut [StepInfo]) {
        let shards = self.auto_shards();
        self.step_all_pooled(actions, infos, shards);
    }

    /// Pool-backed step with an explicit shard count (clamped to the pool
    /// width). Exposed so tests can pin shard counts on the persistent
    /// runtime.
    pub fn step_all_pooled(&mut self, actions: &[usize], infos: &mut [StepInfo], shards: usize) {
        let shards = shards.clamp(1, self.b.max(1)).min(self.threads.max(1));
        let pool = if shards > 1 { Some(self.ensure_pool(shards)) } else { None };
        let mut tasks = self.shard_tasks(StepActs::Given(actions), infos, None, shards);
        run_shard_tasks(pool.as_deref(), &mut tasks);
    }

    /// Scoped-thread fallback with an explicit shard count: spawns (and
    /// joins) `shards` threads for this one call. Kept as the cross-check
    /// oracle for the pool runtime and for environments where persistent
    /// threads are undesirable; bit-identical to `step_all_pooled` at the
    /// same shard count.
    pub fn step_all_sharded(&mut self, actions: &[usize], infos: &mut [StepInfo], shards: usize) {
        let shards = shards.clamp(1, self.b.max(1));
        let mut tasks = self.shard_tasks(StepActs::Given(actions), infos, None, shards);
        if tasks.len() <= 1 {
            for task in tasks.iter_mut() {
                task.run();
            }
            return;
        }
        std::thread::scope(|scope| {
            for task in tasks.iter_mut() {
                scope.spawn(move || task.run());
            }
        });
    }

    /// Fused rollout: advance all lanes `n_steps` times, writing
    /// observations, rewards, dones, and profits directly into
    /// caller-provided PPO buffers in one pass (no separate observe +
    /// copy). `policy(t, obs_t, actions)` reads the `[B * obs_dim]`
    /// observation row for step `t` and fills the `[B * P]` action row;
    /// everything after it runs sharded on the persistent pool, with each
    /// shard observing its own lanes immediately after stepping them
    /// (state still cache-hot).
    pub fn rollout<F>(&mut self, n_steps: usize, bufs: &mut RolloutBuffers<'_>, mut policy: F)
    where
        F: FnMut(usize, &[f32], &mut [usize]),
    {
        let (b, p, d) = (self.b, self.p, self.obs_dim());
        assert_eq!(bufs.obs.len(), (n_steps + 1) * b * d, "obs must be [(T+1)*B*obs_dim]");
        assert_eq!(bufs.rewards.len(), n_steps * b, "rewards must be [T*B]");
        assert_eq!(bufs.dones.len(), n_steps * b, "dones must be [T*B]");
        assert_eq!(bufs.profits.len(), n_steps * b, "profits must be [T*B]");
        let mut actions = vec![0usize; b * p];
        let mut infos = vec![StepInfo::default(); b];
        self.observe_all(&mut bufs.obs[..b * d]);
        let shards = self.auto_shards();
        let pool = if shards > 1 { Some(self.ensure_pool(shards)) } else { None };
        for t in 0..n_steps {
            let (obs_t, obs_next) = bufs.obs[t * b * d..].split_at_mut(b * d);
            policy(t, obs_t, &mut actions);
            let out = StepOut {
                obs: &mut obs_next[..b * d],
                rewards: &mut bufs.rewards[t * b..(t + 1) * b],
                dones: &mut bufs.dones[t * b..(t + 1) * b],
                profits: &mut bufs.profits[t * b..(t + 1) * b],
            };
            let acts = StepActs::Given(actions.as_slice());
            let mut tasks = self.shard_tasks(acts, &mut infos, Some(out), shards);
            run_shard_tasks(pool.as_deref(), &mut tasks);
        }
    }

    /// Fused rollout with the policy forward INSIDE the shard tasks: per
    /// step, each pool shard forwards + samples actions for its own lanes
    /// (shared-read weights, per-shard scratch, per-(lane, t) counter RNG
    /// keyed on `policy_seed`) and then steps + observes them in the same
    /// dispatch — no serial caller-thread policy pass. Sampled actions,
    /// log-probs, and value estimates land in `pol` (time-major, like the
    /// env-side buffers in `bufs`). `greedy` switches every head to
    /// argmax decode (eval mode; `pol.logp` is left 0).
    ///
    /// Determinism: a lane's action at step `t` is a pure function of
    /// `(weights, obs, policy_seed, lane, t)`, and its obs stream of its
    /// own counter RNG — so the whole rollout is bit-identical for ANY
    /// thread count or shard placement. The sampled stream intentionally
    /// differs from the serial-policy [`VectorEnv::rollout`] closure path
    /// (one shared RNG walked in lane order cannot be shard-invariant).
    pub fn rollout_fused(
        &mut self,
        n_steps: usize,
        bufs: &mut RolloutBuffers<'_>,
        pol: &mut PolicyRollout<'_>,
        learner: &Learner,
        policy_seed: u64,
        greedy: bool,
    ) {
        let (b, p, d) = (self.b, self.p, self.obs_dim());
        assert_eq!(bufs.obs.len(), (n_steps + 1) * b * d, "obs must be [(T+1)*B*obs_dim]");
        assert_eq!(bufs.rewards.len(), n_steps * b, "rewards must be [T*B]");
        assert_eq!(bufs.dones.len(), n_steps * b, "dones must be [T*B]");
        assert_eq!(bufs.profits.len(), n_steps * b, "profits must be [T*B]");
        assert_eq!(pol.actions.len(), n_steps * b * p, "actions must be [T*B*n_ports]");
        assert_eq!(pol.logp.len(), n_steps * b, "logp must be [T*B]");
        assert_eq!(pol.values.len(), n_steps * b, "values must be [T*B]");
        let policy = PolicyRef::PerFamily(learner);
        assert_eq!(policy.obs_dim(), d, "learner obs_dim does not match env");
        assert_eq!(policy.n_ports(), p, "learner n_ports does not match env");
        let shards = self.auto_shards();
        let pool = if shards > 1 { Some(self.ensure_pool(shards)) } else { None };
        // One forward scratch per shard, allocated once and reused for
        // every (lane, step) that shard handles.
        let mut scratch: Vec<MlpScratch> =
            (0..shards).map(|_| policy.make_scratch()).collect();
        let mut infos = vec![StepInfo::default(); b];
        self.observe_all(&mut bufs.obs[..b * d]);
        for t in 0..n_steps {
            let (obs_t, obs_next) = bufs.obs[t * b * d..].split_at_mut(b * d);
            let fused = FusedStep {
                learner: policy,
                seed: policy_seed,
                t,
                greedy,
                obs_t: &*obs_t,
                actions: &mut pol.actions[t * b * p..(t + 1) * b * p],
                logp: &mut pol.logp[t * b..(t + 1) * b],
                values: &mut pol.values[t * b..(t + 1) * b],
                scratch: &mut scratch,
            };
            let out = StepOut {
                obs: &mut obs_next[..b * d],
                rewards: &mut bufs.rewards[t * b..(t + 1) * b],
                dones: &mut bufs.dones[t * b..(t + 1) * b],
                profits: &mut bufs.profits[t * b..(t + 1) * b],
            };
            let mut tasks =
                self.shard_tasks(StepActs::Fused(fused), &mut infos, Some(out), shards);
            run_shard_tasks(pool.as_deref(), &mut tasks);
        }
    }

    /// Observations for every lane into `out` (`[B * obs_dim]` row-major).
    pub fn observe_all(&self, out: &mut [f32]) {
        let d = self.obs_dim();
        assert_eq!(out.len(), self.b * d, "out must be [B * obs_dim]");
        for (lane, row) in out.chunks_mut(d).enumerate() {
            self.observe_lane_into(lane, row);
        }
    }

    pub fn observe_lane_into(&self, lane: usize, out: &mut [f32]) {
        let (c, p) = (self.c, self.p);
        let view = LaneRef {
            t: self.t[lane],
            day: self.day[lane],
            battery_soc: self.battery_soc[lane],
            present: &self.present[lane * c..(lane + 1) * c],
            soc: &self.soc[lane * c..(lane + 1) * c],
            de_remain: &self.de_remain[lane * c..(lane + 1) * c],
            dt_remain: &self.dt_remain[lane * c..(lane + 1) * c],
            r_bar: &self.r_bar[lane * c..(lane + 1) * c],
            tau: &self.tau[lane * c..(lane + 1) * c],
            i_drawn: &self.i_drawn[lane * p..(lane + 1) * p],
        };
        core::observe_lane(
            &view,
            &self.cfg,
            &self.tree,
            &self.tables[self.lane_scenario[lane] as usize],
            self.grid_headroom,
            out,
        );
    }

    /// Split the SoA state (plus optional per-step output buffers) into
    /// `shards` disjoint contiguous lane blocks. Chunk boundaries depend
    /// only on `(B, shards)`, so the pool and the scoped fallback compute
    /// bit-identical results for the same shard count. `pub(crate)` so the
    /// fleet scheduler can pool tasks from several envs into one dispatch.
    /// In fused mode ([`StepActs::Fused`]) each task additionally gets its
    /// lanes' policy-input obs row, output slices, and one scratch buffer,
    /// so the shard can run its own policy forwards before stepping.
    pub(crate) fn shard_tasks<'a>(
        &'a mut self,
        acts: StepActs<'a>,
        infos: &'a mut [StepInfo],
        out: Option<StepOut<'a>>,
        shards: usize,
    ) -> Vec<ShardTask<'a>> {
        self.shard_tasks_mode(acts, infos, out, shards, StepMode::Full)
    }

    /// [`VectorEnv::shard_tasks`] with an explicit step phase. A propose
    /// dispatch carries no infos/out (nothing is committed yet) and writes
    /// only the mode's per-lane proposal buffers; a commit dispatch
    /// carries no action source. Shard boundaries are identical across
    /// the phases (same `(B, shards)` split), so a propose + commit pair
    /// covers exactly the lanes a single `Full` dispatch would.
    pub(crate) fn shard_tasks_mode<'a>(
        &'a mut self,
        mut acts: StepActs<'a>,
        infos: &'a mut [StepInfo],
        out: Option<StepOut<'a>>,
        shards: usize,
        mut mode: StepMode<'a>,
    ) -> Vec<ShardTask<'a>> {
        let proposing = matches!(mode, StepMode::Propose { .. });
        match &mode {
            StepMode::Full => assert!(
                !matches!(acts, StepActs::Committed),
                "a full step needs an action source"
            ),
            StepMode::Propose { grid_kw, excess } => {
                assert_eq!(grid_kw.len(), self.b, "propose grid_kw must be [B]");
                assert_eq!(excess.len(), self.b, "propose excess must be [B]");
                assert!(out.is_none(), "propose commits nothing — no step outputs");
                assert!(
                    !matches!(acts, StepActs::Committed),
                    "a propose dispatch needs an action source"
                );
            }
            StepMode::Commit { excess, .. } => {
                assert_eq!(excess.len(), self.b, "commit excess must be [B]");
                assert!(
                    matches!(acts, StepActs::Committed),
                    "a commit dispatch must not re-act (currents already staged)"
                );
            }
        }
        if proposing {
            assert!(infos.is_empty(), "propose produces no StepInfo");
        } else {
            assert_eq!(infos.len(), self.b, "infos must be [B]");
        }
        let shards = shards.clamp(1, self.b.max(1));
        let lanes_per = self.b.div_ceil(shards);
        match &acts {
            StepActs::Given(a) => {
                assert_eq!(a.len(), self.b * self.p, "actions must be [B * n_ports]");
            }
            StepActs::Committed => {}
            StepActs::Fused(f) => {
                let d = core::obs_dim(&self.cfg);
                assert_eq!(f.obs_t.len(), self.b * d, "fused obs_t must be [B * obs_dim]");
                assert_eq!(f.actions.len(), self.b * self.p, "fused actions must be [B * n_ports]");
                assert_eq!(f.logp.len(), self.b, "fused logp must be [B]");
                assert_eq!(f.values.len(), self.b, "fused values must be [B]");
                let n_tasks = self.b.div_ceil(lanes_per);
                assert!(
                    f.scratch.len() >= n_tasks,
                    "fused rollout needs one scratch per shard task ({} < {n_tasks})",
                    f.scratch.len()
                );
            }
        }
        let VectorEnv {
            ref cfg,
            ref tree,
            ref tables,
            ref lane_scenario,
            b,
            c,
            p,
            ref mut t,
            ref mut day,
            ref mut battery_soc,
            ref mut ep_return,
            ref mut ep_profit,
            ref mut rng,
            ref mut present,
            ref mut soc,
            ref mut de_remain,
            ref mut dt_remain,
            ref mut cap,
            ref mut r_bar,
            ref mut tau,
            ref mut sensitive,
            ref mut i_drawn,
            grid_headroom,
            ..
        } = *self;
        let d = core::obs_dim(cfg);

        let mut scen = lane_scenario.as_slice();
        let mut t = t.as_mut_slice();
        let mut day = day.as_mut_slice();
        let mut bsoc = battery_soc.as_mut_slice();
        let mut ep_r = ep_return.as_mut_slice();
        let mut ep_p = ep_profit.as_mut_slice();
        let mut rng = rng.as_mut_slice();
        let mut present = present.as_mut_slice();
        let mut soc = soc.as_mut_slice();
        let mut de = de_remain.as_mut_slice();
        let mut dt = dt_remain.as_mut_slice();
        let mut cap = cap.as_mut_slice();
        let mut r_bar = r_bar.as_mut_slice();
        let mut tau = tau.as_mut_slice();
        let mut sens = sensitive.as_mut_slice();
        let mut i_drawn = i_drawn.as_mut_slice();
        let mut infos = infos;
        let mut out = out;

        let mut tasks = Vec::with_capacity(shards);
        let mut lane0 = 0usize;
        let mut remaining = b;
        while remaining > 0 {
            let take = lanes_per.min(remaining);
            remaining -= take;

            macro_rules! split_mut {
                ($v:ident, $n:expr) => {{
                    let (head, rest) = std::mem::take(&mut $v).split_at_mut($n);
                    $v = rest;
                    head
                }};
            }
            macro_rules! split_ref {
                ($v:ident, $n:expr) => {{
                    let (head, rest) = $v.split_at($n);
                    $v = rest;
                    head
                }};
            }

            let out_h = out.take().map(|o| {
                let (obs_h, obs_r) = o.obs.split_at_mut(take * d);
                let (rew_h, rew_r) = o.rewards.split_at_mut(take);
                let (done_h, done_r) = o.dones.split_at_mut(take);
                let (prof_h, prof_r) = o.profits.split_at_mut(take);
                out = Some(StepOut { obs: obs_r, rewards: rew_r, dones: done_r, profits: prof_r });
                StepOut { obs: obs_h, rewards: rew_h, dones: done_h, profits: prof_h }
            });

            // This shard's slice of the step-phase buffers.
            let task_mode = match &mut mode {
                StepMode::Full => StepMode::Full,
                StepMode::Propose { grid_kw, excess } => {
                    let (g_h, g_r) = std::mem::take(grid_kw).split_at_mut(take);
                    *grid_kw = g_r;
                    let (e_h, e_r) = std::mem::take(excess).split_at_mut(take);
                    *excess = e_r;
                    StepMode::Propose { grid_kw: g_h, excess: e_h }
                }
                StepMode::Commit { budget, excess } => {
                    let (e_h, e_r) = excess.split_at(take);
                    *excess = e_r;
                    StepMode::Commit { budget: *budget, excess: e_h }
                }
            };

            // This shard's slice of the action source (and, in fused mode,
            // of the policy input/output buffers + one scratch).
            let task_acts = match &mut acts {
                StepActs::Given(a) => {
                    let (head, rest) = a.split_at(take * p);
                    *a = rest;
                    ShardActs::Given(head)
                }
                StepActs::Committed => ShardActs::Committed,
                StepActs::Fused(f) => {
                    let (obs_h, obs_r) = f.obs_t.split_at(take * d);
                    f.obs_t = obs_r;
                    let (act_h, act_r) =
                        std::mem::take(&mut f.actions).split_at_mut(take * p);
                    f.actions = act_r;
                    let (logp_h, logp_r) = std::mem::take(&mut f.logp).split_at_mut(take);
                    f.logp = logp_r;
                    let (val_h, val_r) = std::mem::take(&mut f.values).split_at_mut(take);
                    f.values = val_r;
                    let (scr_h, scr_r) = std::mem::take(&mut f.scratch)
                        .split_first_mut()
                        .expect("one scratch per shard task");
                    f.scratch = scr_r;
                    ShardActs::Fused(FusedShard {
                        learner: f.learner,
                        seed: f.seed,
                        t: f.t,
                        lane0,
                        greedy: f.greedy,
                        obs_t: obs_h,
                        actions: act_h,
                        logp: logp_h,
                        values: val_h,
                        scratch: scr_h,
                    })
                }
            };

            tasks.push(ShardTask {
                cfg,
                tree,
                tables,
                scen: split_ref!(scen, take),
                t: split_mut!(t, take),
                day: split_mut!(day, take),
                battery_soc: split_mut!(bsoc, take),
                ep_return: split_mut!(ep_r, take),
                ep_profit: split_mut!(ep_p, take),
                rng: split_mut!(rng, take),
                present: split_mut!(present, take * c),
                soc: split_mut!(soc, take * c),
                de_remain: split_mut!(de, take * c),
                dt_remain: split_mut!(dt, take * c),
                cap: split_mut!(cap, take * c),
                r_bar: split_mut!(r_bar, take * c),
                tau: split_mut!(tau, take * c),
                sensitive: split_mut!(sens, take * c),
                i_drawn: split_mut!(i_drawn, take * p),
                acts: task_acts,
                infos: split_mut!(infos, if proposing { 0 } else { take }),
                out: out_h,
                mode: task_mode,
                headroom: grid_headroom,
            });
            lane0 += take;
        }
        tasks
    }
}

/// Per-step output slices for one shard's lanes (fused rollout only).
pub(crate) struct StepOut<'a> {
    pub(crate) obs: &'a mut [f32],
    pub(crate) rewards: &'a mut [f32],
    pub(crate) dones: &'a mut [f32],
    pub(crate) profits: &'a mut [f32],
}

/// Whole-env action source for one step: caller-supplied rows (serial
/// policy or scripted actions) or a fused policy the shards evaluate
/// themselves. `shard_tasks` splits either variant into per-shard blocks.
/// `Committed` is the commit dispatch of a two-phase coupled step: the
/// matching propose dispatch already staged every lane's currents, so no
/// action source exists (or is needed).
pub(crate) enum StepActs<'a> {
    Given(&'a [usize]),
    Fused(FusedStep<'a>),
    Committed,
}

/// Which phase of the step a dispatch runs. Uncoupled envs always use
/// `Full` (the single-phase [`core::step_lane`] — byte-identical to the
/// pre-coupling runtime). A feeder-coupled env steps in two dispatches:
/// `Propose` stages currents and records each lane's would-be grid draw
/// (kW) and pre-projection excess; the caller reduces the draws, picks a
/// [`GridBudget`] per coupling group, and dispatches `Commit` to apply it.
pub(crate) enum StepMode<'a> {
    Full,
    Propose {
        /// Per-lane proposed grid draw (kW), written by the shards.
        grid_kw: &'a mut [f32],
        /// Per-lane pre-projection excess (kW), carried to the commit.
        excess: &'a mut [f32],
    },
    Commit {
        /// The group's allocation (same for every lane of the env — an
        /// env belongs to at most one coupling group).
        budget: GridBudget,
        /// The per-lane excess recorded by the propose dispatch.
        excess: &'a [f32],
    },
}

/// Env-wide fused-policy inputs/outputs for one step (see
/// [`VectorEnv::rollout_fused`]): the policy (shared read-only — a
/// per-family [`Learner`] or one family's view of the shared-trunk
/// generalist), the policy seed, the step index, the full `[B * obs_dim]`
/// observation row the policy reads, the full-width output rows it fills,
/// and one forward scratch per shard task.
pub(crate) struct FusedStep<'a> {
    pub(crate) learner: PolicyRef<'a>,
    pub(crate) seed: u64,
    pub(crate) t: usize,
    pub(crate) greedy: bool,
    pub(crate) obs_t: &'a [f32],
    pub(crate) actions: &'a mut [usize],
    pub(crate) logp: &'a mut [f32],
    pub(crate) values: &'a mut [f32],
    pub(crate) scratch: &'a mut [MlpScratch],
}

/// One shard's slice of [`StepActs`]: its lanes' pre-filled action rows,
/// the fused-policy block it must evaluate before stepping, or nothing
/// (commit dispatch — currents already staged).
pub(crate) enum ShardActs<'a> {
    Given(&'a [usize]),
    Fused(FusedShard<'a>),
    Committed,
}

/// One shard's fused-policy work: forward + sample `[lane0, lane0 + n)`
/// of the owning env using the shard's own scratch. `lane0` is the
/// env-local offset of this shard's first lane, so per-(lane, t) RNG
/// streams are global to the env, not the shard.
pub(crate) struct FusedShard<'a> {
    learner: PolicyRef<'a>,
    seed: u64,
    t: usize,
    lane0: usize,
    greedy: bool,
    obs_t: &'a [f32],
    actions: &'a mut [usize],
    logp: &'a mut [f32],
    values: &'a mut [f32],
    scratch: &'a mut MlpScratch,
}

/// One shard's work item: a contiguous block of lanes plus everything
/// needed to act (fused mode), step, and (in rollout mode) observe them.
pub(crate) struct ShardTask<'a> {
    cfg: &'a StationConfig,
    tree: &'a StationTree,
    tables: &'a [Arc<ScenarioTables>],
    scen: &'a [u32],
    t: &'a mut [u32],
    day: &'a mut [u32],
    battery_soc: &'a mut [f32],
    ep_return: &'a mut [f32],
    ep_profit: &'a mut [f32],
    rng: &'a mut [CounterRng],
    present: &'a mut [bool],
    soc: &'a mut [f32],
    de_remain: &'a mut [f32],
    dt_remain: &'a mut [f32],
    cap: &'a mut [f32],
    r_bar: &'a mut [f32],
    tau: &'a mut [f32],
    sensitive: &'a mut [bool],
    i_drawn: &'a mut [f32],
    acts: ShardActs<'a>,
    infos: &'a mut [StepInfo],
    out: Option<StepOut<'a>>,
    /// Which step phase this task runs (its slice of the phase buffers).
    mode: StepMode<'a>,
    /// Feeder headroom the observe pass reports (coupled envs only).
    headroom: f32,
}

impl ShardTask<'_> {
    /// Act (fused mode), step, and (in rollout mode) observe every lane in
    /// this shard.
    pub(crate) fn run(&mut self) {
        let c = self.cfg.n_chargers();
        let p = self.cfg.n_ports();
        let d = core::obs_dim(self.cfg);
        // Fused mode: forward + sample this shard's lanes before stepping
        // them — policy inference runs inside the same dispatch, on the
        // same worker. The shard's whole contiguous lane range goes
        // through ONE lane-blocked forward (ISSUE 6 kernels) instead of
        // per-lane rows; the blocked GEMM is bitwise row-blocking
        // invariant and sampling uses per-(lane, t) counter RNG, so shard
        // placement still can never change a lane's action stream.
        if let ShardActs::Fused(f) = &mut self.acts {
            let _span = telemetry::Span::fine(telemetry::SpanKind::PolicyForward);
            if f.greedy {
                f.logp.fill(0.0);
                f.learner.greedy_block(f.obs_t, f.actions, f.values, f.scratch);
            } else {
                f.learner.sample_block(
                    f.t, f.lane0, f.seed, f.obs_t, f.actions, f.logp, f.values, f.scratch,
                );
            }
        }
        let actions: &[usize] = match &self.acts {
            ShardActs::Given(a) => *a,
            ShardActs::Fused(f) => &*f.actions,
            ShardActs::Committed => &[],
        };
        // Telemetry: the env-step span covers step + observe for this
        // shard's whole lane block; domain counters accumulate in locals
        // (one branch per lane when recording, nothing when not) and
        // commit once per task.
        let _span = telemetry::Span::fine(telemetry::SpanKind::EnvStep);
        let recording = telemetry::recording();
        let mut scratch = Scratch::new(p);
        // Propose phase: stage currents and record each lane's would-be
        // draw. Nothing commits — no RNG draw, no clock advance, no
        // counters, no observation — so an allocate + commit can follow
        // with the lane exactly where a single-phase step's phase (i)
        // would have left it.
        if let StepMode::Propose { grid_kw, excess } = &mut self.mode {
            for lane in 0..self.t.len() {
                let mut view = LaneView {
                    t: &mut self.t[lane],
                    day: &mut self.day[lane],
                    battery_soc: &mut self.battery_soc[lane],
                    ep_return: &mut self.ep_return[lane],
                    ep_profit: &mut self.ep_profit[lane],
                    present: &mut self.present[lane * c..(lane + 1) * c],
                    soc: &mut self.soc[lane * c..(lane + 1) * c],
                    de_remain: &mut self.de_remain[lane * c..(lane + 1) * c],
                    dt_remain: &mut self.dt_remain[lane * c..(lane + 1) * c],
                    cap: &mut self.cap[lane * c..(lane + 1) * c],
                    r_bar: &mut self.r_bar[lane * c..(lane + 1) * c],
                    tau: &mut self.tau[lane * c..(lane + 1) * c],
                    sensitive: &mut self.sensitive[lane * c..(lane + 1) * c],
                    i_drawn: &mut self.i_drawn[lane * p..(lane + 1) * p],
                };
                excess[lane] = core::stage_currents(
                    &mut view,
                    self.cfg,
                    self.tree,
                    &actions[lane * p..(lane + 1) * p],
                    &mut scratch,
                );
                grid_kw[lane] = core::proposed_grid_kw(&view, self.cfg, self.tree);
            }
            return;
        }
        let (budget, staged_excess): (GridBudget, Option<&[f32]>) = match &self.mode {
            StepMode::Full => (GridBudget::UNCURTAILED, None),
            StepMode::Commit { budget, excess } => (*budget, Some(excess)),
            StepMode::Propose { .. } => unreachable!("handled above"),
        };
        let (mut arrived, mut departed, mut grid_kwh) = (0.0f64, 0.0f64, 0.0f64);
        for lane in 0..self.t.len() {
            let mut view = LaneView {
                t: &mut self.t[lane],
                day: &mut self.day[lane],
                battery_soc: &mut self.battery_soc[lane],
                ep_return: &mut self.ep_return[lane],
                ep_profit: &mut self.ep_profit[lane],
                present: &mut self.present[lane * c..(lane + 1) * c],
                soc: &mut self.soc[lane * c..(lane + 1) * c],
                de_remain: &mut self.de_remain[lane * c..(lane + 1) * c],
                dt_remain: &mut self.dt_remain[lane * c..(lane + 1) * c],
                cap: &mut self.cap[lane * c..(lane + 1) * c],
                r_bar: &mut self.r_bar[lane * c..(lane + 1) * c],
                tau: &mut self.tau[lane * c..(lane + 1) * c],
                sensitive: &mut self.sensitive[lane * c..(lane + 1) * c],
                i_drawn: &mut self.i_drawn[lane * p..(lane + 1) * p],
            };
            let tables = &self.tables[self.scen[lane] as usize];
            let info = match staged_excess {
                // Single-phase step: the uncoupled path, byte-identical
                // to the pre-coupling runtime.
                None => core::step_lane(
                    &mut view,
                    &mut self.rng[lane],
                    self.cfg,
                    self.tree,
                    tables,
                    &actions[lane * p..(lane + 1) * p],
                    &mut scratch,
                ),
                // Commit phase: apply the group's allocation to the
                // currents staged by the propose dispatch.
                Some(ex) => core::commit_lane(
                    &mut view,
                    &mut self.rng[lane],
                    self.cfg,
                    self.tree,
                    tables,
                    budget,
                    ex[lane],
                ),
            };
            self.infos[lane] = info;
            if recording {
                arrived += info.arrived as f64;
                departed += info.departed as f64;
                grid_kwh += info.energy_grid_net_kwh as f64;
            }
            if let Some(out) = &mut self.out {
                out.rewards[lane] = info.reward;
                out.dones[lane] = info.done as i32 as f32;
                out.profits[lane] = info.profit;
                let ref_view = LaneRef {
                    t: self.t[lane],
                    day: self.day[lane],
                    battery_soc: self.battery_soc[lane],
                    present: &self.present[lane * c..(lane + 1) * c],
                    soc: &self.soc[lane * c..(lane + 1) * c],
                    de_remain: &self.de_remain[lane * c..(lane + 1) * c],
                    dt_remain: &self.dt_remain[lane * c..(lane + 1) * c],
                    r_bar: &self.r_bar[lane * c..(lane + 1) * c],
                    tau: &self.tau[lane * c..(lane + 1) * c],
                    i_drawn: &self.i_drawn[lane * p..(lane + 1) * p],
                };
                core::observe_lane(
                    &ref_view,
                    self.cfg,
                    self.tree,
                    tables,
                    self.headroom,
                    &mut out.obs[lane * d..(lane + 1) * d],
                );
            }
        }
        if recording {
            telemetry::counters(|c| {
                c.env_steps += self.t.len() as u64;
                c.cars_arrived += arrived as u64;
                c.cars_departed += departed as u64;
                c.grid_kwh += grid_kwh;
            });
        }
    }
}

/// Dispatch shard tasks on the pool (caller thread runs shard 0) or, when
/// no pool is supplied or there is a single shard, inline. (The fleet
/// scheduler has its own dispatcher — `fleet::rollout::run_fleet_tasks` —
/// which additionally strides tasks when they outnumber pool lanes.)
fn run_shard_tasks(pool: Option<&WorkerPool>, tasks: &mut [ShardTask<'_>]) {
    match pool {
        Some(pool) if tasks.len() > 1 => {
            let shared = DisjointTasks::new(tasks);
            // SAFETY: `run` hands shard index `s` to exactly one thread,
            // so task `s` has exactly one visitor — no locks on the hot
            // path (telemetry-budget rule).
            pool.run(shared.len(), |s| unsafe { shared.get(s) }.run());
        }
        _ => {
            let _scope = telemetry::quiet_scope();
            for task in tasks {
                task.run();
            }
        }
    }
}

/// Table 2 native batch-size sweep (shared by `chargax bench table2` and
/// `benches/table2_throughput` so the printed table and the JSON artifact
/// always cover the same points).
pub const NATIVE_SWEEP_B: &[usize] = &[1, 16, 256, 1024, 4096];

/// Which execution path a throughput measurement drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPath {
    /// Persistent worker-pool `step_all` (the default runtime).
    Pool,
    /// Per-call scoped-thread fallback (`step_all_sharded`).
    Scoped,
    /// Fused `rollout` writing obs/rewards/dones into PPO-style buffers
    /// (trivial copy policy — measures the env runtime alone).
    Rollout,
    /// Fused rollout with a REAL MLP policy sampled serially on the
    /// caller thread (the pre-fused training path, kept as comparator).
    PolicySerial,
    /// Fused rollout with the same MLP policy forwarded + sampled inside
    /// the shard tasks (`rollout_fused`, the default training path).
    PolicyFused,
}

impl StepPath {
    pub fn label(&self) -> &'static str {
        match self {
            StepPath::Pool => "native-vector",
            StepPath::Scoped => "native-scoped",
            StepPath::Rollout => "native-rollout",
            StepPath::PolicySerial => "policy-serial",
            StepPath::PolicyFused => "policy-fused",
        }
    }
}

/// Hidden width of the throughput-bench policy net: large enough that the
/// forward dominates a lane-step (so serial-vs-fused is a real contrast),
/// small enough for the CI smoke sweep.
pub const BENCH_POLICY_HIDDEN: usize = 64;

/// Measure raw env throughput at batch size `b` with random actions
/// refreshed every step: one warm pass then one timed pass. Shared by
/// `benches/table2_throughput` and `chargax bench table2` so the JSON
/// artifact and the printed table can never use different protocols.
/// `threads` caps the shard count (0 = auto); `budget` is the approximate
/// env-step count per pass. Returns (env-steps/sec, seconds per 100k env
/// steps).
pub fn measure_throughput(
    tables: Arc<ScenarioTables>,
    b: usize,
    threads: usize,
    path: StepPath,
    budget: usize,
) -> (f64, f64) {
    use crate::util::rng::Rng;

    let mut venv = VectorEnv::new(StationConfig::default(), tables, b, 11);
    venv.set_threads(threads);
    let nvec = venv.action_nvec();
    let p = venv.n_ports();
    let d = venv.obs_dim();
    let reps = (budget / b.max(1)).clamp(8, 20_000);
    // Pre-generate every step's actions so the timed region contains only
    // the runtime under test — serial host-side RNG would otherwise be
    // billed as env throughput, and it grows with B.
    let mut arng = Rng::new(17);
    let steps;
    let mut pass: Box<dyn FnMut(&mut VectorEnv)> = match path {
        StepPath::Pool | StepPath::Scoped => {
            let all_actions: Vec<usize> = (0..reps * b * p)
                .map(|k| arng.below(nvec[k % p] as u32) as usize)
                .collect();
            let mut infos = vec![StepInfo::default(); b];
            steps = (reps * b) as f64;
            let scoped = path == StepPath::Scoped;
            Box::new(move |venv: &mut VectorEnv| {
                for actions in all_actions.chunks_exact(b * p) {
                    if scoped {
                        let shards = venv.auto_shards();
                        venv.step_all_sharded(actions, &mut infos, shards);
                    } else {
                        venv.step_all(actions, &mut infos);
                    }
                }
            })
        }
        StepPath::Rollout => {
            // Chunked fused rollouts (bounded T keeps the obs buffer small
            // at large B) with a "policy" that copies pre-drawn actions.
            let t_chunk = reps.min(64);
            let n_chunks = reps.div_ceil(t_chunk);
            steps = (n_chunks * t_chunk * b) as f64;
            let all_actions: Vec<usize> = (0..t_chunk * b * p)
                .map(|k| arng.below(nvec[k % p] as u32) as usize)
                .collect();
            let mut obs = vec![0f32; (t_chunk + 1) * b * d];
            let mut rewards = vec![0f32; t_chunk * b];
            let mut dones = vec![0f32; t_chunk * b];
            let mut profits = vec![0f32; t_chunk * b];
            Box::new(move |venv: &mut VectorEnv| {
                for _ in 0..n_chunks {
                    let mut bufs = RolloutBuffers {
                        obs: &mut obs,
                        rewards: &mut rewards,
                        dones: &mut dones,
                        profits: &mut profits,
                    };
                    venv.rollout(t_chunk, &mut bufs, |t, _obs, actions| {
                        actions.copy_from_slice(&all_actions[t * b * p..(t + 1) * b * p]);
                    });
                }
            })
        }
        StepPath::PolicySerial | StepPath::PolicyFused => {
            // Real MLP policy over chunked rollouts: serial samples on the
            // caller thread via `sample_row` (the pre-fused path), fused
            // forwards + samples inside the shard tasks. Identical nets
            // and buffer shapes, so the row pair isolates where the
            // policy forward runs.
            let t_chunk = reps.min(64);
            let n_chunks = reps.div_ceil(t_chunk);
            steps = (n_chunks * t_chunk * b) as f64;
            let mut lrng = Rng::new(41);
            let learner = Learner::new(&mut lrng, d, BENCH_POLICY_HIDDEN, nvec.clone());
            let mut obs = vec![0f32; (t_chunk + 1) * b * d];
            let mut rewards = vec![0f32; t_chunk * b];
            let mut dones = vec![0f32; t_chunk * b];
            let mut profits = vec![0f32; t_chunk * b];
            let mut act = vec![0usize; t_chunk * b * p];
            let mut logp = vec![0f32; t_chunk * b];
            let mut values = vec![0f32; t_chunk * b];
            let fused = path == StepPath::PolicyFused;
            let mut srng = Rng::new(91);
            Box::new(move |venv: &mut VectorEnv| {
                for chunk in 0..n_chunks {
                    let mut bufs = RolloutBuffers {
                        obs: &mut obs,
                        rewards: &mut rewards,
                        dones: &mut dones,
                        profits: &mut profits,
                    };
                    if fused {
                        let mut pol = PolicyRollout {
                            actions: &mut act,
                            logp: &mut logp,
                            values: &mut values,
                        };
                        venv.rollout_fused(
                            t_chunk, &mut bufs, &mut pol, &learner, chunk as u64, false,
                        );
                    } else {
                        let learner = &learner;
                        let srng = &mut srng;
                        let logp = &mut logp;
                        let values = &mut values;
                        let act = &mut act;
                        venv.rollout(t_chunk, &mut bufs, |t, obs_t, actions| {
                            learner.sample_row(
                                srng,
                                obs_t,
                                actions,
                                &mut logp[t * b..(t + 1) * b],
                                &mut values[t * b..(t + 1) * b],
                            );
                            act[t * b * p..(t + 1) * b * p].copy_from_slice(actions);
                        });
                    }
                }
            })
        }
    };
    pass(&mut venv); // warm (also builds the pool)
    let t0 = std::time::Instant::now();
    pass(&mut venv);
    let el = t0.elapsed().as_secs_f64();
    (steps / el, el * 100_000.0 / steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mixed_env(b: usize) -> VectorEnv {
        let tables = vec![
            Arc::new(ScenarioTables::synthetic(0.8)),
            Arc::new(ScenarioTables::synthetic(2.0)),
        ];
        let scen: Vec<usize> = (0..b).map(|j| j % 2).collect();
        let seeds: Vec<u64> = (0..b as u64).map(|j| 1000 + j * 7).collect();
        VectorEnv::with_seeds(StationConfig::default(), tables, scen, &seeds)
    }

    fn random_actions(rng: &mut Rng, env: &VectorEnv) -> Vec<usize> {
        let nvec = env.action_nvec();
        (0..env.batch())
            .flat_map(|_| nvec.iter().map(|&n| rng.below(n as u32) as usize).collect::<Vec<_>>())
            .collect()
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let mut rng = Rng::new(42);
        let mut envs: Vec<VectorEnv> = (0..3).map(|_| mixed_env(8)).collect();
        let mut infos = vec![StepInfo::default(); 8];
        for step in 0..100 {
            let actions = random_actions(&mut rng, &envs[0]);
            let mut rewards = Vec::new();
            for (i, env) in envs.iter_mut().enumerate() {
                env.step_all_sharded(&actions, &mut infos, [1, 3, 8][i]);
                rewards.push(infos.iter().map(|x| x.reward).collect::<Vec<_>>());
            }
            assert_eq!(rewards[0], rewards[1], "1 vs 3 shards diverged at step {step}");
            assert_eq!(rewards[0], rewards[2], "1 vs 8 shards diverged at step {step}");
        }
        let obs_len = envs[0].batch() * envs[0].obs_dim();
        let mut o1 = vec![0f32; obs_len];
        let mut o3 = vec![0f32; obs_len];
        envs[0].observe_all(&mut o1);
        envs[1].observe_all(&mut o3);
        assert_eq!(o1, o3);
    }

    #[test]
    fn pool_matches_scoped_threads_bit_for_bit() {
        let mut rng = Rng::new(77);
        let mut pooled = mixed_env(8);
        pooled.set_threads(4);
        let mut scoped = mixed_env(8);
        let mut pi = vec![StepInfo::default(); 8];
        let mut si = vec![StepInfo::default(); 8];
        for step in 0..150 {
            let actions = random_actions(&mut rng, &pooled);
            let shards = [1, 2, 3, 4][step % 4];
            pooled.step_all_pooled(&actions, &mut pi, shards);
            scoped.step_all_sharded(&actions, &mut si, shards);
            for lane in 0..8 {
                assert_eq!(pi[lane].reward, si[lane].reward, "step {step} lane {lane}");
                assert_eq!(pi[lane].done, si[lane].done, "step {step} lane {lane}");
            }
        }
        let obs_len = pooled.batch() * pooled.obs_dim();
        let mut po = vec![0f32; obs_len];
        let mut so = vec![0f32; obs_len];
        pooled.observe_all(&mut po);
        scoped.observe_all(&mut so);
        assert_eq!(po, so);
    }

    #[test]
    fn mixed_batch_invariants_hold() {
        let mut env = mixed_env(16);
        let mut rng = Rng::new(7);
        let mut infos = vec![StepInfo::default(); 16];
        for _ in 0..300 {
            let actions = random_actions(&mut rng, &env);
            env.step_all(&actions, &mut infos);
            for (lane, info) in infos.iter().enumerate() {
                assert!(info.reward.is_finite());
                assert!((0.0..=1.0).contains(&env.lane_battery_soc(lane)));
                for slot in 0..env.n_chargers() {
                    if let Some(car) = env.lane_car(lane, slot) {
                        assert!((0.0..=1.0).contains(&car.soc));
                        assert!(car.cap > 0.0);
                    }
                }
            }
        }
        // high-traffic lanes (odd) should have seen more arrivals on
        // average than low-traffic lanes (even) — scenario heterogeneity
        // is actually wired through.
        let mut env2 = mixed_env(32);
        let mut arrived = vec![0f32; 32];
        let mut infos = vec![StepInfo::default(); 32];
        for _ in 0..288 {
            let actions = random_actions(&mut rng, &env2);
            env2.step_all(&actions, &mut infos);
            for (lane, info) in infos.iter().enumerate() {
                arrived[lane] += info.arrived;
            }
        }
        let low: f32 = arrived.iter().step_by(2).sum();
        let high: f32 = arrived.iter().skip(1).step_by(2).sum();
        assert!(high > low, "traffic heterogeneity not visible: low {low} high {high}");
    }

    #[test]
    fn episode_boundary_resets_all_lanes() {
        let mut env = VectorEnv::new(
            StationConfig::default(),
            ScenarioTables::synthetic(1.0),
            4,
            9,
        );
        let mut infos = vec![StepInfo::default(); 4];
        let actions = vec![0usize; 4 * env.n_ports()];
        for i in 1..=core::STEPS_PER_EPISODE {
            env.step_all(&actions, &mut infos);
            let all_done = infos.iter().all(|x| x.done);
            if i == core::STEPS_PER_EPISODE {
                assert!(all_done);
                for lane in 0..4 {
                    assert_eq!(env.lane_t(lane), 0);
                    assert_eq!(env.lane_ep_return(lane), 0.0);
                }
            } else {
                assert!(!all_done);
            }
        }
    }

    #[test]
    fn two_phase_dispatch_with_uncurtailed_budget_matches_step_all() {
        // propose → (no-op allocate) → commit must reproduce the
        // single-phase step bit for bit, even with DIFFERENT shard counts
        // for the two phases (the per-lane proposal buffers are in env
        // order, so phase shard plans are independent).
        let b = 8;
        let mut two = mixed_env(b);
        let mut full = mixed_env(b);
        let mut rng = Rng::new(5);
        let mut grid_kw = vec![0f32; b];
        let mut excess = vec![0f32; b];
        let mut infos2 = vec![StepInfo::default(); b];
        let mut infos1 = vec![StepInfo::default(); b];
        for step in 0..150 {
            let actions = random_actions(&mut rng, &full);
            let mut tasks = two.shard_tasks_mode(
                StepActs::Given(&actions),
                &mut [],
                None,
                [1, 3][step % 2],
                StepMode::Propose { grid_kw: &mut grid_kw, excess: &mut excess },
            );
            for t in tasks.iter_mut() {
                t.run();
            }
            assert!(grid_kw.iter().all(|x| x.is_finite()));
            let mut tasks = two.shard_tasks_mode(
                StepActs::Committed,
                &mut infos2,
                None,
                [2, 1][step % 2],
                StepMode::Commit { budget: GridBudget::UNCURTAILED, excess: &excess },
            );
            for t in tasks.iter_mut() {
                t.run();
            }
            full.step_all_sharded(&actions, &mut infos1, 1);
            for lane in 0..b {
                assert_eq!(
                    infos2[lane].reward.to_bits(),
                    infos1[lane].reward.to_bits(),
                    "step {step} lane {lane}"
                );
                assert_eq!(infos2[lane].done, infos1[lane].done, "step {step} lane {lane}");
            }
        }
        let mut o1 = vec![0f32; b * full.obs_dim()];
        let mut o2 = o1.clone();
        full.observe_all(&mut o1);
        two.observe_all(&mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn fused_rollout_matches_step_then_observe() {
        let b = 8;
        let t_len = 60;
        let mut rolled = mixed_env(b);
        rolled.set_threads(3);
        let mut stepped = mixed_env(b);
        let p = rolled.n_ports();
        let d = rolled.obs_dim();

        // Pre-draw one action row per step so both paths see identical
        // policies.
        let mut arng = Rng::new(31);
        let per_step: Vec<Vec<usize>> =
            (0..t_len).map(|_| random_actions(&mut arng, &rolled)).collect();

        let mut obs = vec![0f32; (t_len + 1) * b * d];
        let mut rewards = vec![0f32; t_len * b];
        let mut dones = vec![0f32; t_len * b];
        let mut profits = vec![0f32; t_len * b];
        let mut bufs = RolloutBuffers {
            obs: &mut obs,
            rewards: &mut rewards,
            dones: &mut dones,
            profits: &mut profits,
        };
        rolled.rollout(t_len, &mut bufs, |t, _obs, actions| {
            actions.copy_from_slice(&per_step[t]);
        });

        let mut infos = vec![StepInfo::default(); b];
        let mut want_obs = vec![0f32; b * d];
        stepped.observe_all(&mut want_obs);
        assert_eq!(&obs[..b * d], want_obs.as_slice(), "row 0");
        for (t, actions) in per_step.iter().enumerate() {
            stepped.step_all(actions, &mut infos);
            for lane in 0..b {
                assert_eq!(rewards[t * b + lane], infos[lane].reward, "step {t} lane {lane}");
                assert_eq!(profits[t * b + lane], infos[lane].profit, "step {t} lane {lane}");
                assert_eq!(
                    dones[t * b + lane],
                    infos[lane].done as i32 as f32,
                    "step {t} lane {lane}"
                );
            }
            stepped.observe_all(&mut want_obs);
            assert_eq!(
                &obs[(t + 1) * b * d..(t + 2) * b * d],
                want_obs.as_slice(),
                "obs row {}",
                t + 1
            );
        }
    }
}
