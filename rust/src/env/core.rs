//! Shared pure transition core for the native simulators.
//!
//! Every piece of the per-step semantics — action → current mapping,
//! charging/discharging curves, port current allocation (Eq. 5 projection),
//! battery update, departures, Poisson arrivals, reward (Eq. 2-3), and the
//! observation builder — lives here as functions over plain state slices.
//! [`super::scalar::ScalarEnv`] (B = 1) and [`super::vector::VectorEnv`]
//! (structure-of-arrays, B lanes) are both thin drivers over this module,
//! so their semantics cannot drift apart. All randomness flows through a
//! per-lane [`CounterRng`], making results independent of batch sharding
//! and thread count.

use crate::data::{DataStore, Scenario};
use crate::util::rng::CounterRng;

use super::tree::{charging_curve, discharging_curve, StationConfig, StationTree};

pub const STEPS_PER_EPISODE: usize = 288;
pub const DT_HOURS: f32 = 1.0 / 12.0;
pub const STEPS_PER_HOUR: usize = 12;
pub const N_LEVELS: usize = 11;
pub const N_LEVELS_BATTERY: usize = 21;
/// V2G car ports reuse the battery's symmetric signed ladder: level
/// `(L-1)/2` is idle, 0 is -100% (full discharge), `L-1` is +100%.
pub const N_LEVELS_V2G: usize = N_LEVELS_BATTERY;
pub const MAX_ARRIVALS: usize = 6;
pub const FIXED_COST_PER_STEP: f32 = 0.25;

/// A parked car (paper A.1 car state) — the AoS view of one charger lane.
#[derive(Debug, Clone, Copy, Default)]
pub struct Car {
    pub soc: f32,
    pub de_remain: f32,
    pub dt_remain: f32,
    pub cap: f32,
    pub r_bar: f32, // max kW at this port
    pub tau: f32,
    pub charge_sensitive: bool, // u = 1
}

/// Per-step outcome metrics (mirrors METRIC_FIELDS where applicable).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepInfo {
    pub reward: f32,
    pub profit: f32,
    pub energy_to_cars_kwh: f32,
    pub energy_grid_net_kwh: f32,
    pub excess_kw: f32,
    pub missing_kwh: f32,
    pub overtime_steps: f32,
    pub rejected: f32,
    pub departed: f32,
    pub arrived: f32,
    pub done: bool,
}

/// Scenario data resolved to flat tables. Shared across envs/lanes via
/// `Arc<ScenarioTables>` — built once, never cloned per environment.
pub struct ScenarioTables {
    pub price_buy: Vec<f32>,       // [days*24]
    pub price_sell_grid: Vec<f32>, // [days*24]
    pub moer: Vec<f32>,            // [days*24]
    pub arrival_rate: Vec<f32>,    // [24]
    pub car_table: Vec<f32>,       // [models*4]
    pub car_weights: Vec<f32>,
    pub user_profile: Vec<f32>, // [6]
    pub n_days: usize,
    pub alpha: [f32; 7],
    pub beta: f32,
    pub p_sell: f32,
    pub traffic: f32,
}

impl ScenarioTables {
    pub fn build(store: &DataStore, sc: &Scenario) -> anyhow::Result<ScenarioTables> {
        let buy = store.price(&sc.country, sc.year)?.clone();
        let sell: Vec<f32> = buy.iter().map(|x| x * sc.feed_in_ratio).collect();
        Ok(ScenarioTables {
            price_sell_grid: sell,
            price_buy: buy,
            moer: store.moer.clone(),
            arrival_rate: store.arrival_shapes[&sc.scenario].clone(),
            car_table: store.car_table.clone(),
            car_weights: store.car_weights[&sc.region].clone(),
            user_profile: store.user_profiles[&sc.scenario].clone(),
            n_days: store.n_days,
            alpha: sc.alpha,
            beta: sc.beta,
            p_sell: sc.p_sell,
            traffic: store.traffic[&sc.traffic],
        })
    }

    /// Synthetic tables needing no artifacts: flat prices, constant
    /// arrivals, a 3-model car catalog. Used by tests and by benches/CLI
    /// paths when `artifacts/data` has not been exported.
    pub fn synthetic(traffic: f32) -> ScenarioTables {
        ScenarioTables {
            price_buy: vec![0.10; 365 * 24],
            price_sell_grid: vec![0.09; 365 * 24],
            moer: vec![0.3; 365 * 24],
            arrival_rate: vec![3.0; 24],
            car_table: vec![
                60.0, 11.0, 120.0, 0.6, // model 0
                90.0, 11.0, 200.0, 0.5, // model 1
                40.0, 7.0, 50.0, 0.7, // model 2
            ],
            car_weights: vec![0.5, 0.3, 0.2],
            user_profile: vec![1.5, 0.6, 2.5, 3.0, 0.8, 0.65],
            n_days: 365,
            alpha: [0.0; 7],
            beta: 0.1,
            p_sell: 0.75,
            traffic,
        }
    }

    /// Synthetic tables parameterized by a [`Scenario`] (traffic level,
    /// price year shift, reward weights), so heterogeneous batches differ
    /// per lane even without exported artifacts.
    pub fn synthetic_for(sc: &Scenario) -> ScenarioTables {
        let traffic = match sc.traffic.as_str() {
            "low" => 0.5,
            "high" => 2.0,
            _ => 1.0,
        };
        let mut t = ScenarioTables::synthetic(traffic);
        let level = 0.08 + 0.02 * (sc.year.saturating_sub(2021) as f32);
        t.price_buy.iter_mut().for_each(|x| *x = level);
        t.price_sell_grid
            .iter_mut()
            .for_each(|x| *x = level * sc.feed_in_ratio);
        t.alpha = sc.alpha;
        t.beta = sc.beta;
        t.p_sell = sc.p_sell;
        t
    }
}

/// Mutable view of one lane's state (B = 1 slice of the SoA block).
/// Charger-indexed slices have length C; `i_drawn` has length P = C + 1
/// (last lane is the battery port).
pub struct LaneView<'a> {
    pub t: &'a mut u32,
    pub day: &'a mut u32,
    pub battery_soc: &'a mut f32,
    pub ep_return: &'a mut f32,
    pub ep_profit: &'a mut f32,
    pub present: &'a mut [bool],
    pub soc: &'a mut [f32],
    pub de_remain: &'a mut [f32],
    pub dt_remain: &'a mut [f32],
    pub cap: &'a mut [f32],
    pub r_bar: &'a mut [f32],
    pub tau: &'a mut [f32],
    pub sensitive: &'a mut [bool],
    pub i_drawn: &'a mut [f32],
}

/// Immutable view of one lane, for the observation builder.
pub struct LaneRef<'a> {
    pub t: u32,
    pub day: u32,
    pub battery_soc: f32,
    pub present: &'a [bool],
    pub soc: &'a [f32],
    pub de_remain: &'a [f32],
    pub dt_remain: &'a [f32],
    pub r_bar: &'a [f32],
    pub tau: &'a [f32],
    pub i_drawn: &'a [f32],
}

/// Per-worker scratch (no per-step allocations on the hot path).
pub struct Scratch {
    pub i_new: Vec<f32>,
    pub leaf_scale: Vec<f32>,
}

impl Scratch {
    pub fn new(n_ports: usize) -> Scratch {
        Scratch {
            i_new: vec![0.0; n_ports],
            leaf_scale: vec![1.0; n_ports],
        }
    }
}

/// A lane's share of its coupling group's feeder for one step, decided by
/// the allocate phase between [`propose_lane`] and [`commit_lane`].
/// `factor` scales every staged current (proportional curtailment);
/// `buy_mult` scales the buy price instead (price-feedback). The
/// uncoupled path commits with [`GridBudget::UNCURTAILED`], and the
/// commit guards on `!= 1.0` so that path executes byte-identically to
/// the pre-split step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridBudget {
    pub factor: f32,
    pub buy_mult: f32,
}

impl GridBudget {
    pub const UNCURTAILED: GridBudget = GridBudget { factor: 1.0, buy_mult: 1.0 };
}

/// Output of the propose phase for one lane: the pre-projection excess
/// (carried to commit for the reward's excess penalty) and the grid-side
/// power the staged currents would draw this step (positive = import).
#[derive(Debug, Clone, Copy, Default)]
pub struct Proposal {
    pub excess_kw: f32,
    pub grid_kw: f32,
}

pub fn obs_dim(cfg: &StationConfig) -> usize {
    6 * cfg.n_chargers() + 3 + 4 + 4 + (cfg.grid_coupled as usize)
}

pub fn action_nvec(cfg: &StationConfig) -> Vec<usize> {
    let car_levels = if cfg.v2g { N_LEVELS_V2G } else { N_LEVELS };
    let mut v = vec![car_levels; cfg.n_chargers()];
    v.push(N_LEVELS_BATTERY);
    v
}

fn hour(t: u32) -> usize {
    (t as usize / STEPS_PER_HOUR).min(23)
}

/// Reset one lane: clear cars/currents, draw a fresh start day.
pub fn reset_lane(
    lane: &mut LaneView<'_>,
    rng: &mut CounterRng,
    cfg: &StationConfig,
    tables: &ScenarioTables,
) {
    *lane.t = 0;
    *lane.day = rng.below(tables.n_days as u32);
    lane.present.iter_mut().for_each(|x| *x = false);
    lane.i_drawn.iter_mut().for_each(|x| *x = 0.0);
    *lane.battery_soc = if cfg.battery_capacity_kwh > 0.0 {
        cfg.battery_soc0
    } else {
        0.0 // battery-less station: pin the (unused) SoC lane to empty
    };
    *lane.ep_return = 0.0;
    *lane.ep_profit = 0.0;
}

/// One env step for one lane. `action[p]` is the discrete level per port.
/// Semantically identical to the original per-object `ScalarEnv::step`
/// (same transition order, same RNG draw order): the propose/commit split
/// composes back into the original single-phase step when the budget is
/// [`GridBudget::UNCURTAILED`].
pub fn step_lane(
    lane: &mut LaneView<'_>,
    rng: &mut CounterRng,
    cfg: &StationConfig,
    tree: &StationTree,
    tables: &ScenarioTables,
    action: &[usize],
    scratch: &mut Scratch,
) -> StepInfo {
    let excess = stage_currents(lane, cfg, tree, action, scratch);
    commit_lane(lane, rng, cfg, tree, tables, GridBudget::UNCURTAILED, excess)
}

/// Propose phase (i): map actions to clamped signed currents, project
/// them through the electrical tree, and stage them in `lane.i_drawn`.
/// Mutates ONLY `i_drawn` — no clock, price, SoC, or RNG effects — so a
/// staged lane can wait for the allocate phase. Returns the
/// pre-projection excess (kW) for the reward's excess penalty.
pub fn stage_currents(
    lane: &mut LaneView<'_>,
    cfg: &StationConfig,
    tree: &StationTree,
    action: &[usize],
    scratch: &mut Scratch,
) -> f32 {
    let c = cfg.n_chargers();
    // (i) apply actions: level -> fraction -> clamped signed current.
    // Charge-only stations map levels to [0, 1] of the port maximum; V2G
    // stations use the battery's symmetric ladder ([-1, 1]), with the
    // discharge side limited by the flipped curve and the drain headroom.
    let i_new = &mut scratch.i_new;
    for j in 0..c {
        if !lane.present[j] {
            i_new[j] = 0.0;
            continue;
        }
        let r_ch = charging_curve(lane.soc[j], lane.r_bar[j], lane.tau[j]);
        let head_up = (1.0 - lane.soc[j]) * lane.cap[j] / DT_HOURS;
        let p_kw = if cfg.v2g {
            let half = (N_LEVELS_V2G - 1) as f32 / 2.0;
            let frac = action[j] as f32 / half - 1.0;
            let p_target = frac * tree.p_max[j];
            let r_dis = discharging_curve(lane.soc[j], lane.r_bar[j], lane.tau[j]);
            let head_dn = lane.soc[j] * lane.cap[j] / DT_HOURS;
            p_target.clamp(-r_dis.min(head_dn), r_ch.min(head_up))
        } else {
            let frac = action[j] as f32 / (N_LEVELS - 1) as f32;
            (frac * tree.p_max[j]).min(r_ch).min(head_up).max(0.0)
        };
        i_new[j] = p_kw * 1000.0 / tree.volt[j];
    }
    {
        // battery lane: symmetric ladder.
        let half = (N_LEVELS_BATTERY - 1) as f32 / 2.0;
        let frac = action[c] as f32 / half - 1.0;
        let p_target = frac * tree.p_max[c];
        let r_ch = charging_curve(*lane.battery_soc, cfg.battery_p_max_kw, cfg.battery_tau);
        let r_dis = discharging_curve(*lane.battery_soc, cfg.battery_p_max_kw, cfg.battery_tau);
        let head_up = (1.0 - *lane.battery_soc) * cfg.battery_capacity_kwh / DT_HOURS;
        let head_dn = *lane.battery_soc * cfg.battery_capacity_kwh / DT_HOURS;
        let p_kw = p_target.clamp(-r_dis.min(head_dn), r_ch.min(head_up));
        i_new[c] = p_kw * 1000.0 / tree.volt[c];
    }
    let excess = tree.project_currents_scratch(i_new, &mut scratch.leaf_scale);
    lane.i_drawn.copy_from_slice(i_new);
    excess
}

/// Read-only preview of the grid-side power (kW, positive = import) the
/// staged currents would move this step, mirroring the charge-phase SoC
/// clamps and port efficiencies exactly. Because [`stage_currents`]
/// already clamped every port to its SoC headroom, the committed grid
/// energy under a proportional budget `f` is `f x` this proposal (the
/// clamps are linear through zero and cannot newly bind when currents
/// shrink) — which is what makes proportional curtailment conserve the
/// feeder capacity exactly.
pub fn proposed_grid_kw(lane: &LaneView<'_>, cfg: &StationConfig, tree: &StationTree) -> f32 {
    let c = cfg.n_chargers();
    let mut grid_kwh = 0f32;
    for j in 0..c {
        if !lane.present[j] {
            continue;
        }
        let p_kw = tree.volt[j] * lane.i_drawn[j] / 1000.0;
        let e = (p_kw * DT_HOURS)
            .min((1.0 - lane.soc[j]) * lane.cap[j])
            .max(-lane.soc[j] * lane.cap[j]);
        grid_kwh += if e > 0.0 {
            e / tree.eta_port[j]
        } else {
            e * tree.eta_port[j]
        };
    }
    if cfg.battery_capacity_kwh > 0.0 {
        let p_kw = tree.volt[c] * lane.i_drawn[c] / 1000.0;
        let e = (p_kw * DT_HOURS)
            .min((1.0 - *lane.battery_soc) * cfg.battery_capacity_kwh)
            .max(-*lane.battery_soc * cfg.battery_capacity_kwh);
        grid_kwh += e;
    }
    grid_kwh / DT_HOURS
}

/// Propose phase for one lane: stage currents and report what they would
/// draw from the grid. No clock/price/SoC/RNG effects — the lane sits
/// staged until [`commit_lane`] applies the allocated budget.
pub fn propose_lane(
    lane: &mut LaneView<'_>,
    cfg: &StationConfig,
    tree: &StationTree,
    action: &[usize],
    scratch: &mut Scratch,
) -> Proposal {
    let excess_kw = stage_currents(lane, cfg, tree, action, scratch);
    let grid_kw = proposed_grid_kw(lane, cfg, tree);
    Proposal { excess_kw, grid_kw }
}

/// Commit phase (ii)-(iv) + reward for one lane: apply the allocated
/// budget to the staged currents, then charge, depart, arrive, and score
/// exactly as the single-phase step always did. `excess` is the staged
/// pre-projection excess from [`stage_currents`]/[`propose_lane`].
pub fn commit_lane(
    lane: &mut LaneView<'_>,
    rng: &mut CounterRng,
    cfg: &StationConfig,
    tree: &StationTree,
    tables: &ScenarioTables,
    budget: GridBudget,
    excess: f32,
) -> StepInfo {
    let c = cfg.n_chargers();
    // Prices read at the still pre-increment clock — same values the
    // single-phase step read before phase (i), which never touches t/day.
    let price_idx = *lane.day as usize * 24 + hour(*lane.t);
    let mut price_buy = tables.price_buy[price_idx];
    let price_sell_grid = tables.price_sell_grid[price_idx];
    let moer = tables.moer[price_idx];
    // Budget guards: the uncoupled path commits UNCURTAILED and must not
    // touch a single float (byte-for-byte contract with the pre-split
    // step), so both applications are skipped at exactly 1.0.
    if budget.factor != 1.0 {
        for i in lane.i_drawn.iter_mut() {
            *i *= budget.factor;
        }
    }
    if budget.buy_mult != 1.0 {
        price_buy *= budget.buy_mult;
    }

    // (ii) charge. Car-side discharge is accumulated here, at charge
    // time, so a car that departs later in this same step still incurs
    // the degradation penalty for its final-step discharge (reading
    // `i_drawn` after departures would see zeroed currents).
    let (de_net, grid_cars, car_discharge) = charge_cars(lane, tree, c);
    let e_bat = if cfg.battery_capacity_kwh > 0.0 {
        let p_kw = tree.volt[c] * lane.i_drawn[c] / 1000.0;
        let mut e = p_kw * DT_HOURS;
        e = e
            .min((1.0 - *lane.battery_soc) * cfg.battery_capacity_kwh)
            .max(-*lane.battery_soc * cfg.battery_capacity_kwh);
        *lane.battery_soc = (*lane.battery_soc + e / cfg.battery_capacity_kwh).clamp(0.0, 1.0);
        e
    } else {
        // Battery-less station (capacity 0): no energy flows, and the SoC
        // update is skipped — dividing by capacity would turn it NaN and
        // poison every later observation.
        0.0
    };
    let de_grid_net = grid_cars + e_bat;
    *lane.t += 1;

    // (iii) departures.
    let mut missing = 0f32;
    let mut overtime = 0f32;
    let mut early = 0f32;
    let mut departed = 0f32;
    for j in 0..c {
        if !lane.present[j] {
            continue;
        }
        let leave = if lane.sensitive[j] {
            lane.de_remain[j] <= 1e-6
        } else {
            lane.dt_remain[j] <= 0.0
        };
        if leave {
            if lane.sensitive[j] {
                overtime += (-lane.dt_remain[j]).max(0.0);
                early += lane.dt_remain[j].max(0.0);
            } else {
                missing += lane.de_remain[j].max(0.0);
            }
            departed += 1.0;
            lane.present[j] = false;
            lane.i_drawn[j] = 0.0;
        }
    }

    // (iv) arrivals.
    let lam =
        tables.arrival_rate[hour(*lane.t)] * tables.traffic / STEPS_PER_HOUR as f32;
    let m = rng.poisson(lam) as usize;
    let n_free = lane.present.iter().filter(|&&p| !p).count();
    let n_take = m.min(n_free).min(MAX_ARRIVALS);
    let rejected = (m - n_take) as f32;
    let mut taken = 0usize;
    for slot in 0..c {
        if taken == n_take {
            break;
        }
        if lane.present[slot] {
            continue;
        }
        let car = sample_car(rng, tree, tables, slot);
        lane.present[slot] = true;
        lane.soc[slot] = car.soc;
        lane.de_remain[slot] = car.de_remain;
        lane.dt_remain[slot] = car.dt_remain;
        lane.cap[slot] = car.cap;
        lane.r_bar[slot] = car.r_bar;
        lane.tau[slot] = car.tau;
        lane.sensitive[slot] = car.charge_sensitive;
        taken += 1;
    }
    let arrived = n_take as f32;

    // Reward (Eq. 2-3).
    let grid_price = if de_grid_net > 0.0 { price_buy } else { price_sell_grid };
    let profit = tables.p_sell * de_net - grid_price * de_grid_net - FIXED_COST_PER_STEP;
    let pens = [
        excess,
        missing,
        overtime - tables.beta * early,
        moer * de_grid_net,
        rejected,
        (-e_bat).max(0.0) + car_discharge,
        (de_net - 0.0).abs(), // grid-demand signal ~0 unless configured
    ];
    let mut reward = profit;
    for (a, c_) in tables.alpha.iter().zip(&pens) {
        reward -= a * c_;
    }

    *lane.ep_return += reward;
    *lane.ep_profit += profit;
    let done = *lane.t as usize >= STEPS_PER_EPISODE;
    let info = StepInfo {
        reward,
        profit,
        energy_to_cars_kwh: de_net,
        energy_grid_net_kwh: de_grid_net,
        excess_kw: excess,
        missing_kwh: missing,
        overtime_steps: overtime,
        rejected,
        departed,
        arrived,
        done,
    };
    if done {
        reset_lane(lane, rng, cfg, tables);
    }
    info
}

/// Transition loop (ii): apply each present car's allocated current for
/// one step. Returns `(net energy into cars kWh, grid-side car energy
/// kWh, car-side discharge kWh)`. Discharge (negative current, V2G-style)
/// is accounted here — before departures clear lanes — so cars leaving
/// this step still incur the degradation penalty for their final
/// discharge.
fn charge_cars(lane: &mut LaneView<'_>, tree: &StationTree, c: usize) -> (f32, f32, f32) {
    let mut de_net = 0f32;
    let mut grid_cars = 0f32;
    let mut car_discharge = 0f32;
    for j in 0..c {
        if !lane.present[j] {
            continue;
        }
        let p_kw = tree.volt[j] * lane.i_drawn[j] / 1000.0;
        let mut e = p_kw * DT_HOURS;
        e = e
            .min((1.0 - lane.soc[j]) * lane.cap[j])
            .max(-lane.soc[j] * lane.cap[j]);
        if e < 0.0 {
            // Degradation counts the SoC-clamped energy actually delivered
            // (same basis as the battery-side `(-e_bat).max(0)` term).
            car_discharge += -e;
        }
        lane.soc[j] = (lane.soc[j] + e / lane.cap[j].max(1e-9)).clamp(0.0, 1.0);
        lane.de_remain[j] -= e;
        lane.dt_remain[j] -= 1.0;
        de_net += e;
        grid_cars += if e > 0.0 {
            e / tree.eta_port[j]
        } else {
            e * tree.eta_port[j]
        };
    }
    (de_net, grid_cars, car_discharge)
}

/// Draw a car for `slot` (paper A.1 arrival model). Consumes exactly one
/// categorical, one normal, one kumaraswamy, and one uniform draw.
pub fn sample_car(
    rng: &mut CounterRng,
    tree: &StationTree,
    tables: &ScenarioTables,
    slot: usize,
) -> Car {
    let up = &tables.user_profile;
    let (stay_mean_h, stay_std_h) = (up[0], up[1]);
    let (soc0_a, soc0_b, target_soc, p_time) = (up[2], up[3], up[4], up[5]);
    let model = rng.categorical(&tables.car_weights);
    let row = &tables.car_table[model * 4..model * 4 + 4];
    let (cap, ac_kw, dc_kw, tau) = (row[0], row[1], row[2], row[3]);
    let stay_h = stay_mean_h + stay_std_h * rng.normal();
    let stay_steps = (stay_h / DT_HOURS).round().max(1.0);
    let soc0 = rng.kumaraswamy(soc0_a, soc0_b).clamp(0.02, 0.98);
    let de = (target_soc - soc0).max(0.0) * cap;
    let charge_sensitive = rng.f32() < 1.0 - p_time;
    let car_rate = if tree.is_dc[slot] { dc_kw } else { ac_kw };
    Car {
        soc: soc0,
        de_remain: de,
        dt_remain: stay_steps,
        cap,
        r_bar: car_rate.min(tree.p_max[slot]),
        tau,
        charge_sensitive,
    }
}

/// Observation for one lane, mirroring env.py::observe (same layout &
/// normalizers). `out` has length [`obs_dim`]. `headroom` is the lane's
/// coupling group's normalized feeder headroom after the last allocate
/// (1.0 before any step, and always 1.0 for uncoupled stations, whose
/// observation simply has no such column).
pub fn observe_lane(
    lane: &LaneRef<'_>,
    cfg: &StationConfig,
    tree: &StationTree,
    tables: &ScenarioTables,
    headroom: f32,
    out: &mut [f32],
) {
    let c = cfg.n_chargers();
    debug_assert_eq!(out.len(), obs_dim(cfg));
    let h = hour(lane.t);
    for j in 0..c {
        let occ = lane.present[j] as i32 as f32;
        let (soc, de, dtr, rhat) = if lane.present[j] {
            (
                lane.soc[j],
                lane.de_remain[j],
                lane.dt_remain[j],
                charging_curve(lane.soc[j], lane.r_bar[j], lane.tau[j]),
            )
        } else {
            (0.0, 0.0, 0.0, 0.0)
        };
        out[j] = occ;
        out[c + j] = soc;
        out[2 * c + j] = de / 100.0;
        out[3 * c + j] = dtr / STEPS_PER_EPISODE as f32;
        out[4 * c + j] = rhat / tree.p_max[j];
        out[5 * c + j] = lane.i_drawn[j] / tree.i_max[j];
    }
    let b = 6 * c;
    out[b] = lane.battery_soc;
    // battery normalizers are guarded: a battery-less station has
    // i_max = p_max = 0 at the battery port and must observe 0, not 0/0.
    out[b + 1] = lane.i_drawn[c] / tree.i_max[c].max(1e-9);
    out[b + 2] = charging_curve(lane.battery_soc, cfg.battery_p_max_kw, cfg.battery_tau)
        / tree.p_max[c].max(1e-9);
    let phase = 2.0 * std::f32::consts::PI * lane.t as f32 / STEPS_PER_EPISODE as f32;
    out[b + 3] = phase.sin();
    out[b + 4] = phase.cos();
    out[b + 5] = ((lane.day % 7) < 5) as i32 as f32;
    out[b + 6] = lane.day as f32 / tables.n_days as f32;
    let idx = lane.day as usize * 24 + h;
    out[b + 7] = tables.price_buy[idx];
    // Next-hour price: the last hour of the day wraps to hour 0 of the
    // next day (mod the table length) — clamping to hour 23 would show the
    // current price as "next" for the whole final hour.
    let idx_next = if h == 23 {
        ((lane.day as usize + 1) % tables.n_days) * 24
    } else {
        idx + 1
    };
    out[b + 8] = tables.price_buy[idx_next];
    out[b + 9] = tables.price_sell_grid[idx];
    out[b + 10] = tables.moer[idx];
    if cfg.grid_coupled {
        out[b + 11] = headroom;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::tree::StationConfig;

    /// Flat per-lane state backing a hand-built [`LaneView`].
    struct LaneState {
        t: u32,
        day: u32,
        battery_soc: f32,
        ep_return: f32,
        ep_profit: f32,
        present: Vec<bool>,
        soc: Vec<f32>,
        de_remain: Vec<f32>,
        dt_remain: Vec<f32>,
        cap: Vec<f32>,
        r_bar: Vec<f32>,
        tau: Vec<f32>,
        sensitive: Vec<bool>,
        i_drawn: Vec<f32>,
    }

    impl LaneState {
        fn empty(cfg: &StationConfig) -> LaneState {
            let (c, p) = (cfg.n_chargers(), cfg.n_ports());
            LaneState {
                t: 0,
                day: 0,
                battery_soc: cfg.battery_soc0,
                ep_return: 0.0,
                ep_profit: 0.0,
                present: vec![false; c],
                soc: vec![0.0; c],
                de_remain: vec![0.0; c],
                dt_remain: vec![0.0; c],
                cap: vec![60.0; c],
                r_bar: vec![50.0; c],
                tau: vec![0.8; c],
                sensitive: vec![false; c],
                i_drawn: vec![0.0; p],
            }
        }

        fn view(&mut self) -> LaneView<'_> {
            LaneView {
                t: &mut self.t,
                day: &mut self.day,
                battery_soc: &mut self.battery_soc,
                ep_return: &mut self.ep_return,
                ep_profit: &mut self.ep_profit,
                present: &mut self.present,
                soc: &mut self.soc,
                de_remain: &mut self.de_remain,
                dt_remain: &mut self.dt_remain,
                cap: &mut self.cap,
                r_bar: &mut self.r_bar,
                tau: &mut self.tau,
                sensitive: &mut self.sensitive,
                i_drawn: &mut self.i_drawn,
            }
        }
    }

    /// Regression for the degradation-accounting bug: discharge must be
    /// accumulated at charge time (loop ii), so a car that departs in the
    /// same step — its `i_drawn` zeroed by the departure pass — is still
    /// penalized for its final-step discharge.
    #[test]
    fn departing_car_final_step_discharge_is_counted() {
        let cfg = StationConfig::default();
        let tree = StationTree::standard(&cfg);
        let c = cfg.n_chargers();
        let mut st = LaneState::empty(&cfg);
        st.present[0] = true;
        st.soc[0] = 0.5;
        st.dt_remain[0] = 1.0; // departs after this step (time-sensitive)
        st.i_drawn[0] = -25.0; // V2G-style discharge: -10 kW at 400 V
        let (de_net, grid_cars, car_discharge) = charge_cars(&mut st.view(), &tree, c);
        let expect_kwh = 400.0 * 25.0 / 1000.0 * DT_HOURS;
        assert!(
            (car_discharge - expect_kwh).abs() < 1e-6,
            "discharge {car_discharge} != {expect_kwh}"
        );
        assert!(de_net < 0.0);
        assert!(grid_cars < 0.0, "discharged energy flows back to the grid");
        assert!(st.soc[0] < 0.5);
        // ...and the charge loop already decremented the stay clock, so
        // the departure pass will clear this lane right after.
        assert!(st.dt_remain[0] <= 0.0);
    }

    #[test]
    fn charging_cars_incur_no_discharge_penalty() {
        let cfg = StationConfig::default();
        let tree = StationTree::standard(&cfg);
        let c = cfg.n_chargers();
        let mut st = LaneState::empty(&cfg);
        st.present[0] = true;
        st.soc[0] = 0.3;
        st.dt_remain[0] = 10.0;
        st.i_drawn[0] = 100.0; // charging
        let (de_net, _grid, car_discharge) = charge_cars(&mut st.view(), &tree, c);
        assert_eq!(car_discharge, 0.0);
        assert!(de_net > 0.0);
    }

    /// V2G action mapping: the mid level idles, level 0 discharges, the
    /// top level charges — and a charge-only config ignores the flag's
    /// ladder entirely (level 0 = idle).
    #[test]
    fn v2g_ladder_is_signed_and_symmetric() {
        let cfg = StationConfig { v2g: true, ..StationConfig::default() };
        let tree = StationTree::standard(&cfg);
        let tables = ScenarioTables::synthetic(0.0); // no arrivals
        let mut rng = crate::util::rng::CounterRng::new(1);
        let mut scratch = Scratch::new(cfg.n_ports());
        let c = cfg.n_chargers();
        let idle_bat = (N_LEVELS_BATTERY - 1) / 2;
        let park = |st: &mut LaneState| {
            st.present[0] = true;
            st.soc[0] = 0.5;
            st.de_remain[0] = 30.0;
            st.dt_remain[0] = 1000.0;
        };
        for (level, sign) in [
            ((N_LEVELS_V2G - 1) / 2, 0.0f32),
            (0, -1.0),
            (N_LEVELS_V2G - 1, 1.0),
        ] {
            let mut st = LaneState::empty(&cfg);
            park(&mut st);
            let mut action = vec![0usize; cfg.n_ports()];
            action[0] = level;
            action[c] = idle_bat;
            let info = step_lane(
                &mut st.view(),
                &mut rng,
                &cfg,
                &tree,
                &tables,
                &action,
                &mut scratch,
            );
            if sign == 0.0 {
                assert_eq!(info.energy_to_cars_kwh, 0.0, "mid level must idle");
            } else {
                assert!(
                    info.energy_to_cars_kwh * sign > 0.0,
                    "level {level}: energy {} has wrong sign",
                    info.energy_to_cars_kwh
                );
            }
        }
        assert_eq!(action_nvec(&cfg), vec![N_LEVELS_V2G; c + 1]);
        let plain = StationConfig::default();
        assert_eq!(action_nvec(&plain)[0], N_LEVELS);
        assert_eq!(*action_nvec(&plain).last().unwrap(), N_LEVELS_BATTERY);
    }

    /// The tentpole's composition contract: propose + commit(UNCURTAILED)
    /// must BE the single-phase step, bit for bit, through full episodes
    /// with arrivals, departures, V2G discharge, and episode resets — the
    /// pre-refactor oracle for every uncoupled trajectory in the repo.
    #[test]
    fn propose_commit_uncurtailed_matches_step_lane_bitwise() {
        let cfg = StationConfig { v2g: true, ..StationConfig::default() };
        let tree = StationTree::standard(&cfg);
        let tables = ScenarioTables::synthetic(1.5);
        let mut rng_a = crate::util::rng::CounterRng::new(7);
        let mut rng_b = crate::util::rng::CounterRng::new(7);
        let mut a = LaneState::empty(&cfg);
        let mut b = LaneState::empty(&cfg);
        reset_lane(&mut a.view(), &mut rng_a, &cfg, &tables);
        reset_lane(&mut b.view(), &mut rng_b, &cfg, &tables);
        let mut scratch = Scratch::new(cfg.n_ports());
        let nvec = action_nvec(&cfg);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for step in 0..2 * STEPS_PER_EPISODE {
            let action: Vec<usize> = nvec
                .iter()
                .enumerate()
                .map(|(p, &n)| (step * 31 + p * 17) % n)
                .collect();
            let ia = step_lane(
                &mut a.view(),
                &mut rng_a,
                &cfg,
                &tree,
                &tables,
                &action,
                &mut scratch,
            );
            let prop = propose_lane(&mut b.view(), &cfg, &tree, &action, &mut scratch);
            let ib = commit_lane(
                &mut b.view(),
                &mut rng_b,
                &cfg,
                &tree,
                &tables,
                GridBudget::UNCURTAILED,
                prop.excess_kw,
            );
            assert_eq!(ia.reward.to_bits(), ib.reward.to_bits(), "reward, step {step}");
            assert_eq!(
                ia.energy_grid_net_kwh.to_bits(),
                ib.energy_grid_net_kwh.to_bits(),
                "grid energy, step {step}"
            );
            assert_eq!(ia.done, ib.done, "done, step {step}");
            assert_eq!(a.t, b.t, "clock, step {step}");
            assert_eq!(a.day, b.day, "day, step {step}");
            assert_eq!(a.battery_soc.to_bits(), b.battery_soc.to_bits(), "bsoc, step {step}");
            assert_eq!(a.present, b.present, "presence, step {step}");
            assert_eq!(bits(&a.soc), bits(&b.soc), "soc, step {step}");
            assert_eq!(bits(&a.de_remain), bits(&b.de_remain), "de, step {step}");
            assert_eq!(bits(&a.i_drawn), bits(&b.i_drawn), "currents, step {step}");
            assert_eq!(a.ep_return.to_bits(), b.ep_return.to_bits(), "return, step {step}");
        }
    }

    /// Proportional curtailment is exact: because stage_currents already
    /// clamped every port to its SoC headroom, committing with factor f
    /// moves exactly f x the proposed grid energy; price-feedback commits
    /// full energy and only reprices the import.
    #[test]
    fn grid_budget_scales_energy_or_reprices_import() {
        let cfg = StationConfig::default();
        let tree = StationTree::standard(&cfg);
        let tables = ScenarioTables::synthetic(0.0); // no arrivals
        let mut scratch = Scratch::new(cfg.n_ports());
        let nvec = action_nvec(&cfg);
        let full: Vec<usize> = nvec.iter().map(|&n| n - 1).collect(); // max charge
        let park = |st: &mut LaneState| {
            for j in 0..cfg.n_chargers() {
                st.present[j] = true;
                st.soc[j] = 0.3;
                st.de_remain[j] = 40.0;
                st.dt_remain[j] = 100.0;
            }
        };
        let run = |budget: GridBudget| {
            let mut st = LaneState::empty(&cfg);
            park(&mut st);
            let mut rng = crate::util::rng::CounterRng::new(3);
            let prop = propose_lane(&mut st.view(), &cfg, &tree, &full, &mut scratch);
            assert!(prop.grid_kw > 0.0, "a full-charge action must propose import");
            let info = commit_lane(
                &mut st.view(),
                &mut rng,
                &cfg,
                &tree,
                &tables,
                budget,
                prop.excess_kw,
            );
            (prop, info)
        };
        let (prop, base) = run(GridBudget::UNCURTAILED);
        assert!(
            (prop.grid_kw * DT_HOURS - base.energy_grid_net_kwh).abs()
                <= 1e-4 * base.energy_grid_net_kwh.abs(),
            "proposal {} kW must preview the uncurtailed commit {} kWh",
            prop.grid_kw,
            base.energy_grid_net_kwh
        );
        let f = 0.4f32;
        let (_, cut) = run(GridBudget { factor: f, buy_mult: 1.0 });
        assert!(
            (cut.energy_grid_net_kwh - f * base.energy_grid_net_kwh).abs()
                <= 1e-4 * base.energy_grid_net_kwh.abs(),
            "factor {f} committed {} kWh, expected {}",
            cut.energy_grid_net_kwh,
            f * base.energy_grid_net_kwh
        );
        let (_, priced) = run(GridBudget { factor: 1.0, buy_mult: 2.0 });
        assert_eq!(
            priced.energy_grid_net_kwh.to_bits(),
            base.energy_grid_net_kwh.to_bits(),
            "price feedback must not curtail energy"
        );
        assert!(priced.profit < base.profit, "doubled buy price must cost profit");
    }

    /// Regression for the next-hour price clamp: at hour 23 the "next
    /// price" must be hour 0 of the next day (mod n_days), not hour 23
    /// again.
    #[test]
    fn next_hour_price_wraps_at_day_boundary() {
        let cfg = StationConfig::default();
        let tree = StationTree::standard(&cfg);
        let mut tables = ScenarioTables::synthetic(1.0);
        tables.n_days = 2;
        tables.price_buy = (0..48).map(|k| 0.01 * k as f32).collect();
        let mut st = LaneState::empty(&cfg);
        st.day = 1; // last day: next day wraps to day 0
        st.t = (23 * STEPS_PER_HOUR) as u32; // hour 23
        let mut out = vec![0f32; obs_dim(&cfg)];
        observe_lane(
            &LaneRef {
                t: st.t,
                day: st.day,
                battery_soc: st.battery_soc,
                present: &st.present,
                soc: &st.soc,
                de_remain: &st.de_remain,
                dt_remain: &st.dt_remain,
                r_bar: &st.r_bar,
                tau: &st.tau,
                i_drawn: &st.i_drawn,
            },
            &cfg,
            &tree,
            &tables,
            1.0,
            &mut out,
        );
        let b = 6 * cfg.n_chargers();
        assert_eq!(out[b + 7], tables.price_buy[47], "current price: day 1 hour 23");
        assert_eq!(out[b + 8], tables.price_buy[0], "next price: day 0 hour 0");
    }
}
