//! Station architecture for the scalar simulator — mirrors
//! python/compile/env/tree.py (standard Fig. 3b layout: root -> per-type
//! splitters, battery under the root).

/// One charger type's electrical limits.
#[derive(Debug, Clone, Copy)]
pub struct ChargerSpec {
    pub voltage: f32,
    pub i_max: f32,
}

impl ChargerSpec {
    pub fn p_max_kw(&self) -> f32 {
        self.voltage * self.i_max / 1000.0
    }
}

pub const DC_CHARGER: ChargerSpec = ChargerSpec { voltage: 400.0, i_max: 375.0 }; // 150 kW
pub const AC_CHARGER: ChargerSpec = ChargerSpec { voltage: 230.0, i_max: 50.0 }; // 11.5 kW

/// Static station config (paper Table 3 defaults; matches python config.py).
///
/// `PartialEq` lets the fleet catalog group lanes into station families:
/// every config field changes either the electrical tree or the action
/// semantics, so "equal config" is exactly "same obs/action space".
#[derive(Debug, Clone, PartialEq)]
pub struct StationConfig {
    pub n_dc: usize,
    pub n_ac: usize,
    pub root_p_kw: f32,
    pub dc_split_p_kw: f32,
    pub ac_split_p_kw: f32,
    pub node_eta: f32,
    pub evse_eta: f32,
    pub battery_capacity_kwh: f32,
    pub battery_p_max_kw: f32,
    pub battery_voltage: f32,
    pub battery_tau: f32,
    pub battery_soc0: f32,
    /// V2G: car ports use the battery's symmetric signed ladder
    /// ([`super::core::N_LEVELS_V2G`] levels spanning -100%..+100% of the
    /// port maximum) instead of the unipolar charge-only ladder, so the
    /// policy can discharge parked cars into the station/grid. The
    /// transition core (`charge_cars`) and the reward path already account
    /// car-side discharge; this flag only changes the action mapping.
    pub v2g: bool,
    /// Grid coupling: the station belongs to a feeder coupling group
    /// (fleet `grid` key with a concrete `capacity_kw`), so its
    /// observation grows one trailing column — the group's normalized
    /// feeder headroom after the last allocate. Like every other field,
    /// this changes the obs space, so coupled and uncoupled stations can
    /// never merge into one family.
    pub grid_coupled: bool,
}

impl Default for StationConfig {
    fn default() -> Self {
        StationConfig {
            n_dc: 10,
            n_ac: 6,
            root_p_kw: 600.0,
            dc_split_p_kw: 450.0,
            ac_split_p_kw: 60.0,
            node_eta: 0.98,
            evse_eta: 0.95,
            battery_capacity_kwh: 200.0,
            battery_p_max_kw: 100.0,
            battery_voltage: 400.0,
            battery_tau: 0.8,
            battery_soc0: 0.5,
            v2g: false,
            grid_coupled: false,
        }
    }
}

impl StationConfig {
    pub fn n_chargers(&self) -> usize {
        self.n_dc + self.n_ac
    }

    pub fn n_ports(&self) -> usize {
        self.n_chargers() + 1
    }

    /// Physical-consistency checks, run by every env constructor. A
    /// battery-less station is expressed as `battery_capacity_kwh == 0`
    /// **and** `battery_p_max_kw == 0`; a real battery port (positive
    /// power rating) must have positive capacity — the SoC update divides
    /// by it, and capacity 0 would turn `battery_soc` into NaN and poison
    /// every later observation.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.n_chargers() == 0 {
            bail!("station must have at least one charger (n_dc + n_ac == 0)");
        }
        if !self.battery_capacity_kwh.is_finite()
            || !self.battery_p_max_kw.is_finite()
            || self.battery_capacity_kwh < 0.0
            || self.battery_p_max_kw < 0.0
        {
            bail!(
                "battery_capacity_kwh ({}) and battery_p_max_kw ({}) must be finite and >= 0",
                self.battery_capacity_kwh,
                self.battery_p_max_kw
            );
        }
        if self.battery_p_max_kw > 0.0 && self.battery_capacity_kwh <= 0.0 {
            bail!(
                "battery_capacity_kwh must be > 0 for a real battery port \
                 (battery_p_max_kw = {} kW); set battery_p_max_kw = 0 for a \
                 battery-less station",
                self.battery_p_max_kw
            );
        }
        if self.battery_voltage <= 0.0 {
            bail!("battery_voltage must be > 0 (got {})", self.battery_voltage);
        }
        Ok(())
    }
}

/// Flattened tree (membership matrix + per-port electrical data).
#[derive(Debug, Clone)]
pub struct StationTree {
    pub volt: Vec<f32>,
    pub i_max: Vec<f32>,
    pub p_max: Vec<f32>,
    pub eta_port: Vec<f32>,
    pub is_dc: Vec<bool>,
    /// membership[n][p]: node n is an ancestor of port p.
    pub membership: Vec<Vec<bool>>,
    pub node_limit: Vec<f32>,
    pub node_eta: Vec<f32>,
}

impl StationTree {
    pub fn standard(cfg: &StationConfig) -> StationTree {
        let c = cfg.n_chargers();
        let p = cfg.n_ports();
        let mut volt = vec![0f32; p];
        let mut i_max = vec![0f32; p];
        let mut is_dc = vec![false; c];
        for i in 0..c {
            let spec = if i < cfg.n_dc { DC_CHARGER } else { AC_CHARGER };
            volt[i] = spec.voltage;
            i_max[i] = spec.i_max;
            is_dc[i] = i < cfg.n_dc;
        }
        volt[c] = cfg.battery_voltage;
        i_max[c] = cfg.battery_p_max_kw * 1000.0 / cfg.battery_voltage;
        let p_max: Vec<f32> = volt.iter().zip(&i_max).map(|(v, i)| v * i / 1000.0).collect();

        let mut membership = vec![vec![true; p]];
        let mut node_limit = vec![cfg.root_p_kw];
        if cfg.n_dc > 0 {
            let mut row = vec![false; p];
            row[..cfg.n_dc].fill(true);
            membership.push(row);
            node_limit.push(cfg.dc_split_p_kw);
        }
        if cfg.n_ac > 0 {
            let mut row = vec![false; p];
            row[cfg.n_dc..c].fill(true);
            membership.push(row);
            node_limit.push(cfg.ac_split_p_kw);
        }
        let node_eta = vec![cfg.node_eta; node_limit.len()];
        StationTree {
            volt,
            i_max,
            p_max,
            eta_port: vec![cfg.evse_eta; p],
            is_dc,
            membership,
            node_limit,
            node_eta,
        }
    }

    pub fn n_ports(&self) -> usize {
        self.volt.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.node_limit.len()
    }

    /// Eq. 5 projection — the scalar mirror of the Pallas
    /// constraint_projection kernel (two fixed-point passes, exact for the
    /// paper's depth-2 trees even with mixed-sign V2G flows). Returns the
    /// pre-projection excess (kW).
    pub fn project_currents(&self, i_drawn: &mut [f32]) -> f32 {
        let mut leaf_scale = vec![1f32; self.n_ports()];
        self.project_currents_scratch(i_drawn, &mut leaf_scale)
    }

    /// Allocation-free variant for the vectorized hot path: `leaf_scale`
    /// is caller-provided scratch of length `n_ports()`.
    pub fn project_currents_scratch(&self, i_drawn: &mut [f32], leaf_scale: &mut [f32]) -> f32 {
        const EPS: f32 = 1e-9;
        let p = self.n_ports();
        let mut excess = 0f32;
        for pass in 0..2 {
            leaf_scale.iter_mut().for_each(|x| *x = 1.0);
            for n in 0..self.n_nodes() {
                let mut flow = 0f32;
                for j in 0..p {
                    if self.membership[n][j] {
                        flow += self.volt[j] * i_drawn[j] / 1000.0;
                    }
                }
                let absf = flow.abs();
                let load = absf / self.node_eta[n].max(EPS);
                if pass == 0 {
                    excess = excess.max((load - self.node_limit[n]).max(0.0));
                }
                let scale = (self.node_limit[n] * self.node_eta[n] / absf.max(EPS)).min(1.0);
                for j in 0..p {
                    if self.membership[n][j] {
                        leaf_scale[j] = leaf_scale[j].min(scale);
                    }
                }
            }
            for j in 0..p {
                i_drawn[j] *= leaf_scale[j];
            }
        }
        excess
    }
}

/// Paper A.1 piecewise-linear charging curve (kW), identical to
/// kernels/ref.py::charging_curve.
pub fn charging_curve(soc: f32, r_bar: f32, tau: f32) -> f32 {
    const EPS: f32 = 1e-9;
    if soc <= tau {
        r_bar
    } else {
        ((1.0 - soc) * r_bar / (1.0 - tau).max(EPS)).max(0.0)
    }
}

/// Discharge limit: the charging curve flipped at SoC = 0.5.
pub fn discharging_curve(soc: f32, r_bar: f32, tau: f32) -> f32 {
    charging_curve(1.0 - soc, r_bar, tau)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_tree_shapes() {
        let cfg = StationConfig::default();
        let t = StationTree::standard(&cfg);
        assert_eq!(t.n_ports(), 17);
        assert_eq!(t.n_nodes(), 3);
        assert!((t.p_max[0] - 150.0).abs() < 1e-3);
        assert!((t.p_max[10] - 11.5).abs() < 1e-3);
        assert!((t.p_max[16] - 100.0).abs() < 1e-3);
        assert!(t.membership[0].iter().all(|&x| x));
    }

    #[test]
    fn projection_enforces_limits() {
        let t = StationTree::standard(&StationConfig::default());
        // All DC chargers at max: 10 * 150 kW >> dc_split 450 kW.
        let mut i = vec![0f32; 17];
        for j in 0..10 {
            i[j] = 375.0;
        }
        let excess = t.project_currents(&mut i);
        assert!(excess > 0.0);
        let flow: f32 = (0..10).map(|j| 400.0 * i[j] / 1000.0).sum();
        assert!(flow / 0.98 <= 450.0 + 1e-3, "flow {flow}");
    }

    #[test]
    fn projection_noop_within_limits() {
        let t = StationTree::standard(&StationConfig::default());
        let mut i = vec![0f32; 17];
        i[0] = 100.0;
        i[12] = 20.0;
        let before = i.clone();
        let excess = t.project_currents(&mut i);
        assert_eq!(excess, 0.0);
        assert_eq!(i, before);
    }

    #[test]
    fn validate_rejects_powered_battery_without_capacity() {
        let ok = StationConfig::default();
        assert!(ok.validate().is_ok());
        // battery-less variant: both zero is legal.
        let batteryless = StationConfig {
            battery_capacity_kwh: 0.0,
            battery_p_max_kw: 0.0,
            ..StationConfig::default()
        };
        assert!(batteryless.validate().is_ok());
        // a real battery port with zero capacity is a config error.
        let bad = StationConfig {
            battery_capacity_kwh: 0.0,
            ..StationConfig::default()
        };
        assert!(bad.validate().is_err());
        let negative = StationConfig {
            battery_capacity_kwh: -5.0,
            ..StationConfig::default()
        };
        assert!(negative.validate().is_err());
        let no_chargers = StationConfig { n_dc: 0, n_ac: 0, ..StationConfig::default() };
        assert!(no_chargers.validate().is_err());
    }

    #[test]
    fn curve_shape() {
        assert_eq!(charging_curve(0.2, 100.0, 0.6), 100.0);
        assert!((charging_curve(0.8, 100.0, 0.6) - 50.0).abs() < 1e-4);
        assert_eq!(charging_curve(1.0, 100.0, 0.6), 0.0);
        // discharge curve mirrors
        assert_eq!(discharging_curve(0.8, 100.0, 0.6), 100.0);
        assert!((discharging_curve(0.2, 100.0, 0.6) - 50.0).abs() < 1e-4);
    }
}
