//! `chargax` CLI — leader entrypoint for the L3 coordinator.
//!
//! Subcommands:
//!   train          train a PPO agent (AOT fast path) and report metrics
//!   eval           evaluate a policy (net after training, or max/random)
//!   bench <id>     regenerate a paper table/figure (table2, fig4a, fig4bc,
//!                  fig5, fig6to8, fig9to11)
//!   list-profiles  show the bundled data stack (paper Table 1)
//!   list-artifacts show AOT variants + programs from the manifest
//!   cross-check    scalar-vs-JAX transition equivalence report
//!
//! Options are `--key value` pairs (see config::RunConfig::set) plus
//! `--config file.json`. clap is unavailable offline; parsing is manual.
//!
//! Output contract (README §Telemetry & profiling): results go to stdout,
//! diagnostics go to stderr, always — routed through one
//! [`RunLog`] so `--quiet` and `--log_format json` apply everywhere.
//! `--telemetry true` drains the span recorder per iteration into
//! structured reports (JSONL sink at runs/telemetry.jsonl); `--trace_out
//! <file>` additionally exports every recorded span as a Chrome trace.

use anyhow::{anyhow, bail, Result};
use std::path::Path;

use chargax::config::RunConfig;
use chargax::coordinator::{metrics, trainer};
use chargax::data::DataStore;
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::telemetry::{self, IterationReport, LogFormat, RunLog};

mod experiments;

/// Default JSONL sink for `--telemetry` runs.
const TELEMETRY_JSONL: &str = "runs/telemetry.jsonl";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// CLI-side telemetry state: the run logger plus the span accumulator
/// feeding `--trace_out`. One per process, threaded through the commands.
struct Telem {
    log: RunLog,
    /// Emit per-iteration reports (`--telemetry true`).
    report: bool,
    /// Chrome trace destination (`--trace_out <file>`), spans accumulated
    /// across every per-iteration drain.
    trace_out: Option<String>,
    trace: Vec<telemetry::SpanRec>,
}

impl Telem {
    fn new(cfg: &RunConfig) -> Result<Telem> {
        let format = LogFormat::parse(&cfg.log_format).map_err(|e| anyhow!(e))?;
        let mut log = RunLog::new(cfg.quiet, format);
        if cfg.telemetry {
            log = log.with_jsonl(Path::new(TELEMETRY_JSONL))?;
        }
        Ok(Telem {
            log,
            report: cfg.telemetry,
            trace_out: cfg.trace_out.clone(),
            trace: Vec::new(),
        })
    }

    /// Drain the recorder at an iteration boundary: append one structured
    /// record (and a text summary in text format), bank spans for the
    /// trace. No-op when telemetry is disabled.
    fn iteration(&mut self, iter: usize, wall_ms: f64) {
        if !telemetry::enabled() {
            return;
        }
        let d = telemetry::drain();
        if self.report {
            let rep = IterationReport::from_drained(iter, wall_ms, &d);
            self.log.record(&rep.to_json());
            if self.log.format() == LogFormat::Text {
                self.log.info(&rep.text_summary());
            }
        }
        if self.trace_out.is_some() {
            self.trace.extend(d.spans);
        }
    }

    /// Write the Chrome trace (if requested) from every span drained so
    /// far plus whatever is still in the recorder.
    fn finish(&mut self) -> Result<()> {
        let Some(path) = self.trace_out.clone() else {
            return Ok(());
        };
        self.trace.extend(telemetry::drain().spans);
        telemetry::write_chrome_trace(Path::new(&path), &self.trace)?;
        self.log
            .info(&format!("wrote chrome trace ({} spans) to {path}", self.trace.len()));
        Ok(())
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (config_path, overrides) = parse_flags(&args[1..])?;
    // Command-local flags that RunConfig doesn't own.
    let cfg_overrides: Vec<(String, String)> = overrides
        .iter()
        .filter(|(k, _)| k != "policy")
        .cloned()
        .collect();
    let cfg = RunConfig::load(config_path.as_deref(), &cfg_overrides)?;
    // Before any pool spawns: recording state is read at scope entry, and
    // the trace origin is pinned at first enable.
    telemetry::set_enabled(cfg.telemetry || cfg.trace_out.is_some());
    let mut tele = Telem::new(&cfg)?;

    let out = match cmd.as_str() {
        "train" => cmd_train(&cfg, &overrides, &mut tele),
        "eval" => cmd_eval(&cfg, &overrides, &mut tele),
        "bench" => {
            let id = args
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .ok_or_else(|| anyhow!("bench needs an experiment id"))?;
            experiments::run(id, &cfg)
        }
        "list-profiles" => cmd_list_profiles(&mut tele),
        "list-artifacts" => cmd_list_artifacts(&mut tele),
        "cross-check" => cmd_cross_check(&cfg, &mut tele),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `chargax help`)"),
    };
    tele.finish()?;
    out
}

/// Boolean config keys that may be passed bare (`--telemetry` ==
/// `--telemetry true`) so the ISSUE-facing flags read naturally.
const BARE_BOOL_FLAGS: [&str; 5] = ["telemetry", "quiet", "pin_cores", "pin-cores", "paper_scale"];

fn parse_flags(args: &[String]) -> Result<(Option<String>, Vec<(String, String)>)> {
    let mut config = None;
    let mut overrides = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let next = args.get(i + 1);
            let has_val = next.is_some_and(|v| !v.starts_with("--"));
            let bare = BARE_BOOL_FLAGS.contains(&key) && !has_val;
            let val = if bare {
                "true".to_string()
            } else {
                next.ok_or_else(|| anyhow!("flag --{key} needs a value"))?.clone()
            };
            if key == "config" {
                config = Some(val);
            } else {
                overrides.push((key.to_string(), val));
            }
            i += if bare { 1 } else { 2 };
        } else {
            i += 1; // positional (subcommand argument), handled by caller
        }
    }
    Ok((config, overrides))
}

fn cmd_train(cfg: &RunConfig, overrides: &[(String, String)], tele: &mut Telem) -> Result<()> {
    // Train-side `--policy` picks the fleet's policy architecture
    // (per-family oracle vs shared-trunk generalist); it is meaningless
    // outside `--fleet`, so reject it there instead of ignoring it.
    let policy = overrides
        .iter()
        .find(|(k, _)| k == "policy")
        .map(|(_, v)| v.as_str())
        .unwrap_or("per-family");
    if cfg.fleet_spec.is_none() && policy != "per-family" {
        bail!("--policy {policy} only applies to --fleet training");
    }
    if cfg.backend == "native" {
        return cmd_train_native(cfg, policy, tele);
    }
    if cfg.fleet_spec.is_some() {
        bail!("--fleet requires the native backend (add --backend native)");
    }
    let manifest = Manifest::load(&artifacts_dir())?;
    let variant = manifest.variant(&cfg.variant)?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let engine = Engine::cpu()?;
    tele.log.info(&format!(
        "training on {} ({} envs x {} rollout steps, {} params) scenario={} {} {}/{} traffic={}",
        cfg.variant,
        variant.meta.num_envs,
        variant.meta.rollout_steps,
        variant.meta.n_params,
        cfg.scenario.scenario,
        cfg.scenario.region,
        cfg.scenario.country,
        cfg.scenario.year,
        cfg.scenario.traffic,
    ));
    let opts = trainer::TrainOptions {
        seed: cfg.seed,
        total_env_steps: cfg.total_env_steps,
        quiet: cfg.quiet,
        ..Default::default()
    };
    let out = trainer::train(&engine, variant, &store, &cfg.scenario, &opts)?;
    tele.log.info(&format!(
        "trained {} env steps in {:.2}s ({:.0} steps/s)",
        out.env_steps,
        out.wallclock_s,
        out.env_steps as f64 / out.wallclock_s
    ));
    // The PJRT driver owns its iteration loop; one aggregate report
    // covers the whole run.
    tele.iteration(0, out.wallclock_s * 1e3);
    let evals = trainer::evaluate(
        &engine,
        &out.session,
        &store,
        &cfg.scenario,
        1000..1000 + cfg.eval_seeds as u32,
    )?;
    let mean = metrics::mean(&evals)?;
    tele.log.result(&format!(
        "eval (net, {} seeds): {}",
        evals.len(),
        mean.fmt_fields(&["ep_reward", "ep_profit", "ep_missing_kwh", "ep_overtime_steps"])
    ));
    Ok(())
}

/// `chargax train --backend native`: pure-Rust VectorEnv PPO. Needs no
/// AOT artifacts or PJRT runtime; falls back to synthetic scenario tables
/// when `artifacts/data` has not been exported.
fn cmd_train_native(cfg: &RunConfig, policy: &str, tele: &mut Telem) -> Result<()> {
    use chargax::baselines::ppo::PpoParams;
    use chargax::env::tree::StationConfig;

    if let Some(spec) = &cfg.fleet_spec {
        return cmd_train_fleet(cfg, spec, policy, tele);
    }
    // Before the first pool spawns: workers read the flag at spawn time.
    chargax::runtime::pool::set_pin_cores(cfg.pin_cores);
    let store = DataStore::load(&artifacts_dir().join("data")).ok();
    if store.is_none() {
        tele.log.info("note: artifacts/data not found; using synthetic scenario tables");
    }
    let params = PpoParams {
        num_envs: cfg.num_envs,
        threads: cfg.num_threads,
        overlap: cfg.overlap,
        ..Default::default()
    };
    tele.log.info(&format!(
        "training native-vector backend ({} envs x {} rollout steps, threads={}) scenario={} {} {}/{} traffic={}",
        params.num_envs,
        params.rollout_steps,
        if params.threads == 0 { "auto".to_string() } else { params.threads.to_string() },
        cfg.scenario.scenario,
        cfg.scenario.region,
        cfg.scenario.country,
        cfg.scenario.year,
        cfg.scenario.traffic,
    ));
    let opts = trainer::TrainOptions {
        seed: cfg.seed,
        total_env_steps: cfg.total_env_steps,
        quiet: cfg.quiet,
        ..Default::default()
    };
    let mut iter_t0 = std::time::Instant::now();
    let out = trainer::train_native(
        store.as_ref(),
        &cfg.scenario,
        StationConfig::default(),
        params,
        &opts,
        |i| {
            let wall_ms = iter_t0.elapsed().as_secs_f64() * 1e3;
            iter_t0 = std::time::Instant::now();
            tele.iteration(i, wall_ms);
        },
    )?;
    tele.log.info(&format!(
        "trained {} env steps in {:.2}s ({:.0} steps/s)",
        out.env_steps,
        out.wallclock_s,
        out.env_steps as f64 / out.wallclock_s
    ));
    let eval_t0 = std::time::Instant::now();
    let mut tr = out.trainer;
    let evals: Vec<(f32, f32)> = (0..cfg.eval_seeds as u64)
        .map(|s| tr.eval_episode(1000 + s))
        .collect();
    tele.iteration(out.history.len(), eval_t0.elapsed().as_secs_f64() * 1e3);
    let n = evals.len().max(1) as f32;
    let (r, p): (f32, f32) = evals
        .iter()
        .fold((0.0, 0.0), |(ar, ap), (r, p)| (ar + r, ap + p));
    tele.log.result(&format!(
        "eval (greedy net, {} seeds): ep_reward={:.3} ep_profit={:.3}",
        evals.len(),
        r / n,
        p / n
    ));
    Ok(())
}

/// `chargax train --backend native --fleet <spec.json | demo |
/// demo-coupled>`: expand the scenario grid into station families, drive
/// every family's `VectorEnv` on one worker pool via the fused fleet
/// rollout, and train either one PPO policy per family
/// (`--policy per-family`, default) or one shared-trunk generalist across
/// the whole grid (`--policy generalist`) in a single pass per iteration.
/// Cells named by the spec's `holdout` key never train and show up in the
/// eval rows as zero-shot. Specs with a `grid` key couple families onto
/// shared feeders (README §Grid coupling); `demo-coupled` is the built-in
/// demo fleet on one proportional-curtailment feeder.
fn cmd_train_fleet(
    cfg: &RunConfig,
    spec_path: &str,
    policy: &str,
    tele: &mut Telem,
) -> Result<()> {
    use chargax::baselines::ppo::PpoParams;
    use chargax::fleet::{Fleet, FleetPpoTrainer, FleetSpec};

    chargax::runtime::pool::set_pin_cores(cfg.pin_cores);
    let store = DataStore::load(&artifacts_dir().join("data")).ok();
    if store.is_none() {
        tele.log.info("note: artifacts/data not found; using synthetic scenario tables");
    }
    let spec = if spec_path == "demo" {
        FleetSpec::demo(cfg.seed as u64, 1)
    } else if spec_path == "demo-coupled" {
        FleetSpec::demo_coupled(cfg.seed as u64, 1)
    } else {
        FleetSpec::from_json_file(spec_path)?
    };
    let mut fleet = Fleet::from_spec(&spec, store.as_ref())?;
    fleet.set_threads(cfg.num_threads);
    tele.log.info(&format!(
        "training fleet of {} lanes across {} station families (threads={}, \
         rollout + PPO update sharded on one worker pool):",
        fleet.total_lanes(),
        fleet.n_envs(),
        if cfg.num_threads == 0 { "auto".to_string() } else { cfg.num_threads.to_string() },
    ));
    for e in 0..fleet.n_envs() {
        let env = fleet.env(e);
        let feeder = match fleet.grid(e) {
            Some(g) if g.coupled() => format!(
                " feeder={} cap={:.0}kW ({})",
                g.feeder,
                g.capacity_kw.unwrap_or(0.0),
                g.policy.label()
            ),
            _ => String::new(),
        };
        tele.log.info(&format!(
            "  [{e}] {:<24} lanes={:<4} chargers={:<3} obs_dim={:<4} v2g={}{feeder}",
            fleet.label(e),
            env.batch(),
            env.n_chargers(),
            env.obs_dim(),
            env.cfg.v2g,
        ));
    }
    let hp = PpoParams {
        threads: cfg.num_threads,
        overlap: cfg.overlap,
        ..Default::default()
    };
    let mut tr = match policy {
        "per-family" => FleetPpoTrainer::new(hp, fleet, cfg.seed as u64),
        "generalist" => FleetPpoTrainer::new_generalist(hp, fleet, cfg.seed as u64),
        other => bail!("unknown --policy '{other}' (expected per-family | generalist)"),
    };
    tele.log.info(&format!("  policy architecture: {}", tr.policy.label()));
    let batch = tr.steps_per_iteration();
    let iters = cfg.total_env_steps.div_ceil(batch).max(1);
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let it0 = std::time::Instant::now();
        // The last iteration never prefetches, so N iterations perform
        // exactly N rollouts in both overlap modes.
        let stats =
            if i + 1 == iters { tr.final_iteration() } else { tr.iteration() };
        if i % 5 == 0 || i + 1 == iters {
            for s in &stats {
                tele.log.info(&format!(
                    "[fleet iter {}/{} steps {}] {:<24} reward={:.3} profit={:.3} loss={:.3} ent={:.3}",
                    i + 1,
                    iters,
                    tr.env_steps,
                    s.label,
                    s.mean_reward,
                    s.mean_profit,
                    s.total_loss,
                    s.entropy,
                ));
            }
        }
        tele.iteration(i, it0.elapsed().as_secs_f64() * 1e3);
    }
    let el = t0.elapsed().as_secs_f64();
    tele.log.info(&format!(
        "trained {} env steps in {el:.2}s ({:.0} steps/s)",
        tr.env_steps,
        tr.env_steps as f64 / el
    ));
    // Greedy eval per (family × scenario cell): every distinct cell a
    // family trains on gets its own number, with the cell named — so
    // distribution shift across the grid is visible instead of hidden
    // behind lane 0's cell. Seeds come off the trainer rng's
    // per-iteration eval seed (ISSUE 5): seed 0 is exactly the
    // reproducible `eval_cells_current` episode, further seeds widen the
    // average, and re-running the eval block cannot drift.
    let eval_t0 = std::time::Instant::now();
    let eval_base = tr.current_eval_seed();
    for e in 0..tr.fleet.n_envs() {
        let per_seed: Vec<Vec<chargax::fleet::CellEval>> = (0..cfg.eval_seeds as u64)
            .map(|s| tr.eval_cells(e, eval_base.wrapping_add(s)))
            .collect();
        if per_seed.is_empty() {
            continue; // eval_seeds = 0: eval disabled, same as the non-fleet path
        }
        let n = per_seed.len() as f32;
        for ci in 0..per_seed[0].len() {
            let r = per_seed.iter().map(|v| v[ci].reward).sum::<f32>() / n;
            let p = per_seed.iter().map(|v| v[ci].profit).sum::<f32>() / n;
            let eps: usize = per_seed.iter().map(|v| v[ci].episodes).sum();
            tele.log.result(&format!(
                "eval (greedy, {} seeds) {:<24} cell {:<28} lanes={:<3} eps={:<3} ep_reward={:.3} ep_profit={:.3}{}",
                per_seed.len(),
                tr.fleet.label(e),
                per_seed[0][ci].cell,
                per_seed[0][ci].lanes,
                eps,
                r,
                p,
                if per_seed[0][ci].holdout { "  [holdout: zero-shot]" } else { "" },
            ));
        }
    }
    // One trailing report covers the greedy-eval pass.
    tele.iteration(iters, eval_t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}

fn cmd_eval(cfg: &RunConfig, overrides: &[(String, String)], tele: &mut Telem) -> Result<()> {
    let policy = overrides
        .iter()
        .find(|(k, _)| k == "policy")
        .map(|(_, v)| v.as_str())
        .unwrap_or("max");
    if policy == "net" {
        bail!("eval --policy net requires training first; use `chargax train`");
    }
    let manifest = Manifest::load(&artifacts_dir())?;
    let variant = manifest.variant(&cfg.variant)?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let engine = Engine::cpu()?;
    let evals = trainer::evaluate_baseline(
        &engine,
        variant,
        &store,
        &cfg.scenario,
        policy,
        1000..1000 + cfg.eval_seeds as u32,
    )?;
    let mean = metrics::mean(&evals)?;
    let std = metrics::std(&evals)?;
    tele.log.result(&format!(
        "policy={policy} scenario={} {} seeds:",
        cfg.scenario.scenario,
        evals.len()
    ));
    for f in &evals[0].fields {
        tele.log
            .result(&format!("  {f:>22}: {:>10.3} ± {:.3}", mean.get(f)?, std.get(f)?));
    }
    Ok(())
}

fn cmd_list_profiles(tele: &mut Telem) -> Result<()> {
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let log = &tele.log;
    log.result(&format!("Price profiles (hourly, {} days):", store.n_days));
    for k in store.prices.keys() {
        log.result(&format!("  {k}"));
    }
    log.result(&format!("Car catalog ({} models):", store.n_models));
    for (i, n) in store.car_names.iter().enumerate() {
        let row = &store.car_table[i * 4..i * 4 + 4];
        log.result(&format!(
            "  {n:<22} cap={:>5.1} kWh  AC={:>4.1} kW  DC={:>5.1} kW  tau={:.2}",
            row[0], row[1], row[2], row[3]
        ));
    }
    log.result(&format!("Car regions: {:?}", store.car_weights.keys().collect::<Vec<_>>()));
    log.result(&format!(
        "Arrival scenarios: {:?}",
        store.arrival_shapes.keys().collect::<Vec<_>>()
    ));
    log.result(&format!("Traffic levels: {:?}", store.traffic));
    log.result(&format!("User profiles: {:?}", store.user_profiles.keys().collect::<Vec<_>>()));
    Ok(())
}

fn cmd_list_artifacts(tele: &mut Telem) -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    for (key, v) in &manifest.variants {
        tele.log.result(&format!(
            "{key}: obs_dim={} ports={} envs={} batch={}",
            v.meta.obs_dim, v.meta.n_ports, v.meta.num_envs, v.meta.batch_size
        ));
        for (name, p) in &v.programs {
            tele.log.result(&format!(
                "  {name:<16} {} inputs, {} outputs  ({})",
                p.inputs.len(),
                p.outputs.len(),
                p.file.file_name().unwrap_or_default().to_string_lossy()
            ));
        }
    }
    Ok(())
}

fn cmd_cross_check(cfg: &RunConfig, tele: &mut Telem) -> Result<()> {
    let report = experiments::cross_check(&cfg.variant)?;
    tele.log.result(&report);
    Ok(())
}

fn print_usage() {
    // Usage text is a result (stdout, never quieted), printed before any
    // RunLog can exist when the binary runs with no arguments.
    println!(
        "chargax — Chargax (JAX EV-charging RL) reproduction, rust coordinator

USAGE: chargax <command> [--config file.json] [--key value ...]

COMMANDS:
  train            train PPO (--backend pjrt: AOT fast path;
                   --backend native: pure-Rust VectorEnv, no artifacts;
                   --backend native --fleet <spec.json | demo |
                   demo-coupled>: scenario fleet, one policy per family)
  eval             evaluate max/random baseline policies
  bench <id>       regenerate a paper table/figure:
                   table2 | fig4a | fig4bc | fig5 | fig6to8 | fig9to11 |
                   perf | fleet
  list-profiles    bundled data stack (paper Table 1)
  list-artifacts   AOT variants and programs
  cross-check      scalar-vs-JAX transition equivalence
  help             this text

KEYS: variant backend num_envs threads pin_cores overlap scenario region
      country year traffic p_sell beta seed n_seeds steps eval_seeds
      paper_scale out fleet telemetry log_format quiet trace_out
      alpha_<penalty>

  --threads N caps the persistent worker pool driving native rollouts
  (0 = all cores); see README §Rollout runtime.
  --overlap off|on selects barrier (default) or double-buffered training:
  with `on`, each iteration's accounting/stats/eval tail runs while the
  next rollout streams on the pool's pipeline lane. Bit-identical to
  `off` at any --threads (README §Overlapped pipeline).
  --pin_cores true pins pool workers to cores (Linux only, no-op
  elsewhere; placement-only, results identical); see README §Kernel layer.
  --fleet takes a scenario-grid JSON (README §Scenario fleets & V2G), the
  literal `demo` for the built-in three-family fleet, or `demo-coupled`
  for the same fleet sharing one curtailed feeder (README §Grid coupling).
  --policy per-family|generalist picks the fleet policy architecture:
  one PPO learner per station family (default) or one shared-trunk
  generalist across the whole grid (README §Generalist policy). Cells
  under the spec's `holdout` key never train and are evaluated
  zero-shot.
  --telemetry enables the profiler: per-iteration stage p50/p99, shard
  imbalance, pool utilization; one JSONL record per iteration lands in
  runs/telemetry.jsonl. Results are bit-identical on or off.
  --log_format text|json routes the per-iteration record to stdout as a
  JSON line (json) or keeps human-readable text (default).
  --quiet true silences stderr diagnostics; stdout results always print.
  --trace_out FILE writes every recorded span as a Chrome trace-event
  file (open in Perfetto / chrome://tracing); implies span recording."
    );
}
