//! Generalist shared-trunk policy across the scenario grid (ISSUE 7).
//!
//! One network serves every station family: a shared tanh trunk consumes
//! observation rows padded to the grid-wide max obs dim plus a per-family
//! one-hot block, per-family categorical action heads project the shared
//! hidden state onto each family's own `action_nvec`, and a single shared
//! value head scores every row. The per-family [`Learner`] path stays as
//! the oracle; [`PolicyRef`] lets the fused rollout dispatch either
//! through the same shard tasks.
//!
//! All math runs on the same blocked kernel layer as [`super::mlp::Mlp`]
//! (per-element accumulation order independent of row blocking), action
//! sampling keys off the same per-(lane, t) [`CounterRng`] streams, and
//! the cross-family PPO update reduces its gradient chunks through the
//! same fixed-order pairwise tree — so the serial==sharded bitwise
//! contract holds for the generalist at any `--threads`, exactly as it
//! does per family.

use crate::runtime::pool::{DisjointTasks, WorkerPool};
use crate::util::rng::{CounterRng, Rng};

use super::kernels;
use super::mlp::MlpScratch;
use super::ppo::{
    gae, minibatch_bounds, ppo_row_grads, tree_reduce, tree_reduce_stats, update_shard_demand,
    Adam, Heads, Learner, PpoParams, UpdateBatch, UPDATE_CHUNK_ROWS,
};

/// One family's action head: its own obs dim (for staging/validation) and
/// its own logit projection off the shared trunk.
pub struct FamilyHead {
    pub obs_dim: usize,
    pub heads: Heads,
    /// `[hidden][n_logits]`, row-major like [`super::mlp::Mlp::wpi`].
    pub wpi: Vec<f32>,
    pub bpi: Vec<f32>,
}

/// Shared trunk + per-family heads + shared value head + Adam state.
///
/// Input layout per row (`in_dim = pad_obs + n_families` columns):
/// `[obs (family obs_dim) | zero padding to pad_obs | family one-hot]`.
/// Family indexing is the catalog's deterministic expansion order, so the
/// one-hot block and the head list can never disagree.
pub struct GeneralistLearner {
    pub hidden: usize,
    /// Grid-wide max family obs dim (the padded obs block width).
    pub pad_obs: usize,
    /// Trunk input width: `pad_obs + families.len()`.
    pub in_dim: usize,
    // trunk (row-major [in][out], like Mlp)
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    // shared value head
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub families: Vec<FamilyHead>,
    pub adam: Adam,
}

/// Gradients, same canonical layout as [`GeneralistLearner::params`]:
/// `[w1, b1, w2, b2, wv, bv, wpi_0, bpi_0, wpi_1, bpi_1, …]`.
pub struct GenGrads {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub wpi: Vec<Vec<f32>>,
    pub bpi: Vec<Vec<f32>>,
}

impl GenGrads {
    pub fn as_slices(&self) -> Vec<&Vec<f32>> {
        let mut v = vec![&self.w1, &self.b1, &self.w2, &self.b2, &self.wv, &self.bv];
        for (w, b) in self.wpi.iter().zip(&self.bpi) {
            v.push(w);
            v.push(b);
        }
        v
    }

    pub fn as_slices_mut(&mut self) -> Vec<&mut Vec<f32>> {
        let mut v = vec![
            &mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2,
            &mut self.wv, &mut self.bv,
        ];
        for (w, b) in self.wpi.iter_mut().zip(self.bpi.iter_mut()) {
            v.push(w);
            v.push(b);
        }
        v
    }

    pub fn zero(&mut self) {
        for v in self.as_slices_mut() {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// `self += other` in a fixed (field, index) order — the combine step
    /// of the cross-family gradient tree reduction.
    pub fn add_from(&mut self, other: &GenGrads) {
        for (a, b) in self.as_slices_mut().into_iter().zip(other.as_slices()) {
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x += y;
            }
        }
    }

    pub fn global_norm(&self) -> f32 {
        let sq: f32 = self
            .as_slices()
            .iter()
            .map(|v| v.iter().map(|x| x * x).sum::<f32>())
            .sum();
        sq.sqrt()
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.as_slices_mut() {
            v.iter_mut().for_each(|x| *x *= s);
        }
    }
}

impl GeneralistLearner {
    /// Build the generalist over `specs` — one `(obs_dim, action_nvec)`
    /// per family in deterministic (catalog expansion) order. Same init
    /// recipe and scales as [`super::mlp::Mlp::new`]; draw order is fixed
    /// (trunk, then each family head in order, then the value head), so a
    /// given `rng` state always yields the same weights.
    pub fn new(
        rng: &mut Rng,
        pad_obs: usize,
        hidden: usize,
        specs: &[(usize, Vec<usize>)],
    ) -> GeneralistLearner {
        assert!(!specs.is_empty(), "generalist needs at least one family");
        for &(d, _) in specs {
            assert!(d <= pad_obs, "family obs_dim {d} exceeds pad_obs {pad_obs}");
        }
        let in_dim = pad_obs + specs.len();
        let init = |rng: &mut Rng, rows: usize, cols: usize, scale: f32| -> Vec<f32> {
            let s = scale / (rows as f32).sqrt();
            (0..rows * cols).map(|_| rng.normal() * s).collect()
        };
        let w1 = init(rng, in_dim, hidden, 1.4);
        let w2 = init(rng, hidden, hidden, 1.4);
        let families: Vec<FamilyHead> = specs
            .iter()
            .map(|(d, nvec)| {
                let heads = Heads::new(nvec.clone());
                let wpi = init(rng, hidden, heads.n_logits, 0.01);
                let bpi = vec![0.0; heads.n_logits];
                FamilyHead { obs_dim: *d, heads, wpi, bpi }
            })
            .collect();
        let wv = init(rng, hidden, 1, 1.0);
        let mut sizes = vec![w1.len(), hidden, w2.len(), hidden, wv.len(), 1];
        for fh in &families {
            sizes.push(fh.wpi.len());
            sizes.push(fh.bpi.len());
        }
        GeneralistLearner {
            hidden,
            pad_obs,
            in_dim,
            w1,
            b1: vec![0.0; hidden],
            w2,
            b2: vec![0.0; hidden],
            wv,
            bv: vec![0.0; 1],
            families,
            adam: Adam::from_sizes(&sizes),
        }
    }

    pub fn n_families(&self) -> usize {
        self.families.len()
    }

    pub fn obs_dim(&self, f: usize) -> usize {
        self.families[f].obs_dim
    }

    pub fn n_ports(&self, f: usize) -> usize {
        self.families[f].heads.nvec.len()
    }

    pub fn n_logits(&self, f: usize) -> usize {
        self.families[f].heads.n_logits
    }

    /// The parameter tensors in canonical order (see [`GenGrads`]).
    pub fn params(&self) -> Vec<&Vec<f32>> {
        let mut v = vec![&self.w1, &self.b1, &self.w2, &self.b2, &self.wv, &self.bv];
        for fh in &self.families {
            v.push(&fh.wpi);
            v.push(&fh.bpi);
        }
        v
    }

    pub fn zero_grads(&self) -> GenGrads {
        GenGrads {
            w1: vec![0.0; self.w1.len()],
            b1: vec![0.0; self.b1.len()],
            w2: vec![0.0; self.w2.len()],
            b2: vec![0.0; self.b2.len()],
            wv: vec![0.0; self.wv.len()],
            bv: vec![0.0; self.bv.len()],
            wpi: self.families.iter().map(|fh| vec![0.0; fh.wpi.len()]).collect(),
            bpi: self.families.iter().map(|fh| vec![0.0; fh.bpi.len()]).collect(),
        }
    }

    /// One clipped-gradient Adam step over the canonical parameter order.
    pub fn apply_grads(&mut self, grads: &GenGrads, lr: f32) {
        let GeneralistLearner { w1, b1, w2, b2, wv, bv, families, adam, .. } = self;
        let mut params: Vec<&mut Vec<f32>> = vec![w1, b1, w2, b2, wv, bv];
        for fh in families.iter_mut() {
            params.push(&mut fh.wpi);
            params.push(&mut fh.bpi);
        }
        adam.step(params, &grads.as_slices(), lr);
    }

    /// Scratch sized for one row; [`GeneralistLearner::forward_block`]
    /// grows it to whatever block a shard actually runs. The `pad` buffer
    /// stages the padded input rows.
    pub fn make_scratch(&self) -> MlpScratch {
        let max_nl = self.families.iter().map(|fh| fh.heads.n_logits).max().unwrap_or(1);
        MlpScratch {
            h1: vec![0.0; self.hidden],
            h2: vec![0.0; self.hidden],
            logits: vec![0.0; max_nl],
            values: vec![0.0; 1],
            rows: 1,
            pad: vec![0.0; self.in_dim],
        }
    }

    /// Stage `rows` family-`f` observation rows into padded trunk-input
    /// rows: obs block, zero padding, family one-hot. Fully overwrites
    /// `pad` (zero fill first), so reuse across families is safe.
    pub fn stage_rows(&self, f: usize, obs: &[f32], rows: usize, pad: &mut Vec<f32>) {
        let d = self.families[f].obs_dim;
        let k = self.in_dim;
        debug_assert_eq!(obs.len(), rows * d);
        pad.resize(rows * k, 0.0);
        pad.fill(0.0);
        for r in 0..rows {
            pad[r * k..r * k + d].copy_from_slice(&obs[r * d..(r + 1) * d]);
            pad[r * k + self.pad_obs + f] = 1.0;
        }
    }

    /// Trunk + family-`f` head forward over already-staged padded rows —
    /// the same blocked-kernel pipeline as [`super::mlp::Mlp`], so row `i`
    /// of a block is bit-identical to the `rows == 1` forward of row `i`.
    fn forward_padded(
        &self,
        f: usize,
        pad: &[f32],
        rows: usize,
        h1: &mut Vec<f32>,
        h2: &mut Vec<f32>,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) {
        let fh = &self.families[f];
        let h = self.hidden;
        let nl = fh.heads.n_logits;
        debug_assert_eq!(pad.len(), rows * self.in_dim);
        h1.resize(rows * h, 0.0);
        kernels::gemm_bias(pad, &self.w1, &self.b1, rows, self.in_dim, h, h1);
        h1.iter_mut().for_each(|x| *x = x.tanh());
        h2.resize(rows * h, 0.0);
        kernels::gemm_bias(h1.as_slice(), &self.w2, &self.b2, rows, h, h, h2);
        h2.iter_mut().for_each(|x| *x = x.tanh());
        logits.resize(rows * nl, 0.0);
        kernels::gemm_bias(h2.as_slice(), &fh.wpi, &fh.bpi, rows, h, nl, logits);
        values.resize(rows, 0.0);
        for (i, v) in values.iter_mut().enumerate() {
            *v = self.bv[0] + kernels::dot8(&h2[i * h..(i + 1) * h], &self.wv);
        }
    }

    /// Stage + forward a block of family-`f` obs rows into `s` (logits and
    /// values; `s.pad` keeps the staged rows). Shard-side entry point —
    /// `&self`, caller-owned scratch, zero allocation after warmup.
    pub fn forward_block(&self, f: usize, obs: &[f32], rows: usize, s: &mut MlpScratch) {
        let MlpScratch { h1, h2, logits, values, rows: srows, pad } = s;
        self.stage_rows(f, obs, rows, pad);
        *srows = rows;
        self.forward_padded(f, pad, rows, h1, h2, logits, values);
    }

    /// Lane-blocked fused-rollout sampling — the generalist counterpart of
    /// [`Learner::sample_block`]: one staged block forward through the
    /// shared trunk, then each row sampled off its own `(seed, lane, t)`
    /// counter stream. Identical stream derivation, so switching policy
    /// never perturbs the env-side action RNG layout.
    #[allow(clippy::too_many_arguments)]
    pub fn sample_block(
        &self,
        f: usize,
        t: usize,
        lane0: usize,
        seed: u64,
        obs: &[f32],
        actions: &mut [usize],
        logp: &mut [f32],
        values: &mut [f32],
        scratch: &mut MlpScratch,
    ) {
        let n = logp.len();
        let fh = &self.families[f];
        let nl = fh.heads.n_logits;
        let p = fh.heads.nvec.len();
        debug_assert_eq!(obs.len(), n * fh.obs_dim);
        debug_assert_eq!(actions.len(), n * p);
        debug_assert_eq!(values.len(), n);
        self.forward_block(f, obs, n, scratch);
        for i in 0..n {
            let lg = &scratch.logits[i * nl..(i + 1) * nl];
            let mut rng = CounterRng::derive2(seed, (lane0 + i) as u64, t as u64);
            logp[i] = fh.heads.sample(&mut rng, lg, &mut actions[i * p..(i + 1) * p]);
        }
        values.copy_from_slice(&scratch.values[..n]);
    }

    /// Lane-blocked greedy decode — [`Learner::greedy_block`]'s generalist
    /// counterpart (one staged block forward, per-row argmax, no RNG).
    pub fn greedy_block(
        &self,
        f: usize,
        obs: &[f32],
        actions: &mut [usize],
        values: &mut [f32],
        scratch: &mut MlpScratch,
    ) {
        let n = values.len();
        let fh = &self.families[f];
        let nl = fh.heads.n_logits;
        let p = fh.heads.nvec.len();
        debug_assert_eq!(obs.len(), n * fh.obs_dim);
        debug_assert_eq!(actions.len(), n * p);
        self.forward_block(f, obs, n, scratch);
        for i in 0..n {
            let lg = &scratch.logits[i * nl..(i + 1) * nl];
            fh.heads.greedy(lg, &mut actions[i * p..(i + 1) * p]);
        }
        values.copy_from_slice(&scratch.values[..n]);
    }

    /// Greedy decode of one family-`f` observation row (the eval path).
    /// Returns the shared value head's estimate.
    pub fn greedy_lane(
        &self,
        f: usize,
        obs: &[f32],
        action: &mut [usize],
        scratch: &mut MlpScratch,
    ) -> f32 {
        let mut values = [0f32; 1];
        let p = self.families[f].heads.nvec.len();
        self.greedy_block(f, obs, &mut action[..p], &mut values, scratch);
        values[0]
    }

    /// Per-row backprop through the family-`f` head, the shared value
    /// head, and the trunk — mirrors [`super::mlp::Mlp::backward_scratch`]
    /// over the padded input rows. Gradients ACCUMULATE into `g` (zero it
    /// for a fresh chunk); only `g`'s trunk/value tensors and family `f`'s
    /// head tensors are touched.
    #[allow(clippy::too_many_arguments)]
    fn backward_padded(
        &self,
        f: usize,
        pad: &[f32],
        h1: &[f32],
        h2: &[f32],
        rows: usize,
        dlogits: &[f32],
        dvalue: &[f32],
        g: &mut GenGrads,
        dh1: &mut Vec<f32>,
        dh2: &mut Vec<f32>,
    ) {
        let fh = &self.families[f];
        let b = rows;
        let h = self.hidden;
        let nl = fh.heads.n_logits;
        debug_assert_eq!(pad.len(), b * self.in_dim);
        // dh2 = dlogits @ wpi_f^T + dvalue * wv^T
        dh2.resize(b * h, 0.0);
        for i in 0..b {
            let dl = &dlogits[i * nl..(i + 1) * nl];
            let dv = dvalue[i];
            for k in 0..h {
                let row = &fh.wpi[k * nl..(k + 1) * nl];
                dh2[i * h + k] = kernels::fmadd(dv, self.wv[k], kernels::dot8(row, dl));
            }
        }
        // head grads (the value head is the j_dim == 1 outer product).
        kernels::outer_acc(h2, dlogits, b, h, nl, &mut g.wpi[f]);
        kernels::colsum_acc(dlogits, b, nl, &mut g.bpi[f]);
        kernels::outer_acc(h2, dvalue, b, h, 1, &mut g.wv);
        kernels::colsum_acc(dvalue, b, 1, &mut g.bv);
        // through tanh of h2
        for i in 0..b * h {
            dh2[i] *= 1.0 - h2[i] * h2[i];
        }
        // dh1 = dh2 @ w2^T
        dh1.resize(b * h, 0.0);
        for i in 0..b {
            let dd = &dh2[i * h..(i + 1) * h];
            for k in 0..h {
                dh1[i * h + k] = kernels::dot8(&self.w2[k * h..(k + 1) * h], dd);
            }
        }
        kernels::outer_acc(h1, dh2, b, h, h, &mut g.w2);
        kernels::colsum_acc(dh2, b, h, &mut g.b2);
        for i in 0..b * h {
            dh1[i] *= 1.0 - h1[i] * h1[i];
        }
        kernels::outer_acc(pad, dh1, b, self.in_dim, h, &mut g.w1);
        kernels::colsum_acc(dh1, b, h, &mut g.b1);
    }
}

/// Which policy a fused shard runs for its lane block: one family's own
/// [`Learner`], or the [`GeneralistLearner`] with that family's index.
/// `Copy`, so shard-task splitting stays as cheap as the old `&Learner`
/// field it replaces.
#[derive(Clone, Copy)]
pub enum PolicyRef<'a> {
    PerFamily(&'a Learner),
    Generalist(&'a GeneralistLearner, usize),
}

impl PolicyRef<'_> {
    pub fn obs_dim(&self) -> usize {
        match self {
            PolicyRef::PerFamily(l) => l.obs_dim,
            PolicyRef::Generalist(g, f) => g.obs_dim(*f),
        }
    }

    pub fn n_ports(&self) -> usize {
        match self {
            PolicyRef::PerFamily(l) => l.n_ports(),
            PolicyRef::Generalist(g, f) => g.n_ports(*f),
        }
    }

    pub fn make_scratch(&self) -> MlpScratch {
        match self {
            PolicyRef::PerFamily(l) => l.make_scratch(),
            PolicyRef::Generalist(g, _) => g.make_scratch(),
        }
    }

    /// Dispatch [`Learner::sample_block`] / the generalist equivalent —
    /// same signature, same per-(lane, t) counter streams either way.
    #[allow(clippy::too_many_arguments)]
    pub fn sample_block(
        &self,
        t: usize,
        lane0: usize,
        seed: u64,
        obs: &[f32],
        actions: &mut [usize],
        logp: &mut [f32],
        values: &mut [f32],
        scratch: &mut MlpScratch,
    ) {
        match self {
            PolicyRef::PerFamily(l) => {
                l.sample_block(t, lane0, seed, obs, actions, logp, values, scratch)
            }
            PolicyRef::Generalist(g, f) => {
                g.sample_block(*f, t, lane0, seed, obs, actions, logp, values, scratch)
            }
        }
    }

    pub fn greedy_block(
        &self,
        obs: &[f32],
        actions: &mut [usize],
        values: &mut [f32],
        scratch: &mut MlpScratch,
    ) {
        match self {
            PolicyRef::PerFamily(l) => l.greedy_block(obs, actions, values, scratch),
            PolicyRef::Generalist(g, f) => g.greedy_block(*f, obs, actions, values, scratch),
        }
    }

    /// Greedy decode of one observation row (the eval path).
    pub fn greedy_lane(&self, obs: &[f32], action: &mut [usize], scratch: &mut MlpScratch) -> f32 {
        match self {
            PolicyRef::PerFamily(l) => l.greedy_lane(obs, action, scratch),
            PolicyRef::Generalist(g, f) => g.greedy_lane(*f, obs, action, scratch),
        }
    }
}

/// Per-pool-lane reusable buffers for the generalist update's chunk passes
/// (padded gathered rows, trunk activations, loss gradients, backward
/// temporaries). Resized on demand, so one scratch serves chunks from any
/// family head.
struct GenUpdateScratch {
    pad: Vec<f32>,
    h1: Vec<f32>,
    h2: Vec<f32>,
    logits: Vec<f32>,
    values: Vec<f32>,
    dlogits: Vec<f32>,
    dvalue: Vec<f32>,
    dlp: Vec<f32>,
    dent: Vec<f32>,
    dh1: Vec<f32>,
    dh2: Vec<f32>,
}

impl GenUpdateScratch {
    fn new() -> GenUpdateScratch {
        GenUpdateScratch {
            pad: Vec::new(),
            h1: Vec::new(),
            h2: Vec::new(),
            logits: Vec::new(),
            values: Vec::new(),
            dlogits: Vec::new(),
            dvalue: Vec::new(),
            dlp: Vec::new(),
            dent: Vec::new(),
            dh1: Vec::new(),
            dh2: Vec::new(),
        }
    }
}

/// One gradient chunk of one family's slice of the current cross-family
/// minibatch round: stage + forward + loss gradients + backward over
/// `idxs` (at most [`UPDATE_CHUNK_ROWS`] rows), writing the partial
/// gradient into this chunk's own full-size [`GenGrads`] accumulator.
/// Chunks share the learner read-only and own disjoint outputs, so any
/// number of them can run concurrently on pool lanes.
struct GenChunkTask<'a> {
    gen: &'a GeneralistLearner,
    hp: &'a PpoParams,
    family: usize,
    idxs: &'a [usize],
    /// Loss/grad normalizer: the FULL round row count across ALL families
    /// (one Adam step serves the whole grid), NOT this family's or this
    /// chunk's.
    norm: f32,
    /// Advantage-normalization stats over this family's WHOLE minibatch.
    adv_mean: f32,
    adv_std: f32,
    batch: &'a UpdateBatch<'a>,
    adv: &'a [f32],
    targets: &'a [f32],
    grads: &'a mut GenGrads,
    /// (loss, entropy) partial sums over this chunk's rows.
    stats: &'a mut (f32, f32),
}

impl GenChunkTask<'_> {
    fn run(&mut self, s: &mut GenUpdateScratch) {
        let _span = crate::telemetry::Span::fine(crate::telemetry::SpanKind::UpdateChunk);
        let gen = self.gen;
        let f = self.family;
        let fh = &gen.families[f];
        let d = fh.obs_dim;
        let k = gen.in_dim;
        let nl = fh.heads.n_logits;
        let n_ports = fh.heads.nvec.len();
        let b = self.idxs.len();
        let GenUpdateScratch { pad, h1, h2, logits, values, dlogits, dvalue, dlp, dent, dh1, dh2 } =
            s;
        // Gather this chunk's observation rows straight into padded trunk
        // rows (zero fill, obs block, family one-hot), then ONE blocked
        // forward over the whole chunk.
        pad.resize(b * k, 0.0);
        pad.fill(0.0);
        for (r, &i) in self.idxs.iter().enumerate() {
            pad[r * k..r * k + d].copy_from_slice(&self.batch.obs[i * d..(i + 1) * d]);
            pad[r * k + gen.pad_obs + f] = 1.0;
        }
        gen.forward_padded(f, pad, b, h1, h2, logits, values);
        dlogits.resize(b * nl, 0.0);
        dvalue.resize(b, 0.0);
        dlp.resize(nl, 0.0);
        dent.resize(nl, 0.0);
        let mut loss_acc = 0f32;
        let mut ent_acc = 0f32;
        for (r, &i) in self.idxs.iter().enumerate() {
            let lg = &logits[r * nl..(r + 1) * nl];
            let act = &self.batch.act[i * n_ports..(i + 1) * n_ports];
            ppo_row_grads(
                &fh.heads,
                self.hp,
                lg,
                act,
                self.adv[i],
                self.adv_mean,
                self.adv_std,
                self.batch.logp[i],
                values[r],
                self.batch.val[i],
                self.targets[i],
                self.norm,
                dlp,
                dent,
                &mut dlogits[r * nl..(r + 1) * nl],
                &mut dvalue[r],
                &mut loss_acc,
                &mut ent_acc,
            );
        }
        self.grads.zero();
        gen.backward_padded(
            f,
            pad,
            h1,
            h2,
            b,
            &dlogits[..b * nl],
            &dvalue[..b],
            self.grads,
            dh1,
            dh2,
        );
        *self.stats = (loss_acc, ent_acc);
        crate::telemetry::counters(|c| c.minibatch_rows += b as u64);
    }
}

/// Dispatch one cross-family round's gradient chunks over the pool, each
/// pool lane reusing its own [`GenUpdateScratch`]. Without a pool (or a
/// single chunk) everything runs inline in chunk order; either way every
/// chunk computes the same bits.
fn run_gen_chunk_tasks(
    pool: Option<&WorkerPool>,
    tasks: &mut [GenChunkTask<'_>],
    scratch: &mut [GenUpdateScratch],
) {
    match pool {
        Some(pool) if tasks.len() > 1 && pool.max_shards() > 1 => {
            let shared = DisjointTasks::new(tasks);
            let scr = DisjointTasks::new(scratch);
            pool.run_strided(shared.len(), |lane, k| {
                // SAFETY: `run_strided` visits chunk `k` exactly once, and lane
                // index `lane` is owned by exactly one OS thread for the whole
                // dispatch — both accesses are exclusive with no locks on the
                // hot path.
                unsafe { shared.get(k).run(scr.get(lane)) }
            });
        }
        _ => {
            let _scope = crate::telemetry::quiet_scope();
            let (first, _) = scratch.split_first_mut().expect("at least one update scratch");
            for task in tasks {
                task.run(first);
            }
        }
    }
}

/// Shard-parallel PPO update of the generalist over every family's filled
/// rollout buffers at once — the cross-family counterpart of
/// [`super::ppo::update_sharded_many`]. Per (epoch, minibatch) round it
/// dispatches EVERY family's gradient chunks in one pooled call, reduces
/// ALL of them (family-major chunk order) through ONE fixed-order pairwise
/// tree, clips, and applies ONE Adam step — so a single optimizer step
/// serves the whole grid while the trunk gradient accumulates across
/// families.
///
/// Determinism contract (tested in rust/tests/generalist.rs): chunk
/// boundaries are a pure function of each family's minibatch partition
/// ([`UPDATE_CHUNK_ROWS`]); every chunk computes the same bits wherever it
/// runs; the reduction order is family-major chunk order, fixed by the
/// round's shape alone; epoch permutations are pre-drawn family-major.
/// Hence the result is bit-identical for ANY pool width (incl. `None`).
///
/// Returns per-family `(mean total loss, mean entropy)` — normalized by
/// each family's own minibatch rows, so the numbers are comparable with
/// the per-family oracle's stats.
pub fn update_generalist_sharded(
    gen: &mut GeneralistLearner,
    hp: &PpoParams,
    rng: &mut Rng,
    pool: Option<&WorkerPool>,
    batches: &[UpdateBatch<'_>],
) -> Vec<(f32, f32)> {
    assert_eq!(gen.n_families(), batches.len(), "one UpdateBatch per family head");
    struct Prep {
        adv: Vec<f32>,
        targets: Vec<f32>,
        bounds: Vec<(usize, usize)>,
        /// One permutation per epoch (pre-drawn, family-major).
        perms: Vec<Vec<usize>>,
        chunk_grads: Vec<GenGrads>,
        chunk_stats: Vec<(f32, f32)>,
        loss_acc: f64,
        ent_acc: f64,
        n_upd: usize,
    }
    let mut boot = gen.make_scratch();
    let mut preps: Vec<Prep> = batches
        .iter()
        .enumerate()
        .map(|(f, b)| {
            let d = gen.obs_dim(f);
            let bsz = b.n_envs * b.t_len;
            assert_eq!(b.obs.len(), (b.t_len + 1) * b.n_envs * d, "obs must be [(T+1)*B*d]");
            // Bootstrap values from the generalist itself (shared value
            // head over the padded last-obs rows).
            gen.forward_block(f, &b.obs[b.t_len * b.n_envs * d..], b.n_envs, &mut boot);
            let (adv, targets) = gae(
                b.rew,
                b.val,
                b.done,
                &boot.values[..b.n_envs],
                b.n_envs,
                hp.gamma,
                hp.gae_lambda,
            );
            let bounds = minibatch_bounds(bsz, hp.n_minibatches);
            let perms: Vec<Vec<usize>> =
                (0..hp.update_epochs).map(|_| rng.permutation(bsz)).collect();
            let max_chunks = update_shard_demand(bsz, hp.n_minibatches);
            Prep {
                adv,
                targets,
                bounds,
                perms,
                chunk_grads: (0..max_chunks).map(|_| gen.zero_grads()).collect(),
                chunk_stats: vec![(0.0, 0.0); max_chunks],
                loss_acc: 0.0,
                ent_acc: 0.0,
                n_upd: 0,
            }
        })
        .collect();
    let width = pool.map(|p| p.max_shards()).unwrap_or(1).max(1);
    let mut scratch: Vec<GenUpdateScratch> = (0..width).map(|_| GenUpdateScratch::new()).collect();
    for epoch in 0..hp.update_epochs {
        for mb in 0..hp.n_minibatches.max(1) {
            // The round's total row count across every family — the
            // normalizer that makes the reduced gradient the mean over all
            // rows one Adam step serves.
            let round_len: usize = preps
                .iter()
                .map(|p| {
                    let (lo, hi) = p.bounds[mb];
                    hi - lo
                })
                .sum();
            if round_len == 0 {
                continue; // n_minibatches > every family's bsz
            }
            let mut tasks: Vec<GenChunkTask<'_>> = Vec::new();
            for (f, (batch, prep)) in batches.iter().zip(preps.iter_mut()).enumerate() {
                let Prep { adv, targets, bounds, perms, chunk_grads, chunk_stats, .. } = prep;
                let (lo, hi) = bounds[mb];
                if lo == hi {
                    continue;
                }
                let mb_len = hi - lo;
                let idxs = &perms[epoch][lo..hi];
                // Normalize advantages over the family's own minibatch
                // (matching the per-family oracle) — once, on the caller.
                let adv_mean = idxs.iter().map(|&i| adv[i]).sum::<f32>() / mb_len as f32;
                let var = idxs
                    .iter()
                    .map(|&i| {
                        let x = adv[i] - adv_mean;
                        x * x
                    })
                    .sum::<f32>()
                    / mb_len as f32;
                let adv_std = var.sqrt() + 1e-8;
                assert!(
                    mb_len.div_ceil(UPDATE_CHUNK_ROWS) <= chunk_grads.len(),
                    "family {f} minibatch {mb}: {} chunks but {} accumulators",
                    mb_len.div_ceil(UPDATE_CHUNK_ROWS),
                    chunk_grads.len()
                );
                for ((chunk, grads), stats) in idxs
                    .chunks(UPDATE_CHUNK_ROWS)
                    .zip(chunk_grads.iter_mut())
                    .zip(chunk_stats.iter_mut())
                {
                    tasks.push(GenChunkTask {
                        gen,
                        hp,
                        family: f,
                        idxs: chunk,
                        norm: round_len as f32,
                        adv_mean,
                        adv_std,
                        batch,
                        adv,
                        targets,
                        grads,
                        stats,
                    });
                }
            }
            run_gen_chunk_tasks(pool, &mut tasks, &mut scratch);
            drop(tasks);
            // Cross-family reduction: every chunk of the round in
            // family-major chunk order through ONE fixed-order tree, then
            // clip + ONE Adam step on the caller.
            let mut stat_counts: Vec<(usize, usize)> = Vec::new();
            {
                let mut used: Vec<&mut GenGrads> = Vec::new();
                for (f, prep) in preps.iter_mut().enumerate() {
                    let (lo, hi) = prep.bounds[mb];
                    if lo == hi {
                        continue;
                    }
                    let n_chunks = (hi - lo).div_ceil(UPDATE_CHUNK_ROWS);
                    for g in prep.chunk_grads[..n_chunks].iter_mut() {
                        used.push(g);
                    }
                    stat_counts.push((f, n_chunks));
                }
                {
                    let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Reduce);
                    tree_reduce(&mut used, |a, b| a.add_from(&**b));
                }
                let grads = &mut *used[0];
                let norm = grads.global_norm();
                if norm > hp.max_grad_norm {
                    grads.scale(hp.max_grad_norm / norm);
                }
                let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Adam);
                gen.apply_grads(grads, hp.lr);
            }
            // Per-family stats off each family's own chunk sub-range.
            for &(f, n_chunks) in &stat_counts {
                let prep = &mut preps[f];
                tree_reduce_stats(&mut prep.chunk_stats[..n_chunks]);
                let (lo, hi) = prep.bounds[mb];
                let mb_len = hi - lo;
                let (loss, ent) = prep.chunk_stats[0];
                prep.loss_acc += (loss / mb_len as f32) as f64;
                prep.ent_acc += (ent / mb_len as f32) as f64;
                prep.n_upd += 1;
            }
        }
    }
    preps
        .iter()
        .map(|p| {
            let n = p.n_upd.max(1) as f64;
            ((p.loss_acc / n) as f32, (p.ent_acc / n) as f32)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_gen(rng: &mut Rng) -> GeneralistLearner {
        GeneralistLearner::new(
            rng,
            7,
            16,
            &[(7, vec![4, 3]), (5, vec![3, 3, 2]), (6, vec![5])],
        )
    }

    /// Padded staging layout: obs block, zero padding, one-hot — and a
    /// dirty/oversized pad buffer is fully overwritten.
    #[test]
    fn stage_rows_layout_and_overwrite() {
        let mut rng = Rng::new(3);
        let gen = demo_gen(&mut rng);
        let k = gen.in_dim;
        assert_eq!(k, 7 + 3);
        let obs: Vec<f32> = (0..2 * 5).map(|i| i as f32 + 1.0).collect();
        let mut pad = vec![f32::NAN; 5 * k]; // stale, too big
        gen.stage_rows(1, &obs, 2, &mut pad);
        assert_eq!(pad.len(), 2 * k);
        for r in 0..2 {
            assert_eq!(&pad[r * k..r * k + 5], &obs[r * 5..(r + 1) * 5], "row {r} obs");
            assert!(pad[r * k + 5..r * k + 7].iter().all(|&x| x == 0.0), "row {r} padding");
            let onehot = &pad[r * k + 7..(r + 1) * k];
            assert_eq!(onehot, &[0.0, 1.0, 0.0], "row {r} one-hot");
        }
    }

    /// A block forward must match the `rows == 1` forward per row bitwise
    /// (the same kernel-layer invariant the per-family Mlp proves), across
    /// different families through the same scratch.
    #[test]
    fn forward_block_matches_single_row_bitwise() {
        let mut rng = Rng::new(11);
        let gen = demo_gen(&mut rng);
        let mut blk = gen.make_scratch();
        let mut row = gen.make_scratch();
        for f in 0..gen.n_families() {
            let d = gen.obs_dim(f);
            let nl = gen.n_logits(f);
            let n = 5usize;
            let obs: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            blk.logits.iter_mut().for_each(|x| *x = f32::NAN);
            gen.forward_block(f, &obs, n, &mut blk);
            for i in 0..n {
                gen.forward_block(f, &obs[i * d..(i + 1) * d], 1, &mut row);
                assert_eq!(
                    row.logits[..nl],
                    blk.logits[i * nl..(i + 1) * nl],
                    "family {f} row {i} logits"
                );
                assert_eq!(row.values[0], blk.values[i], "family {f} row {i} value");
            }
        }
    }

    /// Fused block sampling is a pure function of (weights, obs, seed,
    /// lane, t) and matches a hand-rolled forward + derive2 + heads.sample
    /// — the same contract as `Learner::sample_block`.
    #[test]
    fn sample_block_matches_components() {
        let mut rng = Rng::new(23);
        let gen = demo_gen(&mut rng);
        let (f, n, lane0, t, seed) = (1usize, 4usize, 3usize, 9usize, 0xFEEDu64);
        let d = gen.obs_dim(f);
        let p = gen.n_ports(f);
        let nl = gen.n_logits(f);
        let obs: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let mut blk = gen.make_scratch();
        let mut acts = vec![0usize; n * p];
        let mut logp = vec![0f32; n];
        let mut vals = vec![0f32; n];
        gen.sample_block(f, t, lane0, seed, &obs, &mut acts, &mut logp, &mut vals, &mut blk);
        let mut row = gen.make_scratch();
        for i in 0..n {
            gen.forward_block(f, &obs[i * d..(i + 1) * d], 1, &mut row);
            let mut crng = CounterRng::derive2(seed, (lane0 + i) as u64, t as u64);
            let mut a = vec![0usize; p];
            let lp = gen.families[f].heads.sample(&mut crng, &row.logits[..nl], &mut a);
            assert_eq!(a, acts[i * p..(i + 1) * p], "lane {i} actions");
            assert_eq!(lp, logp[i], "lane {i} logp");
            assert_eq!(row.values[0], vals[i], "lane {i} value");
        }
        // Greedy counterpart agrees with greedy_lane.
        let mut acts_g = vec![0usize; n * p];
        let mut vals_g = vec![0f32; n];
        gen.greedy_block(f, &obs, &mut acts_g, &mut vals_g, &mut blk);
        for i in 0..n {
            let mut a = vec![0usize; p];
            let v = gen.greedy_lane(f, &obs[i * d..(i + 1) * d], &mut a, &mut row);
            assert_eq!(a, acts_g[i * p..(i + 1) * p], "lane {i} greedy actions");
            assert_eq!(v, vals_g[i], "lane {i} greedy value");
        }
    }

    /// Finite-difference check of the padded backward pass: trunk, shared
    /// value head, and one family head — with the OTHER families' head
    /// grads provably untouched.
    #[test]
    fn backward_padded_matches_finite_difference() {
        let mut rng = Rng::new(31);
        let mut gen = demo_gen(&mut rng);
        let (f, b) = (1usize, 3usize);
        let d = gen.obs_dim(f);
        let nl = gen.n_logits(f);
        let obs: Vec<f32> = (0..b * d).map(|_| rng.normal()).collect();
        let cl: Vec<f32> = (0..b * nl).map(|_| rng.normal()).collect();
        let cv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let loss = |g: &GeneralistLearner| -> f32 {
            let mut s = g.make_scratch();
            g.forward_block(f, &obs, b, &mut s);
            s.logits[..b * nl].iter().zip(&cl).map(|(a, b)| a * b).sum::<f32>()
                + s.values[..b].iter().zip(&cv).map(|(a, b)| a * b).sum::<f32>()
        };
        let mut s = gen.make_scratch();
        gen.forward_block(f, &obs, b, &mut s);
        let mut g = gen.zero_grads();
        let (mut dh1, mut dh2) = (Vec::new(), Vec::new());
        gen.backward_padded(
            f, &s.pad, &s.h1, &s.h2, b, &cl, &cv, &mut g, &mut dh1, &mut dh2,
        );
        // Untouched families stay exactly zero.
        for other in [0usize, 2] {
            assert!(g.wpi[other].iter().all(|&x| x == 0.0), "family {other} wpi dirtied");
            assert!(g.bpi[other].iter().all(|&x| x == 0.0), "family {other} bpi dirtied");
        }
        fn nudge(gen: &mut GeneralistLearner, pi: usize, wi: usize, delta: f32) {
            let GeneralistLearner { w1, b1, w2, b2, wv, bv, families, .. } = gen;
            let mut params: Vec<&mut Vec<f32>> = vec![w1, b1, w2, b2, wv, bv];
            for fh in families.iter_mut() {
                params.push(&mut fh.wpi);
                params.push(&mut fh.bpi);
            }
            params[pi][wi] += delta;
        }
        let eps = 1e-3f32;
        // (tensor index in canonical order, weight index)
        let checks: Vec<(usize, usize)> = vec![(0, 3), (2, 17), (4, 5), (6 + 2 * f, 7), (5, 0)];
        let gref = g.as_slices();
        for (pi, wi) in checks {
            let analytic = gref[pi][wi];
            let orig = gen.params()[pi].clone();
            nudge(&mut gen, pi, wi, eps);
            let lp = loss(&gen);
            nudge(&mut gen, pi, wi, -2.0 * eps);
            let lm = loss(&gen);
            nudge(&mut gen, pi, wi, eps); // restore
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - analytic).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {pi}[{wi}]: fd {fd} vs analytic {analytic}"
            );
            // Restoration really restored the weights.
            assert_eq!(gen.params()[pi], &orig, "param {pi} not restored");
        }
    }

    /// The sharded generalist update without a pool is deterministic:
    /// two identically-seeded runs produce identical weight bits. (The
    /// pool-width invariance half lives in rust/tests/generalist.rs where
    /// a real fleet provides the pool.)
    #[test]
    fn update_is_deterministic_across_runs() {
        let run = || -> Vec<f32> {
            let mut rng = Rng::new(5);
            let mut gen = demo_gen(&mut rng);
            let hp = PpoParams {
                n_minibatches: 2,
                update_epochs: 2,
                ..Default::default()
            };
            let mut data_rng = Rng::new(77);
            let (t_len, n_envs) = (6usize, 4usize);
            let mut store: Vec<(Vec<f32>, Vec<usize>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> =
                Vec::new();
            for f in 0..gen.n_families() {
                let d = gen.obs_dim(f);
                let p = gen.n_ports(f);
                let bsz = t_len * n_envs;
                let obs: Vec<f32> = (0..(t_len + 1) * n_envs * d)
                    .map(|_| data_rng.normal() * 0.5)
                    .collect();
                let act: Vec<usize> = (0..bsz * p)
                    .enumerate()
                    .map(|(i, _)| {
                        let head = i % p;
                        (data_rng.below(gen.families[f].heads.nvec[head] as u32)) as usize
                    })
                    .collect();
                let logp: Vec<f32> = (0..bsz).map(|_| -data_rng.normal().abs()).collect();
                let val: Vec<f32> = (0..bsz).map(|_| data_rng.normal()).collect();
                let rew: Vec<f32> = (0..bsz).map(|_| data_rng.normal()).collect();
                let done: Vec<f32> = (0..bsz).map(|i| if i % 7 == 6 { 1.0 } else { 0.0 }).collect();
                store.push((obs, act, logp, val, rew, done));
            }
            let batches: Vec<UpdateBatch<'_>> = store
                .iter()
                .map(|(obs, act, logp, val, rew, done)| UpdateBatch {
                    n_envs,
                    t_len,
                    obs,
                    act,
                    logp,
                    val,
                    rew,
                    done,
                })
                .collect();
            let mut urng = Rng::new(99);
            let stats = update_generalist_sharded(&mut gen, &hp, &mut urng, None, &batches);
            assert_eq!(stats.len(), gen.n_families());
            gen.params().into_iter().flat_map(|p| p.iter().copied()).collect()
        };
        assert_eq!(run(), run());
    }
}
