//! Parameter-free controllers over the scalar simulator: the paper's
//! always-charge-max baseline (Fig. 4a), a random policy (Table 2 "Random"
//! row), and a price-threshold heuristic (ablation).

use crate::env::scalar::{ScalarEnv, StepInfo, N_LEVELS, N_LEVELS_BATTERY};
use crate::util::rng::Rng;

pub trait Policy {
    fn act(&mut self, env: &ScalarEnv, action: &mut [usize]);
    fn name(&self) -> &'static str;
}

/// Paper Fig. 4a baseline: every occupied port at 100%, battery idle.
pub struct MaxCharge;

impl Policy for MaxCharge {
    fn act(&mut self, env: &ScalarEnv, action: &mut [usize]) {
        let c = env.cfg().n_chargers();
        for (j, a) in action.iter_mut().enumerate().take(c) {
            *a = if env.occupied(j) { N_LEVELS - 1 } else { 0 };
        }
        action[c] = (N_LEVELS_BATTERY - 1) / 2; // zero current
    }

    fn name(&self) -> &'static str {
        "max_charge"
    }
}

/// Uniform random action per port.
pub struct RandomPolicy {
    pub rng: Rng,
}

impl Policy for RandomPolicy {
    fn act(&mut self, env: &ScalarEnv, action: &mut [usize]) {
        let c = env.cfg().n_chargers();
        for (j, a) in action.iter_mut().enumerate() {
            let n = if j < c { N_LEVELS } else { N_LEVELS_BATTERY };
            *a = self.rng.below(n as u32) as usize;
        }
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Heuristic: charge hard when the buy price is below its running mean,
/// throttle when above; battery buys low / sells high (ablation baseline).
pub struct PriceThreshold {
    price_sum: f64,
    price_n: u64,
}

impl Default for PriceThreshold {
    fn default() -> Self {
        PriceThreshold { price_sum: 0.0, price_n: 0 }
    }
}

impl Policy for PriceThreshold {
    fn act(&mut self, env: &ScalarEnv, action: &mut [usize]) {
        let c = env.cfg().n_chargers();
        let hour = (env.t() / crate::env::scalar::STEPS_PER_HOUR).min(23);
        let price = env.tables().price_buy[env.day() * 24 + hour];
        self.price_sum += price as f64;
        self.price_n += 1;
        let mean = (self.price_sum / self.price_n as f64) as f32;
        let cheap = price <= mean;
        for (j, a) in action.iter_mut().enumerate().take(c) {
            *a = match (env.occupied(j), cheap) {
                (false, _) => 0,
                (true, true) => N_LEVELS - 1,
                // still serve customers, at reduced rate, when expensive
                (true, false) => (N_LEVELS - 1) / 2,
            };
        }
        let mid = (N_LEVELS_BATTERY - 1) / 2;
        action[c] = if cheap { N_LEVELS_BATTERY - 1 } else { mid / 2 };
    }

    fn name(&self) -> &'static str {
        "price_threshold"
    }
}

/// Roll a policy for `steps` env steps; returns per-step infos summary.
pub struct RolloutSummary {
    pub steps: usize,
    pub mean_reward: f64,
    pub mean_profit: f64,
    pub total_missing_kwh: f64,
    pub total_overtime_steps: f64,
    pub total_rejected: f64,
    pub episodes: usize,
    pub mean_episode_return: f64,
}

pub fn rollout(env: &mut ScalarEnv, policy: &mut dyn Policy, steps: usize) -> RolloutSummary {
    let n_ports = env.n_ports();
    let mut action = vec![0usize; n_ports];
    // An RL loop consumes an observation every step; build it so the
    // comparator pays the same per-step cost the paper's gym envs do.
    let mut obs = vec![0f32; env.obs_dim()];
    let mut sum_r = 0f64;
    let mut sum_p = 0f64;
    let mut missing = 0f64;
    let mut overtime = 0f64;
    let mut rejected = 0f64;
    let mut episodes = 0usize;
    let mut ep_returns = 0f64;
    for _ in 0..steps {
        env.observe(&mut obs);
        policy.act(env, &mut action);
        let prev_return = env.ep_return();
        let info: StepInfo = env.step(&action);
        sum_r += info.reward as f64;
        sum_p += info.profit as f64;
        missing += info.missing_kwh as f64;
        overtime += info.overtime_steps as f64;
        rejected += info.rejected as f64;
        if info.done {
            episodes += 1;
            ep_returns += (prev_return + info.reward) as f64;
        }
    }
    RolloutSummary {
        steps,
        mean_reward: sum_r / steps as f64,
        mean_profit: sum_p / steps as f64,
        total_missing_kwh: missing,
        total_overtime_steps: overtime,
        total_rejected: rejected,
        episodes,
        mean_episode_return: if episodes > 0 { ep_returns / episodes as f64 } else { 0.0 },
    }
}
