//! CPU comparators (DESIGN.md §Substitutions): a pure-Rust PPO + heuristic
//! policies over the scalar simulator, standing in for the paper's
//! SB3-on-CPU-gym baseline rows in Table 2 / Fig. 1.

pub mod generalist;
pub mod kernels;
pub mod mlp;
pub mod policies;
pub mod ppo;
