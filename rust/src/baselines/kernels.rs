//! Cache-blocked, fixed-width-unrolled f32 kernels for the MLP hot paths
//! (ISSUE 6): GEMM-with-bias, strided dot product (GEMV building block),
//! outer-product gradient accumulation, and column sums. Plain stable
//! Rust — the fixed-width inner loops over `[f32; NR]` register tiles are
//! written so LLVM's autovectorizer turns them into SIMD `mul_add`/`add`
//! lanes (verified shapes: 8-wide f32 with AVX/FMA under
//! `-C target-cpu=native`, 4-wide under baseline SSE2).
//!
//! # Tile / unroll widths
//!
//! * [`NR`] = 8 — the column-tile width of [`gemm_bias`] and the unroll
//!   width of [`outer_acc`]'s row axis.
//! * [`MR`] = 4 — rows of `a` processed per register tile in
//!   [`gemm_bias`] (a 4x8 `f32` accumulator block = 8 SSE / 4 AVX
//!   registers, leaving room for the `a` broadcasts and `w` loads).
//! * [`DOT_LANES`] = 8 — the number of striped partial accumulators in
//!   [`dot8`], combined by a fixed pairwise tree.
//!
//! The matrices here are small (hidden <= a few hundred), so "cache
//! blocking" is the register tiling itself: one `w` row tile is loaded
//! per `k` step and shared across all `MR` rows, and every operand of a
//! tile pass fits in L1 for the shapes the MLP uses.
//!
//! # Determinism contract
//!
//! Per output element, the floating-point accumulation order is a pure
//! function of the reduction length and the constants above — NEVER of
//! the row count, how rows are blocked, or `--threads`:
//!
//! * [`gemm_bias`] accumulates each `out[i][j]` into a single
//!   accumulator in ascending-`k` order, whether the row went through
//!   the 4-row tile, the 1-row remainder, or a different row blocking
//!   entirely. A B-row GEMM therefore produces bit-identical rows to B
//!   single-row calls — this is what lets shard tasks forward their
//!   whole lane range as one block (ISSUE 6) without perturbing the
//!   serial == sharded bitwise contract.
//! * [`dot8`] stripes element `k` into partial accumulator `k % 8` and
//!   combines the 8 partials with a fixed pairwise tree, so its order is
//!   a function of the input length alone.
//! * [`outer_acc`] / [`colsum_acc`] accumulate in ascending row order
//!   per element (the PPO update's fixed 64-row chunking, combined with
//!   the fixed-order chunk reduction tree in `ppo.rs`, keeps the update
//!   thread-invariant on top of that).
//!
//! All kernels round through [`fmadd`], which compiles to a fused
//! multiply-add when the build target has one (e.g.
//! `RUSTFLAGS="-C target-cpu=native"` on x86-64 with FMA) and to
//! separate multiply+add otherwise — `f32::mul_add` without hardware FMA
//! lowers to a libm call, which is both slow and needlessly
//! double-rounded-differently. Numerics may therefore differ ACROSS
//! build targets, but never across `--threads` within one binary. (This
//! also intentionally drifts PPO numerics vs the PR 5 scalar loops —
//! see README "Kernel layer".)

/// Column-tile width of [`gemm_bias`] / unroll width of [`outer_acc`].
pub const NR: usize = 8;
/// Row-tile height of [`gemm_bias`].
pub const MR: usize = 4;
/// Striped partial-accumulator count of [`dot8`].
pub const DOT_LANES: usize = 8;

/// `a * b + acc` with one rounding when the build target has hardware
/// FMA, two otherwise. Every kernel (and the test references) round
/// through this one function, so kernel-vs-reference equality is exact
/// on every build target.
#[inline(always)]
pub fn fmadd(a: f32, b: f32, acc: f32) -> f32 {
    #[cfg(target_feature = "fma")]
    {
        a.mul_add(b, acc)
    }
    #[cfg(not(target_feature = "fma"))]
    {
        acc + a * b
    }
}

/// `out = a @ w + bias` — `a: [rows, k_dim]`, `w: [k_dim, j_dim]`,
/// `out: [rows, j_dim]`, all row-major. Register-tiled `MR x NR`; each
/// `out[i][j]` is one accumulator filled in ascending-`k` order, so any
/// row blocking of `a` yields bit-identical rows (see module docs).
pub fn gemm_bias(
    a: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    k_dim: usize,
    j_dim: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), rows * k_dim, "gemm_bias: a shape");
    assert_eq!(w.len(), k_dim * j_dim, "gemm_bias: w shape");
    assert_eq!(bias.len(), j_dim, "gemm_bias: bias shape");
    assert_eq!(out.len(), rows * j_dim, "gemm_bias: out shape");
    let mut i = 0;
    while i + MR <= rows {
        gemm_rows::<MR>(
            &a[i * k_dim..(i + MR) * k_dim],
            w,
            bias,
            k_dim,
            j_dim,
            &mut out[i * j_dim..(i + MR) * j_dim],
        );
        i += MR;
    }
    while i < rows {
        gemm_rows::<1>(
            &a[i * k_dim..(i + 1) * k_dim],
            w,
            bias,
            k_dim,
            j_dim,
            &mut out[i * j_dim..(i + 1) * j_dim],
        );
        i += 1;
    }
}

/// `R`-row micro-kernel of [`gemm_bias`]: an `R x NR` accumulator tile
/// swept over `k`, then a scalar column tail with the identical
/// per-element order.
fn gemm_rows<const R: usize>(
    a: &[f32],
    w: &[f32],
    bias: &[f32],
    k_dim: usize,
    j_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), R * k_dim);
    debug_assert_eq!(out.len(), R * j_dim);
    let j_main = j_dim - j_dim % NR;
    let mut jt = 0;
    while jt < j_main {
        let mut acc = [[0f32; NR]; R];
        for row in acc.iter_mut() {
            row.copy_from_slice(&bias[jt..jt + NR]);
        }
        for kk in 0..k_dim {
            let wrow = &w[kk * j_dim + jt..kk * j_dim + jt + NR];
            for (r, row) in acc.iter_mut().enumerate() {
                let av = a[r * k_dim + kk];
                for u in 0..NR {
                    row[u] = fmadd(av, wrow[u], row[u]);
                }
            }
        }
        for (r, row) in acc.iter().enumerate() {
            out[r * j_dim + jt..r * j_dim + jt + NR].copy_from_slice(row);
        }
        jt += NR;
    }
    for jj in j_main..j_dim {
        for r in 0..R {
            let mut acc = bias[jj];
            for kk in 0..k_dim {
                acc = fmadd(a[r * k_dim + kk], w[kk * j_dim + jj], acc);
            }
            out[r * j_dim + jj] = acc;
        }
    }
}

/// Dot product with [`DOT_LANES`] striped partial accumulators
/// (element `k` lands in partial `k % DOT_LANES`) combined by a fixed
/// pairwise tree — the GEMV building block for the value head and the
/// `d @ W^T` backward projections. Accumulation order is a function of
/// `a.len()` alone.
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot8: length mismatch");
    let n = a.len();
    let main = n - n % DOT_LANES;
    let mut acc = [0f32; DOT_LANES];
    let (ah, at) = a.split_at(main);
    let (bh, bt) = b.split_at(main);
    for (ac, bc) in ah.chunks_exact(DOT_LANES).zip(bh.chunks_exact(DOT_LANES)) {
        for u in 0..DOT_LANES {
            acc[u] = fmadd(ac[u], bc[u], acc[u]);
        }
    }
    for (u, (&av, &bv)) in at.iter().zip(bt).enumerate() {
        acc[u] = fmadd(av, bv, acc[u]);
    }
    let mut stride = DOT_LANES / 2;
    while stride > 0 {
        for u in 0..stride {
            acc[u] += acc[u + stride];
        }
        stride /= 2;
    }
    acc[0]
}

/// `gw[k][j] += sum_i a[i][k] * d[i][j]` (ascending `i` per element) —
/// the weight-gradient outer-product accumulation. `gw: [k_dim, j_dim]`.
pub fn outer_acc(
    a: &[f32],
    d: &[f32],
    rows: usize,
    k_dim: usize,
    j_dim: usize,
    gw: &mut [f32],
) {
    assert_eq!(a.len(), rows * k_dim, "outer_acc: a shape");
    assert_eq!(d.len(), rows * j_dim, "outer_acc: d shape");
    assert_eq!(gw.len(), k_dim * j_dim, "outer_acc: gw shape");
    let j_main = j_dim - j_dim % NR;
    for i in 0..rows {
        let arow = &a[i * k_dim..(i + 1) * k_dim];
        let drow = &d[i * j_dim..(i + 1) * j_dim];
        for (kk, &av) in arow.iter().enumerate() {
            // Exact-zero activations (common in the sparse obs layout) are
            // skipped: with a +0-initialized accumulator, adding `0 * d`
            // can never flip a bit for finite `d` (proven exactly against
            // the skip-free reference in the tests below).
            if av == 0.0 {
                continue;
            }
            let grow = &mut gw[kk * j_dim..(kk + 1) * j_dim];
            let (gh, gt) = grow.split_at_mut(j_main);
            let (dh, dt) = drow.split_at(j_main);
            for (gc, dc) in gh.chunks_exact_mut(NR).zip(dh.chunks_exact(NR)) {
                for u in 0..NR {
                    gc[u] = fmadd(av, dc[u], gc[u]);
                }
            }
            for (g, &dv) in gt.iter_mut().zip(dt) {
                *g = fmadd(av, dv, *g);
            }
        }
    }
}

/// `gb[j] += sum_i d[i][j]` (ascending `i` per element) — bias-gradient
/// column sums.
pub fn colsum_acc(d: &[f32], rows: usize, j_dim: usize, gb: &mut [f32]) {
    assert_eq!(d.len(), rows * j_dim, "colsum_acc: d shape");
    assert_eq!(gb.len(), j_dim, "colsum_acc: gb shape");
    for i in 0..rows {
        let drow = &d[i * j_dim..(i + 1) * j_dim];
        for (g, &dv) in gb.iter_mut().zip(drow) {
            *g += dv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // ---- scalar references ------------------------------------------------
    // Naive per-element loops written independently of the blocked kernels
    // but rounding through the same `fmadd`, so every comparison below is
    // EXACT (bitwise) on every build target — no tolerance needed.

    fn ref_gemm_bias(
        a: &[f32],
        w: &[f32],
        bias: &[f32],
        rows: usize,
        k_dim: usize,
        j_dim: usize,
        out: &mut [f32],
    ) {
        for i in 0..rows {
            for j in 0..j_dim {
                let mut acc = bias[j];
                for k in 0..k_dim {
                    acc = fmadd(a[i * k_dim + k], w[k * j_dim + j], acc);
                }
                out[i * j_dim + j] = acc;
            }
        }
    }

    /// Index-based re-derivation of the stripe + pairwise-tree order.
    fn ref_dot8(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = [0f32; DOT_LANES];
        for k in 0..a.len() {
            // Stripe k % 8 within the 8-aligned head; the tail restarts at
            // stripe 0 (identical to dot8's enumerate over the remainder).
            let main = a.len() - a.len() % DOT_LANES;
            let u = if k < main { k % DOT_LANES } else { k - main };
            acc[u] = fmadd(a[k], b[k], acc[u]);
        }
        ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]))
    }

    /// Skip-free outer product: proves `outer_acc`'s zero-skip is a
    /// bitwise no-op, not just an approximation.
    fn ref_outer_acc(
        a: &[f32],
        d: &[f32],
        rows: usize,
        k_dim: usize,
        j_dim: usize,
        gw: &mut [f32],
    ) {
        for i in 0..rows {
            for k in 0..k_dim {
                for j in 0..j_dim {
                    gw[k * j_dim + j] =
                        fmadd(a[i * k_dim + k], d[i * j_dim + j], gw[k * j_dim + j]);
                }
            }
        }
    }

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Shapes chosen to hit every code path: 1-row and 4-row tiles, row
    /// remainders 1..3, full NR column tiles, and column tails 1..7.
    const SHAPES: [(usize, usize, usize); 8] = [
        (1, 1, 1),
        (3, 5, 7),
        (4, 8, 8),
        (5, 8, 9),
        (7, 13, 16),
        (8, 16, 23),
        (9, 6, 1),
        (13, 24, 40),
    ];

    #[test]
    fn gemm_bias_matches_scalar_reference_bitwise() {
        let mut rng = Rng::new(101);
        for &(rows, k, j) in &SHAPES {
            let a = randv(&mut rng, rows * k);
            let w = randv(&mut rng, k * j);
            let bias = randv(&mut rng, j);
            let mut got = vec![f32::NAN; rows * j];
            let mut want = vec![0f32; rows * j];
            gemm_bias(&a, &w, &bias, rows, k, j, &mut got);
            ref_gemm_bias(&a, &w, &bias, rows, k, j, &mut want);
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "gemm {rows}x{k}x{j}"
            );
        }
    }

    /// The load-bearing invariant behind lane-blocked shard inference: a
    /// B-row GEMM equals B single-row GEMMs AND any contiguous sub-block,
    /// bitwise.
    #[test]
    fn gemm_bias_is_row_blocking_invariant() {
        let mut rng = Rng::new(102);
        let (rows, k, j) = (11usize, 17usize, 12usize);
        let a = randv(&mut rng, rows * k);
        let w = randv(&mut rng, k * j);
        let bias = randv(&mut rng, j);
        let mut full = vec![0f32; rows * j];
        gemm_bias(&a, &w, &bias, rows, k, j, &mut full);
        for i in 0..rows {
            let mut one = vec![f32::NAN; j];
            gemm_bias(&a[i * k..(i + 1) * k], &w, &bias, 1, k, j, &mut one);
            assert_eq!(one, full[i * j..(i + 1) * j], "row {i} vs full batch");
        }
        for (lo, hi) in [(0usize, 3usize), (2, 9), (5, 11), (3, 4)] {
            let mut part = vec![f32::NAN; (hi - lo) * j];
            gemm_bias(&a[lo * k..hi * k], &w, &bias, hi - lo, k, j, &mut part);
            assert_eq!(part, full[lo * j..hi * j], "block {lo}..{hi} vs full batch");
        }
    }

    #[test]
    fn dot8_matches_stripe_reference_bitwise_and_f64_closely() {
        let mut rng = Rng::new(103);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let got = dot8(&a, &b);
            assert_eq!(got.to_bits(), ref_dot8(&a, &b).to_bits(), "n={n} vs stripe reference");
            let wide: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!(
                (got as f64 - wide).abs() <= 1e-4 * (1.0 + wide.abs()),
                "n={n}: dot8 {got} vs f64 {wide}"
            );
        }
    }

    #[test]
    fn outer_acc_matches_skip_free_reference_bitwise() {
        let mut rng = Rng::new(104);
        for &(rows, k, j) in &SHAPES {
            let mut a = randv(&mut rng, rows * k);
            // Force exact zeros so the skip path is exercised.
            for (idx, x) in a.iter_mut().enumerate() {
                if idx % 3 == 0 {
                    *x = 0.0;
                }
            }
            let d = randv(&mut rng, rows * j);
            let mut got = vec![0f32; k * j];
            let mut want = vec![0f32; k * j];
            outer_acc(&a, &d, rows, k, j, &mut got);
            ref_outer_acc(&a, &d, rows, k, j, &mut want);
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "outer {rows}x{k}x{j}"
            );
        }
    }

    #[test]
    fn outer_and_colsum_accumulate_instead_of_overwrite() {
        let a = [1.0f32, 2.0];
        let d = [3.0f32];
        let mut gw = vec![10.0f32, 20.0];
        outer_acc(&a, &d, 1, 2, 1, &mut gw);
        assert_eq!(gw, vec![13.0, 26.0]);
        let mut gb = vec![5.0f32];
        colsum_acc(&d, 1, 1, &mut gb);
        assert_eq!(gb, vec![8.0]);
    }

    #[test]
    fn colsum_matches_naive_reference_bitwise() {
        let mut rng = Rng::new(105);
        for &(rows, _, j) in &SHAPES {
            let d = randv(&mut rng, rows * j);
            let mut got = vec![0f32; j];
            colsum_acc(&d, rows, j, &mut got);
            let mut want = vec![0f32; j];
            for i in 0..rows {
                for jj in 0..j {
                    want[jj] += d[i * j + jj];
                }
            }
            assert_eq!(got, want, "colsum {rows}x{j}");
        }
    }
}
