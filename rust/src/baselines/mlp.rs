//! Dense actor-critic MLP with hand-written backprop — the network for the
//! pure-Rust PPO comparator (mirrors python/compile/networks.py: tanh torso,
//! concatenated categorical heads, scalar value head).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Mlp {
    pub obs_dim: usize,
    pub hidden: usize,
    pub n_logits: usize,
    // weights (row-major [in][out])
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub wpi: Vec<f32>,
    pub bpi: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
}

/// Gradients, same layout as Mlp weights.
#[derive(Debug, Clone)]
pub struct Grads {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub wpi: Vec<f32>,
    pub bpi: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
}

/// Forward-pass activations kept for backprop.
pub struct Cache {
    pub batch: usize,
    pub obs: Vec<f32>, // [B, obs_dim]
    pub h1: Vec<f32>,  // [B, hidden] (post-tanh)
    pub h2: Vec<f32>,  // [B, hidden]
    pub logits: Vec<f32>, // [B, n_logits]
    pub value: Vec<f32>,  // [B]
}

impl Cache {
    /// An empty cache for [`Mlp::forward_reuse`] callers: fill `obs` +
    /// `batch`, then forward into it repeatedly without reallocation.
    pub fn empty() -> Cache {
        Cache {
            batch: 0,
            obs: Vec::new(),
            h1: Vec::new(),
            h2: Vec::new(),
            logits: Vec::new(),
            value: Vec::new(),
        }
    }
}

/// Reusable backward-pass temporaries (`dh1`/`dh2`), so the sharded PPO
/// update's per-chunk backprops allocate nothing after warmup.
#[derive(Default)]
pub struct BackwardScratch {
    dh1: Vec<f32>,
    dh2: Vec<f32>,
}

impl BackwardScratch {
    pub fn new() -> BackwardScratch {
        BackwardScratch::default()
    }
}

/// Reusable single-row forward scratch: hidden activations + logits for
/// exactly one observation row. Pool shards each own one and reuse it for
/// every (lane, step) they forward, so the fused rollout's policy path
/// does no per-step allocation (unlike [`Mlp::forward`], which builds a
/// fresh [`Cache`] per call for backprop).
#[derive(Debug, Clone)]
pub struct MlpScratch {
    pub h1: Vec<f32>,
    pub h2: Vec<f32>,
    pub logits: Vec<f32>,
    pub value: f32,
}

impl Mlp {
    pub fn new(rng: &mut Rng, obs_dim: usize, hidden: usize, n_logits: usize) -> Mlp {
        // He-ish scaled normal init (orthogonal init is overkill here; the
        // comparator only needs to learn, not match the JAX agent exactly).
        let init = |rng: &mut Rng, rows: usize, cols: usize, scale: f32| -> Vec<f32> {
            let s = scale / (rows as f32).sqrt();
            (0..rows * cols).map(|_| rng.normal() * s).collect()
        };
        Mlp {
            obs_dim,
            hidden,
            n_logits,
            w1: init(rng, obs_dim, hidden, 1.4),
            b1: vec![0.0; hidden],
            w2: init(rng, hidden, hidden, 1.4),
            b2: vec![0.0; hidden],
            wpi: init(rng, hidden, n_logits, 0.01),
            bpi: vec![0.0; n_logits],
            wv: init(rng, hidden, 1, 1.0),
            bv: vec![0.0; 1],
        }
    }

    pub fn zero_grads(&self) -> Grads {
        Grads {
            w1: vec![0.0; self.w1.len()],
            b1: vec![0.0; self.b1.len()],
            w2: vec![0.0; self.w2.len()],
            b2: vec![0.0; self.b2.len()],
            wpi: vec![0.0; self.wpi.len()],
            bpi: vec![0.0; self.bpi.len()],
            wv: vec![0.0; self.wv.len()],
            bv: vec![0.0; self.bv.len()],
        }
    }

    /// Batched forward: obs [B * obs_dim] row-major.
    pub fn forward(&self, obs: &[f32]) -> Cache {
        let mut cache = Cache::empty();
        cache.batch = obs.len() / self.obs_dim;
        cache.obs = obs.to_vec();
        self.forward_reuse(&mut cache);
        cache
    }

    /// Batched forward reusing caller-owned cache buffers: `cache.obs`
    /// must already hold the `[batch * obs_dim]` input rows and
    /// `cache.batch` the row count; the remaining buffers are resized and
    /// fully overwritten. This is the allocation-free (after warmup) entry
    /// point the sharded PPO update's chunk passes run on — per-row
    /// results are bit-identical to [`Mlp::forward`] (it delegates here).
    pub fn forward_reuse(&self, cache: &mut Cache) {
        let b = cache.batch;
        debug_assert_eq!(cache.obs.len(), b * self.obs_dim);
        cache.h1.resize(b * self.hidden, 0.0);
        matmul_bias(&cache.obs, &self.w1, &self.b1, b, self.obs_dim, self.hidden, &mut cache.h1);
        cache.h1.iter_mut().for_each(|x| *x = x.tanh());
        cache.h2.resize(b * self.hidden, 0.0);
        matmul_bias(&cache.h1, &self.w2, &self.b2, b, self.hidden, self.hidden, &mut cache.h2);
        cache.h2.iter_mut().for_each(|x| *x = x.tanh());
        cache.logits.resize(b * self.n_logits, 0.0);
        let (h, nl) = (self.hidden, self.n_logits);
        matmul_bias(&cache.h2, &self.wpi, &self.bpi, b, h, nl, &mut cache.logits);
        cache.value.resize(b, 0.0);
        for i in 0..b {
            let mut v = self.bv[0];
            for k in 0..self.hidden {
                v += cache.h2[i * self.hidden + k] * self.wv[k];
            }
            cache.value[i] = v;
        }
    }

    /// Scratch sized for this network's single-row forward.
    pub fn make_scratch(&self) -> MlpScratch {
        MlpScratch {
            h1: vec![0.0; self.hidden],
            h2: vec![0.0; self.hidden],
            logits: vec![0.0; self.n_logits],
            value: 0.0,
        }
    }

    /// Single-row forward into caller-owned scratch: `&self` (weights are
    /// read-only, so many shards may call it concurrently) and zero
    /// allocation. Bit-identical to the corresponding row of the batched
    /// [`Mlp::forward`] — same accumulation order per row.
    pub fn forward_row(&self, obs: &[f32], s: &mut MlpScratch) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        matmul_bias(obs, &self.w1, &self.b1, 1, self.obs_dim, self.hidden, &mut s.h1);
        s.h1.iter_mut().for_each(|x| *x = x.tanh());
        matmul_bias(&s.h1, &self.w2, &self.b2, 1, self.hidden, self.hidden, &mut s.h2);
        s.h2.iter_mut().for_each(|x| *x = x.tanh());
        matmul_bias(&s.h2, &self.wpi, &self.bpi, 1, self.hidden, self.n_logits, &mut s.logits);
        let mut v = self.bv[0];
        for k in 0..self.hidden {
            v += s.h2[k] * self.wv[k];
        }
        s.value = v;
    }

    /// Backprop from (dlogits [B, n_logits], dvalue [B]) into grads.
    pub fn backward(&self, cache: &Cache, dlogits: &[f32], dvalue: &[f32], g: &mut Grads) {
        self.backward_scratch(cache, dlogits, dvalue, g, &mut BackwardScratch::new());
    }

    /// [`Mlp::backward`] with caller-owned `dh1`/`dh2` temporaries —
    /// allocation-free after warmup, bit-identical results (the default
    /// entry point delegates here). Gradients ACCUMULATE into `g` in row
    /// order; zero it first for a fresh pass.
    pub fn backward_scratch(
        &self,
        cache: &Cache,
        dlogits: &[f32],
        dvalue: &[f32],
        g: &mut Grads,
        s: &mut BackwardScratch,
    ) {
        let b = cache.batch;
        let h = self.hidden;
        // dh2 = dlogits @ wpi^T + dvalue * wv^T
        s.dh2.resize(b * h, 0.0);
        let dh2 = &mut s.dh2;
        for i in 0..b {
            for k in 0..h {
                let mut acc = dvalue[i] * self.wv[k];
                let row = &self.wpi[k * self.n_logits..(k + 1) * self.n_logits];
                let dl = &dlogits[i * self.n_logits..(i + 1) * self.n_logits];
                for (w, d) in row.iter().zip(dl) {
                    acc += w * d;
                }
                dh2[i * h + k] = acc;
            }
        }
        // grads of heads
        accum_matmul_t(&cache.h2, dlogits, b, h, self.n_logits, &mut g.wpi);
        accum_colsum(dlogits, b, self.n_logits, &mut g.bpi);
        for i in 0..b {
            for k in 0..h {
                g.wv[k] += cache.h2[i * h + k] * dvalue[i];
            }
            g.bv[0] += dvalue[i];
        }
        // through tanh of h2
        for i in 0..b * h {
            dh2[i] *= 1.0 - cache.h2[i] * cache.h2[i];
        }
        // dh1 = dh2 @ w2^T
        s.dh1.resize(b * h, 0.0);
        let dh1 = &mut s.dh1;
        for i in 0..b {
            for k in 0..h {
                let mut acc = 0f32;
                let row = &self.w2[k * h..(k + 1) * h];
                let dd = &dh2[i * h..(i + 1) * h];
                for (w, d) in row.iter().zip(dd) {
                    acc += w * d;
                }
                dh1[i * h + k] = acc;
            }
        }
        accum_matmul_t(&cache.h1, &dh2, b, h, h, &mut g.w2);
        accum_colsum(&dh2, b, h, &mut g.b2);
        for i in 0..b * h {
            dh1[i] *= 1.0 - cache.h1[i] * cache.h1[i];
        }
        accum_matmul_t(&cache.obs, &dh1, b, self.obs_dim, h, &mut g.w1);
        accum_colsum(&dh1, b, h, &mut g.b1);
    }

    /// The parameter tensors in canonical order (same order as
    /// [`Mlp::params_mut`] / [`Grads::as_slices`] — the reduction and
    /// Adam all zip over this order).
    pub fn params(&self) -> Vec<&Vec<f32>> {
        vec![
            &self.w1, &self.b1, &self.w2, &self.b2,
            &self.wpi, &self.bpi, &self.wv, &self.bv,
        ]
    }

    pub fn params_mut(&mut self) -> Vec<&mut Vec<f32>> {
        vec![
            &mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2,
            &mut self.wpi, &mut self.bpi, &mut self.wv, &mut self.bv,
        ]
    }

    pub fn n_params(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len()
            + self.wpi.len() + self.bpi.len() + self.wv.len() + self.bv.len()
    }
}

impl Grads {
    pub fn as_slices_mut(&mut self) -> Vec<&mut Vec<f32>> {
        vec![
            &mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2,
            &mut self.wpi, &mut self.bpi, &mut self.wv, &mut self.bv,
        ]
    }

    pub fn as_slices(&self) -> Vec<&Vec<f32>> {
        vec![
            &self.w1, &self.b1, &self.w2, &self.b2,
            &self.wpi, &self.bpi, &self.wv, &self.bv,
        ]
    }

    /// Reset every gradient to zero in place (per-chunk accumulators are
    /// reused across minibatches instead of reallocated).
    pub fn zero(&mut self) {
        for v in self.as_slices_mut() {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// `self += other`, element-wise in a fixed (field, index) order — the
    /// combine step of the sharded update's deterministic gradient
    /// reduction. Both operands must come from the same network shape.
    pub fn add_from(&mut self, other: &Grads) {
        for (a, b) in self.as_slices_mut().into_iter().zip(other.as_slices()) {
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x += y;
            }
        }
    }

    pub fn global_norm(&self) -> f32 {
        let sq: f32 = self
            .as_slices()
            .iter()
            .map(|v| v.iter().map(|x| x * x).sum::<f32>())
            .sum();
        sq.sqrt()
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.as_slices_mut() {
            v.iter_mut().for_each(|x| *x *= s);
        }
    }
}

/// out[i][j] = sum_k a[i][k] w[k][j] + bias[j]  (a: [B,K], w: [K,J])
fn matmul_bias(a: &[f32], w: &[f32], bias: &[f32], b: usize, k_dim: usize, j_dim: usize, out: &mut [f32]) {
    for i in 0..b {
        let orow = &mut out[i * j_dim..(i + 1) * j_dim];
        orow.copy_from_slice(bias);
        let arow = &a[i * k_dim..(i + 1) * k_dim];
        for (k, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let wrow = &w[k * j_dim..(k + 1) * j_dim];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += av * wv;
            }
        }
    }
}

/// gw[k][j] += sum_i a[i][k] d[i][j]
fn accum_matmul_t(a: &[f32], d: &[f32], b: usize, k_dim: usize, j_dim: usize, gw: &mut [f32]) {
    for i in 0..b {
        let arow = &a[i * k_dim..(i + 1) * k_dim];
        let drow = &d[i * j_dim..(i + 1) * j_dim];
        for (k, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let grow = &mut gw[k * j_dim..(k + 1) * j_dim];
            for (g, &dv) in grow.iter_mut().zip(drow) {
                *g += av * dv;
            }
        }
    }
}

fn accum_colsum(d: &[f32], b: usize, j_dim: usize, gb: &mut [f32]) {
    for i in 0..b {
        for j in 0..j_dim {
            gb[j] += d[i * j_dim + j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of the full backward pass.
    #[test]
    fn backprop_matches_finite_difference() {
        let mut rng = Rng::new(11);
        let (od, h, nl, b) = (5, 8, 6, 3);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
        // loss = sum(logits * cl) + sum(value * cv) for fixed random c's
        let cl: Vec<f32> = (0..b * nl).map(|_| rng.normal()).collect();
        let cv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let loss = |m: &Mlp| -> f32 {
            let c = m.forward(&obs);
            c.logits.iter().zip(&cl).map(|(a, b)| a * b).sum::<f32>()
                + c.value.iter().zip(&cv).map(|(a, b)| a * b).sum::<f32>()
        };
        let cache = mlp.forward(&obs);
        let mut g = mlp.zero_grads();
        mlp.backward(&cache, &cl, &cv, &mut g);

        let eps = 1e-3f32;
        // probe a few weights in each matrix
        let checks: Vec<(usize, usize)> = vec![(0, 3), (1, 0), (2, 17), (4, 5), (6, 2)];
        for (pi, wi) in checks {
            let mut mp = mlp.clone();
            mp.params_mut()[pi][wi] += eps;
            let lp = loss(&mp);
            let mut mm = mlp.clone();
            mm.params_mut()[pi][wi] -= eps;
            let lm = loss(&mm);
            let fd = (lp - lm) / (2.0 * eps);
            let mut gref = g.clone();
            let an = gref.as_slices_mut()[pi][wi];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {pi}[{wi}]: fd {fd} vs analytic {an}"
            );
        }
    }

    /// The scratch-buffer single-row forward must match the batched
    /// forward bit-for-bit (the fused-rollout invariance tests depend on
    /// shard-side forwards agreeing with the batched reference exactly).
    #[test]
    fn forward_row_matches_batched_forward_bitwise() {
        let mut rng = Rng::new(21);
        let (od, h, nl, b) = (6, 16, 9, 5);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
        let cache = mlp.forward(&obs);
        let mut s = mlp.make_scratch();
        for i in 0..b {
            // Dirty the scratch to prove each forward fully overwrites it.
            s.h1.iter_mut().for_each(|x| *x = f32::NAN);
            s.logits.iter_mut().for_each(|x| *x = f32::NAN);
            mlp.forward_row(&obs[i * od..(i + 1) * od], &mut s);
            assert_eq!(s.logits, cache.logits[i * nl..(i + 1) * nl], "row {i} logits");
            assert_eq!(s.value, cache.value[i], "row {i} value");
        }
    }

    /// `forward_reuse` on a dirty, wrongly-sized cache must produce the
    /// same bits as a fresh `forward` (the sharded update's chunk passes
    /// depend on buffer reuse never changing results).
    #[test]
    fn forward_reuse_matches_forward_bitwise() {
        let mut rng = Rng::new(31);
        let (od, h, nl) = (7, 12, 5);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let mut cache = Cache::empty();
        for &b in &[4usize, 9, 2] {
            let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
            let want = mlp.forward(&obs);
            // Dirty the reusable cache with stale sizes/values.
            cache.batch = b;
            cache.obs.clear();
            cache.obs.extend_from_slice(&obs);
            cache.h1.iter_mut().for_each(|x| *x = f32::NAN);
            cache.logits.iter_mut().for_each(|x| *x = f32::NAN);
            mlp.forward_reuse(&mut cache);
            assert_eq!(cache.h1, want.h1, "B={b} h1");
            assert_eq!(cache.h2, want.h2, "B={b} h2");
            assert_eq!(cache.logits, want.logits, "B={b} logits");
            assert_eq!(cache.value, want.value, "B={b} value");
        }
    }

    /// `backward_scratch` with reused (dirty) temporaries must produce the
    /// same gradient bits as the allocating `backward`.
    #[test]
    fn backward_scratch_matches_backward_bitwise() {
        let mut rng = Rng::new(57);
        let (od, h, nl) = (6, 10, 4);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let mut s = BackwardScratch::new();
        for &b in &[5usize, 11, 3] {
            let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
            let dlogits: Vec<f32> = (0..b * nl).map(|_| rng.normal()).collect();
            let dvalue: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
            let cache = mlp.forward(&obs);
            let mut g_ref = mlp.zero_grads();
            mlp.backward(&cache, &dlogits, &dvalue, &mut g_ref);
            let mut g = mlp.zero_grads();
            mlp.backward_scratch(&cache, &dlogits, &dvalue, &mut g, &mut s);
            for (a, r) in g.as_slices().into_iter().zip(g_ref.as_slices()) {
                assert_eq!(a, r, "B={b}");
            }
        }
    }

    #[test]
    fn grads_zero_and_add_from() {
        let mut rng = Rng::new(8);
        let mlp = Mlp::new(&mut rng, 3, 4, 2);
        let mut a = mlp.zero_grads();
        let mut b = mlp.zero_grads();
        a.w1[0] = 1.5;
        a.bv[0] = -2.0;
        b.w1[0] = 0.25;
        b.wpi[3] = 4.0;
        a.add_from(&b);
        assert_eq!(a.w1[0], 1.75);
        assert_eq!(a.wpi[3], 4.0);
        assert_eq!(a.bv[0], -2.0);
        a.zero();
        assert_eq!(a.global_norm(), 0.0);
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(3);
        let mlp = Mlp::new(&mut rng, 4, 8, 5);
        let c = mlp.forward(&vec![0.1; 2 * 4]);
        assert_eq!(c.logits.len(), 10);
        assert_eq!(c.value.len(), 2);
    }

    #[test]
    fn grad_norm_and_scale() {
        let mut rng = Rng::new(4);
        let mlp = Mlp::new(&mut rng, 3, 4, 2);
        let mut g = mlp.zero_grads();
        g.w1[0] = 3.0;
        g.wv[1] = 4.0;
        assert!((g.global_norm() - 5.0).abs() < 1e-6);
        g.scale(0.5);
        assert!((g.global_norm() - 2.5).abs() < 1e-6);
    }
}
