//! Dense actor-critic MLP with hand-written backprop — the network for the
//! pure-Rust PPO comparator (mirrors python/compile/networks.py: tanh torso,
//! concatenated categorical heads, scalar value head).
//!
//! All matrix math runs through the blocked kernel layer in
//! [`super::kernels`] (ISSUE 6): batched forward, row/block forward, the
//! value head, and the backward pass share one set of tiled
//! GEMM/dot/outer-product kernels whose per-element accumulation order is
//! independent of row blocking — so a B-row batch, a shard's lane block,
//! and a single row all produce bit-identical outputs per row.

use super::kernels;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Mlp {
    pub obs_dim: usize,
    pub hidden: usize,
    pub n_logits: usize,
    // weights (row-major [in][out])
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub wpi: Vec<f32>,
    pub bpi: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
}

/// Gradients, same layout as Mlp weights.
#[derive(Debug, Clone)]
pub struct Grads {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub wpi: Vec<f32>,
    pub bpi: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
}

/// Forward-pass activations kept for backprop. The observation rows are
/// NOT stored here — forward entry points borrow them and the backward
/// pass takes the same slice again, so batched inference is copy-free.
pub struct Cache {
    pub batch: usize,
    pub h1: Vec<f32>,     // [B, hidden] (post-tanh)
    pub h2: Vec<f32>,     // [B, hidden]
    pub logits: Vec<f32>, // [B, n_logits]
    pub value: Vec<f32>,  // [B]
}

impl Cache {
    /// An empty cache for [`Mlp::forward_reuse`] callers: forward into it
    /// repeatedly without reallocation after warmup.
    pub fn empty() -> Cache {
        Cache {
            batch: 0,
            h1: Vec::new(),
            h2: Vec::new(),
            logits: Vec::new(),
            value: Vec::new(),
        }
    }
}

/// Reusable backward-pass temporaries (`dh1`/`dh2`), so the sharded PPO
/// update's per-chunk backprops allocate nothing after warmup.
#[derive(Default)]
pub struct BackwardScratch {
    dh1: Vec<f32>,
    dh2: Vec<f32>,
}

impl BackwardScratch {
    pub fn new() -> BackwardScratch {
        BackwardScratch::default()
    }
}

/// Reusable inference scratch: hidden activations, logits, and values for
/// a block of observation rows. Pool shards each own one and forward
/// their whole contiguous lane range as ONE row-block GEMM per step
/// ([`Mlp::forward_block`]), so the fused rollout's policy path does no
/// per-step allocation and no per-lane kernel dispatch. `rows` is
/// whatever the last forward ran; a single-row forward
/// ([`Mlp::forward_row`]) is just the `rows == 1` case.
#[derive(Debug, Clone)]
pub struct MlpScratch {
    pub h1: Vec<f32>,     // [rows, hidden]
    pub h2: Vec<f32>,     // [rows, hidden]
    pub logits: Vec<f32>, // [rows, n_logits]
    pub values: Vec<f32>, // [rows]
    pub rows: usize,
    /// Padded-input staging rows for the generalist shared-trunk policy
    /// ([`super::generalist`]): obs padded to the grid-wide max dim plus a
    /// family one-hot block. Empty (and never touched) on the per-family
    /// `Learner` path.
    pub pad: Vec<f32>,
}

impl Mlp {
    pub fn new(rng: &mut Rng, obs_dim: usize, hidden: usize, n_logits: usize) -> Mlp {
        // He-ish scaled normal init (orthogonal init is overkill here; the
        // comparator only needs to learn, not match the JAX agent exactly).
        let init = |rng: &mut Rng, rows: usize, cols: usize, scale: f32| -> Vec<f32> {
            let s = scale / (rows as f32).sqrt();
            (0..rows * cols).map(|_| rng.normal() * s).collect()
        };
        Mlp {
            obs_dim,
            hidden,
            n_logits,
            w1: init(rng, obs_dim, hidden, 1.4),
            b1: vec![0.0; hidden],
            w2: init(rng, hidden, hidden, 1.4),
            b2: vec![0.0; hidden],
            wpi: init(rng, hidden, n_logits, 0.01),
            bpi: vec![0.0; n_logits],
            wv: init(rng, hidden, 1, 1.0),
            bv: vec![0.0; 1],
        }
    }

    pub fn zero_grads(&self) -> Grads {
        Grads {
            w1: vec![0.0; self.w1.len()],
            b1: vec![0.0; self.b1.len()],
            w2: vec![0.0; self.w2.len()],
            b2: vec![0.0; self.b2.len()],
            wpi: vec![0.0; self.wpi.len()],
            bpi: vec![0.0; self.bpi.len()],
            wv: vec![0.0; self.wv.len()],
            bv: vec![0.0; self.bv.len()],
        }
    }

    /// The one shared forward pipeline: every public entry point
    /// ([`Mlp::forward`], [`Mlp::forward_reuse`], [`Mlp::forward_block`],
    /// [`Mlp::forward_row`]) lands here, so per-row bitwise identity
    /// between them is structural, not re-proven per call site.
    fn forward_into(
        &self,
        obs: &[f32],
        rows: usize,
        h1: &mut Vec<f32>,
        h2: &mut Vec<f32>,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) {
        debug_assert_eq!(obs.len(), rows * self.obs_dim);
        h1.resize(rows * self.hidden, 0.0);
        kernels::gemm_bias(obs, &self.w1, &self.b1, rows, self.obs_dim, self.hidden, h1);
        h1.iter_mut().for_each(|x| *x = x.tanh());
        h2.resize(rows * self.hidden, 0.0);
        kernels::gemm_bias(h1.as_slice(), &self.w2, &self.b2, rows, self.hidden, self.hidden, h2);
        h2.iter_mut().for_each(|x| *x = x.tanh());
        logits.resize(rows * self.n_logits, 0.0);
        kernels::gemm_bias(
            h2.as_slice(),
            &self.wpi,
            &self.bpi,
            rows,
            self.hidden,
            self.n_logits,
            logits,
        );
        values.resize(rows, 0.0);
        for (i, v) in values.iter_mut().enumerate() {
            *v = self.bv[0]
                + kernels::dot8(&h2[i * self.hidden..(i + 1) * self.hidden], &self.wv);
        }
    }

    /// Batched forward: obs `[B * obs_dim]` row-major, borrowed (never
    /// copied) for the duration of the pass.
    pub fn forward(&self, obs: &[f32]) -> Cache {
        let mut cache = Cache::empty();
        self.forward_reuse(obs, &mut cache);
        cache
    }

    /// Batched forward reusing caller-owned cache buffers — the
    /// allocation-free (after warmup) entry point the sharded PPO update's
    /// chunk passes run on. The cache buffers are resized and fully
    /// overwritten; per-row results are bit-identical to every other
    /// forward entry point (all delegate to one pipeline).
    pub fn forward_reuse(&self, obs: &[f32], cache: &mut Cache) {
        let b = obs.len() / self.obs_dim;
        cache.batch = b;
        self.forward_into(
            obs,
            b,
            &mut cache.h1,
            &mut cache.h2,
            &mut cache.logits,
            &mut cache.value,
        );
    }

    /// Scratch sized for one row of this network; [`Mlp::forward_block`]
    /// grows it to whatever block size a shard actually runs.
    pub fn make_scratch(&self) -> MlpScratch {
        MlpScratch {
            h1: vec![0.0; self.hidden],
            h2: vec![0.0; self.hidden],
            logits: vec![0.0; self.n_logits],
            values: vec![0.0; 1],
            rows: 1,
            pad: Vec::new(),
        }
    }

    /// Row-block forward into caller-owned scratch: `&self` (weights are
    /// read-only, so many shards may call it concurrently) and zero
    /// allocation after warmup. One call runs a shard's whole contiguous
    /// lane range as a single blocked GEMM; row `i` of the result is
    /// bit-identical to [`Mlp::forward_row`] on row `i` alone (kernel
    /// accumulation order is independent of row blocking).
    pub fn forward_block(&self, obs: &[f32], rows: usize, s: &mut MlpScratch) {
        debug_assert_eq!(obs.len(), rows * self.obs_dim);
        s.rows = rows;
        self.forward_into(obs, rows, &mut s.h1, &mut s.h2, &mut s.logits, &mut s.values);
    }

    /// Single-row forward — [`Mlp::forward_block`] at `rows == 1` (the
    /// eval / scalar-comparator path).
    pub fn forward_row(&self, obs: &[f32], s: &mut MlpScratch) {
        self.forward_block(obs, 1, s);
    }

    /// Backprop from (`dlogits [B, n_logits]`, `dvalue [B]`) into grads,
    /// with caller-owned `dh1`/`dh2` temporaries — allocation-free after
    /// warmup. `obs` must be the same rows the cache was forwarded from
    /// (the cache no longer stores a copy). Gradients ACCUMULATE into `g`
    /// in row order; zero it first for a fresh pass. All projections and
    /// accumulations run on the blocked kernels.
    pub fn backward_scratch(
        &self,
        obs: &[f32],
        cache: &Cache,
        dlogits: &[f32],
        dvalue: &[f32],
        g: &mut Grads,
        s: &mut BackwardScratch,
    ) {
        let b = cache.batch;
        let h = self.hidden;
        let nl = self.n_logits;
        debug_assert_eq!(obs.len(), b * self.obs_dim);
        // dh2 = dlogits @ wpi^T + dvalue * wv^T
        s.dh2.resize(b * h, 0.0);
        let dh2 = &mut s.dh2;
        for i in 0..b {
            let dl = &dlogits[i * nl..(i + 1) * nl];
            let dv = dvalue[i];
            for k in 0..h {
                let row = &self.wpi[k * nl..(k + 1) * nl];
                dh2[i * h + k] = kernels::fmadd(dv, self.wv[k], kernels::dot8(row, dl));
            }
        }
        // grads of heads (the value head is the j_dim == 1 outer product).
        kernels::outer_acc(&cache.h2, dlogits, b, h, nl, &mut g.wpi);
        kernels::colsum_acc(dlogits, b, nl, &mut g.bpi);
        kernels::outer_acc(&cache.h2, dvalue, b, h, 1, &mut g.wv);
        kernels::colsum_acc(dvalue, b, 1, &mut g.bv);
        // through tanh of h2
        for i in 0..b * h {
            dh2[i] *= 1.0 - cache.h2[i] * cache.h2[i];
        }
        // dh1 = dh2 @ w2^T
        s.dh1.resize(b * h, 0.0);
        let dh1 = &mut s.dh1;
        for i in 0..b {
            let dd = &dh2[i * h..(i + 1) * h];
            for k in 0..h {
                dh1[i * h + k] = kernels::dot8(&self.w2[k * h..(k + 1) * h], dd);
            }
        }
        kernels::outer_acc(&cache.h1, dh2, b, h, h, &mut g.w2);
        kernels::colsum_acc(dh2, b, h, &mut g.b2);
        for i in 0..b * h {
            dh1[i] *= 1.0 - cache.h1[i] * cache.h1[i];
        }
        kernels::outer_acc(obs, dh1, b, self.obs_dim, h, &mut g.w1);
        kernels::colsum_acc(dh1, b, h, &mut g.b1);
    }

    /// The parameter tensors in canonical order (same order as
    /// [`Mlp::params_mut`] / [`Grads::as_slices`] — the reduction and
    /// Adam all zip over this order).
    pub fn params(&self) -> Vec<&Vec<f32>> {
        vec![
            &self.w1, &self.b1, &self.w2, &self.b2,
            &self.wpi, &self.bpi, &self.wv, &self.bv,
        ]
    }

    pub fn params_mut(&mut self) -> Vec<&mut Vec<f32>> {
        vec![
            &mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2,
            &mut self.wpi, &mut self.bpi, &mut self.wv, &mut self.bv,
        ]
    }

    pub fn n_params(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len()
            + self.wpi.len() + self.bpi.len() + self.wv.len() + self.bv.len()
    }
}

impl Grads {
    pub fn as_slices_mut(&mut self) -> Vec<&mut Vec<f32>> {
        vec![
            &mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2,
            &mut self.wpi, &mut self.bpi, &mut self.wv, &mut self.bv,
        ]
    }

    pub fn as_slices(&self) -> Vec<&Vec<f32>> {
        vec![
            &self.w1, &self.b1, &self.w2, &self.b2,
            &self.wpi, &self.bpi, &self.wv, &self.bv,
        ]
    }

    /// Reset every gradient to zero in place (per-chunk accumulators are
    /// reused across minibatches instead of reallocated).
    pub fn zero(&mut self) {
        for v in self.as_slices_mut() {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// `self += other`, element-wise in a fixed (field, index) order — the
    /// combine step of the sharded update's deterministic gradient
    /// reduction. Both operands must come from the same network shape.
    pub fn add_from(&mut self, other: &Grads) {
        for (a, b) in self.as_slices_mut().into_iter().zip(other.as_slices()) {
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x += y;
            }
        }
    }

    pub fn global_norm(&self) -> f32 {
        let sq: f32 = self
            .as_slices()
            .iter()
            .map(|v| v.iter().map(|x| x * x).sum::<f32>())
            .sum();
        sq.sqrt()
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.as_slices_mut() {
            v.iter_mut().for_each(|x| *x *= s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of the full backward pass.
    #[test]
    fn backprop_matches_finite_difference() {
        let mut rng = Rng::new(11);
        let (od, h, nl, b) = (5, 8, 6, 3);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
        // loss = sum(logits * cl) + sum(value * cv) for fixed random c's
        let cl: Vec<f32> = (0..b * nl).map(|_| rng.normal()).collect();
        let cv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let loss = |m: &Mlp| -> f32 {
            let c = m.forward(&obs);
            c.logits.iter().zip(&cl).map(|(a, b)| a * b).sum::<f32>()
                + c.value.iter().zip(&cv).map(|(a, b)| a * b).sum::<f32>()
        };
        let cache = mlp.forward(&obs);
        let mut g = mlp.zero_grads();
        mlp.backward_scratch(&obs, &cache, &cl, &cv, &mut g, &mut BackwardScratch::new());

        let eps = 1e-3f32;
        // probe a few weights in each matrix
        let checks: Vec<(usize, usize)> = vec![(0, 3), (1, 0), (2, 17), (4, 5), (6, 2)];
        for (pi, wi) in checks {
            let mut mp = mlp.clone();
            mp.params_mut()[pi][wi] += eps;
            let lp = loss(&mp);
            let mut mm = mlp.clone();
            mm.params_mut()[pi][wi] -= eps;
            let lm = loss(&mm);
            let fd = (lp - lm) / (2.0 * eps);
            let mut gref = g.clone();
            let an = gref.as_slices_mut()[pi][wi];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {pi}[{wi}]: fd {fd} vs analytic {an}"
            );
        }
    }

    /// The scratch-buffer single-row forward must match the batched
    /// forward bit-for-bit (the fused-rollout invariance tests depend on
    /// shard-side forwards agreeing with the batched reference exactly).
    #[test]
    fn forward_row_matches_batched_forward_bitwise() {
        let mut rng = Rng::new(21);
        let (od, h, nl, b) = (6, 16, 9, 5);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
        let cache = mlp.forward(&obs);
        let mut s = mlp.make_scratch();
        for i in 0..b {
            // Dirty the scratch to prove each forward fully overwrites it.
            s.h1.iter_mut().for_each(|x| *x = f32::NAN);
            s.logits.iter_mut().for_each(|x| *x = f32::NAN);
            s.values.iter_mut().for_each(|x| *x = f32::NAN);
            mlp.forward_row(&obs[i * od..(i + 1) * od], &mut s);
            assert_eq!(s.rows, 1);
            assert_eq!(s.logits, cache.logits[i * nl..(i + 1) * nl], "row {i} logits");
            assert_eq!(s.values[0], cache.value[i], "row {i} value");
        }
    }

    /// The shard-side lane-block forward (one GEMM over a contiguous row
    /// range) must match per-row forwards bit-for-bit — the invariant that
    /// lets shard inference run blocked without perturbing the
    /// thread-count-invariance contract. Block sizes cover the 4-row tile,
    /// remainders, and a block larger than the previous call (growth).
    #[test]
    fn forward_block_matches_forward_row_bitwise() {
        let mut rng = Rng::new(22);
        let (od, h, nl, b) = (7, 12, 5, 11);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
        let mut row = mlp.make_scratch();
        let mut blk = mlp.make_scratch();
        for (lo, hi) in [(0usize, 4usize), (4, 11), (2, 3), (0, 11)] {
            let rows = hi - lo;
            blk.logits.iter_mut().for_each(|x| *x = f32::NAN);
            mlp.forward_block(&obs[lo * od..hi * od], rows, &mut blk);
            assert_eq!(blk.rows, rows);
            for i in 0..rows {
                mlp.forward_row(&obs[(lo + i) * od..(lo + i + 1) * od], &mut row);
                assert_eq!(
                    row.logits,
                    blk.logits[i * nl..(i + 1) * nl],
                    "block {lo}..{hi} row {i} logits"
                );
                assert_eq!(row.values[0], blk.values[i], "block {lo}..{hi} row {i} value");
            }
        }
    }

    /// `forward_reuse` on a dirty, wrongly-sized cache must produce the
    /// same bits as a fresh `forward` (the sharded update's chunk passes
    /// depend on buffer reuse never changing results).
    #[test]
    fn forward_reuse_matches_forward_bitwise() {
        let mut rng = Rng::new(31);
        let (od, h, nl) = (7, 12, 5);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let mut cache = Cache::empty();
        for &b in &[4usize, 9, 2] {
            let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
            let want = mlp.forward(&obs);
            // Dirty the reusable cache with stale sizes/values.
            cache.h1.iter_mut().for_each(|x| *x = f32::NAN);
            cache.logits.iter_mut().for_each(|x| *x = f32::NAN);
            mlp.forward_reuse(&obs, &mut cache);
            assert_eq!(cache.batch, b, "B={b} batch");
            assert_eq!(cache.h1, want.h1, "B={b} h1");
            assert_eq!(cache.h2, want.h2, "B={b} h2");
            assert_eq!(cache.logits, want.logits, "B={b} logits");
            assert_eq!(cache.value, want.value, "B={b} value");
        }
    }

    /// `backward_scratch` with reused (dirty, wrongly-sized) temporaries
    /// must produce the same gradient bits as a run on fresh temporaries.
    #[test]
    fn backward_scratch_reuse_matches_fresh_scratch_bitwise() {
        let mut rng = Rng::new(57);
        let (od, h, nl) = (6, 10, 4);
        let mlp = Mlp::new(&mut rng, od, h, nl);
        let mut s = BackwardScratch::new();
        for &b in &[5usize, 11, 3] {
            let obs: Vec<f32> = (0..b * od).map(|_| rng.normal()).collect();
            let dlogits: Vec<f32> = (0..b * nl).map(|_| rng.normal()).collect();
            let dvalue: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
            let cache = mlp.forward(&obs);
            let mut g_ref = mlp.zero_grads();
            mlp.backward_scratch(
                &obs, &cache, &dlogits, &dvalue, &mut g_ref, &mut BackwardScratch::new(),
            );
            // Dirty the reused temporaries with stale sizes/values.
            s.dh1.iter_mut().for_each(|x| *x = f32::NAN);
            s.dh2.iter_mut().for_each(|x| *x = f32::NAN);
            let mut g = mlp.zero_grads();
            mlp.backward_scratch(&obs, &cache, &dlogits, &dvalue, &mut g, &mut s);
            for (a, r) in g.as_slices().into_iter().zip(g_ref.as_slices()) {
                assert_eq!(a, r, "B={b}");
            }
        }
    }

    #[test]
    fn grads_zero_and_add_from() {
        let mut rng = Rng::new(8);
        let mlp = Mlp::new(&mut rng, 3, 4, 2);
        let mut a = mlp.zero_grads();
        let mut b = mlp.zero_grads();
        a.w1[0] = 1.5;
        a.bv[0] = -2.0;
        b.w1[0] = 0.25;
        b.wpi[3] = 4.0;
        a.add_from(&b);
        assert_eq!(a.w1[0], 1.75);
        assert_eq!(a.wpi[3], 4.0);
        assert_eq!(a.bv[0], -2.0);
        a.zero();
        assert_eq!(a.global_norm(), 0.0);
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(3);
        let mlp = Mlp::new(&mut rng, 4, 8, 5);
        let c = mlp.forward(&vec![0.1; 2 * 4]);
        assert_eq!(c.logits.len(), 10);
        assert_eq!(c.value.len(), 2);
    }

    #[test]
    fn grad_norm_and_scale() {
        let mut rng = Rng::new(4);
        let mlp = Mlp::new(&mut rng, 3, 4, 2);
        let mut g = mlp.zero_grads();
        g.w1[0] = 3.0;
        g.wv[1] = 4.0;
        assert!((g.global_norm() - 5.0).abs() < 1e-6);
        g.scale(0.5);
        assert!((g.global_norm() - 2.5).abs() < 1e-6);
    }
}
