//! Pure-Rust PPO — the "SB3 on CPU" comparator for Table 2. Same algorithm
//! and hyperparameters as the fused JAX PPO (Table 3): GAE, minibatched
//! clipped-surrogate epochs, Adam, global grad-norm clip. Rollouts run
//! through the fused [`VectorEnv::rollout_fused`] entry point: each pool
//! shard forwards + samples the policy for its own lanes (shared-read
//! weights, per-shard scratch, per-(lane, t) counter RNG) and the env
//! writes next-step observations, rewards, dones, and profits directly
//! into the PPO buffers — no separate observe pass, no per-step copies,
//! no serial caller-thread policy forward. Scenario tables are shared
//! across lanes via `Arc`.

use std::sync::Arc;

use crate::env::scalar::{ScalarEnv, ScenarioTables};
use crate::env::tree::StationConfig;
use crate::env::vector::{PolicyRollout, RolloutBuffers, VectorEnv};
use crate::util::rng::{CounterRng, Rng, Uniform01};

use super::mlp::{Grads, Mlp, MlpScratch};

#[derive(Debug, Clone)]
pub struct PpoParams {
    pub num_envs: usize,
    pub rollout_steps: usize,
    pub lr: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub clip_eps: f32,
    pub vf_clip: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
    pub max_grad_norm: f32,
    pub n_minibatches: usize,
    pub update_epochs: usize,
    pub hidden: usize,
    /// Worker-pool width for rollouts (`--threads`); 0 = auto
    /// (`available_parallelism`).
    pub threads: usize,
}

impl Default for PpoParams {
    fn default() -> Self {
        PpoParams {
            num_envs: 12,
            rollout_steps: 300,
            lr: 2.5e-4,
            gamma: 0.99,
            gae_lambda: 0.95,
            clip_eps: 0.2,
            vf_clip: 10.0,
            ent_coef: 0.01,
            vf_coef: 0.25,
            max_grad_norm: 100.0,
            n_minibatches: 4,
            update_epochs: 4,
            hidden: 128,
            threads: 0,
        }
    }
}

pub struct Adam {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    count: i32,
}

impl Adam {
    pub fn new(mlp: &Mlp) -> Adam {
        let sizes = [
            mlp.w1.len(), mlp.b1.len(), mlp.w2.len(), mlp.b2.len(),
            mlp.wpi.len(), mlp.bpi.len(), mlp.wv.len(), mlp.bv.len(),
        ];
        Adam {
            m: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            v: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            count: 0,
        }
    }

    pub fn update(&mut self, mlp: &mut Mlp, grads: &mut Grads, lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.count += 1;
        let c = self.count as f32;
        let bias1 = 1.0 - B1.powf(c);
        let bias2 = 1.0 - B2.powf(c);
        for (((p, g), m), v) in mlp
            .params_mut()
            .into_iter()
            .zip(grads.as_slices_mut())
            .zip(self.m.iter_mut())
            .zip(self.v.iter_mut())
        {
            for i in 0..p.len() {
                m[i] = B1 * m[i] + (1.0 - B1) * g[i];
                v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
                let mh = m[i] / bias1;
                let vh = v[i] / bias2;
                p[i] -= lr * mh / (vh.sqrt() + EPS);
            }
        }
    }
}

/// Multi-head categorical helpers over a concatenated logit vector.
pub struct Heads {
    pub nvec: Vec<usize>,
    pub offsets: Vec<usize>,
    pub n_logits: usize,
}

impl Heads {
    pub fn new(nvec: Vec<usize>) -> Heads {
        let mut offsets = Vec::with_capacity(nvec.len());
        let mut ofs = 0;
        for n in &nvec {
            offsets.push(ofs);
            ofs += n;
        }
        Heads { nvec, offsets, n_logits: ofs }
    }

    /// Sample all heads for one row of logits; returns (action, logp).
    /// Generic over the draw source so the same code runs off the
    /// trainer's stateful [`Rng`] and the fused rollout's per-(lane, t)
    /// [`CounterRng`] streams.
    pub fn sample<R: Uniform01>(&self, rng: &mut R, logits: &[f32], action: &mut [usize]) -> f32 {
        let mut logp = 0f32;
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            let lse = log_sum_exp(lg);
            // Gumbel-max is what jax uses; inverse-CDF is equivalent.
            let mut x = rng.u01();
            let mut pick = n - 1;
            for (i, &l) in lg.iter().enumerate() {
                let p = (l - lse).exp();
                if x < p {
                    pick = i;
                    break;
                }
                x -= p;
            }
            action[h] = pick;
            logp += lg[pick] - lse;
        }
        logp
    }

    /// Greedy (argmax-per-head) decode of one logit row. NaN-safe via
    /// `total_cmp`: a NaN logit can win its head's argmax (NaN sorts above
    /// +inf) but can never panic the comparator the way
    /// `partial_cmp().unwrap()` did.
    pub fn greedy(&self, logits: &[f32], action: &mut [usize]) {
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            action[h] = lg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
    }

    /// Joint log-prob + entropy of a stored action; also fills dlogits with
    /// d(logp)/d(logits) and dent with d(entropy)/d(logits).
    pub fn logp_entropy(
        &self,
        logits: &[f32],
        action: &[usize],
        dlogp: &mut [f32],
        dent: &mut [f32],
    ) -> (f32, f32) {
        let mut logp = 0f32;
        let mut ent = 0f32;
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            let lse = log_sum_exp(lg);
            let a = action[h];
            logp += lg[a] - lse;
            let mut h_ent = 0f32;
            // p_i, entropy and gradients.
            for i in 0..n {
                let p = (lg[i] - lse).exp();
                let lpi = lg[i] - lse;
                h_ent -= p * lpi;
                dlogp[ofs + i] = -p;
                // d(-sum p log p)/dlogit_i = -p_i (log p_i + 1 - H... ) use:
                // dH/dl_i = -p_i * (lpi + H_partial) computed after loop.
                dent[ofs + i] = p * lpi; // temp store p*lpi
            }
            dlogp[ofs + a] += 1.0;
            // dH/dl_i = -p_i*(lpi - sum_j p_j lpj) = -p_i*lpi + p_i*(-H)... :
            // H = -sum p lpi => sum_j p_j lpj = -H
            for i in 0..n {
                let p = (lg[i] - lse).exp();
                let lpi = lg[i] - lse;
                dent[ofs + i] = -p * (lpi + h_ent);
            }
            ent += h_ent;
        }
        (logp, ent)
    }
}

/// Near-equal minibatch boundaries covering EVERY sample: chunk `i` is
/// `[i*bsz/n, (i+1)*bsz/n)`, so sizes differ by at most one and the chunks
/// partition `0..bsz` exactly. The old `bsz / n` truncating split silently
/// dropped `bsz % n` samples from every epoch whenever the batch didn't
/// divide evenly (e.g. the fleet trainer's `n_minibatches: 2` with an odd
/// `B*T`).
pub fn minibatch_bounds(bsz: usize, n_minibatches: usize) -> Vec<(usize, usize)> {
    let n = n_minibatches.max(1);
    (0..n).map(|i| (i * bsz / n, (i + 1) * bsz / n)).collect()
}

fn log_sum_exp(x: &[f32]) -> f32 {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    m + x.iter().map(|v| (v - m).exp()).sum::<f32>().ln()
}

/// GAE identical to kernels/ref.py::gae_ref (time-major flat arrays).
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    dones: &[f32],
    last_value: &[f32],
    e: usize,
    gamma: f32,
    lam: f32,
) -> (Vec<f32>, Vec<f32>) {
    let t_len = rewards.len() / e;
    let mut adv = vec![0f32; rewards.len()];
    let mut g = vec![0f32; e];
    for t in (0..t_len).rev() {
        for j in 0..e {
            let idx = t * e + j;
            let nv = if t == t_len - 1 { last_value[j] } else { values[(t + 1) * e + j] };
            let nonterm = 1.0 - dones[idx];
            let delta = rewards[idx] + gamma * nv * nonterm - values[idx];
            g[j] = delta + gamma * lam * nonterm * g[j];
            adv[idx] = g[j];
        }
    }
    let targets: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, targets)
}

pub struct TrainStats {
    pub mean_reward: f32,
    pub mean_profit: f32,
    pub total_loss: f32,
    pub entropy: f32,
    pub completed_return_mean: f32,
}

/// One policy/value learner: MLP + categorical heads + Adam state over a
/// fixed (obs_dim, action_nvec) interface. This is the per-station-family
/// unit — [`PpoTrainer`] owns exactly one, the fleet trainer
/// ([`crate::fleet::rollout::FleetPpoTrainer`]) owns one per family, and
/// both drive the identical sample/update math through it.
pub struct Learner {
    pub mlp: Mlp,
    pub heads: Heads,
    pub adam: Adam,
    pub obs_dim: usize,
}

impl Learner {
    pub fn new(rng: &mut Rng, obs_dim: usize, hidden: usize, nvec: Vec<usize>) -> Learner {
        let heads = Heads::new(nvec);
        let mlp = Mlp::new(rng, obs_dim, hidden, heads.n_logits);
        let adam = Adam::new(&mlp);
        Learner { mlp, heads, adam, obs_dim }
    }

    pub fn n_ports(&self) -> usize {
        self.heads.nvec.len()
    }

    /// Scratch for the shared-read single-row forwards below (one per
    /// pool shard; reused across every (lane, step) that shard handles).
    pub fn make_scratch(&self) -> MlpScratch {
        self.mlp.make_scratch()
    }

    /// Sample one time-row for `b` lanes: forward `obs_t` (`[b * obs_dim]`),
    /// fill `actions` (`[b * n_ports]`), `logp` (`[b]`), and `val` (`[b]`).
    /// This is the serial-policy path (single caller-thread RNG); the
    /// fused rollouts use [`Learner::sample_lane`] instead.
    pub fn sample_row(
        &self,
        rng: &mut Rng,
        obs_t: &[f32],
        actions: &mut [usize],
        logp: &mut [f32],
        val: &mut [f32],
    ) {
        let b = logp.len();
        let n_ports = self.n_ports();
        let nl = self.heads.n_logits;
        debug_assert_eq!(obs_t.len(), b * self.obs_dim);
        debug_assert_eq!(actions.len(), b * n_ports);
        debug_assert_eq!(val.len(), b);
        let cache = self.mlp.forward(obs_t);
        for j in 0..b {
            let lg = &cache.logits[j * nl..(j + 1) * nl];
            logp[j] = self.heads.sample(rng, lg, &mut actions[j * n_ports..(j + 1) * n_ports]);
            val[j] = cache.value[j];
        }
    }

    /// Fused-rollout sampling for ONE lane at step `t`: `&self` (weights
    /// shared read-only across shards), caller-owned scratch (no
    /// allocation), and a [`CounterRng`] stream derived from
    /// `(seed, lane, t)` — the sampled action is a pure function of the
    /// weights, the observation, and those three coordinates, so shard
    /// placement and thread count can never change it. Returns
    /// `(joint logp, value)`.
    pub fn sample_lane(
        &self,
        t: usize,
        lane: usize,
        seed: u64,
        obs: &[f32],
        action: &mut [usize],
        scratch: &mut MlpScratch,
    ) -> (f32, f32) {
        self.mlp.forward_row(obs, scratch);
        let mut rng = CounterRng::derive2(seed, lane as u64, t as u64);
        let logp = self.heads.sample(&mut rng, &scratch.logits, action);
        (logp, scratch.value)
    }

    /// Greedy (argmax-per-head) decode for one lane — the fused/eval
    /// counterpart of [`Learner::sample_lane`] (`&self`, zero allocation).
    /// Returns the value estimate.
    pub fn greedy_lane(&self, obs: &[f32], action: &mut [usize], scratch: &mut MlpScratch) -> f32 {
        self.mlp.forward_row(obs, scratch);
        self.heads.greedy(&scratch.logits, action);
        scratch.value
    }

    /// Greedy (argmax-per-head) action for a single observation row.
    /// Convenience wrapper over [`Learner::greedy_lane`] for callers
    /// without a long-lived scratch; allocates one scratch per call.
    pub fn greedy_action(&self, obs: &[f32], action: &mut [usize]) {
        let mut scratch = self.make_scratch();
        self.greedy_lane(obs, action, &mut scratch);
    }

    /// Full PPO update over filled rollout buffers (bootstrap forward +
    /// GAE + minibatched clipped-surrogate epochs). Returns
    /// `(mean total loss, mean entropy)` over all minibatch updates.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        hp: &PpoParams,
        rng: &mut Rng,
        n_envs: usize,
        t_len: usize,
        obs_buf: &[f32],
        act_buf: &[usize],
        logp_buf: &[f32],
        val_buf: &[f32],
        rew_buf: &[f32],
        done_buf: &[f32],
    ) -> (f32, f32) {
        let bsz = n_envs * t_len;
        let d = self.obs_dim;
        let last_cache = self.mlp.forward(&obs_buf[t_len * n_envs * d..]);
        let (adv, targets) = gae(
            rew_buf, val_buf, done_buf, &last_cache.value, n_envs, hp.gamma, hp.gae_lambda,
        );
        let bounds = minibatch_bounds(bsz, hp.n_minibatches);
        let mut total_loss_acc = 0f64;
        let mut ent_acc = 0f64;
        let mut n_upd = 0usize;
        for _ in 0..hp.update_epochs {
            let perm = rng.permutation(bsz);
            for &(lo, hi) in &bounds {
                if lo == hi {
                    continue; // n_minibatches > bsz: some chunks are empty
                }
                let idxs = &perm[lo..hi];
                let (loss, ent) = self.minibatch_update(
                    hp, idxs, obs_buf, act_buf, logp_buf, val_buf, &adv, &targets,
                );
                total_loss_acc += loss as f64;
                ent_acc += ent as f64;
                n_upd += 1;
            }
        }
        let n = n_upd.max(1) as f64;
        ((total_loss_acc / n) as f32, (ent_acc / n) as f32)
    }

    #[allow(clippy::too_many_arguments)]
    fn minibatch_update(
        &mut self,
        hp: &PpoParams,
        idxs: &[usize],
        obs_buf: &[f32],
        act_buf: &[usize],
        logp_buf: &[f32],
        val_buf: &[f32],
        adv: &[f32],
        targets: &[f32],
    ) -> (f32, f32) {
        let b = idxs.len();
        let n_ports = self.heads.nvec.len();
        let nl = self.heads.n_logits;
        // gather minibatch
        let mut obs = vec![0f32; b * self.obs_dim];
        for (r, &i) in idxs.iter().enumerate() {
            obs[r * self.obs_dim..(r + 1) * self.obs_dim]
                .copy_from_slice(&obs_buf[i * self.obs_dim..(i + 1) * self.obs_dim]);
        }
        // normalize advantages over the minibatch (PureJaxRL convention).
        let madv: Vec<f32> = idxs.iter().map(|&i| adv[i]).collect();
        let mean = madv.iter().sum::<f32>() / b as f32;
        let var = madv.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / b as f32;
        let std = var.sqrt() + 1e-8;

        let cache = self.mlp.forward(&obs);
        let mut dlogits = vec![0f32; b * nl];
        let mut dvalue = vec![0f32; b];
        let mut loss_acc = 0f32;
        let mut ent_acc = 0f32;
        let mut dlp = vec![0f32; nl];
        let mut dent = vec![0f32; nl];
        for (r, &i) in idxs.iter().enumerate() {
            let lg = &cache.logits[r * nl..(r + 1) * nl];
            let act = &act_buf[i * n_ports..(i + 1) * n_ports];
            dlp.iter_mut().for_each(|x| *x = 0.0);
            dent.iter_mut().for_each(|x| *x = 0.0);
            let (logp, ent) = self.heads.logp_entropy(lg, act, &mut dlp, &mut dent);
            let a_n = (adv[i] - mean) / std;
            let ratio = (logp - logp_buf[i]).exp();
            let clipped = ratio.clamp(1.0 - hp.clip_eps, 1.0 + hp.clip_eps);
            let pg1 = ratio * a_n;
            let pg2 = clipped * a_n;
            // d(-min(pg1,pg2))/dlogp
            let dpg_dlogp = if pg1 <= pg2 {
                -ratio * a_n // d(-ratio*a)/dlogp = -a*ratio
            } else if (ratio < 1.0 - hp.clip_eps && a_n < 0.0)
                || (ratio > 1.0 + hp.clip_eps && a_n > 0.0)
            {
                0.0 // clipped branch, constant
            } else {
                -ratio * a_n
            };
            loss_acc += -pg1.min(pg2);
            ent_acc += ent;
            // value loss (clipped)
            let v = cache.value[r];
            let v_old = val_buf[i];
            let v_clip = v_old + (v - v_old).clamp(-hp.vf_clip, hp.vf_clip);
            let e1 = (v - targets[i]) * (v - targets[i]);
            let e2 = (v_clip - targets[i]) * (v_clip - targets[i]);
            loss_acc += 0.5 * hp.vf_coef * e1.max(e2);
            let dv = if e1 >= e2 {
                v - targets[i]
            } else if (v - v_old).abs() < hp.vf_clip {
                v_clip - targets[i]
            } else {
                0.0
            };
            dvalue[r] = hp.vf_coef * dv / b as f32;
            for k in 0..nl {
                dlogits[r * nl + k] = (dpg_dlogp * dlp[k]
                    - hp.ent_coef * dent[k])
                    / b as f32;
            }
            loss_acc -= hp.ent_coef * ent;
        }
        let mut grads = self.mlp.zero_grads();
        self.mlp.backward(&cache, &dlogits, &dvalue, &mut grads);
        let norm = grads.global_norm();
        if norm > hp.max_grad_norm {
            grads.scale(hp.max_grad_norm / norm);
        }
        self.adam.update(&mut self.mlp, &mut grads, hp.lr);
        (loss_acc / b as f32, ent_acc / b as f32)
    }
}

/// The CPU PPO trainer (comparator): one [`Learner`] over one
/// [`VectorEnv`] batch.
pub struct PpoTrainer {
    pub cfg: PpoParams,
    pub venv: VectorEnv,
    pub learner: Learner,
    pub rng: Rng,
    /// Per-lane running episode return (mirrors each lane's `ep_return`;
    /// used to report completed-episode returns without querying the env
    /// inside the fused rollout).
    running_return: Vec<f32>,
    pub env_steps: usize,
}

impl PpoTrainer {
    /// `tables` is built once and shared across all `num_envs` lanes (and
    /// later greedy-eval envs) via `Arc` — no per-env table rebuild/clone.
    pub fn new(
        cfg: PpoParams,
        station: StationConfig,
        tables: impl Into<Arc<ScenarioTables>>,
        seed: u64,
    ) -> PpoTrainer {
        let mut rng = Rng::new(seed);
        let seeds: Vec<u64> = (0..cfg.num_envs)
            .map(|i| seed ^ (i as u64 * 7919 + 13))
            .collect();
        let mut venv = VectorEnv::with_seeds(
            station,
            vec![tables.into()],
            vec![0; cfg.num_envs],
            &seeds,
        );
        venv.set_threads(cfg.threads);
        let learner = Learner::new(&mut rng, venv.obs_dim(), cfg.hidden, venv.action_nvec());
        PpoTrainer {
            running_return: vec![0.0; cfg.num_envs],
            cfg,
            venv,
            learner,
            rng,
            env_steps: 0,
        }
    }

    /// One PPO iteration (rollout + update). Mirrors ppo.py::train_iter.
    pub fn iteration(&mut self) -> TrainStats {
        let e = self.cfg.num_envs;
        let t_len = self.cfg.rollout_steps;
        let n_ports = self.learner.n_ports();
        let bsz = e * t_len;
        let d = self.learner.obs_dim;

        // obs has one extra row: row t_len is the bootstrap observation.
        let mut obs_buf = vec![0f32; (t_len + 1) * e * d];
        let mut act_buf = vec![0usize; bsz * n_ports];
        let mut logp_buf = vec![0f32; bsz];
        let mut val_buf = vec![0f32; bsz];
        let mut rew_buf = vec![0f32; bsz];
        let mut done_buf = vec![0f32; bsz];
        let mut profit_buf = vec![0f32; bsz];

        // ---- rollout ------------------------------------------------------
        // One fused pass: each pool shard forwards + samples its own
        // lanes' policies inside the same dispatch that steps them (no
        // serial caller-thread forward), writing actions/logp/values and
        // obs/rewards/dones/profits directly into the PPO buffers above.
        // A fresh per-iteration sampling seed keys the per-(lane, t)
        // counter streams.
        {
            let PpoTrainer { venv, learner, rng, .. } = self;
            let policy_seed = rng.next_u64();
            let mut bufs = RolloutBuffers {
                obs: &mut obs_buf,
                rewards: &mut rew_buf,
                dones: &mut done_buf,
                profits: &mut profit_buf,
            };
            let mut pol = PolicyRollout {
                actions: &mut act_buf,
                logp: &mut logp_buf,
                values: &mut val_buf,
            };
            venv.rollout_fused(t_len, &mut bufs, &mut pol, learner, policy_seed, false);
        }
        self.env_steps += bsz;

        // Episode accounting from the filled buffers (off the hot loop).
        let mut profit_sum = 0f64;
        let mut comp_returns: Vec<f32> = Vec::new();
        for t in 0..t_len {
            for j in 0..e {
                let idx = t * e + j;
                profit_sum += profit_buf[idx] as f64;
                self.running_return[j] += rew_buf[idx];
                if done_buf[idx] > 0.5 {
                    comp_returns.push(self.running_return[j]);
                    self.running_return[j] = 0.0;
                }
            }
        }

        // ---- update -------------------------------------------------------
        let (total_loss, entropy) = self.learner.update(
            &self.cfg, &mut self.rng, e, t_len,
            &obs_buf, &act_buf, &logp_buf, &val_buf, &rew_buf, &done_buf,
        );

        TrainStats {
            mean_reward: rew_buf.iter().sum::<f32>() / bsz as f32,
            mean_profit: (profit_sum / bsz as f64) as f32,
            total_loss,
            entropy,
            completed_return_mean: if comp_returns.is_empty() {
                0.0
            } else {
                comp_returns.iter().sum::<f32>() / comp_returns.len() as f32
            },
        }
    }

    /// Greedy evaluation for one full episode; returns total reward/profit.
    /// Reuses the training envs' shared scenario tables (Arc) — no rebuild.
    pub fn eval_episode(&mut self, seed: u64) -> (f32, f32) {
        let mut env =
            ScalarEnv::new(self.venv.cfg.clone(), self.venv.tables_arc(0), seed);
        let mut obs = vec![0f32; self.learner.obs_dim];
        let mut action = vec![0usize; self.learner.n_ports()];
        let mut scratch = self.learner.make_scratch();
        let mut tot_r = 0f32;
        let mut tot_p = 0f32;
        for _ in 0..crate::env::scalar::STEPS_PER_EPISODE {
            env.observe(&mut obs);
            self.learner.greedy_lane(&obs, &mut action, &mut scratch);
            let info = env.step(&action);
            tot_r += info.reward;
            tot_p += info.profit;
        }
        (tot_r, tot_p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gae_matches_hand_rolled_two_steps() {
        // T=2, E=1, no dones.
        let (adv, tgt) = gae(&[1.0, 1.0], &[0.5, 0.5], &[0.0, 0.0], &[0.5], 1, 0.9, 0.8);
        let d1 = 1.0 + 0.9 * 0.5 - 0.5; // 0.95
        let d0 = 1.0 + 0.9 * 0.5 - 0.5 + 0.9 * 0.8 * 0.95;
        assert!((adv[1] - d1).abs() < 1e-6);
        assert!((adv[0] - d0).abs() < 1e-6);
        assert!((tgt[0] - (adv[0] + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn gae_cuts_at_done() {
        let (adv, _) = gae(&[1.0, 1.0], &[0.0, 0.0], &[1.0, 0.0], &[9.0], 1, 0.9, 0.8);
        // t=0 terminal: delta = r - v = 1, no bootstrap, no propagation.
        assert!((adv[0] - 1.0).abs() < 1e-6);
    }

    /// Regression (ISSUE 4): greedy decode must not panic on NaN logits.
    /// `partial_cmp().unwrap()` blew up the whole eval on the first NaN;
    /// `total_cmp` keeps it total (NaN can win the argmax, never panic).
    #[test]
    fn greedy_decode_survives_nan_logits() {
        let heads = Heads::new(vec![3, 2]);
        let logits = vec![0.1, f32::NAN, 0.3, 0.5, 0.2];
        let mut action = vec![0usize; 2];
        heads.greedy(&logits, &mut action); // must not panic
        assert!(action[0] < 3 && action[1] < 2);
        // Clean rows still pick the true per-head argmax.
        let clean = vec![0.1, 0.9, 0.3, 0.2, 0.5];
        heads.greedy(&clean, &mut action);
        assert_eq!(action, vec![1, 1]);
    }

    /// Regression (ISSUE 4): minibatch chunks must partition 0..bsz — the
    /// old truncating `bsz / n` split dropped `bsz % n` samples per epoch.
    #[test]
    fn minibatch_bounds_cover_every_sample_once() {
        // (480, 2) is the live fleet-demo shape; (481, 2) the odd trigger.
        for (bsz, n) in [(7usize, 2usize), (480, 2), (481, 2), (10, 3), (5, 8), (1, 1)] {
            let bounds = minibatch_bounds(bsz, n);
            assert_eq!(bounds.len(), n);
            let mut seen = vec![false; bsz];
            for &(lo, hi) in &bounds {
                assert!(lo <= hi && hi <= bsz, "bsz={bsz} n={n}: bad chunk {lo}..{hi}");
                for i in lo..hi {
                    assert!(!seen[i], "bsz={bsz} n={n}: index {i} visited twice");
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&x| x), "bsz={bsz} n={n}: samples dropped");
            let sizes: Vec<usize> = bounds.iter().map(|&(lo, hi)| hi - lo).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "bsz={bsz} n={n}: uneven chunks {sizes:?}");
        }
    }

    /// Every permuted index lands in exactly one minibatch per epoch —
    /// the composition `permutation + minibatch_bounds` the update uses.
    #[test]
    fn update_epoch_visits_every_sample_once() {
        let (bsz, n) = (21usize, 2usize); // odd bsz, the fleet's n_minibatches
        let mut rng = Rng::new(13);
        let perm = rng.permutation(bsz);
        let mut seen = vec![0usize; bsz];
        for (lo, hi) in minibatch_bounds(bsz, n) {
            for &i in &perm[lo..hi] {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "coverage: {seen:?}");
    }

    /// Fused per-(lane, t) sampling is a pure function of
    /// (weights, obs, seed, lane, t): repeated calls agree bitwise, and it
    /// matches a hand-rolled forward_row + derive2 + Heads::sample.
    #[test]
    fn sample_lane_is_deterministic_and_matches_components() {
        let mut rng = Rng::new(3);
        let learner = Learner::new(&mut rng, 5, 16, vec![4, 3]);
        let obs: Vec<f32> = (0..5).map(|_| rng.normal()).collect();
        let mut a1 = vec![0usize; 2];
        let mut a2 = vec![0usize; 2];
        let mut s1 = learner.make_scratch();
        let mut s2 = learner.make_scratch();
        let (lp1, v1) = learner.sample_lane(7, 3, 99, &obs, &mut a1, &mut s1);
        let (lp2, v2) = learner.sample_lane(7, 3, 99, &obs, &mut a2, &mut s2);
        assert_eq!((a1.clone(), lp1, v1), (a2, lp2, v2));
        // Hand-rolled equivalent.
        let mut s3 = learner.make_scratch();
        learner.mlp.forward_row(&obs, &mut s3);
        let mut crng = CounterRng::derive2(99, 3, 7);
        let mut a3 = vec![0usize; 2];
        let lp3 = learner.heads.sample(&mut crng, &s3.logits, &mut a3);
        assert_eq!(a1, a3);
        assert_eq!(lp1, lp3);
        assert_eq!(v1, s3.value);
        // Different (lane, t) moves the stream for at least some steps.
        let streams: Vec<Vec<usize>> = (0..16)
            .map(|t| {
                let mut a = vec![0usize; 2];
                let mut s = learner.make_scratch();
                learner.sample_lane(t, 0, 99, &obs, &mut a, &mut s);
                a
            })
            .collect();
        assert!(streams.windows(2).any(|w| w[0] != w[1]), "t never changed the sample");
    }

    #[test]
    fn heads_sample_and_logp_consistent() {
        let heads = Heads::new(vec![3, 4]);
        let mut rng = Rng::new(5);
        let logits = vec![0.1, 0.5, -0.2, 1.0, 0.0, -1.0, 0.3];
        let mut action = vec![0usize; 2];
        let lp = heads.sample(&mut rng, &logits, &mut action);
        let mut d1 = vec![0f32; 7];
        let mut d2 = vec![0f32; 7];
        let (lp2, ent) = heads.logp_entropy(&logits, &action, &mut d1, &mut d2);
        assert!((lp - lp2).abs() < 1e-5);
        assert!(ent > 0.0);
    }

    #[test]
    fn entropy_gradient_finite_difference() {
        let heads = Heads::new(vec![4]);
        let logits = vec![0.3f32, -0.1, 0.7, 0.0];
        let mut dlp = vec![0f32; 4];
        let mut dent = vec![0f32; 4];
        let (_, _) = heads.logp_entropy(&logits, &[2], &mut dlp, &mut dent);
        let eps = 1e-3f32;
        for k in 0..4 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let (_, e_p) = heads.logp_entropy(&lp, &[2], &mut vec![0f32; 4], &mut vec![0f32; 4]);
            let mut lm = logits.clone();
            lm[k] -= eps;
            let (_, e_m) = heads.logp_entropy(&lm, &[2], &mut vec![0f32; 4], &mut vec![0f32; 4]);
            let fd = (e_p - e_m) / (2.0 * eps);
            assert!((fd - dent[k]).abs() < 1e-3, "k={k} fd={fd} an={}", dent[k]);
        }
    }

    #[test]
    fn logp_gradient_finite_difference() {
        let heads = Heads::new(vec![3, 2]);
        let logits = vec![0.3f32, -0.1, 0.7, 0.2, -0.4];
        let act = [1usize, 0];
        let mut dlp = vec![0f32; 5];
        let mut dent = vec![0f32; 5];
        heads.logp_entropy(&logits, &act, &mut dlp, &mut dent);
        let eps = 1e-3f32;
        for k in 0..5 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let (l_p, _) = heads.logp_entropy(&lp, &act, &mut vec![0f32; 5], &mut vec![0f32; 5]);
            let mut lm = logits.clone();
            lm[k] -= eps;
            let (l_m, _) = heads.logp_entropy(&lm, &act, &mut vec![0f32; 5], &mut vec![0f32; 5]);
            let fd = (l_p - l_m) / (2.0 * eps);
            assert!((fd - dlp[k]).abs() < 1e-3, "k={k} fd={fd} an={}", dlp[k]);
        }
    }
}
