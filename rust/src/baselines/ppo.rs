//! Pure-Rust PPO — the "SB3 on CPU" comparator for Table 2. Same algorithm
//! and hyperparameters as the fused JAX PPO (Table 3): GAE, minibatched
//! clipped-surrogate epochs, Adam, global grad-norm clip. Rollouts run
//! through the fused [`VectorEnv::rollout`] entry point: the policy
//! closure samples actions from the observation row the env just wrote,
//! and the env (sharded on the persistent worker pool) writes next-step
//! observations, rewards, dones, and profits directly into the PPO
//! buffers — no separate observe pass, no per-step copies. Scenario
//! tables are shared across lanes via `Arc`.

use std::sync::Arc;

use crate::env::scalar::{ScalarEnv, ScenarioTables};
use crate::env::tree::StationConfig;
use crate::env::vector::{RolloutBuffers, VectorEnv};
use crate::util::rng::Rng;

use super::mlp::{Grads, Mlp};

#[derive(Debug, Clone)]
pub struct PpoParams {
    pub num_envs: usize,
    pub rollout_steps: usize,
    pub lr: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub clip_eps: f32,
    pub vf_clip: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
    pub max_grad_norm: f32,
    pub n_minibatches: usize,
    pub update_epochs: usize,
    pub hidden: usize,
    /// Worker-pool width for rollouts (`--threads`); 0 = auto
    /// (`available_parallelism`).
    pub threads: usize,
}

impl Default for PpoParams {
    fn default() -> Self {
        PpoParams {
            num_envs: 12,
            rollout_steps: 300,
            lr: 2.5e-4,
            gamma: 0.99,
            gae_lambda: 0.95,
            clip_eps: 0.2,
            vf_clip: 10.0,
            ent_coef: 0.01,
            vf_coef: 0.25,
            max_grad_norm: 100.0,
            n_minibatches: 4,
            update_epochs: 4,
            hidden: 128,
            threads: 0,
        }
    }
}

pub struct Adam {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    count: i32,
}

impl Adam {
    pub fn new(mlp: &Mlp) -> Adam {
        let sizes = [
            mlp.w1.len(), mlp.b1.len(), mlp.w2.len(), mlp.b2.len(),
            mlp.wpi.len(), mlp.bpi.len(), mlp.wv.len(), mlp.bv.len(),
        ];
        Adam {
            m: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            v: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            count: 0,
        }
    }

    pub fn update(&mut self, mlp: &mut Mlp, grads: &mut Grads, lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.count += 1;
        let c = self.count as f32;
        let bias1 = 1.0 - B1.powf(c);
        let bias2 = 1.0 - B2.powf(c);
        for (((p, g), m), v) in mlp
            .params_mut()
            .into_iter()
            .zip(grads.as_slices_mut())
            .zip(self.m.iter_mut())
            .zip(self.v.iter_mut())
        {
            for i in 0..p.len() {
                m[i] = B1 * m[i] + (1.0 - B1) * g[i];
                v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
                let mh = m[i] / bias1;
                let vh = v[i] / bias2;
                p[i] -= lr * mh / (vh.sqrt() + EPS);
            }
        }
    }
}

/// Multi-head categorical helpers over a concatenated logit vector.
pub struct Heads {
    pub nvec: Vec<usize>,
    pub offsets: Vec<usize>,
    pub n_logits: usize,
}

impl Heads {
    pub fn new(nvec: Vec<usize>) -> Heads {
        let mut offsets = Vec::with_capacity(nvec.len());
        let mut ofs = 0;
        for n in &nvec {
            offsets.push(ofs);
            ofs += n;
        }
        Heads { nvec, offsets, n_logits: ofs }
    }

    /// Sample all heads for one row of logits; returns (action, logp).
    pub fn sample(&self, rng: &mut Rng, logits: &[f32], action: &mut [usize]) -> f32 {
        let mut logp = 0f32;
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            let lse = log_sum_exp(lg);
            // Gumbel-max is what jax uses; inverse-CDF is equivalent.
            let mut x = rng.f32();
            let mut pick = n - 1;
            for (i, &l) in lg.iter().enumerate() {
                let p = (l - lse).exp();
                if x < p {
                    pick = i;
                    break;
                }
                x -= p;
            }
            action[h] = pick;
            logp += lg[pick] - lse;
        }
        logp
    }

    /// Joint log-prob + entropy of a stored action; also fills dlogits with
    /// d(logp)/d(logits) and dent with d(entropy)/d(logits).
    pub fn logp_entropy(
        &self,
        logits: &[f32],
        action: &[usize],
        dlogp: &mut [f32],
        dent: &mut [f32],
    ) -> (f32, f32) {
        let mut logp = 0f32;
        let mut ent = 0f32;
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            let lse = log_sum_exp(lg);
            let a = action[h];
            logp += lg[a] - lse;
            let mut h_ent = 0f32;
            // p_i, entropy and gradients.
            for i in 0..n {
                let p = (lg[i] - lse).exp();
                let lpi = lg[i] - lse;
                h_ent -= p * lpi;
                dlogp[ofs + i] = -p;
                // d(-sum p log p)/dlogit_i = -p_i (log p_i + 1 - H... ) use:
                // dH/dl_i = -p_i * (lpi + H_partial) computed after loop.
                dent[ofs + i] = p * lpi; // temp store p*lpi
            }
            dlogp[ofs + a] += 1.0;
            // dH/dl_i = -p_i*(lpi - sum_j p_j lpj) = -p_i*lpi + p_i*(-H)... :
            // H = -sum p lpi => sum_j p_j lpj = -H
            for i in 0..n {
                let p = (lg[i] - lse).exp();
                let lpi = lg[i] - lse;
                dent[ofs + i] = -p * (lpi + h_ent);
            }
            ent += h_ent;
        }
        (logp, ent)
    }
}

fn log_sum_exp(x: &[f32]) -> f32 {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    m + x.iter().map(|v| (v - m).exp()).sum::<f32>().ln()
}

/// GAE identical to kernels/ref.py::gae_ref (time-major flat arrays).
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    dones: &[f32],
    last_value: &[f32],
    e: usize,
    gamma: f32,
    lam: f32,
) -> (Vec<f32>, Vec<f32>) {
    let t_len = rewards.len() / e;
    let mut adv = vec![0f32; rewards.len()];
    let mut g = vec![0f32; e];
    for t in (0..t_len).rev() {
        for j in 0..e {
            let idx = t * e + j;
            let nv = if t == t_len - 1 { last_value[j] } else { values[(t + 1) * e + j] };
            let nonterm = 1.0 - dones[idx];
            let delta = rewards[idx] + gamma * nv * nonterm - values[idx];
            g[j] = delta + gamma * lam * nonterm * g[j];
            adv[idx] = g[j];
        }
    }
    let targets: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, targets)
}

pub struct TrainStats {
    pub mean_reward: f32,
    pub mean_profit: f32,
    pub total_loss: f32,
    pub entropy: f32,
    pub completed_return_mean: f32,
}

/// One policy/value learner: MLP + categorical heads + Adam state over a
/// fixed (obs_dim, action_nvec) interface. This is the per-station-family
/// unit — [`PpoTrainer`] owns exactly one, the fleet trainer
/// ([`crate::fleet::rollout::FleetPpoTrainer`]) owns one per family, and
/// both drive the identical sample/update math through it.
pub struct Learner {
    pub mlp: Mlp,
    pub heads: Heads,
    pub adam: Adam,
    pub obs_dim: usize,
}

impl Learner {
    pub fn new(rng: &mut Rng, obs_dim: usize, hidden: usize, nvec: Vec<usize>) -> Learner {
        let heads = Heads::new(nvec);
        let mlp = Mlp::new(rng, obs_dim, hidden, heads.n_logits);
        let adam = Adam::new(&mlp);
        Learner { mlp, heads, adam, obs_dim }
    }

    pub fn n_ports(&self) -> usize {
        self.heads.nvec.len()
    }

    /// Sample one time-row for `b` lanes: forward `obs_t` (`[b * obs_dim]`),
    /// fill `actions` (`[b * n_ports]`), `logp` (`[b]`), and `val` (`[b]`).
    pub fn sample_row(
        &mut self,
        rng: &mut Rng,
        obs_t: &[f32],
        actions: &mut [usize],
        logp: &mut [f32],
        val: &mut [f32],
    ) {
        let b = logp.len();
        let n_ports = self.n_ports();
        let nl = self.heads.n_logits;
        debug_assert_eq!(obs_t.len(), b * self.obs_dim);
        debug_assert_eq!(actions.len(), b * n_ports);
        debug_assert_eq!(val.len(), b);
        let cache = self.mlp.forward(obs_t);
        for j in 0..b {
            let lg = &cache.logits[j * nl..(j + 1) * nl];
            logp[j] = self.heads.sample(rng, lg, &mut actions[j * n_ports..(j + 1) * n_ports]);
            val[j] = cache.value[j];
        }
    }

    /// Greedy (argmax-per-head) action for a single observation row.
    pub fn greedy_action(&self, obs: &[f32], action: &mut [usize]) {
        let cache = self.mlp.forward(obs);
        for (h, (&ofs, &n)) in self.heads.offsets.iter().zip(&self.heads.nvec).enumerate() {
            let lg = &cache.logits[ofs..ofs + n];
            action[h] = lg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
        }
    }

    /// Full PPO update over filled rollout buffers (bootstrap forward +
    /// GAE + minibatched clipped-surrogate epochs). Returns
    /// `(mean total loss, mean entropy)` over all minibatch updates.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        hp: &PpoParams,
        rng: &mut Rng,
        n_envs: usize,
        t_len: usize,
        obs_buf: &[f32],
        act_buf: &[usize],
        logp_buf: &[f32],
        val_buf: &[f32],
        rew_buf: &[f32],
        done_buf: &[f32],
    ) -> (f32, f32) {
        let bsz = n_envs * t_len;
        let d = self.obs_dim;
        let last_cache = self.mlp.forward(&obs_buf[t_len * n_envs * d..]);
        let (adv, targets) = gae(
            rew_buf, val_buf, done_buf, &last_cache.value, n_envs, hp.gamma, hp.gae_lambda,
        );
        let mb = bsz / hp.n_minibatches;
        let mut total_loss_acc = 0f64;
        let mut ent_acc = 0f64;
        let mut n_upd = 0usize;
        for _ in 0..hp.update_epochs {
            let perm = rng.permutation(bsz);
            for mbi in 0..hp.n_minibatches {
                let idxs = &perm[mbi * mb..(mbi + 1) * mb];
                let (loss, ent) = self.minibatch_update(
                    hp, idxs, obs_buf, act_buf, logp_buf, val_buf, &adv, &targets,
                );
                total_loss_acc += loss as f64;
                ent_acc += ent as f64;
                n_upd += 1;
            }
        }
        let n = n_upd.max(1) as f64;
        ((total_loss_acc / n) as f32, (ent_acc / n) as f32)
    }

    #[allow(clippy::too_many_arguments)]
    fn minibatch_update(
        &mut self,
        hp: &PpoParams,
        idxs: &[usize],
        obs_buf: &[f32],
        act_buf: &[usize],
        logp_buf: &[f32],
        val_buf: &[f32],
        adv: &[f32],
        targets: &[f32],
    ) -> (f32, f32) {
        let b = idxs.len();
        let n_ports = self.heads.nvec.len();
        let nl = self.heads.n_logits;
        // gather minibatch
        let mut obs = vec![0f32; b * self.obs_dim];
        for (r, &i) in idxs.iter().enumerate() {
            obs[r * self.obs_dim..(r + 1) * self.obs_dim]
                .copy_from_slice(&obs_buf[i * self.obs_dim..(i + 1) * self.obs_dim]);
        }
        // normalize advantages over the minibatch (PureJaxRL convention).
        let madv: Vec<f32> = idxs.iter().map(|&i| adv[i]).collect();
        let mean = madv.iter().sum::<f32>() / b as f32;
        let var = madv.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / b as f32;
        let std = var.sqrt() + 1e-8;

        let cache = self.mlp.forward(&obs);
        let mut dlogits = vec![0f32; b * nl];
        let mut dvalue = vec![0f32; b];
        let mut loss_acc = 0f32;
        let mut ent_acc = 0f32;
        let mut dlp = vec![0f32; nl];
        let mut dent = vec![0f32; nl];
        for (r, &i) in idxs.iter().enumerate() {
            let lg = &cache.logits[r * nl..(r + 1) * nl];
            let act = &act_buf[i * n_ports..(i + 1) * n_ports];
            dlp.iter_mut().for_each(|x| *x = 0.0);
            dent.iter_mut().for_each(|x| *x = 0.0);
            let (logp, ent) = self.heads.logp_entropy(lg, act, &mut dlp, &mut dent);
            let a_n = (adv[i] - mean) / std;
            let ratio = (logp - logp_buf[i]).exp();
            let clipped = ratio.clamp(1.0 - hp.clip_eps, 1.0 + hp.clip_eps);
            let pg1 = ratio * a_n;
            let pg2 = clipped * a_n;
            // d(-min(pg1,pg2))/dlogp
            let dpg_dlogp = if pg1 <= pg2 {
                -ratio * a_n // d(-ratio*a)/dlogp = -a*ratio
            } else if (ratio < 1.0 - hp.clip_eps && a_n < 0.0)
                || (ratio > 1.0 + hp.clip_eps && a_n > 0.0)
            {
                0.0 // clipped branch, constant
            } else {
                -ratio * a_n
            };
            loss_acc += -pg1.min(pg2);
            ent_acc += ent;
            // value loss (clipped)
            let v = cache.value[r];
            let v_old = val_buf[i];
            let v_clip = v_old + (v - v_old).clamp(-hp.vf_clip, hp.vf_clip);
            let e1 = (v - targets[i]) * (v - targets[i]);
            let e2 = (v_clip - targets[i]) * (v_clip - targets[i]);
            loss_acc += 0.5 * hp.vf_coef * e1.max(e2);
            let dv = if e1 >= e2 {
                v - targets[i]
            } else if (v - v_old).abs() < hp.vf_clip {
                v_clip - targets[i]
            } else {
                0.0
            };
            dvalue[r] = hp.vf_coef * dv / b as f32;
            for k in 0..nl {
                dlogits[r * nl + k] = (dpg_dlogp * dlp[k]
                    - hp.ent_coef * dent[k])
                    / b as f32;
            }
            loss_acc -= hp.ent_coef * ent;
        }
        let mut grads = self.mlp.zero_grads();
        self.mlp.backward(&cache, &dlogits, &dvalue, &mut grads);
        let norm = grads.global_norm();
        if norm > hp.max_grad_norm {
            grads.scale(hp.max_grad_norm / norm);
        }
        self.adam.update(&mut self.mlp, &mut grads, hp.lr);
        (loss_acc / b as f32, ent_acc / b as f32)
    }
}

/// The CPU PPO trainer (comparator): one [`Learner`] over one
/// [`VectorEnv`] batch.
pub struct PpoTrainer {
    pub cfg: PpoParams,
    pub venv: VectorEnv,
    pub learner: Learner,
    pub rng: Rng,
    /// Per-lane running episode return (mirrors each lane's `ep_return`;
    /// used to report completed-episode returns without querying the env
    /// inside the fused rollout).
    running_return: Vec<f32>,
    pub env_steps: usize,
}

impl PpoTrainer {
    /// `tables` is built once and shared across all `num_envs` lanes (and
    /// later greedy-eval envs) via `Arc` — no per-env table rebuild/clone.
    pub fn new(
        cfg: PpoParams,
        station: StationConfig,
        tables: impl Into<Arc<ScenarioTables>>,
        seed: u64,
    ) -> PpoTrainer {
        let mut rng = Rng::new(seed);
        let seeds: Vec<u64> = (0..cfg.num_envs)
            .map(|i| seed ^ (i as u64 * 7919 + 13))
            .collect();
        let mut venv = VectorEnv::with_seeds(
            station,
            vec![tables.into()],
            vec![0; cfg.num_envs],
            &seeds,
        );
        venv.set_threads(cfg.threads);
        let learner = Learner::new(&mut rng, venv.obs_dim(), cfg.hidden, venv.action_nvec());
        PpoTrainer {
            running_return: vec![0.0; cfg.num_envs],
            cfg,
            venv,
            learner,
            rng,
            env_steps: 0,
        }
    }

    /// One PPO iteration (rollout + update). Mirrors ppo.py::train_iter.
    pub fn iteration(&mut self) -> TrainStats {
        let e = self.cfg.num_envs;
        let t_len = self.cfg.rollout_steps;
        let n_ports = self.learner.n_ports();
        let bsz = e * t_len;
        let d = self.learner.obs_dim;

        // obs has one extra row: row t_len is the bootstrap observation.
        let mut obs_buf = vec![0f32; (t_len + 1) * e * d];
        let mut act_buf = vec![0usize; bsz * n_ports];
        let mut logp_buf = vec![0f32; bsz];
        let mut val_buf = vec![0f32; bsz];
        let mut rew_buf = vec![0f32; bsz];
        let mut done_buf = vec![0f32; bsz];
        let mut profit_buf = vec![0f32; bsz];

        // ---- rollout ------------------------------------------------------
        // One fused pass: the policy closure samples every lane's action
        // from the observation row the env just wrote; the env advances
        // all lanes on the persistent worker pool and writes obs, rewards,
        // dones, and profits directly into the PPO buffers above.
        {
            let PpoTrainer { venv, learner, rng, .. } = self;
            let mut bufs = RolloutBuffers {
                obs: &mut obs_buf,
                rewards: &mut rew_buf,
                dones: &mut done_buf,
                profits: &mut profit_buf,
            };
            venv.rollout(t_len, &mut bufs, |t, obs_t, actions| {
                learner.sample_row(
                    rng,
                    obs_t,
                    actions,
                    &mut logp_buf[t * e..(t + 1) * e],
                    &mut val_buf[t * e..(t + 1) * e],
                );
                act_buf[t * e * n_ports..(t + 1) * e * n_ports].copy_from_slice(actions);
            });
        }
        self.env_steps += bsz;

        // Episode accounting from the filled buffers (off the hot loop).
        let mut profit_sum = 0f64;
        let mut comp_returns: Vec<f32> = Vec::new();
        for t in 0..t_len {
            for j in 0..e {
                let idx = t * e + j;
                profit_sum += profit_buf[idx] as f64;
                self.running_return[j] += rew_buf[idx];
                if done_buf[idx] > 0.5 {
                    comp_returns.push(self.running_return[j]);
                    self.running_return[j] = 0.0;
                }
            }
        }

        // ---- update -------------------------------------------------------
        let (total_loss, entropy) = self.learner.update(
            &self.cfg, &mut self.rng, e, t_len,
            &obs_buf, &act_buf, &logp_buf, &val_buf, &rew_buf, &done_buf,
        );

        TrainStats {
            mean_reward: rew_buf.iter().sum::<f32>() / bsz as f32,
            mean_profit: (profit_sum / bsz as f64) as f32,
            total_loss,
            entropy,
            completed_return_mean: if comp_returns.is_empty() {
                0.0
            } else {
                comp_returns.iter().sum::<f32>() / comp_returns.len() as f32
            },
        }
    }

    /// Greedy evaluation for one full episode; returns total reward/profit.
    /// Reuses the training envs' shared scenario tables (Arc) — no rebuild.
    pub fn eval_episode(&mut self, seed: u64) -> (f32, f32) {
        let mut env =
            ScalarEnv::new(self.venv.cfg.clone(), self.venv.tables_arc(0), seed);
        let mut obs = vec![0f32; self.learner.obs_dim];
        let mut action = vec![0usize; self.learner.n_ports()];
        let mut tot_r = 0f32;
        let mut tot_p = 0f32;
        for _ in 0..crate::env::scalar::STEPS_PER_EPISODE {
            env.observe(&mut obs);
            self.learner.greedy_action(&obs, &mut action);
            let info = env.step(&action);
            tot_r += info.reward;
            tot_p += info.profit;
        }
        (tot_r, tot_p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gae_matches_hand_rolled_two_steps() {
        // T=2, E=1, no dones.
        let (adv, tgt) = gae(&[1.0, 1.0], &[0.5, 0.5], &[0.0, 0.0], &[0.5], 1, 0.9, 0.8);
        let d1 = 1.0 + 0.9 * 0.5 - 0.5; // 0.95
        let d0 = 1.0 + 0.9 * 0.5 - 0.5 + 0.9 * 0.8 * 0.95;
        assert!((adv[1] - d1).abs() < 1e-6);
        assert!((adv[0] - d0).abs() < 1e-6);
        assert!((tgt[0] - (adv[0] + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn gae_cuts_at_done() {
        let (adv, _) = gae(&[1.0, 1.0], &[0.0, 0.0], &[1.0, 0.0], &[9.0], 1, 0.9, 0.8);
        // t=0 terminal: delta = r - v = 1, no bootstrap, no propagation.
        assert!((adv[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn heads_sample_and_logp_consistent() {
        let heads = Heads::new(vec![3, 4]);
        let mut rng = Rng::new(5);
        let logits = vec![0.1, 0.5, -0.2, 1.0, 0.0, -1.0, 0.3];
        let mut action = vec![0usize; 2];
        let lp = heads.sample(&mut rng, &logits, &mut action);
        let mut d1 = vec![0f32; 7];
        let mut d2 = vec![0f32; 7];
        let (lp2, ent) = heads.logp_entropy(&logits, &action, &mut d1, &mut d2);
        assert!((lp - lp2).abs() < 1e-5);
        assert!(ent > 0.0);
    }

    #[test]
    fn entropy_gradient_finite_difference() {
        let heads = Heads::new(vec![4]);
        let logits = vec![0.3f32, -0.1, 0.7, 0.0];
        let mut dlp = vec![0f32; 4];
        let mut dent = vec![0f32; 4];
        let (_, _) = heads.logp_entropy(&logits, &[2], &mut dlp, &mut dent);
        let eps = 1e-3f32;
        for k in 0..4 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let (_, e_p) = heads.logp_entropy(&lp, &[2], &mut vec![0f32; 4], &mut vec![0f32; 4]);
            let mut lm = logits.clone();
            lm[k] -= eps;
            let (_, e_m) = heads.logp_entropy(&lm, &[2], &mut vec![0f32; 4], &mut vec![0f32; 4]);
            let fd = (e_p - e_m) / (2.0 * eps);
            assert!((fd - dent[k]).abs() < 1e-3, "k={k} fd={fd} an={}", dent[k]);
        }
    }

    #[test]
    fn logp_gradient_finite_difference() {
        let heads = Heads::new(vec![3, 2]);
        let logits = vec![0.3f32, -0.1, 0.7, 0.2, -0.4];
        let act = [1usize, 0];
        let mut dlp = vec![0f32; 5];
        let mut dent = vec![0f32; 5];
        heads.logp_entropy(&logits, &act, &mut dlp, &mut dent);
        let eps = 1e-3f32;
        for k in 0..5 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let (l_p, _) = heads.logp_entropy(&lp, &act, &mut vec![0f32; 5], &mut vec![0f32; 5]);
            let mut lm = logits.clone();
            lm[k] -= eps;
            let (l_m, _) = heads.logp_entropy(&lm, &act, &mut vec![0f32; 5], &mut vec![0f32; 5]);
            let fd = (l_p - l_m) / (2.0 * eps);
            assert!((fd - dlp[k]).abs() < 1e-3, "k={k} fd={fd} an={}", dlp[k]);
        }
    }
}
