//! Pure-Rust PPO — the "SB3 on CPU" comparator for Table 2. Same algorithm
//! and hyperparameters as the fused JAX PPO (Table 3): GAE, minibatched
//! clipped-surrogate epochs, Adam, global grad-norm clip. Rollouts run
//! through the fused [`VectorEnv::rollout_fused`] entry point: each pool
//! shard forwards + samples the policy for its own lanes (shared-read
//! weights, per-shard scratch, per-(lane, t) counter RNG) and the env
//! writes next-step observations, rewards, dones, and profits directly
//! into the PPO buffers — no separate observe pass, no per-step copies,
//! no serial caller-thread policy forward. Scenario tables are shared
//! across lanes via `Arc`.

use std::sync::Arc;

use crate::env::scalar::{ScalarEnv, ScenarioTables};
use crate::env::tree::StationConfig;
use crate::env::vector::{PolicyRollout, RolloutBuffers, VectorEnv};
use crate::runtime::pool::{DisjointTasks, WorkerPool};
use crate::util::rng::{CounterRng, Rng, Uniform01};

use super::mlp::{BackwardScratch, Cache, Grads, Mlp, MlpScratch};

#[derive(Debug, Clone)]
pub struct PpoParams {
    pub num_envs: usize,
    pub rollout_steps: usize,
    pub lr: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub clip_eps: f32,
    pub vf_clip: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
    pub max_grad_norm: f32,
    pub n_minibatches: usize,
    pub update_epochs: usize,
    pub hidden: usize,
    /// Worker-pool width for rollouts (`--threads`); 0 = auto
    /// (`available_parallelism`).
    pub threads: usize,
    /// Double-buffered training (`--overlap on`): after each update, the
    /// NEXT iteration's fused rollout streams on the pool's pipeline lane
    /// while the caller finishes this iteration's accounting/stats (and
    /// any interleaved eval). Bit-identical to the barrier default — the
    /// rng draw order (policy seed, update perms, eval seed) is the same
    /// sequence either way; only wall-clock changes.
    pub overlap: bool,
}

impl Default for PpoParams {
    fn default() -> Self {
        PpoParams {
            num_envs: 12,
            rollout_steps: 300,
            lr: 2.5e-4,
            gamma: 0.99,
            gae_lambda: 0.95,
            clip_eps: 0.2,
            vf_clip: 10.0,
            ent_coef: 0.01,
            vf_coef: 0.25,
            max_grad_norm: 100.0,
            n_minibatches: 4,
            update_epochs: 4,
            hidden: 128,
            threads: 0,
            overlap: false,
        }
    }
}

pub struct Adam {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    count: i32,
}

impl Adam {
    pub fn new(mlp: &Mlp) -> Adam {
        Adam::from_sizes(&[
            mlp.w1.len(), mlp.b1.len(), mlp.w2.len(), mlp.b2.len(),
            mlp.wpi.len(), mlp.bpi.len(), mlp.wv.len(), mlp.bv.len(),
        ])
    }

    /// Optimizer state over an arbitrary canonical-order tensor list — the
    /// generalist shared-trunk learner ([`super::generalist`]) has a
    /// different parameter layout than [`Mlp`] but steps through the same
    /// optimizer.
    pub fn from_sizes(sizes: &[usize]) -> Adam {
        Adam {
            m: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            v: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            count: 0,
        }
    }

    pub fn update(&mut self, mlp: &mut Mlp, grads: &Grads, lr: f32) {
        self.step(mlp.params_mut(), &grads.as_slices(), lr);
    }

    /// One bias-corrected Adam step over parallel (param, grad) tensor
    /// lists. Both lists must be in the same canonical order as the sizes
    /// this state was built from — the zip silently truncates otherwise,
    /// so callers keep ONE ordering for params, grads, and sizes.
    pub fn step(&mut self, params: Vec<&mut Vec<f32>>, grads: &[&Vec<f32>], lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        debug_assert_eq!(params.len(), self.m.len());
        debug_assert_eq!(grads.len(), self.m.len());
        self.count += 1;
        let c = self.count as f32;
        let bias1 = 1.0 - B1.powf(c);
        let bias2 = 1.0 - B2.powf(c);
        for (((p, g), m), v) in params
            .into_iter()
            .zip(grads.iter())
            .zip(self.m.iter_mut())
            .zip(self.v.iter_mut())
        {
            for i in 0..p.len() {
                m[i] = B1 * m[i] + (1.0 - B1) * g[i];
                v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
                let mh = m[i] / bias1;
                let vh = v[i] / bias2;
                p[i] -= lr * mh / (vh.sqrt() + EPS);
            }
        }
    }
}

/// Multi-head categorical helpers over a concatenated logit vector.
pub struct Heads {
    pub nvec: Vec<usize>,
    pub offsets: Vec<usize>,
    pub n_logits: usize,
}

impl Heads {
    pub fn new(nvec: Vec<usize>) -> Heads {
        let mut offsets = Vec::with_capacity(nvec.len());
        let mut ofs = 0;
        for n in &nvec {
            offsets.push(ofs);
            ofs += n;
        }
        Heads { nvec, offsets, n_logits: ofs }
    }

    /// Sample all heads for one row of logits; returns (action, logp).
    /// Generic over the draw source so the same code runs off the
    /// trainer's stateful [`Rng`] and the fused rollout's per-(lane, t)
    /// [`CounterRng`] streams.
    pub fn sample<R: Uniform01>(&self, rng: &mut R, logits: &[f32], action: &mut [usize]) -> f32 {
        let mut logp = 0f32;
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            let lse = log_sum_exp(lg);
            // Gumbel-max is what jax uses; inverse-CDF is equivalent.
            let mut x = rng.u01();
            let mut pick = n - 1;
            for (i, &l) in lg.iter().enumerate() {
                let p = (l - lse).exp();
                if x < p {
                    pick = i;
                    break;
                }
                x -= p;
            }
            action[h] = pick;
            logp += lg[pick] - lse;
        }
        logp
    }

    /// Greedy (argmax-per-head) decode of one logit row. NaN-safe via
    /// `total_cmp`: a NaN logit can win its head's argmax (NaN sorts above
    /// +inf) but can never panic the comparator the way
    /// `partial_cmp().unwrap()` did.
    pub fn greedy(&self, logits: &[f32], action: &mut [usize]) {
        if crate::telemetry::recording() && logits.iter().any(|x| !x.is_finite()) {
            crate::telemetry::counters(|c| c.nan_guard_trips += 1);
        }
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            action[h] = lg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
    }

    /// Joint log-prob + entropy of a stored action; also fills dlogits with
    /// d(logp)/d(logits) and dent with d(entropy)/d(logits).
    pub fn logp_entropy(
        &self,
        logits: &[f32],
        action: &[usize],
        dlogp: &mut [f32],
        dent: &mut [f32],
    ) -> (f32, f32) {
        let mut logp = 0f32;
        let mut ent = 0f32;
        for (h, (&ofs, &n)) in self.offsets.iter().zip(&self.nvec).enumerate() {
            let lg = &logits[ofs..ofs + n];
            let lse = log_sum_exp(lg);
            let a = action[h];
            logp += lg[a] - lse;
            let mut h_ent = 0f32;
            // p_i, entropy and gradients.
            for i in 0..n {
                let p = (lg[i] - lse).exp();
                let lpi = lg[i] - lse;
                h_ent -= p * lpi;
                dlogp[ofs + i] = -p;
                // d(-sum p log p)/dlogit_i = -p_i (log p_i + 1 - H... ) use:
                // dH/dl_i = -p_i * (lpi + H_partial) computed after loop.
                dent[ofs + i] = p * lpi; // temp store p*lpi
            }
            dlogp[ofs + a] += 1.0;
            // dH/dl_i = -p_i*(lpi - sum_j p_j lpj) = -p_i*lpi + p_i*(-H)... :
            // H = -sum p lpi => sum_j p_j lpj = -H
            for i in 0..n {
                let p = (lg[i] - lse).exp();
                let lpi = lg[i] - lse;
                dent[ofs + i] = -p * (lpi + h_ent);
            }
            ent += h_ent;
        }
        (logp, ent)
    }
}

/// Near-equal minibatch boundaries covering EVERY sample: chunk `i` is
/// `[i*bsz/n, (i+1)*bsz/n)`, so sizes differ by at most one and the chunks
/// partition `0..bsz` exactly. The old `bsz / n` truncating split silently
/// dropped `bsz % n` samples from every epoch whenever the batch didn't
/// divide evenly (e.g. the fleet trainer's `n_minibatches: 2` with an odd
/// `B*T`).
pub fn minibatch_bounds(bsz: usize, n_minibatches: usize) -> Vec<(usize, usize)> {
    let n = n_minibatches.max(1);
    (0..n).map(|i| (i * bsz / n, (i + 1) * bsz / n)).collect()
}

fn log_sum_exp(x: &[f32]) -> f32 {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    m + x.iter().map(|v| (v - m).exp()).sum::<f32>().ln()
}

/// Row count of one gradient chunk in the (sharded) PPO update. Every
/// minibatch is split at fixed `UPDATE_CHUNK_ROWS` boundaries — a function
/// of the minibatch size alone, NEVER of `--threads` — so the per-chunk
/// gradient partials and their fixed-order reduction are bit-identical
/// however many pool lanes the chunks land on.
pub const UPDATE_CHUNK_ROWS: usize = 64;

/// How many pool lanes a sharded update over `bsz` samples can keep busy:
/// the largest minibatch's chunk count.
pub fn update_shard_demand(bsz: usize, n_minibatches: usize) -> usize {
    minibatch_bounds(bsz, n_minibatches)
        .iter()
        .map(|&(lo, hi)| (hi - lo).div_ceil(UPDATE_CHUNK_ROWS))
        .max()
        .unwrap_or(1)
        .max(1)
}

/// Fixed-order pairwise tree reduction into `parts[0]`: combine
/// (0,1), (2,3), … then (0,2), (4,6), … and so on. The reduction shape
/// depends only on `parts.len()` (the chunk count), never on which pool
/// lane computed which partial — the associativity-safe half of the
/// sharded update's bitwise-determinism contract. ONE control flow for
/// every reduced quantity, so gradient and stats reductions can never
/// drift apart structurally.
pub(crate) fn tree_reduce<T>(parts: &mut [T], mut combine: impl FnMut(&mut T, &T)) {
    let n = parts.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (a, b) = parts.split_at_mut(i + stride);
            combine(&mut a[i], &b[0]);
            i += 2 * stride;
        }
        stride *= 2;
    }
}

fn tree_reduce_grads(parts: &mut [Grads]) {
    tree_reduce(parts, |a, b| a.add_from(b));
}

/// The same fixed-order tree over per-chunk (loss, entropy) partial sums.
pub(crate) fn tree_reduce_stats(parts: &mut [(f32, f32)]) {
    tree_reduce(parts, |a, b| {
        a.0 += b.0;
        a.1 += b.1;
    });
}

/// One family's filled rollout buffers, borrowed by the (sharded) update.
/// `obs` carries the extra bootstrap row (`[(T+1) * B * obs_dim]`, like
/// [`RolloutBuffers::obs`]); the rest are `[T * B]` / `[T * B * n_ports]`.
pub struct UpdateBatch<'a> {
    pub n_envs: usize,
    pub t_len: usize,
    pub obs: &'a [f32],
    pub act: &'a [usize],
    pub logp: &'a [f32],
    pub val: &'a [f32],
    pub rew: &'a [f32],
    pub done: &'a [f32],
}

/// Per-pool-lane reusable buffers for the update's chunk passes (gathered
/// obs rows, forward cache, loss gradients, backward temporaries).
/// Resized on demand, so one scratch serves chunks from
/// differently-shaped family learners.
struct UpdateScratch {
    /// Permutation-gathered observation rows for the current chunk (the
    /// forward cache borrows obs instead of storing a copy).
    obs: Vec<f32>,
    cache: Cache,
    dlogits: Vec<f32>,
    dvalue: Vec<f32>,
    dlp: Vec<f32>,
    dent: Vec<f32>,
    bw: BackwardScratch,
}

impl UpdateScratch {
    fn new() -> UpdateScratch {
        UpdateScratch {
            obs: Vec::new(),
            cache: Cache::empty(),
            dlogits: Vec::new(),
            dvalue: Vec::new(),
            dlp: Vec::new(),
            dent: Vec::new(),
            bw: BackwardScratch::new(),
        }
    }
}

/// One sample-row of the PPO clipped-surrogate loss: log-prob/entropy of
/// the stored action, normalized-advantage policy gradient, clipped value
/// loss — filling `dlogits_row`/`dvalue_out` (both scaled by `1/norm`, the
/// FULL minibatch-round row count) and accumulating raw loss/entropy into
/// `loss_acc`/`ent_acc` in a fixed op order. Extracted from the chunk pass
/// so the per-family [`ChunkTask`] and the generalist's cross-family
/// chunks ([`super::generalist`]) run literally the same float ops —
/// their bitwise contracts are one proof, not two.
#[allow(clippy::too_many_arguments)]
pub(crate) fn ppo_row_grads(
    heads: &Heads,
    hp: &PpoParams,
    lg: &[f32],
    act: &[usize],
    adv_raw: f32,
    adv_mean: f32,
    adv_std: f32,
    logp_old: f32,
    v: f32,
    v_old: f32,
    target: f32,
    norm: f32,
    dlp: &mut [f32],
    dent: &mut [f32],
    dlogits_row: &mut [f32],
    dvalue_out: &mut f32,
    loss_acc: &mut f32,
    ent_acc: &mut f32,
) {
    let nl = heads.n_logits;
    dlp.iter_mut().for_each(|x| *x = 0.0);
    dent.iter_mut().for_each(|x| *x = 0.0);
    let (logp, ent) = heads.logp_entropy(lg, act, dlp, dent);
    let a_n = (adv_raw - adv_mean) / adv_std;
    let ratio = (logp - logp_old).exp();
    let clipped = ratio.clamp(1.0 - hp.clip_eps, 1.0 + hp.clip_eps);
    let pg1 = ratio * a_n;
    let pg2 = clipped * a_n;
    // d(-min(pg1,pg2))/dlogp
    let dpg_dlogp = if pg1 <= pg2 {
        -ratio * a_n // d(-ratio*a)/dlogp = -a*ratio
    } else if (ratio < 1.0 - hp.clip_eps && a_n < 0.0)
        || (ratio > 1.0 + hp.clip_eps && a_n > 0.0)
    {
        0.0 // clipped branch, constant
    } else {
        -ratio * a_n
    };
    *loss_acc += -pg1.min(pg2);
    *ent_acc += ent;
    // value loss (clipped)
    let v_clip = v_old + (v - v_old).clamp(-hp.vf_clip, hp.vf_clip);
    let e1 = (v - target) * (v - target);
    let e2 = (v_clip - target) * (v_clip - target);
    *loss_acc += 0.5 * hp.vf_coef * e1.max(e2);
    let dv = if e1 >= e2 {
        v - target
    } else if (v - v_old).abs() < hp.vf_clip {
        v_clip - target
    } else {
        0.0
    };
    *dvalue_out = hp.vf_coef * dv / norm;
    for k in 0..nl {
        dlogits_row[k] = (dpg_dlogp * dlp[k] - hp.ent_coef * dent[k]) / norm;
    }
    *loss_acc -= hp.ent_coef * ent;
}

/// One gradient chunk of one family's current minibatch: forward + loss
/// gradients + backward over `idxs` (at most [`UPDATE_CHUNK_ROWS`] rows),
/// writing the partial gradient into this chunk's own accumulator. Chunks
/// share the learner read-only and own disjoint outputs, so any number of
/// them can run concurrently on pool lanes.
struct ChunkTask<'a> {
    learner: &'a Learner,
    hp: &'a PpoParams,
    idxs: &'a [usize],
    /// Full minibatch row count (loss/grad normalizer — NOT the chunk's).
    mb_len: usize,
    /// Advantage-normalization stats over the WHOLE minibatch (computed
    /// once on the caller; identical for every chunk of the minibatch).
    adv_mean: f32,
    adv_std: f32,
    batch: &'a UpdateBatch<'a>,
    adv: &'a [f32],
    targets: &'a [f32],
    grads: &'a mut Grads,
    /// (loss, entropy) partial sums over this chunk's rows.
    stats: &'a mut (f32, f32),
}

impl ChunkTask<'_> {
    fn run(&mut self, s: &mut UpdateScratch) {
        let _span = crate::telemetry::Span::fine(crate::telemetry::SpanKind::UpdateChunk);
        let learner = self.learner;
        let hp = self.hp;
        let d = learner.obs_dim;
        let nl = learner.heads.n_logits;
        let n_ports = learner.heads.nvec.len();
        let b = self.idxs.len();
        // Gather this chunk's observation rows into the reusable buffer,
        // then run ONE blocked forward over the whole chunk (the same
        // kernels as the rollout's lane-blocked shard inference).
        s.obs.resize(b * d, 0.0);
        for (r, &i) in self.idxs.iter().enumerate() {
            s.obs[r * d..(r + 1) * d].copy_from_slice(&self.batch.obs[i * d..(i + 1) * d]);
        }
        learner.mlp.forward_reuse(&s.obs, &mut s.cache);
        s.dlogits.resize(b * nl, 0.0);
        s.dvalue.resize(b, 0.0);
        s.dlp.resize(nl, 0.0);
        s.dent.resize(nl, 0.0);
        let mut loss_acc = 0f32;
        let mut ent_acc = 0f32;
        for (r, &i) in self.idxs.iter().enumerate() {
            let lg = &s.cache.logits[r * nl..(r + 1) * nl];
            let act = &self.batch.act[i * n_ports..(i + 1) * n_ports];
            ppo_row_grads(
                &learner.heads,
                hp,
                lg,
                act,
                self.adv[i],
                self.adv_mean,
                self.adv_std,
                self.batch.logp[i],
                s.cache.value[r],
                self.batch.val[i],
                self.targets[i],
                self.mb_len as f32,
                &mut s.dlp,
                &mut s.dent,
                &mut s.dlogits[r * nl..(r + 1) * nl],
                &mut s.dvalue[r],
                &mut loss_acc,
                &mut ent_acc,
            );
        }
        self.grads.zero();
        learner.mlp.backward_scratch(
            &s.obs,
            &s.cache,
            &s.dlogits[..b * nl],
            &s.dvalue[..b],
            self.grads,
            &mut s.bw,
        );
        *self.stats = (loss_acc, ent_acc);
        crate::telemetry::counters(|c| c.minibatch_rows += b as u64);
    }
}

/// Dispatch one (epoch, minibatch) round's gradient chunks — from all
/// families — over the pool, each pool lane reusing its own
/// [`UpdateScratch`]. Without a pool (or with a single chunk) everything
/// runs inline on the caller in chunk order; either way every chunk
/// computes the same bits.
fn run_chunk_tasks(
    pool: Option<&WorkerPool>,
    tasks: &mut [ChunkTask<'_>],
    scratch: &mut [UpdateScratch],
) {
    match pool {
        Some(pool) if tasks.len() > 1 && pool.max_shards() > 1 => {
            let shared = DisjointTasks::new(tasks);
            let scr = DisjointTasks::new(scratch);
            pool.run_strided(shared.len(), |lane, k| {
                // SAFETY: `run_strided` visits chunk `k` exactly once,
                // and lane index `lane` is owned by exactly one OS
                // thread for the whole dispatch — both accesses are
                // exclusive with no locks on the hot path.
                unsafe { shared.get(k).run(scr.get(lane)) }
            });
        }
        _ => {
            let _scope = crate::telemetry::quiet_scope();
            let (first, _) = scratch.split_first_mut().expect("at least one update scratch");
            for task in tasks {
                task.run(first);
            }
        }
    }
}

/// Shard-parallel PPO update over one or more families at once — the
/// fleet entry point ([`Learner::update_sharded`] is the single-family
/// wrapper). Per (epoch, minibatch) round it dispatches EVERY family's
/// gradient chunks in one pooled call, then reduces + Adam-steps each
/// family on the caller — so with N families the pool stays busy across
/// the whole update phase instead of idling between per-family updates.
///
/// Determinism contract (tested in rust/tests/ppo_baseline.rs and
/// rust/tests/fleet.rs):
/// * chunk boundaries are a pure function of the minibatch partition
///   ([`UPDATE_CHUNK_ROWS`]), never of `--threads`;
/// * every chunk's partial gradient is computed with the same math
///   wherever it runs (shared-read learner, per-lane scratch fully
///   overwritten per chunk);
/// * partials are combined by a fixed-order pairwise tree
///   ([`tree_reduce_grads`]), and Adam runs once per minibatch on the
///   caller;
/// * epoch permutations are pre-drawn from `rng` in family-major order —
///   exactly the order serial per-family `update` calls would draw them.
///
/// Hence the result is bit-identical to serial per-family updates and to
/// itself for ANY pool width (including `pool: None`).
pub fn update_sharded_many(
    learners: &mut [Learner],
    hp: &PpoParams,
    rng: &mut Rng,
    pool: Option<&WorkerPool>,
    batches: &[UpdateBatch<'_>],
) -> Vec<(f32, f32)> {
    assert_eq!(learners.len(), batches.len(), "one UpdateBatch per learner");
    struct Prep {
        adv: Vec<f32>,
        targets: Vec<f32>,
        bounds: Vec<(usize, usize)>,
        /// One permutation per epoch (pre-drawn, family-major).
        perms: Vec<Vec<usize>>,
        chunk_grads: Vec<Grads>,
        chunk_stats: Vec<(f32, f32)>,
        loss_acc: f64,
        ent_acc: f64,
        n_upd: usize,
    }
    let mut preps: Vec<Prep> = learners
        .iter()
        .zip(batches)
        .map(|(l, b)| {
            let d = l.obs_dim;
            let bsz = b.n_envs * b.t_len;
            assert_eq!(b.obs.len(), (b.t_len + 1) * b.n_envs * d, "obs must be [(T+1)*B*d]");
            let last_cache = l.mlp.forward(&b.obs[b.t_len * b.n_envs * d..]);
            let (adv, targets) = gae(
                b.rew, b.val, b.done, &last_cache.value, b.n_envs, hp.gamma, hp.gae_lambda,
            );
            let bounds = minibatch_bounds(bsz, hp.n_minibatches);
            let perms: Vec<Vec<usize>> =
                (0..hp.update_epochs).map(|_| rng.permutation(bsz)).collect();
            // One accumulator slot per chunk of the family's largest
            // minibatch — the same number `update_shard_demand` sizes the
            // pool for, so dispatch and storage can never disagree.
            let max_chunks = update_shard_demand(bsz, hp.n_minibatches);
            Prep {
                adv,
                targets,
                bounds,
                perms,
                chunk_grads: (0..max_chunks).map(|_| l.mlp.zero_grads()).collect(),
                chunk_stats: vec![(0.0, 0.0); max_chunks],
                loss_acc: 0.0,
                ent_acc: 0.0,
                n_upd: 0,
            }
        })
        .collect();
    let width = pool.map(|p| p.max_shards()).unwrap_or(1).max(1);
    let mut scratch: Vec<UpdateScratch> = (0..width).map(|_| UpdateScratch::new()).collect();
    for epoch in 0..hp.update_epochs {
        for mb in 0..hp.n_minibatches.max(1) {
            let mut tasks: Vec<ChunkTask<'_>> = Vec::new();
            for ((learner, batch), prep) in
                learners.iter().zip(batches).zip(preps.iter_mut())
            {
                let Prep { adv, targets, bounds, perms, chunk_grads, chunk_stats, .. } = prep;
                let (lo, hi) = bounds[mb];
                if lo == hi {
                    continue; // n_minibatches > bsz: some chunks are empty
                }
                let mb_len = hi - lo;
                let idxs = &perms[epoch][lo..hi];
                // Normalize advantages over the minibatch (PureJaxRL
                // convention) — once, on the caller, shared by all chunks.
                let adv_mean = idxs.iter().map(|&i| adv[i]).sum::<f32>() / mb_len as f32;
                let var = idxs
                    .iter()
                    .map(|&i| {
                        let x = adv[i] - adv_mean;
                        x * x
                    })
                    .sum::<f32>()
                    / mb_len as f32;
                let adv_std = var.sqrt() + 1e-8;
                // The zip below would SILENTLY drop chunks if a round ever
                // produced more than the pre-sized accumulators — keep the
                // invariant loud instead.
                assert!(
                    mb_len.div_ceil(UPDATE_CHUNK_ROWS) <= chunk_grads.len(),
                    "minibatch {mb}: {} chunks but {} accumulators",
                    mb_len.div_ceil(UPDATE_CHUNK_ROWS),
                    chunk_grads.len()
                );
                for ((chunk, grads), stats) in idxs
                    .chunks(UPDATE_CHUNK_ROWS)
                    .zip(chunk_grads.iter_mut())
                    .zip(chunk_stats.iter_mut())
                {
                    tasks.push(ChunkTask {
                        learner,
                        hp,
                        idxs: chunk,
                        mb_len,
                        adv_mean,
                        adv_std,
                        batch,
                        adv,
                        targets,
                        grads,
                        stats,
                    });
                }
            }
            run_chunk_tasks(pool, &mut tasks, &mut scratch);
            drop(tasks);
            // Reduce + clip + Adam per family, caller thread, family order.
            for (learner, prep) in learners.iter_mut().zip(preps.iter_mut()) {
                let (lo, hi) = prep.bounds[mb];
                if lo == hi {
                    continue;
                }
                let mb_len = hi - lo;
                let n_chunks = mb_len.div_ceil(UPDATE_CHUNK_ROWS);
                {
                    let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Reduce);
                    tree_reduce_grads(&mut prep.chunk_grads[..n_chunks]);
                    tree_reduce_stats(&mut prep.chunk_stats[..n_chunks]);
                }
                let grads = &mut prep.chunk_grads[0];
                let norm = grads.global_norm();
                if norm > hp.max_grad_norm {
                    grads.scale(hp.max_grad_norm / norm);
                }
                let Learner { mlp, adam, .. } = learner;
                {
                    let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Adam);
                    adam.update(mlp, grads, hp.lr);
                }
                let (loss, ent) = prep.chunk_stats[0];
                prep.loss_acc += (loss / mb_len as f32) as f64;
                prep.ent_acc += (ent / mb_len as f32) as f64;
                prep.n_upd += 1;
            }
        }
    }
    preps
        .iter()
        .map(|p| {
            let n = p.n_upd.max(1) as f64;
            ((p.loss_acc / n) as f32, (p.ent_acc / n) as f32)
        })
        .collect()
}

/// GAE identical to kernels/ref.py::gae_ref (time-major flat arrays).
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    dones: &[f32],
    last_value: &[f32],
    e: usize,
    gamma: f32,
    lam: f32,
) -> (Vec<f32>, Vec<f32>) {
    let t_len = rewards.len() / e;
    let mut adv = vec![0f32; rewards.len()];
    let mut g = vec![0f32; e];
    for t in (0..t_len).rev() {
        for j in 0..e {
            let idx = t * e + j;
            let nv = if t == t_len - 1 { last_value[j] } else { values[(t + 1) * e + j] };
            let nonterm = 1.0 - dones[idx];
            let delta = rewards[idx] + gamma * nv * nonterm - values[idx];
            g[j] = delta + gamma * lam * nonterm * g[j];
            adv[idx] = g[j];
        }
    }
    let targets: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, targets)
}

pub struct TrainStats {
    pub mean_reward: f32,
    pub mean_profit: f32,
    pub total_loss: f32,
    pub entropy: f32,
    pub completed_return_mean: f32,
}

/// One policy/value learner: MLP + categorical heads + Adam state over a
/// fixed (obs_dim, action_nvec) interface. This is the per-station-family
/// unit — [`PpoTrainer`] owns exactly one, the fleet trainer
/// ([`crate::fleet::rollout::FleetPpoTrainer`]) owns one per family, and
/// both drive the identical sample/update math through it.
pub struct Learner {
    pub mlp: Mlp,
    pub heads: Heads,
    pub adam: Adam,
    pub obs_dim: usize,
}

impl Learner {
    pub fn new(rng: &mut Rng, obs_dim: usize, hidden: usize, nvec: Vec<usize>) -> Learner {
        let heads = Heads::new(nvec);
        let mlp = Mlp::new(rng, obs_dim, hidden, heads.n_logits);
        let adam = Adam::new(&mlp);
        Learner { mlp, heads, adam, obs_dim }
    }

    pub fn n_ports(&self) -> usize {
        self.heads.nvec.len()
    }

    /// Scratch for the shared-read single-row forwards below (one per
    /// pool shard; reused across every (lane, step) that shard handles).
    pub fn make_scratch(&self) -> MlpScratch {
        self.mlp.make_scratch()
    }

    /// Sample one time-row for `b` lanes: forward `obs_t` (`[b * obs_dim]`),
    /// fill `actions` (`[b * n_ports]`), `logp` (`[b]`), and `val` (`[b]`).
    /// This is the serial-policy path (single caller-thread RNG); the
    /// fused rollouts use [`Learner::sample_lane`] instead.
    pub fn sample_row(
        &self,
        rng: &mut Rng,
        obs_t: &[f32],
        actions: &mut [usize],
        logp: &mut [f32],
        val: &mut [f32],
    ) {
        let b = logp.len();
        let n_ports = self.n_ports();
        let nl = self.heads.n_logits;
        debug_assert_eq!(obs_t.len(), b * self.obs_dim);
        debug_assert_eq!(actions.len(), b * n_ports);
        debug_assert_eq!(val.len(), b);
        let cache = self.mlp.forward(obs_t);
        for j in 0..b {
            let lg = &cache.logits[j * nl..(j + 1) * nl];
            logp[j] = self.heads.sample(rng, lg, &mut actions[j * n_ports..(j + 1) * n_ports]);
            val[j] = cache.value[j];
        }
    }

    /// Fused-rollout sampling for ONE lane at step `t`: `&self` (weights
    /// shared read-only across shards), caller-owned scratch (no
    /// allocation), and a [`CounterRng`] stream derived from
    /// `(seed, lane, t)` — the sampled action is a pure function of the
    /// weights, the observation, and those three coordinates, so shard
    /// placement and thread count can never change it. Returns
    /// `(joint logp, value)`.
    pub fn sample_lane(
        &self,
        t: usize,
        lane: usize,
        seed: u64,
        obs: &[f32],
        action: &mut [usize],
        scratch: &mut MlpScratch,
    ) -> (f32, f32) {
        self.mlp.forward_row(obs, scratch);
        let mut rng = CounterRng::derive2(seed, lane as u64, t as u64);
        let logp = self.heads.sample(&mut rng, &scratch.logits, action);
        (logp, scratch.values[0])
    }

    /// Lane-blocked fused-rollout sampling (ISSUE 6): forward a shard's
    /// whole contiguous lane range `[lane0, lane0 + n)` as ONE row-block
    /// GEMM into the shard's scratch, then sample each row off its own
    /// `(seed, lane, t)` counter stream. Bit-identical per lane to
    /// [`Learner::sample_lane`] — the kernels' accumulation order is
    /// independent of row blocking, and the RNG streams are per-lane by
    /// construction — so shard boundaries and `--threads` still can't
    /// perturb anything. Fills `actions [n * n_ports]`, `logp [n]`,
    /// `values [n]`.
    #[allow(clippy::too_many_arguments)]
    pub fn sample_block(
        &self,
        t: usize,
        lane0: usize,
        seed: u64,
        obs: &[f32],
        actions: &mut [usize],
        logp: &mut [f32],
        values: &mut [f32],
        scratch: &mut MlpScratch,
    ) {
        let n = logp.len();
        let nl = self.heads.n_logits;
        let p = self.n_ports();
        debug_assert_eq!(obs.len(), n * self.obs_dim);
        debug_assert_eq!(actions.len(), n * p);
        debug_assert_eq!(values.len(), n);
        self.mlp.forward_block(obs, n, scratch);
        for i in 0..n {
            let lg = &scratch.logits[i * nl..(i + 1) * nl];
            let mut rng = CounterRng::derive2(seed, (lane0 + i) as u64, t as u64);
            logp[i] = self.heads.sample(&mut rng, lg, &mut actions[i * p..(i + 1) * p]);
        }
        values.copy_from_slice(&scratch.values[..n]);
    }

    /// Greedy (argmax-per-head) decode for one lane — the fused/eval
    /// counterpart of [`Learner::sample_lane`] (`&self`, zero allocation).
    /// Returns the value estimate.
    pub fn greedy_lane(&self, obs: &[f32], action: &mut [usize], scratch: &mut MlpScratch) -> f32 {
        self.mlp.forward_row(obs, scratch);
        self.heads.greedy(&scratch.logits, action);
        scratch.values[0]
    }

    /// Lane-blocked greedy decode — [`Learner::sample_block`]'s eval
    /// counterpart (one blocked forward, per-row argmax, no RNG).
    pub fn greedy_block(
        &self,
        obs: &[f32],
        actions: &mut [usize],
        values: &mut [f32],
        scratch: &mut MlpScratch,
    ) {
        let n = values.len();
        let nl = self.heads.n_logits;
        let p = self.n_ports();
        debug_assert_eq!(obs.len(), n * self.obs_dim);
        debug_assert_eq!(actions.len(), n * p);
        self.mlp.forward_block(obs, n, scratch);
        for i in 0..n {
            let lg = &scratch.logits[i * nl..(i + 1) * nl];
            self.heads.greedy(lg, &mut actions[i * p..(i + 1) * p]);
        }
        values.copy_from_slice(&scratch.values[..n]);
    }

    /// Greedy (argmax-per-head) action for a single observation row.
    /// Convenience wrapper over [`Learner::greedy_lane`] for callers
    /// without a long-lived scratch; allocates one scratch per call.
    pub fn greedy_action(&self, obs: &[f32], action: &mut [usize]) {
        let mut scratch = self.make_scratch();
        self.greedy_lane(obs, action, &mut scratch);
    }

    /// Full PPO update over filled rollout buffers (bootstrap forward +
    /// GAE + minibatched clipped-surrogate epochs). Returns
    /// `(mean total loss, mean entropy)` over all minibatch updates.
    ///
    /// This is the serial entry point; it runs the SAME chunked
    /// formulation as [`Learner::update_sharded`] inline on the caller
    /// thread, so the two are bit-identical by construction.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        hp: &PpoParams,
        rng: &mut Rng,
        n_envs: usize,
        t_len: usize,
        obs_buf: &[f32],
        act_buf: &[usize],
        logp_buf: &[f32],
        val_buf: &[f32],
        rew_buf: &[f32],
        done_buf: &[f32],
    ) -> (f32, f32) {
        self.update_sharded(
            hp, rng, None, n_envs, t_len, obs_buf, act_buf, logp_buf, val_buf, rew_buf,
            done_buf,
        )
    }

    /// [`Learner::update`] with the minibatch forward/backward sharded
    /// over a [`WorkerPool`]: each minibatch splits into fixed
    /// [`UPDATE_CHUNK_ROWS`]-row gradient chunks strided across the pool
    /// lanes (per-lane scratch, per-chunk accumulators), reduced in fixed
    /// order on the caller, where Adam is applied once. Bit-identical to
    /// the serial [`Learner::update`] for ANY pool width — see
    /// [`update_sharded_many`] for the contract (and for updating several
    /// family learners through one pooled dispatch).
    #[allow(clippy::too_many_arguments)]
    pub fn update_sharded(
        &mut self,
        hp: &PpoParams,
        rng: &mut Rng,
        pool: Option<&WorkerPool>,
        n_envs: usize,
        t_len: usize,
        obs_buf: &[f32],
        act_buf: &[usize],
        logp_buf: &[f32],
        val_buf: &[f32],
        rew_buf: &[f32],
        done_buf: &[f32],
    ) -> (f32, f32) {
        let batch = UpdateBatch {
            n_envs,
            t_len,
            obs: obs_buf,
            act: act_buf,
            logp: logp_buf,
            val: val_buf,
            rew: rew_buf,
            done: done_buf,
        };
        update_sharded_many(
            std::slice::from_mut(self),
            hp,
            rng,
            pool,
            std::slice::from_ref(&batch),
        )[0]
    }
}

/// One slot of [`PpoTrainer`]'s double buffer: all seven rollout/policy
/// buffers of one iteration. With `--overlap on` two slots ping-pong —
/// the caller consumes slot `cur` while the pool's pipeline lane streams
/// the next iteration's fused rollout into the other. Every buffer is
/// fully overwritten by each rollout, so reuse is bitwise inert.
struct TrainerSlot {
    obs: Vec<f32>,
    act: Vec<usize>,
    logp: Vec<f32>,
    val: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<f32>,
    profit: Vec<f32>,
}

impl TrainerSlot {
    fn new(e: usize, d: usize, n_ports: usize, t_len: usize) -> TrainerSlot {
        let bsz = e * t_len;
        TrainerSlot {
            // obs has one extra row: row t_len is the bootstrap observation.
            obs: vec![0f32; (t_len + 1) * e * d],
            act: vec![0usize; bsz * n_ports],
            logp: vec![0f32; bsz],
            val: vec![0f32; bsz],
            rew: vec![0f32; bsz],
            done: vec![0f32; bsz],
            profit: vec![0f32; bsz],
        }
    }

    fn views(&mut self) -> (RolloutBuffers<'_>, PolicyRollout<'_>) {
        (
            RolloutBuffers {
                obs: &mut self.obs,
                rewards: &mut self.rew,
                dones: &mut self.done,
                profits: &mut self.profit,
            },
            PolicyRollout {
                actions: &mut self.act,
                logp: &mut self.logp,
                values: &mut self.val,
            },
        )
    }
}

/// The CPU PPO trainer (comparator): one [`Learner`] over one
/// [`VectorEnv`] batch.
pub struct PpoTrainer {
    pub cfg: PpoParams,
    pub venv: VectorEnv,
    pub learner: Learner,
    pub rng: Rng,
    /// Per-lane running episode return (mirrors each lane's `ep_return`;
    /// used to report completed-episode returns without querying the env
    /// inside the fused rollout).
    running_return: Vec<f32>,
    pub env_steps: usize,
    /// Double-buffer slots, allocated lazily (one for barrier mode, two
    /// once overlap ever prefetches) and reused every iteration.
    slots: Vec<TrainerSlot>,
    /// Which slot the next update consumes; the other (when it exists) is
    /// the pipelined prefetch target.
    cur: usize,
    /// True when slot `cur` already holds the next iteration's rollout.
    pending: bool,
}

impl PpoTrainer {
    /// `tables` is built once and shared across all `num_envs` lanes (and
    /// later greedy-eval envs) via `Arc` — no per-env table rebuild/clone.
    pub fn new(
        cfg: PpoParams,
        station: StationConfig,
        tables: impl Into<Arc<ScenarioTables>>,
        seed: u64,
    ) -> PpoTrainer {
        let mut rng = Rng::new(seed);
        let seeds: Vec<u64> = (0..cfg.num_envs)
            .map(|i| seed ^ (i as u64 * 7919 + 13))
            .collect();
        let mut venv = VectorEnv::with_seeds(
            station,
            vec![tables.into()],
            vec![0; cfg.num_envs],
            &seeds,
        );
        venv.set_threads(cfg.threads);
        let learner = Learner::new(&mut rng, venv.obs_dim(), cfg.hidden, venv.action_nvec());
        PpoTrainer {
            running_return: vec![0.0; cfg.num_envs],
            cfg,
            venv,
            learner,
            rng,
            env_steps: 0,
            slots: Vec::new(),
            cur: 0,
            pending: false,
        }
    }

    /// One PPO iteration (rollout + update). Mirrors ppo.py::train_iter.
    /// With `cfg.overlap` set, the NEXT iteration's rollout is prefetched
    /// on the pool's pipeline lane while this iteration's accounting and
    /// stats assembly run on the caller thread — bit-identical to the
    /// barrier path, only wall-clock changes.
    pub fn iteration(&mut self) -> TrainStats {
        let overlap = self.cfg.overlap;
        self.iteration_inner(overlap)
    }

    /// The last iteration of a run: identical to [`Self::iteration`] but
    /// never prefetches, so N iteration calls perform exactly N rollouts.
    pub fn final_iteration(&mut self) -> TrainStats {
        self.iteration_inner(false)
    }

    fn iteration_inner(&mut self, prefetch: bool) -> TrainStats {
        let e = self.cfg.num_envs;
        let t_len = self.cfg.rollout_steps;
        let n_ports = self.learner.n_ports();
        let bsz = e * t_len;
        let d = self.learner.obs_dim;
        let want_slots = if prefetch { 2 } else { 1 };
        while self.slots.len() < want_slots {
            self.slots.push(TrainerSlot::new(e, d, n_ports, t_len));
        }

        // ---- rollout ------------------------------------------------------
        // One fused pass: each pool shard forwards + samples its own
        // lanes' policies inside the same dispatch that steps them (no
        // serial caller-thread forward), writing actions/logp/values and
        // obs/rewards/dones/profits directly into slot `cur`'s buffers.
        // A fresh per-iteration sampling seed keys the per-(lane, t)
        // counter streams. Skipped when the previous iteration already
        // streamed this rollout into slot `cur` via the pipeline lane.
        if !self.pending {
            let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Rollout);
            let PpoTrainer { venv, learner, rng, slots, cur, .. } = self;
            let policy_seed = rng.next_u64();
            let (mut bufs, mut pol) = slots[*cur].views();
            venv.rollout_fused(t_len, &mut bufs, &mut pol, learner, policy_seed, false);
        }
        self.pending = false;
        self.env_steps += bsz;

        // ---- update -------------------------------------------------------
        // Sharded over the same persistent pool the rollout ran on
        // (`--threads` capped); bit-identical to a serial update.
        let (total_loss, entropy) = {
            let pool = self
                .venv
                .shared_pool(update_shard_demand(bsz, self.cfg.n_minibatches));
            let PpoTrainer { cfg, learner, rng, slots, cur, .. } = self;
            let slot = &slots[*cur];
            learner.update_sharded(
                cfg, rng, pool.as_deref(), e, t_len,
                &slot.obs, &slot.act, &slot.logp, &slot.val, &slot.rew, &slot.done,
            )
        };

        // ---- prefetch + overlapped tail -----------------------------------
        // The prefetch launches AFTER the update (it samples from the
        // post-update weights — same as the barrier path), so the overlap
        // window covers episode accounting and stats assembly below.
        let PpoTrainer {
            venv, learner, rng, running_return, slots, cur, pending, ..
        } = self;
        let mut guard = None;
        if prefetch {
            if let Some(pool) = venv.rollout_pool() {
                // Next iteration's policy seed — drawn HERE, right where
                // the barrier path would draw it, so the global rng
                // sequence is identical in both modes.
                let policy_seed = rng.next_u64();
                let (a, b) = slots.split_at_mut(1);
                let next = if *cur == 0 { &mut b[0] } else { &mut a[0] };
                let learner: &Learner = learner;
                let venv = &mut *venv;
                // SAFETY: until `guard` joins below, the pipeline lane
                // owns `venv`, slot `next`, and a shared view of
                // `learner`. The overlapped tail only reads slot `cur`
                // and mutates `running_return` / stats locals, and the
                // guard joins before this function returns (its Drop
                // joins even on unwind).
                guard = Some(unsafe {
                    pool.run_pipelined(move || {
                        let _span =
                            crate::telemetry::scope(crate::telemetry::SpanKind::Rollout);
                        let (mut bufs, mut pol) = next.views();
                        venv.rollout_fused(
                            t_len, &mut bufs, &mut pol, learner, policy_seed, false,
                        );
                    })
                });
            }
        }

        let _window = guard
            .is_some()
            .then(|| crate::telemetry::scope(crate::telemetry::SpanKind::PipelineOverlap));
        let slot = &slots[*cur];

        // Episode accounting from the filled buffers (off the hot loop).
        let mut profit_sum = 0f64;
        let mut comp_returns: Vec<f32> = Vec::new();
        for t in 0..t_len {
            for j in 0..e {
                let idx = t * e + j;
                profit_sum += slot.profit[idx] as f64;
                running_return[j] += slot.rew[idx];
                if slot.done[idx] > 0.5 {
                    comp_returns.push(running_return[j]);
                    running_return[j] = 0.0;
                }
            }
        }

        let stats = TrainStats {
            mean_reward: slot.rew.iter().sum::<f32>() / bsz as f32,
            mean_profit: (profit_sum / bsz as f64) as f32,
            total_loss,
            entropy,
            completed_return_mean: if comp_returns.is_empty() {
                0.0
            } else {
                comp_returns.iter().sum::<f32>() / comp_returns.len() as f32
            },
        };

        if let Some(g) = guard {
            g.join();
            *cur ^= 1;
            *pending = true;
        }
        stats
    }

    /// Greedy evaluation for one full episode; returns total reward/profit.
    /// Reuses the training envs' shared scenario tables (Arc) — no rebuild.
    pub fn eval_episode(&mut self, seed: u64) -> (f32, f32) {
        let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Eval);
        let mut env =
            ScalarEnv::new(self.venv.cfg.clone(), self.venv.tables_arc(0), seed);
        let mut obs = vec![0f32; self.learner.obs_dim];
        let mut action = vec![0usize; self.learner.n_ports()];
        let mut scratch = self.learner.make_scratch();
        let mut tot_r = 0f32;
        let mut tot_p = 0f32;
        for _ in 0..crate::env::scalar::STEPS_PER_EPISODE {
            env.observe(&mut obs);
            self.learner.greedy_lane(&obs, &mut action, &mut scratch);
            let info = env.step(&action);
            tot_r += info.reward;
            tot_p += info.profit;
        }
        (tot_r, tot_p)
    }
}

/// Measure PPO minibatch-update throughput at batch size `b`: fill one
/// fused rollout's buffers (T = 32, [`BENCH_POLICY_HIDDEN`]-wide net),
/// then repeatedly run the full update over them — serial on the caller
/// thread, or sharded over the env's worker pool. One warm pass then one
/// timed pass (same protocol as
/// [`crate::env::vector::measure_throughput`]). Returns
/// `(samples/sec, seconds per 100k samples)`, where one update consumes
/// `B * T * update_epochs` samples.
pub fn measure_update_throughput(
    tables: Arc<ScenarioTables>,
    b: usize,
    threads: usize,
    sharded: bool,
    budget: usize,
) -> (f64, f64) {
    use crate::env::vector::BENCH_POLICY_HIDDEN;

    let t_len = 32usize;
    let hp = PpoParams {
        num_envs: b,
        rollout_steps: t_len,
        hidden: BENCH_POLICY_HIDDEN,
        threads,
        ..Default::default()
    };
    let mut venv = VectorEnv::new(StationConfig::default(), tables, b, 13);
    venv.set_threads(threads);
    let (d, p) = (venv.obs_dim(), venv.n_ports());
    let mut rng = Rng::new(29);
    let mut learner = Learner::new(&mut rng, d, hp.hidden, venv.action_nvec());
    let bsz = b * t_len;
    let mut obs_buf = vec![0f32; (t_len + 1) * b * d];
    let mut rew_buf = vec![0f32; bsz];
    let mut done_buf = vec![0f32; bsz];
    let mut profit_buf = vec![0f32; bsz];
    let mut act_buf = vec![0usize; bsz * p];
    let mut logp_buf = vec![0f32; bsz];
    let mut val_buf = vec![0f32; bsz];
    {
        let mut bufs = RolloutBuffers {
            obs: &mut obs_buf,
            rewards: &mut rew_buf,
            dones: &mut done_buf,
            profits: &mut profit_buf,
        };
        let mut pol = PolicyRollout {
            actions: &mut act_buf,
            logp: &mut logp_buf,
            values: &mut val_buf,
        };
        venv.rollout_fused(t_len, &mut bufs, &mut pol, &learner, 7, false);
    }
    let pool = if sharded {
        venv.shared_pool(update_shard_demand(bsz, hp.n_minibatches))
    } else {
        None
    };
    let reps = (budget / bsz.max(1)).clamp(2, 500);
    let samples = (bsz * hp.update_epochs.max(1) * reps) as f64;
    let mut pass = |learner: &mut Learner, rng: &mut Rng| {
        for _ in 0..reps {
            learner.update_sharded(
                &hp, rng, pool.as_deref(), b, t_len,
                &obs_buf, &act_buf, &logp_buf, &val_buf, &rew_buf, &done_buf,
            );
        }
    };
    pass(&mut learner, &mut rng); // warm (pool already built by shared_pool)
    let t0 = std::time::Instant::now();
    pass(&mut learner, &mut rng);
    let el = t0.elapsed().as_secs_f64();
    (samples / el, el * 100_000.0 / samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_chunking_demand_matches_minibatch_partition() {
        // bsz 900 over 4 minibatches: chunks of 225 rows -> 4 chunks each.
        assert_eq!(update_shard_demand(900, 4), 4);
        // Tiny batches never demand more than one lane.
        assert_eq!(update_shard_demand(10, 4), 1);
        assert_eq!(update_shard_demand(0, 4), 1);
        // One minibatch of 129 rows -> 3 chunks.
        assert_eq!(update_shard_demand(129, 1), 3);
    }

    /// The gradient tree reduction has a FIXED shape: for three partials
    /// the result is exactly (g0 + g1) + g2 — and it never depends on
    /// which pool lane produced which partial (they are combined by chunk
    /// index alone).
    #[test]
    fn tree_reduction_order_is_fixed() {
        let mut rng = Rng::new(3);
        let mlp = Mlp::new(&mut rng, 2, 3, 2);
        let mk = |seed: f32| {
            let mut g = mlp.zero_grads();
            for (k, v) in g.as_slices_mut().into_iter().enumerate() {
                for (i, x) in v.iter_mut().enumerate() {
                    // Values chosen so float addition order is observable.
                    *x = (seed + k as f32 * 0.1 + i as f32) * 1.000_000_1;
                }
            }
            g
        };
        let mut parts = vec![mk(1.0), mk(2.7), mk(-0.3)];
        let mut want = mk(1.0);
        want.add_from(&parts[1]);
        want.add_from(&parts[2]);
        tree_reduce_grads(&mut parts);
        for (a, b) in parts[0].as_slices().into_iter().zip(want.as_slices()) {
            assert_eq!(a, b);
        }
        let mut stats = vec![(1.0f32, 2.0f32), (0.5, 0.25), (0.125, -1.0)];
        tree_reduce_stats(&mut stats);
        assert_eq!(stats[0], ((1.0 + 0.5) + 0.125, (2.0 + 0.25) + -1.0));
    }

    #[test]
    fn gae_matches_hand_rolled_two_steps() {
        // T=2, E=1, no dones.
        let (adv, tgt) = gae(&[1.0, 1.0], &[0.5, 0.5], &[0.0, 0.0], &[0.5], 1, 0.9, 0.8);
        let d1 = 1.0 + 0.9 * 0.5 - 0.5; // 0.95
        let d0 = 1.0 + 0.9 * 0.5 - 0.5 + 0.9 * 0.8 * 0.95;
        assert!((adv[1] - d1).abs() < 1e-6);
        assert!((adv[0] - d0).abs() < 1e-6);
        assert!((tgt[0] - (adv[0] + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn gae_cuts_at_done() {
        let (adv, _) = gae(&[1.0, 1.0], &[0.0, 0.0], &[1.0, 0.0], &[9.0], 1, 0.9, 0.8);
        // t=0 terminal: delta = r - v = 1, no bootstrap, no propagation.
        assert!((adv[0] - 1.0).abs() < 1e-6);
    }

    /// Regression (ISSUE 4): greedy decode must not panic on NaN logits.
    /// `partial_cmp().unwrap()` blew up the whole eval on the first NaN;
    /// `total_cmp` keeps it total (NaN can win the argmax, never panic).
    #[test]
    fn greedy_decode_survives_nan_logits() {
        let heads = Heads::new(vec![3, 2]);
        let logits = vec![0.1, f32::NAN, 0.3, 0.5, 0.2];
        let mut action = vec![0usize; 2];
        heads.greedy(&logits, &mut action); // must not panic
        assert!(action[0] < 3 && action[1] < 2);
        // Clean rows still pick the true per-head argmax.
        let clean = vec![0.1, 0.9, 0.3, 0.2, 0.5];
        heads.greedy(&clean, &mut action);
        assert_eq!(action, vec![1, 1]);
    }

    /// Regression (ISSUE 4): minibatch chunks must partition 0..bsz — the
    /// old truncating `bsz / n` split dropped `bsz % n` samples per epoch.
    #[test]
    fn minibatch_bounds_cover_every_sample_once() {
        // (480, 2) is the live fleet-demo shape; (481, 2) the odd trigger.
        for (bsz, n) in [(7usize, 2usize), (480, 2), (481, 2), (10, 3), (5, 8), (1, 1)] {
            let bounds = minibatch_bounds(bsz, n);
            assert_eq!(bounds.len(), n);
            let mut seen = vec![false; bsz];
            for &(lo, hi) in &bounds {
                assert!(lo <= hi && hi <= bsz, "bsz={bsz} n={n}: bad chunk {lo}..{hi}");
                for i in lo..hi {
                    assert!(!seen[i], "bsz={bsz} n={n}: index {i} visited twice");
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&x| x), "bsz={bsz} n={n}: samples dropped");
            let sizes: Vec<usize> = bounds.iter().map(|&(lo, hi)| hi - lo).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "bsz={bsz} n={n}: uneven chunks {sizes:?}");
        }
    }

    /// Every permuted index lands in exactly one minibatch per epoch —
    /// the composition `permutation + minibatch_bounds` the update uses.
    #[test]
    fn update_epoch_visits_every_sample_once() {
        let (bsz, n) = (21usize, 2usize); // odd bsz, the fleet's n_minibatches
        let mut rng = Rng::new(13);
        let perm = rng.permutation(bsz);
        let mut seen = vec![0usize; bsz];
        for (lo, hi) in minibatch_bounds(bsz, n) {
            for &i in &perm[lo..hi] {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "coverage: {seen:?}");
    }

    /// Fused per-(lane, t) sampling is a pure function of
    /// (weights, obs, seed, lane, t): repeated calls agree bitwise, and it
    /// matches a hand-rolled forward_row + derive2 + Heads::sample.
    #[test]
    fn sample_lane_is_deterministic_and_matches_components() {
        let mut rng = Rng::new(3);
        let learner = Learner::new(&mut rng, 5, 16, vec![4, 3]);
        let obs: Vec<f32> = (0..5).map(|_| rng.normal()).collect();
        let mut a1 = vec![0usize; 2];
        let mut a2 = vec![0usize; 2];
        let mut s1 = learner.make_scratch();
        let mut s2 = learner.make_scratch();
        let (lp1, v1) = learner.sample_lane(7, 3, 99, &obs, &mut a1, &mut s1);
        let (lp2, v2) = learner.sample_lane(7, 3, 99, &obs, &mut a2, &mut s2);
        assert_eq!((a1.clone(), lp1, v1), (a2, lp2, v2));
        // Hand-rolled equivalent.
        let mut s3 = learner.make_scratch();
        learner.mlp.forward_row(&obs, &mut s3);
        let mut crng = CounterRng::derive2(99, 3, 7);
        let mut a3 = vec![0usize; 2];
        let lp3 = learner.heads.sample(&mut crng, &s3.logits, &mut a3);
        assert_eq!(a1, a3);
        assert_eq!(lp1, lp3);
        assert_eq!(v1, s3.values[0]);
        // Different (lane, t) moves the stream for at least some steps.
        let streams: Vec<Vec<usize>> = (0..16)
            .map(|t| {
                let mut a = vec![0usize; 2];
                let mut s = learner.make_scratch();
                learner.sample_lane(t, 0, 99, &obs, &mut a, &mut s);
                a
            })
            .collect();
        assert!(streams.windows(2).any(|w| w[0] != w[1]), "t never changed the sample");
    }

    /// The lane-blocked shard path (ISSUE 6) must be bit-identical to
    /// per-lane sampling: one block forward + per-(lane, t) counter
    /// streams == N row forwards + the same streams, for sample and
    /// greedy alike — including at a non-zero `lane0` offset.
    #[test]
    fn sample_block_matches_per_lane_sampling_bitwise() {
        let mut rng = Rng::new(17);
        let (d, n, lane0, t, seed) = (6usize, 9usize, 5usize, 11usize, 0xBEEFu64);
        let learner = Learner::new(&mut rng, d, 16, vec![4, 3]);
        let p = learner.n_ports();
        let obs: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let mut blk = learner.make_scratch();
        let mut acts_b = vec![0usize; n * p];
        let mut logp_b = vec![0f32; n];
        let mut vals_b = vec![0f32; n];
        learner.sample_block(t, lane0, seed, &obs, &mut acts_b, &mut logp_b, &mut vals_b, &mut blk);
        let mut row = learner.make_scratch();
        for i in 0..n {
            let mut a = vec![0usize; p];
            let (lp, v) = learner.sample_lane(
                t, lane0 + i, seed, &obs[i * d..(i + 1) * d], &mut a, &mut row,
            );
            assert_eq!(a, acts_b[i * p..(i + 1) * p], "lane {i} actions");
            assert_eq!(lp, logp_b[i], "lane {i} logp");
            assert_eq!(v, vals_b[i], "lane {i} value");
        }
        // Greedy counterpart.
        let mut acts_g = vec![0usize; n * p];
        let mut vals_g = vec![0f32; n];
        learner.greedy_block(&obs, &mut acts_g, &mut vals_g, &mut blk);
        for i in 0..n {
            let mut a = vec![0usize; p];
            let v = learner.greedy_lane(&obs[i * d..(i + 1) * d], &mut a, &mut row);
            assert_eq!(a, acts_g[i * p..(i + 1) * p], "lane {i} greedy actions");
            assert_eq!(v, vals_g[i], "lane {i} greedy value");
        }
    }

    #[test]
    fn heads_sample_and_logp_consistent() {
        let heads = Heads::new(vec![3, 4]);
        let mut rng = Rng::new(5);
        let logits = vec![0.1, 0.5, -0.2, 1.0, 0.0, -1.0, 0.3];
        let mut action = vec![0usize; 2];
        let lp = heads.sample(&mut rng, &logits, &mut action);
        let mut d1 = vec![0f32; 7];
        let mut d2 = vec![0f32; 7];
        let (lp2, ent) = heads.logp_entropy(&logits, &action, &mut d1, &mut d2);
        assert!((lp - lp2).abs() < 1e-5);
        assert!(ent > 0.0);
    }

    #[test]
    fn entropy_gradient_finite_difference() {
        let heads = Heads::new(vec![4]);
        let logits = vec![0.3f32, -0.1, 0.7, 0.0];
        let mut dlp = vec![0f32; 4];
        let mut dent = vec![0f32; 4];
        let (_, _) = heads.logp_entropy(&logits, &[2], &mut dlp, &mut dent);
        let eps = 1e-3f32;
        for k in 0..4 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let (_, e_p) = heads.logp_entropy(&lp, &[2], &mut vec![0f32; 4], &mut vec![0f32; 4]);
            let mut lm = logits.clone();
            lm[k] -= eps;
            let (_, e_m) = heads.logp_entropy(&lm, &[2], &mut vec![0f32; 4], &mut vec![0f32; 4]);
            let fd = (e_p - e_m) / (2.0 * eps);
            assert!((fd - dent[k]).abs() < 1e-3, "k={k} fd={fd} an={}", dent[k]);
        }
    }

    #[test]
    fn logp_gradient_finite_difference() {
        let heads = Heads::new(vec![3, 2]);
        let logits = vec![0.3f32, -0.1, 0.7, 0.2, -0.4];
        let act = [1usize, 0];
        let mut dlp = vec![0f32; 5];
        let mut dent = vec![0f32; 5];
        heads.logp_entropy(&logits, &act, &mut dlp, &mut dent);
        let eps = 1e-3f32;
        for k in 0..5 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let (l_p, _) = heads.logp_entropy(&lp, &act, &mut vec![0f32; 5], &mut vec![0f32; 5]);
            let mut lm = logits.clone();
            lm[k] -= eps;
            let (l_m, _) = heads.logp_entropy(&lm, &act, &mut vec![0f32; 5], &mut vec![0f32; 5]);
            let fd = (l_p - l_m) / (2.0 * eps);
            assert!((fd - dlp[k]).abs() < 1e-3, "k={k} fd={fd} an={}", dlp[k]);
        }
    }
}
