//! Per-iteration aggregation of drained telemetry: per-stage p50/p99,
//! per-shard busy time, the per-epoch imbalance ratio, and pool
//! utilization — plus the JSONL record (`runs/telemetry.jsonl`) and the
//! human-readable `--telemetry` summary.

use std::collections::BTreeMap;

use crate::util::json::{obj, Json};

use super::{Counters, Drained, SpanKind, SpanRec};

/// One stage's duration distribution within a drain window.
#[derive(Clone, Debug)]
pub struct StageStats {
    pub kind: SpanKind,
    pub count: usize,
    pub total_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Aggregate view of one training iteration's telemetry.
#[derive(Clone, Debug)]
pub struct IterationReport {
    pub iter: usize,
    /// Caller-measured wallclock for the iteration (ms).
    pub wall_ms: f64,
    /// One entry per [`SpanKind::STAGES`] member, in display order
    /// (count 0 when a stage did not run this iteration).
    pub stages: Vec<StageStats>,
    /// Summed `PoolShard` busy time per pool lane (index = lane).
    pub shard_busy_ms: Vec<f64>,
    /// Mean over dispatch epochs of (slowest shard / fastest shard);
    /// 1.0 when no multi-shard dispatch ran.
    pub imbalance_mean: f64,
    /// Worst single-epoch imbalance ratio.
    pub imbalance_max: f64,
    /// Total shard busy time / (dispatch envelope × lanes seen), in
    /// [0, 1]; how much of the pool's capacity the dispatches used.
    pub utilization: f64,
    /// Fraction of the iteration's wallclock spent inside the
    /// [`SpanKind::PipelineOverlap`] window — caller-side work done while
    /// the next rollout streamed on the pipeline lane (`--overlap on`);
    /// 0 on the barrier path, which opens no window.
    pub overlap_frac: f64,
    pub counters: Counters,
    pub dropped_spans: u64,
}

/// Nearest-rank percentile of an ascending-sorted slice (0.0 if empty).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

impl IterationReport {
    pub fn from_drained(iter: usize, wall_ms: f64, d: &Drained) -> IterationReport {
        let stages = SpanKind::STAGES
            .iter()
            .map(|&kind| {
                let mut durs: Vec<f64> = d
                    .spans
                    .iter()
                    .filter(|s| s.kind == kind)
                    .map(|s| ms(s.dur_ns))
                    .collect();
                durs.sort_by(|a, b| a.total_cmp(b));
                StageStats {
                    kind,
                    count: durs.len(),
                    total_ms: durs.iter().sum(),
                    p50_ms: percentile(&durs, 50.0),
                    p99_ms: percentile(&durs, 99.0),
                }
            })
            .collect();

        let pool: Vec<&SpanRec> =
            d.spans.iter().filter(|s| s.kind == SpanKind::PoolShard).collect();

        let n_lanes = pool.iter().map(|s| s.lane as usize + 1).max().unwrap_or(0);
        let mut shard_busy_ms = vec![0.0; n_lanes];
        for s in &pool {
            shard_busy_ms[s.lane as usize] += ms(s.dur_ns);
        }

        // Imbalance: within each dispatch epoch (seq), slowest/fastest
        // shard. Single-shard dispatches carry no imbalance signal.
        let mut by_seq: BTreeMap<u64, (f64, f64)> = BTreeMap::new();
        for s in &pool {
            let dur = ms(s.dur_ns);
            let e = by_seq.entry(s.seq).or_insert((f64::INFINITY, 0.0));
            e.0 = e.0.min(dur);
            e.1 = e.1.max(dur);
        }
        let mut count_by_seq: BTreeMap<u64, usize> = BTreeMap::new();
        for s in &pool {
            *count_by_seq.entry(s.seq).or_insert(0) += 1;
        }
        let ratios: Vec<f64> = by_seq
            .iter()
            .filter(|(seq, _)| count_by_seq.get(*seq).copied().unwrap_or(0) >= 2)
            .map(|(_, (lo, hi))| if *lo > 0.0 { hi / lo } else { 1.0 })
            .collect();
        let imbalance_mean = if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        };
        let imbalance_max = ratios.iter().copied().fold(1.0f64, f64::max);

        // Utilization: busy time over the envelope that the dispatches
        // actually spanned, normalized by distinct lanes seen.
        let utilization = if pool.is_empty() {
            0.0
        } else {
            let t_min = pool.iter().map(|s| s.t0_ns).min().unwrap_or(0);
            let t_max = pool.iter().map(|s| s.t0_ns + s.dur_ns).max().unwrap_or(0);
            let envelope = ms(t_max.saturating_sub(t_min));
            let busy: f64 = shard_busy_ms.iter().sum();
            let lanes_seen = {
                let mut lanes: Vec<u32> = pool.iter().map(|s| s.lane).collect();
                lanes.sort_unstable();
                lanes.dedup();
                lanes.len()
            };
            if envelope > 0.0 && lanes_seen > 0 {
                (busy / (envelope * lanes_seen as f64)).min(1.0)
            } else {
                0.0
            }
        };

        let overlap_ms: f64 = d
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::PipelineOverlap)
            .map(|s| ms(s.dur_ns))
            .sum();
        let overlap_frac =
            if wall_ms > 0.0 { (overlap_ms / wall_ms).clamp(0.0, 1.0) } else { 0.0 };

        IterationReport {
            iter,
            wall_ms,
            stages,
            shard_busy_ms,
            imbalance_mean,
            imbalance_max,
            utilization,
            overlap_frac,
            counters: d.counters,
            dropped_spans: d.dropped,
        }
    }

    /// The JSONL record: one line per iteration in `runs/telemetry.jsonl`.
    pub fn to_json(&self) -> Json {
        let stages = Json::Obj(
            self.stages
                .iter()
                .map(|st| {
                    (
                        st.kind.label().to_string(),
                        obj(vec![
                            ("count", Json::Num(st.count as f64)),
                            ("total_ms", Json::Num(st.total_ms)),
                            ("p50_ms", Json::Num(st.p50_ms)),
                            ("p99_ms", Json::Num(st.p99_ms)),
                        ]),
                    )
                })
                .collect(),
        );
        obj(vec![
            ("type", Json::Str("telemetry".to_string())),
            ("iter", Json::Num(self.iter as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            ("overlap_frac", Json::Num(self.overlap_frac)),
            ("stages", stages),
            (
                "shards",
                obj(vec![
                    (
                        "busy_ms",
                        Json::Arr(
                            self.shard_busy_ms.iter().map(|&b| Json::Num(b)).collect(),
                        ),
                    ),
                    ("imbalance_mean", Json::Num(self.imbalance_mean)),
                    ("imbalance_max", Json::Num(self.imbalance_max)),
                    ("utilization", Json::Num(self.utilization)),
                ]),
            ),
            (
                "counters",
                obj(vec![
                    ("env_steps", Json::Num(self.counters.env_steps as f64)),
                    ("cars_arrived", Json::Num(self.counters.cars_arrived as f64)),
                    ("cars_departed", Json::Num(self.counters.cars_departed as f64)),
                    ("grid_kwh", Json::Num(self.counters.grid_kwh)),
                    ("curtailed_kwh", Json::Num(self.counters.curtailed_kwh)),
                    (
                        "nan_guard_trips",
                        Json::Num(self.counters.nan_guard_trips as f64),
                    ),
                    (
                        "minibatch_rows",
                        Json::Num(self.counters.minibatch_rows as f64),
                    ),
                ]),
            ),
            ("dropped_spans", Json::Num(self.dropped_spans as f64)),
        ])
    }

    /// The `--telemetry` console summary (multi-line, stderr-bound).
    pub fn text_summary(&self) -> String {
        let mut out = format!(
            "telemetry iter {}: wall {:.1} ms, pool util {:.1}%, \
             overlap {:.1}%, imbalance mean {:.2}x max {:.2}x, dropped {}",
            self.iter,
            self.wall_ms,
            self.utilization * 100.0,
            self.overlap_frac * 100.0,
            self.imbalance_mean,
            self.imbalance_max,
            self.dropped_spans,
        );
        for st in &self.stages {
            if st.count == 0 {
                continue;
            }
            out.push_str(&format!(
                "\n  {:<14} n={:<5} total {:>9.2} ms  p50 {:>8.3} ms  p99 {:>8.3} ms",
                st.kind.label(),
                st.count,
                st.total_ms,
                st.p50_ms,
                st.p99_ms,
            ));
        }
        let c = &self.counters;
        out.push_str(&format!(
            "\n  counters: env_steps={} arrived={} departed={} grid_kwh={:.2} \
             curtailed_kwh={:.2} nan_trips={} mb_rows={}",
            c.env_steps,
            c.cars_arrived,
            c.cars_departed,
            c.grid_kwh,
            c.curtailed_kwh,
            c.nan_guard_trips,
            c.minibatch_rows,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, lane: u32, seq: u64, t0_ns: u64, dur_ns: u64) -> SpanRec {
        SpanRec { kind, lane, seq, t0_ns, dur_ns }
    }

    fn sample_drain() -> Drained {
        let mut d = Drained::default();
        // One 2-shard dispatch: lane 0 busy 4 ms, lane 1 busy 2 ms.
        d.spans.push(span(SpanKind::PoolShard, 0, 1, 0, 4_000_000));
        d.spans.push(span(SpanKind::PoolShard, 1, 1, 0, 2_000_000));
        // A second dispatch, balanced.
        d.spans.push(span(SpanKind::PoolShard, 0, 2, 5_000_000, 3_000_000));
        d.spans.push(span(SpanKind::PoolShard, 1, 2, 5_000_000, 3_000_000));
        d.spans.push(span(SpanKind::EnvStep, 0, 1, 100, 1_000_000));
        d.spans.push(span(SpanKind::EnvStep, 1, 1, 100, 3_000_000));
        d.spans.push(span(SpanKind::Rollout, 0, 0, 0, 8_000_000));
        // 4.5 ms of overlapped tail work while a prefetch streamed.
        d.spans.push(span(SpanKind::PipelineOverlap, 0, 0, 8_000_000, 4_500_000));
        d.counters.env_steps = 128;
        d.counters.grid_kwh = 2.25;
        d
    }

    #[test]
    fn report_covers_all_stages_and_shard_columns() {
        let d = sample_drain();
        let r = IterationReport::from_drained(3, 9.0, &d);
        assert_eq!(r.stages.len(), SpanKind::STAGES.len());
        let env = r.stages.iter().find(|s| s.kind == SpanKind::EnvStep).unwrap();
        assert_eq!(env.count, 2);
        assert!((env.total_ms - 4.0).abs() < 1e-9);
        assert!(env.p50_ms <= env.p99_ms);
        let adam = r.stages.iter().find(|s| s.kind == SpanKind::Adam).unwrap();
        assert_eq!(adam.count, 0, "absent stages report zero, not vanish");
        assert_eq!(r.shard_busy_ms.len(), 2);
        assert!((r.shard_busy_ms[0] - 7.0).abs() < 1e-9);
        assert!((r.shard_busy_ms[1] - 5.0).abs() < 1e-9);
        // Epoch 1 imbalance 2.0, epoch 2 imbalance 1.0.
        assert!((r.imbalance_mean - 1.5).abs() < 1e-9);
        assert!((r.imbalance_max - 2.0).abs() < 1e-9);
        // busy 12 ms over an 8 ms envelope × 2 lanes.
        assert!((r.utilization - 0.75).abs() < 1e-9);
        // 4.5 ms of PipelineOverlap over a 9 ms wall.
        assert!((r.overlap_frac - 0.5).abs() < 1e-9);
        assert_eq!(r.counters.env_steps, 128);
    }

    #[test]
    fn json_record_has_required_stage_keys() {
        let d = sample_drain();
        let r = IterationReport::from_drained(0, 1.0, &d);
        let j = r.to_json();
        let stages = j.get("stages").unwrap();
        for key in [
            "rollout",
            "policy-forward",
            "env-step",
            "grid-reduce",
            "update-chunks",
            "reduce",
            "adam",
            "eval",
            "pipeline-overlap",
        ] {
            let st = stages.get(key).unwrap_or_else(|| panic!("missing stage {key}"));
            assert!(st.get("p50_ms").unwrap().as_f64().is_some());
            assert!(st.get("p99_ms").unwrap().as_f64().is_some());
        }
        assert!(
            j.get("overlap_frac").unwrap().as_f64().is_some(),
            "the overlap-fraction column must land in the JSONL record"
        );
        let shards = j.get("shards").unwrap();
        assert!(shards.get("imbalance_mean").unwrap().as_f64().is_some());
        assert!(shards.get("utilization").unwrap().as_f64().is_some());
        assert_eq!(shards.get("busy_ms").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("counters").unwrap().get("env_steps").unwrap().as_usize(),
            Some(128)
        );
        assert!(
            j.get("counters").unwrap().get("curtailed_kwh").unwrap().as_f64().is_some(),
            "the grid-coupling counter must land in the JSONL record"
        );
        // The record round-trips through the in-tree parser (JSONL line).
        let line = j.to_string();
        assert!(!line.contains('\n'));
        assert_eq!(Json::parse(&line).unwrap(), j);
    }

    #[test]
    fn empty_drain_produces_neutral_report() {
        let d = Drained::default();
        let r = IterationReport::from_drained(0, 0.0, &d);
        assert_eq!(r.utilization, 0.0);
        assert_eq!(r.overlap_frac, 0.0);
        assert_eq!(r.imbalance_mean, 1.0);
        assert!(r.shard_busy_ms.is_empty());
        assert!(r.stages.iter().all(|s| s.count == 0));
        let _ = r.text_summary();
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 99.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }
}
