//! Chrome trace-event export (`--trace-out <file>`): every worker's
//! spans on a timeline, viewable in Perfetto (https://ui.perfetto.dev)
//! or chrome://tracing. One complete-event (`ph: "X"`) per span, with
//! the pool lane as the thread row and the dispatch sequence id in args.

use std::io::Write;
use std::path::Path;

use crate::util::json::{obj, Json};

use super::SpanRec;

/// Serialize spans (as drained across one or more iterations) into the
/// Chrome trace-event JSON format. Timestamps are microseconds since the
/// telemetry origin; `tid` is the pool lane (0 = the caller thread).
pub fn chrome_trace_json(spans: &[SpanRec]) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + 8);
    // Name the lane rows so Perfetto shows "lane 0 (caller)" etc.
    let mut lanes: Vec<u32> = spans.iter().map(|s| s.lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for lane in &lanes {
        let name = if *lane == 0 {
            "lane 0 (caller)".to_string()
        } else {
            format!("lane {lane}")
        };
        events.push(obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(*lane as f64)),
            ("args", obj(vec![("name", Json::Str(name))])),
        ]));
    }
    for s in spans {
        events.push(obj(vec![
            ("name", Json::Str(s.kind.label().to_string())),
            ("cat", Json::Str("telemetry".to_string())),
            ("ph", Json::Str("X".to_string())),
            ("ts", Json::Num(s.t0_ns as f64 / 1000.0)),
            ("dur", Json::Num(s.dur_ns as f64 / 1000.0)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(s.lane as f64)),
            ("args", obj(vec![("seq", Json::Num(s.seq as f64))])),
        ]));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Write the Chrome trace file (creating parent directories).
pub fn write_chrome_trace(path: &Path, spans: &[SpanRec]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(chrome_trace_json(spans).to_string().as_bytes())?;
    f.write_all(b"\n")?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::SpanKind;

    #[test]
    fn trace_json_is_valid_and_complete() {
        let spans = vec![
            SpanRec {
                kind: SpanKind::PoolShard,
                lane: 0,
                seq: 1,
                t0_ns: 1_000,
                dur_ns: 2_500,
            },
            SpanRec {
                kind: SpanKind::EnvStep,
                lane: 1,
                seq: 1,
                t0_ns: 1_200,
                dur_ns: 800,
            },
        ];
        let j = chrome_trace_json(&spans);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread_name metadata events + 2 span events.
        assert_eq!(events.len(), 4);
        let x: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(x.len(), 2);
        assert_eq!(x[0].get("name").unwrap().as_str(), Some("pool-shard"));
        assert_eq!(x[0].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(x[0].get("dur").unwrap().as_f64(), Some(2.5));
        assert_eq!(x[1].get("tid").unwrap().as_f64(), Some(1.0));
        // Round-trips through the in-tree parser.
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(re, j);
    }
}
