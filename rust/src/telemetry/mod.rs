//! Zero-overhead telemetry: per-shard span tracing, typed counters, and
//! a pool-utilization profiler for the training stack.
//!
//! Design constraints (and how they are met):
//!
//! * **Inert when disabled.** One process-wide [`AtomicBool`] gates the
//!   whole layer. It is read once per *pool dispatch* (by
//!   [`shard_scope`], at the `WorkerPool::run` seam) and once per coarse
//!   caller-side stage ([`scope`]) — never per span. When off, every
//!   recording call is a thread-local boolean read and an untaken branch.
//! * **Lock-free, zero-atomic hot path.** Inside a dispatch, spans and
//!   counters are staged into plain thread-local buffers (a preallocated
//!   `Vec<SpanRec>` ring with a drop counter, capacity [`SPAN_CAP`]).
//!   The staged data is flushed to this thread's shared [`ThreadBuf`]
//!   (a `Mutex`-protected append buffer registered in a global registry)
//!   exactly once, when the outermost scope exits — one uncontended lock
//!   per shard per dispatch, nothing per span.
//! * **Provably non-perturbing.** The recorder only reads `Instant` and
//!   writes its own buffers: it never touches RNG streams, dispatch
//!   shapes, chunk boundaries, or training data, so results are bitwise
//!   identical with telemetry on or off at any `--threads` (proven in
//!   rust/tests/telemetry.rs).
//!
//! Aggregation: [`drain`] collects every thread's completed spans between
//! iterations (safe at any time — the shared buffers are lock-protected
//! and only ever hold *completed* scopes), and
//! [`report::IterationReport`] turns one drain into per-stage p50/p99,
//! per-shard busy time, the per-epoch imbalance ratio, and pool
//! utilization. [`trace::write_chrome_trace`] exports the raw spans as a
//! Chrome trace-event file viewable in Perfetto (`--trace-out`).

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

pub mod log;
pub mod report;
pub mod trace;

pub use log::{LogFormat, RunLog};
pub use report::{IterationReport, StageStats};
pub use trace::write_chrome_trace;

/// Staged spans a single thread can hold between flushes (one pool
/// dispatch); beyond this, spans are counted as dropped, never reallocated.
pub const SPAN_CAP: usize = 1 << 16;

/// Total spans the shared per-thread buffers retain between [`drain`]
/// calls; a runaway producer degrades to drop-counting instead of
/// unbounded growth.
const SHARED_CAP: usize = 1 << 21;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DISPATCH_SEQ: AtomicU64 = AtomicU64::new(0);
static ORIGIN: OnceLock<Instant> = OnceLock::new();
static REGISTRY: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());

/// Turn the telemetry layer on/off process-wide (`--telemetry`). Scopes
/// opened after this call observe the new state; in-flight scopes finish
/// under the state they started with.
pub fn set_enabled(on: bool) {
    if on {
        // Fix the trace time origin before the first span can exist.
        let _ = origin();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

/// Whether the telemetry layer is currently enabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process-wide trace epoch all span timestamps are relative to.
fn origin() -> Instant {
    *ORIGIN.get_or_init(Instant::now)
}

/// Instrumented stages. `PoolShard` is the dispatch envelope (one span
/// per shard per pool job — the utilization/imbalance signal); the rest
/// are the per-iteration report's stage set.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// One shard of one `WorkerPool` job (caller = lane 0, workers 1..).
    PoolShard,
    /// A trainer iteration's fused rollout (caller envelope).
    Rollout,
    /// Fused in-shard policy inference (`sample_block`/`greedy_block`).
    PolicyForward,
    /// A shard's env-step lane loop.
    EnvStep,
    /// A coupled fleet's per-step allocate pass: fixed-order tree reduce
    /// of proposed feeder draws + budget/headroom broadcast (caller-side,
    /// between the propose and commit dispatches).
    GridReduce,
    /// One 64-row PPO gradient chunk.
    UpdateChunk,
    /// Fixed-order pairwise tree-reduce of chunk gradients/stats.
    Reduce,
    /// The Adam application on the caller.
    Adam,
    /// Greedy evaluation (per-cell fleet eval or single-env episode).
    Eval,
    /// The double-buffered trainer's overlap window: caller-side
    /// accounting/stats/eval-filler time spent while the next iteration's
    /// rollout streams on the pool's pipeline lane (`--overlap on`).
    PipelineOverlap,
}

impl SpanKind {
    /// The per-iteration report's stage set, in display order (everything
    /// except the `PoolShard` envelope, which feeds the shard columns).
    pub const STAGES: [SpanKind; 9] = [
        SpanKind::Rollout,
        SpanKind::PolicyForward,
        SpanKind::EnvStep,
        SpanKind::GridReduce,
        SpanKind::UpdateChunk,
        SpanKind::Reduce,
        SpanKind::Adam,
        SpanKind::Eval,
        SpanKind::PipelineOverlap,
    ];

    pub fn label(self) -> &'static str {
        match self {
            SpanKind::PoolShard => "pool-shard",
            SpanKind::Rollout => "rollout",
            SpanKind::PolicyForward => "policy-forward",
            SpanKind::EnvStep => "env-step",
            SpanKind::GridReduce => "grid-reduce",
            SpanKind::UpdateChunk => "update-chunks",
            SpanKind::Reduce => "reduce",
            SpanKind::Adam => "adam",
            SpanKind::Eval => "eval",
            SpanKind::PipelineOverlap => "pipeline-overlap",
        }
    }
}

/// One completed span: stage, pool lane, dispatch sequence id (0 for
/// caller-side coarse stages), and nanoseconds since the trace origin.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    pub kind: SpanKind,
    pub lane: u32,
    pub seq: u64,
    pub t0_ns: u64,
    pub dur_ns: u64,
}

/// Typed domain counters, accumulated per shard task and committed once
/// per scope (never per lane-step).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Counters {
    /// Environment lane-steps advanced (B lanes × 1 step each).
    pub env_steps: u64,
    /// Cars that arrived at a port this drain window.
    pub cars_arrived: u64,
    /// Cars that departed this drain window.
    pub cars_departed: u64,
    /// Net grid energy (kWh, import positive) summed over lane-steps.
    pub grid_kwh: f64,
    /// Feeder energy denied by proportional curtailment (kWh): per
    /// coupling group per step, `(total - capacity)+ * dt`.
    pub curtailed_kwh: f64,
    /// Times the NaN-safe greedy head saw a non-finite logit.
    pub nan_guard_trips: u64,
    /// PPO minibatch rows pushed through gradient chunks.
    pub minibatch_rows: u64,
}

impl Counters {
    pub fn add(&mut self, o: &Counters) {
        self.env_steps += o.env_steps;
        self.cars_arrived += o.cars_arrived;
        self.cars_departed += o.cars_departed;
        self.grid_kwh += o.grid_kwh;
        self.curtailed_kwh += o.curtailed_kwh;
        self.nan_guard_trips += o.nan_guard_trips;
        self.minibatch_rows += o.minibatch_rows;
    }

    pub fn is_zero(&self) -> bool {
        *self == Counters::default()
    }
}

// -- per-thread staging (the hot path) -----------------------------------

struct Staged {
    spans: Vec<SpanRec>,
    dropped: u64,
    counters: Counters,
    buf: Option<Arc<ThreadBuf>>,
}

thread_local! {
    static RECORDING: Cell<bool> = const { Cell::new(false) };
    static LANE: Cell<u32> = const { Cell::new(0) };
    static SEQ: Cell<u64> = const { Cell::new(0) };
    static ORIGIN_TLS: Cell<Option<Instant>> = const { Cell::new(None) };
    static STAGED: RefCell<Staged> = RefCell::new(Staged {
        spans: Vec::new(),
        dropped: 0,
        counters: Counters::default(),
        buf: None,
    });
}

/// Whether the current thread is inside a recording scope. Fine-grained
/// instrumentation (spans inside shard tasks, counter accumulation) gates
/// on this — a thread-local read, zero atomics.
#[inline]
pub fn recording() -> bool {
    RECORDING.with(|c| c.get())
}

/// Accumulate into the staged counters if this thread is recording.
/// Callers batch locally and commit once per task, so the per-lane hot
/// loop pays one branch.
#[inline]
pub fn counters(f: impl FnOnce(&mut Counters)) {
    if recording() {
        STAGED.with(|s| f(&mut s.borrow_mut().counters));
    }
}

#[inline]
fn thread_origin() -> Instant {
    ORIGIN_TLS.with(|c| match c.get() {
        Some(o) => o,
        None => {
            let o = origin();
            c.set(Some(o));
            o
        }
    })
}

fn push_span(kind: SpanKind, t0: Instant, t1: Instant) {
    let o = thread_origin();
    let t0_ns = t0.saturating_duration_since(o).as_nanos() as u64;
    let dur_ns = t1.saturating_duration_since(t0).as_nanos() as u64;
    let lane = LANE.with(|c| c.get());
    let seq = SEQ.with(|c| c.get());
    STAGED.with(|s| {
        let mut s = s.borrow_mut();
        if s.spans.capacity() == 0 {
            s.spans.reserve_exact(SPAN_CAP);
        }
        if s.spans.len() >= SPAN_CAP {
            s.dropped += 1;
        } else {
            s.spans.push(SpanRec { kind, lane, seq, t0_ns, dur_ns });
        }
    });
}

/// Move this thread's staged spans/counters into its shared buffer
/// (registering it on first use). One lock per call; called only at
/// outermost-scope exit and from [`drain`].
fn flush() {
    STAGED.with(|s| {
        let mut s = s.borrow_mut();
        let Staged { spans, dropped, counters, buf } = &mut *s;
        if spans.is_empty() && *dropped == 0 && counters.is_zero() {
            return;
        }
        if buf.is_none() {
            let b = Arc::new(ThreadBuf::default());
            REGISTRY.lock().unwrap().push(Arc::clone(&b));
            *buf = Some(b);
        }
        let mut inner = buf.as_ref().unwrap().inner.lock().unwrap();
        let room = SHARED_CAP.saturating_sub(inner.spans.len());
        if spans.len() > room {
            *dropped += (spans.len() - room) as u64;
            spans.truncate(room);
        }
        inner.spans.append(spans);
        inner.dropped += *dropped;
        *dropped = 0;
        inner.counters.add(counters);
        *counters = Counters::default();
    });
}

// -- shared buffers + drain ----------------------------------------------

#[derive(Default)]
struct BufInner {
    spans: Vec<SpanRec>,
    dropped: u64,
    counters: Counters,
}

/// One thread's published telemetry. Shared only through its `Mutex`;
/// the owner appends at scope exit, [`drain`] takes everything.
#[derive(Default)]
struct ThreadBuf {
    inner: Mutex<BufInner>,
}

/// Everything recorded since the previous drain, across all threads,
/// sorted by start time.
#[derive(Debug, Default)]
pub struct Drained {
    pub spans: Vec<SpanRec>,
    pub counters: Counters,
    pub dropped: u64,
}

/// Collect and clear every thread's published telemetry. Callable at any
/// time (buffers are lock-protected and hold only completed scopes);
/// trainers call it once per iteration.
pub fn drain() -> Drained {
    flush(); // the caller thread may hold staged counters outside a scope
    let bufs: Vec<Arc<ThreadBuf>> = REGISTRY.lock().unwrap().clone();
    let mut out = Drained::default();
    for b in &bufs {
        let mut inner = b.inner.lock().unwrap();
        out.spans.append(&mut inner.spans);
        out.counters.add(&inner.counters);
        inner.counters = Counters::default();
        out.dropped += inner.dropped;
        inner.dropped = 0;
    }
    out.spans.sort_by_key(|s| (s.t0_ns, s.lane));
    out
}

// -- scopes --------------------------------------------------------------

/// Allocate a dispatch sequence id shared by every shard of one pool job
/// (groups `PoolShard` spans for the per-epoch imbalance ratio). Returns
/// 0 when telemetry is off — the single atomic the pool pays per
/// dispatch, nothing per span.
#[inline]
pub fn dispatch_seq() -> u64 {
    if enabled() {
        DISPATCH_SEQ.fetch_add(1, Ordering::Relaxed) + 1
    } else {
        0
    }
}

/// RAII recording scope. Entering marks the thread as recording (saving
/// the outer state); leaving records the scope's own span, restores the
/// outer state, and — when outermost — flushes staged data to the shared
/// buffer.
pub struct Scope {
    active: bool,
    prev_recording: bool,
    prev_lane: u32,
    prev_seq: u64,
    kind: SpanKind,
    t0: Option<Instant>,
}

const INACTIVE_SCOPE: Scope = Scope {
    active: false,
    prev_recording: false,
    prev_lane: 0,
    prev_seq: 0,
    kind: SpanKind::PoolShard,
    t0: None,
};

fn scope_impl(kind: SpanKind, lane: u32, seq: u64) -> Scope {
    if !enabled() {
        return INACTIVE_SCOPE;
    }
    let prev_recording = RECORDING.with(|c| c.replace(true));
    let prev_lane = LANE.with(|c| c.replace(lane));
    let prev_seq = SEQ.with(|c| c.replace(seq));
    Scope {
        active: true,
        prev_recording,
        prev_lane,
        prev_seq,
        kind,
        t0: Some(Instant::now()),
    }
}

/// Pool dispatch seam: one shard of one pool job (`lane` = shard index,
/// `seq` from [`dispatch_seq`], identical across the job's shards).
/// Placed by `WorkerPool::run` around both the caller's shard-0 call and
/// each worker's shard body, so fine spans inside shard tasks see
/// `recording() == true` without ever touching an atomic.
#[inline]
pub fn shard_scope(lane: u32, seq: u64) -> Scope {
    scope_impl(SpanKind::PoolShard, lane, seq)
}

/// Coarse caller-side stage scope (rollout / reduce / adam / eval):
/// checks the atomic enable flag itself, so it is valid outside any pool
/// dispatch (including fully inline `--threads 1` runs).
#[inline]
pub fn scope(kind: SpanKind) -> Scope {
    scope_impl(kind, 0, 0)
}

/// Mark the current thread as recording WITHOUT emitting a span of its
/// own: wraps inline (pool-less) dispatch fallbacks so their fine spans
/// and counters still record at `--threads 1`.
#[inline]
pub fn quiet_scope() -> Scope {
    let mut s = scope_impl(SpanKind::PoolShard, 0, 0);
    s.t0 = None;
    s
}

impl Drop for Scope {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        if let Some(t0) = self.t0 {
            push_span(self.kind, t0, Instant::now());
        }
        RECORDING.with(|c| c.set(self.prev_recording));
        LANE.with(|c| c.set(self.prev_lane));
        SEQ.with(|c| c.set(self.prev_seq));
        if !self.prev_recording {
            flush();
        }
    }
}

/// Fine-grained span inside a recording scope (policy-forward, env-step,
/// update-chunk). Thread-local check only; a no-op outside a scope or
/// with telemetry off.
pub struct Span {
    kind: SpanKind,
    t0: Option<Instant>,
}

impl Span {
    #[inline]
    pub fn fine(kind: SpanKind) -> Span {
        let t0 = if recording() { Some(Instant::now()) } else { None };
        Span { kind, t0 }
    }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.t0 {
            push_span(self.kind, t0, Instant::now());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Telemetry state is process-global; tests in this module serialize
    // on one lock so enable/disable toggles don't interleave.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_scopes_record_nothing() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        let _ = drain();
        {
            let _sc = shard_scope(0, dispatch_seq());
            let _sp = Span::fine(SpanKind::EnvStep);
            counters(|c| c.env_steps += 10);
        }
        let d = drain();
        assert!(d.spans.is_empty(), "disabled telemetry must record no spans");
        assert!(d.counters.is_zero());
    }

    #[test]
    fn scopes_and_counters_round_trip_through_drain() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        let _ = drain();
        let seq = dispatch_seq();
        assert!(seq > 0);
        {
            let _sc = shard_scope(3, seq);
            assert!(recording());
            let _sp = Span::fine(SpanKind::EnvStep);
            counters(|c| {
                c.env_steps += 64;
                c.grid_kwh += 1.5;
            });
        }
        {
            let _sc = scope(SpanKind::Eval);
            counters(|c| c.nan_guard_trips += 1);
        }
        assert!(!recording(), "scope exit must restore the outer state");
        set_enabled(false);
        let d = drain();
        let pool: Vec<_> =
            d.spans.iter().filter(|s| s.kind == SpanKind::PoolShard).collect();
        assert_eq!(pool.len(), 1);
        assert_eq!(pool[0].lane, 3);
        assert_eq!(pool[0].seq, seq);
        assert!(d.spans.iter().any(|s| s.kind == SpanKind::EnvStep));
        assert!(d.spans.iter().any(|s| s.kind == SpanKind::Eval));
        assert_eq!(d.counters.env_steps, 64);
        assert_eq!(d.counters.nan_guard_trips, 1);
        assert!((d.counters.grid_kwh - 1.5).abs() < 1e-12);
        // Drain clears.
        assert!(drain().spans.is_empty());
    }

    #[test]
    fn nested_scopes_restore_lane_and_seq() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        let _ = drain();
        {
            let _outer = scope(SpanKind::Rollout);
            {
                let _inner = shard_scope(5, 42);
            }
            assert!(recording(), "inner scope exit must not end the outer one");
        }
        set_enabled(false);
        let d = drain();
        let outer = d.spans.iter().find(|s| s.kind == SpanKind::Rollout).unwrap();
        let inner = d.spans.iter().find(|s| s.kind == SpanKind::PoolShard).unwrap();
        assert_eq!(outer.lane, 0);
        assert_eq!(inner.lane, 5);
        assert_eq!(inner.seq, 42);
        assert!(outer.dur_ns >= inner.dur_ns, "outer span envelops inner");
    }
}
