//! One logging front end for every human-facing line the CLI emits.
//!
//! Contract (ISSUE 8 satellite): **results go to stdout, diagnostics go
//! to stderr, always.** `--quiet` silences diagnostics; `--log-format
//! json` switches structured per-iteration records onto stdout as JSON
//! lines (and they are always appended to the JSONL sink when one is
//! open, regardless of format).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// `--log-format {text,json}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogFormat {
    Text,
    Json,
}

impl LogFormat {
    pub fn parse(s: &str) -> Result<LogFormat, String> {
        match s {
            "text" => Ok(LogFormat::Text),
            "json" => Ok(LogFormat::Json),
            other => Err(format!("unknown log format '{other}' (text|json)")),
        }
    }
}

/// The run logger: diagnostics vs results routing, quiet gating, and an
/// optional JSONL sink (`runs/telemetry.jsonl`) for structured records.
pub struct RunLog {
    quiet: bool,
    format: LogFormat,
    sink: Option<BufWriter<File>>,
}

impl RunLog {
    pub fn new(quiet: bool, format: LogFormat) -> RunLog {
        RunLog { quiet, format, sink: None }
    }

    /// Attach a JSONL sink (truncates; creates parent directories).
    pub fn with_jsonl(mut self, path: &Path) -> std::io::Result<RunLog> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        self.sink = Some(BufWriter::new(File::create(path)?));
        Ok(self)
    }

    pub fn format(&self) -> LogFormat {
        self.format
    }

    /// Progress/diagnostic line → stderr (dropped under `--quiet`).
    pub fn info(&self, msg: &str) {
        if !self.quiet {
            eprintln!("{msg}");
        }
    }

    /// Result line (tables, summary metrics, output paths) → stdout,
    /// always — quiet mode only silences diagnostics.
    pub fn result(&self, msg: &str) {
        println!("{msg}");
    }

    /// Structured per-iteration record: appended to the JSONL sink when
    /// one is open; printed to stdout as one JSON line in `json` format.
    pub fn record(&mut self, rec: &Json) {
        let line = rec.to_string();
        if let Some(sink) = &mut self.sink {
            // Flush per record so CI artifact uploads and `tail -f` see
            // complete lines even if the run is cut short.
            let _ = writeln!(sink, "{line}");
            let _ = sink.flush();
        }
        if self.format == LogFormat::Json {
            println!("{line}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_format_parses_and_rejects() {
        assert_eq!(LogFormat::parse("text").unwrap(), LogFormat::Text);
        assert_eq!(LogFormat::parse("json").unwrap(), LogFormat::Json);
        assert!(LogFormat::parse("yaml").is_err());
    }

    #[test]
    fn jsonl_sink_appends_one_line_per_record() {
        let dir = std::env::temp_dir().join(format!(
            "chargax-runlog-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = dir.join("telemetry.jsonl");
        {
            let mut log = RunLog::new(true, LogFormat::Text)
                .with_jsonl(&path)
                .expect("open jsonl sink");
            log.record(&Json::parse(r#"{"iter":0,"wall_ms":1.5}"#).unwrap());
            log.record(&Json::parse(r#"{"iter":1,"wall_ms":2.5}"#).unwrap());
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).expect("each line is standalone JSON");
            assert_eq!(j.get("iter").unwrap().as_usize(), Some(i));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
