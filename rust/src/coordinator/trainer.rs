//! High-level training drivers: the PJRT-backed TrainSession loop and the
//! native-vector loop (pure-Rust PPO over [`VectorEnv`], no artifacts or
//! PJRT needed). Both run for a step budget, collect per-iteration metric
//! history, and periodically log.

use anyhow::Result;

use crate::baselines::ppo::{PpoParams, PpoTrainer};
use crate::data::{DataStore, Scenario};
use crate::env::scalar::ScenarioTables;
use crate::env::tree::StationConfig;
use crate::runtime::engine::Engine;
use crate::runtime::manifest::Variant;

use super::metrics::NamedVec;
use super::session::{EvalSession, TrainSession};

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub seed: u32,
    pub total_env_steps: usize,
    pub log_every: usize, // iterations
    pub quiet: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            seed: 0,
            total_env_steps: 200_000,
            log_every: 10,
            quiet: false,
        }
    }
}

pub struct TrainOutcome {
    pub history: Vec<NamedVec>,
    pub env_steps: usize,
    pub wallclock_s: f64,
    pub session: TrainSession,
}

/// Train one agent; returns the per-iteration metric history and the
/// session (whose carry holds the trained parameters).
pub fn train(
    engine: &Engine,
    variant: &Variant,
    store: &DataStore,
    scenario: &Scenario,
    opts: &TrainOptions,
) -> Result<TrainOutcome> {
    let mut session = TrainSession::new(engine, variant, store, scenario, opts.seed)?;
    let iters = opts.total_env_steps.div_ceil(variant.meta.batch_size).max(1);
    let t0 = std::time::Instant::now();
    let mut history = Vec::with_capacity(iters);
    for i in 0..iters {
        let m = session.step()?;
        if !opts.quiet && (i % opts.log_every == 0 || i + 1 == iters) {
            eprintln!(
                "[train seed={} iter {}/{} steps {}] {}",
                opts.seed,
                i + 1,
                iters,
                session.env_steps_done,
                m.fmt_fields(&[
                    "mean_reward",
                    "mean_completed_return",
                    "mean_profit",
                    "total_loss",
                    "entropy",
                ])
            );
        }
        history.push(m);
    }
    Ok(TrainOutcome {
        env_steps: session.env_steps_done,
        wallclock_s: t0.elapsed().as_secs_f64(),
        history,
        session,
    })
}

pub struct NativeTrainOutcome {
    pub history: Vec<NamedVec>,
    pub env_steps: usize,
    pub wallclock_s: f64,
    pub trainer: PpoTrainer,
}

/// Train the native-vector PPO agent (the `--backend native` path): the
/// pure-Rust PPO whose rollouts advance all envs through
/// `VectorEnv::step_all`. Scenario tables are built (or synthesized) once
/// and shared across every lane via `Arc`. `on_iter(i)` fires after each
/// completed iteration (the CLI hangs its per-iteration telemetry drain
/// off it; pass `|_| {}` when unused).
pub fn train_native(
    store: Option<&DataStore>,
    scenario: &Scenario,
    station: StationConfig,
    params: PpoParams,
    opts: &TrainOptions,
    mut on_iter: impl FnMut(usize),
) -> Result<NativeTrainOutcome> {
    let tables = match store {
        Some(s) => ScenarioTables::build(s, scenario)?,
        None => ScenarioTables::synthetic_for(scenario),
    };
    let mut tr = PpoTrainer::new(params, station, tables, opts.seed as u64);
    let batch = tr.cfg.num_envs * tr.cfg.rollout_steps;
    let iters = opts.total_env_steps.div_ceil(batch).max(1);
    let fields: Vec<String> = [
        "mean_reward",
        "mean_completed_return",
        "mean_profit",
        "total_loss",
        "entropy",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let t0 = std::time::Instant::now();
    let mut history = Vec::with_capacity(iters);
    for i in 0..iters {
        // The last iteration never prefetches (`--overlap on` double
        // buffering), so N iterations perform exactly N rollouts.
        let s = if i + 1 == iters { tr.final_iteration() } else { tr.iteration() };
        let m = NamedVec::new(
            &fields,
            vec![
                s.mean_reward,
                s.completed_return_mean,
                s.mean_profit,
                s.total_loss,
                s.entropy,
            ],
        )?;
        if !opts.quiet && (i % opts.log_every == 0 || i + 1 == iters) {
            eprintln!(
                "[native-vector seed={} iter {}/{} steps {}] {}",
                opts.seed,
                i + 1,
                iters,
                tr.env_steps,
                m.fmt_fields(&[
                    "mean_reward",
                    "mean_completed_return",
                    "mean_profit",
                    "total_loss",
                    "entropy",
                ])
            );
        }
        history.push(m);
        on_iter(i);
    }
    Ok(NativeTrainOutcome {
        env_steps: tr.env_steps,
        wallclock_s: t0.elapsed().as_secs_f64(),
        history,
        trainer: tr,
    })
}

/// Evaluate a trained session under `eval_net` over `n_seeds` seeds;
/// returns one NamedVec per seed.
pub fn evaluate(
    engine: &Engine,
    session: &TrainSession,
    store: &DataStore,
    scenario: &Scenario,
    seeds: std::ops::Range<u32>,
) -> Result<Vec<NamedVec>> {
    let eval = EvalSession::new(engine, &session.variant, store, scenario, "net")?;
    let params = session.params();
    seeds.map(|s| eval.run(&params, s)).collect()
}

/// Evaluate a parameter-free baseline policy ("max" or "random").
pub fn evaluate_baseline(
    engine: &Engine,
    variant: &Variant,
    store: &DataStore,
    scenario: &Scenario,
    policy: &str,
    seeds: std::ops::Range<u32>,
) -> Result<Vec<NamedVec>> {
    let eval = EvalSession::new(engine, variant, store, scenario, policy)?;
    let zeros = eval.zero_params()?;
    let refs: Vec<&xla::Literal> = zeros.iter().collect();
    seeds.map(|s| eval.run(&refs, s)).collect()
}
