//! High-level training driver: runs a TrainSession for a step budget,
//! collects the metric history, and periodically logs / evaluates.

use anyhow::Result;

use crate::data::{DataStore, Scenario};
use crate::runtime::engine::Engine;
use crate::runtime::manifest::Variant;

use super::metrics::NamedVec;
use super::session::{EvalSession, TrainSession};

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub seed: u32,
    pub total_env_steps: usize,
    pub log_every: usize, // iterations
    pub quiet: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            seed: 0,
            total_env_steps: 200_000,
            log_every: 10,
            quiet: false,
        }
    }
}

pub struct TrainOutcome {
    pub history: Vec<NamedVec>,
    pub env_steps: usize,
    pub wallclock_s: f64,
    pub session: TrainSession,
}

/// Train one agent; returns the per-iteration metric history and the
/// session (whose carry holds the trained parameters).
pub fn train(
    engine: &Engine,
    variant: &Variant,
    store: &DataStore,
    scenario: &Scenario,
    opts: &TrainOptions,
) -> Result<TrainOutcome> {
    let mut session = TrainSession::new(engine, variant, store, scenario, opts.seed)?;
    let iters = opts.total_env_steps.div_ceil(variant.meta.batch_size).max(1);
    let t0 = std::time::Instant::now();
    let mut history = Vec::with_capacity(iters);
    for i in 0..iters {
        let m = session.step()?;
        if !opts.quiet && (i % opts.log_every == 0 || i + 1 == iters) {
            eprintln!(
                "[train seed={} iter {}/{} steps {}] {}",
                opts.seed,
                i + 1,
                iters,
                session.env_steps_done,
                m.fmt_fields(&[
                    "mean_reward",
                    "mean_completed_return",
                    "mean_profit",
                    "total_loss",
                    "entropy",
                ])
            );
        }
        history.push(m);
    }
    Ok(TrainOutcome {
        env_steps: session.env_steps_done,
        wallclock_s: t0.elapsed().as_secs_f64(),
        history,
        session,
    })
}

/// Evaluate a trained session under `eval_net` over `n_seeds` seeds;
/// returns one NamedVec per seed.
pub fn evaluate(
    engine: &Engine,
    session: &TrainSession,
    store: &DataStore,
    scenario: &Scenario,
    seeds: std::ops::Range<u32>,
) -> Result<Vec<NamedVec>> {
    let eval = EvalSession::new(engine, &session.variant, store, scenario, "net")?;
    let params = session.params();
    seeds.map(|s| eval.run(&params, s)).collect()
}

/// Evaluate a parameter-free baseline policy ("max" or "random").
pub fn evaluate_baseline(
    engine: &Engine,
    variant: &Variant,
    store: &DataStore,
    scenario: &Scenario,
    policy: &str,
    seeds: std::ops::Range<u32>,
) -> Result<Vec<NamedVec>> {
    let eval = EvalSession::new(engine, variant, store, scenario, policy)?;
    let zeros = eval.zero_params()?;
    let refs: Vec<&xla::Literal> = zeros.iter().collect();
    seeds.map(|s| eval.run(&refs, s)).collect()
}
