//! Training / evaluation sessions: the carry-feedback loop around the AOT
//! programs. This is the hot path — Python is not involved.
//!
//! A `TrainSession` owns the PJRT executables for one variant plus the
//! current carry (params, Adam state, env states, last obs, rng) held as
//! opaque literals. `step()` executes one fused PPO iteration
//! (rollout_steps x num_envs env steps + GAE + minibatched updates) and
//! feeds the returned carry straight back in by reference; only the small
//! metrics leaf is copied to host.

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::data::{DataStore, Scenario};
use crate::runtime::engine::{Engine, Executable};
use crate::runtime::manifest::Variant;
use crate::runtime::tensor::Tensor;

use super::metrics::NamedVec;

pub struct TrainSession {
    pub variant: Variant,
    train_init: Arc<Executable>,
    train_iter: Arc<Executable>,
    carry: Vec<xla::Literal>,
    exog: Vec<xla::Literal>,
    param_indices: Vec<usize>,
    pub iters_done: usize,
    pub env_steps_done: usize,
}

impl TrainSession {
    /// Compile (or fetch cached) programs and initialize the carry.
    pub fn new(
        engine: &Engine,
        variant: &Variant,
        store: &DataStore,
        scenario: &Scenario,
        seed: u32,
    ) -> Result<TrainSession> {
        let init_spec = variant.program("train_init")?;
        let iter_spec = variant.program("train_iter")?;
        let train_init = engine.load(init_spec)?;
        let train_iter = engine.load(iter_spec)?;

        let n_carry = iter_spec
            .outputs
            .iter()
            .filter(|o| o.name != "metrics")
            .count();
        let exog = build_exog(scenario, store, variant, n_carry)?;
        let param_indices: Vec<usize> = iter_spec
            .outputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.name.starts_with("params."))
            .map(|(i, _)| i)
            .collect();
        if param_indices.is_empty() {
            return Err(anyhow!("train_iter carry has no params.* leaves"));
        }

        let seed_lit = Tensor::scalar_u32(seed).to_literal()?;
        let carry = train_init
            .run_literals(&[seed_lit])
            .context("train_init")?;

        Ok(TrainSession {
            variant: variant.clone(),
            train_init,
            train_iter,
            carry,
            exog,
            param_indices,
            iters_done: 0,
            env_steps_done: 0,
        })
    }

    /// Swap the scenario (e.g. a different price year) without resetting
    /// the carry — used by the distribution-shift experiment.
    pub fn set_scenario(&mut self, store: &DataStore, scenario: &Scenario) -> Result<()> {
        let n_carry = self.carry.len();
        self.exog = build_exog(scenario, store, &self.variant, n_carry)?;
        Ok(())
    }

    /// Re-initialize the carry from a fresh seed (keeps compiled programs).
    pub fn reset(&mut self, seed: u32) -> Result<()> {
        let seed_lit = Tensor::scalar_u32(seed).to_literal()?;
        self.carry = self.train_init.run_literals(&[seed_lit])?;
        self.iters_done = 0;
        self.env_steps_done = 0;
        Ok(())
    }

    /// One fused PPO iteration; returns the train metrics.
    pub fn step(&mut self) -> Result<NamedVec> {
        let inputs: Vec<&xla::Literal> =
            self.carry.iter().chain(self.exog.iter()).collect();
        let mut outs = self.train_iter.run_literals(&inputs)?;
        let metrics_lit = outs.pop().expect("train_iter returns metrics last");
        self.carry = outs;
        self.iters_done += 1;
        self.env_steps_done += self.variant.meta.batch_size;
        let metrics = Tensor::from_literal(&metrics_lit)?;
        NamedVec::new(
            &self.variant.meta.train_metric_fields,
            metrics.as_f32()?.to_vec(),
        )
    }

    /// Borrow the current policy parameter leaves (for EvalSession).
    pub fn params(&self) -> Vec<&xla::Literal> {
        self.param_indices.iter().map(|&i| &self.carry[i]).collect()
    }
}

/// Evaluation runner: full-episode rollouts under a fixed policy.
pub struct EvalSession {
    pub variant: Variant,
    exe: Arc<Executable>,
    exog: Vec<xla::Literal>,
    n_params: usize,
}

impl EvalSession {
    /// `policy`: "net" | "max" | "random" (the paper's PPO policy,
    /// always-charge-max baseline, and random baseline).
    pub fn new(
        engine: &Engine,
        variant: &Variant,
        store: &DataStore,
        scenario: &Scenario,
        policy: &str,
    ) -> Result<EvalSession> {
        let spec = variant.program(&format!("eval_{policy}"))?;
        let exe = engine.load(spec)?;
        let n_params = spec
            .inputs
            .iter()
            .filter(|s| s.name.starts_with("params."))
            .count();
        let n_non_exog = n_params + 1; // params + seed
        let exog = build_exog(scenario, store, variant, n_non_exog)
            .with_context(|| format!("exog for eval_{policy}"))?;
        Ok(EvalSession { variant: variant.clone(), exe, exog, n_params })
    }

    pub fn set_scenario(&mut self, store: &DataStore, scenario: &Scenario) -> Result<()> {
        self.exog = build_exog(scenario, store, &self.variant, self.n_params + 1)?;
        Ok(())
    }

    /// Evaluate with the given parameter leaves (borrowed from a
    /// TrainSession, or zeros for the non-net policies).
    pub fn run(&self, params: &[&xla::Literal], seed: u32) -> Result<NamedVec> {
        if params.len() != self.n_params {
            return Err(anyhow!(
                "eval wants {} param leaves, got {}",
                self.n_params,
                params.len()
            ));
        }
        let seed_lit = Tensor::scalar_u32(seed).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = params.to_vec();
        inputs.push(&seed_lit);
        inputs.extend(self.exog.iter());
        let outs = self.exe.run_literals(&inputs)?;
        let metrics = Tensor::from_literal(&outs[0])?;
        NamedVec::new(
            &self.variant.meta.eval_metric_fields,
            metrics.as_f32()?.to_vec(),
        )
    }

    /// Zero parameter literals (for max/random policies, which ignore them).
    pub fn zero_params(&self) -> Result<Vec<xla::Literal>> {
        self.exe.spec.inputs[..self.n_params]
            .iter()
            .map(|s| Tensor::zeros(s).to_literal())
            .collect()
    }
}

/// Fused random-action rollout (Table 2 "Random" row): one PJRT call
/// advances `meta.random_rollout_steps * num_envs` env steps.
pub struct RandomRollout {
    pub variant: Variant,
    exe: Arc<Executable>,
    exog: Vec<xla::Literal>,
}

impl RandomRollout {
    pub fn new(
        engine: &Engine,
        variant: &Variant,
        store: &DataStore,
        scenario: &Scenario,
    ) -> Result<RandomRollout> {
        let spec = variant.program("random_rollout")?;
        let exe = engine.load(spec)?;
        let exog = build_exog(scenario, store, variant, 1)?;
        Ok(RandomRollout { variant: variant.clone(), exe, exog })
    }

    /// Returns (mean step metrics, env-steps advanced).
    pub fn run(&self, seed: u32) -> Result<(NamedVec, usize)> {
        let seed_lit = Tensor::scalar_u32(seed).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = vec![&seed_lit];
        inputs.extend(self.exog.iter());
        let outs = self.exe.run_literals(&inputs)?;
        let metrics = Tensor::from_literal(&outs[0])?;
        let steps = Tensor::from_literal(&outs[1])?.as_i32()?[0] as usize;
        Ok((
            NamedVec::new(
                &self.variant.meta.metric_fields,
                metrics.as_f32()?.to_vec(),
            )?,
            steps,
        ))
    }
}

/// Build + validate the exogenous literal tail for any program whose
/// trailing inputs are the ExogData leaves.
fn build_exog(
    scenario: &Scenario,
    store: &DataStore,
    variant: &Variant,
    n_leading: usize,
) -> Result<Vec<xla::Literal>> {
    let spec = variant.program("train_iter")?;
    let _ = spec; // exog shapes are identical across programs; validate
                  // against train_iter's tail (the longest-lived program).
    let tensors = scenario.to_tensors(store)?;
    let iter_spec = variant.program("train_iter")?;
    let tail = &iter_spec.inputs[iter_spec.inputs.len() - tensors.len()..];
    for (t, s) in tensors.iter().zip(tail) {
        if !t.matches(s) {
            return Err(anyhow!(
                "exog leaf '{}': manifest {:?} {:?}, scenario built {:?} {:?}",
                s.name, s.dtype, s.shape, t.dtype(), t.shape()
            ));
        }
    }
    let _ = n_leading;
    tensors.iter().map(Tensor::to_literal).collect()
}
