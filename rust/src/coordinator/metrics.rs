//! Named metric vectors: the AOT programs return flat f32 vectors whose
//! field names live in the manifest; this gives them string-keyed access
//! plus simple aggregation across seeds/iterations.

use anyhow::{anyhow, Result};
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct NamedVec {
    pub fields: Vec<String>,
    pub values: Vec<f32>,
    /// Field-name → position, built once at construction so `get` is a
    /// hash lookup instead of a linear scan (`fmt_fields` over long
    /// manifests hit the O(fields²) scan every logged iteration).
    index: HashMap<String, usize>,
}

impl NamedVec {
    pub fn new(fields: &[String], values: Vec<f32>) -> Result<NamedVec> {
        if fields.len() != values.len() {
            return Err(anyhow!(
                "metric vector length {} != field count {}",
                values.len(),
                fields.len()
            ));
        }
        let mut index = HashMap::with_capacity(fields.len());
        for (i, f) in fields.iter().enumerate() {
            if index.insert(f.clone(), i).is_some() {
                return Err(anyhow!("duplicate metric field '{f}'"));
            }
        }
        Ok(NamedVec { fields: fields.to_vec(), values, index })
    }

    pub fn get(&self, name: &str) -> Result<f32> {
        self.index
            .get(name)
            .map(|&i| self.values[i])
            .ok_or_else(|| anyhow!("no metric '{name}' (have {:?})", self.fields))
    }

    pub fn fmt_fields(&self, names: &[&str]) -> String {
        names
            .iter()
            .map(|n| format!("{n}={:.3}", self.get(n).unwrap_or(f32::NAN)))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Mean over a set of NamedVecs with identical fields.
pub fn mean(vecs: &[NamedVec]) -> Result<NamedVec> {
    let first = vecs.first().ok_or_else(|| anyhow!("empty metric set"))?;
    let mut acc = vec![0f64; first.values.len()];
    for v in vecs {
        if v.fields != first.fields {
            return Err(anyhow!("inconsistent metric fields"));
        }
        for (a, x) in acc.iter_mut().zip(&v.values) {
            *a += *x as f64;
        }
    }
    NamedVec::new(
        &first.fields,
        acc.iter().map(|a| (*a / vecs.len() as f64) as f32).collect(),
    )
}

/// Std-dev (sample) per field.
pub fn std(vecs: &[NamedVec]) -> Result<NamedVec> {
    let m = mean(vecs)?;
    let n = vecs.len();
    let mut acc = vec![0f64; m.values.len()];
    for v in vecs {
        for ((a, x), mu) in acc.iter_mut().zip(&v.values).zip(&m.values) {
            let d = (*x - *mu) as f64;
            *a += d * d;
        }
    }
    let denom = n.max(2) as f64 - 1.0;
    NamedVec::new(
        &m.fields,
        acc.iter().map(|a| ((*a / denom).sqrt()) as f32).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nv(vals: &[f32]) -> NamedVec {
        NamedVec::new(&["a".to_string(), "b".to_string()], vals.to_vec()).unwrap()
    }

    #[test]
    fn get_by_name() {
        let v = nv(&[1.0, 2.0]);
        assert_eq!(v.get("b").unwrap(), 2.0);
        assert!(v.get("c").is_err());
    }

    #[test]
    fn mean_std() {
        let m = mean(&[nv(&[1.0, 10.0]), nv(&[3.0, 30.0])]).unwrap();
        assert_eq!(m.get("a").unwrap(), 2.0);
        assert_eq!(m.get("b").unwrap(), 20.0);
        let s = std(&[nv(&[1.0, 10.0]), nv(&[3.0, 30.0])]).unwrap();
        assert!((s.get("a").unwrap() - std::f32::consts::SQRT_2).abs() < 1e-5);
    }

    #[test]
    fn length_mismatch_rejected() {
        assert!(NamedVec::new(&["a".to_string()], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn duplicate_fields_rejected() {
        let fields = ["a".to_string(), "b".to_string(), "a".to_string()];
        let err = NamedVec::new(&fields, vec![1.0, 2.0, 3.0]).unwrap_err();
        assert!(err.to_string().contains("duplicate metric field 'a'"), "{err}");
    }
}
