//! L3 coordinator: sessions (carry-feedback loop over the AOT programs),
//! the training driver, and named metrics.

pub mod metrics;
pub mod session;
pub mod trainer;
