//! Run configuration: CLI-facing experiment settings.
//!
//! Configs load from JSON files (configs/*.json, parsed with util::json —
//! no serde offline) and/or `--key value` CLI overrides; `RunConfig`
//! bundles the scenario, variant and budgets every subcommand needs.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use crate::data::Scenario;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub variant: String,
    /// Execution backend for train/eval: "pjrt" (AOT fast path over
    /// artifacts) or "native" (pure-Rust VectorEnv PPO, no artifacts).
    pub backend: String,
    pub scenario: Scenario,
    pub seed: u32,
    pub n_seeds: usize,
    /// Parallel envs for the native backend (PJRT variants bake their own).
    pub num_envs: usize,
    /// Worker-pool width for native rollouts (`--threads`); 0 = auto
    /// (`available_parallelism`).
    pub num_threads: usize,
    /// Pin pool workers to cores (`--pin_cores true`; Linux only, no-op
    /// elsewhere). Placement-only: results are bit-identical either way.
    pub pin_cores: bool,
    /// Double-buffered training (`--overlap {off,on}`): with "on", each
    /// iteration's tail (accounting, stats, interleaved eval) runs while
    /// the NEXT iteration's fused rollout streams on the pool's pipeline
    /// lane. Results are bit-identical to the "off" barrier default at
    /// any `--threads` (README §Overlapped pipeline).
    pub overlap: bool,
    pub total_env_steps: usize,
    pub eval_seeds: usize,
    pub paper_scale: bool,
    pub out_path: Option<String>,
    /// Fleet scenario-grid spec for the native backend (`--fleet`):
    /// a JSON file path (README §Scenario fleets & V2G) or the literal
    /// `demo` for the built-in three-family demo fleet.
    pub fleet_spec: Option<String>,
    /// Enable the telemetry layer (`--telemetry true`): per-shard span
    /// recording, typed counters, and per-iteration profiler reports.
    /// Results are bit-identical on or off (README §Telemetry & profiling).
    pub telemetry: bool,
    /// Run-log format (`--log_format {text,json}`). "json" emits one
    /// structured JSONL record per iteration on stdout and into the JSONL
    /// sink; "text" keeps the human-readable per-iteration lines.
    pub log_format: String,
    /// Suppress diagnostic (stderr) output (`--quiet true`). Result
    /// payloads on stdout are always emitted.
    pub quiet: bool,
    /// Write a Chrome trace-event file (load in Perfetto / chrome://tracing)
    /// of every recorded span at exit (`--trace_out runs/trace.json`).
    /// Implies span recording for the traced run.
    pub trace_out: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            variant: "mix10dc6ac_e12".into(),
            backend: "pjrt".into(),
            scenario: Scenario::default(),
            seed: 0,
            n_seeds: 3,
            num_envs: 12,
            num_threads: 0,
            pin_cores: false,
            overlap: false,
            total_env_steps: 200_000,
            eval_seeds: 8,
            paper_scale: false,
            out_path: None,
            fleet_spec: None,
            telemetry: false,
            log_format: "text".into(),
            quiet: false,
            trace_out: None,
        }
    }
}

impl RunConfig {
    /// Load a JSON config file, then apply `--key value` overrides.
    pub fn load(path: Option<&str>, overrides: &[(String, String)]) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(p) = path {
            cfg.apply_json(p)?;
        }
        for (k, v) in overrides {
            cfg.set(k, v)
                .with_context(|| format!("applying override --{k} {v}"))?;
        }
        Ok(cfg)
    }

    fn apply_json(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(Path::new(path))
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        let obj = j.as_obj().ok_or_else(|| anyhow!("config root must be an object"))?;
        for (k, v) in obj {
            let val = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => format!("{n}"),
                Json::Bool(b) => format!("{b}"),
                other => {
                    return Err(anyhow!("config key '{k}': unsupported value {other:?}"))
                }
            };
            self.set(k, &val)?;
        }
        Ok(())
    }

    /// Set one field by name (shared by JSON loader and CLI overrides).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "variant" => self.variant = val.to_string(),
            "backend" => match val {
                "pjrt" | "native" => self.backend = val.to_string(),
                other => return Err(anyhow!("unknown backend '{other}' (pjrt | native)")),
            },
            "num_envs" | "envs" => self.num_envs = val.parse()?,
            "num_threads" | "threads" => self.num_threads = val.parse()?,
            "pin_cores" | "pin-cores" => self.pin_cores = val.parse()?,
            "overlap" => match val {
                "on" => self.overlap = true,
                "off" => self.overlap = false,
                other => return Err(anyhow!("unknown overlap mode '{other}' (off | on)")),
            },
            "scenario" => self.scenario.scenario = val.to_string(),
            "region" => self.scenario.region = val.to_string(),
            "country" => self.scenario.country = val.to_string(),
            "year" => self.scenario.year = val.parse()?,
            "traffic" => self.scenario.traffic = val.to_string(),
            "p_sell" => self.scenario.p_sell = val.parse()?,
            "beta" => self.scenario.beta = val.parse()?,
            "seed" => self.seed = val.parse()?,
            "n_seeds" => self.n_seeds = val.parse()?,
            "total_env_steps" | "steps" => self.total_env_steps = val.parse()?,
            "eval_seeds" => self.eval_seeds = val.parse()?,
            "paper_scale" => self.paper_scale = val.parse()?,
            "out" => self.out_path = Some(val.to_string()),
            "fleet" => self.fleet_spec = Some(val.to_string()),
            "telemetry" => self.telemetry = val.parse()?,
            "log_format" | "log-format" => match val {
                "text" | "json" => self.log_format = val.to_string(),
                other => return Err(anyhow!("unknown log_format '{other}' (text | json)")),
            },
            "quiet" => self.quiet = val.parse()?,
            "trace_out" | "trace-out" => self.trace_out = Some(val.to_string()),
            k if k.starts_with("alpha_") => {
                let name = &k["alpha_".len()..];
                self.scenario = self.scenario.clone().with_alpha(name, val.parse()?)?;
            }
            other => return Err(anyhow!("unknown config key '{other}'")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_apply() {
        let mut cfg = RunConfig::default();
        cfg.set("year", "2022").unwrap();
        cfg.set("traffic", "high").unwrap();
        cfg.set("alpha_satisfaction0", "1.5").unwrap();
        cfg.set("steps", "5000").unwrap();
        assert_eq!(cfg.scenario.year, 2022);
        assert_eq!(cfg.scenario.traffic, "high");
        assert_eq!(cfg.scenario.alpha[1], 1.5);
        assert_eq!(cfg.total_env_steps, 5000);
        assert!(cfg.set("bogus", "1").is_err());
        cfg.set("backend", "native").unwrap();
        cfg.set("num_envs", "64").unwrap();
        cfg.set("threads", "4").unwrap();
        cfg.set("fleet", "configs/fleet_demo.json").unwrap();
        assert!(!cfg.pin_cores, "pin_cores must default off");
        cfg.set("pin_cores", "true").unwrap();
        assert!(cfg.pin_cores);
        cfg.set("pin-cores", "false").unwrap();
        assert!(!cfg.pin_cores);
        assert!(cfg.set("pin_cores", "yes").is_err());
        assert!(!cfg.overlap, "overlap must default off (barrier oracle)");
        cfg.set("overlap", "on").unwrap();
        assert!(cfg.overlap);
        cfg.set("overlap", "off").unwrap();
        assert!(!cfg.overlap);
        assert!(cfg.set("overlap", "true").is_err());
        assert_eq!(cfg.backend, "native");
        assert_eq!(cfg.num_envs, 64);
        assert_eq!(cfg.num_threads, 4);
        assert_eq!(cfg.fleet_spec.as_deref(), Some("configs/fleet_demo.json"));
        assert!(cfg.set("backend", "tpu").is_err());
    }

    #[test]
    fn telemetry_keys_apply() {
        let mut cfg = RunConfig::default();
        assert!(!cfg.telemetry, "telemetry must default off");
        assert_eq!(cfg.log_format, "text");
        assert!(!cfg.quiet);
        assert!(cfg.trace_out.is_none());
        cfg.set("telemetry", "true").unwrap();
        cfg.set("log_format", "json").unwrap();
        cfg.set("quiet", "true").unwrap();
        cfg.set("trace_out", "runs/trace.json").unwrap();
        assert!(cfg.telemetry);
        assert_eq!(cfg.log_format, "json");
        assert!(cfg.quiet);
        assert_eq!(cfg.trace_out.as_deref(), Some("runs/trace.json"));
        cfg.set("log-format", "text").unwrap();
        assert_eq!(cfg.log_format, "text");
        assert!(cfg.set("log_format", "yaml").is_err());
        assert!(cfg.set("telemetry", "maybe").is_err());
    }

    #[test]
    fn json_config_loads() {
        let dir = std::env::temp_dir().join("chargax_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"variant": "dc16_e12", "year": 2023, "n_seeds": 5}"#).unwrap();
        let cfg = RunConfig::load(Some(p.to_str().unwrap()), &[]).unwrap();
        assert_eq!(cfg.variant, "dc16_e12");
        assert_eq!(cfg.scenario.year, 2023);
        assert_eq!(cfg.n_seeds, 5);
    }
}
