//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the AOT contract: for every program of every variant it
//! records the flat input/output leaf order with shapes and dtypes, plus
//! env metadata (metric field names, action arity, ...). The coordinator
//! trusts these specs instead of introspecting HLO.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unknown dtype {other}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl LeafSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(j: &Json) -> Result<Self> {
        Ok(LeafSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .context("leaf name")?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .context("leaf shape")?
                .iter()
                .map(|x| x.as_usize().context("shape dim"))
                .collect::<Result<_>>()?,
            dtype: DType::parse(j.get("dtype").and_then(Json::as_str).context("dtype")?)?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<LeafSpec>,
    pub outputs: Vec<LeafSpec>,
}

impl ProgramSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|l| l.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|l| l.name == name)
    }
}

#[derive(Debug, Clone)]
pub struct EnvMeta {
    pub obs_dim: usize,
    pub n_ports: usize,
    pub n_chargers: usize,
    pub n_dc: usize,
    pub action_nvec: Vec<usize>,
    pub steps_per_episode: usize,
    pub num_envs: usize,
    pub rollout_steps: usize,
    pub batch_size: usize,
    pub random_rollout_steps: usize,
    pub n_params: usize,
    pub metric_fields: Vec<String>,
    pub train_metric_fields: Vec<String>,
    pub eval_metric_fields: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Variant {
    pub key: String,
    pub meta: EnvMeta,
    pub programs: BTreeMap<String, ProgramSpec>,
}

impl Variant {
    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("variant {} has no program {name}", self.key))
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut variants = BTreeMap::new();
        for (key, vj) in j
            .get("variants")
            .and_then(Json::as_obj)
            .context("manifest.variants")?
        {
            variants.insert(key.clone(), parse_variant(key, vj, artifacts_dir)?);
        }
        Ok(Manifest { dir: artifacts_dir.to_path_buf(), variants })
    }

    pub fn variant(&self, key: &str) -> Result<&Variant> {
        self.variants.get(key).ok_or_else(|| {
            anyhow!(
                "no variant '{key}' in manifest (have: {})",
                self.variants.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }
}

fn parse_variant(key: &str, j: &Json, dir: &Path) -> Result<Variant> {
    let m = j.get("meta").context("variant meta")?;
    let geti = |name: &str| -> Result<usize> {
        m.get(name).and_then(Json::as_usize).context(format!("meta.{name}"))
    };
    let gets = |name: &str| -> Result<Vec<String>> {
        m.get(name).and_then(Json::as_str_vec).context(format!("meta.{name}"))
    };
    let meta = EnvMeta {
        obs_dim: geti("obs_dim")?,
        n_ports: geti("n_ports")?,
        n_chargers: geti("n_chargers")?,
        n_dc: geti("n_dc")?,
        action_nvec: m
            .get("action_nvec")
            .and_then(Json::as_arr)
            .context("meta.action_nvec")?
            .iter()
            .map(|x| x.as_usize().context("nvec"))
            .collect::<Result<_>>()?,
        steps_per_episode: geti("steps_per_episode")?,
        num_envs: geti("num_envs")?,
        rollout_steps: geti("rollout_steps")?,
        batch_size: geti("batch_size")?,
        random_rollout_steps: geti("random_rollout_steps")?,
        n_params: geti("n_params")?,
        metric_fields: gets("metric_fields")?,
        train_metric_fields: gets("train_metric_fields")?,
        eval_metric_fields: gets("eval_metric_fields")?,
    };
    let mut programs = BTreeMap::new();
    for (name, pj) in j
        .get("programs")
        .and_then(Json::as_obj)
        .context("variant programs")?
    {
        let parse_leaves = |field: &str| -> Result<Vec<LeafSpec>> {
            pj.get(field)
                .and_then(Json::as_arr)
                .context(format!("{name}.{field}"))?
                .iter()
                .map(LeafSpec::parse)
                .collect()
        };
        programs.insert(
            name.clone(),
            ProgramSpec {
                name: name.clone(),
                file: dir.join(pj.get("file").and_then(Json::as_str).context("file")?),
                inputs: parse_leaves("inputs")?,
                outputs: parse_leaves("outputs")?,
            },
        );
    }
    Ok(Variant { key: key.to_string(), meta, programs })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
          "format": 1,
          "variants": {
            "v_e2": {
              "meta": {
                "obs_dim": 10, "n_ports": 3, "n_chargers": 2, "n_dc": 1,
                "action_nvec": [11, 11, 21], "steps_per_episode": 288,
                "num_envs": 2, "rollout_steps": 4, "batch_size": 8,
                "random_rollout_steps": 16, "n_params": 100,
                "metric_fields": ["reward"],
                "train_metric_fields": ["mean_reward"],
                "eval_metric_fields": ["ep_reward"]
              },
              "programs": {
                "train_init": {
                  "file": "train_init_v_e2.hlo.txt",
                  "inputs": [{"name": "seed", "shape": [], "dtype": "u32"}],
                  "outputs": [{"name": "params.w1", "shape": [10, 4], "dtype": "f32"}]
                }
              }
            }
          }
        }"#
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("chargax_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant("v_e2").unwrap();
        assert_eq!(v.meta.action_nvec, vec![11, 11, 21]);
        assert_eq!(v.meta.num_envs, 2);
        let p = v.program("train_init").unwrap();
        assert_eq!(p.inputs[0].dtype, DType::U32);
        assert_eq!(p.outputs[0].elem_count(), 40);
        assert!(m.variant("nope").is_err());
        assert!(v.program("nope").is_err());
    }
}
