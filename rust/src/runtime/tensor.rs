//! Host-side tensor <-> xla::Literal conversion.
//!
//! `Tensor` is the coordinator's plain-old-data view of a leaf (flat data +
//! shape + dtype); literals are built once per upload and reused across
//! executions (PJRT keeps its own device copy).

use anyhow::{anyhow, bail, Result};

use super::manifest::{DType, LeafSpec};

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn zeros(spec: &LeafSpec) -> Tensor {
        let n = spec.elem_count();
        match spec.dtype {
            DType::F32 => Tensor::F32 { shape: spec.shape.clone(), data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { shape: spec.shape.clone(), data: vec![0; n] },
            DType::U32 => Tensor::U32 { shape: spec.shape.clone(), data: vec![0; n] },
        }
    }

    pub fn scalar_u32(v: u32) -> Tensor {
        Tensor::U32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elems, got {}", data.len());
        }
        Ok(Tensor::F32 { shape, data })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elems, got {}", data.len());
        }
        Ok(Tensor::I32 { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::U32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
            Tensor::U32 { .. } => DType::U32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
            Tensor::U32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Check against a manifest leaf spec.
    pub fn matches(&self, spec: &LeafSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
            Tensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            }),
            xla::ElementType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?,
            }),
            xla::ElementType::U32 => Ok(Tensor::U32 {
                shape: dims,
                data: lit.to_vec::<u32>().map_err(|e| anyhow!("{e}"))?,
            }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_match_spec() {
        let spec = LeafSpec {
            name: "x".into(),
            shape: vec![2, 3],
            dtype: DType::F32,
        };
        let t = Tensor::zeros(&spec);
        assert_eq!(t.len(), 6);
        assert!(t.matches(&spec));
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::f32(vec![2, 2], vec![1.0; 3]).is_err());
        assert!(Tensor::i32(vec![3], vec![1, 2, 3]).is_ok());
    }

    #[test]
    fn scalar_roundtrip_shape() {
        let t = Tensor::scalar_u32(7);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.len(), 1);
    }
}
