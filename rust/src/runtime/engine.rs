//! PJRT engine: load HLO-text artifacts, compile once, execute many.
//!
//! Adapted from /opt/xla-example/load_hlo/. All programs are lowered with
//! `return_tuple=True`, so execution yields a single tuple literal that we
//! decompose into output leaves. Compilation results are cached per
//! program file.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::ProgramSpec;
use super::tensor::Tensor;

/// Wrapper shared by every coordinator component. `Engine` is `Sync`
/// behind a mutex on the executable cache only; execution itself takes
/// `&self`.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ProgramSpec,
    pub compile_time_s: f64,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile a program (cached by program file path).
    pub fn load(&self, spec: &ProgramSpec) -> Result<std::sync::Arc<Executable>> {
        let key = spec.file.display().to_string();
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let exe = std::sync::Arc::new(self.compile(spec)?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    fn compile(&self, spec: &ProgramSpec) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", spec.file.display()))?;
        Ok(Executable {
            exe,
            spec: spec.clone(),
            compile_time_s: t0.elapsed().as_secs_f64(),
        })
    }
}

impl Executable {
    /// Execute with pre-built input literals (fast path: literals for
    /// static inputs are built once by the caller and reused; `execute`
    /// borrows, so carry literals can be passed as references).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let bufs = self
            .exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow!("executing {}: {e}", self.spec.name))?;
        let tuple = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", self.spec.name))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("decomposing result of {}: {e}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            return Err(anyhow!(
                "{}: manifest promises {} outputs, program returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            ));
        }
        Ok(parts)
    }

    /// Execute with host tensors (convenience path; validates against the
    /// manifest specs).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if !t.matches(spec) {
                return Err(anyhow!(
                    "{}: input '{}' wants {:?} {:?}, got {:?} {:?}",
                    self.spec.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape()
                ));
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<_>>()?;
        let outs = self.run_literals(&lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }
}

/// Resolve the artifacts dir: $CHARGAX_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("CHARGAX_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| Path::new("artifacts").to_path_buf())
}
