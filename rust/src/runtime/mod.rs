//! PJRT runtime: manifest-driven loading and execution of the HLO-text
//! artifacts produced by `python/compile/aot.py`.
//! Adapted from /opt/xla-example/load_hlo/.

pub mod engine;
pub mod manifest;
pub mod tensor;
