//! Execution runtimes.
//!
//! * [`engine`]/[`manifest`]/[`tensor`] — the PJRT runtime: manifest-driven
//!   loading and execution of the HLO-text artifacts produced by
//!   `python/compile/aot.py` (adapted from /opt/xla-example/load_hlo/).
//! * [`pool`] — the persistent worker-pool rollout runtime that drives the
//!   native `VectorEnv` fast path (no per-step thread spawning).

pub mod engine;
pub mod manifest;
pub mod pool;
pub mod tensor;
