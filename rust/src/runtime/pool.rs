//! Persistent worker-pool rollout runtime.
//!
//! [`super::super::env::vector::VectorEnv`] originally sharded every
//! `step_all` call across freshly spawned scoped OS threads. Thread
//! creation costs tens of microseconds, so at small-to-medium batch sizes
//! dispatch — not simulation — dominated wall-clock, exactly the overhead
//! the paper's on-device rollouts avoid. This module replaces per-step
//! spawning with a pool of long-lived, shard-pinned workers:
//!
//! * Workers are spawned once and **parked on a condvar** between calls.
//! * Each call publishes a job under a mutex, bumps an **epoch counter**,
//!   and wakes the pool; worker `w` runs shard `w + 1` while the caller
//!   thread runs shard `0` (no idle caller core, one fewer wakeup).
//! * The caller blocks until every participating shard has checked in, so
//!   borrowed state handed to the job provably outlives its use — that
//!   containment is what makes the single lifetime-erasing `transmute`
//!   below sound.
//!
//! The job is a plain `Fn(usize) + Sync` closure over the shard index;
//! callers keep full control of how state is split (see
//! `VectorEnv::shard_tasks`). Results are bit-identical to the scoped
//! fallback for the same shard count because the pool changes *where* a
//! shard runs, never *what* it computes.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::telemetry;

/// Process-wide opt-in for worker core pinning (`--pin_cores true`).
/// Read once by each worker at spawn, so set it BEFORE the first pool is
/// built (main.rs does, right after parsing the run config). Pinning only
/// constrains where a worker runs — never what it computes — so results
/// are bit-identical either way.
static PIN_CORES: AtomicBool = AtomicBool::new(false);

/// Enable/disable core pinning for workers of pools built after this call.
pub fn set_pin_cores(on: bool) {
    PIN_CORES.store(on, Ordering::Relaxed);
}

/// Whether worker core pinning is currently requested.
pub fn pin_cores_enabled() -> bool {
    PIN_CORES.load(Ordering::Relaxed)
}

/// Pin the calling thread to `core` via a raw `sched_setaffinity` syscall
/// (no libc dependency). Best-effort: failures (cpuset limits, exotic
/// topologies, core >= 1024) are silently ignored — pinning is a cache /
/// scheduler-migration optimization, never a correctness requirement.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn pin_current_thread(core: usize) {
    let mut mask = [0u64; 16]; // cpu_set_t-sized: up to 1024 CPUs
    if core / 64 >= mask.len() {
        return;
    }
    mask[core / 64] = 1u64 << (core % 64);
    let size = std::mem::size_of_val(&mask);
    // pid 0 = the calling thread. x86_64 __NR_sched_setaffinity = 203,
    // aarch64 = 122.
    unsafe {
        #[cfg(target_arch = "x86_64")]
        {
            let ret: isize;
            std::arch::asm!(
                "syscall",
                inlateout("rax") 203isize => ret,
                in("rdi") 0usize,
                in("rsi") size,
                in("rdx") mask.as_ptr(),
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
            let _ = ret;
        }
        #[cfg(target_arch = "aarch64")]
        {
            let ret: isize;
            std::arch::asm!(
                "svc 0",
                in("x8") 122usize,
                inlateout("x0") 0usize => ret,
                in("x1") size,
                in("x2") mask.as_ptr(),
                options(nostack)
            );
            let _ = ret;
        }
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn pin_current_thread(_core: usize) {}

/// Type-erased reference to the caller's job closure. Only alive between
/// job publication and the last shard check-in; `run` does not return
/// until then.
#[derive(Clone, Copy)]
struct Job(&'static (dyn Fn(usize) + Sync));

/// Shared handle to a `&mut [T]` whose elements are visited at most once
/// per dispatch, each by exactly one thread — the lock-free replacement
/// for the old `Vec<Mutex<&mut T>>` wrappers the strided dispatchers used
/// to build per call. Those wrappers put an uncontended-but-real mutex
/// acquisition inside every shard task, violating the telemetry budget's
/// no-locks-on-the-hot-path rule; this is a raw pointer plus a length.
///
/// The aliasing discipline is the caller's: [`WorkerPool::run`] hands
/// each shard index to exactly one thread, and [`WorkerPool::run_strided`]
/// visits each item index exactly once — so indexing by shard/item is
/// exclusive by construction, the same argument `VectorEnv::shard_tasks`
/// makes for its disjoint lane blocks.
pub struct DisjointTasks<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: the wrapper only hands out `&mut T` through `get`, whose
// contract (below) requires exclusive per-index access; with that upheld,
// sharing the handle across threads moves `T` values between threads,
// which `T: Send` licenses.
unsafe impl<T: Send> Sync for DisjointTasks<'_, T> {}
unsafe impl<T: Send> Send for DisjointTasks<'_, T> {}

impl<'a, T> DisjointTasks<'a, T> {
    pub fn new(tasks: &'a mut [T]) -> DisjointTasks<'a, T> {
        DisjointTasks { ptr: tasks.as_mut_ptr(), len: tasks.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exclusive access to element `i`.
    ///
    /// # Safety
    /// For the lifetime of the returned reference no other thread may
    /// call `get(i)` for the same index. Dispatching through
    /// [`WorkerPool::run`] (one thread per shard index) or
    /// [`WorkerPool::run_strided`] (each item visited exactly once)
    /// upholds this when `i` is the shard/item index.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len, "disjoint task index {i} out of {}", self.len);
        &mut *self.ptr.add(i)
    }
}

struct State {
    /// Bumped once per dispatched job; workers detect work by comparing
    /// against the last epoch they served (state-based, no lost wakeups).
    epoch: u64,
    /// Telemetry dispatch id for the current job (0 = telemetry off):
    /// every shard of one job tags its `PoolShard` span with the same id
    /// so the profiler can compute per-epoch imbalance. Published under
    /// the state lock alongside the job, read by workers with it.
    tele_seq: u64,
    job: Option<Job>,
    /// Shards in the current job (caller runs shard 0, workers 1..shards).
    shards: usize,
    /// Worker-run shards that have not finished yet.
    remaining: usize,
    /// Worker shards that panicked during the current job (caught so the
    /// worker survives and still checks in; re-raised on the caller).
    panics: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The caller parks here until `remaining == 0`.
    done: Condvar,
}

/// State of the pool's single pipeline lane: a completion-epoch pair
/// (`submitted`/`completed` tickets) alongside the barrier protocol, so
/// one job can stream on the lane while the submitting thread keeps
/// doing other work and joins later.
struct PipeState {
    /// The pending job, if the lane has not picked it up yet.
    job: Option<Box<dyn FnOnce() + Send>>,
    /// Tickets handed out (== the in-flight job's ticket once submitted).
    submitted: u64,
    /// Tickets fully executed; `completed == submitted` means idle.
    completed: u64,
    /// Ticket whose job panicked (re-raised on the joiner), if any.
    panicked: Option<u64>,
    shutdown: bool,
}

struct PipeShared {
    state: Mutex<PipeState>,
    /// The pipeline thread parks here between jobs.
    work: Condvar,
    /// Joiners (and the next submitter) park here until their ticket
    /// completes.
    done: Condvar,
}

/// A pool of `threads - 1` persistent workers supporting up to `threads`
/// concurrent shards (the calling thread is shard 0). Construction is the
/// only time OS threads are created; `run` is wake + park.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes `run` calls: the epoch/job-slot protocol supports one
    /// in-flight job, so concurrent callers (e.g. one pool shared by
    /// several envs) queue here instead of corrupting each other's
    /// `remaining` counts.
    dispatch: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
    /// The lazily-spawned pipeline lane ([`WorkerPool::run_pipelined`]):
    /// one extra thread that executes streamed jobs — which themselves
    /// dispatch `run` calls onto this pool — while the submitting thread
    /// continues. `None` until the first pipelined submission.
    pipe: Mutex<Option<PipeLane>>,
}

struct PipeLane {
    shared: Arc<PipeShared>,
    handle: Option<JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool sized for `threads` total execution lanes (clamped to >= 1).
    /// `threads == 1` spawns no workers; `run` then executes inline.
    pub fn new(threads: usize) -> WorkerPool {
        let workers = threads.max(1) - 1;
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                tele_seq: 0,
                job: None,
                shards: 0,
                remaining: 0,
                panics: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("chargax-pool-{w}"))
                    .spawn(move || worker_loop(w, &shared))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, dispatch: Mutex::new(()), handles, pipe: Mutex::new(None) }
    }

    /// Maximum shard count `run` accepts (workers + the caller thread).
    pub fn max_shards(&self) -> usize {
        self.handles.len() + 1
    }

    /// Execute `f(shard)` for every shard in `0..shards`, blocking until
    /// all complete. Shard 0 runs on the calling thread. `shards` must be
    /// `<= max_shards()`; shard indices are stable, so a caller splitting
    /// state into `shards` disjoint chunks gets exactly one visitor per
    /// chunk.
    pub fn run<F: Fn(usize) + Sync>(&self, shards: usize, f: F) {
        assert!(
            shards <= self.max_shards(),
            "pool of {} lanes cannot run {shards} shards",
            self.max_shards()
        );
        if shards <= 1 {
            if shards == 1 {
                // Inline dispatch still opens a telemetry shard scope so
                // fine spans inside shard tasks record at --threads 1.
                let _scope = telemetry::shard_scope(0, telemetry::dispatch_seq());
                f(0);
            }
            return;
        }
        // One job in flight at a time; a second caller blocks here until
        // the current job fully drains (tolerate poisoning — WaitGuard has
        // already restored protocol state on any panicking path).
        let _dispatch = self.dispatch.lock().unwrap_or_else(|e| e.into_inner());
        let seq = telemetry::dispatch_seq();
        // SAFETY: the erased reference is only reachable through
        // `State.job`, workers only call it between this publication and
        // their check-in, and control cannot leave this function — by
        // return OR by unwind (`WaitGuard`) — until `remaining == 0`,
        // i.e. after every participating worker has checked in. Workers
        // catch their shard's panics, so check-in always happens, and
        // `_dispatch` above keeps a second caller from republishing the
        // job slot while this one is in flight.
        let job: &(dyn Fn(usize) + Sync) = &f;
        let job: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(job) };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.tele_seq = seq;
            st.job = Some(Job(job));
            st.shards = shards;
            st.remaining = shards - 1;
            st.panics = 0;
            self.shared.work.notify_all();
        }
        /// Blocks until every worker shard has checked in, then clears the
        /// job — runs on normal exit AND when shard 0 panics below, so the
        /// erased closure provably outlives all worker access.
        struct WaitGuard<'a>(&'a Shared);
        impl Drop for WaitGuard<'_> {
            fn drop(&mut self) {
                let mut st = self.0.state.lock().unwrap();
                while st.remaining > 0 {
                    st = self.0.done.wait(st).unwrap();
                }
                st.job = None;
            }
        }
        {
            let _guard = WaitGuard(&self.shared);
            // Scope declared after the guard: its span (and flush) ends
            // when shard 0's own work does, before waiting on workers.
            let _scope = telemetry::shard_scope(0, seq);
            f(0);
        }
        let panics = self.shared.state.lock().unwrap().panics;
        if panics > 0 {
            panic!("{panics} worker shard(s) panicked during a pool job (see stderr)");
        }
    }

    /// Execute `f(lane, item)` for every `item in 0..n`, striding items
    /// over at most `max_shards()` concurrent pool lanes: lane `s` runs
    /// items `s, s + width, s + 2·width, …` in order. This is the shared
    /// dispatch shape for work lists that can outnumber pool lanes — the
    /// fleet rollout's per-family shard tasks and the sharded PPO update's
    /// gradient chunks both go through it. Item-to-lane placement never
    /// changes what an item computes (each item owns disjoint outputs;
    /// per-lane state like scratch buffers is fully overwritten per item),
    /// so results are identical for any pool width. With one lane or
    /// `n <= 1` everything runs inline on the caller.
    pub fn run_strided<F: Fn(usize, usize) + Sync>(&self, n: usize, f: F) {
        let width = self.max_shards().min(n);
        if width <= 1 {
            for k in 0..n {
                f(0, k);
            }
            return;
        }
        self.run(width, |s| {
            let mut k = s;
            while k < n {
                f(s, k);
                k += width;
            }
        });
    }

    /// Submit `f` to the pool's pipeline lane and return immediately with
    /// a guard whose [`PipelineGuard::join`] (or drop) blocks until the
    /// job completes. This is the non-blocking counterpart of [`run`]:
    /// the job runs on one persistent pipeline thread — typically calling
    /// `run`/`run_strided` on this same pool with itself as shard 0, the
    /// `dispatch` mutex serializing it against any other caller — while
    /// the submitting thread overlaps independent work (accounting, stats
    /// assembly, greedy eval) before joining.
    ///
    /// One job in flight at a time: a second submission blocks until the
    /// first completes. A panicking job is caught on the lane and
    /// re-raised from `join`/drop, and the lane survives for future jobs.
    ///
    /// # Safety
    /// `f` may borrow from the caller's stack (`'env`). The caller must
    /// let the returned guard run to completion — by `join()` or by
    /// letting it go out of scope — before any borrow in `f` ends, and
    /// must never leak the guard (`std::mem::forget` and friends), since
    /// the guard's drop is what proves the erased closure outlives its
    /// borrows (the same containment argument as `run`'s transmute,
    /// enforced there by blocking inside the call).
    pub unsafe fn run_pipelined<'env, F>(&self, f: F) -> PipelineGuard
    where
        F: FnOnce() + Send + 'env,
    {
        let shared = {
            let mut lane = self.pipe.lock().unwrap();
            let lane = lane.get_or_insert_with(|| {
                let shared = Arc::new(PipeShared {
                    state: Mutex::new(PipeState {
                        job: None,
                        submitted: 0,
                        completed: 0,
                        panicked: None,
                        shutdown: false,
                    }),
                    work: Condvar::new(),
                    done: Condvar::new(),
                });
                let thread_shared = Arc::clone(&shared);
                let handle = std::thread::Builder::new()
                    .name("chargax-pipeline".into())
                    .spawn(move || pipeline_loop(&thread_shared))
                    .expect("spawning pipeline lane");
                PipeLane { shared, handle: Some(handle) }
            });
            Arc::clone(&lane.shared)
        };
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY (of the transmute): the erased box is only reachable
        // through `PipeState.job`, the lane executes it before bumping
        // `completed`, and the caller (per this function's contract)
        // keeps the guard alive until `completed` reaches its ticket —
        // so every borrow in the closure outlives its use.
        let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
        let ticket = {
            let mut st = shared.state.lock().unwrap();
            while st.completed < st.submitted {
                st = shared.done.wait(st).unwrap();
            }
            st.submitted += 1;
            st.job = Some(job);
            shared.work.notify_one();
            st.submitted
        };
        PipelineGuard { shared, ticket, joined: false }
    }
}

/// Completion handle for one [`WorkerPool::run_pipelined`] job. Joining
/// (explicitly or on drop) blocks until the job's ticket completes and
/// re-raises its panic, if any.
pub struct PipelineGuard {
    shared: Arc<PipeShared>,
    ticket: u64,
    joined: bool,
}

impl PipelineGuard {
    /// Block until the pipelined job completes; re-raises its panic.
    pub fn join(mut self) {
        self.wait();
    }

    fn wait(&mut self) {
        if self.joined {
            return;
        }
        self.joined = true;
        let panicked = {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            while st.completed < self.ticket {
                st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.panicked == Some(self.ticket)
        };
        if panicked && !std::thread::panicking() {
            panic!("pipelined job panicked (see stderr)");
        }
    }
}

impl Drop for PipelineGuard {
    fn drop(&mut self) {
        self.wait();
    }
}

fn pipeline_loop(shared: &PipeShared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                // Drain a pending job even under shutdown so a joiner
                // waiting on its ticket can never hang.
                if let Some(job) = st.job.take() {
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        // Catch job panics so `completed` always advances (a lost bump
        // would hang the joiner forever) and the lane stays alive; the
        // joiner re-raises. The default panic hook already printed.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.completed += 1;
        if result.is_err() {
            st.panicked = Some(st.completed);
        }
        shared.done.notify_all();
    }
}

/// Pick a pool with at least `width.min(threads)` lanes for auxiliary
/// caller-driven compute (the sharded PPO update): reuse `primary` (the
/// rollout pool) when it is already wide enough, otherwise lazily grow
/// `aux`. NEVER grows `primary` — its width sets how many workers every
/// per-step rollout dispatch `notify_all`-wakes, so inflating it would
/// tax the hot path with spurious wake/park cycles. Returns `None` when
/// a single lane suffices. One implementation shared by
/// `VectorEnv::shared_pool` and `Fleet::update_pool`, so the two runtimes
/// cannot drift.
pub fn aux_or_primary_pool(
    primary: &Option<Arc<WorkerPool>>,
    aux: &mut Option<Arc<WorkerPool>>,
    threads: usize,
    width: usize,
) -> Option<Arc<WorkerPool>> {
    let w = width.min(threads.max(1));
    if w <= 1 {
        return None;
    }
    if let Some(p) = primary {
        if p.max_shards() >= w {
            return Some(Arc::clone(p));
        }
    }
    let rebuild = match &*aux {
        Some(p) => p.max_shards() < w,
        None => true,
    };
    if rebuild {
        *aux = Some(Arc::new(WorkerPool::new(w)));
    }
    aux.as_ref().map(Arc::clone)
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Pipeline lane first: its jobs dispatch onto the workers below.
        if let Some(mut lane) = self.pipe.lock().unwrap_or_else(|e| e.into_inner()).take() {
            {
                let mut st = lane.shared.state.lock().unwrap_or_else(|e| e.into_inner());
                st.shutdown = true;
                lane.shared.work.notify_all();
            }
            if let Some(h) = lane.handle.take() {
                let _ = h.join();
            }
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(w: usize, shared: &Shared) {
    // Worker `w` always runs shard `w + 1` (the caller owns shard 0), so
    // with pinning on it claims core `(w + 1) % ncpus` — a stable
    // shard-to-core map that keeps each shard's SoA lane block hot in one
    // core's private cache across steps and stops scheduler migration.
    if pin_cores_enabled() {
        let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        pin_current_thread((w + 1) % ncpu);
    }
    let mut seen = 0u64;
    loop {
        let (job, shards, seq) = {
            let mut st = shared.state.lock().unwrap();
            while !st.shutdown && st.epoch == seen {
                st = shared.work.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            seen = st.epoch;
            match st.job {
                Some(job) => (job, st.shards, st.tele_seq),
                // Stale wake: this worker did not participate in `seen`'s
                // job and only woke after the caller already cleared it.
                // (Participants always observe their epoch's job — the
                // caller cannot clear it until they check in.)
                None => continue,
            }
        };
        let mine = w + 1; // caller thread owns shard 0
        if mine < shards {
            // Catch shard panics so this worker always checks in (a lost
            // decrement would hang the caller on `done` forever) and stays
            // alive for future jobs; the caller re-raises after the job.
            // The default panic hook has already printed the message.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _scope = telemetry::shard_scope(mine as u32, seq);
                (job.0)(mine)
            }));
            let mut st = shared.state.lock().unwrap();
            if result.is_err() {
                st.panics += 1;
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                shared.done.notify_one();
            }
        }
    }
}

// SAFETY: `Job` holds a shared reference to a `Sync` closure; sending the
// reference across threads is exactly what `Sync` licenses.
unsafe impl Send for Job {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_shard_runs_exactly_once() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.max_shards(), 4);
        for shards in 1..=4usize {
            let hits: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
            pool.run(shards, |s| {
                hits[s].fetch_add(1, Ordering::SeqCst);
            });
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "shard {s} of {shards}");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..500 {
            pool.run(3, |_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 1500);
    }

    #[test]
    fn mutates_disjoint_caller_state() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0u64; 1024];
        let chunks: Vec<Mutex<&mut [u64]>> =
            data.chunks_mut(256).map(Mutex::new).collect();
        pool.run(chunks.len(), |s| {
            for x in chunks[s].lock().unwrap().iter_mut() {
                *x = s as u64 + 1;
            }
        });
        drop(chunks);
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, (i / 256) as u64 + 1);
        }
    }

    #[test]
    fn concurrent_callers_are_serialized() {
        let pool = Arc::new(WorkerPool::new(3));
        let total = Arc::new(AtomicUsize::new(0));
        let joins: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        pool.run(3, |_| {
                            total.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 1200);
    }

    #[test]
    fn shard_panics_propagate_and_pool_survives() {
        let pool = WorkerPool::new(2);
        // Worker-shard panic: must not hang the caller, must re-raise.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, |s| {
                if s == 1 {
                    panic!("worker shard boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate to the caller");
        // Caller-shard panic: guard must wait for workers, then unwind.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, |s| {
                if s == 0 {
                    panic!("caller shard boom");
                }
            });
        }));
        assert!(r.is_err());
        // The pool is still fully functional afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(2, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn strided_dispatch_runs_every_item_once_within_width() {
        let pool = WorkerPool::new(3);
        // More items than lanes, fewer items than lanes, and n = 0/1.
        for n in [0usize, 1, 2, 3, 11] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_strided(n, |lane, k| {
                assert!(lane < pool.max_shards(), "lane {lane} out of range");
                assert_eq!(k % pool.max_shards().min(n), lane, "stride placement");
                hits[k].fetch_add(1, Ordering::SeqCst);
            });
            for (k, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "item {k} of {n}");
            }
        }
        // A 1-lane pool runs everything inline on lane 0.
        let inline = WorkerPool::new(1);
        let hits = AtomicUsize::new(0);
        inline.run_strided(5, |lane, _| {
            assert_eq!(lane, 0);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    /// Pinned pools must behave identically to unpinned ones (pinning
    /// only constrains placement). Exercises the flag round trip and a
    /// full job on a pool whose workers pinned themselves at spawn.
    #[test]
    fn pinned_pool_runs_jobs_and_flag_round_trips() {
        assert!(!pin_cores_enabled(), "pinning must default off");
        set_pin_cores(true);
        assert!(pin_cores_enabled());
        let pool = WorkerPool::new(3);
        set_pin_cores(false);
        assert!(!pin_cores_enabled());
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.run(3, |s| {
                hits[s].fetch_add(1, Ordering::SeqCst);
            });
        }
        for (s, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 50, "shard {s}");
        }
    }

    /// The lock-free task handle: every item mutated exactly once through
    /// a strided dispatch, no Mutex anywhere — the dispatch shape all four
    /// hot-path task runners (fleet/vector shard tasks, ppo/generalist
    /// gradient chunks) now use.
    #[test]
    fn disjoint_tasks_mutate_every_item_once_without_locks() {
        let pool = WorkerPool::new(4);
        for n in [1usize, 3, 4, 17] {
            let mut items: Vec<u64> = vec![0; n];
            let shared = DisjointTasks::new(&mut items);
            assert_eq!(shared.len(), n);
            assert!(!shared.is_empty());
            pool.run_strided(shared.len(), |_, k| {
                // SAFETY: run_strided visits each item index exactly once.
                let item = unsafe { shared.get(k) };
                *item += k as u64 + 1;
            });
            for (k, &x) in items.iter().enumerate() {
                assert_eq!(x, k as u64 + 1, "item {k} of {n}");
            }
        }
        // Per-lane state (the scratch-buffer pattern): each lane index is
        // owned by exactly one OS thread per dispatch.
        let mut lanes: Vec<usize> = vec![0; pool.max_shards()];
        let scr = DisjointTasks::new(&mut lanes);
        pool.run_strided(64, |lane, _| {
            // SAFETY: `lane` is this OS thread's shard index for the
            // whole dispatch — exclusive by the pool's shard contract.
            unsafe { *scr.get(lane) += 1 };
        });
        assert_eq!(lanes.iter().sum::<usize>(), 64);
    }

    /// The pipeline lane: a submitted job runs to completion while the
    /// submitter keeps working, borrows of caller state are released by
    /// join, the lane is reusable, and a second submission waits for the
    /// first (one in flight).
    #[test]
    fn pipelined_jobs_complete_and_lane_is_reusable() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 256];
        for round in 1..=3u64 {
            let guard = unsafe {
                pool.run_pipelined(|| {
                    // The pipelined job itself dispatches onto the pool.
                    let chunks = DisjointTasks::new(&mut data);
                    pool.run_strided(chunks.len(), |_, k| {
                        // SAFETY: each item visited exactly once.
                        unsafe { *chunks.get(k) += round };
                    });
                })
            };
            guard.join();
            let want: u64 = (1..=round).sum();
            assert!(data.iter().all(|&x| x == want), "round {round}");
        }
        // Implicit join on drop.
        let flag = AtomicUsize::new(0);
        {
            let _guard = unsafe {
                pool.run_pipelined(|| {
                    flag.store(7, Ordering::SeqCst);
                })
            };
        }
        assert_eq!(flag.load(Ordering::SeqCst), 7);
    }

    /// A panicking pipelined job re-raises on join and leaves the lane
    /// (and pool) fully functional.
    #[test]
    fn pipelined_panic_propagates_and_lane_survives() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let guard = unsafe { pool.run_pipelined(|| panic!("pipeline boom")) };
            guard.join();
        }));
        assert!(r.is_err(), "pipelined panic must propagate to the joiner");
        let hit = AtomicUsize::new(0);
        let guard = unsafe {
            pool.run_pipelined(|| {
                hit.store(1, Ordering::SeqCst);
            })
        };
        guard.join();
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.max_shards(), 1);
        let hit = AtomicUsize::new(0);
        pool.run(1, |s| {
            assert_eq!(s, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }
}
