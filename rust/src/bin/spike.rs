// Spike: load HLO text with PRNG+scan+multi-output, execute, feed outputs back.
use anyhow::Result;

fn main() -> Result<()> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("platform={}", client.platform_name());
    let proto = xla::HloModuleProto::from_text_file("/tmp/spike.hlo.txt")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("{e}"))?;

    let key = xla::Literal::vec1(&[0u32, 0u32]);
    let x = xla::Literal::vec1(&[0f32; 4]);
    let result = exe
        .execute::<xla::Literal>(&[key, x])
        .map_err(|e| anyhow::anyhow!("{e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("n outputs: {}", parts.len());
    for (i, p) in parts.iter().enumerate() {
        println!("  out[{i}]: {:?}", p.shape());
    }
    let key_out = parts[0].to_vec::<u32>().map_err(|e| anyhow::anyhow!("{e}"))?;
    let x_out = parts[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
    let ys = parts[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("key={key_out:?} x={x_out:?} ys={ys:?}");
    assert_eq!(key_out, vec![3952908011u32, 3835524538u32]);
    assert_eq!(x_out, vec![13.0, 15.0, 24.0, 8.0]);
    assert_eq!(ys, vec![10.0, 21.0, 33.0, 49.0, 60.0]);

    // feed carry back: inputs (key, x) <- outputs (key, x)
    let mut parts = parts;
    let x2 = parts.remove(1);
    let k2 = parts.remove(0);
    let result2 = exe
        .execute::<xla::Literal>(&[k2, x2])
        .map_err(|e| anyhow::anyhow!("{e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let parts2 = result2.to_tuple().map_err(|e| anyhow::anyhow!("{e}"))?;
    let x_out2 = parts2[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("x after feedback={x_out2:?}");
    println!("spike OK");
    Ok(())
}
