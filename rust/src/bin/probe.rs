// Debug probe: run env_reset and report non-finite observation entries.
use anyhow::Result;
use chargax::data::{DataStore, Scenario};
use chargax::runtime::engine::{artifacts_dir, Engine};
use chargax::runtime::manifest::Manifest;
use chargax::runtime::tensor::Tensor;

fn main() -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let store = DataStore::load(&artifacts_dir().join("data"))?;
    let v = manifest.variant("mix10dc6ac_e12")?;
    let engine = Engine::cpu()?;
    let reset = engine.load(v.program("env_reset")?)?;
    let exog: Vec<xla::Literal> = Scenario::default()
        .to_tensors(&store)?
        .iter()
        .map(|t| t.to_literal().unwrap())
        .collect();
    let seed = Tensor::scalar_u32(42).to_literal()?;
    let mut ins: Vec<&xla::Literal> = vec![&seed];
    ins.extend(exog.iter());
    let outs = reset.run_literals(&ins)?;
    for (spec, lit) in v.program("env_reset")?.outputs.iter().zip(&outs) {
        let t = Tensor::from_literal(lit)?;
        match &t {
            Tensor::F32 { data, .. } => {
                let bad = data.iter().filter(|x| !x.is_finite()).count();
                let mn = data.iter().cloned().fold(f32::INFINITY, f32::min);
                let mx = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                println!("{:<24} f32 {:?} bad={} range=[{:.3},{:.3}]", spec.name, t.shape(), bad, mn, mx);
                if bad > 0 && spec.name == "obs" {
                    for (i, x) in data.iter().enumerate().filter(|(_, x)| !x.is_finite()).take(200) {
                        println!("   obs[{}] (col {}) = {}", i, i % 107, x);
                    }
                }
            }
            Tensor::I32 { data, .. } => {
                println!("{:<24} i32 {:?} first={:?}", spec.name, t.shape(), &data[..data.len().min(4)]);
            }
            Tensor::U32 { data, .. } => {
                println!("{:<24} u32 {:?} first={:?}", spec.name, t.shape(), &data[..data.len().min(4)]);
            }
        }
    }
    Ok(())
}
